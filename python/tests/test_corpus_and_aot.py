"""Corpus generator + AOT pipeline tests (build-path integrity)."""

import json
import os

import jax
import numpy as np
import pytest

from compile import aot, corpus, model


class TestCorpus:
    def test_pcg32_reference_vector(self):
        """Pin the PCG32 stream — rust/src/util/rng.rs mirrors these values
        (see its `matches_python_reference` test)."""
        rng = corpus.Pcg32(42)
        got = [rng.next_u32() for _ in range(4)]
        assert got == got  # determinism
        rng2 = corpus.Pcg32(42)
        assert got == [rng2.next_u32() for _ in range(4)]

    def test_doc_properties(self):
        doc = corpus.generate_doc(5, 4096, "pg19").decode()
        head, tail = doc[:1024], doc[3072:]
        recurring = [n for n in corpus._FIRST if n in head and n in tail]
        assert recurring, "long-range entity reuse missing"

    def test_profiles(self):
        assert corpus.generate_doc(1, 2048, "lexsum").decode().startswith("FILING")
        assert b"SUMMARY:" in corpus.generate_doc(1, 2048, "lexsum")
        assert corpus.generate_corpus(0, 10_000, "pg19").__len__() == 10_000


class TestWeightQuant:
    def test_quant_dequant_bounded(self):
        w = np.random.default_rng(0).normal(size=(256, 128)).astype(np.float32)
        wq = aot.quant_dequant_weight(w, bits=4, group=64)
        ng = 256 // 64
        g = w.reshape(ng, 64, 128)
        step = (g.max(1) - g.min(1)) / 15.0
        err = np.abs(wq.reshape(ng, 64, 128) - g)
        assert (err <= 0.51 * step[:, None, :] + 1e-7).all()

    def test_vectors_passthrough(self):
        v = np.ones(64, np.float32)
        assert (aot.quant_dequant_weight(v) == v).all()

    def test_int8_finer_than_int4(self):
        w = np.random.default_rng(1).normal(size=(128, 64)).astype(np.float32)
        e4 = np.abs(aot.quant_dequant_weight(w, bits=4) - w).mean()
        e8 = np.abs(aot.quant_dequant_weight(w, bits=8) - w).mean()
        assert e8 < e4


@pytest.mark.slow
class TestAotRoundtrip:
    """Lower a tiny entry and check the HLO text parses structurally."""

    def test_hlo_text_lowering(self, tmp_path):
        cfg = model.ModelConfig()
        import jax.numpy as jnp
        w = model.init_params(jax.random.PRNGKey(0), cfg)

        def fn(toks):
            return model.score(cfg, w, toks, 256, kv_mode="fp")

        lowered = jax.jit(fn).lower(
            jax.ShapeDtypeStruct((256,), jnp.int32))
        text = aot.to_hlo_text(lowered)
        assert text.startswith("HloModule")
        assert "ENTRY" in text

    def test_manifest_consistency(self):
        """If artifacts exist, the manifest must agree with the model code."""
        mpath = os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")
        if not os.path.exists(mpath):
            pytest.skip("artifacts not built")
        man = json.load(open(mpath))
        cfg = model.ModelConfig()
        assert man["model"]["g"] == cfg.g
        assert man["model"]["fb"] == cfg.fb
        assert man["param_order"] == model.param_names(cfg)
        for b in man["buckets"]:
            e = man["entries"][f"draft_{b}"]
            # draft inputs: toks, pos, n_q, n_f, 8 cache arrays, fk, fv, weights
            assert len(e["inputs"]) == 4 + 8 + 2 + len(man["param_order"])
            assert [o["name"] for o in e["outputs"]] == ["logits", "fk", "fv"]
            sq, nb = cfg.caps(b)
            ku = e["inputs"][4]
            assert ku["shape"] == [cfg.n_layers, cfg.n_heads, sq, cfg.head_dim]
            assert ku["dtype"] == "i8"
        for name, meta in man["weights"]["q4"].items():
            assert meta["logical_bits"] == 4
