"""L1 kernel correctness: Pallas vs pure-jnp oracle (the core signal).

Hypothesis sweeps shapes/modes; every case asserts bit-exact quantization
codes and allclose attention statistics.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import hier_quant, quant_attn, ref

SHAPES = st.tuples(
    st.integers(1, 4),        # H
    st.sampled_from([8, 16, 64]),  # G
    st.sampled_from([8, 16, 64]),  # dh
)


def rand(key, shape, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(key), shape)


def assert_codes_equivalent(u, l, s, z, ur, lr, sr, zr):
    """Pallas vs ref codes: scales match to fp tolerance; nibble codes may
    differ by one step on round-half ties (reduction-order ULP differences
    in min/max) for a vanishing fraction of elements; the reconstructed
    INT8 values must still agree to one scale step."""
    np.testing.assert_allclose(s, sr, rtol=1e-5)
    np.testing.assert_allclose(z, zr, rtol=1e-5, atol=1e-6)
    du = np.abs(np.asarray(u, np.int32) - np.asarray(ur, np.int32))
    assert du.max() <= 1 and (du > 0).mean() < 0.005, f"upper codes diverge"
    c8 = 16.0 * np.asarray(u, np.float32) + np.asarray(l, np.float32)
    c8r = 16.0 * np.asarray(ur, np.float32) + np.asarray(lr, np.float32)
    dc = np.abs(c8 - c8r)
    assert dc.max() <= 16.0 and (dc > 1.0).mean() < 0.005


class TestHierQuant:
    @settings(max_examples=20, deadline=None)
    @given(SHAPES, st.integers(0, 10_000))
    def test_key_quant_matches_ref(self, shape, seed):
        H, G, dh = shape
        k = rand(seed, (H, G, dh), 2.0)
        u, l, s, z = hier_quant.hier_quant_block_k(k)
        ur, lr, sr, zr = ref.hier_quant_block_k(k)
        assert_codes_equivalent(u, l, s, z, ur, lr, sr, zr)

    @settings(max_examples=20, deadline=None)
    @given(SHAPES, st.integers(0, 10_000))
    def test_value_quant_matches_ref(self, shape, seed):
        H, G, dh = shape
        v = rand(seed, (H, G, dh), 3.0)
        u, l, s, z = hier_quant.hier_quant_block_v(v)
        ur, lr, sr, zr = ref.hier_quant_block_v(v)
        assert_codes_equivalent(u, l, s, z, ur, lr, sr, zr)

    def test_nibble_ranges(self):
        k = rand(0, (2, 64, 64), 10.0)
        u, l, _, _ = hier_quant.hier_quant_block_k(k)
        assert int(u.min()) >= 0 and int(u.max()) <= 15
        assert int(l.min()) >= -8 and int(l.max()) <= 7

    def test_hierarchical_identity(self):
        """C8 = 16*C_U + C_L must reconstruct the direct INT8 code for
        values inside the representable range (paper §4.2)."""
        k = rand(1, (1, 64, 16))
        u, l, s, z = ref.hier_quant_block_k(k)
        c8 = 16.0 * u.astype(jnp.float32) + l.astype(jnp.float32)
        recon = c8 * s[:, None, :] + z[:, None, :]
        # interior values: reconstruction error <= S8 (clipped tail: 8*S8)
        err = jnp.abs(recon - k)
        frac_tight = float(jnp.mean(err <= 1.01 * s[:, None, :]))
        assert frac_tight > 0.95
        assert float(jnp.max(err / s[:, None, :])) <= 8.5

    def test_constant_block_safe(self):
        k = jnp.full((2, 16, 8), 3.25)
        u, l, s, z = hier_quant.hier_quant_block_k(k)
        deq = 16.0 * u.astype(jnp.float32) * s[:, None, :] + \
            l.astype(jnp.float32) * s[:, None, :] + z[:, None, :]
        np.testing.assert_allclose(deq, k, atol=1e-3)

    def test_draft_error_larger_than_target(self):
        k = rand(3, (2, 64, 32), 2.0)
        u, l, s, z = ref.hier_quant_block_k(k)
        nb_u = u[:, None]  # fake single-block region layout helpers
        d4 = ref.dequant_blocks_k(u, l, s[:, None, :], z[:, None, :], "draft")
        d8 = ref.dequant_blocks_k(u, l, s[:, None, :], z[:, None, :], "target")
        e4 = float(jnp.mean(jnp.abs(d4 - k)))
        e8 = float(jnp.mean(jnp.abs(d8 - k)))
        assert e8 < e4


class TestQuantAttn:
    def _build_region(self, seed, H, G, dh, nb):
        keys = []
        ku = kl = None
        ks_l, kz_l, vu_l, vl_l, vs_l, vz_l, ku_l = [], [], [], [], [], [], []
        kll = []
        for b in range(nb):
            k = rand(seed * 100 + b, (H, G, dh), 1.5)
            v = rand(seed * 100 + 50 + b, (H, G, dh), 1.5)
            u, l, s, z = ref.hier_quant_block_k(k)
            uv, lv, sv, zv = ref.hier_quant_block_v(v)
            ku_l.append(u); kll.append(l); ks_l.append(s); kz_l.append(z)
            vu_l.append(uv); vl_l.append(lv); vs_l.append(sv); vz_l.append(zv)
        ku = jnp.concatenate(ku_l, axis=1)
        kl = jnp.concatenate(kll, axis=1)
        vu = jnp.concatenate(vu_l, axis=1)
        vl = jnp.concatenate(vl_l, axis=1)
        ks = jnp.stack(ks_l, axis=1); kz = jnp.stack(kz_l, axis=1)
        vs = jnp.stack(vs_l, axis=1); vz = jnp.stack(vz_l, axis=1)
        return ku, kl, ks, kz, vu, vl, vs, vz

    @settings(max_examples=10, deadline=None)
    @given(
        st.integers(1, 3),            # H
        st.sampled_from([8, 16]),     # G = dh here
        st.integers(1, 4),            # nb
        st.integers(1, 4),            # T
        st.sampled_from(["draft", "target"]),
        st.integers(0, 1000),
    )
    def test_matches_reference(self, H, G, nb, T, mode, seed):
        dh = G
        region = self._build_region(seed + 1, H, G, dh, nb)
        q = rand(seed, (H, T, dh))
        for blocks_valid in range(1, nb + 1):
            n_q = blocks_valid * G
            o, m, l = quant_attn.quant_attn(q, *region, n_q, g=G, mode=mode)
            orf, mr, lr = ref.quant_attn_reference(q, *region, n_q, mode)
            got = ref.merge_chunks([(o, m, l)])
            want = ref.merge_chunks([(orf, mr, lr)])
            np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)

    def test_draft_target_differ(self):
        H, G, dh, nb = 2, 16, 16, 2
        region = self._build_region(7, H, G, dh, nb)
        q = rand(8, (H, 1, dh))
        od = ref.merge_chunks([quant_attn.quant_attn(q, *region, nb * G, g=G, mode="draft")])
        ot = ref.merge_chunks([quant_attn.quant_attn(q, *region, nb * G, g=G, mode="target")])
        assert float(jnp.max(jnp.abs(od - ot))) > 1e-6

    def test_lse_merge_equals_monolithic(self):
        """Appendix E: chunked LSE merge == full softmax attention."""
        H, T, dh, S = 2, 3, 16, 48
        q = rand(1, (H, T, dh))
        k = rand(2, (H, S, dh))
        v = rand(3, (H, S, dh))
        mask = jnp.ones((T, S), bool)
        full = ref.attn_reference(q, k, v, mask)
        chunks = []
        for c0 in range(0, S, 16):
            kc, vc = k[:, c0:c0 + 16], v[:, c0:c0 + 16]
            scores = jnp.einsum("htd,hsd->hts", q, kc) / jnp.sqrt(jnp.float32(dh))
            m = jnp.max(scores, axis=-1)
            p = jnp.exp(scores - m[..., None])
            chunks.append((jnp.einsum("hts,hsd->htd", p, vc), m, jnp.sum(p, axis=-1)))
        merged = ref.merge_chunks(chunks)
        np.testing.assert_allclose(merged, full, atol=1e-5, rtol=1e-5)

    def test_empty_region_neutral(self):
        """n_q = 0: the quantized chunk must contribute nothing."""
        H, G, dh = 2, 16, 16
        region = self._build_region(9, H, G, dh, 2)
        q = rand(10, (H, 1, dh))
        o, m, l = quant_attn.quant_attn(q, *region, 0, g=G, mode="draft")
        assert float(jnp.max(jnp.abs(l))) == 0.0
        # merging with a real chunk leaves the real chunk unchanged
        k = rand(11, (H, 8, dh))
        v = rand(12, (H, 8, dh))
        mask = jnp.ones((1, 8), bool)
        full = ref.attn_reference(q, k, v, mask)
        scores = jnp.einsum("htd,hsd->hts", q, k) / jnp.sqrt(jnp.float32(dh))
        mm = jnp.max(scores, axis=-1)
        p = jnp.exp(scores - mm[..., None])
        chunk = (jnp.einsum("hts,hsd->htd", p, v), mm, jnp.sum(p, axis=-1))
        merged = ref.merge_chunks([(o, m, l), chunk])
        np.testing.assert_allclose(merged, full, atol=1e-5)
