"""L2 model semantics: decode paths vs a dense-attention reference.

These tests prove Algorithm 1's cache plumbing: prefill + quantized decode +
flush must track a plain dense forward pass, with errors bounded by the
quantization mode (fp exact, INT8 tight, INT4 looser).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref

CFG = model.ModelConfig()
S = 256


@pytest.fixture(scope="module")
def setup():
    w = model.init_params(jax.random.PRNGKey(0), CFG)
    toks = jax.random.randint(jax.random.PRNGKey(1), (S,), 0, CFG.vocab)
    pre = jax.jit(lambda w, t: model.prefill(CFG, w, t, S))(w, toks)
    return w, toks, pre


def dense_logits(w, toks_all):
    """Oracle: full dense causal forward, logits for every position."""
    S2 = toks_all.shape[0]
    positions = jnp.arange(S2, dtype=jnp.int32)
    x = w["embed"][toks_all]
    for i in range(CFG.n_layers):
        p = f"layers.{i}."
        h = model.rmsnorm(x, w[p + "attn_norm"])
        q, k, v = model._qkv(CFG, w, p, h)
        q = model.rope(q, positions, CFG.rope_theta)
        k = model.rope(k, positions, CFG.rope_theta)
        mask = jnp.arange(S2)[:, None] >= jnp.arange(S2)[None, :]
        o = ref.attn_reference(q, k, v, mask)
        o = o.transpose(1, 0, 2).reshape(S2, -1)
        x = x + o @ w[p + "wo"]
        x = x + model._mlp(CFG, w, p, x)
    return model.rmsnorm(x, w["final_norm"]) @ w["lm_head"]


def test_prefill_logits_match_dense(setup):
    w, toks, pre = setup
    want = dense_logits(w, toks)[-1]
    np.testing.assert_allclose(pre[0], want, atol=1e-4, rtol=1e-4)


def test_prefill_cache_layout(setup):
    _, _, pre = setup
    logits, ku, kl, ks, kz, vu, vl, vs, vz, fk, fv, kfull, vfull, snap = pre
    sq, nb = CFG.caps(S)
    assert ku.shape == (CFG.n_layers, CFG.n_heads, sq, CFG.head_dim)
    assert ks.shape == (CFG.n_layers, CFG.n_heads, nb, CFG.head_dim)
    assert vs.shape == (CFG.n_layers, CFG.n_heads, nb, CFG.g)
    # C_F1 = last G prompt tokens, in buffer slots [0, G)
    np.testing.assert_allclose(fk[:, :, : CFG.g], kfull[:, :, S - CFG.g:],
                               atol=1e-6)
    # slots beyond G are zero
    assert float(jnp.abs(fk[:, :, CFG.g:]).max()) == 0.0
    # quantized region covers exactly the first S-G tokens
    assert float(jnp.abs(jnp.asarray(ks[:, :, (S // CFG.g - 1):])).max()) == 0.0


@pytest.mark.parametrize("mode,atol", [("target", 0.5), ("draft", 1.5)])
def test_decode_tracks_dense(setup, mode, atol):
    w, toks, pre = setup
    region = pre[1:9]
    fk, fv = pre[9], pre[11 - 1]
    n_q, n_f = S - CFG.g, CFG.g
    new = jnp.array([42], jnp.int32)
    lg, fk2, fv2 = jax.jit(
        lambda w, *a: model.decode_core(CFG, w, *a, region_kind="quant", mode=mode)
    )(w, new, jnp.int32(S), jnp.int32(n_q), jnp.int32(n_f), region, fk, fv)
    want = dense_logits(w, jnp.concatenate([toks, new]))[-1]
    err = float(jnp.max(jnp.abs(lg[0] - want)))
    assert err < atol, f"{mode}: {err}"
    # new token's KV landed in slot n_f
    assert float(jnp.abs(fk2[:, :, n_f]).max()) > 0.0
    assert float(jnp.abs(fk2[:, :, n_f + 1:]).max()) == 0.0


def test_draft_coarser_than_target(setup):
    w, toks, pre = setup
    region = pre[1:9]
    fk, fv = pre[9], pre[10]
    args = (jnp.array([7], jnp.int32), jnp.int32(S), jnp.int32(S - CFG.g),
            jnp.int32(CFG.g), region, fk, fv)
    want = dense_logits(w, jnp.concatenate([toks, jnp.array([7])]))[-1]
    lt = model.decode_core(CFG, w, *args, region_kind="quant", mode="target")[0][0]
    ld = model.decode_core(CFG, w, *args, region_kind="quant", mode="draft")[0][0]
    assert float(jnp.max(jnp.abs(lt - want))) < float(jnp.max(jnp.abs(ld - want)))


def test_multi_token_verify_matches_dense(setup):
    """TMAX-slot verify: each row must equal the dense forward at that
    position (within INT8 error)."""
    w, toks, pre = setup
    region = pre[1:9]
    fk, fv = pre[9], pre[10]
    seg = jnp.array([10, 20, 30, 40, 0, 0, 0, 0], jnp.int32)
    lg, _, _ = model.decode_core(
        CFG, w, seg, jnp.int32(S), jnp.int32(S - CFG.g), jnp.int32(CFG.g),
        region, fk, fv, region_kind="quant", mode="target")
    for i in range(4):
        ctx = jnp.concatenate([toks, seg[: i + 1]])
        want = dense_logits(w, ctx)[-1]
        err = float(jnp.max(jnp.abs(lg[i] - want)))
        assert err < 0.6, f"slot {i}: {err}"


def test_flush_preserves_decode(setup):
    """Flushing C_F1 into the quantized region then decoding ≈ decoding
    before the flush (difference bounded by INT8 error on G tokens)."""
    w, toks, pre = setup
    region = list(pre[1:9])
    fk, fv = pre[9], pre[10]
    n_q = S - CFG.g
    out = jax.jit(lambda *a: model.flush(CFG, *a))(*region, fk, fv, jnp.int32(n_q))
    region2, fk2, fv2 = out[:8], out[8], out[9]
    # after flush: n_q' = S, n_f' = 0
    new = jnp.array([42], jnp.int32)
    lg_pre, _, _ = model.decode_core(
        CFG, w, new, jnp.int32(S), jnp.int32(n_q), jnp.int32(CFG.g),
        tuple(region), fk, fv, region_kind="quant", mode="target")
    lg_post, _, _ = model.decode_core(
        CFG, w, new, jnp.int32(S), jnp.int32(S), jnp.int32(0),
        tuple(region2), fk2, fv2, region_kind="quant", mode="target")
    err = float(jnp.max(jnp.abs(lg_pre - lg_post)))
    assert err < 0.5, f"flush perturbation {err}"
    # buffer shifted: slot 0 must now be empty
    assert float(jnp.abs(fk2[:, :, 0]).max()) == 0.0


def test_ar_dense_region_exact(setup):
    w, toks, pre = setup
    kfull, vfull = pre[11], pre[12]
    sq, _ = CFG.caps(S)
    pad = ((0, 0), (0, 0), (0, sq - (S - CFG.g)), (0, 0))
    kr = jnp.pad(kfull[:, :, : S - CFG.g], pad)
    vr = jnp.pad(vfull[:, :, : S - CFG.g], pad)
    fk, fv = pre[9], pre[10]
    new = jnp.array([42], jnp.int32)
    lg, _, _ = model.decode_core(
        CFG, w, new, jnp.int32(S), jnp.int32(S - CFG.g), jnp.int32(CFG.g),
        (kr, vr), fk, fv, region_kind="dense", mode="fp")
    want = dense_logits(w, jnp.concatenate([toks, new]))[-1]
    np.testing.assert_allclose(lg[0], want, atol=2e-4, rtol=1e-4)


def test_sparse_flush_append_and_evict():
    L, H, g, dh = CFG.n_layers, CFG.n_heads, CFG.g, CFG.head_dim
    sb = 2 * g
    kr = jnp.arange(L * H * sb * dh, dtype=jnp.float32).reshape(L, H, sb, dh)
    vr = kr + 1
    fb = CFG.fb
    fk = jnp.ones((L, H, fb, dh)) * 7.0
    fv = fk + 1
    # append path: region half full
    kr2, vr2, fk2, _ = model.sparse_flush(CFG, kr, vr, fk, fv,
                                          jnp.int32(g), jnp.int32(16))
    np.testing.assert_allclose(kr2[:, :, g: 2 * g], fk[:, :, :g])
    np.testing.assert_allclose(kr2[:, :, :g], kr[:, :, :g])
    # evict path: full region, protected prefix 16
    kr3, _, _, _ = model.sparse_flush(CFG, kr, vr, fk, fv,
                                      jnp.int32(sb), jnp.int32(16))
    np.testing.assert_allclose(kr3[:, :, :16], kr[:, :, :16])  # protected
    np.testing.assert_allclose(kr3[:, :, 16: sb - g], kr[:, :, 16 + g: sb])
    np.testing.assert_allclose(kr3[:, :, sb - g:], fk[:, :, :g])  # appended


def test_score_fp_matches_dense(setup):
    w, toks, _ = setup
    ll = jax.jit(lambda w, t: model.score(CFG, w, t, S, kv_mode="fp"))(w, toks)
    logits = dense_logits(w, toks)
    logp = jax.nn.log_softmax(logits[:-1], axis=-1)
    want = jnp.take_along_axis(logp, toks[1:, None], axis=-1)[:, 0]
    np.testing.assert_allclose(ll, want, atol=1e-4, rtol=1e-4)


def test_score_quant_ordering(setup):
    """Table 2/5 sanity: ppl(fp) <= ppl(int8) <= ppl(int4) approximately
    (quantization can only hurt on average)."""
    w, toks, _ = setup
    def nll(**kw):
        ll = model.score(CFG, w, toks, S, **kw)
        return -float(jnp.mean(ll))
    fp = nll(kv_mode="fp")
    i8 = nll(kv_mode="int8")
    i4 = nll(kv_mode="int4")
    assert i8 < fp + 0.05, f"int8 {i8} vs fp {fp}"
    assert i4 < fp + 0.6, f"int4 {i4} vs fp {fp}"
    assert abs(i8 - fp) <= abs(i4 - fp) + 1e-6


def test_param_flatten_roundtrip():
    w = model.init_params(jax.random.PRNGKey(3), CFG)
    flat = model.flatten_params(CFG, w)
    w2 = model.unflatten_params(CFG, flat)
    assert set(w2) == set(w)
    for k in w:
        np.testing.assert_array_equal(w[k], w2[k])
