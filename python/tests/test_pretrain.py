"""Pretraining smoke tests (the build-time weight pipeline)."""

import jax
import numpy as np
import pytest

from compile import model, pretrain


@pytest.mark.slow
def test_loss_decreases_in_a_few_steps():
    cfg = model.ModelConfig()
    params, trace = pretrain.pretrain(
        cfg, steps=6, batch=2, seq=64, lr=2e-3, seed=1,
        corpus_bytes=1 << 16, log_every=1,
    )
    losses = [l for _, l in trace]
    assert losses[0] > losses[-1], f"loss did not drop: {losses}"
    # params stay finite
    for k, p in params.items():
        assert bool(np.isfinite(np.asarray(p)).all()), k


def test_adam_step_moves_params():
    cfg = model.ModelConfig()
    w = model.init_params(jax.random.PRNGKey(0), cfg)
    zeros = {k: np.zeros_like(v) for k, v in w.items()}
    import jax.numpy as jnp
    batch = jnp.zeros((1, 17), jnp.int32)
    w2, m, v, loss = pretrain.adam_step(cfg, w, dict(zeros), dict(zeros),
                                        batch, 0.0, 1e-3)
    assert float(loss) > 0
    moved = sum(
        float(jnp.max(jnp.abs(w2[k] - w[k]))) > 0 for k in w
    )
    assert moved > len(w) * 0.9
