"""Synthetic long-context corpus generator.

Stands in for the paper's datasets (PG-19 / ∞Bench Sum / Multi-LexSum /
WikiText-2 / C4), which are not available offline. The generator produces
byte-level "books" with the structural property those datasets contribute to
the paper's evaluation: **long-range dependence** — a per-document cast of
entities (names, places, code words) is drawn once and reused throughout, so
a model (or a draft cache) that loses early context measurably degrades.

Three profiles mirror the paper's dataset roles (Appendix F):
  * ``pg19``     — book-like continuous prose (language modeling).
  * ``lexsum``   — multi-document legal-ish filings with heavy entity reuse
                   and a trailing summary section (Multi-LexSum-like).
  * ``infbench`` — a long narrative whose named entities are systematically
                   substituted (∞Bench-Sum-like core-entity substitution).

The Rust workload generator (`rust/src/workload/textgen.rs`) implements the
same scheme so serving benchmarks draw from the same distribution the model
was pretrained on.
"""

from __future__ import annotations


class Pcg32:
    """PCG-XSH-RR 32, mirrored bit-for-bit in rust/src/util/rng.rs so the
    Python pretraining corpus and Rust serving workloads share streams."""

    MULT = 6364136223846793005
    INC = 1442695040888963407
    MASK = (1 << 64) - 1

    def __init__(self, seed: int):
        self.state = 0
        self.next_u32()
        self.state = (self.state + (seed & self.MASK)) & self.MASK
        self.next_u32()

    def next_u32(self) -> int:
        old = self.state
        self.state = (old * self.MULT + self.INC) & self.MASK
        xorshifted = ((old >> 18) ^ old) >> 27 & 0xFFFFFFFF
        rot = old >> 59
        return ((xorshifted >> rot) | (xorshifted << ((-rot) & 31))) & 0xFFFFFFFF

    def below(self, n: int) -> int:
        return self.next_u32() % n

    def choice(self, xs):
        return xs[self.below(len(xs))]


_FIRST = ["Aldren", "Bryn", "Cormac", "Delia", "Edmund", "Farrah", "Gideon",
          "Halia", "Ines", "Jorah", "Kestrel", "Lysandra", "Merek", "Nadia",
          "Orin", "Petra"]
_LAST = ["Ashford", "Blackwood", "Carver", "Dunmore", "Eastgate", "Fenwick",
         "Greystone", "Hollis", "Ironwood", "Kearney", "Larkspur", "Mercer"]
_PLACE = ["Avonlea", "Briarhollow", "Caldera", "Dunhaven", "Eastmarch",
          "Fallowfield", "Gildenport", "Harrowgate"]
_VERB = ["argued", "claimed", "discovered", "reported", "testified",
         "recalled", "insisted", "admitted", "wrote", "observed"]
_OBJ = ["the ledger", "the treaty", "the northern road", "the old archive",
        "the court record", "the shipment", "the boundary stone",
        "the witness statement"]
_CONN = ["Meanwhile", "Later that year", "According to the record",
         "In the third chapter", "As the council noted", "Despite this",
         "By the following spring", "In a separate filing"]


def _cast(rng: Pcg32, n: int):
    return [f"{rng.choice(_FIRST)} {rng.choice(_LAST)}" for _ in range(n)]


def _sentence(rng: Pcg32, cast, places) -> str:
    s = rng.below(4)
    a, b = rng.choice(cast), rng.choice(cast)
    pl, vb, ob = rng.choice(places), rng.choice(_VERB), rng.choice(_OBJ)
    if s == 0:
        return f"{a} {vb} that {ob} in {pl} belonged to {b}."
    if s == 1:
        return f"{rng.choice(_CONN)}, {a} {vb} about {ob} near {pl}."
    if s == 2:
        return f"The case of {a} versus {b} concerned {ob} at {pl}."
    return f"{a} met {b} in {pl} and {vb} over {ob}."


def generate_doc(seed: int, length: int, profile: str = "pg19") -> bytes:
    """Generate one document of at least `length` bytes (then truncated)."""
    rng = Pcg32(seed)
    cast = _cast(rng, 6 if profile == "pg19" else 10)
    places = [rng.choice(_PLACE) for _ in range(4)]
    parts = []
    if profile == "lexsum":
        parts.append(f"FILING {seed % 9973}: {cast[0]} v. {cast[1]}.\n")
    elif profile == "infbench":
        parts.append(f"The Chronicle of {places[0]}. Book {1 + seed % 12}.\n")
    else:
        parts.append(f"{places[0]}: A History. Chapter {1 + seed % 20}.\n")
    size = len(parts[0])
    while size < length:
        para = " ".join(_sentence(rng, cast, places)
                        for _ in range(3 + rng.below(4)))
        if profile == "lexsum" and rng.below(6) == 0:
            para = f"EXHIBIT {chr(65 + rng.below(26))}. " + para
        para += "\n"
        parts.append(para)
        size += len(para)
    doc = "".join(parts)[:length]
    if profile in ("lexsum", "infbench"):
        tail = f"\nSUMMARY: the dispute between {cast[0]} and {cast[1]} over "\
               f"{rng.choice(_OBJ)} in {places[0]}"
        doc = doc[: length - len(tail)] + tail
    return doc.encode("ascii", errors="replace")


def generate_corpus(seed: int, total_bytes: int, profile: str = "pg19") -> bytes:
    """Concatenate documents to `total_bytes`."""
    rng = Pcg32(seed ^ 0x5EED)
    out = bytearray()
    i = 0
    while len(out) < total_bytes:
        out += generate_doc(seed * 1000 + i, 4096 + rng.below(8192), profile)
        out += b"\n\n"
        i += 1
    return bytes(out[:total_bytes])
