"""Layer 2: the QuantSpec JAX model — a Llama-architecture transformer whose
attention runs over the paper's hierarchical quantized KV cache.

Everything here is build-time only. `aot.py` lowers the entry points below to
HLO text once; the Rust coordinator (L3) owns all state (caches, buffers,
counters) and calls the compiled artifacts on the request path. Entry points
are pure functions: caches go in as arguments and come out as results.

Entry points (all per context-bucket S, batch = 1):

  prefill      tokens[S] -> logits, hierarchical quantized caches for the
               first S-G tokens, FP buffer C_F1 = last G tokens, SnapKV
               pooled observation scores (used by the SnapKV baseline).
  draft_step   1 token, INT4 (upper-nibble) KV + FP buffer attention.
               Weights are inputs, so the same artifact serves the
               weight-quantization ablation (fed FP vs Q4 weight sets).
  verify       TMAX token slots, INT8 (both-nibble) KV; writes target-model
               KV for the drafted tokens into the FP buffer (Alg. 1).
  ar_step /    dense-FP-region variants: the autoregressive baseline and the
  ar_verify    sparse baselines' target-side verification.
  sparse_draft draft over a gathered budget-size dense region
               (StreamingLLM / SnapKV draft caches).
  flush        quantize C_F1 (G tokens) into the hierarchical cache, shift
               C_F2 -> C_F1 (paper §4.3.2 double-buffer flush).
  ar_flush /   dense-region equivalents (append / ring-evict with a
  sparse_flush protected prefix).
  score_*      teacher-forced per-token log-likelihoods with fake-quantized
               KV (Table 2 / Table 5 perplexity evaluations).

Shape/state conventions (see DESIGN.md §5):
  G = head_dim (paper §4.3.1); the quantized region only grows by whole
  G-token blocks, so `n_q` is always a multiple of G. The FP buffer holds
  FB = 2G + TMAX slots; entry j holds the KV of absolute position n_q + j,
  and rollback after a rejected speculation is just a decrement of `n_f`
  (stale slots are masked and later overwritten).
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
from jax import lax

from .kernels import hier_quant, quant_attn, ref


# --------------------------------------------------------------------------
# Configuration
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture hyperparameters for the tiny-Llama preset.

    head_dim doubles as the quantization group size G (paper §4.3.1), so a
    value group is exactly one token's head vector and the FP-buffer flush
    granularity equals the key channel-group length.
    """

    vocab: int = 256
    d_model: int = 256
    n_heads: int = 4
    head_dim: int = 64
    n_layers: int = 4
    d_ff: int = 512
    tmax: int = 8  # verify slots: gamma_max = tmax - 1
    rope_theta: float = 10000.0

    @property
    def g(self) -> int:
        return self.head_dim

    @property
    def fb(self) -> int:
        """FP buffer capacity: double buffer (2G) + verify-slot slack."""
        return 2 * self.g + self.tmax

    def caps(self, s: int):
        """(quantized-region token capacity, block capacity) for bucket s.

        The region starts at s - G tokens after prefill and grows by one
        G-block per flush; two spare blocks cover the paper's 90-token
        output budget plus speculation slack.
        """
        sq_cap = s + 4 * self.g  # multiple of ATTN_CHUNK blocks
        return sq_cap, sq_cap // self.g


# Quantization blocks per kernel grid step (§Perf block-shape knob); the
# region block capacity (caps) is kept a multiple of this.
ATTN_CHUNK = 4


# Canonical per-layer weight names, in lowering argument order.
_LAYER_PARAMS = (
    "attn_norm", "wq", "wk", "wv", "wo", "mlp_norm", "w_gate", "w_up",
    "w_down",
)


def param_names(cfg: ModelConfig):
    """Canonical flat parameter ordering shared with aot.py and the Rust
    runtime (manifest order == lowering argument order)."""
    names = ["embed"]
    for i in range(cfg.n_layers):
        names.extend(f"layers.{i}.{p}" for p in _LAYER_PARAMS)
    names.extend(["final_norm", "lm_head"])
    return names


def param_shapes(cfg: ModelConfig):
    """Shape for every canonical parameter name."""
    d, hd, f = cfg.d_model, cfg.n_heads * cfg.head_dim, cfg.d_ff
    shapes = {"embed": (cfg.vocab, d), "final_norm": (d,), "lm_head": (d, cfg.vocab)}
    for i in range(cfg.n_layers):
        p = f"layers.{i}."
        shapes[p + "attn_norm"] = (d,)
        shapes[p + "wq"] = (d, hd)
        shapes[p + "wk"] = (d, hd)
        shapes[p + "wv"] = (d, hd)
        shapes[p + "wo"] = (hd, d)
        shapes[p + "mlp_norm"] = (d,)
        shapes[p + "w_gate"] = (d, f)
        shapes[p + "w_up"] = (d, f)
        shapes[p + "w_down"] = (f, d)
    return shapes


def init_params(key, cfg: ModelConfig):
    """Random init (scaled normal), as a flat {name: array} dict."""
    params = {}
    for name, shape in param_shapes(cfg).items():
        key, sub = jax.random.split(key)
        if name.endswith("norm"):
            params[name] = jnp.ones(shape, jnp.float32)
        else:
            fan_in = shape[0] if len(shape) == 2 else cfg.d_model
            std = 1.0 / math.sqrt(fan_in)
            params[name] = std * jax.random.normal(sub, shape, jnp.float32)
    return params


def flatten_params(cfg: ModelConfig, params: dict):
    return [params[n] for n in param_names(cfg)]


def unflatten_params(cfg: ModelConfig, flat):
    return dict(zip(param_names(cfg), flat))


# --------------------------------------------------------------------------
# Core ops
# --------------------------------------------------------------------------


def rmsnorm(x, w, eps=1e-5):
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * w


def rope(x, positions, theta):
    """Rotary embedding. x: [H, T, dh]; positions: i32[T]."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[:, None] * freqs[None, :]  # [T, half]
    cos, sin = jnp.cos(ang)[None], jnp.sin(ang)[None]  # [1, T, half]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def _qkv(cfg, w, prefix, h):
    """Project hidden states h [T, d] to q/k/v [H, T, dh]."""
    def proj(name):
        y = h @ w[prefix + name]  # [T, H*dh]
        return y.reshape(-1, cfg.n_heads, cfg.head_dim).transpose(1, 0, 2)
    return proj("wq"), proj("wk"), proj("wv")


def _mlp(cfg, w, prefix, x):
    h = rmsnorm(x, w[prefix + "mlp_norm"])
    return (jax.nn.silu(h @ w[prefix + "w_gate"]) * (h @ w[prefix + "w_up"])) @ w[prefix + "w_down"]


def dense_chunk(q, k, v, n):
    """Flash-chunk statistics over a dense region, tokens [0, n) valid.

    q: [H,T,dh]; k,v: [H,S,dh]. Returns (o, m, l) in merge_chunks format.
    """
    dh = q.shape[-1]
    S = k.shape[1]
    scores = jnp.einsum("htd,hsd->hts", q, k) / jnp.sqrt(jnp.float32(dh))
    valid = jnp.arange(S)[None, None, :] < n
    scores = jnp.where(valid, scores, -jnp.inf)
    m = jnp.max(scores, axis=-1)
    msafe = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.where(valid, jnp.exp(scores - msafe[..., None]), 0.0)
    return jnp.einsum("hts,hsd->htd", p, v), msafe, jnp.sum(p, axis=-1)


def self_chunk(q, k, v):
    """Causal self-attention chunk over the T in-flight tokens."""
    dh = q.shape[-1]
    T = q.shape[1]
    scores = jnp.einsum("htd,hsd->hts", q, k) / jnp.sqrt(jnp.float32(dh))
    causal = jnp.arange(T)[:, None] >= jnp.arange(T)[None, :]
    scores = jnp.where(causal[None], scores, -jnp.inf)
    m = jnp.max(scores, axis=-1)
    p = jnp.where(causal[None], jnp.exp(scores - m[..., None]), 0.0)
    return jnp.einsum("hts,hsd->htd", p, v), m, jnp.sum(p, axis=-1)


# --------------------------------------------------------------------------
# Decode core (shared by draft / verify / AR / sparse entries)
# --------------------------------------------------------------------------


def decode_core(cfg, w, toks, pos, n_q, n_f, region, fk, fv, *, region_kind,
                mode):
    """One decode step over T = len(toks) in-flight tokens.

    Attention per layer is three flash chunks merged by LSE (paper App. E):
      1. the region — hierarchical quantized (Pallas kernel, draft/target
         dequant per `mode`) or a dense FP region (AR & sparse baselines),
         valid tokens [0, n_q);
      2. the FP buffer — valid slots [0, n_f);
      3. the in-flight segment itself — causal.

    Returns (logits f32[T, vocab], fk', fv') where the buffers have the new
    tokens' KV written at slots [n_f, n_f+T).
    """
    T = toks.shape[0]
    positions = pos + jnp.arange(T, dtype=jnp.int32)
    x = w["embed"][toks]  # [T, d]
    k_news, v_news = [], []
    for i in range(cfg.n_layers):
        p = f"layers.{i}."
        h = rmsnorm(x, w[p + "attn_norm"])
        q, k_new, v_new = _qkv(cfg, w, p, h)
        q = rope(q, positions, cfg.rope_theta)
        k_new = rope(k_new, positions, cfg.rope_theta)
        chunks = []
        if region_kind == "quant":
            ku, kl, ks, kz, vu, vl, vs, vz = (r[i] for r in region)
            chunks.append(
                quant_attn.quant_attn(
                    q, ku, kl, ks, kz, vu, vl, vs, vz, n_q, g=cfg.g,
                    mode=mode, chunk=ATTN_CHUNK,
                )
            )
        else:
            kr, vr = region
            chunks.append(dense_chunk(q, kr[i], vr[i], n_q))
        chunks.append(dense_chunk(q, fk[i], fv[i], n_f))
        chunks.append(self_chunk(q, k_new, v_new))
        o = ref.merge_chunks(chunks)  # [H, T, dh]
        o = o.transpose(1, 0, 2).reshape(T, cfg.n_heads * cfg.head_dim)
        x = x + o @ w[p + "wo"]
        x = x + _mlp(cfg, w, p, x)
        k_news.append(k_new)
        v_news.append(v_new)
    logits = rmsnorm(x, w["final_norm"]) @ w["lm_head"]  # [T, vocab]
    k_stack = jnp.stack(k_news)  # [L, H, T, dh]
    v_stack = jnp.stack(v_news)
    zero = jnp.int32(0)
    fk2 = lax.dynamic_update_slice(fk, k_stack, (zero, zero, n_f, zero))
    fv2 = lax.dynamic_update_slice(fv, v_stack, (zero, zero, n_f, zero))
    return logits, fk2, fv2


# --------------------------------------------------------------------------
# Prefill
# --------------------------------------------------------------------------

_PREFILL_CHUNK = 256
_SNAP_WINDOW = 32  # SnapKV observation window (last queries of the prompt)


def _chunked_causal(q, k, v, snap_accum):
    """Memory-bounded causal attention for prefill. q,k,v: [H,S,dh].

    Returns (out [H,S,dh], snap [S]) where snap accumulates the summed
    attention probability mass received by each position from the last
    _SNAP_WINDOW queries (the SnapKV observation-window statistic).
    """
    H, S, dh = q.shape
    c = min(_PREFILL_CHUNK, S)
    nc = S // c
    scale = 1.0 / math.sqrt(dh)
    qs = q.reshape(H, nc, c, dh).transpose(1, 0, 2, 3)  # [nc, H, c, dh]

    def body(ci, qc):
        c0 = ci * c
        scores = jnp.einsum("htd,hsd->hts", qc, k) * scale  # [H, c, S]
        jpos = jnp.arange(S)[None, None, :]
        ipos = (c0 + jnp.arange(c))[None, :, None]
        scores = jnp.where(jpos <= ipos, scores, -jnp.inf)
        mx = jnp.max(scores, axis=-1, keepdims=True)
        pr = jnp.exp(scores - mx)
        pr = pr / jnp.sum(pr, axis=-1, keepdims=True)
        out = jnp.einsum("hts,hsd->htd", pr, v)
        # SnapKV statistic: probability mass from the final-window queries.
        in_win = (c0 + jnp.arange(c)) >= (S - _SNAP_WINDOW)
        snap = jnp.sum(pr * in_win[None, :, None], axis=(0, 1))  # [S]
        return out, snap

    outs, snaps = lax.map(lambda args: body(*args), (jnp.arange(nc), qs))
    out = outs.transpose(1, 0, 2, 3).reshape(H, S, dh)
    return out, snap_accum + jnp.sum(snaps, axis=0)


def prefill(cfg: ModelConfig, w, toks, s: int):
    """Process an S-token prompt; build the hierarchical cache (paper Fig 3a).

    Returns, in manifest order:
      logits f32[vocab]           — next-token distribution for the prompt
      ku, kl int8[L,H,SQ,dh]      — key nibbles (first S-G tokens valid)
      ks, kz f32[L,H,NB,dh]       — key INT8 scale/zero (channel-wise groups)
      vu, vl int8[L,H,SQ,dh]      — value nibbles
      vs, vz f32[L,H,NB,G]        — value INT8 scale/zero (token-wise groups)
      fk, fv f32[L,H,FB,dh]       — FP buffer, C_F1 = last G prompt tokens
      kfull, vfull f32[L,H,S,dh]  — FP KV (baselines' dense region seed)
      snap f32[S]                 — SnapKV observation scores
    """
    sq_cap, nb_cap = cfg.caps(s)
    positions = jnp.arange(s, dtype=jnp.int32)
    x = w["embed"][toks]
    snap = jnp.zeros((s,), jnp.float32)
    k_all, v_all = [], []
    for i in range(cfg.n_layers):
        p = f"layers.{i}."
        h = rmsnorm(x, w[p + "attn_norm"])
        q, k, v = _qkv(cfg, w, p, h)
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
        o, snap = _chunked_causal(q, k, v, snap)
        o = o.transpose(1, 0, 2).reshape(s, cfg.n_heads * cfg.head_dim)
        x = x + o @ w[p + "wo"]
        x = x + _mlp(cfg, w, p, x)
        k_all.append(k)
        v_all.append(v)
    logits = rmsnorm(x[-1], w["final_norm"]) @ w["lm_head"]  # [vocab]

    kfull = jnp.stack(k_all)  # [L, H, S, dh]
    vfull = jnp.stack(v_all)
    L, H, g = cfg.n_layers, cfg.n_heads, cfg.g
    nb = s // g - 1  # quantize all but the trailing G tokens (C_F1)

    def quant_region(x_full, quant_fn):
        xb = x_full[:, :, : nb * g].reshape(L, H, nb, g, cfg.head_dim)
        xb = xb.transpose(0, 2, 1, 3, 4).reshape(L * nb, H, g, cfg.head_dim)
        u, lo, s8, z = lax.map(quant_fn, xb)
        stat = s8.shape[-1]
        u = u.reshape(L, nb, H, g, cfg.head_dim).transpose(0, 2, 1, 3, 4)
        u = u.reshape(L, H, nb * g, cfg.head_dim)
        lo = lo.reshape(L, nb, H, g, cfg.head_dim).transpose(0, 2, 1, 3, 4)
        lo = lo.reshape(L, H, nb * g, cfg.head_dim)
        s8 = s8.reshape(L, nb, H, stat).transpose(0, 2, 1, 3)
        z = z.reshape(L, nb, H, stat).transpose(0, 2, 1, 3)
        padt = ((0, 0), (0, 0), (0, sq_cap - nb * g), (0, 0))
        padb = ((0, 0), (0, 0), (0, nb_cap - nb), (0, 0))
        return (jnp.pad(u, padt), jnp.pad(lo, padt), jnp.pad(s8, padb),
                jnp.pad(z, padb))

    ku, kl, ks, kz = quant_region(kfull, hier_quant.hier_quant_block_k)
    vu, vl, vs, vz = quant_region(vfull, hier_quant.hier_quant_block_v)

    fpad = ((0, 0), (0, 0), (0, cfg.fb - g), (0, 0))
    fk = jnp.pad(kfull[:, :, s - g:], fpad)
    fv = jnp.pad(vfull[:, :, s - g:], fpad)
    return (logits, ku, kl, ks, kz, vu, vl, vs, vz, fk, fv, kfull, vfull,
            snap)


# --------------------------------------------------------------------------
# Flush entries (paper Alg. 1 lines 22-25)
# --------------------------------------------------------------------------


def flush(cfg: ModelConfig, ku, kl, ks, kz, vu, vl, vs, vz, fk, fv, n_q):
    """Quantize C_F1 into the hierarchical cache; shift C_F2 -> C_F1."""
    L, H, g, dh = cfg.n_layers, cfg.n_heads, cfg.g, cfg.head_dim
    zero = jnp.int32(0)
    blk = n_q // g

    def quantize(buf, fn):
        xb = buf[:, :, :g].reshape(L * H, g, dh)
        u, lo, s8, z = fn(xb)
        stat = s8.shape[-1]
        return (u.reshape(L, H, g, dh), lo.reshape(L, H, g, dh),
                s8.reshape(L, H, 1, stat), z.reshape(L, H, 1, stat))

    u, lo, s8, z = quantize(fk, hier_quant.hier_quant_block_k)
    ku = lax.dynamic_update_slice(ku, u, (zero, zero, n_q, zero))
    kl = lax.dynamic_update_slice(kl, lo, (zero, zero, n_q, zero))
    ks = lax.dynamic_update_slice(ks, s8, (zero, zero, blk, zero))
    kz = lax.dynamic_update_slice(kz, z, (zero, zero, blk, zero))
    u, lo, s8, z = quantize(fv, hier_quant.hier_quant_block_v)
    vu = lax.dynamic_update_slice(vu, u, (zero, zero, n_q, zero))
    vl = lax.dynamic_update_slice(vl, lo, (zero, zero, n_q, zero))
    vs = lax.dynamic_update_slice(vs, s8, (zero, zero, blk, zero))
    vz = lax.dynamic_update_slice(vz, z, (zero, zero, blk, zero))

    fk = _shift_buffer(fk, g)
    fv = _shift_buffer(fv, g)
    return ku, kl, ks, kz, vu, vl, vs, vz, fk, fv


def _shift_buffer(buf, g):
    """Drop the first g slots (C_F1) and zero-fill the tail."""
    pad = ((0, 0), (0, 0), (0, g), (0, 0))
    return jnp.pad(buf[:, :, g:], pad)


def ar_flush(cfg: ModelConfig, kr, vr, fk, fv, n_q):
    """Dense-region flush: append C_F1 verbatim (FP16 baseline semantics)."""
    zero = jnp.int32(0)
    g = cfg.g
    kr = lax.dynamic_update_slice(kr, fk[:, :, :g], (zero, zero, n_q, zero))
    vr = lax.dynamic_update_slice(vr, fv[:, :, :g], (zero, zero, n_q, zero))
    return kr, vr, _shift_buffer(fk, g), _shift_buffer(fv, g)


def sparse_flush(cfg: ModelConfig, kr, vr, fk, fv, n_s, p):
    """Budget-region flush for the sparse-KV draft baselines.

    If the region has room, append C_F1 at n_s. Otherwise ring-evict: keep
    the protected prefix [0, p) (attention sinks for StreamingLLM; the
    SnapKV-selected set for SnapKV), shift the rest left by G, and append
    C_F1 at the end — a sliding recent window over the unprotected suffix.
    """
    g = cfg.g
    sb = kr.shape[2]
    zero = jnp.int32(0)

    k_app = lax.dynamic_update_slice(kr, fk[:, :, :g], (zero, zero, n_s, zero))
    v_app = lax.dynamic_update_slice(vr, fv[:, :, :g], (zero, zero, n_s, zero))

    idx = jnp.arange(sb, dtype=jnp.int32)
    src = jnp.where(idx < p, idx, jnp.minimum(idx + g, sb - 1))
    k_ev = lax.dynamic_update_slice(
        jnp.take(kr, src, axis=2), fk[:, :, :g], (zero, zero, jnp.int32(sb - g), zero)
    )
    v_ev = lax.dynamic_update_slice(
        jnp.take(vr, src, axis=2), fv[:, :, :g], (zero, zero, jnp.int32(sb - g), zero)
    )

    full = n_s + g > sb
    kr2 = jnp.where(full, k_ev, k_app)
    vr2 = jnp.where(full, v_ev, v_app)
    return kr2, vr2, _shift_buffer(fk, g), _shift_buffer(fv, g)


# --------------------------------------------------------------------------
# Perplexity scoring entries (Tables 2 and 5)
# --------------------------------------------------------------------------


def score(cfg: ModelConfig, w, toks, s: int, *, kv_mode: str,
          k_axis: str = "channel", v_axis: str = "token",
          residual: int | None = None):
    """Teacher-forced per-token log-likelihood with a fake-quantized cache.

    kv_mode: 'fp' | 'int8' | 'int4'. k_axis/v_axis choose the quantization
    grouping axis (Table 5 ablation). All but the trailing `residual`
    (default 2G, matching the paper's R=256 at G=128) tokens are quantized.
    Returns ll f32[S-1]: log p(toks[i+1] | toks[:i+1]).
    """
    residual = 2 * cfg.g if residual is None else residual
    positions = jnp.arange(s, dtype=jnp.int32)
    x = w["embed"][toks]
    snap = jnp.zeros((s,), jnp.float32)
    for i in range(cfg.n_layers):
        p = f"layers.{i}."
        h = rmsnorm(x, w[p + "attn_norm"])
        q, k, v = _qkv(cfg, w, p, h)
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
        if kv_mode != "fp":
            k = fake_quant_seq(k, cfg.g, k_axis, kv_mode, residual)
            v = fake_quant_seq(v, cfg.g, v_axis, kv_mode, residual)
        o, snap = _chunked_causal(q, k, v, snap)
        o = o.transpose(1, 0, 2).reshape(s, cfg.n_heads * cfg.head_dim)
        x = x + o @ w[p + "wo"]
        x = x + _mlp(cfg, w, p, x)
    logits = rmsnorm(x, w["final_norm"]) @ w["lm_head"]  # [S, vocab]
    logp = jax.nn.log_softmax(logits[:-1], axis=-1)
    return jnp.take_along_axis(logp, toks[1:, None], axis=-1)[:, 0]


def fake_quant_seq(x, g, axis, mode, residual):
    """Quantize-dequantize a [H,S,dh] KV sequence blockwise, keeping the
    trailing `residual` tokens full precision (paper Table 2 setup)."""
    H, S, dh = x.shape
    cut = ((S - residual) // g) * g
    if cut <= 0:
        return x
    nb = cut // g
    xb = x[:, :cut].reshape(H, nb, g, dh)
    if axis == "channel":  # stats over the g tokens, per channel
        mn = jnp.min(xb, axis=2, keepdims=True)
        mx = jnp.max(xb, axis=2, keepdims=True)
    else:  # 'token': stats over the dh channels, per token
        mn = jnp.min(xb, axis=3, keepdims=True)
        mx = jnp.max(xb, axis=3, keepdims=True)
    s8 = jnp.maximum((mx - mn) / 255.0, ref.EPS)
    z = mn
    s4 = 16.0 * s8
    u = jnp.clip(jnp.round((xb - z) / s4), 0.0, 15.0)
    if mode == "int4":
        deq = u * s4 + z
    else:  # int8: hierarchical reconstruction with the lower nibble
        lo = jnp.clip(jnp.round((xb - (u * s4 + z)) / s8), -8.0, 7.0)
        deq = (16.0 * u + lo) * s8 + z
    deq = deq.reshape(H, cut, dh)
    return jnp.concatenate([deq, x[:, cut:]], axis=1)
