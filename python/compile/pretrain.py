"""Build-time pretraining of the tiny-Llama target model.

The paper serves Llama-2-7B-32K / LWM-Text-Chat-128k; no pretrained weights
are available offline, so we train the same architecture family at tiny scale
on the synthetic long-context corpus (`corpus.py`) for a few hundred Adam
steps. This gives the served model *peaked, context-dependent* next-token
distributions — the property that makes speculative-decoding acceptance rates
meaningful (a random-weight model would accept everything under any draft).

Runs once from `make artifacts`; skipped when `artifacts/params.npz` exists.

Usage: python -m compile.pretrain [--steps N] [--out PATH]
"""

from __future__ import annotations

import argparse
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import corpus, model


def _train_forward(cfg, w, toks):
    """Dense-causal training forward: toks i32[B,S] -> logits f32[B,S,V]."""
    def one(seq):
        positions = jnp.arange(seq.shape[0], dtype=jnp.int32)
        x = w["embed"][seq]
        for i in range(cfg.n_layers):
            p = f"layers.{i}."
            h = model.rmsnorm(x, w[p + "attn_norm"])
            q, k, v = model._qkv(cfg, w, p, h)
            q = model.rope(q, positions, cfg.rope_theta)
            k = model.rope(k, positions, cfg.rope_theta)
            S = seq.shape[0]
            mask = jnp.arange(S)[:, None] >= jnp.arange(S)[None, :]
            o = model.ref.attn_reference(q, k, v, mask)
            o = o.transpose(1, 0, 2).reshape(S, cfg.n_heads * cfg.head_dim)
            x = x + o @ w[p + "wo"]
            x = x + model._mlp(cfg, w, p, x)
        return model.rmsnorm(x, w["final_norm"]) @ w["lm_head"]
    return jax.vmap(one)(toks)


def loss_fn(cfg, w, batch):
    logits = _train_forward(cfg, w, batch[:, :-1])
    logp = jax.nn.log_softmax(logits, axis=-1)
    tgt = batch[:, 1:]
    ll = jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    return -jnp.mean(ll)


def adam_step(cfg, w, m, v, batch, step, lr, b1=0.9, b2=0.95, eps=1e-8):
    loss, grads = jax.value_and_grad(functools.partial(loss_fn, cfg))(w, batch)
    t = step + 1.0
    new_w, new_m, new_v = {}, {}, {}
    for k in w:
        m_k = b1 * m[k] + (1 - b1) * grads[k]
        v_k = b2 * v[k] + (1 - b2) * jnp.square(grads[k])
        mhat = m_k / (1 - b1 ** t)
        vhat = v_k / (1 - b2 ** t)
        new_w[k] = w[k] - lr * mhat / (jnp.sqrt(vhat) + eps)
        new_m[k], new_v[k] = m_k, v_k
    return new_w, new_m, new_v, loss


def pretrain(cfg: model.ModelConfig, steps: int = 300, batch: int = 8,
             seq: int = 256, lr: float = 1e-3, seed: int = 0,
             corpus_bytes: int = 1 << 21, log_every: int = 25):
    """Train and return params plus the (step, loss) trace."""
    data = np.frombuffer(
        corpus.generate_corpus(seed, corpus_bytes, "pg19"), dtype=np.uint8
    ).astype(np.int32)
    key = jax.random.PRNGKey(seed)
    params = model.init_params(key, cfg)
    zeros = {k: jnp.zeros_like(v) for k, v in params.items()}
    m, v = zeros, dict(zeros)

    @jax.jit
    def step_fn(w, m, v, batch_toks, step, cur_lr):
        return adam_step(cfg, w, m, v, batch_toks, step, cur_lr)

    rng = np.random.default_rng(seed)
    trace = []
    t0 = time.time()
    for i in range(steps):
        starts = rng.integers(0, len(data) - seq - 1, size=batch)
        toks = jnp.asarray(np.stack([data[s: s + seq + 1] for s in starts]))
        # linear warmup then cosine decay
        warm = min(1.0, (i + 1) / 20)
        cos = 0.5 * (1 + np.cos(np.pi * i / max(steps, 1)))
        cur_lr = lr * warm * (0.1 + 0.9 * cos)
        params, m, v, loss = step_fn(params, m, v, toks, float(i), cur_lr)
        if i % log_every == 0 or i == steps - 1:
            loss_v = float(loss)
            trace.append((i, loss_v))
            print(f"step {i:4d} loss {loss_v:.4f} "
                  f"({time.time() - t0:.0f}s)", flush=True)
    return params, trace


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="../artifacts/params.npz")
    args = ap.parse_args()

    cfg = model.ModelConfig()
    params, trace = pretrain(cfg, args.steps, args.batch, args.seq, args.lr,
                             args.seed)
    np.savez(args.out, **{k: np.asarray(p) for k, p in params.items()})
    with open(args.out + ".loss.csv", "w") as f:
        f.write("step,loss\n")
        f.writelines(f"{s},{l:.6f}\n" for s, l in trace)
    print(f"saved {args.out} (final loss {trace[-1][1]:.4f})")


if __name__ == "__main__":
    main()
