"""AOT lowering: JAX entry points -> HLO text artifacts + manifest.

This is the only place Python touches the model after pretraining. Each entry
point from model.py is jitted, lowered to StableHLO, converted to an
XlaComputation, and dumped as HLO **text** — the interchange format the Rust
runtime can parse (`HloModuleProto::from_text_file`). Serialized protos are
NOT used: jax >= 0.5 emits 64-bit instruction ids that the image's
xla_extension 0.5.1 rejects; the text parser reassigns ids.

Outputs (under artifacts/):
  *.hlo.txt           one per entry point x context bucket
  manifest.json       model config, bucket list, per-entry input/output
                      specs (name, dtype, shape) in argument order, and the
                      weight-blob index
  weights/fp/*.bin    trained FP weights, raw little-endian f32
  weights/q4/*.bin    INT4-sim draft weights (group-wise quant-dequant),
                      stored f32, logical width 4 bit (memory accounting in
                      Rust uses the logical width)

Usage: python -m compile.aot [--out-dir ../artifacts] [--buckets 256,512,...]
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model

BUCKETS_DEFAULT = (256, 512, 1024, 2048)
SCORE_BUCKET = 1024
WQ_GROUP = 64  # weight-quant group size along the input dimension


# --------------------------------------------------------------------------
# Weight quantization (draft weight set)
# --------------------------------------------------------------------------


def quant_dequant_weight(w: np.ndarray, bits: int = 4, group: int = WQ_GROUP):
    """Group-wise asymmetric INT-N quant-dequant along the input dim.

    Matrices are [in, out]; groups are `group` consecutive input rows per
    output column (AWQ-style). 1-D tensors (norms) pass through untouched.
    """
    if w.ndim != 2 or w.shape[0] % group != 0:
        return w.copy()
    qmax = float(2 ** bits - 1)
    ng = w.shape[0] // group
    g = w.reshape(ng, group, w.shape[1])
    mn = g.min(axis=1, keepdims=True)
    mx = g.max(axis=1, keepdims=True)
    scale = np.maximum((mx - mn) / qmax, 1e-8)
    q = np.clip(np.round((g - mn) / scale), 0, qmax)
    return (q * scale + mn).reshape(w.shape).astype(np.float32)


# --------------------------------------------------------------------------
# Lowering helpers
# --------------------------------------------------------------------------


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


_DT = {jnp.float32.dtype: "f32", jnp.int8.dtype: "i8", jnp.int32.dtype: "i32"}


def _iospec(name, s):
    return {"name": name, "dtype": _DT[s.dtype], "shape": list(s.shape)}


class EntryBuilder:
    """Collects (name, fn, input specs, output names) and lowers them."""

    def __init__(self, cfg: model.ModelConfig, out_dir: str):
        self.cfg = cfg
        self.out_dir = out_dir
        self.entries = {}

    def weight_specs(self):
        shapes = model.param_shapes(self.cfg)
        return [(n, _spec(shapes[n])) for n in model.param_names(self.cfg)]

    def add(self, name, fn, inputs, output_names):
        """inputs: list of (name, ShapeDtypeStruct); weights appended last."""
        wspecs = self.weight_specs()
        all_inputs = inputs + [(f"w:{n}", s) for n, s in wspecs]

        def wrapped(*args):
            n_dyn = len(inputs)
            dyn, wflat = args[:n_dyn], args[n_dyn:]
            w = model.unflatten_params(self.cfg, list(wflat))
            return fn(w, *dyn)

        t0 = time.time()
        lowered = jax.jit(wrapped).lower(*[s for _, s in all_inputs])
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.out_dir, fname), "w") as f:
            f.write(text)
        out_shapes = jax.eval_shape(wrapped, *[s for _, s in all_inputs])
        if not isinstance(out_shapes, (tuple, list)):
            out_shapes = (out_shapes,)
        self.entries[name] = {
            "file": fname,
            "inputs": [_iospec(n, s) for n, s in all_inputs],
            "outputs": [
                _iospec(o_name, o_s)
                for o_name, o_s in zip(output_names, out_shapes)
            ],
        }
        print(f"  {name}: {len(text) / 1e6:.2f} MB HLO "
              f"({time.time() - t0:.1f}s)", flush=True)

    def add_stateless(self, name, fn, inputs, output_names):
        """Entry with no weight inputs (cache-manipulation only)."""
        t0 = time.time()
        lowered = jax.jit(fn).lower(*[s for _, s in inputs])
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.out_dir, fname), "w") as f:
            f.write(text)
        out_shapes = jax.eval_shape(fn, *[s for _, s in inputs])
        if not isinstance(out_shapes, (tuple, list)):
            out_shapes = (out_shapes,)
        self.entries[name] = {
            "file": fname,
            "inputs": [_iospec(n, s) for n, s in inputs],
            "outputs": [
                _iospec(o_name, o_s)
                for o_name, o_s in zip(output_names, out_shapes)
            ],
        }
        print(f"  {name}: {len(text) / 1e6:.2f} MB HLO "
              f"({time.time() - t0:.1f}s)", flush=True)


def quant_cache_specs(cfg, s):
    """Input specs for the hierarchical cache arrays of bucket s."""
    L, H, dh, g = cfg.n_layers, cfg.n_heads, cfg.head_dim, cfg.g
    sq, nb = cfg.caps(s)
    return [
        ("ku", _spec((L, H, sq, dh), jnp.int8)),
        ("kl", _spec((L, H, sq, dh), jnp.int8)),
        ("ks", _spec((L, H, nb, dh))),
        ("kz", _spec((L, H, nb, dh))),
        ("vu", _spec((L, H, sq, dh), jnp.int8)),
        ("vl", _spec((L, H, sq, dh), jnp.int8)),
        ("vs", _spec((L, H, nb, g))),
        ("vz", _spec((L, H, nb, g))),
    ]


def build_entries(cfg: model.ModelConfig, out_dir: str, buckets):
    b = EntryBuilder(cfg, out_dir)
    L, H, dh, g, fb, tmax = (cfg.n_layers, cfg.n_heads, cfg.head_dim, cfg.g,
                             cfg.fb, cfg.tmax)
    i32 = jnp.int32
    fbuf = [("fk", _spec((L, H, fb, dh))), ("fv", _spec((L, H, fb, dh)))]
    scalars = [("pos", _spec((), i32)), ("n_q", _spec((), i32)),
               ("n_f", _spec((), i32))]

    for s in buckets:
        sq, nb = cfg.caps(s)
        qc = quant_cache_specs(cfg, s)
        dense = [("kr", _spec((L, H, sq, dh))), ("vr", _spec((L, H, sq, dh)))]
        sb = max(s // 4, 2 * g)  # sparse draft budget = context/4 (paper §5.1)
        sparse = [("kr", _spec((L, H, sb, dh))), ("vr", _spec((L, H, sb, dh)))]

        # ---- prefill ----
        b.add(
            f"prefill_{s}",
            lambda w, toks, s=s: model.prefill(cfg, w, toks, s),
            [("toks", _spec((s,), i32))],
            ["logits", "ku", "kl", "ks", "kz", "vu", "vl", "vs", "vz",
             "fk", "fv", "kfull", "vfull", "snap"],
        )

        # ---- QuantSpec draft (INT4 upper nibble) ----
        def draft_fn(w, toks, pos, n_q, n_f, *arrs):
            region, bufs = arrs[:8], arrs[8:]
            return model.decode_core(cfg, w, toks, pos, n_q, n_f, region,
                                     *bufs, region_kind="quant", mode="draft")
        b.add(f"draft_{s}", draft_fn,
              [("toks", _spec((1,), i32))] + scalars + qc + fbuf,
              ["logits", "fk", "fv"])

        # ---- QuantSpec verify (INT8 both nibbles, TMAX slots) ----
        def verify_fn(w, toks, pos, n_q, n_f, *arrs):
            region, bufs = arrs[:8], arrs[8:]
            return model.decode_core(cfg, w, toks, pos, n_q, n_f, region,
                                     *bufs, region_kind="quant",
                                     mode="target")
        b.add(f"verify_{s}", verify_fn,
              [("toks", _spec((tmax,), i32))] + scalars + qc + fbuf,
              ["logits", "fk", "fv"])

        # ---- dense-region steps (AR baseline + sparse-baseline target) ----
        def ar_fn(w, toks, pos, n_q, n_f, kr, vr, fk, fv):
            return model.decode_core(cfg, w, toks, pos, n_q, n_f, (kr, vr),
                                     fk, fv, region_kind="dense", mode="fp")
        b.add(f"ar_step_{s}", ar_fn,
              [("toks", _spec((1,), i32))] + scalars + dense + fbuf,
              ["logits", "fk", "fv"])
        b.add(f"ar_verify_{s}", ar_fn,
              [("toks", _spec((tmax,), i32))] + scalars + dense + fbuf,
              ["logits", "fk", "fv"])

        # ---- sparse draft (StreamingLLM / SnapKV budget region) ----
        b.add(f"sparse_draft_{s}", ar_fn,
              [("toks", _spec((1,), i32))] + scalars + sparse + fbuf,
              ["logits", "fk", "fv"])

        # ---- flushes (no weights) ----
        b.add_stateless(
            f"flush_{s}",
            lambda *a: model.flush(cfg, *a),
            qc + fbuf + [("n_q", _spec((), i32))],
            ["ku", "kl", "ks", "kz", "vu", "vl", "vs", "vz", "fk", "fv"],
        )
        b.add_stateless(
            f"ar_flush_{s}",
            lambda kr, vr, fk, fv, n_q: model.ar_flush(cfg, kr, vr, fk, fv, n_q),
            dense + fbuf + [("n_q", _spec((), i32))],
            ["kr", "vr", "fk", "fv"],
        )
        b.add_stateless(
            f"sparse_flush_{s}",
            lambda kr, vr, fk, fv, n_s, p: model.sparse_flush(
                cfg, kr, vr, fk, fv, n_s, p),
            sparse + fbuf + [("n_s", _spec((), i32)), ("p", _spec((), i32))],
            ["kr", "vr", "fk", "fv"],
        )

    # ---- perplexity scoring entries (Tables 2 and 5) ----
    s = SCORE_BUCKET
    variants = {
        "score_fp": dict(kv_mode="fp"),
        "score_int8": dict(kv_mode="int8"),  # QuantSpec target cache
        "score_int4_kc_vt": dict(kv_mode="int4", k_axis="channel",
                                 v_axis="token"),  # QuantSpec draft cache
        "score_int4_kt_vt": dict(kv_mode="int4", k_axis="token",
                                 v_axis="token"),
        "score_int4_kc_vc": dict(kv_mode="int4", k_axis="channel",
                                 v_axis="channel"),
        "score_int4_kt_vc": dict(kv_mode="int4", k_axis="token",
                                 v_axis="channel"),
    }
    for name, kw in variants.items():
        b.add(
            f"{name}_{s}",
            lambda w, toks, kw=kw: model.score(cfg, w, toks, s, **kw),
            [("toks", _spec((s,), i32))],
            ["ll"],
        )
    return b.entries


# --------------------------------------------------------------------------
# Main
# --------------------------------------------------------------------------


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--params", default=None,
                    help="params.npz (default <out-dir>/params.npz)")
    ap.add_argument("--buckets",
                    default=",".join(str(x) for x in BUCKETS_DEFAULT))
    args = ap.parse_args()

    cfg = model.ModelConfig()
    out_dir = args.out_dir
    os.makedirs(out_dir, exist_ok=True)
    buckets = [int(x) for x in args.buckets.split(",") if x]

    # ---- weights ----
    params_path = args.params or os.path.join(out_dir, "params.npz")
    if os.path.exists(params_path):
        raw = np.load(params_path)
        params = {k: raw[k] for k in raw.files}
        print(f"loaded trained params from {params_path}")
    else:
        print("WARNING: no trained params found, exporting random init "
              "(run `python -m compile.pretrain` first)")
        params = {k: np.asarray(v) for k, v in
                  model.init_params(jax.random.PRNGKey(0), cfg).items()}

    windex = {"fp": {}, "q4": {}}
    for setname, xform in (("fp", lambda x: x),
                           ("q4", quant_dequant_weight)):
        wdir = os.path.join(out_dir, "weights", setname)
        os.makedirs(wdir, exist_ok=True)
        for name in model.param_names(cfg):
            arr = xform(np.asarray(params[name], dtype=np.float32))
            fn = name.replace(".", "_") + ".bin"
            arr.tofile(os.path.join(wdir, fn))
            windex[setname][name] = {
                "file": f"weights/{setname}/{fn}",
                "shape": list(arr.shape),
                "dtype": "f32",
                "logical_bits": 32 if setname == "fp" else 4,
            }
    print("weights exported (fp + q4 sets)")

    # ---- entries ----
    entries = build_entries(cfg, out_dir, buckets)

    manifest = {
        "model": {
            "vocab": cfg.vocab, "d_model": cfg.d_model,
            "n_heads": cfg.n_heads, "head_dim": cfg.head_dim,
            "n_layers": cfg.n_layers, "d_ff": cfg.d_ff,
            "g": cfg.g, "tmax": cfg.tmax, "fb": cfg.fb,
            "rope_theta": cfg.rope_theta,
        },
        "buckets": buckets,
        "score_bucket": SCORE_BUCKET,
        "param_order": model.param_names(cfg),
        "weights": windex,
        "entries": entries,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"manifest: {len(entries)} entries, buckets {buckets}")


if __name__ == "__main__":
    main()
