"""Pallas kernel: flash-decoding attention over the hierarchical quantized KV.

The paper's kernel contribution (§5.2.1, Appendix E): attention where the
key/value cache is stored as upper/lower INT4 nibbles and dequantized
in-kernel, so the draft pass touches half the bytes of the target pass and a
quarter of an FP16 cache.

Structure (flash-decoding / split-KV):
  grid = (H, NB/CHUNK) over heads × tiles of CHUNK quantization blocks.
  Each grid step dequantizes a [CHUNK*G, dh] K/V tile per `mode`
      draft  : k = u * (16*S8) + Z           (upper nibble only — INT4)
      target : k = (16*u + l) * S8 + Z       (both nibbles — INT8)
  computes the tile's scores against the [T, dh] query tile, masks tokens
  >= n_q in-kernel (the region fill is dynamic; blocks are appended by the
  every-G-steps buffer flush), and emits the *partial* flash statistics
  (m = tile max, l = tile sum-of-exp, o = unnormalized p@v). The host-side
  `merge_chunks` (ref.py) LSE-combines the partials with the full-precision
  buffer chunk — exactly the paper's Appendix-E FlashDecoding integration
  where the FP buffer is "an additional chunk".

CHUNK (default 4) is the §Perf block-shape knob: one grid step per
quantization group made the interpret-lowered while-loop the CPU
bottleneck (9.3 ms/draft-step at bucket 512); 4 groups per step amortizes
the loop and feeds larger GEMMs. On TPU the same knob sizes the HBM→VMEM
DMA per grid step (4 blocks × G×dh × int4 ≈ 8 KiB — well under VMEM while
long enough to hide DMA latency behind the MXU).

Lowered with interpret=True: CPU PJRT cannot run Mosaic custom-calls;
real-TPU performance is estimated analytically in DESIGN.md.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _attn_tile_kernel(
    nq_ref, q_ref, ku_ref, kl_ref, ks_ref, kz_ref, vu_ref, vl_ref, vs_ref,
    vz_ref, o_ref, m_ref, l_ref, *, mode, scale, g, chunk,
):
    """One tile-of-CHUNK-blocks grid step, all heads batched (§Perf iter 2:
    folding H into the tile quarters the interpret-loop trip count)."""
    c = pl.program_id(0)
    cg = chunk * g
    q = q_ref[:, :, :]  # [H, T, dh]
    H, _, dh = q.shape
    ku = ku_ref[:, :, :].astype(jnp.float32).reshape(H, chunk, g, dh)
    ks = ks_ref[:, :, :]  # [H, chunk, dh] per-channel INT8 scale
    kz = kz_ref[:, :, :]
    vu = vu_ref[:, :, :].astype(jnp.float32).reshape(H, chunk, g, dh)
    vs = vs_ref[:, :, :]  # [H, chunk, g] per-token INT8 scale
    vz = vz_ref[:, :, :]
    if mode == "draft":
        k = ku * (16.0 * ks)[:, :, None, :] + kz[:, :, None, :]
        v = vu * (16.0 * vs)[:, :, :, None] + vz[:, :, :, None]
    else:
        kl = kl_ref[:, :, :].astype(jnp.float32).reshape(H, chunk, g, dh)
        vl = vl_ref[:, :, :].astype(jnp.float32).reshape(H, chunk, g, dh)
        k = (16.0 * ku + kl) * ks[:, :, None, :] + kz[:, :, None, :]
        v = (16.0 * vu + vl) * vs[:, :, :, None] + vz[:, :, :, None]
    k = k.reshape(H, cg, dh)
    v = v.reshape(H, cg, dh)
    s = jnp.einsum("htd,hsd->hts", q, k) * scale  # [H, T, cg]
    # dynamic region fill: tokens at absolute index >= n_q are invalid
    limit = nq_ref[0] - c * cg
    idx = jax.lax.broadcasted_iota(jnp.int32, (1, 1, cg), 2)
    valid = idx < limit
    s = jnp.where(valid, s, -jnp.inf)
    m = jnp.max(s, axis=2)  # [H, T]
    msafe = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.where(valid, jnp.exp(s - msafe[:, :, None]), 0.0)
    l = jnp.sum(p, axis=2)
    o = jnp.einsum("hts,hsd->htd", p, v)  # [H, T, dh]
    o_ref[:, 0, :, :] = o
    m_ref[:, 0, :] = msafe
    l_ref[:, 0, :] = l


def quant_attn_partials(q, ku, kl, ks, kz, vu, vl, vs, vz, n_q, *, g, mode,
                        chunk=1):
    """Per-tile flash partials over the quantized region.

    Args:
      q:  f32[H, T, dh] queries.
      ku, kl: int8[H, NB*G, dh] key nibbles; ks, kz: f32[H, NB, dh].
      vu, vl: int8[H, NB*G, dh] value nibbles; vs, vz: f32[H, NB, G].
      n_q: i32[1] — region fill in tokens (masked in-kernel).
      g: group size G; mode: 'draft' | 'target'; chunk: blocks per grid
         step (NB must be a multiple).
    Returns:
      (o f32[H, NC, T, dh], m f32[H, NC, T], l f32[H, NC, T]) partials,
      NC = NB/chunk, ready for merge_chunks (fully-masked tiles have l=0).
    """
    H, T, dh = q.shape
    nb = ku.shape[1] // g
    assert nb % chunk == 0, f"NB={nb} not a multiple of chunk={chunk}"
    nc = nb // chunk
    cg = chunk * g
    scale = 1.0 / (dh ** 0.5)
    kern = functools.partial(
        _attn_tile_kernel, mode=mode, scale=scale, g=g, chunk=chunk
    )
    o, m, l = pl.pallas_call(
        kern,
        grid=(nc,),
        in_specs=[
            pl.BlockSpec((1,), lambda c: (0,)),                 # n_q
            pl.BlockSpec((H, T, dh), lambda c: (0, 0, 0)),      # q
            pl.BlockSpec((H, cg, dh), lambda c: (0, c, 0)),     # ku
            pl.BlockSpec((H, cg, dh), lambda c: (0, c, 0)),     # kl
            pl.BlockSpec((H, chunk, dh), lambda c: (0, c, 0)),  # ks
            pl.BlockSpec((H, chunk, dh), lambda c: (0, c, 0)),  # kz
            pl.BlockSpec((H, cg, dh), lambda c: (0, c, 0)),     # vu
            pl.BlockSpec((H, cg, dh), lambda c: (0, c, 0)),     # vl
            pl.BlockSpec((H, chunk, g), lambda c: (0, c, 0)),   # vs
            pl.BlockSpec((H, chunk, g), lambda c: (0, c, 0)),   # vz
        ],
        out_specs=[
            pl.BlockSpec((H, 1, T, dh), lambda c: (0, c, 0, 0)),
            pl.BlockSpec((H, 1, T), lambda c: (0, c, 0)),
            pl.BlockSpec((H, 1, T), lambda c: (0, c, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((H, nc, T, dh), jnp.float32),
            jax.ShapeDtypeStruct((H, nc, T), jnp.float32),
            jax.ShapeDtypeStruct((H, nc, T), jnp.float32),
        ],
        interpret=True,
    )(jnp.reshape(n_q, (1,)).astype(jnp.int32), q, ku, kl, ks, kz, vu, vl,
      vs, vz)
    return o, m, l


def quant_attn(q, ku, kl, ks, kz, vu, vl, vs, vz, n_q, *, g, mode, chunk=1):
    """Full quantized-region attention chunk in merge_chunks format:
    o f32[H,T,dh] unnormalized, m f32[H,T], l f32[H,T]. Tokens >= n_q are
    masked in-kernel (n_q is always a multiple of G — the region only ever
    grows by whole-block flushes, paper §4.3.2)."""
    o_p, m_p, l_p = quant_attn_partials(
        q, ku, kl, ks, kz, vu, vl, vs, vz, n_q, g=g, mode=mode, chunk=chunk
    )
    vmask = l_p > 0.0  # [H, NC, T]
    m_masked = jnp.where(vmask, m_p, -jnp.inf)
    m_all = jnp.max(m_masked, axis=1)  # [H, T]
    m_safe = jnp.where(jnp.isfinite(m_all), m_all, 0.0)
    w = jnp.where(vmask, jnp.exp(m_p - m_safe[:, None, :]), 0.0)
    o = jnp.sum(o_p * w[..., None], axis=1)  # [H, T, dh]
    l = jnp.sum(l_p * w, axis=1)  # [H, T]
    return o, m_safe, l
