"""Pure-jnp reference oracles for the QuantSpec L1 kernels.

These are the correctness ground truth that the Pallas kernels
(`hier_quant.py`, `quant_attn.py`) are tested against (pytest + hypothesis).
They implement the paper's §4.2 hierarchical quantization exactly:

    C_INT8  = 16 * C_U + C_L            (upper/lower nibble decomposition)
    x_fp    = C_INT8 * S8 + Z8          (asymmetric per-group INT8)
    draft   : x ≈ C_U * (16*S8) + Z8    (upper nibble only, INT4)
    target  : x ≈ (16*C_U + C_L) * S8 + Z8   (INT8 reconstruction)

Grouping (paper §4.3.1, KIVI-style):
  * Key cache   — channel-wise: one (S8, Z8) per (token-block of G, channel).
  * Value cache — token-wise:   one (S8, Z8) per (token, channel-block of G).
With G = head_dim (the default), a value group is exactly one token's head
vector.

All functions operate on a single token-block of shape [H, G, dh] so that
the same code path serves both prefill bulk quantization and the every-G-steps
buffer flush (paper §4.3.2).
"""

from __future__ import annotations

import jax.numpy as jnp

# Epsilon guarding zero-range groups (constant inputs).
EPS = 1e-6


def _asym_scale(mn, mx):
    """Asymmetric INT8 scale/zero-point for values in [mn, mx]."""
    scale = jnp.maximum((mx - mn) / 255.0, EPS)
    zero = mn
    return scale, zero


def hier_quant_block_k(k):
    """Hierarchically quantize one key block, channel-wise.

    Args:
      k: f32[H, G, dh] — one block of G tokens of the key cache.
    Returns:
      (u, l, s8, z): u int8[H,G,dh] in [0,15], l int8[H,G,dh] in [-8,7],
      s8 f32[H,dh], z f32[H,dh] — per-(block, channel) INT8 scale/zero.
    """
    mn = jnp.min(k, axis=1)  # [H, dh] over the token axis
    mx = jnp.max(k, axis=1)
    s8, z = _asym_scale(mn, mx)
    return _hier_encode(k, s8[:, None, :], z[:, None, :]) + (s8, z)


def hier_quant_block_v(v):
    """Hierarchically quantize one value block, token-wise.

    Args:
      v: f32[H, G, dh] — one block of G tokens of the value cache.
    Returns:
      (u, l, s8, z): u int8[H,G,dh], l int8[H,G,dh], s8 f32[H,G], z f32[H,G]
      — per-token INT8 scale/zero (group = the token's dh channels).
    """
    mn = jnp.min(v, axis=2)  # [H, G] over the channel axis
    mx = jnp.max(v, axis=2)
    s8, z = _asym_scale(mn, mx)
    return _hier_encode(v, s8[:, :, None], z[:, :, None]) + (s8, z)


def _hier_encode(x, s8, z):
    """Shared upper/lower nibble encoder (paper §4.2).

    The upper nibble is asymmetric round-to-nearest INT4 with
    S4 = 16*S8, Z4 = Z8; the lower nibble symmetrically quantizes the
    upper's rounding error with step S8.
    """
    s4 = 16.0 * s8
    u = jnp.clip(jnp.round((x - z) / s4), 0.0, 15.0)
    err = x - (u * s4 + z)
    l = jnp.clip(jnp.round(err / s8), -8.0, 7.0)
    return u.astype(jnp.int8), l.astype(jnp.int8)


def dequant_blocks_k(u, l, s8, z, mode):
    """Dequantize a multi-block key region.

    u, l: int8[H, NB*G, dh]; s8, z: f32[H, NB, dh]; mode: 'draft'|'target'.
    Returns f32[H, NB*G, dh].
    """
    H, S, dh = u.shape
    nb = s8.shape[1]
    g = S // nb
    uu = u.reshape(H, nb, g, dh).astype(jnp.float32)
    if mode == "draft":
        out = uu * (16.0 * s8)[:, :, None, :] + z[:, :, None, :]
    else:
        ll = l.reshape(H, nb, g, dh).astype(jnp.float32)
        out = (16.0 * uu + ll) * s8[:, :, None, :] + z[:, :, None, :]
    return out.reshape(H, S, dh)


def dequant_blocks_v(u, l, s8, z, mode):
    """Dequantize a multi-block value region.

    u, l: int8[H, NB*G, dh]; s8, z: f32[H, NB, G]; mode: 'draft'|'target'.
    """
    H, S, dh = u.shape
    nb, g = s8.shape[1], s8.shape[2]
    uu = u.reshape(H, nb, g, dh).astype(jnp.float32)
    if mode == "draft":
        out = uu * (16.0 * s8)[:, :, :, None] + z[:, :, :, None]
    else:
        ll = l.reshape(H, nb, g, dh).astype(jnp.float32)
        out = (16.0 * uu + ll) * s8[:, :, :, None] + z[:, :, :, None]
    return out.reshape(H, S, dh)


def attn_reference(q, k, v, mask):
    """Plain masked softmax attention oracle.

    q: f32[H, T, dh]; k, v: f32[H, S, dh]; mask: bool[T, S] (True = attend).
    Returns f32[H, T, dh].
    """
    dh = q.shape[-1]
    scores = jnp.einsum("htd,hsd->hts", q, k) / jnp.sqrt(jnp.float32(dh))
    scores = jnp.where(mask[None, :, :], scores, -jnp.inf)
    p = jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return jnp.einsum("hts,hsd->htd", p, v)


def quant_attn_reference(q, ku, kl, ks, kz, vu, vl, vs, vz, n_q, mode):
    """Oracle for attention over the quantized region only.

    Dequantizes the whole region per `mode` and runs plain attention with a
    validity mask on the first `n_q` tokens. Mirrors what the Pallas kernel's
    per-block partials must combine to.

    Returns (o f32[H,T,dh], m f32[H,T], l f32[H,T]) where o is the
    UNnormalized p@v accumulator and m/l are the flash-style max and
    sum-of-exp statistics for LSE merging with other chunks (paper App. E).
    """
    kq = dequant_blocks_k(ku, kl, ks, kz, mode)
    vq = dequant_blocks_v(vu, vl, vs, vz, mode)
    dh = q.shape[-1]
    S = kq.shape[1]
    scores = jnp.einsum("htd,hsd->hts", q, kq) / jnp.sqrt(jnp.float32(dh))
    valid = jnp.arange(S)[None, None, :] < n_q
    scores = jnp.where(valid, scores, -jnp.inf)
    m = jnp.max(scores, axis=-1)
    msafe = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.where(valid, jnp.exp(scores - msafe[..., None]), 0.0)
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("hts,hsd->htd", p, vq)
    return o, msafe, l


def merge_chunks(parts):
    """LSE-merge flash-decoding chunks (paper Appendix E).

    parts: list of (o, m, l) with o f32[H,T,dh] (UNnormalized p@v), m f32[H,T]
    (chunk max), l f32[H,T] (chunk sum-of-exp). Chunks with l == 0 (fully
    masked) are neutral. Returns normalized f32[H,T,dh].
    """
    ms = jnp.stack([jnp.where(l > 0.0, m, -jnp.inf) for (_, m, l) in parts])
    m_all = jnp.max(ms, axis=0)  # [H, T]
    m_safe = jnp.where(jnp.isfinite(m_all), m_all, 0.0)
    num = 0.0
    den = 0.0
    for (o, m, l) in parts:
        w = jnp.where(l > 0.0, jnp.exp(m - m_safe), 0.0)
        num = num + o * w[..., None]
        den = den + l * w
    return num / jnp.maximum(den, EPS)[..., None]
