"""Pallas kernel: hierarchical INT4|INT4 quantization of one KV token-block.

This is the quantizer half of the paper's kernel contribution (§4.2): given a
block of G tokens of the FP key/value cache, emit the upper-nibble INT4 code,
the lower-nibble INT4 code (the quantized residual), and the shared INT8
scale/zero per group. It runs at prefill (bulk, over every block) and at the
every-G-steps full-precision buffer flush (paper Alg. 1 line 23).

TPU mapping (DESIGN.md §Hardware-Adaptation): grid over heads; each grid step
pulls one [G, dh] tile HBM→VMEM, reduces min/max on the VPU along the group
axis, and writes two int8 tiles + two f32 scale vectors back. The tile is
G*dh*4B ≈ 16 KiB for the tiny preset — trivially VMEM-resident, so the kernel
is bandwidth-bound and fuses into the surrounding prefill HLO.

Lowered with interpret=True: CPU PJRT cannot execute Mosaic custom-calls, so
interpret mode (which lowers to plain HLO) is the correctness path; real-TPU
performance is estimated analytically in DESIGN.md.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

EPS = 1e-6


def _quant_kernel(x_ref, u_ref, l_ref, s_ref, z_ref, *, axis):
    """Quantize one [G, dh] head tile.

    axis=0 → channel-wise groups (keys): stats over the G tokens per channel.
    axis=1 → token-wise groups (values): stats over the dh channels per token.
    """
    x = x_ref[0, :, :]  # [G, dh]
    mn = jnp.min(x, axis=axis)
    mx = jnp.max(x, axis=axis)
    s8 = jnp.maximum((mx - mn) / 255.0, EPS)
    z = mn
    if axis == 0:
        s8b, zb = s8[None, :], z[None, :]
    else:
        s8b, zb = s8[:, None], z[:, None]
    s4 = 16.0 * s8b
    u = jnp.clip(jnp.round((x - zb) / s4), 0.0, 15.0)
    err = x - (u * s4 + zb)
    low = jnp.clip(jnp.round(err / s8b), -8.0, 7.0)
    u_ref[0, :, :] = u.astype(jnp.int8)
    l_ref[0, :, :] = low.astype(jnp.int8)
    s_ref[0, :] = s8
    z_ref[0, :] = z


def _hier_quant_block(x, *, axis):
    """pallas_call wrapper: x f32[H, G, dh] → (u, l, s8, z)."""
    H, G, dh = x.shape
    stat = dh if axis == 0 else G
    return pl.pallas_call(
        functools.partial(_quant_kernel, axis=axis),
        grid=(H,),
        in_specs=[pl.BlockSpec((1, G, dh), lambda h: (h, 0, 0))],
        out_specs=[
            pl.BlockSpec((1, G, dh), lambda h: (h, 0, 0)),
            pl.BlockSpec((1, G, dh), lambda h: (h, 0, 0)),
            pl.BlockSpec((1, stat), lambda h: (h, 0)),
            pl.BlockSpec((1, stat), lambda h: (h, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((H, G, dh), jnp.int8),
            jax.ShapeDtypeStruct((H, G, dh), jnp.int8),
            jax.ShapeDtypeStruct((H, stat), jnp.float32),
            jax.ShapeDtypeStruct((H, stat), jnp.float32),
        ],
        interpret=True,
    )(x)


def hier_quant_block_k(k):
    """Key block quantizer: f32[H,G,dh] → (u, l, s8 f32[H,dh], z f32[H,dh])."""
    return tuple(_hier_quant_block(k, axis=0))


def hier_quant_block_v(v):
    """Value block quantizer: f32[H,G,dh] → (u, l, s8 f32[H,G], z f32[H,G])."""
    return tuple(_hier_quant_block(v, axis=1))
