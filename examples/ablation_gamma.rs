//! Walkthrough of the gamma / acceptance trade-off (paper Table 6 logic)
//! on the mock backend — runs in milliseconds, no artifacts needed, and
//! shows how expected-tokens-per-cycle interacts with draft quality.
//!
//!     cargo run --release --example ablation_gamma

use quantspec::config::Method;
use quantspec::costmodel::latency::expected_tokens_per_cycle;
use quantspec::model::MockDecoder;
use quantspec::spec::{Sampler, SpecEngine};

fn main() -> anyhow::Result<()> {
    println!("gamma ablation on the mock backend (draft error = acceptance knob)\n");
    println!("{:<10} {:>6} {:>10} {:>14} {:>16}", "draft_err", "gamma",
             "accept_%", "tok/cycle", "E[tok/cycle] fml");
    for draft_err in [0.05, 0.2, 0.5] {
        for gamma in [1usize, 2, 4, 7] {
            let mut dec = MockDecoder::new(64, 7, draft_err);
            dec.force_method(Method::QuantSpec);
            let mut eng = SpecEngine::new(gamma, Sampler::new(0.0, 1));
            let out = eng.generate(&mut dec, &[1, 2, 3, 4], 300)?;
            let measured = out.tokens.len() as f64 / out.cycles as f64;
            let formula = expected_tokens_per_cycle(out.acceptance_rate(), gamma);
            println!(
                "{:<10.2} {:>6} {:>10.1} {:>14.2} {:>16.2}",
                draft_err, gamma,
                out.acceptance_rate() * 100.0,
                measured, formula,
            );
        }
        println!();
    }
    println!("reading: higher gamma only pays when acceptance stays high —");
    println!("the paper's Table 6 finding that sparse drafts (low acceptance at");
    println!("large gamma) peak at gamma=1 while QuantSpec peaks at 4-6.");
    Ok(())
}
