//! Quickstart: load the artifacts, run one QuantSpec generation, print the
//! text and the speculation statistics.
//!
//!     make artifacts && cargo run --release --example quickstart

use std::sync::Arc;

use quantspec::config::{Method, QuantMode};
use quantspec::model::xla_session::XlaSession;
use quantspec::model::Decoder;
use quantspec::runtime::{Runtime, WeightSet, Weights};
use quantspec::spec::{Sampler, SpecEngine};
use quantspec::workload::{self, Profile};

fn main() -> anyhow::Result<()> {
    // 1. Load the AOT artifacts (HLO text -> PJRT executables, compiled
    //    lazily) and the two weight sets the paper's method needs: the
    //    full-precision target weights and the INT4 draft weights.
    let rt = Runtime::load("artifacts")?;
    let w_fp = Arc::new(Weights::load(&rt, WeightSet::Fp)?);
    let w_q4 = Arc::new(Weights::load(&rt, WeightSet::Q4)?);

    // 2. Make a long-context prompt (synthetic book, PG-19 stand-in).
    let bucket = 512;
    let prompt = workload::prompt(7, bucket, Profile::Pg19);

    // 3. One QuantSpec session: hierarchical INT4|INT4 KV cache, INT4
    //    draft weights, double FP buffer.
    let mut session = XlaSession::new(
        Arc::clone(&rt),
        Method::QuantSpec,
        QuantMode::Both,
        bucket,
        w_fp,
        w_q4,
    )?;

    // 4. Speculative decode: draft gamma=4 tokens on the INT4 path, verify
    //    them in one INT8 pass (greedy, so speculation is lossless).
    let mut engine = SpecEngine::new(4, Sampler::new(0.0, 0));
    let out = engine.generate(&mut session, &prompt, 64)?;

    let text: String = out
        .tokens
        .iter()
        .map(|&t| char::from(t.clamp(0, 255) as u8))
        .map(|c| if c.is_ascii_graphic() || c == ' ' || c == '\n' { c } else { '?' })
        .collect();
    println!("generated: {text:?}");
    println!("acceptance rate : {:.1}%", out.acceptance_rate() * 100.0);
    println!("cycles          : {} (gamma=4)", out.cycles);
    println!("decode          : {:.2} tok/s", out.decode_tokens_per_sec());
    let mem = session.memory();
    println!(
        "cache memory    : {:.1} MB logical ({:.1} MB host-resident)",
        mem.cache_logical as f64 / 1e6,
        mem.cache_host as f64 / 1e6
    );
    Ok(())
}
