//! End-to-end serving driver (the repo's E2E validation; EXPERIMENTS.md §E2E).
//!
//! Starts the full coordinator (HTTP server + router + engines) over the
//! real artifacts, fires a batch of long-context requests through the HTTP
//! API with Poisson arrivals, and reports latency percentiles + throughput
//! + acceptance — the serving-paper validation loop.
//!
//!     cargo run --release --example serve_longcontext [-- --requests N]

use std::sync::Arc;

use quantspec::config::ServeConfig;
use quantspec::coordinator::{server, Coordinator};
use quantspec::util::argparse::Args;
use quantspec::util::httpd::http_request;
use quantspec::util::json::Json;
use quantspec::workload::{self, Profile};

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let n_requests = args.get_usize("requests", 8);
    let bucket = args.get_usize("bucket", 512);
    let max_new = args.get_usize("max-new-tokens", 48);
    let rate = args.get_f64("rate", 0.5); // req/s open-loop

    let cfg = ServeConfig {
        engines: 1, // single-core testbed
        max_new_tokens: max_new,
        ..ServeConfig::default()
    };
    let rt = quantspec::runtime::Runtime::load(&cfg.artifacts_dir)?;
    eprintln!("compiling bucket {bucket} artifacts...");
    rt.warmup(&[bucket])?;
    let coord = Arc::new(Coordinator::with_runtime(cfg, rt)?);
    let srv = server::serve(Arc::clone(&coord), "127.0.0.1:0")?;
    let addr = srv.addr.to_string();
    println!("coordinator on http://{addr}; firing {n_requests} requests");

    let arrivals = workload::poisson_arrivals(9, n_requests, rate);
    let t0 = std::time::Instant::now();
    let mut handles = Vec::new();
    for (i, &at) in arrivals.iter().enumerate() {
        let addr = addr.clone();
        let profile = [Profile::Pg19, Profile::LexSum, Profile::InfBench][i % 3];
        // prompts a bit under the bucket exercise the router's padding
        let len = bucket - (i % 64);
        handles.push(std::thread::spawn(move || {
            let wait = at - t0.elapsed().as_secs_f64();
            if wait > 0.0 {
                std::thread::sleep(std::time::Duration::from_secs_f64(wait));
            }
            let prompt_toks = workload::prompt(100 + i as u64, len, profile);
            let body = Json::obj(vec![
                ("tokens", Json::arr(prompt_toks.iter().map(|&t| Json::num(t as f64)))),
                ("max_new_tokens", Json::num(max_new as f64)),
            ])
            .to_string();
            let t = std::time::Instant::now();
            let (status, resp) =
                http_request(&addr, "POST", "/generate", body.as_bytes()).unwrap();
            (status, resp, t.elapsed().as_secs_f64())
        }));
    }

    let mut e2e = Vec::new();
    let mut accepts = Vec::new();
    let mut tokens = 0usize;
    for h in handles {
        let (status, resp, secs) = h.join().unwrap();
        assert_eq!(status, 200, "{}", String::from_utf8_lossy(&resp));
        let j = Json::parse(std::str::from_utf8(&resp)?).unwrap();
        tokens += j.get("tokens").unwrap().as_arr().unwrap().len();
        accepts.push(j.get("acceptance_rate").unwrap().as_f64().unwrap());
        e2e.push(secs);
    }
    let wall = t0.elapsed().as_secs_f64();
    e2e.sort_by(f64::total_cmp);
    let pct = |q: f64| e2e[((e2e.len() as f64 * q) as usize).min(e2e.len() - 1)];
    println!("\n== serve_longcontext results ==");
    println!("requests        : {n_requests} (bucket {bucket}, {max_new} new tokens each)");
    println!("wall time       : {wall:.1}s");
    println!("throughput      : {:.2} tokens/s aggregate", tokens as f64 / wall);
    println!("e2e latency     : p50 {:.2}s  p95 {:.2}s  max {:.2}s",
             pct(0.50), pct(0.95), e2e.last().unwrap());
    println!("acceptance      : mean {:.1}%",
             100.0 * accepts.iter().sum::<f64>() / accepts.len() as f64);
    println!("\ncoordinator stats: {}", coord.metrics.snapshot());
    Ok(())
}
