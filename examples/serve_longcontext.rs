//! End-to-end serving driver (the repo's E2E validation; EXPERIMENTS.md §E2E).
//!
//! Starts the full coordinator (HTTP server + router + engines), fires a
//! batch of long-context requests through the HTTP API with Poisson
//! arrivals, and reports latency percentiles + throughput + acceptance.
//!
//! Two modes:
//!
//! * **artifacts** (default when `artifacts/manifest.json` exists): the
//!   real AOT/XLA backend, single engine.
//! * **mock / pooled** (`--mock`, or no artifacts): ≥4 engines decode
//!   concurrently out of ONE bounded paged KV pool. The run validates the
//!   pool contract: pages-in-use never exceeds the configured pool size,
//!   an over-capacity request is rejected cleanly (never OOM), and
//!   acceptance/output match the unpooled path exactly. It also scrapes
//!   the observability surface: `GET /metrics` (Prometheus exposition,
//!   written to `bench_out/metrics.prom` for CI to format-check) and
//!   `GET /debug/requests` (the flight recorder's request timelines), and
//!   fires a `"stream": true` request — validating the SSE frame sequence
//!   (`prefill` → `token`* → `done`), chunk-concat parity against the
//!   buffered body, and the `x-total-tokens` trailer.
//!
//!     cargo run --release --example serve_longcontext -- --mock [--requests N]

use std::sync::Arc;

use quantspec::config::ServeConfig;
use quantspec::coordinator::{server, Coordinator};
use quantspec::pool::PoolConfig;
use quantspec::util::argparse::Args;
use quantspec::util::httpd::http_request;
use quantspec::util::json::Json;
use quantspec::workload::{self, Profile};

struct BatchResult {
    e2e: Vec<f64>,
    accepts: Vec<f64>,
    token_lists: Vec<Vec<i64>>,
    tokens: usize,
    wall: f64,
}

/// Fire `n` generate calls with Poisson arrivals (or, with `simultaneous`,
/// all at once through a start barrier); panics on non-200.
fn fire_batch(
    addr: &str,
    n: usize,
    base_len: usize,
    len_jitter: usize,
    max_new: usize,
    rate: f64,
    simultaneous: bool,
) -> anyhow::Result<BatchResult> {
    let arrivals = workload::poisson_arrivals(9, n, rate);
    let barrier = simultaneous.then(|| Arc::new(std::sync::Barrier::new(n)));
    let t0 = std::time::Instant::now();
    let mut handles = Vec::new();
    for (i, &at) in arrivals.iter().enumerate() {
        let addr = addr.to_string();
        let barrier = barrier.clone();
        let profile = [Profile::Pg19, Profile::LexSum, Profile::InfBench][i % 3];
        // prompts a bit under the base exercise the router's padding
        let len = base_len - (i % len_jitter.max(1));
        handles.push(std::thread::spawn(move || {
            if let Some(b) = &barrier {
                b.wait();
            } else {
                let wait = at - t0.elapsed().as_secs_f64();
                if wait > 0.0 {
                    std::thread::sleep(std::time::Duration::from_secs_f64(wait));
                }
            }
            let prompt_toks = workload::prompt(100 + i as u64, len, profile);
            let body = Json::obj(vec![
                ("tokens", Json::arr(prompt_toks.iter().map(|&t| Json::num(t as f64)))),
                ("max_new_tokens", Json::num(max_new as f64)),
            ])
            .to_string();
            let t = std::time::Instant::now();
            let (status, resp) =
                http_request(&addr, "POST", "/generate", body.as_bytes()).unwrap();
            (status, resp, t.elapsed().as_secs_f64())
        }));
    }

    let mut out = BatchResult {
        e2e: Vec::new(),
        accepts: Vec::new(),
        token_lists: Vec::new(),
        tokens: 0,
        wall: 0.0,
    };
    for h in handles {
        let (status, resp, secs) = h.join().unwrap();
        assert_eq!(status, 200, "{}", String::from_utf8_lossy(&resp));
        let j = Json::parse(std::str::from_utf8(&resp)?).unwrap();
        let toks: Vec<i64> = j
            .get("tokens")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .filter_map(Json::as_i64)
            .collect();
        out.tokens += toks.len();
        out.token_lists.push(toks);
        out.accepts
            .push(j.get("acceptance_rate").unwrap().as_f64().unwrap());
        out.e2e.push(secs);
    }
    out.wall = t0.elapsed().as_secs_f64();
    out.e2e.sort_by(f64::total_cmp);
    Ok(out)
}

/// One line of Prometheus text exposition: a `#` comment, a blank, or
/// `name{labels} value` where the value parses as f64 (or `+Inf`).
fn exposition_line_ok(line: &str) -> bool {
    if line.is_empty() || line.starts_with('#') {
        return true;
    }
    let Some((name_part, value)) = line.rsplit_once(' ') else {
        return false;
    };
    if value != "+Inf" && value.parse::<f64>().is_err() {
        return false;
    }
    let name = name_part.split('{').next().unwrap_or("");
    !name.is_empty()
        && name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
}

fn report(tag: &str, n: usize, max_new: usize, r: &BatchResult) {
    let pct = |q: f64| r.e2e[((r.e2e.len() as f64 * q) as usize).min(r.e2e.len() - 1)];
    println!("\n== serve_longcontext results ({tag}) ==");
    println!("requests        : {n} ({max_new} new tokens each)");
    println!("wall time       : {:.2}s", r.wall);
    println!("throughput      : {:.2} tokens/s aggregate", r.tokens as f64 / r.wall);
    println!(
        "e2e latency     : p50 {:.3}s  p95 {:.3}s  max {:.3}s",
        pct(0.50),
        pct(0.95),
        r.e2e.last().unwrap()
    );
    println!(
        "acceptance      : mean {:.1}%",
        100.0 * r.accepts.iter().sum::<f64>() / r.accepts.len() as f64
    );
}

fn mock_main(args: &Args) -> anyhow::Result<()> {
    let n_requests = args.get_usize("requests", 12);
    let prompt_len = args.get_usize("prompt-len", 96);
    let max_new = args.get_usize("max-new-tokens", 48);
    // near-simultaneous arrivals so the engines genuinely overlap
    let rate = args.get_f64("rate", 100_000.0);
    let engines = args.get_usize("engines", 4);
    // each request reserves ~22 pages; 112 pages (ceiling 100) admit four
    // concurrent sessions and make the fifth wait at the queue head
    let pool_pages = args.get_usize("pool-pages", 112);
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    // parallel rounds on the pooled path (serial on a single-core host);
    // the unpooled comparison coordinator stays serial on purpose — its
    // outputs must still match bit-for-bit
    let step_workers = args.get_usize("step-workers", if cores >= 2 { 2 } else { 1 });

    let pool = PoolConfig {
        pages: pool_pages,
        page_tokens: 8,
        kv_dim: 2,
        high_watermark: 0.9,
        low_watermark: 0.7,
        ..PoolConfig::default()
    };
    let pooled_cfg = ServeConfig {
        engines,
        max_new_tokens: max_new,
        pool: pool.clone(),
        step_workers,
        ..ServeConfig::default()
    };
    let unpooled_cfg = ServeConfig {
        engines,
        max_new_tokens: max_new,
        ..ServeConfig::default()
    };

    let pooled = Arc::new(Coordinator::with_mock(pooled_cfg, 0.1)?);
    let plain = Arc::new(Coordinator::with_mock(unpooled_cfg, 0.1)?);
    let srv_pooled = server::serve(Arc::clone(&pooled), "127.0.0.1:0")?;
    let srv_plain = server::serve(Arc::clone(&plain), "127.0.0.1:0")?;
    let addr = srv_pooled.addr.to_string();
    println!(
        "pooled coordinator on http://{addr}: {engines} engines over one \
         {pool_pages}-page KV pool; firing {n_requests} requests"
    );

    let pr = fire_batch(&addr, n_requests, prompt_len, 16, max_new, rate, true)?;
    report("pooled", n_requests, max_new, &pr);

    // --- pool contract: hard bound, clean rejection, zero leak ----------
    let (status, resp) = {
        // a prompt this size needs more pages than the whole pool
        let giant: Vec<Json> = (0..pool_pages * 8 * 2).map(|t| Json::num(t as f64)).collect();
        let body = Json::obj(vec![
            ("tokens", Json::Arr(giant)),
            ("max_new_tokens", Json::num(max_new as f64)),
        ])
        .to_string();
        http_request(&addr, "POST", "/generate", body.as_bytes())?
    };
    assert_ne!(status, 200, "over-capacity request must not be served");
    let msg = String::from_utf8_lossy(&resp).to_string();
    assert!(msg.contains("pool"), "clean admission rejection, got: {msg}");
    println!("\nover-capacity request rejected cleanly ({status}): {msg}");

    let (_, stats) = http_request(&addr, "GET", "/stats", b"")?;
    let stats = Json::parse(std::str::from_utf8(&stats)?).unwrap();
    let pool_stats = stats.get("pool").expect("pool block in /stats").clone();
    let peak = pool_stats.get("pages_peak").unwrap().as_usize().unwrap();
    let in_use = pool_stats.get("pages_in_use").unwrap().as_usize().unwrap();
    assert!(peak <= pool_pages, "peak {peak} exceeded pool size {pool_pages}");
    assert_eq!(in_use, 0, "all sessions released");
    // Each live session holds ≥12 pages from prefill on (the ~81-96-token
    // prompts quantize ≥9 groups + 3 FP pages; non-G-multiple prompts no
    // longer pad up to a bucket); a peak of 2x that proves sessions
    // genuinely decoded concurrently out of the one arena. On a
    // single-core host the mock decodes too fast to guarantee overlap, so
    // only report there instead of asserting.
    if cores >= 2 {
        assert!(peak >= 24, "expected concurrent sessions, peak was only {peak}");
    } else {
        println!("single-core host: skipping concurrency assertion (peak {peak})");
    }
    assert!(
        pool_stats.get("prefill_deferrals").is_some(),
        "/stats pool block surfaces the backpressure counter"
    );
    // round-parallelism telemetry on the SERVING path: the unified
    // scheduler's global batcher reports its rounds through the session
    // manager, and the gauges mirror the keys (plus the scheduler's
    // global depth/queue gauges)
    for key in ["step_workers", "round_span_us", "step_workers_busy", "batcher_rounds"] {
        assert!(
            pool_stats.get(key).is_some(),
            "/stats pool block missing round-parallelism key {key}"
        );
    }
    assert_eq!(
        pool_stats.get("step_workers").unwrap().as_usize(),
        Some(engines * step_workers),
        "fleet-wide stealing-pool size surfaced (engines x step-workers)"
    );
    let rounds = pool_stats.get("batcher_rounds").unwrap().as_usize().unwrap();
    assert!(rounds > 0, "serving ran through batcher rounds");
    let gauges = stats.get("gauges").expect("gauges block");
    assert!(gauges.get("step_workers").is_some(), "step_workers gauge");
    assert!(gauges.get("round_span_us").is_some(), "round_span_us gauge");
    assert!(
        gauges.get("sched_batcher_depth").is_some(),
        "unified scheduler batcher depth gauge"
    );
    assert!(
        gauges.get("sched_pool_workers").is_some(),
        "unified scheduler pool-size gauge"
    );
    println!(
        "round telemetry : {rounds} rounds, step_workers {step_workers}, \
         last span {:.1}us",
        pool_stats.get("round_span_us").unwrap().as_f64().unwrap_or(0.0)
    );
    println!("\npool state      : {pool_stats}");
    println!(
        "pages           : peak {peak} / {pool_pages} (bound held), in use now {in_use}"
    );
    println!(
        "admission       : {} wait-polls, {} shed, {} too-large",
        pooled.metrics.counter("pool_admission_wait_polls"),
        pooled.metrics.counter("requests_shed_pool"),
        pooled.metrics.counter("requests_rejected_too_large"),
    );

    // --- observability: Prometheus exposition + flight recorder ---------
    // Scrape /metrics from the live pooled coordinator, check every line
    // is well-formed exposition, and persist the body so CI can gate on
    // it; then pull /debug/requests and check the flight recorder holds
    // complete timelines for the requests just served.
    {
        let (status, body) = http_request(&addr, "GET", "/metrics", b"")?;
        assert_eq!(status, 200, "/metrics must serve");
        let text = String::from_utf8(body)?;
        let mut lines = 0usize;
        for line in text.lines() {
            lines += 1;
            assert!(exposition_line_ok(line), "malformed exposition line: {line:?}");
        }
        for needle in [
            "# TYPE requests_completed counter",
            "# TYPE acceptance_rate_pct histogram",
            "phase_verify_us_bucket",
            "round_prefill_us",
            "le=\"+Inf\"",
        ] {
            assert!(text.contains(needle), "/metrics missing {needle:?}");
        }
        std::fs::create_dir_all("bench_out")?;
        std::fs::write("bench_out/metrics.prom", &text)?;
        println!(
            "\nmetrics         : {lines} exposition lines -> bench_out/metrics.prom"
        );

        let (status, body) = http_request(&addr, "GET", "/debug/requests", b"")?;
        assert_eq!(status, 200, "/debug/requests must serve");
        let j = Json::parse(std::str::from_utf8(&body)?).unwrap();
        let reqs = j.get("requests").expect("requests array").as_arr().unwrap();
        assert!(
            !reqs.is_empty(),
            "flight recorder must hold the requests just served"
        );
        for r in reqs {
            let events = r.get("events").expect("events").as_arr().unwrap();
            assert!(!events.is_empty(), "timeline has events");
            assert_eq!(
                events.last().unwrap().get("phase").unwrap().as_str(),
                Some("completed"),
                "every recorded timeline ends with its completion marker"
            );
            let mut last = 0i64;
            for e in events {
                let at = e.get("at_us").unwrap().as_i64().unwrap();
                assert!(at >= last, "event stamps monotone");
                last = at;
            }
        }
        println!(
            "flight recorder : {} complete request timelines in /debug/requests",
            reqs.len()
        );
    }

    // --- streaming: SSE-chunked response off the same engine path -------
    // `"stream": true` turns the response into one HTTP chunk per frame
    // (`prefill`, then a `token` frame per verify cycle, then `done`);
    // validate the frame sequence, the chunk-concat == buffered parity,
    // and the `x-total-tokens` trailer, and report the client-observed
    // TTFT.
    {
        use quantspec::util::httpd::http_open_stream;
        let prompt_toks = workload::prompt(777, prompt_len, Profile::Pg19);
        let mk_body = |stream: bool| {
            let mut fields = vec![
                ("tokens", Json::arr(prompt_toks.iter().map(|&t| Json::num(t as f64)))),
                ("max_new_tokens", Json::num(max_new as f64)),
            ];
            if stream {
                fields.push(("stream", Json::Bool(true)));
            }
            Json::obj(fields).to_string()
        };
        let (st, body) = http_request(&addr, "POST", "/generate", mk_body(false).as_bytes())?;
        assert_eq!(st, 200, "{}", String::from_utf8_lossy(&body));
        let want: Vec<i64> = Json::parse(std::str::from_utf8(&body)?)
            .unwrap()
            .get("tokens")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .filter_map(Json::as_i64)
            .collect();
        let t = std::time::Instant::now();
        let (st, mut chunks) =
            http_open_stream(&addr, "POST", "/generate", mk_body(true).as_bytes())?;
        assert_eq!(st, 200, "streamed generate must commit a chunked 200 head");
        let mut ttft = None;
        let mut frames = 0usize;
        let mut streamed: Vec<i64> = Vec::new();
        let mut done_seen = false;
        while let Some(chunk) = chunks.next_chunk()? {
            let text = String::from_utf8_lossy(&chunk).into_owned();
            assert!(!done_seen, "no frame may follow the terminal `done`");
            if text.starts_with("event: token") {
                ttft.get_or_insert(t.elapsed().as_secs_f64());
                frames += 1;
                let data = text
                    .lines()
                    .find_map(|l| l.strip_prefix("data: "))
                    .expect("token frame carries a data line");
                streamed.extend(
                    Json::parse(data)
                        .unwrap()
                        .get("tokens")
                        .unwrap()
                        .as_arr()
                        .unwrap()
                        .iter()
                        .filter_map(Json::as_i64),
                );
            } else if text.starts_with("event: done") {
                done_seen = true;
            }
        }
        assert!(done_seen, "stream must end with a `done` frame");
        assert_eq!(streamed, want, "streamed chunks diverged from the buffered body");
        let trailer = chunks
            .trailers()
            .iter()
            .find(|(k, _)| k == "x-total-tokens")
            .map(|(_, v)| v.clone())
            .expect("terminal chunk carries x-total-tokens");
        assert_eq!(trailer, streamed.len().to_string());
        println!(
            "streaming       : {} tokens over {frames} SSE chunks, \
             TTFT {:.1}ms (trailer x-total-tokens={trailer}) ✓",
            streamed.len(),
            ttft.unwrap_or(0.0) * 1e3,
        );
    }

    // --- pooled output must match the unpooled seed path ----------------
    let ur = fire_batch(&srv_plain.addr.to_string(), n_requests, prompt_len, 16, max_new, rate, true)?;
    report("unpooled", n_requests, max_new, &ur);
    assert_eq!(
        pr.token_lists, ur.token_lists,
        "paged pool changed decode outputs"
    );
    for (a, b) in pr.accepts.iter().zip(&ur.accepts) {
        assert!((a - b).abs() < 1e-9, "acceptance diverged: {a} vs {b}");
    }
    println!("\npooled outputs identical to unpooled path ✓");

    // --- chunked prefill: a huge prompt never blocks decode --------------
    // A standalone batcher over its own pool: one 2048-token prompt
    // admitted in `Prefilling` state (128-token chunks, quant-pool
    // backpressure wired) alongside two live decode sessions. The short
    // sessions must retire while the huge prefill is still feeding chunks,
    // and no round may feed more than one chunk of prefill work.
    {
        use quantspec::coordinator::batcher::{
            ActiveSession, QuantBackpressure, StepBatcher,
        };
        use quantspec::costmodel::memory::pool_pages_for_request;
        use quantspec::model::{mock_fb, MockDecoder, MOCK_GAMMA_MAX, MOCK_VOCAB};
        use quantspec::spec::Sampler;
        let (g, d, chunk, huge) = (8usize, 2usize, 128usize, 2048usize);
        let fb = mock_fb(g, MOCK_GAMMA_MAX);
        let mgr = quantspec::pool::shared(PoolConfig {
            pages: 600,
            page_tokens: g,
            kv_dim: d,
            high_watermark: 1.0,
            low_watermark: 1.0,
            ..PoolConfig::default()
        })?;
        // the config knob is the single source of the soft limit (the
        // pooled coordinator's policy reads the same field)
        let soft_limit = pooled.cfg.quant_queue_soft_limit;
        let mut b = StepBatcher::new(3)
            .with_backpressure(QuantBackpressure::for_pool(mgr.clone(), soft_limit));
        let mut admit = |id: u64, len: usize, new: usize, chunked: bool| {
            let pages = pool_pages_for_request(len, new, g, fb);
            let cap = (pages - fb.div_ceil(g)) * g;
            mgr.lock().unwrap().admit(id, pages, false).unwrap();
            let dec = Box::new(
                MockDecoder::with_pool(MOCK_VOCAB, MOCK_GAMMA_MAX, 0.1, mgr.clone(), id, cap)
                    .unwrap(),
            );
            let prompt = workload::prompt(id, len, Profile::Pg19);
            let s = if chunked {
                ActiveSession::admit_chunked(id, dec, Sampler::new(0.0, id), 4, &prompt, new, chunk)
            } else {
                ActiveSession::admit(id, dec, Sampler::new(0.0, id), 4, &prompt, new).unwrap()
            };
            b.admit(s).unwrap();
        };
        admit(1, huge, 8, true);
        admit(2, 24, 24, false);
        admit(3, 24, 24, false);
        let mut last_fed = 0usize;
        let mut shorts_done_at_fed = None;
        while b.active_len() > 0 {
            b.round()?;
            let fed = b
                .active_sessions()
                .find(|s| s.id == 1)
                .and_then(|s| s.prefill_progress())
                .map(|(f, _)| f)
                .unwrap_or(huge);
            assert!(fed - last_fed <= chunk, "round fed {} tokens", fed - last_fed);
            last_fed = fed;
            if shorts_done_at_fed.is_none()
                && b.finished.iter().filter(|s| s.id > 1).count() == 2
            {
                shorts_done_at_fed = Some(fed);
            }
        }
        let shorts_done_at_fed = shorts_done_at_fed.expect("short sessions finished");
        assert!(
            shorts_done_at_fed < huge,
            "short sessions only finished after the whole {huge}-token prefill"
        );
        assert_eq!(b.finished.len(), 3);
        for id in 1..=3 {
            mgr.lock().unwrap().release(id);
        }
        println!(
            "chunked prefill : {huge}-token prompt fed in {chunk}-token rounds; \
             short sessions retired at {shorts_done_at_fed} tokens fed \
             ({} deferrals) ✓",
            b.prefill_deferrals()
        );
    }
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let use_mock = args.has_flag("mock")
        || !std::path::Path::new("artifacts/manifest.json").exists();
    if use_mock {
        return mock_main(&args);
    }

    let n_requests = args.get_usize("requests", 8);
    let bucket = args.get_usize("bucket", 512);
    let max_new = args.get_usize("max-new-tokens", 48);
    let rate = args.get_f64("rate", 0.5); // req/s open-loop

    let cfg = ServeConfig {
        engines: 1, // single-core testbed
        max_new_tokens: max_new,
        ..ServeConfig::default()
    };
    let rt = quantspec::runtime::Runtime::load(&cfg.artifacts_dir)?;
    eprintln!("compiling bucket {bucket} artifacts...");
    rt.warmup(&[bucket])?;
    let coord = Arc::new(Coordinator::with_runtime(cfg, rt)?);
    let srv = server::serve(Arc::clone(&coord), "127.0.0.1:0")?;
    let addr = srv.addr.to_string();
    println!("coordinator on http://{addr}; firing {n_requests} requests");

    let r = fire_batch(&addr, n_requests, bucket, 64, max_new, rate, false)?;
    report("artifacts", n_requests, max_new, &r);
    println!("\ncoordinator stats: {}", coord.metrics.snapshot());
    Ok(())
}
