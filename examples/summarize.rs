//! Summarization-style workload (the paper's Multi-LexSum / ∞Bench setting):
//! a long legal-ish document whose SUMMARY section depends on entities from
//! the whole context. Compares QuantSpec against the sparse-KV baselines on
//! the same document — the setting where sparse drafts lose acceptance.
//!
//!     cargo run --release --example summarize

use std::sync::Arc;

use quantspec::config::{Method, QuantMode};
use quantspec::model::xla_session::XlaSession;
use quantspec::model::Decoder;
use quantspec::runtime::{Runtime, WeightSet, Weights};
use quantspec::spec::{Sampler, SpecEngine};
use quantspec::workload::{self, Profile};

fn main() -> anyhow::Result<()> {
    let rt = Runtime::load("artifacts")?;
    let w_fp = Arc::new(Weights::load(&rt, WeightSet::Fp)?);
    let w_q4 = Arc::new(Weights::load(&rt, WeightSet::Q4)?);
    let bucket = 1024;
    let gamma = 4;
    // LexSum-like document ending in "SUMMARY: the dispute between ..." —
    // continuing it forces the model to recall document-wide entities.
    let prompt = workload::prompt(1234, bucket, Profile::LexSum);

    println!("summarizing a {bucket}-token filing (gamma={gamma})\n");
    for method in [Method::QuantSpec, Method::StreamingLlm, Method::SnapKv] {
        let mut session = XlaSession::new(
            Arc::clone(&rt), method, QuantMode::Both, bucket,
            Arc::clone(&w_fp), Arc::clone(&w_q4),
        )?;
        let mut engine = SpecEngine::new(gamma, Sampler::new(0.0, 0));
        let out = engine.generate(&mut session, &prompt, 48)?;
        let text: String = out
            .tokens
            .iter()
            .map(|&t| char::from(t.clamp(0, 255) as u8))
            .map(|c| if c.is_ascii_graphic() || c == ' ' { c } else { ' ' })
            .collect();
        let t = session.timings();
        println!("--- {} ---", method.name());
        println!("  continuation : {}", text.trim());
        println!("  acceptance   : {:.1}%", out.acceptance_rate() * 100.0);
        println!("  decode       : {:.2} tok/s", out.decode_tokens_per_sec());
        println!(
            "  phase secs   : draft {:.2} verify {:.2} flush {:.2}",
            t.draft, t.verify, t.flush
        );
    }
    println!("\nexpected: QuantSpec holds the highest acceptance here because the");
    println!("summary depends on context the sparse drafts evicted (paper §5.2).");
    Ok(())
}
