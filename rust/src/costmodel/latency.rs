//! Per-step latency model and speedup projection.
//!
//! The projection combines (a) modeled per-step times on the paper's A6000
//! from the roofline byte/FLOP tallies with (b) *measured* acceptance rates
//! from real end-to-end runs on the CPU testbed. This is the substitution
//! documented in DESIGN.md §4: acceptance is an algorithmic property we
//! measure; step latency is a bandwidth property we model with the paper's
//! own §3 methodology.

use super::intensity::{decode_attention_kv, decode_linear, OpCount};
use super::{Hardware, PaperModel};
use crate::config::{Method, QuantMode};

/// Bytes per KV element for each cache representation.
pub const KV_FP16: f64 = 2.0;
pub const KV_INT8: f64 = 1.0; // both nibbles (target verify)
pub const KV_INT4: f64 = 0.5; // upper nibble only (draft)

/// Weight bytes multiplier (vs fp16 params).
fn weight_bytes(m: &PaperModel, bits: f64) -> f64 {
    m.params() as f64 * bits / 8.0
}

/// One decode step over T in-flight tokens with the given weight width and
/// KV representation; `s` = attended context length.
pub fn step_ops(
    m: &PaperModel,
    b: usize,
    s: usize,
    t: usize,
    weight_bits: f64,
    kv_bytes: f64,
) -> OpCount {
    // Linear part: weights loaded once per step regardless of T.
    let lin = decode_linear(m, b, 1);
    let lin = OpCount {
        flops: lin.flops * t as f64,
        mops_bytes: weight_bytes(m, weight_bits)
            + (lin.mops_bytes - weight_bytes(m, 16.0)) * t as f64,
    };
    // Attention: cache loaded once per step; scores for T queries.
    let attn = decode_attention_kv(m, b, s, 1, kv_bytes);
    let attn = OpCount { flops: attn.flops * t as f64, mops_bytes: attn.mops_bytes };
    lin.add(attn)
}

/// Modeled times for one speculation cycle of a method.
#[derive(Debug, Clone, Copy)]
pub struct CycleModel {
    pub draft_step_secs: f64,
    pub verify_secs: f64,
    pub ar_step_secs: f64,
}

pub fn cycle_model(
    m: &PaperModel,
    hw: &Hardware,
    method: Method,
    quant_mode: QuantMode,
    b: usize,
    s: usize,
    gamma: usize,
) -> CycleModel {
    let ar = hw.time_secs(&step_ops(m, b, s, 1, 16.0, KV_FP16));
    let (draft, verify) = match method {
        Method::Autoregressive => (ar, ar),
        Method::QuantSpec => {
            let (wbits, kv_draft) = match quant_mode {
                QuantMode::Both => (4.0, KV_INT4),
                QuantMode::KvOnly => (16.0, KV_INT4),
                QuantMode::WeightOnly => (4.0, KV_FP16),
            };
            let d = hw.time_secs(&step_ops(m, b, s, 1, wbits, kv_draft));
            // Verify: γ+1 tokens through INT8 reconstruction, fp16 weights.
            let v = hw.time_secs(&step_ops(m, b, s, gamma + 1, 16.0, KV_INT8));
            (d, v)
        }
        Method::StreamingLlm | Method::SnapKv => {
            // Draft attends a budget of S/4 at fp16; fp16 weights.
            let d = hw.time_secs(&step_ops(m, b, s / 4, 1, 16.0, KV_FP16));
            // Verify attends the full fp16 cache.
            let v = hw.time_secs(&step_ops(m, b, s, gamma + 1, 16.0, KV_FP16));
            (d, v)
        }
    };
    CycleModel { draft_step_secs: draft, verify_secs: verify, ar_step_secs: ar }
}

/// Expected tokens committed per speculation cycle given a per-token
/// acceptance rate α and speculation length γ (Leviathan et al.):
/// E = (1 - α^{γ+1}) / (1 - α), capped at γ+1 (all accepted + bonus).
pub fn expected_tokens_per_cycle(alpha: f64, gamma: usize) -> f64 {
    let g = gamma as f64;
    if (1.0 - alpha).abs() < 1e-9 {
        return g + 1.0;
    }
    ((1.0 - alpha.powi(gamma as i32 + 1)) / (1.0 - alpha)).min(g + 1.0)
}

/// Projected speedup over autoregressive decoding for a measured
/// acceptance rate. The paper's Table 3 "Speedup (× AR)" column.
pub fn projected_speedup(
    m: &PaperModel,
    hw: &Hardware,
    method: Method,
    quant_mode: QuantMode,
    b: usize,
    s: usize,
    gamma: usize,
    accept_rate: f64,
) -> f64 {
    let cm = cycle_model(m, hw, method, quant_mode, b, s, gamma);
    if method == Method::Autoregressive {
        return 1.0;
    }
    let cycle = gamma as f64 * cm.draft_step_secs + cm.verify_secs;
    let toks = expected_tokens_per_cycle(accept_rate, gamma);
    (toks * cm.ar_step_secs) / cycle
}

/// Modeled attention-kernel latency (paper Table 4): time to read the KV
/// cache + scores for one token at context `s`.
pub fn kernel_latency_secs(m: &PaperModel, hw: &Hardware, s: usize, kv_bytes: f64) -> f64 {
    hw.time_secs(&decode_attention_kv(m, 1, s, 1, kv_bytes))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (PaperModel, Hardware) {
        (PaperModel::llama2_7b(), Hardware::a6000())
    }

    #[test]
    fn expected_tokens_monotone_in_alpha() {
        let lo = expected_tokens_per_cycle(0.5, 4);
        let hi = expected_tokens_per_cycle(0.95, 4);
        assert!(hi > lo);
        assert!(expected_tokens_per_cycle(1.0, 4) == 5.0);
    }

    #[test]
    fn table4_kernel_ratios() {
        // Paper Table 4: INT4 ≈ 2.88x, INT8 ≈ 1.5x vs FP16 at 64k-256k.
        let (m, hw) = setup();
        for s in [65_536usize, 262_144] {
            let fp = kernel_latency_secs(&m, &hw, s, KV_FP16);
            let i8 = kernel_latency_secs(&m, &hw, s, KV_INT8);
            let i4 = kernel_latency_secs(&m, &hw, s, KV_INT4);
            assert!((1.3..2.2).contains(&(fp / i8)), "int8 ratio {}", fp / i8);
            assert!((2.4..4.2).contains(&(fp / i4)), "int4 ratio {}", fp / i4);
        }
    }

    #[test]
    fn quantspec_speedup_grows_with_context() {
        let (m, hw) = setup();
        let short = projected_speedup(&m, &hw, Method::QuantSpec, QuantMode::Both, 1, 4096, 4, 0.92);
        let long = projected_speedup(&m, &hw, Method::QuantSpec, QuantMode::Both, 1, 131_072, 4, 0.92);
        assert!(long > short, "long {long} short {short}");
        // Table 3 ballpark at 128k: ~2.5x.
        assert!((1.6..3.2).contains(&long), "{long}");
    }

    #[test]
    fn weight_only_wins_short_kv_only_wins_long() {
        // Fig. 4 crossover.
        let (m, hw) = setup();
        let a = 0.9;
        let w_s = projected_speedup(&m, &hw, Method::QuantSpec, QuantMode::WeightOnly, 1, 1024, 4, a);
        let k_s = projected_speedup(&m, &hw, Method::QuantSpec, QuantMode::KvOnly, 1, 1024, 4, a);
        assert!(w_s > k_s, "short ctx: weight {w_s} vs kv {k_s}");
        let w_l = projected_speedup(&m, &hw, Method::QuantSpec, QuantMode::WeightOnly, 1, 131_072, 4, a);
        let k_l = projected_speedup(&m, &hw, Method::QuantSpec, QuantMode::KvOnly, 1, 131_072, 4, a);
        assert!(k_l > w_l, "long ctx: kv {k_l} vs weight {w_l}");
    }

    #[test]
    fn sparse_draft_faster_than_ar_but_verify_full() {
        let (m, hw) = setup();
        let cm = cycle_model(&m, &hw, Method::StreamingLlm, QuantMode::Both, 1, 65_536, 2);
        assert!(cm.draft_step_secs < cm.ar_step_secs);
        assert!(cm.verify_secs > cm.ar_step_secs * 0.9);
    }
}
