//! Roofline hardware model (Williams et al., paper §3.1.2).

use super::intensity::OpCount;

/// Hardware description: peak compute + memory bandwidth.
#[derive(Debug, Clone, Copy)]
pub struct Hardware {
    pub name: &'static str,
    /// Peak half-precision tensor throughput, FLOP/s.
    pub peak_flops: f64,
    /// DRAM bandwidth, bytes/s.
    pub mem_bw: f64,
    /// DRAM capacity, bytes (Fig. 6 VRAM lines).
    pub vram_bytes: f64,
}

impl Hardware {
    /// NVIDIA RTX A6000 — the paper's testbed (§5.1).
    pub fn a6000() -> Hardware {
        Hardware {
            name: "A6000",
            peak_flops: 154.8e12, // FP16 tensor core
            mem_bw: 768e9,        // GDDR6
            vram_bytes: 48e9,
        }
    }

    pub fn a100_80g() -> Hardware {
        Hardware { name: "A100-80G", peak_flops: 312e12, mem_bw: 2039e9, vram_bytes: 80e9 }
    }

    pub fn h100_sxm() -> Hardware {
        Hardware { name: "H100", peak_flops: 989e12, mem_bw: 3350e9, vram_bytes: 80e9 }
    }

    pub fn rtx_4090() -> Hardware {
        Hardware { name: "RTX4090", peak_flops: 330e12, mem_bw: 1008e9, vram_bytes: 24e9 }
    }

    /// Ridge point (FLOPs/byte): intensity below ⇒ memory-bound.
    pub fn ridge_point(&self) -> f64 {
        self.peak_flops / self.mem_bw
    }

    pub fn classify(&self, ops: &OpCount) -> Regime {
        if ops.intensity() < self.ridge_point() {
            Regime::MemoryBound
        } else {
            Regime::ComputeBound
        }
    }

    /// Roofline execution-time estimate: max of compute and memory time.
    pub fn time_secs(&self, ops: &OpCount) -> f64 {
        let t_compute = ops.flops / self.peak_flops;
        let t_memory = ops.mops_bytes / self.mem_bw;
        t_compute.max(t_memory)
    }

    /// Attainable FLOP/s at a given intensity (the roofline curve).
    pub fn attainable_flops(&self, intensity: f64) -> f64 {
        (intensity * self.mem_bw).min(self.peak_flops)
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Regime {
    MemoryBound,
    ComputeBound,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn a6000_ridge_point_plausible() {
        // 154.8 TFLOP/s ÷ 768 GB/s ≈ 201 FLOPs/byte.
        let r = Hardware::a6000().ridge_point();
        assert!((150.0..260.0).contains(&r), "{r}");
    }

    #[test]
    fn classify_and_time() {
        let hw = Hardware::a6000();
        let mem = OpCount { flops: 1e9, mops_bytes: 1e9 }; // intensity 1
        assert_eq!(hw.classify(&mem), Regime::MemoryBound);
        assert!((hw.time_secs(&mem) - 1e9 / 768e9).abs() < 1e-12);
        let comp = OpCount { flops: 1e12, mops_bytes: 1e6 };
        assert_eq!(hw.classify(&comp), Regime::ComputeBound);
    }

    #[test]
    fn roofline_curve_saturates() {
        let hw = Hardware::a6000();
        assert!(hw.attainable_flops(1.0) < hw.peak_flops);
        assert_eq!(hw.attainable_flops(1e6), hw.peak_flops);
    }
}
