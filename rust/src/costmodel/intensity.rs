//! Arithmetic-intensity formulas (paper Table 1, Figures 2 and 5).
//!
//! FLOPs and memory operations (bytes moved) for the linear and attention
//! components of a Transformer under prefill and decode, as functions of
//! batch B, sequence length S_L, and the model shape. FlashAttention
//! semantics: the S_L² score matrix is never materialized (its MOPs are
//! O(B·S_L) per the paper).

use super::PaperModel;

/// FLOPs + MOPs tally for one phase/component.
#[derive(Debug, Clone, Copy, Default)]
pub struct OpCount {
    pub flops: f64,
    pub mops_bytes: f64,
}

impl OpCount {
    pub fn intensity(&self) -> f64 {
        if self.mops_bytes == 0.0 {
            0.0
        } else {
            self.flops / self.mops_bytes
        }
    }

    pub fn add(self, other: OpCount) -> OpCount {
        OpCount {
            flops: self.flops + other.flops,
            mops_bytes: self.mops_bytes + other.mops_bytes,
        }
    }
}

/// Bytes per element for weights/activations (paper analyzes 16-bit).
pub const BYTES_FP16: f64 = 2.0;

/// Linear (weight × activation) ops for prefill over S tokens, batch B.
pub fn prefill_linear(m: &PaperModel, b: usize, s: usize) -> OpCount {
    let (b, s) = (b as f64, s as f64);
    let params = m.params() as f64;
    OpCount {
        // 2 FLOPs per weight per token (MAC).
        flops: 2.0 * b * s * params,
        // weights loaded once + activations in/out per layer.
        mops_bytes: BYTES_FP16
            * (params + b * s * (m.d_model as f64) * 2.0 * (m.n_layers as f64)),
    }
}

/// Attention (activation × activation) ops for prefill (FlashAttention).
pub fn prefill_attention(m: &PaperModel, b: usize, s: usize) -> OpCount {
    let (bf, sf) = (b as f64, s as f64);
    let l = m.n_layers as f64;
    let hd = (m.n_heads * m.head_dim) as f64;
    OpCount {
        // q·kᵀ and p·v: 2 × 2 FLOPs × B S² h·dh per layer (causal ≈ ½,
        // kept whole as in the paper's asymptotics).
        flops: 2.0 * 2.0 * bf * sf * sf * hd * l,
        // flash-attn running stats O(B·S) + q/k/v/o activations O(B·S·d).
        mops_bytes: BYTES_FP16 * l * (bf * sf + 4.0 * bf * sf * hd),
    }
}

/// Linear ops for decoding k tokens.
pub fn decode_linear(m: &PaperModel, b: usize, k: usize) -> OpCount {
    let (bf, kf) = (b as f64, k as f64);
    let params = m.params() as f64;
    OpCount {
        flops: 2.0 * kf * bf * params,
        // weights reloaded every step + per-token activations.
        mops_bytes: BYTES_FP16
            * (kf * params + kf * bf * (m.d_model as f64) * 2.0 * (m.n_layers as f64)),
    }
}

/// Attention ops for decoding k tokens at context S with `kv_bytes` bytes
/// per cache element (2.0 = FP16, 1.0 = INT8, 0.5 = INT4).
pub fn decode_attention_kv(
    m: &PaperModel,
    b: usize,
    s: usize,
    k: usize,
    kv_bytes: f64,
) -> OpCount {
    let (bf, sf, kf) = (b as f64, s as f64, k as f64);
    let l = m.n_layers as f64;
    let hd = (m.n_heads * m.head_dim) as f64;
    OpCount {
        flops: 2.0 * 2.0 * kf * bf * sf * hd * l,
        // the KV cache is re-read every decode step: k · B · S · 2(kv) · h·dh
        mops_bytes: l * (kf * bf * sf + kv_bytes * 2.0 * kf * bf * sf * hd),
    }
}

/// FP16-cache decode attention (the paper's Table 1 baseline).
pub fn decode_attention(m: &PaperModel, b: usize, s: usize, k: usize) -> OpCount {
    decode_attention_kv(m, b, s, k, BYTES_FP16)
}

/// Aggregate = linear + attention (paper's "aggregate" column).
pub fn prefill_aggregate(m: &PaperModel, b: usize, s: usize) -> OpCount {
    prefill_linear(m, b, s).add(prefill_attention(m, b, s))
}

pub fn decode_aggregate(m: &PaperModel, b: usize, s: usize, k: usize) -> OpCount {
    decode_linear(m, b, k).add(decode_attention(m, b, s, k))
}

/// Attention's share of modeled decode latency on `hw` (colors Fig. 2).
pub fn decode_attention_fraction(
    m: &PaperModel,
    hw: &super::Hardware,
    b: usize,
    s: usize,
) -> f64 {
    let lin = hw.time_secs(&decode_linear(m, b, 1));
    let attn = hw.time_secs(&decode_attention(m, b, s, 1));
    attn / (lin + attn)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::Hardware;

    fn model() -> PaperModel {
        PaperModel::llama2_7b()
    }

    #[test]
    fn prefill_intensity_scales_with_s() {
        // Table 1: prefill aggregate intensity ~ O(S_L) for long context.
        let m = model();
        let a = prefill_aggregate(&m, 1, 4096).intensity();
        let b = prefill_aggregate(&m, 1, 65536).intensity();
        assert!(b > 4.0 * a, "prefill intensity should grow with S: {a} {b}");
    }

    #[test]
    fn decode_intensity_flat_in_s_long_context() {
        // Table 1: decode aggregate intensity ~ O(1) for S_L >> d.
        let m = model();
        let a = decode_aggregate(&m, 1, 1 << 17, 1).intensity();
        let b = decode_aggregate(&m, 1, 1 << 19, 1).intensity();
        assert!((a / b - 1.0).abs() < 0.3, "long-context decode ~flat: {a} {b}");
    }

    #[test]
    fn decode_intensity_scales_with_b_short_context() {
        // Table 1: decode aggregate intensity ~ O(B) for S_L << d.
        let m = model();
        let a = decode_aggregate(&m, 1, 128, 1).intensity();
        let b = decode_aggregate(&m, 16, 128, 1).intensity();
        assert!(b > 8.0 * a, "short-context decode ~O(B): {a} {b}");
    }

    #[test]
    fn prefill_compute_bound_decode_memory_bound() {
        // Fig 2 vs Fig 5: on the A6000 all decode regimes sit below the
        // ridge point, prefill (long ctx) above it.
        let m = model();
        let hw = Hardware::a6000();
        assert!(prefill_aggregate(&m, 1, 16384).intensity() > hw.ridge_point());
        for &(b, s) in &[(1usize, 1024usize), (1, 1 << 17), (64, 1024), (16, 1 << 15)] {
            let i = decode_aggregate(&m, b, s, 1).intensity();
            assert!(i < hw.ridge_point(), "decode B={b} S={s} intensity {i}");
        }
    }

    #[test]
    fn quantized_kv_cuts_attention_bytes() {
        let m = model();
        let fp = decode_attention_kv(&m, 1, 1 << 16, 1, 2.0).mops_bytes;
        let i4 = decode_attention_kv(&m, 1, 1 << 16, 1, 0.5).mops_bytes;
        let ratio = fp / i4;
        assert!((3.5..4.2).contains(&ratio), "INT4 ~4x fewer bytes: {ratio}");
    }

    #[test]
    fn attention_dominates_long_context_decode() {
        let m = model();
        let hw = Hardware::a6000();
        let frac_long = decode_attention_fraction(&m, &hw, 1, 1 << 17);
        let frac_short = decode_attention_fraction(&m, &hw, 1, 256);
        assert!(frac_long > 0.8, "{frac_long}");
        assert!(frac_short < 0.2, "{frac_short}");
    }
}
