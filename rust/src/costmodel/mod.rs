//! Analytical GPU cost model (paper §3): arithmetic intensity, roofline
//! classification, latency and memory projection.
//!
//! The paper's speedups are a memory-bandwidth story — decoding is
//! memory-bound in every regime (Fig. 2), so step latency ≈ bytes-moved /
//! bandwidth. We reproduce the paper's own analysis tooling here:
//!
//! * `intensity` — the Table 1 FLOPs/MOPs formulas (prefill & decode,
//!   linear / attention / aggregate) and the Fig. 2 / Fig. 5 surfaces.
//! * `roofline` — hardware descriptions (A6000 as in the paper) and the
//!   ridge-point classification.
//! * `memory`   — KV-cache memory accounting (Fig. 6, Table 3 peak-memory)
//!   for FP16 / hierarchical-INT4 / sparse-draft layouts.
//! * `latency`  — per-step byte/FLOP tallies for each method, combined with
//!   *measured* acceptance rates to project end-to-end speedups on the
//!   paper's A6000 testbed from runs on this CPU testbed (DESIGN.md §4).

pub mod intensity;
pub mod latency;
pub mod memory;
pub mod roofline;

pub use roofline::{Hardware, Regime};

/// Llama-2-7B-like shape used for the paper-scale analysis figures.
#[derive(Debug, Clone, Copy)]
pub struct PaperModel {
    pub n_layers: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub d_ff: usize,
    pub vocab: usize,
}

impl PaperModel {
    pub fn llama2_7b() -> Self {
        PaperModel {
            n_layers: 32,
            d_model: 4096,
            n_heads: 32,
            head_dim: 128,
            d_ff: 11008,
            vocab: 32000,
        }
    }

    /// Total parameter count (weights only).
    pub fn params(&self) -> usize {
        let attn = 4 * self.d_model * self.d_model;
        let mlp = 3 * self.d_model * self.d_ff;
        self.n_layers * (attn + mlp) + 2 * self.vocab * self.d_model
    }

    /// KV cache elements per token (both K and V, all layers).
    pub fn kv_elems_per_token(&self) -> usize {
        2 * self.n_layers * self.n_heads * self.head_dim
    }
}
