//! KV-cache and weight memory accounting (paper Fig. 6, Table 3 peak mem).
//!
//! Uses *logical* bit widths (INT4 = 0.5 byte) as on real hardware; the CPU
//! testbed's host-resident byte counts (bit-packed nibbles at two codes per
//! byte, f32-held "fp16") are reported separately by `cache::MemoryReport`.
//! The packed-group helpers below are the single source of the host-byte
//! formula shared by `pool::PoolConfig` and the kernel benches.

use super::PaperModel;
use crate::config::Method;

pub const GIB: f64 = 1024.0 * 1024.0 * 1024.0;

/// FP16 KV-cache bytes at batch B, context S (Fig. 6 surface).
pub fn kv_bytes_fp16(m: &PaperModel, b: usize, s: usize) -> f64 {
    (b * s * m.kv_elems_per_token()) as f64 * 2.0
}

/// FP16 weight bytes.
pub fn weight_bytes_fp16(m: &PaperModel) -> f64 {
    m.params() as f64 * 2.0
}

/// Per-method total memory (weights + caches) for a decode session.
///
/// Mirrors the paper's Table 3 "Peak GPU Memory" structure:
/// * AR: FP16 weights + FP16 KV.
/// * QuantSpec: INT4 weights + hierarchical INT4+INT4 KV (= INT8 total,
///   shared between draft and target — the paper's bit-sharing saving) +
///   scales/zeros + the 2G FP16 residual buffer.
/// * Sparse baselines: FP16 weights + full FP16 KV (target) + a separate
///   FP16 draft cache of S/4 (the draft budget).
pub fn method_bytes(
    m: &PaperModel,
    method: Method,
    b: usize,
    s: usize,
    g: usize,
) -> f64 {
    let kv_fp = kv_bytes_fp16(m, b, s);
    let w_fp = weight_bytes_fp16(m);
    let elems = (b * s * m.kv_elems_per_token()) as f64;
    match method {
        Method::Autoregressive => w_fp + kv_fp,
        Method::QuantSpec => {
            // fp16 target weights stay resident; the INT4 draft set is extra.
            let w_q4 = w_fp + m.params() as f64 * 0.5;
            // upper + lower nibble = 1 byte per element.
            let kv_q = elems * 1.0;
            // scale + zero per group of g elements, fp16 each.
            let meta = elems / g as f64 * 2.0 * 2.0;
            // double FP buffer: 2G tokens at fp16.
            let buf = (b * 2 * g * m.kv_elems_per_token()) as f64 * 2.0;
            w_q4 + kv_q + meta + buf
        }
        Method::StreamingLlm | Method::SnapKv => {
            let draft = kv_bytes_fp16(m, b, s / 4);
            w_fp + kv_fp + draft
        }
    }
}

/// The Fig. 6 color channel: KV bytes as a multiple of weight bytes.
pub fn kv_to_weight_ratio(m: &PaperModel, b: usize, s: usize) -> f64 {
    kv_bytes_fp16(m, b, s) / weight_bytes_fp16(m)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig6_anchor_point() {
        // Paper Fig. 6: at (B=16, S=262k) the Llama-2-7B KV cache is ~160x
        // the weight memory.
        let m = PaperModel::llama2_7b();
        let r = kv_to_weight_ratio(&m, 16, 262_144);
        assert!((120.0..200.0).contains(&r), "ratio {r}");
    }

    #[test]
    fn quantspec_smaller_than_sparse() {
        // Table 3: QuantSpec uses ~1.3x less memory than the sparse
        // baselines at long context.
        let m = PaperModel::llama2_7b();
        let qs = method_bytes(&m, Method::QuantSpec, 1, 131_072, 128);
        let sp = method_bytes(&m, Method::StreamingLlm, 1, 131_072, 128);
        let ratio = sp / qs;
        assert!(ratio > 1.25, "sparse/quantspec memory ratio {ratio}");
    }

    #[test]
    fn kv_dominates_at_long_context() {
        let m = PaperModel::llama2_7b();
        assert!(kv_bytes_fp16(&m, 1, 131_072) > weight_bytes_fp16(&m));
    }

    #[test]
    fn a6000_oom_at_128k_for_sparse_two_gpus() {
        // Table 3's 128k Multi-LexSum rows: baselines OOM on 2 A6000s
        // (96 GB total), QuantSpec fits.
        let m = PaperModel::llama2_7b();
        let vram2 = 2.0 * 48.0 * GIB;
        let sparse = method_bytes(&m, Method::SnapKv, 1, 131_072, 128);
        let qs = method_bytes(&m, Method::QuantSpec, 1, 131_072, 128);
        // LWM-Text-Chat-128k is Llama-7B-shaped; add activation slack ~25%.
        assert!(sparse * 1.25 > vram2 * 0.55, "sparse near/over budget");
        assert!(qs < sparse, "quantspec under sparse");
    }
}

/// Minimum number of GPUs (each with `vram_bytes`) needed to hold a
/// method's state plus an activation slack — the paper Table 3 "# GPUs"
/// column (1 at ≤32k, 2 at 64k/128k, OOM for the sparse baselines at 128k
/// on 2 GPUs).
pub fn gpus_needed(
    m: &PaperModel,
    method: Method,
    b: usize,
    s: usize,
    g: usize,
    vram_bytes: f64,
    max_gpus: usize,
) -> Option<usize> {
    let bytes = method_bytes(m, method, b, s, g) * 1.25; // activation slack
    for n in 1..=max_gpus {
        if bytes <= n as f64 * vram_bytes {
            return Some(n);
        }
    }
    None // OOM — the paper's "-" rows
}

/// Page reservation for one request against the paged KV pool: the cost
/// model's upper bound on pages the session can ever hold. The prompt is
/// padded up to a G-bucket (minimum 2G, mirroring the prefill invariant);
/// the quantized region can grow to cover every generated token *plus the
/// speculative overshoot* (the engine's last cycle may commit up to
/// tmax − 2 cache entries past `max_new`, where tmax = FB − 2G); and the
/// double FP buffer occupies `ceil(FB/G)` pages for the session's
/// lifetime. Admission control books exactly this many pages, so an
/// admitted decode can never outgrow its reservation.
pub fn pool_pages_for_request(
    prompt_len: usize,
    max_new: usize,
    g: usize,
    fb: usize,
) -> usize {
    let g = g.max(1);
    let padded = padded_bucket(prompt_len, g);
    let overshoot = fb.saturating_sub(2 * g).saturating_sub(2);
    let quant_pages = (padded + max_new + overshoot).div_ceil(g);
    let fp_pages = fb.div_ceil(g);
    quant_pages + fp_pages
}

/// Host bytes of one packed quantized group of `elems` codes: two
/// bit-packed nibble planes (two 4-bit codes per byte) plus f32
/// scale/zero. The pre-packing representation held a full byte per nibble
/// ([`unpacked_group_host_bytes`]); packing halves the code bytes, closing
/// the gap between `MemoryReport::cache_host` and `cache_logical` to the
/// scale/zero overhead (f32 here vs fp16 logically).
pub fn packed_group_host_bytes(elems: usize) -> usize {
    2 * elems.div_ceil(2) + 8
}

/// Host bytes the unpacked byte-per-nibble representation used. Kept as
/// the comparison baseline for the packing win asserted in tests and
/// measured by `benches/kernel_hotpath.rs`.
pub fn unpacked_group_host_bytes(elems: usize) -> usize {
    2 * elems + 8
}

#[cfg(test)]
mod packing_tests {
    use super::*;

    #[test]
    fn packed_host_bytes_at_most_55pct_of_unpacked() {
        // The default pool geometry (G=64, d=8 -> 512 codes) and the
        // paper-ish G=128, d=128 both halve within the 0.55x budget.
        for elems in [512usize, 128 * 128, 64 * 64] {
            let packed = packed_group_host_bytes(elems);
            let unpacked = unpacked_group_host_bytes(elems);
            assert!(
                (packed as f64) <= 0.55 * unpacked as f64,
                "elems {elems}: {packed} vs {unpacked}"
            );
        }
        // odd lengths round the planes up to whole bytes
        assert_eq!(packed_group_host_bytes(7), 2 * 4 + 8);
    }
}

/// Serialized payload bytes of one spilled *quantized* page of `elems`
/// codes: a 12-byte frame (`len`, `scale8` bits, `zero` bits, u32 LE each)
/// plus the two bit-packed nibble planes. Must match
/// `quant::PackedGroup::serialized_bytes`; `pool::tier` sizes its slots
/// from this, so the cost model stays the single source of byte formulas.
pub fn spilled_quant_page_bytes(elems: usize) -> usize {
    12 + 2 * elems.div_ceil(2)
}

/// Serialized payload bytes of one spilled *FP* page of `elems` f32
/// values: a u32 length frame plus raw IEEE-754 bits.
pub fn spilled_fp_page_bytes(elems: usize) -> usize {
    4 + 4 * elems
}

/// One cold-tier slot, page-aligned: the 32-byte slot header (magic,
/// generation, kind, payload length, checksum) plus the larger of the two
/// page payloads, rounded up to `SPILL_SLOT_ALIGN`. Every page of a given
/// pool geometry fits in one slot, so the spill file is a flat array of
/// fixed-size slots addressable by index.
pub const SPILL_SLOT_ALIGN: usize = 4096;

pub fn spill_slot_bytes(elems: usize) -> usize {
    let payload = spilled_quant_page_bytes(elems).max(spilled_fp_page_bytes(elems));
    (32 + payload).div_ceil(SPILL_SLOT_ALIGN) * SPILL_SLOT_ALIGN
}

#[cfg(test)]
mod spill_tests {
    use super::*;

    #[test]
    fn spill_slots_are_page_aligned_and_cover_both_kinds() {
        for elems in [7usize, 512, 64 * 64, 128 * 128] {
            let slot = spill_slot_bytes(elems);
            assert_eq!(slot % SPILL_SLOT_ALIGN, 0, "elems {elems}");
            assert!(slot >= 32 + spilled_quant_page_bytes(elems));
            assert!(slot >= 32 + spilled_fp_page_bytes(elems));
        }
        // FP pages dominate (4 bytes/elem vs ~1): the slot tracks them
        assert_eq!(spill_slot_bytes(512), (32 + 4 + 2048 + 4095) / 4096 * 4096);
    }
}

/// Prompt length padded up to a G-bucket, minimum 2G (the prefill
/// invariant needs one full quant group plus a full C_F1). The single
/// source of the bucketing rule: the paged decoder's prefill and the
/// admission reservation above both use it, so admission always covers
/// the bucket the decoder will actually allocate.
pub fn padded_bucket(prompt_len: usize, g: usize) -> usize {
    let g = g.max(1);
    prompt_len.max(1).div_ceil(g).max(2) * g
}

#[cfg(test)]
mod pool_tests {
    use super::*;

    #[test]
    fn reservation_covers_generation() {
        // G=64, FB=136 (tmax=8, overshoot 6): a 512-token prompt
        // generating 90 tokens can reach 602+6 cache entries; n_q never
        // exceeds total - G, so ceil((512+90+6)/64) quant pages suffice;
        // plus ceil(136/64) = 3 FP pages.
        let pages = pool_pages_for_request(512, 90, 64, 136);
        assert_eq!(pages, (512 + 90 + 6 + 63) / 64 + 3);
        // tiny prompts still pad to the 2G prefill bucket
        let tiny = pool_pages_for_request(5, 10, 64, 136);
        assert_eq!(tiny, (128 + 10 + 6 + 63) / 64 + 3);
    }

    #[test]
    fn reservation_monotonic() {
        let base = pool_pages_for_request(256, 32, 64, 136);
        assert!(pool_pages_for_request(512, 32, 64, 136) >= base);
        assert!(pool_pages_for_request(256, 128, 64, 136) >= base);
    }
}

#[cfg(test)]
mod gpu_tests {
    use super::*;

    #[test]
    fn table3_gpu_counts() {
        // Paper Table 3 structure on A6000s (48 GB): 1 GPU at 32k,
        // 2 GPUs at 64k, and at 128k the sparse baselines OOM on 2 GPUs
        // while QuantSpec fits.
        let m = PaperModel::llama2_7b();
        let vram = 48e9;
        let gpus = |method, s| gpus_needed(&m, method, 1, s, 128, vram, 2);
        assert_eq!(gpus(Method::QuantSpec, 32_768), Some(1));
        assert_eq!(gpus(Method::SnapKv, 65_536), Some(2));
        assert_eq!(gpus(Method::SnapKv, 131_072), None, "sparse OOMs at 128k");
        assert_eq!(gpus(Method::StreamingLlm, 131_072), None);
        assert_eq!(gpus(Method::QuantSpec, 131_072), Some(2), "QuantSpec fits");
    }
}
