//! Host-side mirror of the paper's §4.2 hierarchical quantization.
//!
//! The authoritative implementation is the L1 Pallas kernel
//! (`python/compile/kernels/hier_quant.py`); this mirror exists so Rust
//! tests can cross-check artifact outputs and so the mock backend can
//! emulate quantization error without XLA. Semantics are identical:
//! asymmetric INT8 per group, decomposed as C8 = 16*C_U + C_L.
//!
//! # Packed representation
//!
//! Codes are stored **bit-packed, two 4-bit codes per byte**, in two planes
//! (the paper's bit-shared layout): the upper plane holds the INT4 draft
//! codes C_U ∈ [0, 15], the lower plane the refinement codes C_L ∈ [-8, 7]
//! (stored biased by +8 so both planes are plain nibbles). Element `i`
//! lives in byte `i / 2`; even elements occupy the low nibble, odd elements
//! the high nibble. A group of `n` values therefore costs
//! `2 * ceil(n/2)` host bytes of codes — half of the previous
//! byte-per-nibble representation — plus one f32 scale and zero.
//!
//! # Readers
//!
//! The decode hot path never allocates: [`PackedGroup::dequant_token_into`]
//! reconstructs one token's `d` values straight into a caller scratch
//! buffer, [`PackedGroup::dequant_span_into`] handles any contiguous
//! element span (the paged cache's batched verify-window reads), and the
//! whole-group [`PackedGroup::dequant_draft_into`] /
//! [`PackedGroup::dequant_target_into`] variants exist for bulk readers and
//! benches. All of them unpack **lane-wise**: whole packed bytes are
//! processed two codes at a time, with a 16-byte inner chunk written so
//! LLVM can autovectorize — bit-identical to the scalar per-nibble
//! accessors (`draft_value` / `target_value`), which remain the property-
//! tested reference. The allocating `dequant_draft` / `dequant_target`
//! wrappers remain for tests and one-shot callers.

use anyhow::{ensure, Result};

use crate::util::threadpool::{PoolHandle, WaitGroup};

/// One quantized group: two nibble-packed code planes plus scale/zero.
///
/// Immutable once built; construct with [`quant_group`].
#[derive(Debug, Clone, PartialEq)]
pub struct PackedGroup {
    /// Upper (INT4 draft) codes, two per byte, low nibble = even element.
    upper: Vec<u8>,
    /// Lower (refinement) codes biased by +8, same packing as `upper`.
    lower: Vec<u8>,
    /// Number of quantized values (nibbles) per plane.
    len: usize,
    pub scale8: f32,
    pub zero: f32,
}

pub const EPS: f32 = 1e-6;

/// Bias applied to lower-plane codes so C_L ∈ [-8, 7] stores as a nibble.
const LOWER_BIAS: i8 = 8;

#[inline]
fn nibble(plane: &[u8], i: usize) -> u8 {
    (plane[i >> 1] >> ((i & 1) * 4)) & 0x0F
}

#[inline]
fn set_nibble(plane: &mut [u8], i: usize, v: u8) {
    debug_assert!(v <= 0x0F);
    plane[i >> 1] |= v << ((i & 1) * 4);
}

impl PackedGroup {
    /// Number of quantized values in the group.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Host bytes of the packed planes (excludes scale/zero).
    pub fn code_bytes(&self) -> usize {
        self.upper.len() + self.lower.len()
    }

    /// Upper (draft) code of element `i`, in [0, 15].
    #[inline]
    pub fn upper_code(&self, i: usize) -> u8 {
        nibble(&self.upper, i)
    }

    /// Lower (refinement) code of element `i`, in [-8, 7].
    #[inline]
    pub fn lower_code(&self, i: usize) -> i8 {
        nibble(&self.lower, i) as i8 - LOWER_BIAS
    }

    /// Dequantize one element through the draft (INT4) plane.
    #[inline]
    pub fn draft_value(&self, i: usize) -> f32 {
        self.upper_code(i) as f32 * (16.0 * self.scale8) + self.zero
    }

    /// Dequantize one element through the target (INT8) planes.
    #[inline]
    pub fn target_value(&self, i: usize) -> f32 {
        (16.0 * self.upper_code(i) as f32 + self.lower_code(i) as f32) * self.scale8
            + self.zero
    }

    /// Fused, zero-allocation read of one token's values: element range
    /// `[pos * out.len(), (pos + 1) * out.len())` is dequantized through the
    /// draft or target plane straight into `out`. The group length must be
    /// a multiple of `out.len()` tokens. Panics on out-of-range `pos`
    /// (caller-side invariant; the paged cache bounds-checks positions).
    #[inline]
    pub fn dequant_token_into(&self, pos: usize, draft: bool, out: &mut [f32]) {
        let d = out.len();
        let start = pos * d;
        assert!(
            start + d <= self.len,
            "token {pos} x dim {d} out of group ({} codes)",
            self.len
        );
        self.dequant_span_into(start, draft, out);
    }

    /// Lane-wise dequantization of the contiguous element span
    /// `[start, start + out.len())` through the chosen plane into `out` —
    /// the batched verify-window read primitive. Zero allocation;
    /// bit-identical to calling `draft_value` / `target_value` per element.
    /// Panics when the span exceeds the group (caller-side invariant).
    #[inline]
    pub fn dequant_span_into(&self, start: usize, draft: bool, out: &mut [f32]) {
        assert!(
            start + out.len() <= self.len,
            "span {start}+{} out of group ({} codes)",
            out.len(),
            self.len
        );
        if draft {
            self.unpack_draft_span(start, out);
        } else {
            self.unpack_target_span(start, out);
        }
    }

    /// Whole-group draft dequantization into a caller buffer (no alloc).
    pub fn dequant_draft_into(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.len, "scratch buffer length");
        self.unpack_draft_span(0, out);
    }

    /// Whole-group target dequantization into a caller buffer (no alloc).
    pub fn dequant_target_into(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.len, "scratch buffer length");
        self.unpack_target_span(0, out);
    }

    /// Lane-wise draft (upper-plane) unpack: consume whole packed bytes —
    /// two codes per step — in [`LANE_BYTES`]-byte inner chunks the
    /// compiler can autovectorize. Per-element arithmetic is exactly the
    /// scalar `draft_value` expression, so output bits are identical.
    fn unpack_draft_span(&self, start: usize, out: &mut [f32]) {
        let n = out.len();
        if n == 0 {
            return;
        }
        let s4 = 16.0 * self.scale8;
        let zero = self.zero;
        let mut i = start;
        let mut o = 0usize;
        // unaligned head: an odd start element lives in a high nibble
        if i & 1 == 1 {
            out[0] = (self.upper[i >> 1] >> 4) as f32 * s4 + zero;
            i += 1;
            o += 1;
        }
        let pairs = (n - o) / 2;
        let bytes = &self.upper[i >> 1..(i >> 1) + pairs];
        let vals = &mut out[o..o + 2 * pairs];
        let mut bi = bytes.chunks_exact(LANE_BYTES);
        let mut vi = vals.chunks_exact_mut(2 * LANE_BYTES);
        for (bc, vc) in (&mut bi).zip(&mut vi) {
            for k in 0..LANE_BYTES {
                vc[2 * k] = (bc[k] & 0x0F) as f32 * s4 + zero;
                vc[2 * k + 1] = (bc[k] >> 4) as f32 * s4 + zero;
            }
        }
        for (&b, v) in bi.remainder().iter().zip(vi.into_remainder().chunks_exact_mut(2)) {
            v[0] = (b & 0x0F) as f32 * s4 + zero;
            v[1] = (b >> 4) as f32 * s4 + zero;
        }
        o += 2 * pairs;
        i += 2 * pairs;
        // tail: a final even element occupies a low nibble
        if o < n {
            out[o] = (self.upper[i >> 1] & 0x0F) as f32 * s4 + zero;
        }
    }

    /// Exact number of bytes [`PackedGroup::write_bytes`] appends.
    pub fn serialized_bytes(&self) -> usize {
        12 + self.upper.len() + self.lower.len()
    }

    /// Serialize the group for the spill tier: `[len u32 LE]
    /// [scale8 f32-bits u32 LE] [zero f32-bits u32 LE] [upper plane]
    /// [lower plane]`. Floats travel as raw IEEE bits (`to_bits`), so a
    /// round trip through [`PackedGroup::from_bytes`] is bit-identical —
    /// the invariant every spill/restore path in the pool relies on.
    pub fn write_bytes(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&(self.len as u32).to_le_bytes());
        out.extend_from_slice(&self.scale8.to_bits().to_le_bytes());
        out.extend_from_slice(&self.zero.to_bits().to_le_bytes());
        out.extend_from_slice(&self.upper);
        out.extend_from_slice(&self.lower);
    }

    /// Allocating convenience wrapper over [`PackedGroup::write_bytes`].
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.serialized_bytes());
        self.write_bytes(&mut out);
        out
    }

    /// Reconstruct a group serialized by [`PackedGroup::write_bytes`].
    /// Validates the framing exactly: a truncated or oversized buffer is
    /// an error, never a silently short group.
    pub fn from_bytes(buf: &[u8]) -> Result<PackedGroup> {
        ensure!(buf.len() >= 12, "packed group header truncated ({} bytes)", buf.len());
        let len = u32::from_le_bytes(buf[0..4].try_into().unwrap()) as usize;
        let scale8 = f32::from_bits(u32::from_le_bytes(buf[4..8].try_into().unwrap()));
        let zero = f32::from_bits(u32::from_le_bytes(buf[8..12].try_into().unwrap()));
        ensure!(len > 0, "packed group with zero codes");
        let plane = len.div_ceil(2);
        ensure!(
            buf.len() == 12 + 2 * plane,
            "packed group payload is {} bytes, expected {}",
            buf.len(),
            12 + 2 * plane
        );
        Ok(PackedGroup {
            upper: buf[12..12 + plane].to_vec(),
            lower: buf[12 + plane..].to_vec(),
            len,
            scale8,
            zero,
        })
    }

    /// Lane-wise target (both-planes) unpack; same structure as
    /// [`PackedGroup::unpack_draft_span`], arithmetic exactly the scalar
    /// `target_value` expression.
    fn unpack_target_span(&self, start: usize, out: &mut [f32]) {
        let n = out.len();
        if n == 0 {
            return;
        }
        let s8 = self.scale8;
        let zero = self.zero;
        let mut i = start;
        let mut o = 0usize;
        if i & 1 == 1 {
            let u = (self.upper[i >> 1] >> 4) as f32;
            let l = ((self.lower[i >> 1] >> 4) as i8 - LOWER_BIAS) as f32;
            out[0] = (16.0 * u + l) * s8 + zero;
            i += 1;
            o += 1;
        }
        let pairs = (n - o) / 2;
        let ub = &self.upper[i >> 1..(i >> 1) + pairs];
        let lb = &self.lower[i >> 1..(i >> 1) + pairs];
        let vals = &mut out[o..o + 2 * pairs];
        let mut ui = ub.chunks_exact(LANE_BYTES);
        let mut li = lb.chunks_exact(LANE_BYTES);
        let mut vi = vals.chunks_exact_mut(2 * LANE_BYTES);
        for ((uc, lc), vc) in (&mut ui).zip(&mut li).zip(&mut vi) {
            for k in 0..LANE_BYTES {
                let u0 = (uc[k] & 0x0F) as f32;
                let l0 = ((lc[k] & 0x0F) as i8 - LOWER_BIAS) as f32;
                vc[2 * k] = (16.0 * u0 + l0) * s8 + zero;
                let u1 = (uc[k] >> 4) as f32;
                let l1 = ((lc[k] >> 4) as i8 - LOWER_BIAS) as f32;
                vc[2 * k + 1] = (16.0 * u1 + l1) * s8 + zero;
            }
        }
        let tail_v = vi.into_remainder();
        for ((&u, &l), v) in ui
            .remainder()
            .iter()
            .zip(li.remainder())
            .zip(tail_v.chunks_exact_mut(2))
        {
            let u0 = (u & 0x0F) as f32;
            let l0 = ((l & 0x0F) as i8 - LOWER_BIAS) as f32;
            v[0] = (16.0 * u0 + l0) * s8 + zero;
            let u1 = (u >> 4) as f32;
            let l1 = ((l >> 4) as i8 - LOWER_BIAS) as f32;
            v[1] = (16.0 * u1 + l1) * s8 + zero;
        }
        o += 2 * pairs;
        i += 2 * pairs;
        if o < n {
            let u = (self.upper[i >> 1] & 0x0F) as f32;
            let l = ((self.lower[i >> 1] & 0x0F) as i8 - LOWER_BIAS) as f32;
            out[o] = (16.0 * u + l) * s8 + zero;
        }
    }
}

/// Inner-chunk width of the lane-wise unpackers: 16 packed bytes = 32
/// codes per iteration, sized for 128/256-bit SIMD autovectorization.
const LANE_BYTES: usize = 16;

/// Hierarchically quantize one group of values.
///
/// The min/max scan is a single fused pass that rejects non-finite inputs:
/// a NaN or ±∞ anywhere in the group would silently poison the scale (NaN
/// propagates through `(mx - mn) / 255`) and corrupt every code, so it is
/// an error here rather than a garbage cache entry downstream.
pub fn quant_group(xs: &[f32]) -> Result<PackedGroup> {
    ensure!(!xs.is_empty(), "cannot quantize an empty group");
    let mut mn = f32::INFINITY;
    let mut mx = f32::NEG_INFINITY;
    for (i, &x) in xs.iter().enumerate() {
        ensure!(
            x.is_finite(),
            "non-finite value {x} at index {i}: refusing to quantize"
        );
        mn = mn.min(x);
        mx = mx.max(x);
    }
    let scale8 = ((mx - mn) / 255.0).max(EPS);
    let zero = mn;
    let s4 = 16.0 * scale8;
    let bytes = xs.len().div_ceil(2);
    let mut upper = vec![0u8; bytes];
    let mut lower = vec![0u8; bytes];
    for (i, &x) in xs.iter().enumerate() {
        let u = ((x - zero) / s4).round().clamp(0.0, 15.0);
        let err = x - (u * s4 + zero);
        let l = (err / scale8).round().clamp(-8.0, 7.0);
        set_nibble(&mut upper, i, u as u8);
        set_nibble(&mut lower, i, (l as i8 + LOWER_BIAS) as u8);
    }
    Ok(PackedGroup { upper, lower, len: xs.len(), scale8, zero })
}

/// Quantize many groups, fanned out over the process-wide shared
/// quantization pool (bulk prefill quantization; a decode-time flush has
/// one group and stays serial). The pool is created ONCE at coordinator
/// startup — sized by `pool.quant_workers` — and every session submits
/// through a cloned [`PoolHandle`], so concurrent prefills share one
/// worker set instead of spawning threads per call. Takes the groups by
/// value: the parallel path moves them into an `Arc` to satisfy the
/// pool's `'static` job bound, so no input data is copied. A single-worker
/// pool or a single group runs serially inline. Output order and bits are
/// identical to the serial path; completion is caller-scoped (a
/// [`WaitGroup`]), so one session's prefill never waits on another's jobs.
pub fn quant_groups_parallel(
    inputs: Vec<Vec<f32>>,
    pool: &PoolHandle,
) -> Result<Vec<PackedGroup>> {
    if pool.size() <= 1 || inputs.len() <= 1 {
        return inputs.iter().map(|xs| quant_group(xs)).collect();
    }
    use std::sync::{Arc, Mutex};
    let n = inputs.len();
    let shared: Arc<Vec<Vec<f32>>> = Arc::new(inputs);
    let slots: Arc<Mutex<Vec<Option<Result<PackedGroup>>>>> =
        Arc::new(Mutex::new(std::iter::repeat_with(|| None).take(n).collect()));
    let wg = WaitGroup::new();
    for i in 0..n {
        let shared = Arc::clone(&shared);
        let slots = Arc::clone(&slots);
        pool.scoped_submit(&wg, move || {
            let r = quant_group(&shared[i]);
            slots.lock().unwrap()[i] = Some(r);
        });
    }
    wg.wait();
    let mut guard = slots.lock().unwrap();
    let mut out = Vec::with_capacity(n);
    for (i, slot) in guard.iter_mut().enumerate() {
        match slot.take() {
            Some(Ok(g)) => out.push(g),
            Some(Err(e)) => return Err(e),
            None => anyhow::bail!("quantization worker dropped group {i}"),
        }
    }
    Ok(out)
}

/// Draft-path dequantization: upper nibble only (INT4). Allocating
/// convenience wrapper over [`PackedGroup::dequant_draft_into`].
pub fn dequant_draft(g: &PackedGroup) -> Vec<f32> {
    let mut out = vec![0.0f32; g.len()];
    g.dequant_draft_into(&mut out);
    out
}

/// Target-path dequantization: both nibbles (INT8). Allocating convenience
/// wrapper over [`PackedGroup::dequant_target_into`].
pub fn dequant_target(g: &PackedGroup) -> Vec<f32> {
    let mut out = vec![0.0f32; g.len()];
    g.dequant_target_into(&mut out);
    out
}

/// Max reconstruction error bounds. The paper's decomposition
/// C8 = 16·C_U + C_L with C_U ∈ [0,15], C_L ∈ [-8,7] spans [-8, 247], so
/// codes near the top of the asymmetric range clip: the INT8 path is
/// ≤ S8/2 for ~97% of the range but up to 8·S8 at the clipped tail; the
/// INT4 path is ≤ S4/2 = 8·S8 plus the same tail, i.e. ≤ 15.5·S8.
pub fn error_bounds(g: &PackedGroup) -> (f32, f32) {
    (8.0 * g.scale8, 15.5 * g.scale8)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn random_group(seed: u64, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        let mut rng = Pcg32::new(seed);
        (0..n).map(|_| lo + (hi - lo) * rng.uniform() as f32).collect()
    }

    /// The pre-packing reference: one i8 code per plane element, exactly
    /// the algorithm the byte-per-nibble representation used.
    fn reference_codes(xs: &[f32]) -> (Vec<i8>, Vec<i8>, f32, f32) {
        let mn = xs.iter().copied().fold(f32::INFINITY, f32::min);
        let mx = xs.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let scale8 = ((mx - mn) / 255.0).max(EPS);
        let zero = mn;
        let s4 = 16.0 * scale8;
        let mut upper = Vec::with_capacity(xs.len());
        let mut lower = Vec::with_capacity(xs.len());
        for &x in xs {
            let u = ((x - zero) / s4).round().clamp(0.0, 15.0);
            let err = x - (u * s4 + zero);
            let l = (err / scale8).round().clamp(-8.0, 7.0);
            upper.push(u as i8);
            lower.push(l as i8);
        }
        (upper, lower, scale8, zero)
    }

    #[test]
    fn int8_reconstruction_tight() {
        for seed in 0..20 {
            let xs = random_group(seed, 64, -3.0, 2.0);
            let g = quant_group(&xs).unwrap();
            let (e8, _) = error_bounds(&g);
            let errs: Vec<f32> =
                xs.iter().zip(dequant_target(&g)).map(|(x, y)| (x - y).abs()).collect();
            for e in &errs {
                assert!(*e <= e8 * 1.01 + 1e-6, "{e}");
            }
            // typical (non-clipped) error is half an INT8 step
            let mean = errs.iter().sum::<f32>() / errs.len() as f32;
            assert!(mean <= 0.75 * g.scale8, "mean {mean} vs s8 {}", g.scale8);
        }
    }

    #[test]
    fn int4_reconstruction_bounded() {
        for seed in 0..20 {
            let xs = random_group(seed, 64, -1.0, 4.0);
            let g = quant_group(&xs).unwrap();
            let (_, e4) = error_bounds(&g);
            for (x, y) in xs.iter().zip(dequant_draft(&g)) {
                assert!((x - y).abs() <= e4 * 1.01 + 1e-6, "{x} vs {y}");
            }
        }
    }

    #[test]
    fn draft_coarser_than_target() {
        let xs = random_group(7, 128, -2.0, 2.0);
        let g = quant_group(&xs).unwrap();
        let err = |ys: Vec<f32>| -> f32 {
            xs.iter().zip(ys).map(|(x, y)| (x - y).abs()).sum()
        };
        assert!(err(dequant_target(&g)) < err(dequant_draft(&g)));
    }

    #[test]
    fn nibble_ranges() {
        let xs = random_group(9, 256, -10.0, 10.0);
        let g = quant_group(&xs).unwrap();
        for i in 0..g.len() {
            assert!(g.upper_code(i) <= 15);
            assert!((-8..=7).contains(&g.lower_code(i)));
        }
    }

    #[test]
    fn constant_group_safe() {
        let xs = vec![1.5f32; 32];
        let g = quant_group(&xs).unwrap();
        for y in dequant_target(&g) {
            assert!((y - 1.5).abs() < 1e-3);
        }
    }

    #[test]
    fn non_finite_inputs_rejected() {
        for bad in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY] {
            let mut xs = vec![0.5f32; 16];
            xs[7] = bad;
            let err = quant_group(&xs).unwrap_err().to_string();
            assert!(err.contains("non-finite"), "{bad}: {err}");
        }
        assert!(quant_group(&[]).is_err(), "empty group rejected");
        // all-finite still fine, including subnormals and zero range
        assert!(quant_group(&[0.0, f32::MIN_POSITIVE, -0.0]).is_ok());
    }

    /// Property: the packed planes round-trip bit-identically to the
    /// reference byte-per-nibble codes for random groups of random (odd and
    /// even) lengths, and the token reader matches the whole-group reader.
    #[test]
    fn prop_packed_roundtrips_reference() {
        use crate::util::prop::{check, Config};
        check::<Vec<u64>, _>(
            Config { cases: 60, size: 24, ..Config::default() },
            |seeds| {
                for &seed in seeds {
                    let n = 1 + (seed % 129) as usize; // exercise odd lengths
                    let xs = random_group(seed, n, -4.0, 3.0);
                    let (ru, rl, rs, rz) = reference_codes(&xs);
                    let g = quant_group(&xs).unwrap();
                    if g.len() != n
                        || g.scale8.to_bits() != rs.to_bits()
                        || g.zero.to_bits() != rz.to_bits()
                    {
                        return false;
                    }
                    for i in 0..n {
                        if g.upper_code(i) as i8 != ru[i] || g.lower_code(i) != rl[i] {
                            return false;
                        }
                    }
                    // packed codes cost half the bytes of the reference
                    if g.code_bytes() != 2 * n.div_ceil(2) {
                        return false;
                    }
                }
                true
            },
        );
    }

    #[test]
    fn token_reader_matches_whole_group() {
        let (g_tokens, d) = (16usize, 5usize);
        let xs = random_group(11, g_tokens * d, -2.0, 2.0);
        let g = quant_group(&xs).unwrap();
        let mut tok = vec![0.0f32; d];
        for (draft, whole) in [(true, dequant_draft(&g)), (false, dequant_target(&g))] {
            for pos in 0..g_tokens {
                g.dequant_token_into(pos, draft, &mut tok);
                assert_eq!(tok, whole[pos * d..(pos + 1) * d], "pos {pos} draft {draft}");
            }
        }
    }

    #[test]
    fn parallel_quantization_is_bit_identical() {
        use crate::util::threadpool::ThreadPool;
        let inputs: Vec<Vec<f32>> =
            (0..9).map(|s| random_group(s, 96 + s as usize, -3.0, 3.0)).collect();
        let serial_pool = ThreadPool::new(1);
        let shared_pool = ThreadPool::new(4);
        let serial = quant_groups_parallel(inputs.clone(), &serial_pool.handle()).unwrap();
        let parallel = quant_groups_parallel(inputs.clone(), &shared_pool.handle()).unwrap();
        assert_eq!(serial, parallel);
        // the serial fallback never touched the shared workers; the
        // parallel fan-out pushed every group through the one pool
        assert_eq!(serial_pool.jobs_executed(), 0);
        assert_eq!(shared_pool.jobs_executed(), inputs.len());
        // a poisoned group surfaces as an error, not a hang or panic
        let mut bad = inputs;
        bad[4][0] = f32::NAN;
        assert!(quant_groups_parallel(bad, &shared_pool.handle()).is_err());
    }

    /// Property: spill-tier serialization round-trips bit-identically for
    /// random (odd and even) group lengths — codes, scale/zero bits, and
    /// every dequantized value through both planes.
    #[test]
    fn prop_serialization_roundtrips_bit_identical() {
        use crate::util::prop::{check, Config};
        check::<Vec<u64>, _>(
            Config { cases: 40, size: 16, ..Config::default() },
            |seeds| {
                for &seed in seeds {
                    let n = 1 + (seed % 133) as usize;
                    let xs = random_group(seed, n, -5.0, 3.0);
                    let g = quant_group(&xs).unwrap();
                    let bytes = g.to_bytes();
                    if bytes.len() != g.serialized_bytes() {
                        return false;
                    }
                    let back = match PackedGroup::from_bytes(&bytes) {
                        Ok(b) => b,
                        Err(_) => return false,
                    };
                    if back != g
                        || back.scale8.to_bits() != g.scale8.to_bits()
                        || back.zero.to_bits() != g.zero.to_bits()
                    {
                        return false;
                    }
                    for i in 0..n {
                        if back.draft_value(i).to_bits() != g.draft_value(i).to_bits()
                            || back.target_value(i).to_bits() != g.target_value(i).to_bits()
                        {
                            return false;
                        }
                    }
                    // truncated and padded buffers are rejected, not misread
                    if PackedGroup::from_bytes(&bytes[..bytes.len() - 1]).is_ok() {
                        return false;
                    }
                    let mut padded = bytes.clone();
                    padded.push(0);
                    if PackedGroup::from_bytes(&padded).is_ok() {
                        return false;
                    }
                }
                true
            },
        );
    }

    /// Property (lane-wise unpack parity): for random group lengths (odd
    /// and even) and every span shape — unaligned heads, 16-byte body
    /// chunks, sub-chunk remainders, dangling tails — the lane-wise span
    /// readers return bit-for-bit what the scalar per-nibble accessors
    /// (`draft_value` / `target_value`) compute.
    #[test]
    fn prop_lane_unpack_matches_scalar() {
        use crate::util::prop::{check, Config};
        check::<Vec<u64>, _>(
            Config { cases: 30, size: 8, ..Config::default() },
            |seeds| {
                for &seed in seeds {
                    let n = 1 + (seed % 131) as usize;
                    let xs = random_group(seed, n, -3.0, 2.5);
                    let g = quant_group(&xs).unwrap();
                    let step = (n / 17).max(1);
                    for start in (0..n).step_by(step) {
                        for len in [0, 1, 2, 3, 5, 34, n - start] {
                            if start + len > n {
                                continue;
                            }
                            let mut out = vec![0.0f32; len];
                            for draft in [true, false] {
                                g.dequant_span_into(start, draft, &mut out);
                                for (j, &got) in out.iter().enumerate() {
                                    let want = if draft {
                                        g.draft_value(start + j)
                                    } else {
                                        g.target_value(start + j)
                                    };
                                    if got.to_bits() != want.to_bits() {
                                        return false;
                                    }
                                }
                            }
                        }
                    }
                }
                true
            },
        );
    }
}
