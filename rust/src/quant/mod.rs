//! Host-side mirror of the paper's §4.2 hierarchical quantization.
//!
//! The authoritative implementation is the L1 Pallas kernel
//! (`python/compile/kernels/hier_quant.py`); this mirror exists so Rust
//! tests can cross-check artifact outputs and so the mock backend can
//! emulate quantization error without XLA. Semantics are identical:
//! asymmetric INT8 per group, decomposed as C8 = 16*C_U + C_L.

/// One quantized group: nibble codes plus INT8 scale/zero.
#[derive(Debug, Clone)]
pub struct QuantGroup {
    pub upper: Vec<i8>,
    pub lower: Vec<i8>,
    pub scale8: f32,
    pub zero: f32,
}

pub const EPS: f32 = 1e-6;

/// Hierarchically quantize one group of values.
pub fn quant_group(xs: &[f32]) -> QuantGroup {
    let mn = xs.iter().copied().fold(f32::INFINITY, f32::min);
    let mx = xs.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let scale8 = ((mx - mn) / 255.0).max(EPS);
    let zero = mn;
    let s4 = 16.0 * scale8;
    let mut upper = Vec::with_capacity(xs.len());
    let mut lower = Vec::with_capacity(xs.len());
    for &x in xs {
        let u = ((x - zero) / s4).round().clamp(0.0, 15.0);
        let err = x - (u * s4 + zero);
        let l = (err / scale8).round().clamp(-8.0, 7.0);
        upper.push(u as i8);
        lower.push(l as i8);
    }
    QuantGroup { upper, lower, scale8, zero }
}

/// Draft-path dequantization: upper nibble only (INT4).
pub fn dequant_draft(g: &QuantGroup) -> Vec<f32> {
    let s4 = 16.0 * g.scale8;
    g.upper.iter().map(|&u| u as f32 * s4 + g.zero).collect()
}

/// Target-path dequantization: both nibbles (INT8).
pub fn dequant_target(g: &QuantGroup) -> Vec<f32> {
    g.upper
        .iter()
        .zip(&g.lower)
        .map(|(&u, &l)| (16.0 * u as f32 + l as f32) * g.scale8 + g.zero)
        .collect()
}

/// Max reconstruction error bounds. The paper's decomposition
/// C8 = 16·C_U + C_L with C_U ∈ [0,15], C_L ∈ [-8,7] spans [-8, 247], so
/// codes near the top of the asymmetric range clip: the INT8 path is
/// ≤ S8/2 for ~97% of the range but up to 8·S8 at the clipped tail; the
/// INT4 path is ≤ S4/2 = 8·S8 plus the same tail, i.e. ≤ 15.5·S8.
pub fn error_bounds(g: &QuantGroup) -> (f32, f32) {
    (8.0 * g.scale8, 15.5 * g.scale8)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn random_group(seed: u64, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        let mut rng = Pcg32::new(seed);
        (0..n).map(|_| lo + (hi - lo) * rng.uniform() as f32).collect()
    }

    #[test]
    fn int8_reconstruction_tight() {
        for seed in 0..20 {
            let xs = random_group(seed, 64, -3.0, 2.0);
            let g = quant_group(&xs);
            let (e8, _) = error_bounds(&g);
            let errs: Vec<f32> =
                xs.iter().zip(dequant_target(&g)).map(|(x, y)| (x - y).abs()).collect();
            for e in &errs {
                assert!(*e <= e8 * 1.01 + 1e-6, "{e}");
            }
            // typical (non-clipped) error is half an INT8 step
            let mean = errs.iter().sum::<f32>() / errs.len() as f32;
            assert!(mean <= 0.75 * g.scale8, "mean {mean} vs s8 {}", g.scale8);
        }
    }

    #[test]
    fn int4_reconstruction_bounded() {
        for seed in 0..20 {
            let xs = random_group(seed, 64, -1.0, 4.0);
            let g = quant_group(&xs);
            let (_, e4) = error_bounds(&g);
            for (x, y) in xs.iter().zip(dequant_draft(&g)) {
                assert!((x - y).abs() <= e4 * 1.01 + 1e-6, "{x} vs {y}");
            }
        }
    }

    #[test]
    fn draft_coarser_than_target() {
        let xs = random_group(7, 128, -2.0, 2.0);
        let g = quant_group(&xs);
        let err = |ys: Vec<f32>| -> f32 {
            xs.iter().zip(ys).map(|(x, y)| (x - y).abs()).sum()
        };
        assert!(err(dequant_target(&g)) < err(dequant_draft(&g)));
    }

    #[test]
    fn nibble_ranges() {
        let xs = random_group(9, 256, -10.0, 10.0);
        let g = quant_group(&xs);
        assert!(g.upper.iter().all(|&u| (0..=15).contains(&u)));
        assert!(g.lower.iter().all(|&l| (-8..=7).contains(&l)));
    }

    #[test]
    fn constant_group_safe() {
        let xs = vec![1.5f32; 32];
        let g = quant_group(&xs);
        for y in dequant_target(&g) {
            assert!((y - 1.5).abs() < 1e-3);
        }
    }
}
