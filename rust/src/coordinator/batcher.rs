//! Step-level continuous batcher with parallel rounds.
//!
//! The router's engine pool used to run whole requests; this batcher is the
//! vLLM-style alternative: one engine multiplexes many *active sessions*,
//! interleaving one speculation cycle per session per scheduling round
//! (round-robin). New sessions join between rounds, finished sessions
//! retire immediately — so a long request no longer blocks a short one
//! behind it (head-of-line blocking drops from O(request) to O(cycle)).
//! The router embeds one `StepBatcher` per engine, so chunked admission,
//! quant-pool backpressure, and parallel stepping all apply to real HTTP
//! requests, not just the examples.
//!
//! # Parallel rounds
//!
//! With [`StepBatcher::with_step_workers`] ≥ 2, a round dispatches each
//! session's step onto a dedicated `util::threadpool` pool
//! (`scoped_submit` + [`WaitGroup`], caller-scoped — concurrent batchers
//! never wait on each other's work) and reassembles results in round-robin
//! order. This is safe AND bit-identical to serial rounds because
//! sessions share no mutable state on the step path: each session's KV
//! pages live in its own pool shard (`pool::SessionShard`, its own lock),
//! the global page budget and traffic counters are atomics, and the
//! session-manager mutex is only touched by control-plane edges (admit /
//! release / evict / once-per-round telemetry). The parity is
//! property-tested across randomized prefilling+decoding session mixes.
//!
//! A step that returns an error no longer poisons the round: the session
//! is parked in [`StepBatcher::failed`] with its error and every other
//! session keeps being served. (A step that *panics* is caught, reported
//! as a failure, and the worker survives; the session itself is lost.)
//!
//! Round telemetry — `round_span_us` (wall span of the last round) and
//! `step_workers_busy` (sessions actually stepped concurrently) — flows
//! through [`StepBatcher::with_stats_sink`] into the session manager and
//! from there to `/stats`, one manager-lock acquisition per round.
//!
//! # Chunked prefill
//!
//! Admission comes in two shapes. [`ActiveSession::admit`] runs the whole
//! prefill up front (the classic path — fine for short prompts, but it
//! holds a round for O(prompt)). [`ActiveSession::admit_chunked`] instead
//! enters the session in a `Prefilling` state carrying the prompt and a
//! cursor; each scheduling round advances exactly ONE
//! `prefill_chunk_tokens` slice through [`crate::model::Decoder::prefill_chunk`],
//! interleaved with other sessions' decode cycles, so admitting a
//! 100k-token prompt costs each round O(chunk), not O(prompt). The final
//! chunk completes the prefill, samples the first token, and flips the
//! session to decoding — chunking is bit-invisible in the output.
//!
//! # Quant-pool backpressure
//!
//! Prefill chunks are the quantization-heavy step (each flushes full
//! G-groups through the process-wide quant pool). When the pool's queue
//! depth exceeds [`QuantBackpressure`]'s soft limit, the batcher defers
//! further prefill chunks for the round — decode cycles keep running —
//! and counts the deferral (locally and, when wired to a
//! [`SharedSessionManager`], into the `/stats` `prefill_deferrals`
//! counter). Deferral never stalls the batcher: it only applies while
//! some session has decode work to run.
//!
//! Works over any `Decoder`, so it is fully tested against the mock.

use std::collections::VecDeque;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{ensure, Context, Result};

use crate::config::Method;
use crate::model::Decoder;
use crate::pool::{RoundPhases, SharedSessionManager};
use crate::spec::gamma::{CycleFeedback, FixedGamma, GammaController};
use crate::spec::{Sampler, VerifyOutcome};
use crate::trace::{self, PhaseEvent, TraceBuf};
use crate::util::fault::{FaultInjector, FaultSite};
use crate::util::threadpool::{ScopedSpawn, StealHandle, ThreadPool, WaitGroup};

/// Where a session is in its lifecycle.
enum Phase {
    /// Prompt processing in flight: `cursor` of `prompt.len()` tokens have
    /// been fed; each batcher round advances one `chunk`-token slice.
    Prefilling { prompt: Vec<i32>, cursor: usize, chunk: usize },
    /// Prefill complete; each round runs one speculation cycle.
    Decoding,
}

/// One multiplexed generation in flight.
pub struct ActiveSession {
    pub id: u64,
    decoder: Box<dyn Decoder>,
    sampler: Sampler,
    gamma_ctl: Box<dyn GammaController>,
    pub tokens: Vec<i32>,
    last: i32,
    pub max_new: usize,
    pub drafted: u64,
    pub accepted: u64,
    phase: Phase,
    // Cycle-persistent buffers (mirroring `SpecEngine::generate`): the
    // drafted-token/logit/verify-window vectors are reused across cycles,
    // so a steady-state step's only allocations are the logits vectors
    // the `Decoder` trait returns by value (pinned by
    // `rust/tests/alloc_hotpath.rs`).
    drafted_buf: Vec<i32>,
    draft_logits_buf: Vec<Vec<f32>>,
    vtokens_buf: Vec<i32>,
    // Request-scoped tracing (None = untraced). The buffer is fully
    // preallocated at admission; `step` binds it as the thread's span
    // scope so pool-level events (QuantFlush, EvictLru) attribute here
    // without plumbing through the Decoder signatures.
    trace: Option<Arc<TraceBuf>>,
}

impl ActiveSession {
    /// Admit a request the classic way: runs the whole prefill immediately
    /// and samples the first token. Holds the caller for O(prompt) — use
    /// [`ActiveSession::admit_chunked`] under a batcher.
    pub fn admit(
        id: u64,
        mut decoder: Box<dyn Decoder>,
        mut sampler: Sampler,
        gamma: usize,
        prompt: &[i32],
        max_new: usize,
    ) -> Result<ActiveSession> {
        let logits = decoder.prefill(prompt)?;
        // a zero budget reports zero tokens: never sample the first token
        let first = (max_new > 0).then(|| sampler.sample(&logits));
        let mut s = Self::new_session(id, decoder, sampler, gamma, max_new, Phase::Decoding);
        if let Some(first) = first {
            s.tokens.push(first);
            s.last = first;
        }
        Ok(s)
    }

    /// Admit a request with NO prefill work done yet: the session enters
    /// `Prefilling` and each [`ActiveSession::step`] (one batcher round)
    /// feeds one `chunk_tokens` slice of the prompt. `chunk_tokens == 0`,
    /// or a decoder without chunk support, falls back to a single chunk
    /// (the whole prompt on the first round — the one-shot path, just
    /// scheduled instead of run at admission).
    pub fn admit_chunked(
        id: u64,
        decoder: Box<dyn Decoder>,
        sampler: Sampler,
        gamma: usize,
        prompt: &[i32],
        max_new: usize,
        chunk_tokens: usize,
    ) -> ActiveSession {
        let chunk = if chunk_tokens == 0 || !decoder.supports_chunked_prefill() {
            prompt.len().max(1)
        } else {
            chunk_tokens
        };
        let phase = Phase::Prefilling { prompt: prompt.to_vec(), cursor: 0, chunk };
        Self::new_session(id, decoder, sampler, gamma, max_new, phase)
    }

    fn new_session(
        id: u64,
        decoder: Box<dyn Decoder>,
        sampler: Sampler,
        gamma: usize,
        max_new: usize,
        phase: Phase,
    ) -> ActiveSession {
        let gcap = gamma.min(decoder.gamma_max()).max(1);
        ActiveSession {
            id,
            decoder,
            sampler,
            gamma_ctl: Box::new(FixedGamma(gamma)),
            // pre-sized: the budget is exact (γ-clamped), so steady-state
            // pushes never reallocate
            tokens: Vec::with_capacity(max_new + 1),
            last: 0,
            max_new,
            drafted: 0,
            accepted: 0,
            phase,
            drafted_buf: Vec::with_capacity(gcap),
            draft_logits_buf: Vec::with_capacity(gcap),
            vtokens_buf: Vec::with_capacity(gcap + 1),
            trace: None,
        }
    }

    pub fn with_controller(mut self, ctl: Box<dyn GammaController>) -> Self {
        self.gamma_ctl = ctl;
        self
    }

    /// Attach a preallocated trace buffer: every subsequent step records
    /// its phase events (prefill chunks, draft cycles, verify spans, and —
    /// via the thread-local span scope — pool-level flush/evict events)
    /// into it.
    pub fn with_trace(mut self, buf: Arc<TraceBuf>) -> Self {
        self.trace = Some(buf);
        self
    }

    pub fn trace(&self) -> Option<&Arc<TraceBuf>> {
        self.trace.as_ref()
    }

    /// True while prompt chunks remain to be fed.
    pub fn is_prefilling(&self) -> bool {
        matches!(self.phase, Phase::Prefilling { .. })
    }

    /// (tokens fed, prompt length) while prefilling; None once decoding.
    pub fn prefill_progress(&self) -> Option<(usize, usize)> {
        match &self.phase {
            Phase::Prefilling { prompt, cursor, .. } => Some((*cursor, prompt.len())),
            Phase::Decoding => None,
        }
    }

    /// Prefill chunks still to run (0 once decoding; ≥ 1 while
    /// prefilling — the final, possibly empty, chunk always remains).
    pub fn prefill_chunks_remaining(&self) -> usize {
        match &self.phase {
            Phase::Prefilling { prompt, cursor, chunk } => {
                prompt.len().saturating_sub(*cursor).div_ceil(*chunk).max(1)
            }
            Phase::Decoding => 0,
        }
    }

    pub fn done(&self) -> bool {
        !self.is_prefilling() && self.tokens.len() >= self.max_new
    }

    /// The session's decoder (read-only: context length, memory report).
    pub fn decoder(&self) -> &dyn Decoder {
        self.decoder.as_ref()
    }

    /// Run ONE unit of work: a prefill chunk while `Prefilling`, else one
    /// speculation cycle (or one AR step); returns tokens added.
    pub fn step(&mut self) -> Result<usize> {
        // Bind this request's trace for the whole step so deep layers
        // (paged-cache flush, LRU eviction) attribute their events here.
        // Arc clone + TLS swap: no allocation on the untraced or traced
        // path (pinned by alloc_hotpath).
        let _scope = self.trace.as_ref().map(|t| trace::SpanScope::enter(Arc::clone(t)));
        if self.is_prefilling() {
            return self.step_prefill();
        }
        if self.done() {
            return Ok(0);
        }
        let before = self.tokens.len();
        if self.decoder.method() == Method::Autoregressive {
            // AR has no draft phase; the target-model step lands in the
            // Verify series so the timeline still covers the step.
            let t0 = self.trace.is_some().then(Instant::now);
            let logits = self.decoder.ar_step(self.last)?;
            self.last = self.sampler.sample(&logits);
            self.tokens.push(self.last);
            if let Some(t0) = t0 {
                trace::emit(PhaseEvent::Verify { us: t0.elapsed().as_micros() as u64 });
            }
        } else {
            // Clamp γ to the remaining budget (see `SpecEngine::generate`):
            // a cycle reports at most γ + 1 tokens, so γ = remaining − 1
            // can never overshoot — the decoder never commits KV for a
            // token that is not reported. The final cycle runs with γ = 0
            // (verify the feed token alone: an AR step through the verify
            // path, valid on every backend).
            let remaining = self.max_new - self.tokens.len();
            let gamma = self
                .gamma_ctl
                .next_gamma()
                .min(self.decoder.gamma_max())
                .max(1)
                .min(remaining - 1);
            let t_draft = self.trace.is_some().then(Instant::now);
            self.decoder.begin_cycle();
            let mut feed = self.last;
            self.drafted_buf.clear();
            self.draft_logits_buf.clear();
            for _ in 0..gamma {
                let q = self.decoder.draft_step(feed)?;
                let g = self.sampler.sample(&q);
                self.drafted_buf.push(g);
                self.draft_logits_buf.push(q);
                feed = g;
            }
            self.vtokens_buf.clear();
            self.vtokens_buf.push(self.last);
            self.vtokens_buf.extend_from_slice(&self.drafted_buf);
            let draft_us = t_draft.map(|t| t.elapsed().as_micros() as u64);
            let t_verify = self.trace.is_some().then(Instant::now);
            let target = self.decoder.verify(&self.vtokens_buf)?;
            let VerifyOutcome { accepted, next_token } =
                self.sampler
                    .verify(&self.drafted_buf, &self.draft_logits_buf, &target);
            self.decoder.commit(accepted, self.vtokens_buf.len())?;
            for &g in self.drafted_buf.iter().take(accepted) {
                self.tokens.push(g);
            }
            self.tokens.push(next_token);
            self.last = next_token;
            self.drafted += gamma as u64;
            self.accepted += accepted as u64;
            if gamma > 0 {
                self.gamma_ctl.observe(CycleFeedback { gamma, accepted });
            }
            // Emitted only after verify resolves `accepted`, so the draft
            // event carries the cycle's outcome. Any QuantFlush the commit
            // triggered was recorded mid-span; at_us stays monotone.
            if let Some(us) = draft_us {
                trace::emit(PhaseEvent::DraftCycle { gamma, accepted, us });
                trace::emit(PhaseEvent::Verify {
                    us: t_verify.map_or(0, |t| t.elapsed().as_micros() as u64),
                });
            }
        }
        // No truncate: γ-clamping lands exactly on the budget, so reported
        // tokens and committed KV stay in lockstep
        // (`context_len() + 1 == prompt + reported` at exit).
        debug_assert!(self.tokens.len() <= self.max_new);
        Ok(self.tokens.len() - before)
    }

    /// Feed the next prompt chunk; on the final chunk, complete the
    /// prefill and sample the first token (1 token added).
    fn step_prefill(&mut self) -> Result<usize> {
        let t0 = self.trace.is_some().then(Instant::now);
        let (logits, finished, chunk_n, fed) = {
            let Phase::Prefilling { prompt, cursor, chunk } = &mut self.phase else {
                unreachable!("step_prefill outside Prefilling");
            };
            let end = (*cursor + *chunk).min(prompt.len());
            let is_last = end >= prompt.len();
            // chunk index: every chunk before this one was full-size
            let n = *cursor / *chunk;
            let logits = self.decoder.prefill_chunk(&prompt[*cursor..end], is_last)?;
            let fed = end - *cursor;
            *cursor = end;
            (logits, is_last, n, fed)
        };
        if let Some(t0) = t0 {
            trace::emit(PhaseEvent::PrefillChunk {
                n: chunk_n,
                tokens: fed,
                us: t0.elapsed().as_micros() as u64,
            });
        }
        if !finished {
            return Ok(0);
        }
        self.phase = Phase::Decoding;
        if self.max_new == 0 {
            // zero budget: prefill ran, nothing is sampled or reported
            return Ok(0);
        }
        let logits = logits.context("final prefill chunk must return logits")?;
        let first = self.sampler.sample(&logits);
        self.tokens.push(first);
        self.last = first;
        Ok(1)
    }
}

/// Quant-pool backpressure policy: defer prefill chunks for a round when
/// the shared quantization pool's queue depth exceeds `soft_limit`.
pub struct QuantBackpressure {
    probe: Box<dyn Fn() -> usize + Send>,
    pub soft_limit: usize,
    /// When present, deferrals are also recorded in the session manager so
    /// the router's `/stats` surfaces a `prefill_deferrals` counter.
    sink: Option<SharedSessionManager>,
}

impl QuantBackpressure {
    /// Probe the shared quantization pool of `mgr` and record deferrals
    /// into it (→ `/stats` `prefill_deferrals`). The probe holds a cloned
    /// [`crate::util::threadpool::PoolHandle`], so the per-round depth
    /// read never touches the manager mutex (the control-plane lock);
    /// only an actual deferral locks it.
    pub fn for_pool(mgr: SharedSessionManager, soft_limit: usize) -> QuantBackpressure {
        let handle = mgr.lock().unwrap_or_else(|p| p.into_inner()).quant_handle();
        QuantBackpressure {
            probe: Box::new(move || handle.queue_depth()),
            soft_limit,
            sink: Some(mgr),
        }
    }

    /// Custom depth probe (tests; pool-less embeddings). No `/stats` sink.
    pub fn with_probe(
        probe: Box<dyn Fn() -> usize + Send>,
        soft_limit: usize,
    ) -> QuantBackpressure {
        QuantBackpressure { probe, soft_limit, sink: None }
    }

    fn over_limit(&self) -> bool {
        (self.probe)() > self.soft_limit
    }

    /// Record `n` deferred chunks in one manager-lock acquisition (called
    /// at most once per round — never per deferred session).
    fn note_deferrals(&self, n: u64) {
        if let Some(mgr) = &self.sink {
            mgr.lock()
                .unwrap_or_else(|p| p.into_inner())
                .note_prefill_deferrals(n);
        }
    }
}

/// A session parked after its step failed: the batcher keeps serving
/// everyone else; the embedder (router) reports the error to the caller
/// and releases the session's resources.
pub struct FailedSession {
    pub id: u64,
    pub error: anyhow::Error,
    /// The step *panicked* (vs returning an error): the unwind was
    /// contained and the worker survived — the scheduler counts these in
    /// `step_panics_contained`.
    pub panicked: bool,
    /// The parked session. `None` only when the step panicked — the
    /// session state is gone, but the error is still reported.
    pub session: Option<ActiveSession>,
}

/// A fault the round driver decided to inject into one session's step
/// (decided on the driver thread, BEFORE dispatch, so the schedule is
/// deterministic regardless of worker interleaving).
#[derive(Clone, Copy)]
enum StepFault {
    /// Panic inside the step (exercises worker containment).
    Panic,
    /// Synthesize a decoder step error (exercises the failed-session path).
    Error,
}

/// Result of one dispatched step, reassembled in round-robin order.
struct StepOutcome {
    id: u64,
    session: Option<ActiveSession>,
    result: Result<usize>,
    /// The step was a prefill chunk (vs a decode cycle) — splits the
    /// round's wall time into the `/stats` phase aggregates.
    was_prefill: bool,
    step_us: f64,
    /// The step panicked (unwind contained by `step_one_contained`).
    panicked: bool,
}

fn step_one(mut s: ActiveSession, fault: Option<StepFault>) -> StepOutcome {
    let id = s.id;
    let was_prefill = s.is_prefilling();
    let t0 = Instant::now();
    let result = match fault {
        Some(StepFault::Panic) => panic!("injected: step worker panic (session {id})"),
        Some(StepFault::Error) => {
            Err(anyhow::anyhow!("injected: decoder step error (session {id})"))
        }
        None => s.step(),
    };
    let step_us = t0.elapsed().as_secs_f64() * 1e6;
    StepOutcome { id, session: Some(s), result, was_prefill, step_us, panicked: false }
}

/// Run one step with panic containment: a panicking step — organic or
/// injected — reports a failed outcome instead of unwinding the round.
/// Both the serial and the parallel dispatch paths go through here, so
/// containment does not depend on the worker count.
fn step_one_contained(s: ActiveSession, fault: Option<StepFault>) -> StepOutcome {
    let id = s.id;
    match std::panic::catch_unwind(std::panic::AssertUnwindSafe(move || step_one(s, fault))) {
        Ok(o) => o,
        Err(_) => StepOutcome {
            id,
            session: None,
            result: Err(anyhow::anyhow!(
                "session {id}: step panicked; session state dropped"
            )),
            was_prefill: false,
            step_us: 0.0,
            panicked: true,
        },
    }
}

/// Per-session result slots for one parallel round (indexed by round-robin
/// position).
type StepSlots = Arc<Vec<Mutex<Option<StepOutcome>>>>;

/// Fan the round's steps over the step pool (any [`ScopedSpawn`] — the
/// batcher's own FIFO pool or the process-wide stealing pool); results land
/// in fixed per-session slots so reassembly order is the round-robin order,
/// not completion order — a precondition for serial-parity determinism (and
/// for tests that compare `active` queues across configurations).
fn step_parallel(
    pool: &dyn ScopedSpawn,
    sessions: Vec<(ActiveSession, Option<StepFault>)>,
) -> Vec<StepOutcome> {
    let slots: StepSlots = Arc::new(sessions.iter().map(|_| Mutex::new(None)).collect());
    let wg = WaitGroup::new();
    for (i, (s, fault)) in sessions.into_iter().enumerate() {
        let slots = Arc::clone(&slots);
        pool.spawn_scoped(
            &wg,
            Box::new(move || {
                *slots[i].lock().unwrap_or_else(|p| p.into_inner()) =
                    Some(step_one_contained(s, fault));
            }),
        );
    }
    wg.wait();
    Arc::try_unwrap(slots)
        .unwrap_or_else(|_| unreachable!("wait group drained every step job"))
        .into_iter()
        .map(|m| {
            m.into_inner()
                .unwrap_or_else(|p| p.into_inner())
                .expect("every step job fills its slot")
        })
        .collect()
}

/// Round-robin scheduler over active sessions with an admission bound.
pub struct StepBatcher {
    pub max_active: usize,
    active: VecDeque<ActiveSession>,
    pub finished: Vec<ActiveSession>,
    /// Sessions whose step errored (or panicked), parked with the error.
    pub failed: Vec<FailedSession>,
    rounds: u64,
    backpressure: Option<QuantBackpressure>,
    prefill_deferrals: u64,
    /// Step pool for parallel rounds; None = serial (`step_workers == 1`).
    step_pool: Option<ThreadPool>,
    /// Handle onto the process-wide stealing step pool (the cross-engine
    /// scheduler's). Takes precedence over `step_pool`: the batcher fans
    /// its rounds over shared workers instead of owning a pool.
    shared_pool: Option<StealHandle>,
    step_workers: usize,
    /// Once-per-round telemetry sink (→ `/stats` via the session manager).
    stats_sink: Option<SharedSessionManager>,
    /// Deterministic fault injector (None unless faults are configured).
    /// Fault decisions are made on the driver thread before dispatch so
    /// the schedule is reproducible for a given seed/spec.
    fault: Option<Arc<FaultInjector>>,
    last_round_span_us: f64,
    last_busy: usize,
    last_phases: RoundPhases,
}

impl StepBatcher {
    pub fn new(max_active: usize) -> StepBatcher {
        StepBatcher {
            max_active: max_active.max(1),
            active: VecDeque::new(),
            finished: Vec::new(),
            failed: Vec::new(),
            rounds: 0,
            backpressure: None,
            prefill_deferrals: 0,
            step_pool: None,
            shared_pool: None,
            step_workers: 1,
            stats_sink: None,
            fault: None,
            last_round_span_us: 0.0,
            last_busy: 0,
            last_phases: RoundPhases::default(),
        }
    }

    /// Enable quant-pool backpressure (see [`QuantBackpressure`]).
    pub fn with_backpressure(mut self, bp: QuantBackpressure) -> StepBatcher {
        self.backpressure = Some(bp);
        self
    }

    /// Run rounds on `workers` step workers (a dedicated
    /// `util::threadpool` pool named `qs-step-*`). 1 = serial rounds (no
    /// pool is spawned); ≥ 2 dispatches sessions concurrently,
    /// bit-identical to serial per session. 0 is rejected at the
    /// coordinator boundary, never silently clamped — this builder
    /// asserts, mirroring `pool.quant_workers`.
    pub fn with_step_workers(mut self, workers: usize) -> StepBatcher {
        assert!(workers >= 1, "step_workers must be >= 1 (1 = serial rounds)");
        self.step_workers = workers;
        self.step_pool = (workers >= 2).then(|| ThreadPool::named(workers, "qs-step"));
        self
    }

    /// Fan rounds over a SHARED work-stealing pool instead of an owned
    /// per-batcher pool (the cross-engine scheduler wires every session
    /// through one process-wide `qs-sched-*` pool). Takes precedence over
    /// [`StepBatcher::with_step_workers`]; reported `step_workers` becomes
    /// the shared pool's size.
    pub fn with_shared_step_pool(mut self, handle: StealHandle) -> StepBatcher {
        self.step_workers = handle.size();
        self.shared_pool = Some(handle);
        self.step_pool = None;
        self
    }

    /// Report once-per-round telemetry (`round_span_us`,
    /// `step_workers_busy`) into the session manager → `/stats`.
    pub fn with_stats_sink(mut self, mgr: SharedSessionManager) -> StepBatcher {
        self.stats_sink = Some(mgr);
        self
    }

    /// Drive step-path fault sites (`step_panic`, `decode_error`,
    /// `quant_stall`) from a seeded injector. A disabled injector is
    /// dropped so the hot path stays free of per-step queries.
    pub fn with_fault_injector(mut self, inj: Arc<FaultInjector>) -> StepBatcher {
        self.fault = inj.enabled().then_some(inj);
        self
    }

    pub fn has_capacity(&self) -> bool {
        self.active.len() < self.max_active
    }

    pub fn active_len(&self) -> usize {
        self.active.len()
    }

    /// The currently active sessions, in round-robin order (benches and
    /// embedders read prefill progress / ids through this).
    pub fn active_sessions(&self) -> impl Iterator<Item = &ActiveSession> {
        self.active.iter()
    }

    /// Prefill chunks deferred by backpressure so far.
    pub fn prefill_deferrals(&self) -> u64 {
        self.prefill_deferrals
    }

    /// Configured step workers (1 = serial rounds).
    pub fn step_workers(&self) -> usize {
        self.step_workers
    }

    /// Wall-clock span of the last round, microseconds.
    pub fn last_round_span_us(&self) -> f64 {
        self.last_round_span_us
    }

    /// Sessions stepped concurrently in the last round
    /// (min(step_workers, sessions stepped)).
    pub fn last_step_workers_busy(&self) -> usize {
        self.last_busy
    }

    /// Per-phase split of the last round: µs spent inside prefill-chunk
    /// steps, decode steps, and (deferred sessions × round span) quant
    /// wait.
    pub fn last_round_phases(&self) -> RoundPhases {
        self.last_phases
    }

    /// Evict an active session mid-flight (cancellation, deadline expiry).
    /// Returns the session so the embedder can drop it and release its
    /// pool pages; round-robin order of the survivors is preserved.
    pub fn remove(&mut self, id: u64) -> Option<ActiveSession> {
        let pos = self.active.iter().position(|s| s.id == id)?;
        self.active.remove(pos)
    }

    /// Admit a session into the round-robin. Errors (instead of aborting
    /// the process) on over-capacity admission: the batcher is embedded in
    /// router/server contexts where a caller bug must surface as a clean
    /// failure, not a panic that takes every in-flight request with it.
    pub fn admit(&mut self, s: ActiveSession) -> Result<()> {
        ensure!(
            self.has_capacity(),
            "admission over capacity: {} sessions active of max {}",
            self.active.len(),
            self.max_active
        );
        self.active.push_back(s);
        Ok(())
    }

    /// One scheduling round: each active session advances one unit of work
    /// (a prefill chunk or a speculation cycle); finished sessions retire;
    /// sessions whose step errors are parked in [`StepBatcher::failed`]
    /// (the rest keep being served). With step workers ≥ 2, sessions step
    /// concurrently — bit-identical per session to a serial round. Under
    /// quant-pool backpressure, prefill chunks are deferred for the round
    /// while decode work exists. Returns tokens produced this round.
    pub fn round(&mut self) -> Result<usize> {
        self.rounds += 1;
        // Probe once per round. Deferral only applies while some session
        // has decode work — if every active session is prefilling, chunks
        // proceed regardless, so the batcher always makes progress.
        let has_decode = self.active.iter().any(|s| !s.is_prefilling());
        // An injected quant stall behaves exactly like a backpressure
        // probe tripping: prefill chunks sit out the round while decode
        // work exists (and count as quant-wait in the phase split).
        let injected_stall = has_decode
            && self.fault.as_ref().is_some_and(|f| f.should_fire(FaultSite::QuantStall));
        let defer_prefill = injected_stall
            || (has_decode && self.backpressure.as_ref().is_some_and(|bp| bp.over_limit()));
        let mut deferred = 0u64;
        let mut to_step: Vec<(ActiveSession, Option<StepFault>)> =
            Vec::with_capacity(self.active.len());
        for _ in 0..self.active.len() {
            let s = self.active.pop_front().expect("non-empty");
            if defer_prefill && s.is_prefilling() {
                deferred += 1;
                self.active.push_back(s);
                continue;
            }
            // Decide per-session step faults here, on the driver thread,
            // in round-robin order — never inside the workers — so a given
            // seed/spec produces the same schedule under any worker count.
            let fault = match &self.fault {
                Some(f) if f.should_fire(FaultSite::StepPanic) => Some(StepFault::Panic),
                Some(f) if f.should_fire(FaultSite::DecodeError) => Some(StepFault::Error),
                _ => None,
            };
            to_step.push((s, fault));
        }
        let stepped = to_step.len();
        let t0 = Instant::now();
        let outcomes = match (&self.shared_pool, &self.step_pool) {
            (Some(shared), _) if stepped >= 2 && shared.size() >= 2 => {
                step_parallel(shared, to_step)
            }
            (None, Some(pool)) if stepped >= 2 => step_parallel(&pool.handle(), to_step),
            _ => to_step.into_iter().map(|(s, f)| step_one_contained(s, f)).collect(),
        };
        let span_us = t0.elapsed().as_secs_f64() * 1e6;
        let mut produced = 0usize;
        let mut prefill_us = 0.0f64;
        let mut decode_us = 0.0f64;
        for o in outcomes {
            if o.was_prefill {
                prefill_us += o.step_us;
            } else {
                decode_us += o.step_us;
            }
            match (o.session, o.result) {
                (Some(s), Ok(n)) => {
                    produced += n;
                    if s.done() {
                        self.finished.push(s);
                    } else {
                        self.active.push_back(s);
                    }
                }
                (session, Err(error)) => {
                    self.failed.push(FailedSession {
                        id: o.id,
                        error,
                        panicked: o.panicked,
                        session,
                    });
                }
                (None, Ok(_)) => unreachable!("a panicked step reports an error"),
            }
        }
        self.last_round_span_us = span_us;
        self.last_busy = stepped.min(self.step_workers);
        // Deferred prefill sessions sat out the whole round waiting on
        // quant-pool capacity — that is their quant-wait contribution.
        self.last_phases = RoundPhases {
            prefill_us,
            decode_us,
            quant_wait_us: deferred as f64 * span_us,
        };
        if deferred > 0 {
            self.prefill_deferrals += deferred;
            if let Some(bp) = &self.backpressure {
                bp.note_deferrals(deferred);
            }
        }
        if let Some(mgr) = &self.stats_sink {
            mgr.lock()
                .unwrap_or_else(|p| p.into_inner())
                .note_round(span_us, self.last_busy, self.step_workers, self.last_phases);
        }
        Ok(produced)
    }

    /// Drive until everything currently admitted finishes (or fails).
    pub fn drain(&mut self) -> Result<()> {
        while !self.active.is_empty() {
            self.round()?;
        }
        Ok(())
    }

    pub fn rounds(&self) -> u64 {
        self.rounds
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::MockDecoder;
    use crate::spec::gamma::AimdGamma;

    fn mock_session(id: u64, max_new: usize, err: f64, gamma: usize) -> ActiveSession {
        let dec = Box::new(MockDecoder::new(64, 7, err));
        ActiveSession::admit(
            id,
            dec,
            Sampler::new(0.0, id),
            gamma,
            &[1, 2, 3, id as i32],
            max_new,
        )
        .unwrap()
    }

    fn chunked_session(
        id: u64,
        prompt: &[i32],
        max_new: usize,
        gamma: usize,
        chunk: usize,
    ) -> ActiveSession {
        let dec = Box::new(MockDecoder::new(64, 7, 0.1));
        let sampler = Sampler::new(0.0, id);
        ActiveSession::admit_chunked(id, dec, sampler, gamma, prompt, max_new, chunk)
    }

    #[test]
    fn active_session_is_send() {
        fn assert_send<T: Send>() {}
        assert_send::<ActiveSession>();
    }

    #[test]
    fn single_session_matches_engine_output() {
        // The step batcher must produce exactly what SpecEngine produces.
        let mut b = StepBatcher::new(4);
        b.admit(mock_session(7, 30, 0.2, 4)).unwrap();
        b.drain().unwrap();
        let batched = b.finished.pop().unwrap().tokens;

        let mut dec = MockDecoder::new(64, 7, 0.2);
        let mut eng = crate::spec::SpecEngine::new(4, Sampler::new(0.0, 7));
        let direct = eng.generate(&mut dec, &[1, 2, 3, 7], 30).unwrap().tokens;
        assert_eq!(batched, direct);
    }

    /// Chunked admission is output-invisible: any chunk size produces
    /// exactly the monolithic-admission tokens.
    #[test]
    fn chunked_admission_matches_monolithic() {
        let prompt: Vec<i32> = (0..37).map(|t| (t * 3) % 64).collect();
        let mut b = StepBatcher::new(1);
        let dec = Box::new(MockDecoder::new(64, 7, 0.1));
        let s = ActiveSession::admit(9, dec, Sampler::new(0.0, 9), 4, &prompt, 25).unwrap();
        b.admit(s).unwrap();
        b.drain().unwrap();
        let want = b.finished.pop().unwrap().tokens;
        for chunk in [1usize, 5, 8, 9, 16, 37, 0 /* = one-shot */] {
            let mut b = StepBatcher::new(1);
            b.admit(chunked_session(9, &prompt, 25, 4, chunk)).unwrap();
            b.drain().unwrap();
            let s = b.finished.pop().unwrap();
            assert_eq!(s.tokens, want, "chunk {chunk}");
            assert!(!s.is_prefilling());
        }
    }

    /// A 4k-token prompt admitted alongside active decode sessions
    /// advances at most `chunk` prefill tokens per round (no round does
    /// O(prompt) prefill work), and a short decode request admitted at the
    /// same time finishes in ~its own number of rounds — no head-of-line
    /// blocking behind the huge prefill.
    #[test]
    fn huge_prefill_interleaves_without_hol_blocking() {
        let chunk = 64usize;
        let long_prompt: Vec<i32> = (0..4096).map(|t| t % 64).collect();
        let mut b = StepBatcher::new(4);
        b.admit(chunked_session(1, &long_prompt, 8, 4, chunk)).unwrap();
        b.admit(mock_session(2, 10, 0.0, 4)).unwrap();
        let mut rounds_to_short = 0;
        let mut last_fed = 0usize;
        while !b.finished.iter().any(|s| s.id == 2) {
            b.round().unwrap();
            rounds_to_short += 1;
            // prefill work this round is bounded by the chunk size
            if let Some(s) = b.active.iter().find(|s| s.id == 1) {
                let (fed, total) = s.prefill_progress().unwrap_or((4096, 4096));
                assert!(fed - last_fed <= chunk, "round fed {} tokens", fed - last_fed);
                assert_eq!(total, 4096);
                last_fed = fed;
            }
            assert!(rounds_to_short < 20, "short request starved by 4k prefill");
        }
        // the long session is still mid-prefill when the short one retires
        let long = b.active.iter().find(|s| s.id == 1).unwrap();
        let (fed, _) = long.prefill_progress().unwrap();
        assert!(fed < 4096, "prefill monopolized rounds: {fed} tokens already fed");
        assert!(long.prefill_chunks_remaining() > 0);
        b.drain().unwrap();
        assert_eq!(b.finished.len(), 2);
        let long = b.finished.iter().find(|s| s.id == 1).unwrap();
        assert_eq!(long.tokens.len(), 8);
    }

    /// Backpressure: with the quant queue over the soft limit, prefill
    /// chunks are deferred (and counted) while decode cycles keep running;
    /// once pressure clears, prefill resumes. A batcher whose sessions are
    /// ALL prefilling ignores the limit (progress guarantee).
    #[test]
    fn backpressure_defers_prefill_but_not_decode() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        use std::sync::Arc;
        let depth = Arc::new(AtomicUsize::new(100));
        let probe_depth = Arc::clone(&depth);
        let mut b = StepBatcher::new(4).with_backpressure(QuantBackpressure::with_probe(
            Box::new(move || probe_depth.load(Ordering::Relaxed)),
            8,
        ));
        let prompt: Vec<i32> = (0..64).collect();
        b.admit(chunked_session(1, &prompt, 6, 2, 16)).unwrap();
        b.admit(mock_session(2, 40, 0.0, 4)).unwrap();
        let decoded_before = {
            let mut produced = 0;
            for _ in 0..3 {
                produced += b.round().unwrap();
            }
            produced
        };
        assert!(decoded_before > 0, "decode cycles kept running");
        assert_eq!(b.prefill_deferrals(), 3, "each round deferred the one prefill");
        let s = b.active.iter().find(|s| s.id == 1).unwrap();
        assert_eq!(s.prefill_progress(), Some((0, 64)), "no prefill ran under pressure");
        // pressure clears -> prefill advances exactly one chunk per round
        depth.store(0, Ordering::Relaxed);
        b.round().unwrap();
        let s = b.active.iter().find(|s| s.id == 1).unwrap();
        assert_eq!(s.prefill_progress(), Some((16, 64)));
        assert_eq!(b.prefill_deferrals(), 3);
        b.drain().unwrap();
        assert_eq!(b.finished.len(), 2);

        // all-prefilling batcher: the soft limit cannot stall it
        let depth = Arc::new(AtomicUsize::new(100));
        let probe_depth = Arc::clone(&depth);
        let mut b = StepBatcher::new(2).with_backpressure(QuantBackpressure::with_probe(
            Box::new(move || probe_depth.load(Ordering::Relaxed)),
            0,
        ));
        b.admit(chunked_session(3, &prompt, 4, 2, 16)).unwrap();
        b.drain().unwrap();
        assert_eq!(b.finished.len(), 1);
        assert_eq!(b.prefill_deferrals(), 0, "no decode work -> no deferral");
    }

    /// `for_pool` wiring: deferrals recorded through the session manager
    /// surface in the pool's `/stats` JSON (and its gauge mirror).
    #[test]
    fn for_pool_backpressure_records_deferrals_in_stats() {
        use crate::pool::{shared, PoolConfig};
        let mgr = shared(PoolConfig { pages: 8, ..PoolConfig::default() }).unwrap();
        let bp = QuantBackpressure::for_pool(mgr.clone(), 3);
        assert!(!bp.over_limit(), "idle quant pool is under any limit");
        bp.note_deferrals(2);
        let m = mgr.lock().unwrap();
        assert_eq!(m.prefill_deferrals(), 2);
        let js = m.stats_json().to_string();
        assert!(js.contains("\"prefill_deferrals\""), "{js}");
    }

    /// Round telemetry flows through the stats sink: one `note_round` per
    /// round, carrying the configured workers and last-round busy count.
    #[test]
    fn round_telemetry_reaches_stats_sink() {
        use crate::pool::{shared, PoolConfig};
        let mgr = shared(PoolConfig { pages: 8, ..PoolConfig::default() }).unwrap();
        let mut b = StepBatcher::new(4)
            .with_step_workers(2)
            .with_stats_sink(mgr.clone());
        b.admit(mock_session(1, 6, 0.0, 2)).unwrap();
        b.admit(mock_session(2, 6, 0.0, 2)).unwrap();
        b.round().unwrap();
        assert_eq!(b.last_step_workers_busy(), 2);
        assert!(b.last_round_span_us() > 0.0);
        let m = mgr.lock().unwrap();
        let s = m.snapshot();
        assert_eq!((s.step_workers, s.step_workers_busy, s.rounds), (2, 2, 1));
        assert!(s.round_span_us > 0.0);
        let js = m.stats_json().to_string();
        assert!(js.contains("\"round_span_us\""), "{js}");
        assert!(js.contains("\"step_workers\""), "{js}");
    }

    /// Satellite regression: a session whose step errors mid-round is
    /// parked in `failed` WITH its error — not silently dropped — and the
    /// other sessions keep being served to completion. Before the fix the
    /// popped session vanished: neither re-queued nor recorded.
    #[test]
    fn failing_session_is_parked_not_dropped() {
        /// Errors on the N-th draft step.
        struct FailAfter {
            inner: MockDecoder,
            steps_left: usize,
        }
        impl Decoder for FailAfter {
            fn vocab(&self) -> usize {
                self.inner.vocab()
            }
            fn gamma_max(&self) -> usize {
                self.inner.gamma_max()
            }
            fn method(&self) -> Method {
                self.inner.method()
            }
            fn prefill(&mut self, t: &[i32]) -> Result<Vec<f32>> {
                self.inner.prefill(t)
            }
            fn begin_cycle(&mut self) {
                self.inner.begin_cycle()
            }
            fn draft_step(&mut self, t: i32) -> Result<Vec<f32>> {
                if self.steps_left == 0 {
                    anyhow::bail!("injected device fault");
                }
                self.steps_left -= 1;
                self.inner.draft_step(t)
            }
            fn verify(&mut self, t: &[i32]) -> Result<Vec<Vec<f32>>> {
                self.inner.verify(t)
            }
            fn commit(&mut self, a: usize, v: usize) -> Result<()> {
                self.inner.commit(a, v)
            }
            fn ar_step(&mut self, t: i32) -> Result<Vec<f32>> {
                self.inner.ar_step(t)
            }
            fn context_len(&self) -> usize {
                self.inner.context_len()
            }
            fn memory(&self) -> crate::cache::MemoryReport {
                self.inner.memory()
            }
            fn timings(&self) -> crate::model::PhaseTimings {
                self.inner.timings()
            }
        }
        for workers in [1usize, 2] {
            let mut b = StepBatcher::new(4).with_step_workers(workers);
            let flaky = ActiveSession::admit(
                1,
                Box::new(FailAfter {
                    inner: MockDecoder::new(64, 7, 0.0),
                    steps_left: 5,
                }),
                Sampler::new(0.0, 1),
                3,
                &[1, 2, 3],
                40,
            )
            .unwrap();
            b.admit(flaky).unwrap();
            b.admit(mock_session(2, 12, 0.1, 3)).unwrap();
            b.admit(mock_session(3, 9, 0.1, 3)).unwrap();
            b.drain().unwrap();
            assert_eq!(b.failed.len(), 1, "workers={workers}");
            let f = &b.failed[0];
            assert_eq!(f.id, 1);
            assert!(f.error.to_string().contains("injected device fault"));
            let parked = f.session.as_ref().expect("session parked, not lost");
            assert!(!parked.tokens.is_empty(), "partial progress preserved");
            // the healthy sessions were unaffected
            assert_eq!(b.finished.len(), 2, "workers={workers}");
            for s in &b.finished {
                assert_eq!(s.tokens.len(), s.max_new);
            }
        }
    }

    /// Regression (satellite): over-capacity admission is a clean error,
    /// not a process-aborting panic, and the batcher keeps serving.
    #[test]
    fn admit_over_capacity_is_error_not_panic() {
        let mut b = StepBatcher::new(2);
        b.admit(mock_session(1, 8, 0.0, 2)).unwrap();
        b.admit(mock_session(2, 8, 0.0, 2)).unwrap();
        let err = b.admit(mock_session(3, 8, 0.0, 2)).unwrap_err().to_string();
        assert!(err.contains("over capacity"), "got: {err}");
        // existing sessions are unaffected
        b.drain().unwrap();
        assert_eq!(b.finished.len(), 2);
        b.admit(mock_session(3, 8, 0.0, 2)).unwrap();
        b.drain().unwrap();
        assert_eq!(b.finished.len(), 3);
    }

    /// Injected step faults (panic + decoder error) park exactly the
    /// targeted sessions while co-scheduled healthy sessions finish their
    /// full budgets — on the serial path AND the parallel path (the panic
    /// is contained either way).
    #[test]
    fn injected_step_faults_park_sessions_and_spare_the_rest() {
        for workers in [1usize, 2] {
            let inj = Arc::new(
                FaultInjector::parse(5, "step_panic:1000:1,decode_error:1000:1").unwrap(),
            );
            let mut b = StepBatcher::new(4)
                .with_step_workers(workers)
                .with_fault_injector(Arc::clone(&inj));
            b.admit(mock_session(1, 12, 0.1, 3)).unwrap();
            b.admit(mock_session(2, 12, 0.1, 3)).unwrap();
            b.admit(mock_session(3, 12, 0.1, 3)).unwrap();
            b.drain().unwrap();
            assert_eq!(b.failed.len(), 2, "workers={workers}");
            // Faults are decided in round-robin order on the driver
            // thread: session 1 draws the panic, session 2 the error.
            let parked = b.failed.iter().find(|f| f.id == 1).unwrap();
            assert!(parked.panicked, "workers={workers}");
            assert!(parked.session.is_none(), "panicked session state is dropped");
            assert!(parked.error.to_string().contains("panicked"));
            let errored = b.failed.iter().find(|f| f.id == 2).unwrap();
            assert!(!errored.panicked);
            assert!(errored.error.to_string().contains("injected"));
            assert!(errored.session.is_some(), "errored session parked intact");
            // the healthy session is unaffected and finishes its budget
            assert_eq!(b.finished.len(), 1, "workers={workers}");
            assert_eq!(b.finished[0].id, 3);
            assert_eq!(b.finished[0].tokens.len(), 12);
            assert_eq!(inj.total_fires(), 2);
        }
    }

    /// An injected quant stall behaves like a tripped backpressure probe
    /// (prefill defers, decode proceeds) without any probe being wired,
    /// and stops exactly when its fire budget is spent.
    #[test]
    fn injected_quant_stall_defers_prefill_without_a_probe() {
        let inj = Arc::new(FaultInjector::parse(9, "quant_stall:1000:2").unwrap());
        let prompt: Vec<i32> = (0..32).map(|t| t % 64).collect();
        let mut b = StepBatcher::new(4).with_fault_injector(inj);
        b.admit(chunked_session(1, &prompt, 6, 2, 16)).unwrap();
        b.admit(mock_session(2, 30, 0.0, 4)).unwrap();
        // rounds 1-2: the stall fires; prefill sits out while decode runs
        b.round().unwrap();
        b.round().unwrap();
        assert_eq!(b.prefill_deferrals(), 2);
        let s = b.active_sessions().find(|s| s.id == 1).unwrap();
        assert_eq!(s.prefill_progress().unwrap(), (0, 32), "no chunk fed while stalled");
        // budget exhausted: round 3 feeds the first chunk
        b.round().unwrap();
        let s = b.active_sessions().find(|s| s.id == 1).unwrap();
        assert_eq!(s.prefill_progress().unwrap(), (16, 32));
        b.drain().unwrap();
        assert_eq!(b.finished.len(), 2);
        assert_eq!(b.prefill_deferrals(), 2, "no deferrals after the budget is spent");
    }

    /// Regression (budget over-commit, batcher loop): committed KV tracks
    /// reported tokens exactly — γ is clamped to the remaining budget, so
    /// at exit `context_len() + 1 == prompt + reported` (the trailing
    /// reported token is the next feed, never yet fed back) and the
    /// report is never truncated after the decoder committed tokens.
    #[test]
    fn committed_context_matches_reported_tokens() {
        for max_new in [1usize, 2, 5, 12, 30] {
            for gamma in [1usize, 3, 7] {
                let prompt = [4, 5, 6];
                let dec = Box::new(MockDecoder::new(64, 7, 0.25));
                let sampler = Sampler::new(0.0, 11);
                let mut s =
                    ActiveSession::admit(11, dec, sampler, gamma, &prompt, max_new).unwrap();
                while !s.done() {
                    s.step().unwrap();
                }
                assert_eq!(s.tokens.len(), max_new);
                assert_eq!(
                    s.decoder().context_len() + 1,
                    prompt.len() + s.tokens.len(),
                    "gamma={gamma} max_new={max_new}"
                );
            }
        }
    }

    /// A zero budget reports zero tokens on both admission paths (the
    /// prefill still runs; the first token is never sampled).
    #[test]
    fn zero_budget_session_reports_zero_tokens() {
        let mut b = StepBatcher::new(2);
        b.admit(mock_session(1, 0, 0.0, 2)).unwrap();
        b.admit(chunked_session(2, &[1, 2, 3, 4, 5], 0, 2, 2)).unwrap();
        b.drain().unwrap();
        assert_eq!(b.finished.len(), 2);
        for s in &b.finished {
            assert!(s.tokens.is_empty(), "id {}", s.id);
        }
    }

    #[test]
    fn interleaves_without_hol_blocking() {
        // A short request admitted alongside a long one must finish in
        // ~its own number of rounds, not after the long one.
        let mut b = StepBatcher::new(4);
        b.admit(mock_session(1, 200, 0.0, 4)).unwrap(); // long
        b.admit(mock_session(2, 10, 0.0, 4)).unwrap(); // short
        let mut rounds_to_short = 0;
        while !b.finished.iter().any(|s| s.id == 2) {
            b.round().unwrap();
            rounds_to_short += 1;
            assert!(rounds_to_short < 20, "short request starved");
        }
        assert!(!b.finished.iter().any(|s| s.id == 1), "long not done yet");
        b.drain().unwrap();
        assert_eq!(b.finished.len(), 2);
    }

    #[test]
    fn all_sessions_complete_exactly() {
        let mut b = StepBatcher::new(8);
        for i in 0..8 {
            b.admit(mock_session(i, 12 + i as usize, 0.3, 3)).unwrap();
        }
        b.drain().unwrap();
        assert_eq!(b.finished.len(), 8);
        for s in &b.finished {
            assert_eq!(s.tokens.len(), s.max_new);
        }
    }

    /// Parallel rounds retire every session with its exact budget, same
    /// as serial (the cheap smoke version of the parity property below).
    #[test]
    fn parallel_rounds_complete_all_sessions() {
        let mut b = StepBatcher::new(8).with_step_workers(4);
        for i in 0..8 {
            b.admit(mock_session(i, 12 + i as usize, 0.3, 3)).unwrap();
        }
        b.drain().unwrap();
        assert_eq!(b.finished.len(), 8);
        for s in &b.finished {
            assert_eq!(s.tokens.len(), s.max_new);
        }
    }

    /// Rounds fanned over a SHARED stealing pool produce exactly the
    /// serial token streams (the scheduler's dispatch path), and the
    /// batcher reports the shared pool's size as its step workers.
    #[test]
    fn shared_steal_pool_rounds_match_serial() {
        let run_serial = |ids: &[u64]| -> Vec<(u64, Vec<i32>)> {
            let mut b = StepBatcher::new(8);
            for &i in ids {
                b.admit(mock_session(i, 10 + i as usize, 0.3, 3)).unwrap();
            }
            b.drain().unwrap();
            let mut t: Vec<_> = b.finished.iter().map(|s| (s.id, s.tokens.clone())).collect();
            t.sort_by_key(|(id, _)| *id);
            t
        };
        let ids: Vec<u64> = (0..6).collect();
        let want = run_serial(&ids);
        let pool = crate::util::threadpool::StealPool::named(3, "qs-sched");
        let mut b = StepBatcher::new(8).with_shared_step_pool(pool.handle());
        assert_eq!(b.step_workers(), 3);
        for &i in &ids {
            b.admit(mock_session(i, 10 + i as usize, 0.3, 3)).unwrap();
        }
        b.drain().unwrap();
        let mut got: Vec<_> = b.finished.iter().map(|s| (s.id, s.tokens.clone())).collect();
        got.sort_by_key(|(id, _)| *id);
        assert_eq!(got, want, "shared-pool rounds must be bit-identical to serial");
    }

    /// `remove` evicts exactly the target session mid-flight; the others
    /// keep their round-robin order and complete untouched.
    #[test]
    fn remove_evicts_only_the_target_session() {
        let mut b = StepBatcher::new(4);
        b.admit(mock_session(1, 40, 0.0, 3)).unwrap();
        b.admit(mock_session(2, 8, 0.0, 3)).unwrap();
        b.admit(mock_session(3, 8, 0.0, 3)).unwrap();
        b.round().unwrap();
        let evicted = b.remove(1).expect("session 1 is active");
        assert_eq!(evicted.id, 1);
        assert!(!evicted.tokens.is_empty(), "partial progress travels with it");
        assert!(b.remove(1).is_none(), "second remove finds nothing");
        assert!(b.remove(99).is_none());
        b.drain().unwrap();
        assert_eq!(b.finished.len(), 2);
        assert!(b.finished.iter().all(|s| s.id != 1));
        assert!(b.failed.is_empty());
    }

    /// Tracing: a traced chunked session emits every prefill chunk and
    /// every decode cycle (with γ and accepted) in timeline order, one
    /// verify per cycle — and tracing is output-invisible.
    #[test]
    fn traced_session_emits_ordered_phase_events() {
        let prompt: Vec<i32> = (0..40).map(|t| t % 64).collect();
        let mut plain = StepBatcher::new(1);
        plain.admit(chunked_session(5, &prompt, 20, 3, 16)).unwrap();
        plain.drain().unwrap();
        let want = plain.finished.pop().unwrap().tokens;

        let buf = TraceBuf::new(256);
        let mut b = StepBatcher::new(1);
        b.admit(chunked_session(5, &prompt, 20, 3, 16).with_trace(Arc::clone(&buf)))
            .unwrap();
        b.drain().unwrap();
        let s = b.finished.pop().unwrap();
        assert_eq!(s.tokens, want, "tracing must not change output");

        let events = buf.snapshot();
        let chunks: Vec<_> = events
            .iter()
            .filter_map(|(_, e)| match e {
                PhaseEvent::PrefillChunk { n, tokens, .. } => Some((*n, *tokens)),
                _ => None,
            })
            .collect();
        assert_eq!(chunks, vec![(0, 16), (1, 16), (2, 8)], "every chunk traced");
        let cycles: Vec<_> = events
            .iter()
            .filter_map(|(_, e)| match e {
                PhaseEvent::DraftCycle { gamma, accepted, .. } => Some((*gamma, *accepted)),
                _ => None,
            })
            .collect();
        assert!(!cycles.is_empty());
        assert!(cycles.iter().all(|&(g, a)| a <= g), "accepted <= gamma");
        let verifies = events
            .iter()
            .filter(|(_, e)| matches!(e, PhaseEvent::Verify { .. }))
            .count();
        assert_eq!(verifies, cycles.len(), "one verify per cycle");
        let last_chunk = events
            .iter()
            .rposition(|(_, e)| matches!(e, PhaseEvent::PrefillChunk { .. }))
            .unwrap();
        let first_cycle = events
            .iter()
            .position(|(_, e)| matches!(e, PhaseEvent::DraftCycle { .. }))
            .unwrap();
        assert!(last_chunk < first_cycle, "prefill precedes decode in the timeline");
        assert!(events.windows(2).all(|w| w[0].0 <= w[1].0), "monotone timestamps");
        assert_eq!(buf.dropped(), 0);
    }

    /// Round phase aggregates: a mixed round splits its wall time into
    /// prefill vs decode step spans; no deferrals → zero quant wait.
    #[test]
    fn round_phases_split_prefill_and_decode() {
        let prompt: Vec<i32> = (0..64).collect();
        let mut b = StepBatcher::new(4);
        b.admit(chunked_session(1, &prompt, 8, 2, 16)).unwrap();
        b.admit(mock_session(2, 10, 0.0, 4)).unwrap();
        b.round().unwrap();
        let p = b.last_round_phases();
        assert!(p.prefill_us > 0.0, "prefill stepped this round");
        assert!(p.decode_us > 0.0, "decode stepped this round");
        assert_eq!(p.quant_wait_us, 0.0, "no deferrals this round");
    }

    #[test]
    fn adaptive_gamma_session_runs() {
        let dec = Box::new(MockDecoder::new(64, 7, 0.15));
        let s = ActiveSession::admit(9, dec, Sampler::new(0.0, 9), 2, &[5, 6], 60)
            .unwrap()
            .with_controller(Box::new(AimdGamma::new(2, 1, 7)));
        let mut b = StepBatcher::new(1);
        b.admit(s).unwrap();
        b.drain().unwrap();
        let s = b.finished.pop().unwrap();
        assert_eq!(s.tokens.len(), 60);
        assert!(s.drafted > 0 && s.accepted > 0);
    }

    /// Tentpole acceptance (bit-parity): for randomized session mixes —
    /// prefilling (chunked) and decoding sessions over POOLED decoders,
    /// with a deterministic backpressure schedule forcing deferrals — a
    /// parallel batcher (2–4 step workers) produces exactly what the
    /// serial batcher produces: identical per-session token streams,
    /// drafted/accepted counts, page counts, `cache_host`/`cache_logical`
    /// accounting, quant-job totals, and deferral counts.
    #[test]
    fn prop_parallel_rounds_bit_identical_to_serial() {
        use crate::costmodel::memory::pool_pages_for_request;
        use crate::model::{mock_fb, MOCK_GAMMA_MAX, MOCK_VOCAB};
        use crate::pool::{shared, PoolConfig, SharedSessionManager};
        use crate::util::prop::{check, Config};
        use std::sync::atomic::{AtomicUsize, Ordering};

        const G: usize = 8;
        const D: usize = 2;

        struct RunResult {
            tokens: Vec<(u64, Vec<i32>)>,
            counts: Vec<(u64, u64, u64)>,
            pages_in_use: usize,
            cache_host: usize,
            cache_logical: usize,
            quant_jobs: u64,
            deferrals: u64,
        }

        fn run(seeds: &[u64], workers: usize) -> RunResult {
            let mgr: SharedSessionManager = shared(PoolConfig {
                pages: 512,
                page_tokens: G,
                kv_dim: D,
                high_watermark: 1.0,
                low_watermark: 1.0,
                quant_workers: 2,
                ..PoolConfig::default()
            })
            .unwrap();
            // deterministic backpressure: pressure on 2 of every 5 probes,
            // independent of wall clock or thread timing
            let calls = AtomicUsize::new(0);
            let bp = QuantBackpressure::with_probe(
                Box::new(move || {
                    if calls.fetch_add(1, Ordering::Relaxed) % 5 < 2 {
                        100
                    } else {
                        0
                    }
                }),
                8,
            );
            let mut b = StepBatcher::new(seeds.len().max(1))
                .with_step_workers(workers)
                .with_backpressure(bp);
            let fb = mock_fb(G, MOCK_GAMMA_MAX);
            for (i, &seed) in seeds.iter().enumerate() {
                let id = i as u64 + 1;
                let prompt_len = 17 + (seed % 40) as usize;
                let max_new = 5 + (seed % 25) as usize;
                let gamma = 1 + (seed % 4) as usize;
                let prompt: Vec<i32> =
                    (0..prompt_len).map(|t| ((t as u64 * 7 + seed) % 64) as i32).collect();
                let pages = pool_pages_for_request(prompt_len, max_new, G, fb);
                let cap = (pages - fb.div_ceil(G)) * G;
                assert_eq!(
                    mgr.lock().unwrap().admit(id, pages, false).unwrap(),
                    crate::pool::AdmitOutcome::Admitted
                );
                let dec = Box::new(
                    MockDecoder::with_pool(
                        MOCK_VOCAB,
                        MOCK_GAMMA_MAX,
                        0.2,
                        mgr.clone(),
                        id,
                        cap,
                    )
                    .unwrap(),
                );
                let sampler = Sampler::new(0.0, id);
                // mix: half the sessions prefill chunked (still Prefilling
                // at round 1 -> exercises deferrals), half monolithic
                let s = if seed % 2 == 0 {
                    ActiveSession::admit_chunked(
                        id,
                        dec,
                        sampler,
                        gamma,
                        &prompt,
                        max_new,
                        3 + (seed % 5) as usize,
                    )
                } else {
                    ActiveSession::admit(id, dec, sampler, gamma, &prompt, max_new).unwrap()
                };
                b.admit(s).unwrap();
            }
            b.drain().unwrap();
            assert!(b.failed.is_empty());
            let mut tokens: Vec<(u64, Vec<i32>)> =
                b.finished.iter().map(|s| (s.id, s.tokens.clone())).collect();
            tokens.sort_by_key(|(id, _)| *id);
            let mut counts: Vec<(u64, u64, u64)> =
                b.finished.iter().map(|s| (s.id, s.drafted, s.accepted)).collect();
            counts.sort_by_key(|(id, _, _)| *id);
            let m = mgr.lock().unwrap();
            let rep = m.memory_report();
            let (_, jobs, _) = m.quant_pool_stats();
            RunResult {
                tokens,
                counts,
                pages_in_use: m.pool().pages_in_use(),
                cache_host: rep.cache_host,
                cache_logical: rep.cache_logical,
                quant_jobs: jobs,
                deferrals: b.prefill_deferrals(),
            }
        }

        check::<Vec<u64>, _>(
            Config { cases: 6, size: 6, ..Config::default() },
            |seeds| {
                if seeds.is_empty() {
                    return true;
                }
                let serial = run(seeds, 1);
                for workers in [2usize, 4] {
                    let par = run(seeds, workers);
                    if par.tokens != serial.tokens
                        || par.counts != serial.counts
                        || par.pages_in_use != serial.pages_in_use
                        || par.cache_host != serial.cache_host
                        || par.cache_logical != serial.cache_logical
                        || par.quant_jobs != serial.quant_jobs
                        || par.deferrals != serial.deferrals
                    {
                        return false;
                    }
                }
                true
            },
        );
    }

    /// Property: any admission pattern within capacity completes all
    /// sessions with their exact token budgets, and admissions are either
    /// accepted or rejected cleanly — never lost, never panicking.
    #[test]
    fn prop_batcher_conserves_requests() {
        use crate::util::prop::{check, Config};
        check::<Vec<usize>, _>(
            Config { cases: 20, size: 16, ..Config::default() },
            |sizes| {
                let mut b = StepBatcher::new(4);
                let mut pending: VecDeque<ActiveSession> = sizes
                    .iter()
                    .enumerate()
                    .map(|(i, &m)| {
                        // mix monolithic and chunked admissions
                        if i % 2 == 0 {
                            mock_session(i as u64, m % 24 + 1, 0.25, 3)
                        } else {
                            chunked_session(
                                i as u64,
                                &[1, 2, 3, i as i32],
                                m % 24 + 1,
                                3,
                                m % 3 + 1,
                            )
                        }
                    })
                    .collect();
                let total = pending.len();
                let mut tried_over_capacity = false;
                while !pending.is_empty() || b.active_len() > 0 {
                    while b.has_capacity() && !pending.is_empty() {
                        if b.admit(pending.pop_front().unwrap()).is_err() {
                            return false;
                        }
                    }
                    // over-capacity admission must reject cleanly, not
                    // panic (the rejected probe session is intentionally
                    // discarded — it is not part of `total`)
                    if !tried_over_capacity && !b.has_capacity() {
                        tried_over_capacity = true;
                        if b.admit(mock_session(999, 1, 0.0, 1)).is_ok() {
                            return false;
                        }
                    }
                    if b.round().is_err() {
                        return false;
                    }
                }
                b.finished.len() == total
                    && b.finished.iter().all(|s| s.tokens.len() == s.max_new)
            },
        );
    }
}
