//! Step-level continuous batcher.
//!
//! The router's engine pool runs whole requests; this batcher is the
//! vLLM-style alternative: one engine multiplexes many *active sessions*,
//! interleaving one speculation cycle per session per scheduling round
//! (round-robin). New sessions join between rounds, finished sessions
//! retire immediately — so a long request no longer blocks a short one
//! behind it (head-of-line blocking drops from O(request) to O(cycle)).
//!
//! # Chunked prefill
//!
//! Admission comes in two shapes. [`ActiveSession::admit`] runs the whole
//! prefill up front (the classic path — fine for short prompts, but it
//! holds a round for O(prompt)). [`ActiveSession::admit_chunked`] instead
//! enters the session in a `Prefilling` state carrying the prompt and a
//! cursor; each scheduling round advances exactly ONE
//! `prefill_chunk_tokens` slice through [`crate::model::Decoder::prefill_chunk`],
//! interleaved with other sessions' decode cycles, so admitting a
//! 100k-token prompt costs each round O(chunk), not O(prompt). The final
//! chunk completes the prefill, samples the first token, and flips the
//! session to decoding — chunking is bit-invisible in the output.
//!
//! # Quant-pool backpressure
//!
//! Prefill chunks are the quantization-heavy step (each flushes full
//! G-groups through the process-wide quant pool). When the pool's queue
//! depth exceeds [`QuantBackpressure`]'s soft limit, the batcher defers
//! further prefill chunks for the round — decode cycles keep running —
//! and counts the deferral (locally and, when wired to a
//! [`SharedSessionManager`], into the `/stats` `prefill_deferrals`
//! counter). Deferral never stalls the batcher: it only applies while
//! some session has decode work to run.
//!
//! Works over any `Decoder`, so it is fully tested against the mock; the
//! serving path can opt in by embedding `StepBatcher` directly (see
//! `examples/serve_longcontext`).

use std::collections::VecDeque;

use anyhow::{ensure, Context, Result};

use crate::config::Method;
use crate::model::Decoder;
use crate::pool::SharedSessionManager;
use crate::spec::gamma::{CycleFeedback, FixedGamma, GammaController};
use crate::spec::{Sampler, VerifyOutcome};

/// Where a session is in its lifecycle.
enum Phase {
    /// Prompt processing in flight: `cursor` of `prompt.len()` tokens have
    /// been fed; each batcher round advances one `chunk`-token slice.
    Prefilling { prompt: Vec<i32>, cursor: usize, chunk: usize },
    /// Prefill complete; each round runs one speculation cycle.
    Decoding,
}

/// One multiplexed generation in flight.
pub struct ActiveSession {
    pub id: u64,
    decoder: Box<dyn Decoder>,
    sampler: Sampler,
    gamma_ctl: Box<dyn GammaController>,
    pub tokens: Vec<i32>,
    last: i32,
    pub max_new: usize,
    pub drafted: u64,
    pub accepted: u64,
    phase: Phase,
    // Cycle-persistent buffers (mirroring `SpecEngine::generate`): the
    // drafted-token/logit/verify-window vectors are reused across cycles,
    // so a steady-state step's only allocations are the logits vectors
    // the `Decoder` trait returns by value (pinned by
    // `rust/tests/alloc_hotpath.rs`).
    drafted_buf: Vec<i32>,
    draft_logits_buf: Vec<Vec<f32>>,
    vtokens_buf: Vec<i32>,
}

impl ActiveSession {
    /// Admit a request the classic way: runs the whole prefill immediately
    /// and samples the first token. Holds the caller for O(prompt) — use
    /// [`ActiveSession::admit_chunked`] under a batcher.
    pub fn admit(
        id: u64,
        mut decoder: Box<dyn Decoder>,
        mut sampler: Sampler,
        gamma: usize,
        prompt: &[i32],
        max_new: usize,
    ) -> Result<ActiveSession> {
        let logits = decoder.prefill(prompt)?;
        // a zero budget reports zero tokens: never sample the first token
        let first = (max_new > 0).then(|| sampler.sample(&logits));
        let mut s = Self::new_session(id, decoder, sampler, gamma, max_new, Phase::Decoding);
        if let Some(first) = first {
            s.tokens.push(first);
            s.last = first;
        }
        Ok(s)
    }

    /// Admit a request with NO prefill work done yet: the session enters
    /// `Prefilling` and each [`ActiveSession::step`] (one batcher round)
    /// feeds one `chunk_tokens` slice of the prompt. `chunk_tokens == 0`,
    /// or a decoder without chunk support, falls back to a single chunk
    /// (the whole prompt on the first round — the one-shot path, just
    /// scheduled instead of run at admission).
    pub fn admit_chunked(
        id: u64,
        decoder: Box<dyn Decoder>,
        sampler: Sampler,
        gamma: usize,
        prompt: &[i32],
        max_new: usize,
        chunk_tokens: usize,
    ) -> ActiveSession {
        let chunk = if chunk_tokens == 0 || !decoder.supports_chunked_prefill() {
            prompt.len().max(1)
        } else {
            chunk_tokens
        };
        let phase = Phase::Prefilling { prompt: prompt.to_vec(), cursor: 0, chunk };
        Self::new_session(id, decoder, sampler, gamma, max_new, phase)
    }

    fn new_session(
        id: u64,
        decoder: Box<dyn Decoder>,
        sampler: Sampler,
        gamma: usize,
        max_new: usize,
        phase: Phase,
    ) -> ActiveSession {
        let gcap = gamma.min(decoder.gamma_max()).max(1);
        ActiveSession {
            id,
            decoder,
            sampler,
            gamma_ctl: Box::new(FixedGamma(gamma)),
            // pre-sized: the budget is exact (γ-clamped), so steady-state
            // pushes never reallocate
            tokens: Vec::with_capacity(max_new + 1),
            last: 0,
            max_new,
            drafted: 0,
            accepted: 0,
            phase,
            drafted_buf: Vec::with_capacity(gcap),
            draft_logits_buf: Vec::with_capacity(gcap),
            vtokens_buf: Vec::with_capacity(gcap + 1),
        }
    }

    pub fn with_controller(mut self, ctl: Box<dyn GammaController>) -> Self {
        self.gamma_ctl = ctl;
        self
    }

    /// True while prompt chunks remain to be fed.
    pub fn is_prefilling(&self) -> bool {
        matches!(self.phase, Phase::Prefilling { .. })
    }

    /// (tokens fed, prompt length) while prefilling; None once decoding.
    pub fn prefill_progress(&self) -> Option<(usize, usize)> {
        match &self.phase {
            Phase::Prefilling { prompt, cursor, .. } => Some((*cursor, prompt.len())),
            Phase::Decoding => None,
        }
    }

    /// Prefill chunks still to run (0 once decoding; ≥ 1 while
    /// prefilling — the final, possibly empty, chunk always remains).
    pub fn prefill_chunks_remaining(&self) -> usize {
        match &self.phase {
            Phase::Prefilling { prompt, cursor, chunk } => {
                prompt.len().saturating_sub(*cursor).div_ceil(*chunk).max(1)
            }
            Phase::Decoding => 0,
        }
    }

    pub fn done(&self) -> bool {
        !self.is_prefilling() && self.tokens.len() >= self.max_new
    }

    /// Run ONE unit of work: a prefill chunk while `Prefilling`, else one
    /// speculation cycle (or one AR step); returns tokens added.
    pub fn step(&mut self) -> Result<usize> {
        if self.is_prefilling() {
            return self.step_prefill();
        }
        if self.done() {
            return Ok(0);
        }
        let before = self.tokens.len();
        if self.decoder.method() == Method::Autoregressive {
            let logits = self.decoder.ar_step(self.last)?;
            self.last = self.sampler.sample(&logits);
            self.tokens.push(self.last);
        } else {
            // Clamp γ to the remaining budget (see `SpecEngine::generate`):
            // a cycle reports at most γ + 1 tokens, so γ = remaining − 1
            // can never overshoot — the decoder never commits KV for a
            // token that is not reported. The final cycle runs with γ = 0
            // (verify the feed token alone: an AR step through the verify
            // path, valid on every backend).
            let remaining = self.max_new - self.tokens.len();
            let gamma = self
                .gamma_ctl
                .next_gamma()
                .min(self.decoder.gamma_max())
                .max(1)
                .min(remaining - 1);
            self.decoder.begin_cycle();
            let mut feed = self.last;
            self.drafted_buf.clear();
            self.draft_logits_buf.clear();
            for _ in 0..gamma {
                let q = self.decoder.draft_step(feed)?;
                let g = self.sampler.sample(&q);
                self.drafted_buf.push(g);
                self.draft_logits_buf.push(q);
                feed = g;
            }
            self.vtokens_buf.clear();
            self.vtokens_buf.push(self.last);
            self.vtokens_buf.extend_from_slice(&self.drafted_buf);
            let target = self.decoder.verify(&self.vtokens_buf)?;
            let VerifyOutcome { accepted, next_token } =
                self.sampler
                    .verify(&self.drafted_buf, &self.draft_logits_buf, &target);
            self.decoder.commit(accepted, self.vtokens_buf.len())?;
            for &g in self.drafted_buf.iter().take(accepted) {
                self.tokens.push(g);
            }
            self.tokens.push(next_token);
            self.last = next_token;
            self.drafted += gamma as u64;
            self.accepted += accepted as u64;
            if gamma > 0 {
                self.gamma_ctl.observe(CycleFeedback { gamma, accepted });
            }
        }
        // No truncate: γ-clamping lands exactly on the budget, so reported
        // tokens and committed KV stay in lockstep
        // (`context_len() + 1 == prompt + reported` at exit).
        debug_assert!(self.tokens.len() <= self.max_new);
        Ok(self.tokens.len() - before)
    }

    /// Feed the next prompt chunk; on the final chunk, complete the
    /// prefill and sample the first token (1 token added).
    fn step_prefill(&mut self) -> Result<usize> {
        let (logits, finished) = {
            let Phase::Prefilling { prompt, cursor, chunk } = &mut self.phase else {
                unreachable!("step_prefill outside Prefilling");
            };
            let end = (*cursor + *chunk).min(prompt.len());
            let is_last = end >= prompt.len();
            let logits = self.decoder.prefill_chunk(&prompt[*cursor..end], is_last)?;
            *cursor = end;
            (logits, is_last)
        };
        if !finished {
            return Ok(0);
        }
        self.phase = Phase::Decoding;
        if self.max_new == 0 {
            // zero budget: prefill ran, nothing is sampled or reported
            return Ok(0);
        }
        let logits = logits.context("final prefill chunk must return logits")?;
        let first = self.sampler.sample(&logits);
        self.tokens.push(first);
        self.last = first;
        Ok(1)
    }
}

/// Quant-pool backpressure policy: defer prefill chunks for a round when
/// the shared quantization pool's queue depth exceeds `soft_limit`.
pub struct QuantBackpressure {
    probe: Box<dyn Fn() -> usize + Send>,
    pub soft_limit: usize,
    /// When present, deferrals are also recorded in the session manager so
    /// the router's `/stats` surfaces a `prefill_deferrals` counter.
    sink: Option<SharedSessionManager>,
}

impl QuantBackpressure {
    /// Probe the shared quantization pool of `mgr` and record deferrals
    /// into it (→ `/stats` `prefill_deferrals`). The probe holds a cloned
    /// [`crate::util::threadpool::PoolHandle`], so the per-round depth
    /// read never touches the manager mutex (the KV hot path's lock);
    /// only an actual deferral locks it.
    pub fn for_pool(mgr: SharedSessionManager, soft_limit: usize) -> QuantBackpressure {
        let handle = mgr.lock().unwrap_or_else(|p| p.into_inner()).quant_handle();
        QuantBackpressure {
            probe: Box::new(move || handle.queue_depth()),
            soft_limit,
            sink: Some(mgr),
        }
    }

    /// Custom depth probe (tests; pool-less embeddings). No `/stats` sink.
    pub fn with_probe(
        probe: Box<dyn Fn() -> usize + Send>,
        soft_limit: usize,
    ) -> QuantBackpressure {
        QuantBackpressure { probe, soft_limit, sink: None }
    }

    fn over_limit(&self) -> bool {
        (self.probe)() > self.soft_limit
    }

    /// Record `n` deferred chunks in one manager-lock acquisition (called
    /// at most once per round — never per deferred session).
    fn note_deferrals(&self, n: u64) {
        if let Some(mgr) = &self.sink {
            mgr.lock()
                .unwrap_or_else(|p| p.into_inner())
                .note_prefill_deferrals(n);
        }
    }
}

/// Round-robin scheduler over active sessions with an admission bound.
pub struct StepBatcher {
    pub max_active: usize,
    active: VecDeque<ActiveSession>,
    pub finished: Vec<ActiveSession>,
    rounds: u64,
    backpressure: Option<QuantBackpressure>,
    prefill_deferrals: u64,
}

impl StepBatcher {
    pub fn new(max_active: usize) -> StepBatcher {
        StepBatcher {
            max_active: max_active.max(1),
            active: VecDeque::new(),
            finished: Vec::new(),
            rounds: 0,
            backpressure: None,
            prefill_deferrals: 0,
        }
    }

    /// Enable quant-pool backpressure (see [`QuantBackpressure`]).
    pub fn with_backpressure(mut self, bp: QuantBackpressure) -> StepBatcher {
        self.backpressure = Some(bp);
        self
    }

    pub fn has_capacity(&self) -> bool {
        self.active.len() < self.max_active
    }

    pub fn active_len(&self) -> usize {
        self.active.len()
    }

    /// The currently active sessions, in round-robin order (benches and
    /// embedders read prefill progress / ids through this).
    pub fn active_sessions(&self) -> impl Iterator<Item = &ActiveSession> {
        self.active.iter()
    }

    /// Prefill chunks deferred by backpressure so far.
    pub fn prefill_deferrals(&self) -> u64 {
        self.prefill_deferrals
    }

    /// Admit a session into the round-robin. Errors (instead of aborting
    /// the process) on over-capacity admission: the batcher is embedded in
    /// router/server contexts where a caller bug must surface as a clean
    /// failure, not a panic that takes every in-flight request with it.
    pub fn admit(&mut self, s: ActiveSession) -> Result<()> {
        ensure!(
            self.has_capacity(),
            "admission over capacity: {} sessions active of max {}",
            self.active.len(),
            self.max_active
        );
        self.active.push_back(s);
        Ok(())
    }

    /// One scheduling round: each active session advances one unit of work
    /// (a prefill chunk or a speculation cycle); finished sessions retire.
    /// Under quant-pool backpressure, prefill chunks are deferred for the
    /// round while decode work exists. Returns tokens produced this round.
    pub fn round(&mut self) -> Result<usize> {
        self.rounds += 1;
        // Probe once per round. Deferral only applies while some session
        // has decode work — if every active session is prefilling, chunks
        // proceed regardless, so the batcher always makes progress.
        let has_decode = self.active.iter().any(|s| !s.is_prefilling());
        let defer_prefill =
            has_decode && self.backpressure.as_ref().is_some_and(|bp| bp.over_limit());
        let mut produced = 0;
        let mut deferred = 0u64;
        for _ in 0..self.active.len() {
            let mut s = self.active.pop_front().expect("non-empty");
            if defer_prefill && s.is_prefilling() {
                deferred += 1;
                self.active.push_back(s);
                continue;
            }
            produced += s.step()?;
            if s.done() {
                self.finished.push(s);
            } else {
                self.active.push_back(s);
            }
        }
        if deferred > 0 {
            self.prefill_deferrals += deferred;
            if let Some(bp) = &self.backpressure {
                bp.note_deferrals(deferred);
            }
        }
        Ok(produced)
    }

    /// Drive until everything currently admitted finishes.
    pub fn drain(&mut self) -> Result<()> {
        while !self.active.is_empty() {
            self.round()?;
        }
        Ok(())
    }

    pub fn rounds(&self) -> u64 {
        self.rounds
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::MockDecoder;
    use crate::spec::gamma::AimdGamma;

    fn mock_session(id: u64, max_new: usize, err: f64, gamma: usize) -> ActiveSession {
        let dec = Box::new(MockDecoder::new(64, 7, err));
        ActiveSession::admit(
            id,
            dec,
            Sampler::new(0.0, id),
            gamma,
            &[1, 2, 3, id as i32],
            max_new,
        )
        .unwrap()
    }

    fn chunked_session(
        id: u64,
        prompt: &[i32],
        max_new: usize,
        gamma: usize,
        chunk: usize,
    ) -> ActiveSession {
        let dec = Box::new(MockDecoder::new(64, 7, 0.1));
        let sampler = Sampler::new(0.0, id);
        ActiveSession::admit_chunked(id, dec, sampler, gamma, prompt, max_new, chunk)
    }

    #[test]
    fn single_session_matches_engine_output() {
        // The step batcher must produce exactly what SpecEngine produces.
        let mut b = StepBatcher::new(4);
        b.admit(mock_session(7, 30, 0.2, 4)).unwrap();
        b.drain().unwrap();
        let batched = b.finished.pop().unwrap().tokens;

        let mut dec = MockDecoder::new(64, 7, 0.2);
        let mut eng = crate::spec::SpecEngine::new(4, Sampler::new(0.0, 7));
        let direct = eng.generate(&mut dec, &[1, 2, 3, 7], 30).unwrap().tokens;
        assert_eq!(batched, direct);
    }

    /// Chunked admission is output-invisible: any chunk size produces
    /// exactly the monolithic-admission tokens.
    #[test]
    fn chunked_admission_matches_monolithic() {
        let prompt: Vec<i32> = (0..37).map(|t| (t * 3) % 64).collect();
        let mut b = StepBatcher::new(1);
        let dec = Box::new(MockDecoder::new(64, 7, 0.1));
        let s = ActiveSession::admit(9, dec, Sampler::new(0.0, 9), 4, &prompt, 25).unwrap();
        b.admit(s).unwrap();
        b.drain().unwrap();
        let want = b.finished.pop().unwrap().tokens;
        for chunk in [1usize, 5, 8, 9, 16, 37, 0 /* = one-shot */] {
            let mut b = StepBatcher::new(1);
            b.admit(chunked_session(9, &prompt, 25, 4, chunk)).unwrap();
            b.drain().unwrap();
            let s = b.finished.pop().unwrap();
            assert_eq!(s.tokens, want, "chunk {chunk}");
            assert!(!s.is_prefilling());
        }
    }

    /// Tentpole acceptance: a 4k-token prompt admitted alongside active
    /// decode sessions advances at most `chunk` prefill tokens per round
    /// (no round does O(prompt) prefill work), and a short decode request
    /// admitted at the same time finishes in ~its own number of rounds —
    /// no head-of-line blocking behind the huge prefill.
    #[test]
    fn huge_prefill_interleaves_without_hol_blocking() {
        let chunk = 64usize;
        let long_prompt: Vec<i32> = (0..4096).map(|t| t % 64).collect();
        let mut b = StepBatcher::new(4);
        b.admit(chunked_session(1, &long_prompt, 8, 4, chunk)).unwrap();
        b.admit(mock_session(2, 10, 0.0, 4)).unwrap();
        let mut rounds_to_short = 0;
        let mut last_fed = 0usize;
        while !b.finished.iter().any(|s| s.id == 2) {
            b.round().unwrap();
            rounds_to_short += 1;
            // prefill work this round is bounded by the chunk size
            if let Some(s) = b.active.iter().find(|s| s.id == 1) {
                let (fed, total) = s.prefill_progress().unwrap_or((4096, 4096));
                assert!(fed - last_fed <= chunk, "round fed {} tokens", fed - last_fed);
                assert_eq!(total, 4096);
                last_fed = fed;
            }
            assert!(rounds_to_short < 20, "short request starved by 4k prefill");
        }
        // the long session is still mid-prefill when the short one retires
        let long = b.active.iter().find(|s| s.id == 1).unwrap();
        let (fed, _) = long.prefill_progress().unwrap();
        assert!(fed < 4096, "prefill monopolized rounds: {fed} tokens already fed");
        assert!(long.prefill_chunks_remaining() > 0);
        b.drain().unwrap();
        assert_eq!(b.finished.len(), 2);
        let long = b.finished.iter().find(|s| s.id == 1).unwrap();
        assert_eq!(long.tokens.len(), 8);
    }

    /// Backpressure: with the quant queue over the soft limit, prefill
    /// chunks are deferred (and counted) while decode cycles keep running;
    /// once pressure clears, prefill resumes. A batcher whose sessions are
    /// ALL prefilling ignores the limit (progress guarantee).
    #[test]
    fn backpressure_defers_prefill_but_not_decode() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        use std::sync::Arc;
        let depth = Arc::new(AtomicUsize::new(100));
        let probe_depth = Arc::clone(&depth);
        let mut b = StepBatcher::new(4).with_backpressure(QuantBackpressure::with_probe(
            Box::new(move || probe_depth.load(Ordering::Relaxed)),
            8,
        ));
        let prompt: Vec<i32> = (0..64).collect();
        b.admit(chunked_session(1, &prompt, 6, 2, 16)).unwrap();
        b.admit(mock_session(2, 40, 0.0, 4)).unwrap();
        let decoded_before = {
            let mut produced = 0;
            for _ in 0..3 {
                produced += b.round().unwrap();
            }
            produced
        };
        assert!(decoded_before > 0, "decode cycles kept running");
        assert_eq!(b.prefill_deferrals(), 3, "each round deferred the one prefill");
        let s = b.active.iter().find(|s| s.id == 1).unwrap();
        assert_eq!(s.prefill_progress(), Some((0, 64)), "no prefill ran under pressure");
        // pressure clears -> prefill advances exactly one chunk per round
        depth.store(0, Ordering::Relaxed);
        b.round().unwrap();
        let s = b.active.iter().find(|s| s.id == 1).unwrap();
        assert_eq!(s.prefill_progress(), Some((16, 64)));
        assert_eq!(b.prefill_deferrals(), 3);
        b.drain().unwrap();
        assert_eq!(b.finished.len(), 2);

        // all-prefilling batcher: the soft limit cannot stall it
        let depth = Arc::new(AtomicUsize::new(100));
        let probe_depth = Arc::clone(&depth);
        let mut b = StepBatcher::new(2).with_backpressure(QuantBackpressure::with_probe(
            Box::new(move || probe_depth.load(Ordering::Relaxed)),
            0,
        ));
        b.admit(chunked_session(3, &prompt, 4, 2, 16)).unwrap();
        b.drain().unwrap();
        assert_eq!(b.finished.len(), 1);
        assert_eq!(b.prefill_deferrals(), 0, "no decode work -> no deferral");
    }

    /// `for_pool` wiring: deferrals recorded through the session manager
    /// surface in the pool's `/stats` JSON (and its gauge mirror).
    #[test]
    fn for_pool_backpressure_records_deferrals_in_stats() {
        use crate::pool::{shared, PoolConfig};
        let mgr = shared(PoolConfig { pages: 8, ..PoolConfig::default() }).unwrap();
        let bp = QuantBackpressure::for_pool(mgr.clone(), 3);
        assert!(!bp.over_limit(), "idle quant pool is under any limit");
        bp.note_deferrals(2);
        let m = mgr.lock().unwrap();
        assert_eq!(m.prefill_deferrals(), 2);
        let js = m.stats_json().to_string();
        assert!(js.contains("\"prefill_deferrals\""), "{js}");
    }

    /// Regression (satellite): over-capacity admission is a clean error,
    /// not a process-aborting panic, and the batcher keeps serving.
    #[test]
    fn admit_over_capacity_is_error_not_panic() {
        let mut b = StepBatcher::new(2);
        b.admit(mock_session(1, 8, 0.0, 2)).unwrap();
        b.admit(mock_session(2, 8, 0.0, 2)).unwrap();
        let err = b.admit(mock_session(3, 8, 0.0, 2)).unwrap_err().to_string();
        assert!(err.contains("over capacity"), "got: {err}");
        // existing sessions are unaffected
        b.drain().unwrap();
        assert_eq!(b.finished.len(), 2);
        b.admit(mock_session(3, 8, 0.0, 2)).unwrap();
        b.drain().unwrap();
        assert_eq!(b.finished.len(), 3);
    }

    /// Regression (budget over-commit, batcher loop): committed KV tracks
    /// reported tokens exactly — γ is clamped to the remaining budget, so
    /// at exit `context_len() + 1 == prompt + reported` (the trailing
    /// reported token is the next feed, never yet fed back) and the
    /// report is never truncated after the decoder committed tokens.
    #[test]
    fn committed_context_matches_reported_tokens() {
        for max_new in [1usize, 2, 5, 12, 30] {
            for gamma in [1usize, 3, 7] {
                let prompt = [4, 5, 6];
                let dec = Box::new(MockDecoder::new(64, 7, 0.25));
                let sampler = Sampler::new(0.0, 11);
                let mut s =
                    ActiveSession::admit(11, dec, sampler, gamma, &prompt, max_new).unwrap();
                while !s.done() {
                    s.step().unwrap();
                }
                assert_eq!(s.tokens.len(), max_new);
                assert_eq!(
                    s.decoder.context_len() + 1,
                    prompt.len() + s.tokens.len(),
                    "gamma={gamma} max_new={max_new}"
                );
            }
        }
    }

    /// A zero budget reports zero tokens on both admission paths (the
    /// prefill still runs; the first token is never sampled).
    #[test]
    fn zero_budget_session_reports_zero_tokens() {
        let mut b = StepBatcher::new(2);
        b.admit(mock_session(1, 0, 0.0, 2)).unwrap();
        b.admit(chunked_session(2, &[1, 2, 3, 4, 5], 0, 2, 2)).unwrap();
        b.drain().unwrap();
        assert_eq!(b.finished.len(), 2);
        for s in &b.finished {
            assert!(s.tokens.is_empty(), "id {}", s.id);
        }
    }

    #[test]
    fn interleaves_without_hol_blocking() {
        // A short request admitted alongside a long one must finish in
        // ~its own number of rounds, not after the long one.
        let mut b = StepBatcher::new(4);
        b.admit(mock_session(1, 200, 0.0, 4)).unwrap(); // long
        b.admit(mock_session(2, 10, 0.0, 4)).unwrap(); // short
        let mut rounds_to_short = 0;
        while !b.finished.iter().any(|s| s.id == 2) {
            b.round().unwrap();
            rounds_to_short += 1;
            assert!(rounds_to_short < 20, "short request starved");
        }
        assert!(!b.finished.iter().any(|s| s.id == 1), "long not done yet");
        b.drain().unwrap();
        assert_eq!(b.finished.len(), 2);
    }

    #[test]
    fn all_sessions_complete_exactly() {
        let mut b = StepBatcher::new(8);
        for i in 0..8 {
            b.admit(mock_session(i, 12 + i as usize, 0.3, 3)).unwrap();
        }
        b.drain().unwrap();
        assert_eq!(b.finished.len(), 8);
        for s in &b.finished {
            assert_eq!(s.tokens.len(), s.max_new);
        }
    }

    #[test]
    fn adaptive_gamma_session_runs() {
        let dec = Box::new(MockDecoder::new(64, 7, 0.15));
        let s = ActiveSession::admit(9, dec, Sampler::new(0.0, 9), 2, &[5, 6], 60)
            .unwrap()
            .with_controller(Box::new(AimdGamma::new(2, 1, 7)));
        let mut b = StepBatcher::new(1);
        b.admit(s).unwrap();
        b.drain().unwrap();
        let s = b.finished.pop().unwrap();
        assert_eq!(s.tokens.len(), 60);
        assert!(s.drafted > 0 && s.accepted > 0);
    }

    /// Property: any admission pattern within capacity completes all
    /// sessions with their exact token budgets, and admissions are either
    /// accepted or rejected cleanly — never lost, never panicking.
    #[test]
    fn prop_batcher_conserves_requests() {
        use crate::util::prop::{check, Config};
        check::<Vec<usize>, _>(
            Config { cases: 20, size: 16, ..Config::default() },
            |sizes| {
                let mut b = StepBatcher::new(4);
                let mut pending: VecDeque<ActiveSession> = sizes
                    .iter()
                    .enumerate()
                    .map(|(i, &m)| {
                        // mix monolithic and chunked admissions
                        if i % 2 == 0 {
                            mock_session(i as u64, m % 24 + 1, 0.25, 3)
                        } else {
                            chunked_session(
                                i as u64,
                                &[1, 2, 3, i as i32],
                                m % 24 + 1,
                                3,
                                m % 3 + 1,
                            )
                        }
                    })
                    .collect();
                let total = pending.len();
                let mut tried_over_capacity = false;
                while !pending.is_empty() || b.active_len() > 0 {
                    while b.has_capacity() && !pending.is_empty() {
                        if b.admit(pending.pop_front().unwrap()).is_err() {
                            return false;
                        }
                    }
                    // over-capacity admission must reject cleanly, not
                    // panic (the rejected probe session is intentionally
                    // discarded — it is not part of `total`)
                    if !tried_over_capacity && !b.has_capacity() {
                        tried_over_capacity = true;
                        if b.admit(mock_session(999, 1, 0.0, 1)).is_ok() {
                            return false;
                        }
                    }
                    if b.round().is_err() {
                        return false;
                    }
                }
                b.finished.len() == total
                    && b.finished.iter().all(|s| s.tokens.len() == s.max_new)
            },
        );
    }
}
