//! Step-level continuous batcher.
//!
//! The router's engine pool runs whole requests; this batcher is the
//! vLLM-style alternative: one engine multiplexes many *active sessions*,
//! interleaving one speculation cycle per session per scheduling round
//! (round-robin). New sessions join between rounds (prefill is admitted
//! when a slot frees), finished sessions retire immediately — so a long
//! request no longer blocks a short one behind it (head-of-line blocking
//! drops from O(request) to O(cycle)).
//!
//! Works over any `Decoder`, so it is fully tested against the mock; the
//! serving path can opt in via `ServeConfig::engines == 0` semantics or by
//! embedding `StepBatcher` directly (see `examples/serve_longcontext`).

use std::collections::VecDeque;

use anyhow::Result;

use crate::config::Method;
use crate::model::Decoder;
use crate::spec::gamma::{CycleFeedback, FixedGamma, GammaController};
use crate::spec::{Sampler, VerifyOutcome};

/// One multiplexed generation in flight.
pub struct ActiveSession {
    pub id: u64,
    decoder: Box<dyn Decoder>,
    sampler: Sampler,
    gamma_ctl: Box<dyn GammaController>,
    pub tokens: Vec<i32>,
    last: i32,
    pub max_new: usize,
    pub drafted: u64,
    pub accepted: u64,
}

impl ActiveSession {
    /// Admit a request: runs the prefill and samples the first token.
    pub fn admit(
        id: u64,
        mut decoder: Box<dyn Decoder>,
        mut sampler: Sampler,
        gamma: usize,
        prompt: &[i32],
        max_new: usize,
    ) -> Result<ActiveSession> {
        let logits = decoder.prefill(prompt)?;
        let first = sampler.sample(&logits);
        Ok(ActiveSession {
            id,
            decoder,
            sampler,
            gamma_ctl: Box::new(FixedGamma(gamma)),
            tokens: vec![first],
            last: first,
            max_new,
            drafted: 0,
            accepted: 0,
        })
    }

    pub fn with_controller(mut self, ctl: Box<dyn GammaController>) -> Self {
        self.gamma_ctl = ctl;
        self
    }

    pub fn done(&self) -> bool {
        self.tokens.len() >= self.max_new
    }

    /// Run ONE speculation cycle (or one AR step); returns tokens added.
    pub fn step(&mut self) -> Result<usize> {
        if self.done() {
            return Ok(0);
        }
        let before = self.tokens.len();
        if self.decoder.method() == Method::Autoregressive {
            let logits = self.decoder.ar_step(self.last)?;
            self.last = self.sampler.sample(&logits);
            self.tokens.push(self.last);
        } else {
            let gamma = self
                .gamma_ctl
                .next_gamma()
                .min(self.decoder.gamma_max())
                .max(1);
            self.decoder.begin_cycle();
            let mut feed = self.last;
            let mut drafted = Vec::with_capacity(gamma);
            let mut draft_logits = Vec::with_capacity(gamma);
            for _ in 0..gamma {
                let q = self.decoder.draft_step(feed)?;
                let g = self.sampler.sample(&q);
                drafted.push(g);
                draft_logits.push(q);
                feed = g;
            }
            let mut vtokens = vec![self.last];
            vtokens.extend(&drafted);
            let target = self.decoder.verify(&vtokens)?;
            let VerifyOutcome { accepted, next_token } =
                self.sampler.verify(&drafted, &draft_logits, &target);
            self.decoder.commit(accepted, vtokens.len())?;
            for &g in drafted.iter().take(accepted) {
                self.tokens.push(g);
            }
            self.tokens.push(next_token);
            self.last = next_token;
            self.drafted += gamma as u64;
            self.accepted += accepted as u64;
            self.gamma_ctl.observe(CycleFeedback { gamma, accepted });
        }
        self.tokens.truncate(self.max_new);
        Ok(self.tokens.len() - before)
    }
}

/// Round-robin scheduler over active sessions with an admission bound.
pub struct StepBatcher {
    pub max_active: usize,
    active: VecDeque<ActiveSession>,
    pub finished: Vec<ActiveSession>,
    rounds: u64,
}

impl StepBatcher {
    pub fn new(max_active: usize) -> StepBatcher {
        StepBatcher {
            max_active: max_active.max(1),
            active: VecDeque::new(),
            finished: Vec::new(),
            rounds: 0,
        }
    }

    pub fn has_capacity(&self) -> bool {
        self.active.len() < self.max_active
    }

    pub fn active_len(&self) -> usize {
        self.active.len()
    }

    pub fn admit(&mut self, s: ActiveSession) {
        assert!(self.has_capacity(), "admission over capacity");
        self.active.push_back(s);
    }

    /// One scheduling round: each active session advances one cycle;
    /// finished sessions retire. Returns tokens produced this round.
    pub fn round(&mut self) -> Result<usize> {
        self.rounds += 1;
        let mut produced = 0;
        for _ in 0..self.active.len() {
            let mut s = self.active.pop_front().expect("non-empty");
            produced += s.step()?;
            if s.done() {
                self.finished.push(s);
            } else {
                self.active.push_back(s);
            }
        }
        Ok(produced)
    }

    /// Drive until everything currently admitted finishes.
    pub fn drain(&mut self) -> Result<()> {
        while !self.active.is_empty() {
            self.round()?;
        }
        Ok(())
    }

    pub fn rounds(&self) -> u64 {
        self.rounds
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::MockDecoder;
    use crate::spec::gamma::AimdGamma;

    fn mock_session(id: u64, max_new: usize, err: f64, gamma: usize) -> ActiveSession {
        let dec = Box::new(MockDecoder::new(64, 7, err));
        ActiveSession::admit(
            id,
            dec,
            Sampler::new(0.0, id),
            gamma,
            &[1, 2, 3, id as i32],
            max_new,
        )
        .unwrap()
    }

    #[test]
    fn single_session_matches_engine_output() {
        // The step batcher must produce exactly what SpecEngine produces.
        let mut b = StepBatcher::new(4);
        b.admit(mock_session(7, 30, 0.2, 4));
        b.drain().unwrap();
        let batched = b.finished.pop().unwrap().tokens;

        let mut dec = MockDecoder::new(64, 7, 0.2);
        let mut eng = crate::spec::SpecEngine::new(4, Sampler::new(0.0, 7));
        let direct = eng.generate(&mut dec, &[1, 2, 3, 7], 30).unwrap().tokens;
        assert_eq!(batched, direct);
    }

    #[test]
    fn interleaves_without_hol_blocking() {
        // A short request admitted alongside a long one must finish in
        // ~its own number of rounds, not after the long one.
        let mut b = StepBatcher::new(4);
        b.admit(mock_session(1, 200, 0.0, 4)); // long
        b.admit(mock_session(2, 10, 0.0, 4)); // short
        let mut rounds_to_short = 0;
        while !b.finished.iter().any(|s| s.id == 2) {
            b.round().unwrap();
            rounds_to_short += 1;
            assert!(rounds_to_short < 20, "short request starved");
        }
        assert!(!b.finished.iter().any(|s| s.id == 1), "long not done yet");
        b.drain().unwrap();
        assert_eq!(b.finished.len(), 2);
    }

    #[test]
    fn all_sessions_complete_exactly() {
        let mut b = StepBatcher::new(8);
        for i in 0..8 {
            b.admit(mock_session(i, 12 + i as usize, 0.3, 3));
        }
        b.drain().unwrap();
        assert_eq!(b.finished.len(), 8);
        for s in &b.finished {
            assert_eq!(s.tokens.len(), s.max_new);
        }
    }

    #[test]
    fn adaptive_gamma_session_runs() {
        let dec = Box::new(MockDecoder::new(64, 7, 0.15));
        let s = ActiveSession::admit(9, dec, Sampler::new(0.0, 9), 2, &[5, 6], 60)
            .unwrap()
            .with_controller(Box::new(AimdGamma::new(2, 1, 7)));
        let mut b = StepBatcher::new(1);
        b.admit(s);
        b.drain().unwrap();
        let s = b.finished.pop().unwrap();
        assert_eq!(s.tokens.len(), 60);
        assert!(s.drafted > 0 && s.accepted > 0);
    }

    /// Property: any admission pattern within capacity completes all
    /// sessions with their exact token budgets.
    #[test]
    fn prop_batcher_conserves_requests() {
        use crate::util::prop::{check, Config};
        check::<Vec<usize>, _>(
            Config { cases: 20, size: 16, ..Config::default() },
            |sizes| {
                let mut b = StepBatcher::new(4);
                let mut pending: VecDeque<ActiveSession> = sizes
                    .iter()
                    .enumerate()
                    .map(|(i, &m)| mock_session(i as u64, m % 24 + 1, 0.25, 3))
                    .collect();
                let total = pending.len();
                while !pending.is_empty() || b.active_len() > 0 {
                    while b.has_capacity() && !pending.is_empty() {
                        b.admit(pending.pop_front().unwrap());
                    }
                    if b.round().is_err() {
                        return false;
                    }
                }
                b.finished.len() == total
                    && b.finished.iter().all(|s| s.tokens.len() == s.max_new)
            },
        );
    }
}
