//! Unified cross-engine scheduler: ONE work-stealing step pool, global
//! continuous batching, fair SLO-aware admission.
//!
//! Replaces the per-engine serving threads (`qs-engine-{N}`, each with its
//! own FIFO `ThreadPool` and private `StepBatcher`) with a single driver
//! thread (`qs-sched-drive`) owning ONE global [`StepBatcher`] sized
//! `engines × batcher_slots`, fanned over ONE process-wide work-stealing
//! pool (`qs-sched-{i}`, `engines × step_workers` threads). Every round is
//! formed across *all* engines' sessions: any free step worker takes any
//! runnable session, chunked-prefill and decode steps interleave
//! fleet-wide, and idle workers steal queued steps off loaded peers'
//! deques (`sched_steals` counts the thefts). Per-request outputs stay
//! bit-identical to the serial path — stealing reorders *execution*, never
//! results (each outcome lands in its slot; see `StepBatcher::round`).
//!
//! Admission stops being pure FIFO. The [`FairQueue`] runs per-tenant
//! deficit-round-robin (weights from `cfg.fair_weights`, default 1): a
//! tenant with weight `w` is offered `w` pops per cursor visit, so between
//! two consecutive requests of a backlogged tenant at most
//! `Σ other tenants' weights` foreign requests are served — no tenant
//! starves under adversarial bursts (property-tested below). Per-tenant
//! token-bucket rate limits (`tenant_rate_limit` req/s, burst = one
//! second's worth) shed excess arrivals at submit. Within a tenant, order
//! stays FIFO, and the WFQ-chosen head keeps the head-of-line pool
//! admission semantics of the old engine loop: a large-but-admissible head
//! waits for page releases while already-admitted sessions keep decoding.
//!
//! SLO enforcement: a request may carry a deadline (per-request
//! `deadline_ms` or the `request_deadline_ms` default). Expiry is enforced
//! at the two scheduling points — when the request surfaces as the
//! WFQ-chosen head (rejected before any pool pages are booked) and after
//! every round for active sessions (evicted mid-flight). Cancellation
//! ([`super::router::Coordinator::cancel`]) removes queued requests
//! immediately and marks active ones for eviction at the next round
//! boundary. Both paths run the ONE release sequence (drop session →
//! release pages → refresh gauges → `notify_all`), so admission waiters
//! parked on a saturated pool wake the moment a cancelled or expired
//! session frees its pages.

use std::collections::{HashMap, HashSet, VecDeque};
use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use crate::config::ServeConfig;
use crate::coordinator::batcher::{ActiveSession, QuantBackpressure, StepBatcher};
use crate::coordinator::router::{
    build_session, pool_plan, sync_pool_gauges, RequestSpec, ResponseOut, Shared,
    TOO_LARGE_PREFIX,
};
use crate::metrics::{names, Histogram, Registry};
use crate::pool::{AdmitOutcome, SharedSessionManager};
use crate::stream::{SinkClosed, StreamEvent, TokenSink};
use crate::trace::{self, PhaseEvent, Tracer};
use crate::util::fault::FaultInjector;
use crate::util::now_secs;
use crate::util::threadpool::StealPool;

use super::router::EngineBackend;

/// Marker prefix for a request terminated by client cancellation; the HTTP
/// layer maps it to 499 (client closed request).
pub const CANCELLED_PREFIX: &str = "cancelled: ";

/// Marker prefix for a request that blew its deadline (queued or
/// mid-flight); the HTTP layer maps it to 504.
pub const DEADLINE_PREFIX: &str = "deadline: ";

/// Marker prefix for a streaming request shed because its consumer
/// stopped draining a bounded sink; the HTTP layer maps it to 503.
pub const SHED_PREFIX: &str = "shed: ";

/// Serving-path lock recovery: a poisoned lock means some thread panicked
/// while holding it — the panic itself is contained elsewhere (step
/// workers catch unwinds; HTTP workers are per-connection), and every
/// structure behind these locks is kept consistent by its own methods, so
/// the serving path keeps going instead of cascading the abort.
pub(crate) fn lock_ok<T>(m: &std::sync::Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// One queued generation request, tagged with its tenant and deadline.
#[derive(Debug)]
pub(crate) struct Queued {
    pub(crate) spec: RequestSpec,
    pub(crate) tenant: String,
    pub(crate) enqueued_at: f64,
    /// Absolute expiry; None = no deadline.
    pub(crate) deadline: Option<Instant>,
    pub(crate) done: mpsc::Sender<Result<ResponseOut, String>>,
}

/// One tenant's FIFO lane inside the fair queue.
struct Lane {
    tenant: String,
    weight: u64,
    queue: VecDeque<Queued>,
    /// Token bucket (only consulted when a rate limit is configured).
    tokens: f64,
    refilled_at: Instant,
}

/// Per-tenant weighted fair queue (deficit round robin) with token-bucket
/// rate limits and cancellation marks.
///
/// DRR with unit request cost: the cursor visits non-empty lanes in
/// round-robin order; arriving at a lane grants it `weight` pops before
/// the cursor moves on. `peek`/`pop` both route through the same
/// deterministic `select`, so the engine-loop pattern of "peek head,
/// decide admission under the lock, then pop the same head" carries over
/// unchanged from the FIFO queue.
pub(crate) struct FairQueue {
    lanes: Vec<Lane>,
    max_tenants: usize,
    /// Requests/second/tenant; 0 = unlimited.
    rate_limit: usize,
    weights: Vec<(String, u64)>,
    /// Lane holding the current DRR grant (None on a cold queue).
    current: Option<usize>,
    quantum_left: u64,
    len: usize,
    /// Cancel marks for ids not found queued (presumed active); drained by
    /// the scheduler each iteration and applied against live sessions.
    marks: HashSet<u64>,
}

impl FairQueue {
    pub(crate) fn new(cfg: &ServeConfig) -> FairQueue {
        Self::with_params(cfg.sched_tenants, cfg.tenant_rate_limit, cfg.fair_weights.clone())
    }

    pub(crate) fn with_params(
        max_tenants: usize,
        rate_limit: usize,
        weights: Vec<(String, u64)>,
    ) -> FairQueue {
        FairQueue {
            lanes: Vec::new(),
            max_tenants: max_tenants.max(1),
            rate_limit,
            weights,
            current: None,
            quantum_left: 0,
            len: 0,
            marks: HashSet::new(),
        }
    }

    pub(crate) fn len(&self) -> usize {
        self.len
    }

    pub(crate) fn is_empty(&self) -> bool {
        self.len == 0
    }

    fn burst(&self) -> f64 {
        self.rate_limit.max(1) as f64
    }

    /// Enqueue under the tenant's lane. Sheds (returning the job and a
    /// reason) when the tenant's token bucket is dry or when the lane
    /// table is full of *backlogged* tenants (idle lanes are reclaimed
    /// first, so `max_tenants` caps concurrent tenants, not lifetime ones).
    pub(crate) fn push(&mut self, job: Queued) -> Result<(), (Queued, &'static str)> {
        let idx = match self.lanes.iter().position(|l| l.tenant == job.tenant) {
            Some(i) => i,
            None => {
                if self.lanes.len() >= self.max_tenants {
                    match self.lanes.iter().position(|l| l.queue.is_empty()) {
                        Some(i) => self.remove_lane(i),
                        None => return Err((job, "tenant limit")),
                    }
                }
                let weight = self
                    .weights
                    .iter()
                    .find(|(t, _)| *t == job.tenant)
                    .map_or(1, |(_, w)| *w)
                    .max(1);
                self.lanes.push(Lane {
                    tenant: job.tenant.clone(),
                    weight,
                    queue: VecDeque::new(),
                    tokens: self.burst(),
                    refilled_at: Instant::now(),
                });
                self.lanes.len() - 1
            }
        };
        if self.rate_limit > 0 {
            let burst = self.burst();
            let lane = &mut self.lanes[idx];
            let now = Instant::now();
            let dt = now.duration_since(lane.refilled_at).as_secs_f64();
            lane.refilled_at = now;
            lane.tokens = (lane.tokens + dt * self.rate_limit as f64).min(burst);
            if lane.tokens < 1.0 {
                return Err((job, "rate limited"));
            }
            lane.tokens -= 1.0;
        }
        self.lanes[idx].queue.push_back(job);
        self.len += 1;
        Ok(())
    }

    /// Remove an (empty) lane, keeping the DRR grant pointing at the same
    /// logical lane. Only ever called on idle lanes, so forfeiting a stale
    /// grant's quantum cannot perturb a backlogged tenant's share.
    fn remove_lane(&mut self, i: usize) {
        self.lanes.remove(i);
        match self.current {
            Some(c) if c == i => {
                self.current = i.checked_sub(1);
                self.quantum_left = 0;
            }
            Some(c) if c > i => self.current = Some(c - 1),
            _ => {}
        }
    }

    /// DRR head selection. Deterministic between mutations: consecutive
    /// calls pick the same lane until a pop exhausts its quantum (or the
    /// lane drains, forfeiting the rest of the quantum). A new grant goes
    /// to the first non-empty lane after the last granted one — lane 0
    /// first on a cold queue.
    fn select(&mut self) -> Option<usize> {
        if self.len == 0 {
            return None;
        }
        if let Some(i) = self.current {
            if self.quantum_left > 0 && !self.lanes[i].queue.is_empty() {
                return Some(i);
            }
        }
        let n = self.lanes.len();
        let start = self.current.map_or(0, |i| i + 1);
        for k in 0..n {
            let i = (start + k) % n;
            if !self.lanes[i].queue.is_empty() {
                self.current = Some(i);
                self.quantum_left = self.lanes[i].weight;
                return Some(i);
            }
        }
        None
    }

    /// The WFQ-chosen head (the request `pop` would return).
    pub(crate) fn peek(&mut self) -> Option<&Queued> {
        let i = self.select()?;
        self.lanes[i].queue.front()
    }

    pub(crate) fn pop(&mut self) -> Option<Queued> {
        let i = self.select()?;
        let job = self.lanes[i].queue.pop_front()?;
        self.quantum_left -= 1;
        self.len -= 1;
        Some(job)
    }

    /// Cancel by id: a queued request is removed and returned (the caller
    /// responds to it); an unknown id is marked for the scheduler's active
    /// sweep. Marks for already-completed ids are dropped at the next
    /// drain, so the set cannot grow unbounded.
    pub(crate) fn cancel(&mut self, id: u64) -> Option<Queued> {
        for lane in &mut self.lanes {
            if let Some(pos) = lane.queue.iter().position(|j| j.spec.id == id) {
                self.len -= 1;
                return lane.queue.remove(pos);
            }
        }
        self.marks.insert(id);
        None
    }

    fn drain_marks(&mut self) -> Vec<u64> {
        self.marks.drain().collect()
    }

    /// (tenant, queued requests) per lane, for the per-tenant depth gauges.
    pub(crate) fn tenant_depths(&self) -> Vec<(String, usize)> {
        self.lanes.iter().map(|l| (l.tenant.clone(), l.queue.len())).collect()
    }
}

/// Outcome of head-of-line admission, decided while holding the queue lock.
enum Admission {
    Run,
    Reject(String),
}

/// Per-session serving metadata while the session lives in the batcher.
struct Inflight {
    done: mpsc::Sender<Result<ResponseOut, String>>,
    queue_secs: f64,
    admitted_at: Instant,
    /// Set the first time the session is observed past its prefill phase.
    prefill_done_at: Option<Instant>,
    bucket: usize,
    /// Absolute expiry checked after every round; None = no deadline.
    deadline: Option<Instant>,
    /// This request's span buffer (None when tracing is disabled); finished
    /// into the flight recorder at retirement.
    trace: Option<Arc<crate::trace::TraceBuf>>,
    /// Incremental response stream (None = buffered-only request).
    stream: Option<StreamState>,
}

/// Flush cursor for one streaming session: how much of the session's
/// committed `tokens` has already been pushed into the sink, the next
/// flush cycle index, and the timing state behind the `ttft_us` /
/// `inter_token_gap_us` histograms. One batcher round advances a session
/// by at most one unit (prefill chunk or verify cycle), so a round-boundary
/// flush of `tokens[flushed..]` emits exactly one `Token` event per cycle.
struct StreamState {
    sink: TokenSink,
    /// Prompt length reported in the one-shot `Prefilled` event.
    prompt_tokens: usize,
    flushed: usize,
    cycle: usize,
    last_flush: Option<Instant>,
    prefilled_sent: bool,
}

impl StreamState {
    /// Mirror a terminal failure onto the stream so a streaming consumer
    /// never blocks on a request the buffered channel already failed.
    fn send_error(&self, msg: &str) {
        let _ = self.sink.send(StreamEvent::Error { message: msg.to_string() });
    }
}

/// `StreamState::send_error` for requests that never became inflight
/// (rejected, expired, or failed at session build).
fn send_sink_error(sink: &Option<TokenSink>, msg: &str) {
    if let Some(s) = sink {
        let _ = s.send(StreamEvent::Error { message: msg.to_string() });
    }
}

/// The unified scheduler driver: one thread forming global rounds across
/// all engines' sessions. See the module docs for the full picture; the
/// loop structure is the old engine loop's (admission under the queue lock
/// → build sessions outside it → one round → retire), with three
/// additions: WFQ head selection, the cancellation sweep, and the deadline
/// sweep.
pub(crate) fn scheduler_loop(
    cfg: ServeConfig,
    shared: Arc<Shared>,
    metrics: Arc<Registry>,
    tracer: Arc<Tracer>,
    backend: Arc<EngineBackend>,
    pool: Option<SharedSessionManager>,
    fault: Option<Arc<FaultInjector>>,
) {
    let engines = cfg.engines.max(1);
    let pool_threads = engines * cfg.step_workers;
    // One process-wide stealing pool, sized to the fleet's configured step
    // budget (a pool of 1 would only add hand-off latency: serial rounds
    // step inline instead).
    let step_pool = (pool_threads >= 2).then(|| StealPool::named(pool_threads, "qs-sched"));
    let mut batcher = StepBatcher::new(engines * cfg.batcher_slots.max(1));
    if let Some(p) = &step_pool {
        batcher = batcher.with_shared_step_pool(p.handle());
    }
    if let Some(mgr) = &pool {
        batcher = batcher
            .with_backpressure(QuantBackpressure::for_pool(
                mgr.clone(),
                cfg.quant_queue_soft_limit,
            ))
            .with_stats_sink(mgr.clone());
    }
    if let Some(inj) = &fault {
        batcher = batcher.with_fault_injector(Arc::clone(inj));
    }
    let mut inflight: HashMap<u64, Inflight> = HashMap::new();
    // Hot-loop gauges are pre-resolved to atomic handles once; the dynamic
    // per-tenant depth gauges are resolved lazily and cached.
    let depth_gauge = metrics.gauge_handle(names::SCHED_BATCHER_DEPTH);
    let queue_gauge = metrics.gauge_handle(names::SCHED_QUEUE_DEPTH);
    let steals_gauge = metrics.gauge_handle(names::SCHED_STEALS);
    // Streaming latency histograms are recorded live at flush time (they
    // must exist even with tracing disabled), resolved once for the loop.
    let ttft_hist = metrics.histogram(names::TTFT_US);
    let gap_hist = metrics.histogram(names::INTER_TOKEN_GAP_US);
    let mut tenant_gauges: HashMap<String, Arc<crate::metrics::Gauge>> = HashMap::new();
    metrics.set_gauge(
        names::SCHED_POOL_WORKERS,
        step_pool.as_ref().map_or(1, |p| p.size()) as f64,
    );
    let round_gauges = pool.is_none().then(|| {
        (
            metrics.gauge_handle(names::STEP_WORKERS),
            metrics.gauge_handle(names::STEP_WORKERS_BUSY),
            metrics.gauge_handle(names::ROUND_SPAN_US),
        )
    });
    // Head-of-line admission wait: set when the WFQ head first sees
    // `Saturated`, drained into its trace when it finally pops.
    let mut admission_wait: Option<(u64, Instant)> = None;
    loop {
        let stopping = shared.stop.load(Ordering::Relaxed);
        // ---- admission: pull admissible WFQ heads into free slots -------
        let mut popped: Vec<(Queued, u64)> = Vec::new();
        let mut rejected: Vec<(Queued, String)> = Vec::new();
        let mut expired: Vec<Queued> = Vec::new();
        if !stopping {
            let mut q = lock_ok(&shared.queue);
            loop {
                if shared.stop.load(Ordering::Relaxed) {
                    break;
                }
                if batcher.active_len() + popped.len() >= batcher.max_active {
                    break;
                }
                let head = q.peek().map(|j| {
                    (j.spec.id, j.spec.prompt.len(), j.spec.max_new_tokens, j.deadline)
                });
                let Some((id, prompt_len, max_new, deadline)) = head else {
                    if batcher.active_len() + popped.len() == 0 {
                        // fully idle: park until work (or stop) arrives
                        q = shared
                            .cv
                            .wait(q)
                            .unwrap_or_else(std::sync::PoisonError::into_inner);
                        continue;
                    }
                    break; // keep stepping the sessions we already have
                };
                // Deadline expired while queued: reject before any pool
                // pages are booked (also unblocks a saturated-head wait).
                if deadline.is_some_and(|d| Instant::now() >= d) {
                    if admission_wait.is_some_and(|(aid, _)| aid == id) {
                        admission_wait = None;
                    }
                    expired.push(q.pop().expect("peeked head"));
                    continue;
                }
                let decision = match &pool {
                    None => Admission::Run,
                    Some(mgr) => {
                        let plan = pool_plan(&cfg, prompt_len, max_new);
                        match lock_ok(mgr).admit(id, plan.pages, false) {
                            Ok(AdmitOutcome::Admitted) => Admission::Run,
                            Ok(AdmitOutcome::TooLarge) => {
                                metrics.incr("requests_rejected_too_large", 1);
                                Admission::Reject(format!(
                                    "{TOO_LARGE_PREFIX}request needs {} KV \
                                     pages, over the pool's admission ceiling \
                                     (no OOM: rejected up front)",
                                    plan.pages
                                ))
                            }
                            Ok(AdmitOutcome::Saturated) => {
                                if admission_wait.map_or(true, |(aid, _)| aid != id) {
                                    admission_wait = Some((id, Instant::now()));
                                }
                                if batcher.active_len() + popped.len() == 0 {
                                    // Nothing to step: wait (bounded) for a
                                    // release. Counter counts 5 ms polls.
                                    metrics.incr("pool_admission_wait_polls", 1);
                                    q = shared
                                        .cv
                                        .wait_timeout(q, Duration::from_millis(5))
                                        .unwrap_or_else(std::sync::PoisonError::into_inner)
                                        .0;
                                    continue;
                                }
                                // Active sessions exist: keep decoding;
                                // their releases will free pages.
                                break;
                            }
                            Err(e) => Admission::Reject(format!("{e:#}")),
                        }
                    }
                };
                let job = q.pop().expect("peeked head");
                // If this head waited out a saturated pool, charge the wait.
                let admission_us = match admission_wait {
                    Some((aid, t0)) if aid == id => {
                        admission_wait = None;
                        t0.elapsed().as_micros() as u64
                    }
                    _ => 0,
                };
                match decision {
                    Admission::Run => popped.push((job, admission_us)),
                    Admission::Reject(msg) => rejected.push((job, msg)),
                }
            }
        }
        if stopping && batcher.active_len() == 0 {
            return; // in-flight work drained; still-queued jobs fail at drop
        }
        for (job, msg) in rejected {
            metrics.incr("requests_failed", 1);
            send_sink_error(&job.spec.sink, &msg);
            let _ = job.done.send(Err(msg));
        }
        for job in expired {
            metrics.incr("requests_deadline_rejected", 1);
            let waited_ms = ((now_secs() - job.enqueued_at) * 1e3) as u64;
            let msg = format!(
                "{DEADLINE_PREFIX}request {} expired after {waited_ms}ms in queue",
                job.spec.id
            );
            send_sink_error(&job.spec.sink, &msg);
            let _ = job.done.send(Err(msg));
        }
        // ---- build sessions (outside the queue lock) --------------------
        for (mut job, admission_us) in popped {
            // The sink leaves the spec before the session is built: the
            // scheduler owns flushing from here on (as part of `Inflight`),
            // and a build failure must still reach a streaming consumer.
            let sink = job.spec.sink.take();
            let queue_secs = now_secs() - job.enqueued_at;
            metrics.histogram("queue_wait").record_secs(queue_secs);
            // Open the request's timeline: total queue time split into the
            // fair-queue wait and the saturated-pool admission wait (the
            // two sum to `queue_secs`, so the timeline never double-counts).
            let buf = tracer.new_request();
            if let Some(b) = &buf {
                let queue_us = ((queue_secs * 1e6) as u64).saturating_sub(admission_us);
                b.record(PhaseEvent::QueueWait { us: queue_us });
                b.record(PhaseEvent::AdmissionWait { us: admission_us });
            }
            match build_session(&cfg, &backend, &job.spec, pool.as_ref()) {
                Ok((sess, bucket)) => {
                    let sess = match &buf {
                        Some(b) => sess.with_trace(Arc::clone(b)),
                        None => sess,
                    };
                    let id = sess.id;
                    batcher.admit(sess).expect("slot was counted during admission");
                    inflight.insert(
                        id,
                        Inflight {
                            done: job.done,
                            queue_secs,
                            admitted_at: Instant::now(),
                            prefill_done_at: None,
                            bucket,
                            deadline: job.deadline,
                            trace: buf,
                            stream: sink.map(|sink| StreamState {
                                sink,
                                prompt_tokens: job.spec.prompt.len(),
                                flushed: 0,
                                cycle: 0,
                                last_flush: None,
                                prefilled_sent: false,
                            }),
                        },
                    );
                }
                Err(e) => {
                    release_pool_session(pool.as_ref(), &shared, &metrics, job.spec.id);
                    metrics.incr("requests_failed", 1);
                    let msg = format!("{e:#}");
                    send_sink_error(&sink, &msg);
                    let _ = job.done.send(Err(msg));
                }
            }
        }
        // ---- cancellation sweep -----------------------------------------
        // Drained AFTER session build: a mark set while a request is being
        // admitted lands here on the next iteration, when the session is
        // already active — no cancel can fall through the pop→admit window.
        let marks = lock_ok(&shared.queue).drain_marks();
        for id in marks {
            let Some(sess) = batcher.remove(id) else { continue };
            let inf = inflight.remove(&id).expect("active sessions are tracked");
            drop(sess); // decoder resources go before the pool release
            if let Some(mgr) = &pool {
                lock_ok(mgr).note_cancellation();
            }
            release_pool_session(pool.as_ref(), &shared, &metrics, id);
            metrics.incr("requests_cancelled", 1);
            finish_aborted(&inf, &tracer, &metrics, id, true);
            let msg = format!("{CANCELLED_PREFIX}request {id} cancelled by client");
            if let Some(st) = &inf.stream {
                st.send_error(&msg);
            }
            let _ = inf.done.send(Err(msg));
        }
        // ---- one scheduling round ---------------------------------------
        if batcher.active_len() == 0 {
            depth_gauge.set(0.0);
            queue_gauge.set(lock_ok(&shared.queue).len() as f64);
            continue;
        }
        batcher.round().expect("round parks failures; it does not error");
        let now = Instant::now();
        // ---- stream flush (commit order, one Token event per cycle) -----
        // A send failing means the receiver is gone — the client
        // disconnected mid-stream. Mark the request in the fair queue so
        // the NEXT iteration's cancellation sweep (which runs before the
        // round) evicts the session at the round boundary, running the ONE
        // release sequence: pages freed, gauges synced, waiters woken,
        // `requests_cancelled` bumped.
        let mut disconnected: Vec<u64> = Vec::new();
        let mut shed: Vec<(u64, usize, usize)> = Vec::new();
        for s in batcher.active_sessions() {
            let Some(inf) = inflight.get_mut(&s.id) else { continue };
            if !s.is_prefilling() {
                inf.prefill_done_at.get_or_insert(now);
            }
            if flush_stream(&s.tokens, s.is_prefilling(), inf, &ttft_hist, &gap_hist, now)
                .is_err()
            {
                disconnected.push(s.id);
            } else if let Some(st) = &inf.stream {
                // The send went through (so a dead receiver wins over a
                // slow one), but the consumer has fallen behind a bounded
                // sink: shed this session at the round boundary.
                if st.sink.over_capacity() {
                    shed.push((s.id, st.sink.depth(), st.sink.capacity()));
                }
            }
        }
        if !disconnected.is_empty() {
            let mut q = lock_ok(&shared.queue);
            for id in disconnected {
                q.cancel(id); // active, not queued: inserts an eviction mark
            }
        }
        // ---- backpressure shed ------------------------------------------
        // The sink never blocks the step path (sends are unbounded); the
        // SCHEDULER enforces the buffer bound here, where eviction runs
        // the ONE release sequence. The consumer still gets an in-band
        // error frame (mapped to 503 at the HTTP layer), so a stalled
        // reader that resumes sees why its stream ended.
        for (id, depth, cap) in shed {
            let Some(sess) = batcher.remove(id) else { continue };
            let inf = inflight.remove(&id).expect("active sessions are tracked");
            drop(sess); // decoder resources go before the pool release
            if let Some(mgr) = &pool {
                lock_ok(mgr).note_cancellation();
            }
            release_pool_session(pool.as_ref(), &shared, &metrics, id);
            metrics.incr(names::STREAM_BACKPRESSURE_SHEDS, 1);
            metrics.incr("requests_failed", 1);
            finish_aborted(&inf, &tracer, &metrics, id, true);
            let msg = format!(
                "{SHED_PREFIX}request {id} stream consumer fell behind: \
                 {depth} buffered events over the {cap}-event limit"
            );
            if let Some(st) = &inf.stream {
                st.send_error(&msg);
            }
            let _ = inf.done.send(Err(msg));
        }
        // ---- deadline sweep ---------------------------------------------
        // A session that finished THIS round is delivered normally (it beat
        // the sweep); only still-active expired sessions are evicted.
        let over: Vec<u64> = inflight
            .iter()
            .filter(|(_, inf)| inf.deadline.is_some_and(|d| now >= d))
            .map(|(&id, _)| id)
            .collect();
        for id in over {
            let Some(sess) = batcher.remove(id) else { continue };
            let inf = inflight.remove(&id).expect("active sessions are tracked");
            drop(sess); // decoder resources go before the pool release
            if let Some(mgr) = &pool {
                lock_ok(mgr).note_cancellation();
            }
            release_pool_session(pool.as_ref(), &shared, &metrics, id);
            metrics.incr("requests_deadline_rejected", 1);
            finish_aborted(&inf, &tracer, &metrics, id, false);
            let msg =
                format!("{DEADLINE_PREFIX}request {id} exceeded its deadline mid-flight");
            if let Some(st) = &inf.stream {
                st.send_error(&msg);
            }
            let _ = inf.done.send(Err(msg));
        }
        // ---- idle-hibernation sweep -------------------------------------
        // Sessions the batcher is actively driving are touched every round,
        // so only sessions the scheduler is NOT stepping (admitted to the
        // pool but stalled, e.g. parked by an embedder or starved behind
        // sustained backpressure) age past the idle knob and move to the
        // cold tier. Hibernation is lossless: the shard faults back
        // bit-identically on its next touch, no re-prefill.
        if cfg.hibernate_idle_ms > 0 {
            if let Some(mgr) = &pool {
                let hibernated = {
                    let mut m = lock_ok(mgr);
                    for s in batcher.active_sessions() {
                        m.touch(s.id);
                    }
                    m.hibernate_idle(Duration::from_millis(cfg.hibernate_idle_ms))
                };
                if hibernated > 0 {
                    // Spilled shards freed arena pages: refresh the gauges
                    // and wake any admission waiter parked on Saturated.
                    sync_pool_gauges(mgr, &metrics);
                    shared.cv.notify_all();
                }
            }
        }
        // ---- round telemetry --------------------------------------------
        // With a pool, the manager snapshot (note_round → sync_pool_gauges)
        // is the ONE writer of the step/round gauges; only unpooled
        // coordinators write them directly here. Scheduler gauges have no
        // manager mirror, so they are always written directly.
        if let Some((g_workers, g_busy, g_span)) = &round_gauges {
            g_workers.set(batcher.step_workers() as f64);
            g_busy.set(batcher.last_step_workers_busy() as f64);
            g_span.set(batcher.last_round_span_us());
        }
        depth_gauge.set(batcher.active_len() as f64);
        if let Some(p) = &step_pool {
            steals_gauge.set(p.steals() as f64);
        }
        {
            let q = lock_ok(&shared.queue);
            queue_gauge.set(q.len() as f64);
            for (_, g) in tenant_gauges.iter() {
                g.set(0.0);
            }
            for (tenant, depth) in q.tenant_depths() {
                tenant_gauges
                    .entry(tenant.clone())
                    .or_insert_with(|| metrics.gauge_handle(&names::sched_tenant_depth(&tenant)))
                    .set(depth as f64);
            }
        }
        // ---- retire ------------------------------------------------------
        for s in batcher.finished.drain(..) {
            let Some(inf) = inflight.remove(&s.id) else { continue };
            respond_finished(s, inf, &metrics, &tracer, pool.as_ref(), &shared);
        }
        for f in batcher.failed.drain(..) {
            // Release pages FIRST, inflight entry or not: a failed session
            // whose metadata was already reaped must never park its pool
            // reservation (that would leak pages and wedge admission
            // waiters forever).
            drop(f.session); // decoder resources go before the pool release
            release_pool_session(pool.as_ref(), &shared, &metrics, f.id);
            if f.panicked {
                metrics.incr(names::STEP_PANICS_CONTAINED, 1);
            }
            let Some(inf) = inflight.remove(&f.id) else { continue };
            metrics.incr("requests_failed", 1);
            let msg = format!("{:#}", f.error);
            if let Some(st) = &inf.stream {
                st.send_error(&msg);
            }
            let _ = inf.done.send(Err(msg));
        }
    }
}

/// Release one request's pool reservation (no-op when pooling is off),
/// refresh the gauges, and wake workers parked on Saturated admissions —
/// the ONE release sequence shared by the finished, failed, build-error,
/// cancelled, and deadline-expired paths.
fn release_pool_session(
    pool: Option<&SharedSessionManager>,
    shared: &Shared,
    metrics: &Registry,
    id: u64,
) {
    if let Some(mgr) = pool {
        lock_ok(mgr).release(id);
        sync_pool_gauges(mgr, metrics);
        shared.cv.notify_all();
    }
}

/// Close the timeline of a cancelled / deadline-expired session with its
/// terminal marker and push it to the flight recorder, so aborted requests
/// are debuggable at `/debug/requests` like completed ones.
fn finish_aborted(inf: &Inflight, tracer: &Tracer, metrics: &Registry, id: u64, cancelled: bool) {
    if let Some(buf) = &inf.trace {
        let total_us = (inf.queue_secs * 1e6) as u64
            + inf.admitted_at.elapsed().as_micros() as u64;
        buf.record(if cancelled {
            PhaseEvent::Cancelled { total_us }
        } else {
            PhaseEvent::DeadlineExpired { total_us }
        });
        let timeline = tracer.finish(id, buf, total_us);
        trace::record_phase_histograms(&timeline, metrics);
        tracer.push(timeline);
    }
}

/// Push one session's newly committed tokens into its stream at a round
/// boundary. Emits `Prefilled` once when the session leaves its prefill
/// phase, then one `Token` event carrying the run committed since the
/// previous flush; records `ttft_us` on the first run (measured from
/// enqueue: queue wait + residency so far) and `inter_token_gap_us`
/// between subsequent runs, plus the matching `first_token` / `stream`
/// trace markers. `Err(SinkClosed)` = the receiver is gone (client
/// disconnected); no-op for buffered-only requests.
fn flush_stream(
    tokens: &[i32],
    prefilling: bool,
    inf: &mut Inflight,
    ttft_hist: &Histogram,
    gap_hist: &Histogram,
    now: Instant,
) -> Result<(), SinkClosed> {
    let Some(st) = inf.stream.as_mut() else { return Ok(()) };
    if !st.prefilled_sent && !prefilling {
        st.prefilled_sent = true;
        st.sink.send(StreamEvent::Prefilled { prompt_tokens: st.prompt_tokens })?;
    }
    if tokens.len() <= st.flushed {
        return Ok(());
    }
    let run = tokens[st.flushed..].to_vec();
    let total = tokens.len();
    let gap_us = st.last_flush.map(|t| now.duration_since(t).as_micros() as u64);
    match gap_us {
        None => {
            let ttft_us = (inf.queue_secs * 1e6) as u64
                + now.duration_since(inf.admitted_at).as_micros() as u64;
            ttft_hist.record_us(ttft_us as f64);
            if let Some(buf) = &inf.trace {
                buf.record(PhaseEvent::FirstToken { cycle: st.cycle, us: ttft_us });
            }
        }
        Some(us) => gap_hist.record_us(us as f64),
    }
    if let Some(buf) = &inf.trace {
        buf.record(PhaseEvent::StreamFlush {
            cycle: st.cycle,
            tokens: run.len(),
            us: gap_us.unwrap_or(0),
        });
    }
    st.sink.send(StreamEvent::Token { cycle: st.cycle, tokens: run, total })?;
    st.flushed = total;
    st.cycle += 1;
    st.last_flush = Some(now);
    Ok(())
}

/// Build the response for a finished session and release its resources.
fn respond_finished(
    mut s: ActiveSession,
    mut inf: Inflight,
    metrics: &Registry,
    tracer: &Tracer,
    pool: Option<&SharedSessionManager>,
    shared: &Shared,
) {
    let now = Instant::now();
    // Final stream flush: a session finishing mid-round leaves the active
    // set before the round-boundary flush sees it, so the last committed
    // run (and the `Prefilled` event of a one-round request) streams here,
    // before `s.tokens` is taken for the buffered response. A dead receiver
    // is ignored — the request already retired.
    if inf.stream.is_some() {
        let ttft = metrics.histogram(names::TTFT_US);
        let gap = metrics.histogram(names::INTER_TOKEN_GAP_US);
        let _ = flush_stream(&s.tokens, false, &mut inf, &ttft, &gap, now);
    }
    let prefill_done = inf.prefill_done_at.unwrap_or(now);
    let prefill_secs = prefill_done.duration_since(inf.admitted_at).as_secs_f64();
    let decode_secs = now.duration_since(prefill_done).as_secs_f64();
    let acceptance_rate = if s.drafted == 0 {
        0.0
    } else {
        s.accepted as f64 / s.drafted as f64
    };
    metrics.incr("drafted", s.drafted);
    metrics.incr("accepted", s.accepted);
    metrics.incr("requests_completed", 1);
    metrics.incr("tokens_generated", s.tokens.len() as u64);
    metrics.histogram("prefill").record_secs(prefill_secs);
    metrics.histogram("decode").record_secs(decode_secs);
    metrics
        .histogram("e2e")
        .record_secs(prefill_secs + decode_secs + inf.queue_secs);
    let id = s.id;
    let tokens = std::mem::take(&mut s.tokens);
    // decode-phase tokens only: the first reported token is sampled from
    // the prefill logits (see `GenResult::decode_tokens`)
    let decode_tokens = tokens.len().saturating_sub(1);
    drop(s); // decoder resources go before the pool release
    release_pool_session(pool, shared, metrics, id);
    // Close the timeline: total = queue (incl. admission wait) + residency.
    // Finishing BEFORE the response is sent makes the flight recorder and
    // the phase histograms visible the moment `generate` returns.
    if let Some(buf) = &inf.trace {
        let total_us = (inf.queue_secs * 1e6) as u64
            + now.duration_since(inf.admitted_at).as_micros() as u64;
        let timeline = tracer.finish(id, buf, total_us);
        trace::record_phase_histograms(&timeline, metrics);
        tracer.push(timeline);
    }
    let total = tokens.len();
    let _ = inf.done.send(Ok(ResponseOut {
        id,
        tokens,
        bucket: inf.bucket,
        acceptance_rate,
        prefill_secs,
        decode_secs,
        decode_tokens_per_sec: decode_tokens as f64 / decode_secs.max(1e-9),
        queue_secs: inf.queue_secs,
    }));
    // Terminal AFTER the buffered result: a streaming consumer that sees
    // `Done` can immediately `recv` the done channel for the final stats.
    if let Some(st) = &inf.stream {
        let _ = st.sink.send(StreamEvent::Done { total });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::router::Coordinator;
    use crate::pool::PoolConfig;
    use crate::util::prop;
    use std::sync::Mutex;

    fn job(id: u64, tenant: &str) -> Queued {
        // queue-only tests never send on `done`; a dropped receiver is fine
        let (tx, _rx) = mpsc::channel();
        Queued {
            spec: RequestSpec {
                id,
                prompt: vec![1, 2, 3],
                max_new_tokens: 8,
                method: None,
                gamma: None,
                tenant: Some(tenant.to_string()),
                deadline_ms: None,
                sink: None,
            },
            tenant: tenant.to_string(),
            enqueued_at: now_secs(),
            deadline: None,
            done: tx,
        }
    }

    #[test]
    fn drr_respects_weights_per_cursor_visit() {
        let mut q =
            FairQueue::with_params(8, 0, vec![("gold".into(), 3), ("free".into(), 1)]);
        for i in 0..6 {
            q.push(job(i, "gold")).unwrap();
        }
        for i in 10..16 {
            q.push(job(i, "free")).unwrap();
        }
        let mut order = Vec::new();
        while let Some(j) = q.pop() {
            order.push(j.tenant.clone());
        }
        assert_eq!(order.len(), 12);
        // Per full cursor cycle: 3 gold then 1 free, until gold drains.
        assert_eq!(
            order,
            vec![
                "gold", "gold", "gold", "free", "gold", "gold", "gold", "free", "free",
                "free", "free", "free"
            ]
        );
    }

    #[test]
    fn peek_and_pop_agree_on_the_wfq_head() {
        let mut q = FairQueue::with_params(8, 0, vec![("b".into(), 2)]);
        q.push(job(1, "a")).unwrap();
        q.push(job(2, "b")).unwrap();
        q.push(job(3, "b")).unwrap();
        for _ in 0..3 {
            let want = q.peek().map(|j| j.spec.id).unwrap();
            // repeated peeks are stable between pops
            assert_eq!(q.peek().map(|j| j.spec.id), Some(want));
            assert_eq!(q.pop().map(|j| j.spec.id), Some(want));
        }
        assert!(q.is_empty());
    }

    #[test]
    fn rate_limit_sheds_a_burst_but_spares_other_tenants() {
        let mut q = FairQueue::with_params(8, 2, vec![]);
        let mut ok = 0;
        let mut shed = 0;
        for i in 0..5 {
            match q.push(job(i, "spammer")) {
                Ok(()) => ok += 1,
                Err((_, why)) => {
                    assert_eq!(why, "rate limited");
                    shed += 1;
                }
            }
        }
        // burst = one second's worth = 2 tokens (± a refill sliver)
        assert!((2..=3).contains(&ok), "accepted {ok} of a 5-burst at 2 req/s");
        assert!(shed >= 2);
        // a fresh tenant has its own full bucket
        assert!(q.push(job(100, "quiet")).is_ok());
    }

    #[test]
    fn tenant_limit_reclaims_idle_lanes_before_shedding() {
        let mut q = FairQueue::with_params(2, 0, vec![]);
        q.push(job(1, "a")).unwrap();
        q.push(job(2, "b")).unwrap();
        q.push(job(25, "b")).unwrap();
        // both lanes backlogged: a third tenant is shed
        let (_, why) = q.push(job(3, "c")).unwrap_err();
        assert_eq!(why, "tenant limit");
        // drain lane "a"; its idle lane is reclaimed for "c"
        while q.pop().map(|j| j.tenant == "a").unwrap_or(false) {}
        let before = q.len();
        q.push(job(4, "c")).unwrap();
        assert_eq!(q.len(), before + 1);
    }

    #[test]
    fn cancel_removes_queued_and_marks_unknown() {
        let mut q = FairQueue::with_params(4, 0, vec![]);
        q.push(job(1, "a")).unwrap();
        q.push(job(2, "a")).unwrap();
        assert_eq!(q.cancel(2).map(|j| j.spec.id), Some(2));
        assert_eq!(q.len(), 1);
        assert!(q.cancel(77).is_none());
        assert_eq!(q.drain_marks(), vec![77]);
        assert!(q.drain_marks().is_empty());
    }

    fn req(id: u64, len: usize, tenant: Option<&str>) -> RequestSpec {
        RequestSpec {
            id,
            prompt: (0..len as i32).collect(),
            max_new_tokens: 24,
            method: None,
            gamma: None,
            tenant: tenant.map(str::to_string),
            deadline_ms: None,
            sink: None,
        }
    }

    /// Acceptance: serial-vs-scheduled token streams are bit-identical.
    /// A 1-engine serial coordinator and a 2-engine scheduled one (shared
    /// stealing pool, concurrent multiplexed rounds) produce the same
    /// tokens request for request — stealing reorders execution, never
    /// results.
    #[test]
    fn scheduled_concurrent_output_identical_to_serial() {
        let mk = |engines: usize, workers: usize| ServeConfig {
            engines,
            step_workers: workers,
            queue_capacity: 64,
            max_new_tokens: 24,
            batcher_slots: 4,
            ..ServeConfig::default()
        };
        let serial = Coordinator::with_mock(mk(1, 1), 0.2).unwrap();
        let sched = Coordinator::with_mock(mk(2, 2), 0.2).unwrap();
        // serial reference, one request at a time
        let want: Vec<Vec<i32>> = (0..8u64)
            .map(|i| serial.generate(req(i, 4 + (i as usize % 5), None)).unwrap().tokens)
            .collect();
        // scheduled: all 8 in flight at once, multiplexed across rounds
        let rxs: Vec<_> = (0..8u64)
            .map(|i| sched.submit(req(i, 4 + (i as usize % 5), None)).unwrap())
            .collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            let out = rx.recv().unwrap().unwrap();
            assert_eq!(out.tokens, want[i], "request {i}");
        }
    }

    /// A weighted tenant's small batch overtakes a bulk tenant's earlier
    /// backlog: with one batcher slot, completion order IS admission
    /// order, and DRR grants "gold" (weight 8) the lane before "bulk"
    /// drains. Under FIFO both gold requests would finish last.
    #[test]
    fn weighted_tenant_overtakes_a_bulk_backlog() {
        let cfg = ServeConfig {
            engines: 1,
            batcher_slots: 1,
            queue_capacity: 64,
            max_new_tokens: 24,
            fair_weights: vec![("gold".to_string(), 8)],
            ..ServeConfig::default()
        };
        let c = Coordinator::with_mock(cfg, 0.2).unwrap();
        let mut rxs = Vec::new();
        for i in 0..6u64 {
            rxs.push(("bulk", c.submit(req(i, 8, Some("bulk"))).unwrap()));
        }
        for i in 10..12u64 {
            rxs.push(("gold", c.submit(req(i, 8, Some("gold"))).unwrap()));
        }
        let order = std::sync::Arc::new(Mutex::new(Vec::new()));
        let joins: Vec<_> = rxs
            .into_iter()
            .map(|(tenant, rx)| {
                let order = std::sync::Arc::clone(&order);
                std::thread::spawn(move || {
                    rx.recv().unwrap().unwrap();
                    order.lock().unwrap().push(tenant);
                })
            })
            .collect();
        for j in joins {
            j.join().unwrap();
        }
        let order = order.lock().unwrap();
        let last_gold = order.iter().rposition(|t| *t == "gold").unwrap();
        let last_bulk = order.iter().rposition(|t| *t == "bulk").unwrap();
        assert!(
            last_gold < last_bulk,
            "gold (weight 8) must finish before the bulk backlog drains: {order:?}"
        );
    }

    /// Per-tenant token bucket: at 1 req/s, the second instant submit from
    /// one tenant is shed as rate limited (burst = one second's worth).
    #[test]
    fn tenant_rate_limit_sheds_at_submit() {
        let cfg = ServeConfig {
            engines: 1,
            queue_capacity: 64,
            max_new_tokens: 24,
            tenant_rate_limit: 1,
            ..ServeConfig::default()
        };
        let c = Coordinator::with_mock(cfg, 0.2).unwrap();
        let rx = c.submit(req(1, 6, Some("spammer"))).unwrap();
        let (_, why) = c.submit(req(2, 6, Some("spammer"))).unwrap_err();
        assert_eq!(why, "rate limited");
        assert_eq!(c.metrics.counter("requests_rate_limited"), 1);
        assert_eq!(c.metrics.counter("requests_shed"), 1);
        rx.recv().unwrap().unwrap();
    }

    /// Pooled config where one long-prefill request saturates the pool:
    /// `pages` fits one plan (ceiling 0.9 × 1.5 × plan) but not two.
    fn saturating_pool_cfg(prompt_len: usize) -> ServeConfig {
        let mut cfg = ServeConfig {
            engines: 1,
            queue_capacity: 64,
            max_new_tokens: 24,
            prefill_chunk_tokens: 8,
            pool: PoolConfig {
                pages: 1, // placeholder, sized below
                page_tokens: 8,
                kv_dim: 2,
                high_watermark: 0.9,
                low_watermark: 0.7,
                ..PoolConfig::default()
            },
            ..ServeConfig::default()
        };
        let plan = pool_plan(&cfg, prompt_len, cfg.max_new_tokens).pages;
        cfg.pool.pages = plan + plan / 2;
        cfg
    }

    /// Cancellation mid-flight releases the session's pool pages and wakes
    /// the admission waiter parked on the saturated pool: r2 (same size as
    /// r1, does not fit alongside it) completes only because cancelling r1
    /// freed its reservation.
    #[test]
    fn cancelled_request_releases_pages_and_wakes_admission_waiters() {
        const PROMPT: usize = 3000; // 375 prefill chunks: a wide cancel window
        let c = Coordinator::with_mock(saturating_pool_cfg(PROMPT), 0.2).unwrap();
        let rx1 = c.submit(req(1, PROMPT, None)).unwrap();
        // wait until r1 is admitted and holds pages
        let mgr = c.pool().expect("pooled").clone();
        let t0 = std::time::Instant::now();
        while mgr.lock().unwrap().pool().pages_in_use() == 0 {
            assert!(t0.elapsed().as_secs() < 10, "r1 never admitted");
            std::thread::sleep(Duration::from_millis(1));
        }
        let rx2 = c.submit(req(2, PROMPT, None)).unwrap();
        c.cancel(1);
        let e = rx1.recv().unwrap().unwrap_err();
        assert!(e.contains("cancelled"), "got: {e}");
        // r2 was parked on the saturated pool; r1's release admitted it
        let out = rx2.recv().unwrap().unwrap();
        assert_eq!(out.tokens.len(), 24);
        assert_eq!(c.metrics.counter("requests_cancelled"), 1);
        let m = mgr.lock().unwrap();
        assert_eq!(m.pool().pages_in_use(), 0, "cancelled pages released");
        assert_eq!(m.cancellations(), 1);
        m.check_integrity().unwrap();
    }

    /// A queued request whose deadline lapses while a long request holds
    /// the only slot is rejected cleanly at pop — before any pool pages
    /// are booked — and the long request is unaffected.
    #[test]
    fn queued_deadline_expiry_rejects_cleanly() {
        let cfg = ServeConfig {
            engines: 1,
            batcher_slots: 1,
            queue_capacity: 64,
            max_new_tokens: 24,
            prefill_chunk_tokens: 8,
            ..ServeConfig::default()
        };
        let c = Coordinator::with_mock(cfg, 0.2).unwrap();
        // 1500 chunked prefill rounds + a 20k-token decode: r1 holds the
        // only slot far longer than r2's 1 ms deadline on any host
        let mut r1 = req(1, 12_000, None);
        r1.max_new_tokens = 20_000;
        let rx1 = c.submit(r1).unwrap();
        let mut r2 = req(2, 6, None);
        r2.deadline_ms = Some(1);
        let rx2 = c.submit(r2).unwrap();
        let e = rx2.recv().unwrap().unwrap_err();
        assert!(e.contains("deadline"), "got: {e}");
        assert!(e.contains("in queue"), "queued-expiry path: {e}");
        assert_eq!(c.metrics.counter("requests_deadline_rejected"), 1);
        assert_eq!(rx1.recv().unwrap().unwrap().tokens.len(), 20_000);
        assert_eq!(c.metrics.counter("requests_completed"), 1);
    }

    /// An active session that blows its deadline mid-prefill is evicted at
    /// the round boundary, its pages released, and the scheduler keeps
    /// serving.
    #[test]
    fn midflight_deadline_expiry_evicts_and_releases() {
        // 2000 pooled prefill chunks + a 200k-token pooled decode: total
        // residency far exceeds the 50 ms deadline on any host (the
        // eviction itself caps the test's runtime at ~the deadline), while
        // the deadline dwarfs scheduler wake-up latency — the expiry
        // deterministically lands mid-flight, not in the queue.
        const PROMPT: usize = 16_000;
        const BUDGET: usize = 200_000;
        let mut cfg = saturating_pool_cfg(PROMPT);
        let plan = pool_plan(&cfg, PROMPT, BUDGET).pages;
        cfg.pool.pages = plan + plan / 2;
        let c = Coordinator::with_mock(cfg, 0.2).unwrap();
        let mut r1 = req(1, PROMPT, None);
        r1.max_new_tokens = BUDGET;
        r1.deadline_ms = Some(50);
        let rx1 = c.submit(r1).unwrap();
        let e = rx1.recv().unwrap().unwrap_err();
        assert!(e.contains("deadline"), "got: {e}");
        assert!(e.contains("mid-flight"), "active-eviction path: {e}");
        assert_eq!(c.metrics.counter("requests_deadline_rejected"), 1);
        // pages released; a small follow-up request is served normally
        assert_eq!(c.generate(req(2, 6, None)).unwrap().tokens.len(), 24);
        let m = c.pool().unwrap().lock().unwrap();
        assert_eq!(m.pool().pages_in_use(), 0);
        assert_eq!(m.cancellations(), 1);
        m.check_integrity().unwrap();
    }

    /// The scheduler's idle sweep (`hibernate_idle_ms`) moves a pool
    /// session the batcher is NOT driving to the cold tier, while the
    /// actively-decoding request — touched every round — is spared. The
    /// cold session's KV then faults back bit-identically on its next
    /// read: hibernate/resume with no re-prefill and no eviction.
    #[test]
    fn idle_sweep_hibernates_stalled_sessions_but_spares_active_ones() {
        use crate::pool::{mock_kv, AdmitOutcome, PagedKvCache};
        let dir = std::env::temp_dir()
            .join(format!("qs-idle-sweep-{}", std::process::id()));
        let cfg = ServeConfig {
            engines: 1,
            queue_capacity: 64,
            max_new_tokens: 64,
            prefill_chunk_tokens: 8,
            hibernate_idle_ms: 1,
            pool: PoolConfig {
                pages: 1024,
                page_tokens: 8,
                kv_dim: 2,
                high_watermark: 0.9,
                low_watermark: 0.7,
                spill_pages: 256,
                spill_dir: dir.to_string_lossy().into_owned(),
                ..PoolConfig::default()
            },
            ..ServeConfig::default()
        };
        let c = Coordinator::with_mock(cfg, 0.2).unwrap();
        let mgr = c.pool().expect("pooled").clone();
        // A "stalled" session the scheduler never steps: admitted into the
        // pool with real KV but never entering the batcher.
        const IDLE: u64 = 9001;
        assert!(matches!(
            mgr.lock().unwrap().admit(IDLE, 8, false).unwrap(),
            AdmitOutcome::Admitted
        ));
        let mut idle = PagedKvCache::new(mgr.clone(), IDLE, 8, 2, 16, 32).unwrap();
        idle.prefill(16, &|p| mock_kv(p, 7, 2)).unwrap();
        let want: Vec<Vec<f32>> =
            (0..16).map(|p| idle.read_token(p, true).unwrap()).collect();
        // Real requests keep scheduler rounds (and the sweep) ticking well
        // past the 1 ms knob; bounded retries absorb a fast host.
        let mut hibernations = 0;
        for i in 0..50 {
            let out = c.generate(req(100 + i, 3000, None)).unwrap();
            assert_eq!(out.tokens.len(), 24);
            hibernations = mgr.lock().unwrap().tier_stats().hibernations;
            if hibernations >= 1 {
                break;
            }
        }
        assert!(hibernations >= 1, "idle session never swept to the cold tier");
        {
            let m = mgr.lock().unwrap();
            assert_eq!(m.hibernated_sessions(), 1, "only the stalled session");
            assert_eq!(m.snapshot().evictions, 0, "hibernation, not eviction");
        }
        // Fault-back on read: bit-identical KV, counted as restore faults.
        for (p, w) in want.iter().enumerate() {
            assert_eq!(&idle.read_token(p, true).unwrap(), w, "token {p}");
        }
        let m = mgr.lock().unwrap();
        assert!(m.tier_stats().restore_faults > 0, "resume faulted pages back");
        assert_eq!(m.hibernated_sessions(), 0, "session is warm again");
        drop(m);
        idle.release();
        mgr.lock().unwrap().check_integrity().unwrap();
    }

    /// DRR starvation bound, property-tested under adversarial bursty
    /// arrivals: while a tenant stays backlogged, at most
    /// `Σ other tenants' weights` foreign pops occur between two of its
    /// consecutive pops — every tenant keeps making progress no matter how
    /// the others burst. Each generated case is a schedule of
    /// (burst, pops) ops; shrinking finds a minimal starving schedule.
    #[test]
    fn prop_no_tenant_starves_under_bursty_arrivals() {
        const TENANTS: [&str; 4] = ["a", "b", "c", "d"];
        const WEIGHTS: [u64; 4] = [3, 2, 1, 1];
        let bound: u64 = WEIGHTS.iter().sum();
        prop::check(
            prop::Config { cases: 120, size: 48, ..Default::default() },
            |ops: &Vec<(usize, usize)>| {
                let weights: Vec<(String, u64)> = TENANTS
                    .iter()
                    .zip(WEIGHTS)
                    .map(|(t, w)| (t.to_string(), w))
                    .collect();
                let mut q = FairQueue::with_params(TENANTS.len(), 0, weights);
                let mut id = 0u64;
                let mut gap: HashMap<&str, u64> = HashMap::new();
                for &(burst, pops) in ops {
                    let tenant = TENANTS[burst % TENANTS.len()];
                    for _ in 0..(burst / TENANTS.len()) % 12 {
                        id += 1;
                        q.push(job(id, tenant)).unwrap();
                    }
                    for _ in 0..pops % 8 {
                        let Some(popped) = q.pop() else { break };
                        // every OTHER backlogged tenant ate one pop of delay
                        let depths: HashMap<String, usize> =
                            q.tenant_depths().into_iter().collect();
                        for (t, w_t) in TENANTS.iter().zip(WEIGHTS) {
                            if *t == popped.tenant {
                                gap.insert(t, 0);
                            } else if depths.get(*t).copied().unwrap_or(0) > 0 {
                                let g = gap.entry(t).or_insert(0);
                                *g += 1;
                                // a backlogged tenant of weight w waits at
                                // most (bound - w) foreign pops for its turn
                                if *g > bound - w_t {
                                    return false;
                                }
                            }
                        }
                    }
                }
                true
            },
        );
    }

    /// Streaming parity, property-tested: concatenated streamed chunks are
    /// bit-identical to the buffered response across randomized
    /// chunked-prefill / decode / hibernate-resume mixes. Each case derives
    /// a prefill chunking, request shape, and (on pooled cases) a
    /// spill-enabled pool with a stalled occupant the idle sweep hibernates
    /// mid-serving; the same deterministic mock request is served buffered
    /// first, then streamed, and the stream must be well-formed (one
    /// `Prefilled`, dense cycle indices, cumulative totals) with its
    /// concatenation equal to the buffered tokens.
    #[test]
    fn prop_streamed_chunks_match_buffered_response() {
        use crate::pool::{mock_kv, PagedKvCache};
        use crate::stream::{StreamEvent, StreamReceiver, TokenSink};
        let dir = std::env::temp_dir()
            .join(format!("qs-stream-parity-{}", std::process::id()));
        let check = |rx: &StreamReceiver, want: &[i32], prompt_len: usize| {
            let mut got: Vec<i32> = Vec::new();
            let mut cycle = 0usize;
            let mut saw_prefilled = false;
            loop {
                let Ok(ev) = rx.recv() else { return false };
                match ev {
                    StreamEvent::Prefilled { prompt_tokens } => {
                        if saw_prefilled || !got.is_empty() || prompt_tokens != prompt_len {
                            return false;
                        }
                        saw_prefilled = true;
                    }
                    StreamEvent::Token { cycle: cy, tokens, total } => {
                        if !saw_prefilled || cy != cycle || tokens.is_empty() {
                            return false;
                        }
                        cycle += 1;
                        got.extend_from_slice(&tokens);
                        if got.len() != total {
                            return false;
                        }
                    }
                    StreamEvent::Done { total } => {
                        return total == want.len() && got == want;
                    }
                    StreamEvent::Error { .. } => return false,
                }
            }
        };
        prop::check(
            prop::Config { cases: 6, size: 64, ..Default::default() },
            |case: &(usize, usize)| {
                let &(a, b) = case;
                let chunk = [0, 1, 7, 16][a % 4];
                let prompt_len = 4 + (b * 7) % 200;
                let max_new = 1 + (a * 3 + b) % 40;
                let pooled = (a + b) % 2 == 0;
                let mut cfg = ServeConfig {
                    engines: 1,
                    queue_capacity: 64,
                    max_new_tokens: max_new,
                    prefill_chunk_tokens: chunk,
                    ..ServeConfig::default()
                };
                if pooled {
                    cfg.hibernate_idle_ms = 1;
                    cfg.pool = PoolConfig {
                        pages: 1, // sized below
                        page_tokens: 8,
                        kv_dim: 2,
                        spill_pages: 4096,
                        spill_dir: dir.to_string_lossy().into_owned(),
                        ..PoolConfig::default()
                    };
                    let plan = pool_plan(&cfg, prompt_len, max_new).pages;
                    // the request plus the 8-page occupant always co-fit
                    cfg.pool.pages = plan + plan / 2 + 8;
                }
                let c = Coordinator::with_mock(cfg, 0.3).unwrap();
                // On pooled cases, park a stalled occupant the scheduler's
                // idle sweep hibernates while the streamed request decodes;
                // it must fault back bit-identically afterwards.
                let occupant = pooled.then(|| {
                    let mgr = c.pool().expect("pooled").clone();
                    mgr.lock().unwrap().admit(9001, 8, false).unwrap();
                    let mut kv = PagedKvCache::new(mgr, 9001, 8, 2, 16, 32).unwrap();
                    kv.prefill(16, &|p| mock_kv(p, 7, 2)).unwrap();
                    let want: Vec<Vec<f32>> =
                        (0..16).map(|p| kv.read_token(p, true).unwrap()).collect();
                    std::thread::sleep(Duration::from_millis(2)); // age past the knob
                    (kv, want)
                });
                let mut r = req(1, prompt_len, None);
                r.max_new_tokens = max_new;
                let want = c.generate(r.clone()).unwrap().tokens;
                let (sink, rx) = TokenSink::channel();
                r.sink = Some(sink);
                let done = c.submit(r).unwrap();
                let ok = check(&rx, &want, prompt_len);
                let out = done.recv().unwrap().unwrap();
                if let Some((mut kv, want_kv)) = occupant {
                    for (p, w) in want_kv.iter().enumerate() {
                        if &kv.read_token(p, true).unwrap() != w {
                            return false;
                        }
                    }
                    kv.release();
                }
                ok && out.tokens == want
            },
        );
    }

    /// Client disconnect mid-stream: dropping the stream receiver is
    /// detected at the next round-boundary flush and feeds the cancellation
    /// machinery — the session is evicted, its pool pages released,
    /// `requests_cancelled` bumped, and the buffered channel reports the
    /// same cancellation an explicit `cancel()` would.
    #[test]
    fn dropped_stream_receiver_cancels_and_releases_pages() {
        use crate::stream::{StreamEvent, TokenSink};
        const PROMPT: usize = 3000;
        const BUDGET: usize = 200_000; // far more than the test ever decodes
        let mut cfg = saturating_pool_cfg(PROMPT);
        let plan = pool_plan(&cfg, PROMPT, BUDGET).pages;
        cfg.pool.pages = plan + plan / 2;
        let c = Coordinator::with_mock(cfg, 0.2).unwrap();
        let (sink, rx) = TokenSink::channel();
        let mut r = req(1, PROMPT, None);
        r.max_new_tokens = BUDGET;
        r.sink = Some(sink);
        let done = c.submit(r).unwrap();
        // first committed run arrives long before the generation could end
        while !matches!(
            rx.recv().expect("stream died before first token"),
            StreamEvent::Token { .. }
        ) {}
        assert!(c.metrics.histogram(names::TTFT_US).count() >= 1);
        drop(rx); // client disconnects mid-stream
        let e = done.recv().unwrap().unwrap_err();
        assert!(e.contains("cancelled"), "disconnect maps to cancellation: {e}");
        assert_eq!(c.metrics.counter("requests_cancelled"), 1);
        let m = c.pool().unwrap().lock().unwrap();
        assert_eq!(m.pool().pages_in_use(), 0, "no leaked pages");
        assert_eq!(m.cancellations(), 1);
        m.check_integrity().unwrap();
    }

    /// Backpressure shed: a streaming consumer that holds its receiver
    /// open but never drains a bounded sink is shed at a round boundary —
    /// the buffered channel reports the `shed: ` error (503 at the HTTP
    /// layer), an in-band error frame lands in the sink, the shed counter
    /// bumps, and the session's pool pages are released.
    #[test]
    fn undrained_bounded_stream_is_shed_with_pages_released() {
        use crate::stream::{StreamEvent, TokenSink};
        const PROMPT: usize = 3000;
        const BUDGET: usize = 200_000; // far more than the test ever decodes
        let mut cfg = saturating_pool_cfg(PROMPT);
        let plan = pool_plan(&cfg, PROMPT, BUDGET).pages;
        cfg.pool.pages = plan + plan / 2;
        let c = Coordinator::with_mock(cfg, 0.2).unwrap();
        let (sink, rx) = TokenSink::bounded(2);
        let mut r = req(1, PROMPT, None);
        r.max_new_tokens = BUDGET;
        r.sink = Some(sink);
        let done = c.submit(r).unwrap();
        // never drain rx: the sink depth climbs one event per decode round
        let e = done.recv().unwrap().unwrap_err();
        assert!(e.starts_with(SHED_PREFIX), "got: {e}");
        assert!(e.contains("fell behind"), "got: {e}");
        assert_eq!(c.metrics.counter(names::STREAM_BACKPRESSURE_SHEDS), 1);
        // the in-band error frame reaches the (stalled) consumer too
        let saw_err = rx.try_iter().any(|ev| matches!(ev, StreamEvent::Error { .. }));
        assert!(saw_err, "terminal error frame in the sink");
        let m = c.pool().unwrap().lock().unwrap();
        assert_eq!(m.pool().pages_in_use(), 0, "shed pages released");
        m.check_integrity().unwrap();
    }

    /// Robustness property (satellite): ANY fault schedule — spill write
    /// failures, step panics, decoder errors, quant stalls, at any rates —
    /// converges on the one retire/release sequence: zero pages in use
    /// once every request answers, pool integrity intact, and the stats
    /// surfaces still parseable with the robustness counters present.
    #[test]
    fn prop_any_fault_schedule_leaves_zero_pages_in_use() {
        use crate::pool::PoolConfig;
        let dir = std::env::temp_dir()
            .join(format!("qs-chaos-prop-{}", std::process::id()));
        prop::check(
            prop::Config { cases: 10, size: 32, ..Default::default() },
            |case: &(u64, usize, usize, usize)| {
                let &(seed, a, b, cc) = case;
                let rates = [0usize, 120, 350, 1000];
                let spec = format!(
                    "spill_write:{},step_panic:{}:2,decode_error:{},quant_stall:250",
                    rates[a % 4],
                    rates[b % 4],
                    rates[cc % 4],
                );
                let cfg = ServeConfig {
                    engines: 1,
                    queue_capacity: 64,
                    max_new_tokens: 12,
                    prefill_chunk_tokens: 8,
                    batcher_slots: 3,
                    fault_seed: seed,
                    fault_spec: spec,
                    pool: PoolConfig {
                        pages: 96,
                        page_tokens: 8,
                        kv_dim: 2,
                        spill_pages: 32,
                        spill_dir: dir.to_string_lossy().into_owned(),
                        ..PoolConfig::default()
                    },
                    ..ServeConfig::default()
                };
                let c = Coordinator::with_mock(cfg, 0.2).unwrap();
                let rxs: Vec<_> = (0..6u64)
                    .filter_map(|i| c.submit(req(i, 8 + (i as usize * 9) % 40, None)).ok())
                    .collect();
                for rx in rxs {
                    // Ok and injected-fault Err are both acceptable ends;
                    // what must hold is the release invariant below.
                    let _ = rx.recv();
                }
                let m = c.pool().unwrap().lock().unwrap();
                let clean = m.pool().pages_in_use() == 0 && m.check_integrity().is_ok();
                let stats = m.stats_json().to_string();
                drop(m);
                // counters materialize on first increment: require the
                // panic-containment counter only when panics actually fired
                let panics = c
                    .fault_injector()
                    .map_or(0, |f| f.fires(crate::util::fault::FaultSite::StepPanic));
                let metrics = c.metrics.snapshot().to_string();
                clean
                    && stats.contains(names::SPILL_IO_ERRORS)
                    && stats.contains(names::TIER_DEGRADED)
                    && (panics == 0 || metrics.contains(names::STEP_PANICS_CONTAINED))
            },
        );
    }
}
