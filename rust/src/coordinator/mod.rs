//! The serving coordinator: request router, continuous batcher, HTTP API.
//!
//! vLLM-router-shaped: an admission queue feeds a pool of decode engines;
//! each engine worker embeds a [`batcher::StepBatcher`] multiplexing up to
//! `batcher_slots` sessions (chunked prefill admission, quant-pool
//! backpressure, and `step_workers`-way parallel rounds over the sharded
//! KV pool). The router picks the context bucket, pads the prompt, and
//! sheds load when the queue is full. Python never runs here — engines
//! call the AOT artifacts via `runtime`.

pub mod batcher;
pub mod router;
pub mod server;

pub use router::{Coordinator, RequestSpec, ResponseOut};
