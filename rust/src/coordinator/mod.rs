//! The serving coordinator: request router, unified scheduler, HTTP API.
//!
//! vLLM-router-shaped intake, one global brain: submissions land in a
//! per-tenant weighted fair queue ([`sched::FairQueue`]) and a single
//! scheduler driver ([`sched`]) forms continuous-batching rounds across
//! ALL engines' sessions on one process-wide work-stealing step pool
//! (`qs-sched-*` threads) — chunked prefill admission, quant-pool
//! backpressure, SLO deadlines, cancellation, and work stealing all
//! operate fleet-wide over the sharded KV pool. The router picks the
//! context bucket, pads the prompt, and sheds load at submit. Python
//! never runs here — engines call the AOT artifacts via `runtime`.

pub mod batcher;
pub mod router;
pub mod sched;
pub mod server;

pub use router::{Coordinator, RequestSpec, ResponseOut};
