//! HTTP JSON API over the coordinator.
//!
//! Endpoints:
//!   POST /generate  {"prompt": "text" | "tokens": [..], "max_new_tokens",
//!                    "method", "gamma", "tenant", "deadline_ms", "stream"}
//!                   -> tokens + text + stats. A missed deadline maps to
//!                   504, a cancellation to 499, an oversized request to
//!                   413. With "stream": true the response is SSE-style
//!                   chunked frames (`prefill`/`token`/`done`/`error`
//!                   events, one per verify cycle; see docs/STREAMING.md),
//!                   delivered as each cycle commits; both paths drain the
//!                   same TokenSink, so the concatenated stream is
//!                   bit-identical to the buffered body. Dropping the
//!                   connection mid-stream cancels the request and frees
//!                   its pool pages.
//!   POST /cancel    {"id": N} -> {"ok":true}; queued requests are
//!                   removed immediately, in-flight ones are evicted at
//!                   the next scheduler round and their pool pages freed
//!   GET  /stats     metrics snapshot (+ "pool": paged KV pool state —
//!                   pages in use/peak/committed, pressure, watermarks,
//!                   evictions, logical vs host cache bytes)
//!   GET  /metrics   the whole registry in Prometheus text exposition
//!                   (counters, gauges, phase/acceptance histograms)
//!   GET  /debug/requests  flight recorder: the last N completed request
//!                   timelines (queue → admission → prefill chunks →
//!                   draft/verify cycles → completion) as JSON
//!   GET  /healthz   liveness

use std::sync::{mpsc, Arc};

use crate::config::Method;
use crate::stream::{drain_tokens, StreamEvent, StreamReceiver, TokenSink};
use crate::util::httpd::{ChunkWriter, Handler, Request, Response, Server};
use crate::util::json::Json;

use super::router::{Coordinator, RequestSpec, ResponseOut};

pub fn make_handler(coord: Arc<Coordinator>) -> Handler {
    Arc::new(move |req: &Request| handle(&coord, req))
}

pub fn serve(coord: Arc<Coordinator>, bind: &str) -> std::io::Result<Server> {
    // When fault injection is armed, the HTTP layer shares the same
    // injector so `socket_write` faults exercise the disconnect path.
    let fault = coord.fault_injector().cloned();
    Server::start_with_fault(bind, make_handler(coord), fault)
}

fn handle(coord: &Arc<Coordinator>, req: &Request) -> Response {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => Response::json(200, r#"{"ok":true}"#),
        ("GET", "/stats") => {
            coord.sync_pool_gauges();
            let mut snap = coord.metrics.snapshot();
            if let Json::Obj(map) = &mut snap {
                map.insert("pool".to_string(), coord.pool_json());
            }
            Response::json(200, snap.to_string())
        }
        ("GET", "/metrics") => {
            coord.sync_pool_gauges();
            Response::text(200, coord.metrics.render_prometheus())
        }
        ("GET", "/debug/requests") => Response::json(200, coord.tracer.to_json().to_string()),
        ("POST", "/generate") => generate(coord, &req.body),
        ("POST", "/cancel") => cancel(coord, &req.body),
        _ => Response::json(404, r#"{"error":"not found"}"#),
    }
}

fn cancel(coord: &Coordinator, body: &[u8]) -> Response {
    let id = std::str::from_utf8(body)
        .ok()
        .and_then(|t| Json::parse(t).ok())
        .and_then(|j| j.get("id").and_then(Json::as_usize));
    let Some(id) = id else {
        return Response::json(400, r#"{"error":"need {\"id\": N}"}"#);
    };
    coord.cancel(id as u64);
    Response::json(200, r#"{"ok":true}"#)
}

/// The lossy byte→char rendering both response paths share.
fn token_text(tokens: &[i32]) -> String {
    tokens
        .iter()
        .map(|&t| {
            let b = (t as u32).min(255) as u8;
            if b.is_ascii() && !b.is_ascii_control() || b == b'\n' {
                b as char
            } else {
                '\u{fffd}'
            }
        })
        .collect()
}

/// Map an engine error string to its HTTP status: pool-admission size
/// rejections are the client's problem (shrink the request), not a server
/// fault; cancellations and missed SLO deadlines get their own statuses so
/// clients can tell them apart from engine faults; a backpressure shed
/// (the stream consumer fell behind the bounded sink) is 503 — the server
/// gave up on this consumer, retry with a faster one.
fn error_status(e: &str) -> u16 {
    if e.starts_with(super::router::TOO_LARGE_PREFIX) {
        413
    } else if e.starts_with(super::sched::CANCELLED_PREFIX) {
        499
    } else if e.starts_with(super::sched::DEADLINE_PREFIX) {
        504
    } else if e.starts_with(super::sched::SHED_PREFIX) {
        503
    } else {
        500
    }
}

fn generate(coord: &Arc<Coordinator>, body: &[u8]) -> Response {
    let text = match std::str::from_utf8(body) {
        Ok(t) => t,
        Err(_) => return Response::json(400, r#"{"error":"body not utf-8"}"#),
    };
    let j = match Json::parse(text) {
        Ok(j) => j,
        Err(e) => return Response::json(400, format!(r#"{{"error":"bad json: {e}"}}"#)),
    };
    // prompt: byte-level tokens from "prompt" text or explicit "tokens".
    let prompt: Vec<i32> = if let Some(toks) = j.get("tokens").and_then(Json::as_arr) {
        toks.iter().filter_map(|t| t.as_i64().map(|v| v as i32)).collect()
    } else if let Some(p) = j.get("prompt").and_then(Json::as_str) {
        p.bytes().map(|b| b as i32).collect()
    } else {
        return Response::json(400, r#"{"error":"need prompt or tokens"}"#);
    };
    if prompt.is_empty() {
        return Response::json(400, r#"{"error":"empty prompt"}"#);
    }
    let method = match j.get("method").and_then(Json::as_str) {
        Some(s) => match Method::parse(s) {
            Ok(m) => Some(m),
            Err(e) => return Response::json(400, format!(r#"{{"error":"{e}"}}"#)),
        },
        None => None,
    };
    let streaming = j.get("stream").and_then(Json::as_bool).unwrap_or(false);
    // ONE response path: every request carries a TokenSink. Streaming
    // drains it onto the wire as chunked frames; buffered drains it in
    // place — the concatenation is the response body either way. The sink
    // is bounded: a consumer that falls more than `stream_buffer_events`
    // behind is shed by the scheduler (503 in-band error frame) instead of
    // buffering the whole generation in memory.
    let (sink, events) = TokenSink::bounded(coord.cfg.stream_buffer_events);
    let spec = RequestSpec {
        id: coord.next_id(),
        prompt,
        max_new_tokens: j
            .get("max_new_tokens")
            .and_then(Json::as_usize)
            .unwrap_or(coord.cfg.max_new_tokens),
        method,
        gamma: j.get("gamma").and_then(Json::as_usize),
        tenant: j.get("tenant").and_then(Json::as_str).map(str::to_string),
        deadline_ms: j.get("deadline_ms").and_then(Json::as_usize).map(|v| v as u64),
        sink: Some(sink),
    };
    let id = spec.id;
    let rx = match coord.submit(spec) {
        Ok(rx) => rx,
        Err((_, why)) => {
            return Response::json(
                429,
                Json::obj(vec![("error", Json::str(format!("load shed: {why}")))]).to_string(),
            )
        }
    };
    if streaming {
        // The 200 head goes out before generation runs; failures surface
        // in-band as an `error` event carrying the would-be status.
        let coord = Arc::clone(coord);
        return Response::chunked(200, "text/event-stream", move |w| {
            stream_events(&coord, id, &events, &rx, w)
        });
    }
    let (tokens, terminal) = drain_tokens(&events);
    match terminal {
        Some(StreamEvent::Done { .. }) => match rx.recv() {
            // final stats are sent on the done channel BEFORE the sink's
            // terminal event, so this recv never blocks on the engine
            Ok(Ok(out)) => {
                debug_assert_eq!(out.tokens, tokens, "streamed/buffered divergence");
                Response::json(200, finished_json(&out, &tokens).to_string())
            }
            Ok(Err(e)) => Response::json(
                error_status(&e),
                Json::obj(vec![("error", Json::str(e))]).to_string(),
            ),
            Err(_) => Response::json(500, r#"{"error":"engine dropped"}"#),
        },
        Some(StreamEvent::Error { message }) => {
            let e = match rx.recv() {
                Ok(Err(e)) => e,
                _ => message,
            };
            Response::json(
                error_status(&e),
                Json::obj(vec![("error", Json::str(e))]).to_string(),
            )
        }
        _ => Response::json(500, r#"{"error":"engine dropped"}"#),
    }
}

/// The buffered 200 body (also the `stats` payload of a streamed `done`
/// frame): tokens + text from the drained stream, timing from the
/// scheduler's `ResponseOut`.
fn finished_json(out: &ResponseOut, tokens: &[i32]) -> Json {
    Json::obj(vec![
        ("id", Json::num(out.id as f64)),
        ("tokens", Json::arr(tokens.iter().map(|&t| Json::num(t as f64)))),
        ("text", Json::str(token_text(tokens))),
        ("bucket", Json::num(out.bucket as f64)),
        ("acceptance_rate", Json::num(out.acceptance_rate)),
        ("prefill_secs", Json::num(out.prefill_secs)),
        ("decode_secs", Json::num(out.decode_secs)),
        ("decode_tokens_per_sec", Json::num(out.decode_tokens_per_sec)),
        ("queue_secs", Json::num(out.queue_secs)),
    ])
}

/// Drain one request's stream onto the wire as SSE-style frames, one HTTP
/// chunk per event: `event: <kind>\ndata: <json>\n\n`. Token frames carry
/// the cycle index, the accepted run, and cumulative counts; the `done`
/// frame carries the final stats; the terminal chunk's trailer reports the
/// total streamed token count. A chunk write failing means the client went
/// away — cancel the request so the scheduler evicts the session and
/// releases its pages at the next round boundary (the scheduler also
/// notices on its own once this closure's receiver drops).
fn stream_events(
    coord: &Coordinator,
    id: u64,
    events: &StreamReceiver,
    done: &mpsc::Receiver<Result<ResponseOut, String>>,
    w: &mut ChunkWriter<'_>,
) -> std::io::Result<()> {
    let mut sent = 0usize;
    loop {
        let Ok(ev) = events.recv() else {
            // producer vanished without a terminal event
            let frame = Json::obj(vec![
                ("status", Json::num(500.0)),
                ("error", Json::str("engine dropped")),
            ]);
            return write_frame(w, "error", &frame).and_then(|()| w.finish());
        };
        let frame = match &ev {
            StreamEvent::Prefilled { prompt_tokens } => Json::obj(vec![
                ("id", Json::num(id as f64)),
                ("prompt_tokens", Json::num(*prompt_tokens as f64)),
            ]),
            StreamEvent::Token { cycle, tokens, total } => {
                sent = *total;
                Json::obj(vec![
                    ("cycle", Json::num(*cycle as f64)),
                    ("accepted", Json::num(tokens.len() as f64)),
                    ("tokens", Json::arr(tokens.iter().map(|&t| Json::num(t as f64)))),
                    ("text", Json::str(token_text(tokens))),
                    ("total", Json::num(*total as f64)),
                ])
            }
            StreamEvent::Done { total } => {
                // sent on the done channel before the sink terminal, so
                // this recv returns immediately
                let stats = match done.recv() {
                    Ok(Ok(out)) => finished_json(&out, &[]),
                    _ => Json::Null,
                };
                Json::obj(vec![("total", Json::num(*total as f64)), ("stats", stats)])
            }
            StreamEvent::Error { message } => Json::obj(vec![
                ("status", Json::num(error_status(message) as f64)),
                ("error", Json::str(message.clone())),
            ]),
        };
        if let Err(e) = write_frame(w, ev.kind(), &frame) {
            coord.cancel(id);
            return Err(e);
        }
        if ev.is_terminal() {
            let total = sent.to_string();
            return w.finish_with_trailers(&[("x-total-tokens", &total)]);
        }
    }
}

fn write_frame(w: &mut ChunkWriter<'_>, kind: &str, data: &Json) -> std::io::Result<()> {
    w.write_chunk(format!("event: {kind}\ndata: {data}\n\n").as_bytes())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ServeConfig;
    use crate::util::httpd::http_request;

    fn start_mock_server() -> (Server, Arc<Coordinator>) {
        let cfg = ServeConfig { engines: 2, max_new_tokens: 16, ..ServeConfig::default() };
        let coord = Arc::new(Coordinator::with_mock(cfg, 0.1).unwrap());
        let srv = serve(Arc::clone(&coord), "127.0.0.1:0").unwrap();
        (srv, coord)
    }

    #[test]
    fn healthz_and_stats() {
        let (srv, _c) = start_mock_server();
        let addr = srv.addr.to_string();
        let (st, body) = http_request(&addr, "GET", "/healthz", b"").unwrap();
        assert_eq!(st, 200);
        assert!(String::from_utf8_lossy(&body).contains("ok"));
        let (st, _) = http_request(&addr, "GET", "/stats", b"").unwrap();
        assert_eq!(st, 200);
    }

    #[test]
    fn generate_roundtrip() {
        let (srv, _c) = start_mock_server();
        let addr = srv.addr.to_string();
        let (st, body) =
            http_request(&addr, "POST", "/generate", br#"{"prompt":"hello world"}"#).unwrap();
        assert_eq!(st, 200, "{}", String::from_utf8_lossy(&body));
        let j = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        assert_eq!(j.get("tokens").unwrap().as_arr().unwrap().len(), 16);
    }

    #[test]
    fn stats_expose_pool_state() {
        let cfg = ServeConfig {
            engines: 1,
            max_new_tokens: 12,
            pool: crate::pool::PoolConfig {
                pages: 32,
                page_tokens: 8,
                kv_dim: 2,
                high_watermark: 0.9,
                low_watermark: 0.7,
                ..crate::pool::PoolConfig::default()
            },
            ..ServeConfig::default()
        };
        let coord = Arc::new(Coordinator::with_mock(cfg, 0.1).unwrap());
        let srv = serve(Arc::clone(&coord), "127.0.0.1:0").unwrap();
        let addr = srv.addr.to_string();
        let (st, _) =
            http_request(&addr, "POST", "/generate", br#"{"prompt":"hello"}"#).unwrap();
        assert_eq!(st, 200);
        let (st, body) = http_request(&addr, "GET", "/stats", b"").unwrap();
        assert_eq!(st, 200);
        let j = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        let pool = j.get("pool").expect("pool block in /stats");
        assert_eq!(pool.get("pages_capacity").unwrap().as_usize(), Some(32));
        assert_eq!(pool.get("pages_in_use").unwrap().as_usize(), Some(0));
        assert!(pool.get("pages_peak").unwrap().as_usize().unwrap() > 0);
        assert!(j.get("gauges").is_some(), "metrics gauges in snapshot");
        // cache-traffic counters: a speculative decode read the draft and
        // target planes, so both call counters are live in /stats
        use crate::metrics::names;
        let calls = |name: &str| pool.get(name).unwrap().as_usize().unwrap();
        assert!(calls(names::DEQUANT_CALLS_DRAFT) > 0, "draft dequants counted");
        assert!(calls(names::DEQUANT_CALLS_TARGET) > 0, "target dequants counted");
        assert!(calls(names::QUANT_BYTES_READ_DRAFT) > 0);
        assert!(
            j.get("gauges").unwrap().get(names::DEQUANT_CALLS_DRAFT).is_some(),
            "traffic mirrored into metrics gauges"
        );
        // the shared quantization pool surfaces in the pool block and the
        // gauges (default config: one worker, so the pool ran no jobs)
        assert_eq!(calls(names::QUANT_POOL_WORKERS), 1);
        assert_eq!(calls(names::QUANT_POOL_JOBS), 0);
        assert_eq!(calls(names::QUANT_POOL_QUEUE_DEPTH), 0);
        assert!(
            j.get("gauges").unwrap().get(names::QUANT_POOL_JOBS).is_some(),
            "quant pool gauges mirrored into metrics"
        );
        // backpressure counter: present in the pool block and the gauges
        // (zero here — nothing deferred a prefill in this run)
        assert_eq!(calls(names::PREFILL_DEFERRALS), 0);
        assert!(
            j.get("gauges").unwrap().get(names::PREFILL_DEFERRALS).is_some(),
            "prefill_deferrals surfaced as a gauge"
        );
        // round-parallelism telemetry (serving path): the pool block and
        // the gauges both carry the step-worker and round-span keys, and
        // the unified scheduler publishes its global batcher depth gauge
        // (the old per-engine batcher_depth_engine_{N} gauges are gone)
        assert_eq!(calls(names::STEP_WORKERS), 1, "default = serial rounds");
        assert!(pool.get(names::ROUND_SPAN_US).is_some());
        assert!(pool.get(names::STEP_WORKERS_BUSY).is_some());
        assert!(
            pool.get(names::BATCHER_ROUNDS).unwrap().as_usize().unwrap() > 0,
            "the embedded batcher recorded its rounds"
        );
        let gauges = j.get("gauges").unwrap();
        for key in [names::STEP_WORKERS, names::ROUND_SPAN_US, names::STEP_WORKERS_BUSY] {
            assert!(gauges.get(key).is_some(), "gauge {key} missing");
        }
        assert!(
            gauges.get(names::SCHED_BATCHER_DEPTH).is_some(),
            "unified scheduler batcher depth gauge missing"
        );
        assert!(
            gauges.get(names::SCHED_QUEUE_DEPTH).is_some(),
            "unified scheduler queue depth gauge missing"
        );
    }

    /// One Prometheus exposition line: `# TYPE/HELP ...` comment, blank, or
    /// `name{labels} value` with a parseable float value.
    fn exposition_line_ok(line: &str) -> bool {
        if line.is_empty() || line.starts_with("# ") {
            return true;
        }
        let Some((name_part, value)) = line.rsplit_once(' ') else {
            return false;
        };
        if value.parse::<f64>().is_err() && value != "+Inf" {
            return false;
        }
        let name_end = name_part.find('{').unwrap_or(name_part.len());
        let (name, labels) = name_part.split_at(name_end);
        !name.is_empty()
            && name
                .chars()
                .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
            && !name.starts_with(|c: char| c.is_ascii_digit())
            && (labels.is_empty() || (labels.starts_with('{') && labels.ends_with('}')))
    }

    /// `GET /metrics` serves valid Prometheus text exposition carrying the
    /// acceptance-rate and per-phase histograms.
    #[test]
    fn metrics_endpoint_serves_valid_exposition() {
        use crate::metrics::names;
        let (srv, _c) = start_mock_server();
        let addr = srv.addr.to_string();
        let (st, body) =
            http_request(&addr, "POST", "/generate", br#"{"prompt":"hello world"}"#).unwrap();
        assert_eq!(st, 200, "{}", String::from_utf8_lossy(&body));
        let (st, body) = http_request(&addr, "GET", "/metrics", b"").unwrap();
        assert_eq!(st, 200);
        let text = String::from_utf8(body).unwrap();
        for line in text.lines() {
            assert!(exposition_line_ok(line), "malformed exposition line: {line:?}");
        }
        for needle in [
            "# TYPE requests_completed counter",
            "requests_completed 1",
            &format!("# TYPE {} histogram", names::ACCEPTANCE_RATE_PCT),
            &format!("{}_count", names::ACCEPTANCE_RATE_PCT),
            &format!("{}_bucket", names::PHASE_VERIFY_US),
            &format!("{}_sum", names::PHASE_DRAFT_US),
            "le=\"+Inf\"",
        ] {
            assert!(text.contains(needle), "missing {needle:?} in:\n{text}");
        }
    }

    /// Acceptance (tentpole): a pooled HTTP request appears in
    /// `/debug/requests` with a complete ordered timeline — queue wait,
    /// admission, every prefill chunk, each draft cycle with γ/accepted, a
    /// verify per cycle, completion last — and the phase durations account
    /// for the request's wall time within 10%. Heavy pool geometry makes
    /// the traced spans dominate scheduling overhead.
    #[test]
    fn debug_requests_timeline_is_complete_and_covers_wall_time() {
        let cfg = ServeConfig {
            engines: 1,
            max_new_tokens: 48,
            prefill_chunk_tokens: 32,
            pool: crate::pool::PoolConfig {
                pages: 64,
                page_tokens: 32,
                kv_dim: 256,
                high_watermark: 0.9,
                low_watermark: 0.7,
                ..crate::pool::PoolConfig::default()
            },
            ..ServeConfig::default()
        };
        let coord = Arc::new(Coordinator::with_mock(cfg, 0.15).unwrap());
        let srv = serve(Arc::clone(&coord), "127.0.0.1:0").unwrap();
        let addr = srv.addr.to_string();
        let prompt: String = "x".repeat(96); // 3 chunks of 32
        let body = format!(r#"{{"prompt":"{prompt}","max_new_tokens":48}}"#);
        let (st, resp) = http_request(&addr, "POST", "/generate", body.as_bytes()).unwrap();
        assert_eq!(st, 200, "{}", String::from_utf8_lossy(&resp));

        let (st, body) = http_request(&addr, "GET", "/debug/requests", b"").unwrap();
        assert_eq!(st, 200);
        let j = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        let reqs = j.get("requests").unwrap().as_arr().unwrap();
        assert_eq!(reqs.len(), 1, "one completed request in the recorder");
        let t = &reqs[0];
        assert_eq!(t.get("dropped").unwrap().as_usize(), Some(0));
        let events = t.get("events").unwrap().as_arr().unwrap();
        let phase = |e: &Json| e.get("phase").unwrap().as_str().unwrap().to_string();

        // ordered: queue → admission → prefill chunks → cycles → completed
        assert_eq!(phase(&events[0]), "queue_wait");
        assert_eq!(phase(&events[1]), "admission_wait");
        assert_eq!(phase(events.last().unwrap()), "completed");
        let chunks: Vec<usize> = events
            .iter()
            .filter(|e| phase(e) == "prefill_chunk")
            .map(|e| e.get("n").unwrap().as_usize().unwrap())
            .collect();
        assert_eq!(chunks, vec![0, 1, 2], "every prefill chunk traced in order");
        let cycles: Vec<&Json> =
            events.iter().filter(|e| phase(e) == "draft_cycle").collect();
        assert!(!cycles.is_empty(), "decode cycles traced");
        for c in &cycles {
            let gamma = c.get("gamma").unwrap().as_usize().unwrap();
            let accepted = c.get("accepted").unwrap().as_usize().unwrap();
            assert!(accepted <= gamma, "cycle accepted {accepted} > gamma {gamma}");
        }
        let verifies = events.iter().filter(|e| phase(e) == "verify").count();
        assert_eq!(verifies, cycles.len(), "one verify span per cycle");
        let last_chunk = events.iter().rposition(|e| phase(e) == "prefill_chunk").unwrap();
        let first_cycle = events.iter().position(|e| phase(e) == "draft_cycle").unwrap();
        assert!(last_chunk < first_cycle, "prefill precedes decode");
        let stamps: Vec<usize> = events
            .iter()
            .map(|e| e.get("at_us").unwrap().as_usize().unwrap())
            .collect();
        assert!(stamps.windows(2).all(|w| w[0] <= w[1]), "monotone timestamps");

        // coverage: phase durations account for the wall time within 10%
        let total = t.get("total_us").unwrap().as_usize().unwrap() as f64;
        let sum = t.get("phase_sum_us").unwrap().as_usize().unwrap() as f64;
        assert!(total > 0.0);
        let ratio = sum / total;
        assert!(
            (0.9..=1.1).contains(&ratio),
            "phase sum {sum}µs vs wall {total}µs (ratio {ratio:.3})"
        );
    }

    /// Satellite: `/stats` and `/metrics` stay parseable and monotone while
    /// requests hammer the coordinator from other threads.
    #[test]
    fn stats_and_metrics_scrape_cleanly_under_concurrent_load() {
        use std::sync::atomic::{AtomicBool, Ordering};
        let (srv, coord) = start_mock_server();
        let addr = srv.addr.to_string();
        let done = Arc::new(AtomicBool::new(false));
        let mut submitters = Vec::new();
        for t in 0..2u64 {
            let addr = addr.clone();
            submitters.push(std::thread::spawn(move || {
                for i in 0..8 {
                    let body = format!(
                        r#"{{"prompt":"load {t} {i}","max_new_tokens":16}}"#
                    );
                    let (st, resp) =
                        http_request(&addr, "POST", "/generate", body.as_bytes()).unwrap();
                    assert_eq!(st, 200, "{}", String::from_utf8_lossy(&resp));
                }
            }));
        }
        let scraper = {
            let addr = addr.clone();
            let done = Arc::clone(&done);
            std::thread::spawn(move || {
                let mut last_completed = 0u64;
                let mut last_tokens = 0u64;
                let mut scrapes = 0usize;
                while !done.load(Ordering::Relaxed) || scrapes == 0 {
                    let (st, body) = http_request(&addr, "GET", "/stats", b"").unwrap();
                    assert_eq!(st, 200);
                    let j = Json::parse(std::str::from_utf8(&body).unwrap())
                        .expect("mid-load /stats snapshot parses");
                    let counter = |name: &str| {
                        j.get("counters")
                            .and_then(|c| c.get(name))
                            .and_then(Json::as_usize)
                            .unwrap_or(0) as u64
                    };
                    let completed = counter("requests_completed");
                    let tokens = counter("tokens_generated");
                    assert!(completed >= last_completed, "completed went backwards");
                    assert!(tokens >= last_tokens, "tokens_generated went backwards");
                    last_completed = completed;
                    last_tokens = tokens;
                    let (st, body) = http_request(&addr, "GET", "/metrics", b"").unwrap();
                    assert_eq!(st, 200);
                    for line in std::str::from_utf8(&body).unwrap().lines() {
                        assert!(
                            exposition_line_ok(line),
                            "malformed mid-load exposition line: {line:?}"
                        );
                    }
                    scrapes += 1;
                }
                (last_completed, scrapes)
            })
        };
        for s in submitters {
            s.join().unwrap();
        }
        done.store(true, Ordering::Relaxed);
        let (completed, scrapes) = scraper.join().unwrap();
        assert!(scrapes > 0);
        assert!(completed <= 16);
        assert_eq!(coord.metrics.counter("requests_completed"), 16);
    }

    /// `/cancel` aborts an in-flight request with 499 and a missed SLO
    /// deadline maps to 504, both end-to-end over HTTP.
    #[test]
    fn http_cancel_maps_to_499_and_deadline_to_504() {
        use crate::metrics::names;
        let cfg = ServeConfig {
            engines: 1,
            prefill_chunk_tokens: 8,
            ..ServeConfig::default()
        };
        let coord = Arc::new(Coordinator::with_mock(cfg, 0.1).unwrap());
        let srv = serve(Arc::clone(&coord), "127.0.0.1:0").unwrap();
        let addr = srv.addr.to_string();

        // id 1: 500 prefill chunks + a 20k-token decode, cancelled mid-run
        let gen = {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let body = format!(
                    r#"{{"prompt":"{}","max_new_tokens":20000,"tenant":"alice"}}"#,
                    "x".repeat(4000)
                );
                http_request(&addr, "POST", "/generate", body.as_bytes()).unwrap()
            })
        };
        // wait until it is active so the cancel mark cannot go stale
        let t0 = std::time::Instant::now();
        while coord.metrics.gauge(names::SCHED_BATCHER_DEPTH) < 1.0 {
            assert!(t0.elapsed().as_secs() < 10, "request never became active");
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        let (st, body) = http_request(&addr, "POST", "/cancel", br#"{"id":1}"#).unwrap();
        assert_eq!(st, 200, "{}", String::from_utf8_lossy(&body));
        let (st, body) = gen.join().unwrap();
        assert_eq!(st, 499, "{}", String::from_utf8_lossy(&body));
        assert!(String::from_utf8_lossy(&body).contains("cancelled"));

        // id 2: a 1 ms deadline on heavy work expires whichever sweep
        // catches it (queued or mid-flight) — either way the client
        // sees 504
        let body = format!(
            r#"{{"prompt":"{}","max_new_tokens":20000,"deadline_ms":1}}"#,
            "x".repeat(4000)
        );
        let (st, body) = http_request(&addr, "POST", "/generate", body.as_bytes()).unwrap();
        assert_eq!(st, 504, "{}", String::from_utf8_lossy(&body));
        assert!(String::from_utf8_lossy(&body).contains("deadline"));
        assert_eq!(coord.metrics.counter("requests_cancelled"), 1);
        assert_eq!(coord.metrics.counter("requests_deadline_rejected"), 1);

        // a cancel body without an id is a 400
        let (st, _) = http_request(&addr, "POST", "/cancel", b"{}").unwrap();
        assert_eq!(st, 400);
    }

    /// Acceptance (tentpole): a session hibernated under admission
    /// pressure resumes bit-identically WITHOUT re-prefill, end-to-end
    /// over HTTP. Request A prefills, then B arrives needing pages the
    /// pool cannot hold alongside A: admission reclaims A page-granularly
    /// and escalates to whole-shard hibernation (low watermark sized so
    /// spilling quant pages alone cannot satisfy it). A faults its KV
    /// back from the cold tier mid-decode and its token stream matches a
    /// pressure-free baseline run exactly — zero evictions, so the
    /// recovery was spill/restore, never a destructive re-prefill.
    #[test]
    fn hibernated_session_resumes_bit_identically_over_http() {
        use super::super::router::pool_plan;
        use crate::metrics::names;
        const PROMPT_A: usize = 3000;
        const DECODE_A: usize = 256;
        let base = ServeConfig {
            engines: 1,
            queue_capacity: 64,
            max_new_tokens: DECODE_A,
            prefill_chunk_tokens: 8,
            pool: crate::pool::PoolConfig {
                pages: 1, // sized below
                page_tokens: 8,
                kv_dim: 2,
                high_watermark: 0.9,
                low_watermark: 0.1,
                ..crate::pool::PoolConfig::default()
            },
            ..ServeConfig::default()
        };
        let plan = pool_plan(&base, PROMPT_A, DECODE_A).pages;
        let prompt_a = "a".repeat(PROMPT_A);
        let body_a =
            format!(r#"{{"prompt":"{prompt_a}","max_new_tokens":{DECODE_A}}}"#);

        // Baseline: same geometry, no pressure (pool holds A four times
        // over, tiering off) — the reference token stream.
        let mut cfg = base.clone();
        cfg.pool.pages = plan * 4;
        let coord = Arc::new(Coordinator::with_mock(cfg, 0.2).unwrap());
        let srv = serve(Arc::clone(&coord), "127.0.0.1:0").unwrap();
        let (st, body) =
            http_request(&srv.addr.to_string(), "POST", "/generate", body_a.as_bytes())
                .unwrap();
        assert_eq!(st, 200, "{}", String::from_utf8_lossy(&body));
        let want = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        let want_tokens = want.get("tokens").unwrap().to_string();
        drop(srv);

        // Pressure run: pool holds 1.5× A's plan, cold tier enabled.
        let mut cfg = base.clone();
        cfg.pool.pages = plan + plan / 2;
        cfg.pool.spill_pages = 4 * plan;
        cfg.pool.spill_dir = std::env::temp_dir()
            .join(format!("qs-http-hibernate-{}", std::process::id()))
            .to_string_lossy()
            .into_owned();
        let coord = Arc::new(Coordinator::with_mock(cfg, 0.2).unwrap());
        let srv = serve(Arc::clone(&coord), "127.0.0.1:0").unwrap();
        let addr = srv.addr.to_string();
        let gen_a = {
            let addr = addr.clone();
            let body_a = body_a.clone();
            std::thread::spawn(move || {
                http_request(&addr, "POST", "/generate", body_a.as_bytes()).unwrap()
            })
        };
        // Wait until A's prefill has landed in the pool, then submit B —
        // big enough that admitting it must reclaim A's pages.
        let mgr = coord.pool().expect("pooled").clone();
        let t0 = std::time::Instant::now();
        while mgr.lock().unwrap().snapshot().pages_in_use < PROMPT_A / 8 {
            assert!(t0.elapsed().as_secs() < 30, "request A never prefilled");
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        let body_b = format!(r#"{{"prompt":"{}","max_new_tokens":16}}"#, "b".repeat(2400));
        let (st, body) =
            http_request(&addr, "POST", "/generate", body_b.as_bytes()).unwrap();
        assert_eq!(st, 200, "B admitted via reclaim: {}", String::from_utf8_lossy(&body));
        let (st, body) = gen_a.join().unwrap();
        assert_eq!(st, 200, "{}", String::from_utf8_lossy(&body));
        let got = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        assert_eq!(
            got.get("tokens").unwrap().to_string(),
            want_tokens,
            "hibernated session's tokens diverged from the pressure-free baseline"
        );

        // /stats pins the mechanism: pages moved through the cold tier and
        // faulted back; nothing was evicted, so nothing re-prefilled.
        let (st, body) = http_request(&addr, "GET", "/stats", b"").unwrap();
        assert_eq!(st, 200);
        let j = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        let pool = j.get("pool").expect("pool block");
        assert_eq!(pool.get("evictions").unwrap().as_usize(), Some(0));
        let tier = pool.get("tier").expect("tier block in /stats");
        assert_eq!(tier.get("enabled"), Some(&Json::Bool(true)));
        let stat = |name: &str| tier.get(name).unwrap().as_usize().unwrap();
        assert!(stat(names::SPILL_BYTES_WRITTEN) > 0, "A spilled to disk");
        assert!(stat(names::RESTORE_FAULTS) > 0, "A faulted back from disk");
        assert!(
            stat(names::SESSIONS_HIBERNATED_TOTAL) >= 1,
            "reclaim escalated to whole-shard hibernation"
        );
        assert_eq!(stat(names::HIBERNATED_SESSIONS), 0, "everyone resumed");
        mgr.lock().unwrap().check_integrity().unwrap();
    }

    /// Split one SSE frame chunk into (event kind, data JSON).
    fn parse_frame(chunk: &[u8]) -> (String, Json) {
        let text = std::str::from_utf8(chunk).unwrap();
        let mut kind = String::new();
        let mut data = String::new();
        for line in text.lines() {
            if let Some(v) = line.strip_prefix("event: ") {
                kind = v.to_string();
            } else if let Some(v) = line.strip_prefix("data: ") {
                data = v.to_string();
            }
        }
        (kind, Json::parse(&data).unwrap())
    }

    /// Tentpole acceptance: `"stream": true` returns SSE-style chunked
    /// frames — `prefill`, one `token` frame per verify cycle with cycle
    /// index / accepted run / cumulative total, then `done` carrying the
    /// final stats and a trailer with the streamed token count — and the
    /// concatenated streamed tokens are bit-identical to the buffered
    /// response for the same prompt.
    #[test]
    fn streamed_generate_matches_buffered_response() {
        use crate::util::httpd::http_open_stream;
        let cfg = ServeConfig {
            engines: 1,
            max_new_tokens: 48,
            prefill_chunk_tokens: 16,
            ..ServeConfig::default()
        };
        let coord = Arc::new(Coordinator::with_mock(cfg, 0.15).unwrap());
        let srv = serve(Arc::clone(&coord), "127.0.0.1:0").unwrap();
        let addr = srv.addr.to_string();
        let prompt = "s".repeat(64);
        let body = format!(r#"{{"prompt":"{prompt}","max_new_tokens":48}}"#);
        let (st, buf) = http_request(&addr, "POST", "/generate", body.as_bytes()).unwrap();
        assert_eq!(st, 200, "{}", String::from_utf8_lossy(&buf));
        let want = Json::parse(std::str::from_utf8(&buf).unwrap()).unwrap();
        let want_tokens = want.get("tokens").unwrap().to_string();

        let body = format!(r#"{{"prompt":"{prompt}","max_new_tokens":48,"stream":true}}"#);
        let (st, mut chunks) =
            http_open_stream(&addr, "POST", "/generate", body.as_bytes()).unwrap();
        assert_eq!(st, 200);
        let mut kinds: Vec<String> = Vec::new();
        let mut tokens: Vec<Json> = Vec::new();
        let mut cycle = 0usize;
        while let Some(chunk) = chunks.next_chunk().unwrap() {
            let (kind, data) = parse_frame(&chunk);
            match kind.as_str() {
                "token" => {
                    assert_eq!(data.get("cycle").unwrap().as_usize(), Some(cycle));
                    cycle += 1;
                    let run = data.get("tokens").unwrap().as_arr().unwrap();
                    assert_eq!(data.get("accepted").unwrap().as_usize(), Some(run.len()));
                    tokens.extend(run.iter().cloned());
                    assert_eq!(data.get("total").unwrap().as_usize(), Some(tokens.len()));
                }
                "done" => {
                    assert_eq!(data.get("total").unwrap().as_usize(), Some(tokens.len()));
                    assert!(
                        data.get("stats").unwrap().get("decode_secs").is_some(),
                        "done frame carries final stats"
                    );
                }
                _ => {}
            }
            kinds.push(kind);
        }
        assert_eq!(kinds.first().map(String::as_str), Some("prefill"));
        assert_eq!(kinds.last().map(String::as_str), Some("done"));
        assert!(
            kinds.iter().filter(|k| *k == "token").count() >= 2,
            "token runs streamed per cycle, not buffered into one frame: {kinds:?}"
        );
        assert_eq!(Json::arr(tokens.into_iter()).to_string(), want_tokens);
        assert_eq!(
            chunks
                .trailers()
                .iter()
                .find(|(k, _)| k == "x-total-tokens")
                .map(|(_, v)| v.as_str()),
            Some("48")
        );
        // both latency histograms went live at flush time
        use crate::metrics::names;
        assert!(coord.metrics.histogram(names::TTFT_US).count() >= 1);
        assert!(coord.metrics.histogram(names::INTER_TOKEN_GAP_US).count() >= 1);
    }

    /// Satellite + tentpole acceptance: the first token chunk reaches the
    /// client while the 200k-token generation is still running, and
    /// dropping the connection mid-stream cancels the request — session
    /// evicted at the round boundary, zero leaked pool pages,
    /// `requests_cancelled` bumped.
    #[test]
    fn mid_stream_disconnect_cancels_and_releases_pages() {
        use super::super::router::pool_plan;
        use crate::util::httpd::http_open_stream;
        const PROMPT: usize = 2000;
        const BUDGET: usize = 200_000;
        let mut cfg = ServeConfig {
            engines: 1,
            queue_capacity: 64,
            max_new_tokens: BUDGET,
            prefill_chunk_tokens: 8,
            pool: crate::pool::PoolConfig {
                pages: 1, // sized below
                page_tokens: 8,
                kv_dim: 2,
                high_watermark: 0.9,
                low_watermark: 0.7,
                ..crate::pool::PoolConfig::default()
            },
            ..ServeConfig::default()
        };
        let plan = pool_plan(&cfg, PROMPT, BUDGET).pages;
        cfg.pool.pages = plan + plan / 2;
        let coord = Arc::new(Coordinator::with_mock(cfg, 0.2).unwrap());
        let srv = serve(Arc::clone(&coord), "127.0.0.1:0").unwrap();
        let addr = srv.addr.to_string();
        let body = format!(
            r#"{{"prompt":"{}","max_new_tokens":{BUDGET},"stream":true}}"#,
            "x".repeat(PROMPT)
        );
        let (st, mut chunks) =
            http_open_stream(&addr, "POST", "/generate", body.as_bytes()).unwrap();
        assert_eq!(st, 200);
        loop {
            let chunk = chunks.next_chunk().unwrap().expect("stream ended early");
            if parse_frame(&chunk).0 == "token" {
                break;
            }
        }
        // the first chunk arrived long before the generation could finish
        assert_eq!(coord.metrics.counter("requests_completed"), 0);
        drop(chunks); // client disconnects mid-stream
        let t0 = std::time::Instant::now();
        while coord.metrics.counter("requests_cancelled") < 1 {
            assert!(t0.elapsed().as_secs() < 30, "disconnect never cancelled");
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        let mgr = coord.pool().expect("pooled").clone();
        let t0 = std::time::Instant::now();
        while mgr.lock().unwrap().pool().pages_in_use() != 0 {
            assert!(t0.elapsed().as_secs() < 30, "pages leaked after disconnect");
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        mgr.lock().unwrap().check_integrity().unwrap();
    }

    /// Satellite: the robustness counters ride the existing observability
    /// surfaces — `spill_retries`, `spill_io_errors`, and `tier_degraded`
    /// show up in the `/stats` pool tier block AND the metrics gauges, and
    /// a scheduler shed error maps to HTTP 503 (between the client-fault
    /// and server-fault families).
    #[test]
    fn robustness_gauges_surface_and_shed_maps_to_503() {
        use crate::metrics::names;
        assert_eq!(error_status(&format!("{}x", super::super::sched::SHED_PREFIX)), 503);
        assert_eq!(error_status("anything else"), 500);
        let cfg = ServeConfig {
            engines: 1,
            max_new_tokens: 12,
            pool: crate::pool::PoolConfig {
                pages: 32,
                page_tokens: 8,
                kv_dim: 2,
                ..crate::pool::PoolConfig::default()
            },
            ..ServeConfig::default()
        };
        let coord = Arc::new(Coordinator::with_mock(cfg, 0.1).unwrap());
        let srv = serve(Arc::clone(&coord), "127.0.0.1:0").unwrap();
        let addr = srv.addr.to_string();
        let (st, body) =
            http_request(&addr, "POST", "/generate", br#"{"prompt":"hello"}"#).unwrap();
        assert_eq!(st, 200, "{}", String::from_utf8_lossy(&body));
        let (st, body) = http_request(&addr, "GET", "/stats", b"").unwrap();
        assert_eq!(st, 200);
        let j = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        let tier = j.get("pool").unwrap().get("tier").expect("tier block");
        assert_eq!(tier.get(names::SPILL_RETRIES).and_then(Json::as_usize), Some(0));
        assert_eq!(tier.get(names::SPILL_IO_ERRORS).and_then(Json::as_usize), Some(0));
        assert_eq!(tier.get(names::TIER_DEGRADED), Some(&Json::Bool(false)));
        let gauges = j.get("gauges").unwrap();
        for key in [names::SPILL_RETRIES, names::SPILL_IO_ERRORS, names::TIER_DEGRADED] {
            assert!(gauges.get(key).is_some(), "gauge {key} missing from /stats");
        }
        let (st, body) = http_request(&addr, "GET", "/metrics", b"").unwrap();
        assert_eq!(st, 200);
        let text = String::from_utf8(body).unwrap();
        for key in [names::SPILL_RETRIES, names::SPILL_IO_ERRORS, names::TIER_DEGRADED] {
            assert!(text.contains(key), "{key} missing from /metrics exposition");
        }
    }

    #[test]
    fn bad_requests_rejected() {
        let (srv, _c) = start_mock_server();
        let addr = srv.addr.to_string();
        for (body, want) in [
            (&b"not json"[..], 400u16),
            (br#"{"no_prompt":1}"#, 400),
            (br#"{"prompt":""}"#, 400),
            (br#"{"prompt":"x","method":"bogus"}"#, 400),
        ] {
            let (st, _) = http_request(&addr, "POST", "/generate", body).unwrap();
            assert_eq!(st, want);
        }
        let (st, _) = http_request(&addr, "GET", "/nope", b"").unwrap();
        assert_eq!(st, 404);
    }
}
