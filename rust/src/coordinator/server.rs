//! HTTP JSON API over the coordinator.
//!
//! Endpoints:
//!   POST /generate  {"prompt": "text" | "tokens": [..], "max_new_tokens",
//!                    "method", "gamma"} -> tokens + text + stats
//!   GET  /stats     metrics snapshot (+ "pool": paged KV pool state —
//!                   pages in use/peak/committed, pressure, watermarks,
//!                   evictions, logical vs host cache bytes)
//!   GET  /healthz   liveness

use std::sync::Arc;

use crate::config::Method;
use crate::util::httpd::{Handler, Request, Response, Server};
use crate::util::json::Json;

use super::router::{Coordinator, RequestSpec};

pub fn make_handler(coord: Arc<Coordinator>) -> Handler {
    Arc::new(move |req: &Request| handle(&coord, req))
}

pub fn serve(coord: Arc<Coordinator>, bind: &str) -> std::io::Result<Server> {
    Server::start(bind, make_handler(coord))
}

fn handle(coord: &Coordinator, req: &Request) -> Response {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => Response::json(200, r#"{"ok":true}"#),
        ("GET", "/stats") => {
            coord.sync_pool_gauges();
            let mut snap = coord.metrics.snapshot();
            if let Json::Obj(map) = &mut snap {
                map.insert("pool".to_string(), coord.pool_json());
            }
            Response::json(200, snap.to_string())
        }
        ("POST", "/generate") => generate(coord, &req.body),
        _ => Response::json(404, r#"{"error":"not found"}"#),
    }
}

fn generate(coord: &Coordinator, body: &[u8]) -> Response {
    let text = match std::str::from_utf8(body) {
        Ok(t) => t,
        Err(_) => return Response::json(400, r#"{"error":"body not utf-8"}"#),
    };
    let j = match Json::parse(text) {
        Ok(j) => j,
        Err(e) => return Response::json(400, format!(r#"{{"error":"bad json: {e}"}}"#)),
    };
    // prompt: byte-level tokens from "prompt" text or explicit "tokens".
    let prompt: Vec<i32> = if let Some(toks) = j.get("tokens").and_then(Json::as_arr) {
        toks.iter().filter_map(|t| t.as_i64().map(|v| v as i32)).collect()
    } else if let Some(p) = j.get("prompt").and_then(Json::as_str) {
        p.bytes().map(|b| b as i32).collect()
    } else {
        return Response::json(400, r#"{"error":"need prompt or tokens"}"#);
    };
    if prompt.is_empty() {
        return Response::json(400, r#"{"error":"empty prompt"}"#);
    }
    let method = match j.get("method").and_then(Json::as_str) {
        Some(s) => match Method::parse(s) {
            Ok(m) => Some(m),
            Err(e) => return Response::json(400, format!(r#"{{"error":"{e}"}}"#)),
        },
        None => None,
    };
    let spec = RequestSpec {
        id: coord.next_id(),
        prompt,
        max_new_tokens: j
            .get("max_new_tokens")
            .and_then(Json::as_usize)
            .unwrap_or(coord.cfg.max_new_tokens),
        method,
        gamma: j.get("gamma").and_then(Json::as_usize),
    };
    let rx = match coord.submit(spec) {
        Ok(rx) => rx,
        Err((_, why)) => {
            return Response::json(
                429,
                Json::obj(vec![("error", Json::str(format!("load shed: {why}")))]).to_string(),
            )
        }
    };
    match rx.recv() {
        Ok(Ok(out)) => {
            let text: String = out
                .tokens
                .iter()
                .map(|&t| {
                    let b = (t as u32).min(255) as u8;
                    if b.is_ascii() && !b.is_ascii_control() || b == b'\n' {
                        b as char
                    } else {
                        '\u{fffd}'
                    }
                })
                .collect();
            Response::json(
                200,
                Json::obj(vec![
                    ("id", Json::num(out.id as f64)),
                    ("tokens", Json::arr(out.tokens.iter().map(|&t| Json::num(t as f64)))),
                    ("text", Json::str(text)),
                    ("bucket", Json::num(out.bucket as f64)),
                    ("acceptance_rate", Json::num(out.acceptance_rate)),
                    ("prefill_secs", Json::num(out.prefill_secs)),
                    ("decode_secs", Json::num(out.decode_secs)),
                    ("decode_tokens_per_sec", Json::num(out.decode_tokens_per_sec)),
                    ("queue_secs", Json::num(out.queue_secs)),
                ])
                .to_string(),
            )
        }
        Ok(Err(e)) => {
            // A pool-admission size rejection is the client's problem
            // (shrink the request), not a server fault.
            let status = if e.starts_with(super::router::TOO_LARGE_PREFIX) { 413 } else { 500 };
            Response::json(status, Json::obj(vec![("error", Json::str(e))]).to_string())
        }
        Err(_) => Response::json(500, r#"{"error":"engine dropped"}"#),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ServeConfig;
    use crate::util::httpd::http_request;

    fn start_mock_server() -> (Server, Arc<Coordinator>) {
        let cfg = ServeConfig { engines: 2, max_new_tokens: 16, ..ServeConfig::default() };
        let coord = Arc::new(Coordinator::with_mock(cfg, 0.1).unwrap());
        let srv = serve(Arc::clone(&coord), "127.0.0.1:0").unwrap();
        (srv, coord)
    }

    #[test]
    fn healthz_and_stats() {
        let (srv, _c) = start_mock_server();
        let addr = srv.addr.to_string();
        let (st, body) = http_request(&addr, "GET", "/healthz", b"").unwrap();
        assert_eq!(st, 200);
        assert!(String::from_utf8_lossy(&body).contains("ok"));
        let (st, _) = http_request(&addr, "GET", "/stats", b"").unwrap();
        assert_eq!(st, 200);
    }

    #[test]
    fn generate_roundtrip() {
        let (srv, _c) = start_mock_server();
        let addr = srv.addr.to_string();
        let (st, body) =
            http_request(&addr, "POST", "/generate", br#"{"prompt":"hello world"}"#).unwrap();
        assert_eq!(st, 200, "{}", String::from_utf8_lossy(&body));
        let j = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        assert_eq!(j.get("tokens").unwrap().as_arr().unwrap().len(), 16);
    }

    #[test]
    fn stats_expose_pool_state() {
        let cfg = ServeConfig {
            engines: 1,
            max_new_tokens: 12,
            pool: crate::pool::PoolConfig {
                pages: 32,
                page_tokens: 8,
                kv_dim: 2,
                high_watermark: 0.9,
                low_watermark: 0.7,
                ..crate::pool::PoolConfig::default()
            },
            ..ServeConfig::default()
        };
        let coord = Arc::new(Coordinator::with_mock(cfg, 0.1).unwrap());
        let srv = serve(Arc::clone(&coord), "127.0.0.1:0").unwrap();
        let addr = srv.addr.to_string();
        let (st, _) =
            http_request(&addr, "POST", "/generate", br#"{"prompt":"hello"}"#).unwrap();
        assert_eq!(st, 200);
        let (st, body) = http_request(&addr, "GET", "/stats", b"").unwrap();
        assert_eq!(st, 200);
        let j = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        let pool = j.get("pool").expect("pool block in /stats");
        assert_eq!(pool.get("pages_capacity").unwrap().as_usize(), Some(32));
        assert_eq!(pool.get("pages_in_use").unwrap().as_usize(), Some(0));
        assert!(pool.get("pages_peak").unwrap().as_usize().unwrap() > 0);
        assert!(j.get("gauges").is_some(), "metrics gauges in snapshot");
        // cache-traffic counters: a speculative decode read the draft and
        // target planes, so both call counters are live in /stats
        use crate::metrics::names;
        let calls = |name: &str| pool.get(name).unwrap().as_usize().unwrap();
        assert!(calls(names::DEQUANT_CALLS_DRAFT) > 0, "draft dequants counted");
        assert!(calls(names::DEQUANT_CALLS_TARGET) > 0, "target dequants counted");
        assert!(calls(names::QUANT_BYTES_READ_DRAFT) > 0);
        assert!(
            j.get("gauges").unwrap().get(names::DEQUANT_CALLS_DRAFT).is_some(),
            "traffic mirrored into metrics gauges"
        );
        // the shared quantization pool surfaces in the pool block and the
        // gauges (default config: one worker, so the pool ran no jobs)
        assert_eq!(calls(names::QUANT_POOL_WORKERS), 1);
        assert_eq!(calls(names::QUANT_POOL_JOBS), 0);
        assert_eq!(calls(names::QUANT_POOL_QUEUE_DEPTH), 0);
        assert!(
            j.get("gauges").unwrap().get(names::QUANT_POOL_JOBS).is_some(),
            "quant pool gauges mirrored into metrics"
        );
        // backpressure counter: present in the pool block and the gauges
        // (zero here — nothing deferred a prefill in this run)
        assert_eq!(calls(names::PREFILL_DEFERRALS), 0);
        assert!(
            j.get("gauges").unwrap().get(names::PREFILL_DEFERRALS).is_some(),
            "prefill_deferrals surfaced as a gauge"
        );
        // round-parallelism telemetry (serving path): the pool block and
        // the gauges both carry the step-worker and round-span keys, and
        // the per-engine batcher depth gauge exists for engine 0
        assert_eq!(calls(names::STEP_WORKERS), 1, "default = serial rounds");
        assert!(pool.get(names::ROUND_SPAN_US).is_some());
        assert!(pool.get(names::STEP_WORKERS_BUSY).is_some());
        assert!(
            pool.get(names::BATCHER_ROUNDS).unwrap().as_usize().unwrap() > 0,
            "the embedded batcher recorded its rounds"
        );
        let gauges = j.get("gauges").unwrap();
        for key in [names::STEP_WORKERS, names::ROUND_SPAN_US, names::STEP_WORKERS_BUSY] {
            assert!(gauges.get(key).is_some(), "gauge {key} missing");
        }
        assert!(
            gauges.get(&names::engine_batcher_depth(0)).is_some(),
            "per-engine batcher depth gauge missing"
        );
    }

    #[test]
    fn bad_requests_rejected() {
        let (srv, _c) = start_mock_server();
        let addr = srv.addr.to_string();
        for (body, want) in [
            (&b"not json"[..], 400u16),
            (br#"{"no_prompt":1}"#, 400),
            (br#"{"prompt":""}"#, 400),
            (br#"{"prompt":"x","method":"bogus"}"#, 400),
        ] {
            let (st, _) = http_request(&addr, "POST", "/generate", body).unwrap();
            assert_eq!(st, want);
        }
        let (st, _) = http_request(&addr, "GET", "/nope", b"").unwrap();
        assert_eq!(st, 404);
    }
}
