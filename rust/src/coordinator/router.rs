//! Router: request intake, fair admission, and the coordinator facade.
//!
//! Serving runs on the unified cross-engine scheduler
//! ([`super::sched`]): ONE driver thread forms global continuous-batching
//! rounds across all engines' sessions over ONE process-wide
//! work-stealing step pool — chunked prefill admission
//! (`prefill_chunk_tokens`), quant-pool backpressure, and parallel
//! stepping (`step_workers`) therefore all apply to real HTTP requests,
//! not just the examples. Outputs are bit-identical to the old
//! run-to-completion path: an `ActiveSession` with a fixed γ produces
//! exactly what `SpecEngine` produces, chunked prefill is
//! output-invisible, and stolen/parallel rounds are property-tested equal
//! to serial rounds.
//!
//! The router owns the intake side: requests enter a per-tenant weighted
//! fair queue (deficit round robin, `fair_weights`), are shed at submit
//! on queue overflow / tenant rate limits (`tenant_rate_limit`) / pool
//! saturation, carry optional deadlines (`request_deadline_ms` or
//! per-request `deadline_ms`), and can be cancelled mid-queue or
//! mid-flight via [`Coordinator::cancel`].
//!
//! When the paged KV pool is enabled (`cfg.pool.pages > 0`) the scheduler
//! runs admission control against it: every request gets a cost-model page
//! reservation; a reservation that can never fit is failed cleanly, one
//! that does not fit *right now* waits in the queue until a release (or an
//! LRU eviction of a preemptable session) frees pages — the pool never
//! overcommits, so concurrent long-context sessions cannot OOM each other.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread;

use anyhow::{ensure, Result};

use crate::config::{Method, ServeConfig};
use crate::coordinator::batcher::{ActiveSession, QuantBackpressure};
use crate::coordinator::sched::{lock_ok, scheduler_loop, FairQueue, Queued, CANCELLED_PREFIX};
use crate::costmodel::memory::pool_pages_for_request;
use crate::metrics::{names, Registry};
use crate::model::{mock_fb, Decoder, MockDecoder, MOCK_GAMMA_MAX, MOCK_VOCAB};
use crate::pool::{self, SharedSessionManager};
use crate::runtime::{Runtime, WeightSet, Weights};
use crate::spec::gamma::AimdGamma;
use crate::spec::Sampler;
use crate::stream::{StreamEvent, TokenSink};
use crate::trace::Tracer;
use crate::util::fault::FaultInjector;
use crate::util::now_secs;

/// Marker prefix for admission rejections that are the *client's* size
/// problem, not a server fault; the HTTP layer maps these to 413.
pub const TOO_LARGE_PREFIX: &str = "too_large: ";

/// One inbound generation request.
#[derive(Debug, Clone)]
pub struct RequestSpec {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    /// Per-request overrides (None = coordinator defaults).
    pub method: Option<Method>,
    pub gamma: Option<usize>,
    /// Fair-queue lane (None = the "default" tenant). Weight comes from
    /// `cfg.fair_weights` (1 when unlisted).
    pub tenant: Option<String>,
    /// SLO deadline override in milliseconds: None = `request_deadline_ms`
    /// from config, Some(0) = explicitly no deadline.
    pub deadline_ms: Option<u64>,
    /// Incremental response stream: when set, the scheduler flushes each
    /// round's newly committed tokens (plus prefill-done and a terminal
    /// `Done`/`Error`) into this sink in commit order. The buffered `done`
    /// channel still delivers the final `ResponseOut` either way; a send
    /// failure on the sink (receiver dropped) is treated as a client
    /// disconnect and cancels the request at the next round boundary.
    pub sink: Option<TokenSink>,
}

/// Completed generation.
///
/// Timing semantics under continuous batching: `prefill_secs` /
/// `decode_secs` are WALL time across the engine's shared scheduling
/// rounds (admission → prefill completion → finish), so a request that
/// decodes alongside other sessions in the same batcher reports elapsed
/// time, not exclusive compute time — `decode_tokens_per_sec` is
/// per-request *delivered* throughput (it shrinks as an engine multiplexes
/// more sessions even though aggregate throughput grows). The pre-batcher
/// router measured exclusive per-request time; compare histograms across
/// that change accordingly.
#[derive(Debug, Clone)]
pub struct ResponseOut {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub bucket: usize,
    pub acceptance_rate: f64,
    pub prefill_secs: f64,
    pub decode_secs: f64,
    pub decode_tokens_per_sec: f64,
    pub queue_secs: f64,
}

/// State shared between the intake side (submit/cancel) and the scheduler
/// driver thread: the fair queue, its wake-up condvar (also pulsed by pool
/// releases so Saturated admission waits unblock), and the stop flag.
pub(crate) struct Shared {
    pub(crate) queue: Mutex<FairQueue>,
    pub(crate) cv: Condvar,
    pub(crate) stop: AtomicBool,
}

/// How engines are backed.
pub enum EngineBackend {
    /// Real artifacts (None until `with_runtime`).
    Xla { rt: Arc<Runtime>, w_fp: Arc<Weights>, w_q4: Arc<Weights> },
    /// Deterministic mock (tests / `--mock`): draft error rate.
    Mock { draft_err: f64 },
}

pub struct Coordinator {
    pub cfg: ServeConfig,
    shared: Arc<Shared>,
    workers: Vec<thread::JoinHandle<()>>,
    pub metrics: Arc<Registry>,
    /// Request tracing: per-request span buffers + the flight recorder
    /// behind `/debug/requests`. A disabled tracer hands out no buffers
    /// and the serving path stays untraced.
    pub tracer: Arc<Tracer>,
    next_id: AtomicU64,
    backend: Arc<EngineBackend>,
    /// Shared paged KV pool; None when `cfg.pool.pages == 0`.
    pool: Option<SharedSessionManager>,
    /// Deterministic fault injector, parsed from `fault_spec` at startup
    /// and threaded through the pool, scheduler, and HTTP layers. None =
    /// faults disabled (the production default).
    fault: Option<Arc<FaultInjector>>,
}

impl Coordinator {
    pub fn with_runtime(cfg: ServeConfig, rt: Arc<Runtime>) -> Result<Coordinator> {
        let w_fp = Arc::new(Weights::load(&rt, WeightSet::Fp)?);
        let w_q4 = Arc::new(Weights::load(&rt, WeightSet::Q4)?);
        Self::start(cfg, EngineBackend::Xla { rt, w_fp, w_q4 })
    }

    pub fn with_mock(cfg: ServeConfig, draft_err: f64) -> Result<Coordinator> {
        Self::start(cfg, EngineBackend::Mock { draft_err })
    }

    fn start(cfg: ServeConfig, backend: EngineBackend) -> Result<Coordinator> {
        ensure!(
            cfg.step_workers >= 1,
            "step_workers must be >= 1 (use 1 for serial batcher rounds)"
        );
        ensure!(
            cfg.sched_tenants >= 1,
            "sched_tenants must be >= 1 (tenant lanes the fair queue can track)"
        );
        for (t, w) in &cfg.fair_weights {
            ensure!(
                *w >= 1,
                "fair_weights: tenant '{t}' has weight 0 (weights must be >= 1; \
                 omit the tenant to give it the default weight of 1)"
            );
        }
        let shared = Arc::new(Shared {
            queue: Mutex::new(FairQueue::new(&cfg)),
            cv: Condvar::new(),
            stop: AtomicBool::new(false),
        });
        let metrics = Arc::new(Registry::new());
        let tracer = Arc::new(Tracer::new(
            cfg.trace_enabled,
            cfg.trace_buffer_events,
            cfg.flight_recorder_requests,
        ));
        let backend = Arc::new(backend);
        // The pool currently backs the mock decoder only; the XLA session
        // manages its own device cache, so booking phantom pages for it
        // would reject requests against memory it never allocates.
        // Creating the manager also spins up the ONE process-wide
        // quantization pool (sized by `pool.quant_workers`; 0 is a
        // startup error, not a silent clamp).
        // Fault injection is validated here, not at config parse: a
        // malformed spec is a loud startup error, and an armed injector
        // announces itself so a production config can never inject
        // silently.
        let fault = if cfg.fault_spec.trim().is_empty() {
            None
        } else {
            let inj = FaultInjector::parse(cfg.fault_seed, &cfg.fault_spec).map_err(|e| {
                anyhow::anyhow!("invalid fault_spec {:?}: {e:#}", cfg.fault_spec)
            })?;
            eprintln!(
                "warning: fault injection ARMED (fault_seed {}, fault_spec {:?}); \
                 this process will synthesize deterministic failures",
                cfg.fault_seed, cfg.fault_spec
            );
            inj.enabled().then(|| Arc::new(inj))
        };
        let pool = if cfg.pool.pages > 0 {
            if matches!(&*backend, EngineBackend::Mock { .. }) {
                Some(pool::shared(cfg.pool.clone())?)
            } else {
                eprintln!(
                    "warning: paged KV pool requested (pool.pages = {}) but \
                     the XLA backend manages its own cache; pooling disabled",
                    cfg.pool.pages
                );
                None
            }
        } else {
            None
        };
        // The spill store consults the injector on slot I/O; installing it
        // before the first request means even the first reclaim is under
        // the configured schedule.
        if let (Some(mgr), Some(inj)) = (&pool, &fault) {
            lock_ok(mgr).set_fault_injector(Arc::clone(inj));
        }
        // ONE driver thread replaces the per-engine workers: it owns the
        // global batcher (engines × batcher_slots sessions) and the shared
        // work-stealing step pool (engines × step_workers threads).
        let workers = {
            let shared = Arc::clone(&shared);
            let metrics = Arc::clone(&metrics);
            let tracer = Arc::clone(&tracer);
            let backend = Arc::clone(&backend);
            let pool = pool.clone();
            let fault = fault.clone();
            let cfg2 = cfg.clone();
            vec![thread::Builder::new().name("qs-sched-drive".into()).spawn(
                move || scheduler_loop(cfg2, shared, metrics, tracer, backend, pool, fault),
            )?]
        };
        Ok(Coordinator {
            cfg,
            shared,
            workers,
            metrics,
            tracer,
            next_id: AtomicU64::new(1),
            backend,
            pool,
            fault,
        })
    }

    pub fn next_id(&self) -> u64 {
        self.next_id.fetch_add(1, Ordering::Relaxed)
    }

    /// Enqueue a request into its tenant's fair-queue lane; Err (with the
    /// spec and a reason) when shedding load: queue full, tenant over its
    /// rate limit, the lane table full of backlogged tenants, or — with
    /// the paged pool enabled — pool pressure already at the high
    /// watermark with a backlog (admitting more arrivals could only grow
    /// the queue).
    pub fn submit(
        &self,
        spec: RequestSpec,
    ) -> Result<mpsc::Receiver<Result<ResponseOut, String>>, (RequestSpec, &'static str)> {
        let (tx, rx) = mpsc::channel();
        {
            let mut q = lock_ok(&self.shared.queue);
            if q.len() >= self.cfg.queue_capacity {
                self.metrics.incr("requests_shed", 1);
                return Err((spec, "queue full"));
            }
            if let Some(mgr) = &self.pool {
                let m = lock_ok(mgr);
                let saturated = m.committed_pages() >= m.high_pages();
                if saturated && !q.is_empty() {
                    drop(m);
                    self.metrics.incr("requests_shed", 1);
                    self.metrics.incr("requests_shed_pool", 1);
                    return Err((spec, "KV pool saturated"));
                }
            }
            let tenant = spec.tenant.clone().unwrap_or_else(|| "default".to_string());
            let deadline_ms = spec.deadline_ms.unwrap_or(self.cfg.request_deadline_ms);
            let deadline = (deadline_ms > 0)
                .then(|| std::time::Instant::now() + std::time::Duration::from_millis(deadline_ms));
            let job = Queued { spec, tenant, enqueued_at: now_secs(), deadline, done: tx };
            if let Err((job, why)) = q.push(job) {
                self.metrics.incr("requests_shed", 1);
                if why == "rate limited" {
                    self.metrics.incr("requests_rate_limited", 1);
                }
                return Err((job.spec, why));
            }
            self.metrics.incr("requests_enqueued", 1);
        }
        self.shared.cv.notify_one();
        Ok(rx)
    }

    /// Cancel a request by id (client disconnect, user abort). A
    /// still-queued request is removed and answered immediately; an active
    /// one is marked and evicted by the scheduler at the next round
    /// boundary — either way its pool pages are released and admission
    /// waiters are woken. Cancelling an unknown or completed id is a
    /// no-op.
    pub fn cancel(&self, id: u64) {
        let queued = lock_ok(&self.shared.queue).cancel(id);
        if let Some(job) = queued {
            self.metrics.incr("requests_cancelled", 1);
            let msg = format!("{CANCELLED_PREFIX}request {id} cancelled while queued");
            if let Some(sink) = &job.spec.sink {
                let _ = sink.send(StreamEvent::Error { message: msg.clone() });
            }
            let _ = job.done.send(Err(msg));
        }
        self.shared.cv.notify_all();
    }

    /// Convenience: submit and block for the result.
    pub fn generate(&self, spec: RequestSpec) -> Result<ResponseOut> {
        let rx = self
            .submit(spec)
            .map_err(|(_, why)| anyhow::anyhow!("load shed: {why}"))?;
        rx.recv()
            .map_err(|_| anyhow::anyhow!("engine dropped request"))?
            .map_err(|e| anyhow::anyhow!(e))
    }

    pub fn queue_len(&self) -> usize {
        lock_ok(&self.shared.queue).len()
    }

    /// The shared paged KV pool (None when disabled). Exposed so benches
    /// and examples can seed preemptable sessions or read pool state.
    pub fn pool(&self) -> Option<&SharedSessionManager> {
        self.pool.as_ref()
    }

    /// The armed fault injector (None when `fault_spec` is empty).
    /// Exposed so the HTTP layer threads the same schedule through its
    /// socket-write fault point and so benches can read fire counts.
    pub fn fault_injector(&self) -> Option<&Arc<FaultInjector>> {
        self.fault.as_ref()
    }

    /// Backpressure policy for an embedded `StepBatcher`, built from this
    /// coordinator's pool and its `quant_queue_soft_limit` knob (None when
    /// pooling is disabled). The engine workers build the same policy for
    /// their own batchers; examples and benches wire this into theirs so
    /// the config knob is the single source of the limit.
    pub fn quant_backpressure(&self) -> Option<QuantBackpressure> {
        self.pool
            .as_ref()
            .map(|mgr| QuantBackpressure::for_pool(mgr.clone(), self.cfg.quant_queue_soft_limit))
    }

    /// Refresh the pool gauges in the metrics registry (called before each
    /// `/stats` snapshot and after request completion).
    pub fn sync_pool_gauges(&self) {
        if let Some(mgr) = &self.pool {
            sync_pool_gauges(mgr, &self.metrics);
        }
    }

    /// Pool state for `/stats` (`null` when pooling is disabled).
    pub fn pool_json(&self) -> crate::util::json::Json {
        match &self.pool {
            None => crate::util::json::Json::Null,
            Some(mgr) => lock_ok(mgr).stats_json(),
        }
    }

    pub fn shutdown(&mut self) {
        self.shared.stop.store(true, Ordering::Relaxed);
        self.shared.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }

    #[allow(dead_code)]
    fn backend(&self) -> &EngineBackend {
        &self.backend
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown();
    }
}

pub(crate) fn sync_pool_gauges(mgr: &SharedSessionManager, metrics: &Registry) {
    // ONE manager lock per scrape: everything below reads the snapshot.
    let s = lock_ok(mgr).snapshot();
    metrics.set_gauge("pool_pages_capacity", s.pages_capacity as f64);
    metrics.set_gauge("pool_pages_in_use", s.pages_in_use as f64);
    metrics.set_gauge("pool_pages_peak", s.pages_peak as f64);
    metrics.set_gauge("pool_pressure", s.pressure);
    metrics.set_gauge("pool_sessions_active", s.sessions_active as f64);
    metrics.set_gauge("pool_evictions", s.evictions as f64);
    // quantized-cache read traffic, split draft (INT4) vs target (INT8)
    let t = s.traffic;
    metrics.set_gauge(names::DEQUANT_CALLS_DRAFT, t.dequant_calls_draft as f64);
    metrics.set_gauge(names::DEQUANT_CALLS_TARGET, t.dequant_calls_target as f64);
    metrics.set_gauge(names::QUANT_BYTES_READ_DRAFT, t.bytes_read_draft as f64);
    metrics.set_gauge(names::QUANT_BYTES_READ_TARGET, t.bytes_read_target as f64);
    // the process-wide shared quantization pool (one per coordinator)
    metrics.set_gauge(names::QUANT_POOL_WORKERS, s.quant_workers as f64);
    metrics.set_gauge(names::QUANT_POOL_JOBS, s.quant_jobs as f64);
    metrics.set_gauge(names::QUANT_POOL_QUEUE_DEPTH, s.quant_queue_depth as f64);
    // prefill chunks deferred under quant-pool backpressure
    metrics.set_gauge(names::PREFILL_DEFERRALS, s.prefill_deferrals as f64);
    // round-parallelism telemetry recorded by the engines' batchers
    metrics.set_gauge(names::STEP_WORKERS, s.step_workers as f64);
    metrics.set_gauge(names::STEP_WORKERS_BUSY, s.step_workers_busy as f64);
    metrics.set_gauge(names::ROUND_SPAN_US, s.round_span_us);
    metrics.set_gauge(names::BATCHER_ROUNDS, s.rounds as f64);
    // cumulative per-phase round time (prefill vs decode vs quant-wait)
    metrics.set_gauge(names::ROUND_PREFILL_US, s.round_phases.prefill_us);
    metrics.set_gauge(names::ROUND_DECODE_US, s.round_phases.decode_us);
    metrics.set_gauge(names::ROUND_QUANT_WAIT_US, s.round_phases.quant_wait_us);
    // the tier hierarchy: hot/warm residency, cold-tier traffic,
    // hibernation (gauges are harmless zeros when tiering is off)
    metrics.set_gauge(names::TIER_HOT_PAGES, s.tier_hot_pages as f64);
    metrics.set_gauge(names::TIER_WARM_PAGES, s.tier_warm_pages as f64);
    metrics.set_gauge(names::TIER_SPILLED_PAGES, s.tier.spilled_pages as f64);
    metrics.set_gauge(names::SPILL_BYTES_WRITTEN, s.tier.spill_bytes_written as f64);
    metrics.set_gauge(names::RESTORE_FAULTS, s.tier.restore_faults as f64);
    metrics.set_gauge(names::FETCH_AHEAD_HITS, s.tier.fetch_ahead_hits as f64);
    metrics.set_gauge(names::HIBERNATED_SESSIONS, s.hibernated_sessions as f64);
    metrics.set_gauge(names::SESSIONS_HIBERNATED_TOTAL, s.tier.hibernations as f64);
    // robustness: cold-tier write retries / hard I/O errors, and the
    // tiering circuit breaker (1 = degraded to evict-only reclaim)
    metrics.set_gauge(names::SPILL_RETRIES, s.tier.spill_retries as f64);
    metrics.set_gauge(names::SPILL_IO_ERRORS, s.tier.spill_io_errors as f64);
    metrics.set_gauge(names::TIER_DEGRADED, if s.tier_degraded { 1.0 } else { 0.0 });
}

/// Pool geometry plan for one mock request. Reservation (admission) and
/// quantized-region cap (decoder) are derived in ONE place so they can
/// never disagree: a request admission accepts always has the cache
/// capacity its decode can reach.
#[derive(Debug, Clone, Copy)]
pub(crate) struct PoolPlan {
    /// Pages booked at admission.
    pub(crate) pages: usize,
    /// Quantized-region token cap handed to the paged decoder.
    cap_tokens: usize,
}

pub(crate) fn pool_plan(cfg: &ServeConfig, prompt_len: usize, max_new: usize) -> PoolPlan {
    let g = cfg.pool.page_tokens.max(1);
    let fb = mock_fb(g, MOCK_GAMMA_MAX);
    let fp_pages = (fb + g - 1) / g;
    let pages = pool_pages_for_request(prompt_len, max_new, g, fb);
    PoolPlan { pages, cap_tokens: pages.saturating_sub(fp_pages) * g }
}

/// Construct the request's decoder (XLA session or pooled/plain mock) and
/// pick its context bucket.
fn build_decoder(
    cfg: &ServeConfig,
    backend: &EngineBackend,
    spec: &RequestSpec,
    pool: Option<&SharedSessionManager>,
    method: Method,
) -> Result<(Box<dyn Decoder>, usize)> {
    match backend {
        EngineBackend::Xla { rt, w_fp, w_q4 } => {
            let bucket = rt
                .manifest
                .bucket_for(spec.prompt.len())
                .ok_or_else(|| {
                    anyhow::anyhow!(
                        "prompt of {} tokens exceeds largest bucket {:?}",
                        spec.prompt.len(),
                        rt.manifest.buckets.iter().max()
                    )
                })?;
            let session = crate::model::xla_session::XlaSession::new(
                Arc::clone(rt),
                method,
                cfg.quant_mode,
                bucket,
                Arc::clone(w_fp),
                Arc::clone(w_q4),
            )?;
            Ok((Box::new(session), bucket))
        }
        EngineBackend::Mock { draft_err } => {
            let mut m = match pool {
                // Session already admitted by the engine loop; the KV cache
                // lives in the shared arena, capped by the reservation.
                Some(mgr) => {
                    let plan = pool_plan(cfg, spec.prompt.len(), spec.max_new_tokens);
                    MockDecoder::with_pool(
                        MOCK_VOCAB,
                        MOCK_GAMMA_MAX,
                        *draft_err,
                        mgr.clone(),
                        spec.id,
                        plan.cap_tokens,
                    )?
                }
                None => MockDecoder::new(MOCK_VOCAB, MOCK_GAMMA_MAX, *draft_err),
            };
            m.force_method(method);
            Ok((Box::new(m), spec.prompt.len().max(1)))
        }
    }
}

/// Build the batcher session for one request: decoder + padded prompt +
/// seeded sampler, admitted in `Prefilling` state (chunked when
/// `prefill_chunk_tokens` is set, otherwise the whole prompt as one
/// first-round chunk) so prefill work runs inside scheduling rounds.
/// With `adaptive_gamma`, γ is AIMD-controlled as before.
pub(crate) fn build_session(
    cfg: &ServeConfig,
    backend: &EngineBackend,
    spec: &RequestSpec,
    pool: Option<&SharedSessionManager>,
) -> Result<(ActiveSession, usize)> {
    let method = spec.method.unwrap_or(cfg.method);
    let gamma = spec.gamma.unwrap_or(cfg.gamma);
    let (decoder, bucket) = build_decoder(cfg, backend, spec, pool, method)?;
    let gmax = decoder.gamma_max();
    // Pad / truncate the prompt to the bucket (left-pad with newline 0x0A;
    // long prompts keep their tail — the recent context matters most).
    let prompt = pad_prompt(&spec.prompt, bucket, matches!(backend, EngineBackend::Xla { .. }));
    let sampler = Sampler::new(cfg.sampling.temperature, cfg.sampling.seed ^ spec.id);
    let mut sess = ActiveSession::admit_chunked(
        spec.id,
        decoder,
        sampler,
        gamma,
        &prompt,
        spec.max_new_tokens,
        cfg.prefill_chunk_tokens,
    );
    if cfg.adaptive_gamma && method != Method::Autoregressive {
        sess = sess.with_controller(Box::new(AimdGamma::new(gamma.min(gmax), 1, gmax)));
    }
    Ok((sess, bucket))
}

/// Left-pad (with 0x0A) or head-truncate a prompt to exactly `bucket`
/// tokens. Only applied for the XLA backend (static shapes).
pub fn pad_prompt(prompt: &[i32], bucket: usize, pad: bool) -> Vec<i32> {
    if !pad {
        return prompt.to_vec();
    }
    if prompt.len() >= bucket {
        prompt[prompt.len() - bucket..].to_vec()
    } else {
        let mut out = vec![0x0A; bucket - prompt.len()];
        out.extend_from_slice(prompt);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn mock_coordinator(engines: usize, queue: usize) -> Coordinator {
        let cfg = ServeConfig {
            engines,
            queue_capacity: queue,
            max_new_tokens: 24,
            ..ServeConfig::default()
        };
        Coordinator::with_mock(cfg, 0.2).unwrap()
    }

    fn req(id: u64, len: usize) -> RequestSpec {
        RequestSpec {
            id,
            prompt: (0..len as i32).collect(),
            max_new_tokens: 24,
            method: None,
            gamma: None,
            tenant: None,
            deadline_ms: None,
            sink: None,
        }
    }

    #[test]
    fn serves_requests_end_to_end() {
        let c = mock_coordinator(2, 16);
        let r = c.generate(req(1, 8)).unwrap();
        assert_eq!(r.tokens.len(), 24);
        assert!(r.acceptance_rate > 0.0);
        assert_eq!(c.metrics.counter("requests_completed"), 1);
    }

    #[test]
    fn zero_step_workers_is_a_startup_error() {
        let cfg = ServeConfig { step_workers: 0, ..ServeConfig::default() };
        let err = Coordinator::with_mock(cfg, 0.1).unwrap_err().to_string();
        assert!(err.contains("step_workers"), "got: {err}");
    }

    #[test]
    fn concurrent_requests_all_complete() {
        let c = Arc::new(mock_coordinator(4, 64));
        let mut rxs = Vec::new();
        for i in 0..32 {
            rxs.push(c.submit(req(i, 4 + (i as usize % 8))).unwrap());
        }
        for rx in rxs {
            let out = rx.recv().unwrap().unwrap();
            assert_eq!(out.tokens.len(), 24);
        }
        assert_eq!(c.metrics.counter("requests_completed"), 32);
    }

    /// Parallel stepping on the serving path: outputs are identical to the
    /// serial-round coordinator, request for request.
    #[test]
    fn parallel_engine_output_identical_to_serial_engine() {
        let mk = |workers: usize| ServeConfig {
            engines: 1,
            queue_capacity: 64,
            max_new_tokens: 24,
            step_workers: workers,
            batcher_slots: 4,
            ..ServeConfig::default()
        };
        let serial = Coordinator::with_mock(mk(1), 0.2).unwrap();
        let parallel = Coordinator::with_mock(mk(3), 0.2).unwrap();
        for i in 0..6u64 {
            let a = serial.generate(req(i, 4 + (i as usize % 5))).unwrap();
            let b = parallel.generate(req(i, 4 + (i as usize % 5))).unwrap();
            assert_eq!(a.tokens, b.tokens, "request {i}");
            assert_eq!(a.acceptance_rate, b.acceptance_rate, "request {i}");
        }
        // the serving path surfaced its round telemetry: the shared
        // stealing pool is sized engines × step_workers = 3
        assert_eq!(parallel.metrics.gauge(names::STEP_WORKERS), 3.0);
        assert_eq!(parallel.metrics.gauge(names::SCHED_POOL_WORKERS), 3.0);
        assert!(parallel.metrics.gauge(names::ROUND_SPAN_US) > 0.0);
        assert!(
            parallel
                .metrics
                .snapshot()
                .to_string()
                .contains(names::SCHED_BATCHER_DEPTH),
            "global batcher depth gauge exported"
        );
    }

    #[test]
    fn sheds_load_when_queue_full() {
        // 1 engine, tiny queue, many requests: some must be shed.
        let c = mock_coordinator(1, 2);
        let mut shed = 0;
        let mut rxs = Vec::new();
        for i in 0..40 {
            match c.submit(req(i, 6)) {
                Ok(rx) => rxs.push(rx),
                Err(_) => shed += 1,
            }
        }
        for rx in rxs {
            let _ = rx.recv();
        }
        assert!(shed > 0, "expected load shedding");
        assert_eq!(
            c.metrics.counter("requests_shed"),
            shed as u64
        );
    }

    #[test]
    fn per_request_method_override() {
        let c = mock_coordinator(1, 8);
        let mut r = req(9, 4);
        r.method = Some(Method::Autoregressive);
        let out = c.generate(r).unwrap();
        assert_eq!(out.acceptance_rate, 0.0); // AR path drafts nothing
    }

    #[test]
    fn pad_prompt_shapes() {
        assert_eq!(pad_prompt(&[1, 2], 4, true), vec![0x0A, 0x0A, 1, 2]);
        assert_eq!(pad_prompt(&[1, 2, 3, 4, 5], 3, true), vec![3, 4, 5]);
        assert_eq!(pad_prompt(&[1, 2], 4, false), vec![1, 2]);
    }

    #[test]
    fn adaptive_gamma_mode_serves() {
        let cfg = ServeConfig {
            engines: 1,
            max_new_tokens: 40,
            adaptive_gamma: true,
            ..ServeConfig::default()
        };
        let c = Coordinator::with_mock(cfg, 0.1).unwrap();
        let out = c.generate(req(77, 6)).unwrap();
        assert_eq!(out.tokens.len(), 24); // req() helper's budget
        assert!(out.acceptance_rate > 0.5);
    }

    /// `prefill_chunk_tokens` routes the serving path through chunked
    /// prefill; outputs must match the monolithic path exactly.
    #[test]
    fn chunked_prefill_serving_matches_monolithic() {
        let mk = |chunk: usize| ServeConfig {
            engines: 1,
            max_new_tokens: 24,
            adaptive_gamma: true,
            prefill_chunk_tokens: chunk,
            ..ServeConfig::default()
        };
        let mono = Coordinator::with_mock(mk(0), 0.1).unwrap();
        let want = mono.generate(req(5, 21)).unwrap();
        for chunk in [1usize, 7, 8, 64] {
            let c = Coordinator::with_mock(mk(chunk), 0.1).unwrap();
            let out = c.generate(req(5, 21)).unwrap();
            assert_eq!(out.tokens, want.tokens, "chunk {chunk}");
            assert_eq!(out.acceptance_rate, want.acceptance_rate, "chunk {chunk}");
        }
    }

    fn pool_coordinator(engines: usize, pages: usize) -> Coordinator {
        let cfg = ServeConfig {
            engines,
            queue_capacity: 64,
            max_new_tokens: 24,
            pool: crate::pool::PoolConfig {
                pages,
                page_tokens: 8,
                kv_dim: 2,
                high_watermark: 0.9,
                low_watermark: 0.7,
                ..crate::pool::PoolConfig::default()
            },
            ..ServeConfig::default()
        };
        Coordinator::with_mock(cfg, 0.2).unwrap()
    }

    /// The `quant_queue_soft_limit` knob is consumed: a pooled coordinator
    /// hands embedders a backpressure policy carrying the configured
    /// limit; an unpooled one hands back None.
    #[test]
    fn quant_backpressure_carries_configured_soft_limit() {
        let cfg = ServeConfig {
            engines: 1,
            quant_queue_soft_limit: 5,
            pool: crate::pool::PoolConfig { pages: 16, ..crate::pool::PoolConfig::default() },
            ..ServeConfig::default()
        };
        let c = Coordinator::with_mock(cfg, 0.1).unwrap();
        let bp = c.quant_backpressure().expect("pooled coordinator");
        assert_eq!(bp.soft_limit, 5);
        let plain = mock_coordinator(1, 4);
        assert!(plain.quant_backpressure().is_none(), "no pool, no policy");
    }

    #[test]
    fn pooled_requests_complete_and_release() {
        let c = pool_coordinator(2, 64);
        for i in 0..4 {
            let r = c.generate(req(i, 6)).unwrap();
            assert_eq!(r.tokens.len(), 24);
            assert!(r.acceptance_rate > 0.0);
        }
        let mgr = c.pool().expect("pool enabled");
        let m = mgr.lock().unwrap();
        assert_eq!(m.pool().pages_in_use(), 0, "all sessions released");
        assert!(m.pool().peak_pages_in_use() > 0);
        assert!(m.pool().peak_pages_in_use() <= 64);
        // embedded batchers reported rounds through the manager
        assert!(m.rounds() > 0, "serving rounds recorded");
    }

    #[test]
    fn pooled_output_identical_to_unpooled() {
        let pooled = pool_coordinator(1, 64);
        let plain = mock_coordinator(1, 16);
        let a = pooled.generate(req(3, 8)).unwrap();
        let b = plain.generate(req(3, 8)).unwrap();
        assert_eq!(a.tokens, b.tokens, "pool must not change decode output");
        assert_eq!(a.acceptance_rate, b.acceptance_rate);
    }

    #[test]
    fn too_large_request_fails_cleanly() {
        // 16-page pool (ceiling 14); a 200-token prompt needs ~31 pages.
        let c = pool_coordinator(1, 16);
        let err = c.generate(req(1, 200)).unwrap_err().to_string();
        assert!(err.contains("pool"), "got: {err}");
        assert_eq!(c.metrics.counter("requests_rejected_too_large"), 1);
        // the pool is untouched and the next sane request still works
        assert_eq!(c.generate(req(2, 6)).unwrap().tokens.len(), 24);
    }

    #[test]
    fn saturated_pool_queues_until_release() {
        // Each 6-token request reserves 9 pages; a 20-page pool (ceiling
        // 18) fits two at a time, so with 4 engines racing, admissions
        // must serialize (Saturated → head-of-line wait) — and all
        // complete, none OOM or get lost.
        let c = Arc::new(pool_coordinator(4, 20));
        let mut rxs = Vec::new();
        for i in 0..6 {
            // submit() may shed under pool pressure depending on worker
            // timing; retry until accepted so the test is deterministic.
            let mut spec = req(i, 6);
            let rx = loop {
                match c.submit(spec) {
                    Ok(rx) => break rx,
                    Err((s, _)) => {
                        spec = s;
                        thread::sleep(Duration::from_millis(2));
                    }
                }
            };
            rxs.push(rx);
        }
        for rx in rxs {
            let out = rx.recv().unwrap().unwrap();
            assert_eq!(out.tokens.len(), 24);
        }
        assert_eq!(c.metrics.counter("requests_completed"), 6);
        let mgr = c.pool().unwrap();
        let m = mgr.lock().unwrap();
        assert!(m.pool().peak_pages_in_use() <= 20, "hard bound held");
        assert_eq!(m.pool().pages_in_use(), 0);
    }

    /// Acceptance: exactly one quantization pool exists per coordinator.
    /// Concurrent pooled requests with multi-worker quantization all fan
    /// out over the same shared pool: `quant_pool_jobs` sums every
    /// request's prefill groups (4 groups per 40-token prompt) and the
    /// worker gauge stays at `pool.quant_workers`.
    #[test]
    fn one_quant_pool_serves_all_requests() {
        let cfg = ServeConfig {
            engines: 2,
            queue_capacity: 64,
            max_new_tokens: 24,
            pool: crate::pool::PoolConfig {
                pages: 128,
                page_tokens: 8,
                kv_dim: 2,
                high_watermark: 1.0,
                low_watermark: 1.0,
                quant_workers: 2,
                ..crate::pool::PoolConfig::default()
            },
            ..ServeConfig::default()
        };
        let c = Coordinator::with_mock(cfg, 0.1).unwrap();
        let rxs: Vec<_> = (0..4).map(|i| c.submit(req(i, 40)).unwrap()).collect();
        for rx in rxs {
            let out = rx.recv().unwrap().unwrap();
            assert_eq!(out.tokens.len(), 24);
        }
        c.sync_pool_gauges();
        assert_eq!(c.metrics.gauge(names::QUANT_POOL_WORKERS), 2.0);
        assert_eq!(
            c.metrics.gauge(names::QUANT_POOL_JOBS),
            16.0,
            "4 requests x 4 prefill groups, all through the one shared pool"
        );
        assert_eq!(c.metrics.gauge(names::QUANT_POOL_QUEUE_DEPTH), 0.0);
    }

    /// A completed request shows up in the flight recorder (before the
    /// response is delivered — no retirement race) and its completion
    /// feeds the acceptance/phase histograms.
    #[test]
    fn completed_request_lands_in_flight_recorder() {
        let c = mock_coordinator(1, 8); // tracing on by default
        assert!(c.tracer.enabled());
        let r = c.generate(req(1, 8)).unwrap();
        assert_eq!(r.tokens.len(), 24);
        assert_eq!(c.tracer.recorder().len(), 1);
        let js = c.tracer.to_json().to_string();
        assert!(js.contains("\"events\""), "timeline serialized: {js}");
        assert!(
            c.metrics.histogram(names::ACCEPTANCE_RATE_PCT).count() == 1,
            "per-request acceptance rate recorded at completion"
        );
        assert!(c.metrics.histogram(names::PHASE_VERIFY_US).count() > 0);
    }

    /// `trace_enabled: false` turns the whole subsystem off: no buffers,
    /// an empty recorder, identical decode output.
    #[test]
    fn disabled_tracing_serves_identically_with_empty_recorder() {
        let cfg = ServeConfig {
            engines: 1,
            queue_capacity: 8,
            max_new_tokens: 24,
            trace_enabled: false,
            ..ServeConfig::default()
        };
        let c = Coordinator::with_mock(cfg, 0.2).unwrap();
        let base = mock_coordinator(1, 8);
        let a = c.generate(req(4, 8)).unwrap();
        let b = base.generate(req(4, 8)).unwrap();
        assert_eq!(a.tokens, b.tokens, "tracing must not perturb decode");
        assert!(!c.tracer.enabled());
        assert!(c.tracer.recorder().is_empty());
        assert_eq!(c.metrics.histogram(names::ACCEPTANCE_RATE_PCT).count(), 0);
    }

    /// A malformed `fault_spec` is a loud startup error; a valid spec arms
    /// the injector (exposed through the coordinator) and a zero-rate site
    /// never perturbs serving.
    #[test]
    fn fault_spec_validated_at_startup() {
        let bad = ServeConfig {
            engines: 1,
            fault_spec: "warp_core_breach:10".to_string(),
            ..ServeConfig::default()
        };
        let err = Coordinator::with_mock(bad, 0.1).unwrap_err().to_string();
        assert!(err.contains("fault_spec"), "got: {err}");
        let cfg = ServeConfig {
            engines: 1,
            queue_capacity: 8,
            max_new_tokens: 24,
            fault_seed: 42,
            fault_spec: "decode_error:0".to_string(),
            ..ServeConfig::default()
        };
        let c = Coordinator::with_mock(cfg, 0.1).unwrap();
        let inj = c.fault_injector().expect("spec armed the injector").clone();
        assert_eq!(c.generate(req(1, 8)).unwrap().tokens.len(), 24);
        assert_eq!(inj.total_fires(), 0, "a 0-permille site never fires");
    }

    /// Property: with random request sizes and queue capacities, every
    /// submitted request is either completed or shed — none lost.
    #[test]
    fn prop_no_request_lost() {
        use crate::util::prop::{check, Config};
        check::<Vec<usize>, _>(
            Config { cases: 12, size: 24, ..Config::default() },
            |lens| {
                let c = mock_coordinator(2, 8);
                let mut got = 0usize;
                let mut shed = 0usize;
                let mut rxs = Vec::new();
                for (i, &l) in lens.iter().enumerate() {
                    match c.submit(req(i as u64, l % 16 + 1)) {
                        Ok(rx) => rxs.push(rx),
                        Err(_) => shed += 1,
                    }
                }
                for rx in rxs {
                    if rx.recv().map(|r| r.is_ok()).unwrap_or(false) {
                        got += 1;
                    }
                }
                got + shed == lens.len()
            },
        );
    }
}
