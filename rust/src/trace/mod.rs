//! Request-scoped tracing for the serving path.
//!
//! Three pieces, sized so the decode hot path stays allocation-free
//! (pinned by `tests/alloc_hotpath.rs`):
//!
//! * [`TraceBuf`] — a per-request event buffer, fully preallocated at
//!   admission (`trace_buffer_events` slots). Recording an event is a
//!   `fetch_add` on the write cursor plus plain atomic stores into the
//!   claimed slot: no locks, no allocation, monotonic µs timestamps
//!   anchored to the buffer's creation `Instant`. Events past capacity are
//!   counted in `dropped` rather than grown into.
//! * [`SpanScope`] — a thread-local RAII guard binding the current
//!   request's `TraceBuf` for the duration of a step, so deep layers
//!   (`PagedKvCache::flush`, `SessionManager::evict_lru`) can attribute
//!   events via [`emit`] without threading a handle through every
//!   signature. Entering a scope clones an `Arc` (refcount bump only).
//! * [`FlightRecorder`] — a fixed-capacity ring of the last N *completed*
//!   request timelines, mutexed because it is touched once per request at
//!   completion (control plane), never per step. Served by
//!   `GET /debug/requests`.
//!
//! The phase vocabulary ([`PhaseEvent`]) follows the request's life:
//! queue wait → pool admission → prefill chunks → speculation cycles
//! (draft span with γ/accepted, verify span) → completion, with
//! `QuantFlush`/`EvictLru` interleaved wherever the paged cache flushes a
//! full FP group or the pool evicts an LRU victim mid-step.

use std::cell::RefCell;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::metrics::{names, Registry};
use crate::util::json::Json;

/// One typed phase event on a request's timeline. Durations are µs of
/// wall clock spent *inside* the phase; marker events (`EvictLru`,
/// `Completed`) carry no duration and do not count toward the phase sum.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PhaseEvent {
    /// Time from enqueue to dispatch, minus any admission polling.
    QueueWait { us: u64 },
    /// Time the request's head-of-queue slot spent polling a saturated
    /// pool before `admit` returned `Run`.
    AdmissionWait { us: u64 },
    /// One chunked-prefill step: chunk index `n`, tokens fed, span.
    PrefillChunk { n: usize, tokens: usize, us: u64 },
    /// One speculation cycle's draft phase: γ requested, tokens accepted
    /// by the subsequent verify, and the draft-loop span.
    DraftCycle { gamma: usize, accepted: usize, us: u64 },
    /// One speculation cycle's verify+commit span.
    Verify { us: u64 },
    /// A paged-cache FP-buffer flush (quantize C_F1 into a fresh page).
    QuantFlush { us: u64 },
    /// The pool evicted LRU session `victim` while this request held the
    /// span scope (slow-path page allocation under pressure).
    EvictLru { victim: u64 },
    /// Terminal marker: total wall µs from enqueue to retirement.
    Completed { total_us: u64 },
    /// Terminal marker: the client cancelled the request; wall µs from
    /// enqueue to eviction (pages released, admission waiters notified).
    Cancelled { total_us: u64 },
    /// Terminal marker: the request's deadline expired while queued or
    /// mid-flight; wall µs from enqueue to eviction.
    DeadlineExpired { total_us: u64 },
    /// The tier policy spilled `pages` pages of session `session` to the
    /// cold store (page-granular reclaim or hibernate) while this request
    /// held the span scope.
    Spill { session: u64, pages: usize, us: u64 },
    /// A read faulted `pages` cold pages back into the arena on demand.
    Restore { pages: usize, us: u64 },
    /// The speculative fetch-ahead hook restored `pages` cold pages ahead
    /// of the next verify window (overlapped with the decode round).
    FetchAhead { pages: usize, us: u64 },
    /// Marker: the first committed token left the scheduler toward the
    /// client, `us` µs after the trace began (the request's TTFT as seen
    /// at the round boundary). Carries no duration — the wall it covers is
    /// already accounted to queue/prefill/draft phases.
    FirstToken { cycle: usize, us: u64 },
    /// Marker: one round-boundary stream flush pushed `tokens` committed
    /// tokens of cycle `cycle` into the response sink, `us` µs after the
    /// previous flush (the observed inter-chunk gap). No duration — the
    /// gap wall belongs to the decode phases that produced the tokens.
    StreamFlush { cycle: usize, tokens: usize, us: u64 },
}

impl PhaseEvent {
    pub fn name(&self) -> &'static str {
        match self {
            PhaseEvent::QueueWait { .. } => "queue_wait",
            PhaseEvent::AdmissionWait { .. } => "admission_wait",
            PhaseEvent::PrefillChunk { .. } => "prefill_chunk",
            PhaseEvent::DraftCycle { .. } => "draft_cycle",
            PhaseEvent::Verify { .. } => "verify",
            PhaseEvent::QuantFlush { .. } => "quant_flush",
            PhaseEvent::EvictLru { .. } => "evict_lru",
            PhaseEvent::Completed { .. } => "completed",
            PhaseEvent::Cancelled { .. } => "cancelled",
            PhaseEvent::DeadlineExpired { .. } => "deadline_expired",
            PhaseEvent::Spill { .. } => "spill",
            PhaseEvent::Restore { .. } => "restore",
            PhaseEvent::FetchAhead { .. } => "fetch_ahead",
            PhaseEvent::FirstToken { .. } => "first_token",
            PhaseEvent::StreamFlush { .. } => "stream",
        }
    }

    /// Wall-clock contribution of this event to the per-phase breakdown.
    pub fn duration_us(&self) -> u64 {
        match *self {
            PhaseEvent::QueueWait { us }
            | PhaseEvent::AdmissionWait { us }
            | PhaseEvent::PrefillChunk { us, .. }
            | PhaseEvent::DraftCycle { us, .. }
            | PhaseEvent::Verify { us }
            | PhaseEvent::QuantFlush { us }
            | PhaseEvent::Spill { us, .. }
            | PhaseEvent::Restore { us, .. }
            | PhaseEvent::FetchAhead { us, .. } => us,
            PhaseEvent::EvictLru { .. }
            | PhaseEvent::Completed { .. }
            | PhaseEvent::Cancelled { .. }
            | PhaseEvent::DeadlineExpired { .. }
            | PhaseEvent::FirstToken { .. }
            | PhaseEvent::StreamFlush { .. } => 0,
        }
    }

    fn encode(&self) -> (u64, u64, u64, u64) {
        match *self {
            PhaseEvent::QueueWait { us } => (0, us, 0, 0),
            PhaseEvent::AdmissionWait { us } => (1, us, 0, 0),
            PhaseEvent::PrefillChunk { n, tokens, us } => (2, n as u64, tokens as u64, us),
            PhaseEvent::DraftCycle { gamma, accepted, us } => {
                (3, gamma as u64, accepted as u64, us)
            }
            PhaseEvent::Verify { us } => (4, us, 0, 0),
            PhaseEvent::QuantFlush { us } => (5, us, 0, 0),
            PhaseEvent::EvictLru { victim } => (6, victim, 0, 0),
            PhaseEvent::Completed { total_us } => (7, total_us, 0, 0),
            PhaseEvent::Cancelled { total_us } => (8, total_us, 0, 0),
            PhaseEvent::DeadlineExpired { total_us } => (9, total_us, 0, 0),
            PhaseEvent::Spill { session, pages, us } => (10, session, pages as u64, us),
            PhaseEvent::Restore { pages, us } => (11, pages as u64, us, 0),
            PhaseEvent::FetchAhead { pages, us } => (12, pages as u64, us, 0),
            PhaseEvent::FirstToken { cycle, us } => (13, cycle as u64, us, 0),
            PhaseEvent::StreamFlush { cycle, tokens, us } => {
                (14, cycle as u64, tokens as u64, us)
            }
        }
    }

    fn decode(kind: u64, a: u64, b: u64, c: u64) -> Option<PhaseEvent> {
        Some(match kind {
            0 => PhaseEvent::QueueWait { us: a },
            1 => PhaseEvent::AdmissionWait { us: a },
            2 => PhaseEvent::PrefillChunk { n: a as usize, tokens: b as usize, us: c },
            3 => PhaseEvent::DraftCycle { gamma: a as usize, accepted: b as usize, us: c },
            4 => PhaseEvent::Verify { us: a },
            5 => PhaseEvent::QuantFlush { us: a },
            6 => PhaseEvent::EvictLru { victim: a },
            7 => PhaseEvent::Completed { total_us: a },
            8 => PhaseEvent::Cancelled { total_us: a },
            9 => PhaseEvent::DeadlineExpired { total_us: a },
            10 => PhaseEvent::Spill { session: a, pages: b as usize, us: c },
            11 => PhaseEvent::Restore { pages: a as usize, us: b },
            12 => PhaseEvent::FetchAhead { pages: a as usize, us: b },
            13 => PhaseEvent::FirstToken { cycle: a as usize, us: b },
            14 => PhaseEvent::StreamFlush { cycle: a as usize, tokens: b as usize, us: c },
            _ => return None,
        })
    }

    pub fn to_json(&self, at_us: u64) -> Json {
        let mut pairs = vec![
            ("at_us", Json::num(at_us as f64)),
            ("phase", Json::str(self.name())),
        ];
        match *self {
            PhaseEvent::PrefillChunk { n, tokens, us } => {
                pairs.push(("n", Json::num(n as f64)));
                pairs.push(("tokens", Json::num(tokens as f64)));
                pairs.push(("us", Json::num(us as f64)));
            }
            PhaseEvent::DraftCycle { gamma, accepted, us } => {
                pairs.push(("gamma", Json::num(gamma as f64)));
                pairs.push(("accepted", Json::num(accepted as f64)));
                pairs.push(("us", Json::num(us as f64)));
            }
            PhaseEvent::EvictLru { victim } => {
                pairs.push(("victim", Json::num(victim as f64)));
            }
            PhaseEvent::Spill { session, pages, us } => {
                pairs.push(("session", Json::num(session as f64)));
                pairs.push(("pages", Json::num(pages as f64)));
                pairs.push(("us", Json::num(us as f64)));
            }
            PhaseEvent::Restore { pages, us } | PhaseEvent::FetchAhead { pages, us } => {
                pairs.push(("pages", Json::num(pages as f64)));
                pairs.push(("us", Json::num(us as f64)));
            }
            PhaseEvent::FirstToken { cycle, us } => {
                pairs.push(("cycle", Json::num(cycle as f64)));
                pairs.push(("us", Json::num(us as f64)));
            }
            PhaseEvent::StreamFlush { cycle, tokens, us } => {
                pairs.push(("cycle", Json::num(cycle as f64)));
                pairs.push(("tokens", Json::num(tokens as f64)));
                pairs.push(("us", Json::num(us as f64)));
            }
            PhaseEvent::Completed { total_us }
            | PhaseEvent::Cancelled { total_us }
            | PhaseEvent::DeadlineExpired { total_us } => {
                pairs.push(("total_us", Json::num(total_us as f64)));
            }
            _ => pairs.push(("us", Json::num(self.duration_us() as f64))),
        }
        Json::obj(pairs)
    }
}

/// One preallocated event slot: the kind discriminant plus up to three
/// payload words and the µs offset from trace start. Plain relaxed atomics
/// — a slot is written by exactly one thread (the session is stepped by
/// one worker at a time) and only read after the request retires.
#[derive(Default)]
struct Slot {
    at_us: AtomicU64,
    kind: AtomicU64,
    a: AtomicU64,
    b: AtomicU64,
    c: AtomicU64,
}

/// Per-request span buffer. See module docs for the recording contract.
pub struct TraceBuf {
    start: Instant,
    slots: Vec<Slot>,
    len: AtomicUsize,
    dropped: AtomicU64,
}

impl TraceBuf {
    /// Preallocate `capacity` event slots (the only allocation this buffer
    /// ever performs).
    pub fn new(capacity: usize) -> Arc<TraceBuf> {
        Arc::new(TraceBuf {
            start: Instant::now(),
            slots: (0..capacity).map(|_| Slot::default()).collect(),
            len: AtomicUsize::new(0),
            dropped: AtomicU64::new(0),
        })
    }

    /// Record an event at the current monotonic offset. Lock-free and
    /// allocation-free; events past capacity bump `dropped` instead.
    pub fn record(&self, ev: PhaseEvent) {
        let at = self.start.elapsed().as_micros() as u64;
        let i = self.len.fetch_add(1, Ordering::Relaxed);
        match self.slots.get(i) {
            Some(slot) => {
                let (kind, a, b, c) = ev.encode();
                slot.at_us.store(at, Ordering::Relaxed);
                slot.a.store(a, Ordering::Relaxed);
                slot.b.store(b, Ordering::Relaxed);
                slot.c.store(c, Ordering::Relaxed);
                // kind last: a snapshot racing a write sees kind+1 == 0
                // (unwritten) rather than a half-initialized payload.
                slot.kind.store(kind + 1, Ordering::Release);
            }
            None => {
                self.dropped.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    pub fn recorded(&self) -> usize {
        self.len.load(Ordering::Relaxed).min(self.slots.len())
    }

    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Copy out the recorded events in order. Called once at retirement.
    pub fn snapshot(&self) -> Vec<(u64, PhaseEvent)> {
        let n = self.recorded();
        let mut out = Vec::with_capacity(n);
        for slot in &self.slots[..n] {
            let kind = slot.kind.load(Ordering::Acquire);
            if kind == 0 {
                continue; // claimed but not yet written
            }
            let ev = PhaseEvent::decode(
                kind - 1,
                slot.a.load(Ordering::Relaxed),
                slot.b.load(Ordering::Relaxed),
                slot.c.load(Ordering::Relaxed),
            );
            if let Some(ev) = ev {
                out.push((slot.at_us.load(Ordering::Relaxed), ev));
            }
        }
        out
    }
}

thread_local! {
    static CURRENT: RefCell<Option<Arc<TraceBuf>>> = const { RefCell::new(None) };
}

/// RAII guard binding a request's `TraceBuf` to the current thread so
/// nested layers can [`emit`] without plumbing. Scopes nest: dropping
/// restores the previous binding.
pub struct SpanScope {
    prev: Option<Arc<TraceBuf>>,
}

impl SpanScope {
    pub fn enter(buf: Arc<TraceBuf>) -> SpanScope {
        let prev = CURRENT.with(|c| c.borrow_mut().replace(buf));
        SpanScope { prev }
    }
}

impl Drop for SpanScope {
    fn drop(&mut self) {
        CURRENT.with(|c| *c.borrow_mut() = self.prev.take());
    }
}

/// Record `ev` against the thread's current span scope; a no-op (one TLS
/// read and a branch) when no request is being traced on this thread.
pub fn emit(ev: PhaseEvent) {
    CURRENT.with(|c| {
        if let Some(buf) = c.borrow().as_ref() {
            buf.record(ev);
        }
    });
}

/// A completed request's timeline, as held by the flight recorder.
#[derive(Debug, Clone)]
pub struct RequestTimeline {
    pub id: u64,
    pub total_us: u64,
    pub dropped: u64,
    pub events: Vec<(u64, PhaseEvent)>,
}

impl RequestTimeline {
    /// Sum of all phase durations — the coverage check against `total_us`.
    pub fn phase_sum_us(&self) -> u64 {
        self.events.iter().map(|(_, e)| e.duration_us()).sum()
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("id", Json::num(self.id as f64)),
            ("total_us", Json::num(self.total_us as f64)),
            ("phase_sum_us", Json::num(self.phase_sum_us() as f64)),
            ("dropped", Json::num(self.dropped as f64)),
            (
                "events",
                Json::arr(self.events.iter().map(|(at, e)| e.to_json(*at))),
            ),
        ])
    }
}

/// Fixed-capacity ring of the last N completed request timelines.
pub struct FlightRecorder {
    cap: usize,
    ring: Mutex<VecDeque<RequestTimeline>>,
}

impl FlightRecorder {
    pub fn new(cap: usize) -> FlightRecorder {
        FlightRecorder { cap, ring: Mutex::new(VecDeque::with_capacity(cap)) }
    }

    pub fn push(&self, t: RequestTimeline) {
        if self.cap == 0 {
            return;
        }
        let mut ring = self.ring.lock().unwrap();
        if ring.len() == self.cap {
            ring.pop_front();
        }
        ring.push_back(t);
    }

    pub fn len(&self) -> usize {
        self.ring.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Oldest-first JSON view, the `GET /debug/requests` payload.
    pub fn to_json(&self) -> Json {
        let ring = self.ring.lock().unwrap();
        Json::obj(vec![
            ("capacity", Json::num(self.cap as f64)),
            ("requests", Json::arr(ring.iter().map(|t| t.to_json()))),
        ])
    }
}

/// Per-coordinator tracing config + flight recorder.
pub struct Tracer {
    enabled: bool,
    buffer_events: usize,
    recorder: FlightRecorder,
}

impl Tracer {
    pub fn new(enabled: bool, buffer_events: usize, recorder_cap: usize) -> Tracer {
        Tracer {
            enabled,
            buffer_events,
            recorder: FlightRecorder::new(recorder_cap),
        }
    }

    /// Disabled tracer for paths that don't serve `/debug/requests`.
    pub fn disabled() -> Tracer {
        Tracer::new(false, 0, 0)
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Allocate a fresh request buffer, or `None` when tracing is off.
    pub fn new_request(&self) -> Option<Arc<TraceBuf>> {
        self.enabled.then(|| TraceBuf::new(self.buffer_events))
    }

    /// Seal a request's buffer into a timeline: stamps the `Completed`
    /// marker, snapshots the events, and hands the timeline back so the
    /// caller can mine it (phase histograms) before [`Tracer::push`].
    pub fn finish(&self, id: u64, buf: &TraceBuf, total_us: u64) -> RequestTimeline {
        buf.record(PhaseEvent::Completed { total_us });
        RequestTimeline {
            id,
            total_us,
            dropped: buf.dropped(),
            events: buf.snapshot(),
        }
    }

    pub fn push(&self, t: RequestTimeline) {
        self.recorder.push(t);
    }

    pub fn recorder(&self) -> &FlightRecorder {
        &self.recorder
    }

    pub fn to_json(&self) -> Json {
        self.recorder.to_json()
    }
}

/// Fold a completed timeline into the registry's per-phase, acceptance,
/// and accepted-length histograms (the `GET /metrics` view of tracing).
/// Completion-time work — never on the step path.
pub fn record_phase_histograms(t: &RequestTimeline, metrics: &Registry) {
    let queue = metrics.histogram(names::PHASE_QUEUE_US);
    let admission = metrics.histogram(names::PHASE_ADMISSION_US);
    let prefill = metrics.histogram(names::PHASE_PREFILL_CHUNK_US);
    let draft = metrics.histogram(names::PHASE_DRAFT_US);
    let verify = metrics.histogram(names::PHASE_VERIFY_US);
    let flush = metrics.histogram(names::PHASE_QUANT_FLUSH_US);
    let spill = metrics.histogram(names::PHASE_SPILL_US);
    let restore = metrics.histogram(names::PHASE_RESTORE_US);
    let fetch_ahead = metrics.histogram(names::PHASE_FETCH_AHEAD_US);
    let accepted_len = metrics.histogram(names::ACCEPTED_LEN);
    let mut drafted_total = 0u64;
    let mut accepted_total = 0u64;
    for (_, ev) in &t.events {
        match *ev {
            PhaseEvent::QueueWait { us } => queue.record_us(us as f64),
            PhaseEvent::AdmissionWait { us } => admission.record_us(us as f64),
            PhaseEvent::PrefillChunk { us, .. } => prefill.record_us(us as f64),
            PhaseEvent::DraftCycle { gamma, accepted, us } => {
                draft.record_us(us as f64);
                accepted_len.record_us(accepted as f64);
                drafted_total += gamma as u64;
                accepted_total += accepted as u64;
            }
            PhaseEvent::Verify { us } => verify.record_us(us as f64),
            PhaseEvent::QuantFlush { us } => flush.record_us(us as f64),
            PhaseEvent::Spill { us, .. } => spill.record_us(us as f64),
            PhaseEvent::Restore { us, .. } => restore.record_us(us as f64),
            PhaseEvent::FetchAhead { us, .. } => fetch_ahead.record_us(us as f64),
            // ttft_us / inter_token_gap_us are recorded live at flush time
            // by the scheduler (they must exist with tracing off), so the
            // stream markers fold into nothing here.
            PhaseEvent::EvictLru { .. }
            | PhaseEvent::Completed { .. }
            | PhaseEvent::Cancelled { .. }
            | PhaseEvent::DeadlineExpired { .. }
            | PhaseEvent::FirstToken { .. }
            | PhaseEvent::StreamFlush { .. } => {}
        }
    }
    if drafted_total > 0 {
        metrics
            .histogram(names::ACCEPTANCE_RATE_PCT)
            .record_us(100.0 * accepted_total as f64 / drafted_total as f64);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_roundtrip_through_slots() {
        let buf = TraceBuf::new(16);
        let evs = [
            PhaseEvent::QueueWait { us: 12 },
            PhaseEvent::AdmissionWait { us: 0 },
            PhaseEvent::PrefillChunk { n: 3, tokens: 128, us: 455 },
            PhaseEvent::DraftCycle { gamma: 4, accepted: 3, us: 88 },
            PhaseEvent::Verify { us: 31 },
            PhaseEvent::QuantFlush { us: 9 },
            PhaseEvent::EvictLru { victim: 7 },
            PhaseEvent::Spill { session: 3, pages: 5, us: 120 },
            PhaseEvent::Restore { pages: 2, us: 60 },
            PhaseEvent::FetchAhead { pages: 4, us: 45 },
            PhaseEvent::FirstToken { cycle: 0, us: 140 },
            PhaseEvent::StreamFlush { cycle: 2, tokens: 5, us: 77 },
            PhaseEvent::Cancelled { total_us: 550 },
            PhaseEvent::DeadlineExpired { total_us: 580 },
            PhaseEvent::Completed { total_us: 600 },
        ];
        for ev in evs {
            buf.record(ev);
        }
        let snap = buf.snapshot();
        assert_eq!(snap.len(), evs.len());
        for ((_, got), want) in snap.iter().zip(evs) {
            assert_eq!(*got, want);
        }
        // timestamps are monotone
        assert!(snap.windows(2).all(|w| w[0].0 <= w[1].0));
        assert_eq!(buf.dropped(), 0);
    }

    #[test]
    fn capacity_overflow_drops_without_growing() {
        let buf = TraceBuf::new(4);
        for i in 0..10 {
            buf.record(PhaseEvent::Verify { us: i });
        }
        assert_eq!(buf.recorded(), 4);
        assert_eq!(buf.dropped(), 6);
        let snap = buf.snapshot();
        assert_eq!(snap.len(), 4);
        assert_eq!(snap[3].1, PhaseEvent::Verify { us: 3 });
    }

    #[test]
    fn emit_without_scope_is_a_noop() {
        emit(PhaseEvent::Verify { us: 1 }); // must not panic or record anywhere
        let buf = TraceBuf::new(8);
        {
            let _scope = SpanScope::enter(Arc::clone(&buf));
            emit(PhaseEvent::QuantFlush { us: 5 });
        }
        emit(PhaseEvent::QuantFlush { us: 6 }); // scope dropped: not recorded
        let snap = buf.snapshot();
        assert_eq!(snap.len(), 1);
        assert_eq!(snap[0].1, PhaseEvent::QuantFlush { us: 5 });
    }

    #[test]
    fn scopes_nest_and_restore() {
        let outer = TraceBuf::new(8);
        let inner = TraceBuf::new(8);
        let _o = SpanScope::enter(Arc::clone(&outer));
        {
            let _i = SpanScope::enter(Arc::clone(&inner));
            emit(PhaseEvent::Verify { us: 1 });
        }
        emit(PhaseEvent::Verify { us: 2 });
        assert_eq!(inner.snapshot().len(), 1);
        assert_eq!(outer.snapshot().len(), 1);
        assert_eq!(outer.snapshot()[0].1, PhaseEvent::Verify { us: 2 });
    }

    #[test]
    fn flight_recorder_keeps_last_n() {
        let rec = FlightRecorder::new(3);
        for id in 0..5 {
            rec.push(RequestTimeline { id, total_us: id * 10, dropped: 0, events: vec![] });
        }
        assert_eq!(rec.len(), 3);
        let j = rec.to_json();
        let reqs = j.get("requests").unwrap().as_arr().unwrap();
        let ids: Vec<_> = reqs
            .iter()
            .map(|r| r.get("id").unwrap().as_usize().unwrap())
            .collect();
        assert_eq!(ids, vec![2, 3, 4], "oldest-first, last 3 kept");
    }

    #[test]
    fn tracer_finish_builds_timeline_and_histograms() {
        let tracer = Tracer::new(true, 64, 4);
        let buf = tracer.new_request().unwrap();
        buf.record(PhaseEvent::QueueWait { us: 10 });
        buf.record(PhaseEvent::PrefillChunk { n: 0, tokens: 32, us: 100 });
        buf.record(PhaseEvent::DraftCycle { gamma: 4, accepted: 2, us: 50 });
        buf.record(PhaseEvent::Verify { us: 40 });
        let t = tracer.finish(9, &buf, 210);
        assert_eq!(t.id, 9);
        assert_eq!(t.phase_sum_us(), 200);
        assert!(matches!(t.events.last().unwrap().1, PhaseEvent::Completed { total_us: 210 }));
        let metrics = Registry::new();
        record_phase_histograms(&t, &metrics);
        assert_eq!(metrics.histogram(names::PHASE_DRAFT_US).count(), 1);
        assert_eq!(metrics.histogram(names::ACCEPTED_LEN).count(), 1);
        // 2 of 4 drafted accepted -> 50%
        assert_eq!(metrics.histogram(names::ACCEPTANCE_RATE_PCT).max_us(), 50.0);
        tracer.push(t);
        assert_eq!(tracer.recorder().len(), 1);
        let json = tracer.to_json().to_string();
        assert!(json.contains("\"phase\":\"draft_cycle\""));
        assert!(json.contains("\"gamma\":4"));
    }

    #[test]
    fn disabled_tracer_hands_out_nothing() {
        let tracer = Tracer::disabled();
        assert!(tracer.new_request().is_none());
        assert!(!tracer.enabled());
        tracer.push(RequestTimeline { id: 1, total_us: 1, dropped: 0, events: vec![] });
        assert!(tracer.recorder().is_empty(), "cap-0 ring stays empty");
    }

    #[test]
    fn timeline_json_shape() {
        let t = RequestTimeline {
            id: 3,
            total_us: 500,
            dropped: 1,
            events: vec![
                (0, PhaseEvent::QueueWait { us: 20 }),
                (25, PhaseEvent::EvictLru { victim: 11 }),
            ],
        };
        let j = t.to_json();
        assert_eq!(j.get("id").unwrap().as_usize(), Some(3));
        assert_eq!(j.get("phase_sum_us").unwrap().as_usize(), Some(20));
        assert_eq!(j.get("dropped").unwrap().as_usize(), Some(1));
        let evs = j.get("events").unwrap().as_arr().unwrap();
        assert_eq!(evs[0].get("phase").unwrap().as_str(), Some("queue_wait"));
        assert_eq!(evs[1].get("victim").unwrap().as_usize(), Some(11));
    }
}
