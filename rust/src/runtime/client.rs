//! PJRT runtime: compile HLO-text artifacts once, execute them with
//! device-resident state on the request path.
//!
//! Threading: PJRT's CPU client and compiled executables are internally
//! thread-safe; device buffers are immutable once created. The `xla` crate's
//! wrappers hold raw pointers and are not marked Send/Sync, so we wrap them
//! in newtypes with explicit unsafe impls (documented invariant: buffers are
//! only read after creation; executables are stateless).

use std::collections::HashMap;
use std::path::Path;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::manifest::{EntrySpec, Manifest};
use super::tensor::{DType, HostTensor};

/// Device-resident tensor. Safe to share across threads: PJRT CPU buffers
/// are immutable after creation and the runtime never mutates them.
pub struct DeviceTensor {
    buf: xla::PjRtBuffer,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

unsafe impl Send for DeviceTensor {}
unsafe impl Sync for DeviceTensor {}

impl DeviceTensor {
    pub fn buffer(&self) -> &xla::PjRtBuffer {
        &self.buf
    }

    pub fn byte_size(&self) -> usize {
        self.shape.iter().product::<usize>() * self.dtype.size_bytes()
    }

    /// Download back to host (used by tests and cache snapshots).
    pub fn to_host(&self) -> Result<HostTensor> {
        let lit = self.buf.to_literal_sync()?;
        HostTensor::from_literal(&lit)
    }
}

/// One compiled entry point.
pub struct Executor {
    pub spec: EntrySpec,
    exe: xla::PjRtLoadedExecutable,
}

unsafe impl Send for Executor {}
unsafe impl Sync for Executor {}

/// Timing breakdown of one execute call (feeds the §Perf iteration log).
#[derive(Debug, Clone, Copy, Default)]
pub struct CallTiming {
    pub upload_secs: f64,
    pub execute_secs: f64,
    pub download_secs: f64,
}

pub enum Arg<'a> {
    Device(&'a DeviceTensor),
    Host(&'a HostTensor),
}

impl Executor {
    /// Execute with mixed device/host args (host args are uploaded first).
    /// Returns host tensors for every output in manifest order.
    pub fn call(
        &self,
        client: &xla::PjRtClient,
        args: &[Arg<'_>],
    ) -> Result<(Vec<HostTensor>, CallTiming)> {
        let mut timing = CallTiming::default();
        if args.len() != self.spec.inputs.len() {
            bail!(
                "entry '{}' wants {} inputs, got {}",
                self.spec.name,
                self.spec.inputs.len(),
                args.len()
            );
        }
        let t0 = Instant::now();
        // Upload host args; keep owned buffers alive for the call.
        let mut owned: Vec<xla::PjRtBuffer> = Vec::new();
        let mut ptrs: Vec<*const xla::PjRtBuffer> = Vec::with_capacity(args.len());
        for (i, a) in args.iter().enumerate() {
            match a {
                Arg::Device(d) => {
                    debug_assert_eq!(
                        d.shape, self.spec.inputs[i].shape,
                        "input {} ({}) shape mismatch", i, self.spec.inputs[i].name
                    );
                    ptrs.push(d.buffer() as *const _);
                }
                Arg::Host(h) => {
                    debug_assert_eq!(
                        h.shape, self.spec.inputs[i].shape,
                        "input {} ({}) shape mismatch", i, self.spec.inputs[i].name
                    );
                    let buf = h.to_buffer(client)?;
                    owned.push(buf);
                    ptrs.push(owned.last().unwrap() as *const _);
                }
            }
        }
        // Rebuild an ordered borrow list (owned buffers may have reallocated
        // is avoided by reserving: we pushed into `owned` while collecting
        // raw positions — re-walk instead to stay safe).
        let mut ordered: Vec<&xla::PjRtBuffer> = Vec::with_capacity(args.len());
        let mut oi = 0;
        for a in args {
            match a {
                Arg::Device(d) => ordered.push(d.buffer()),
                Arg::Host(_) => {
                    ordered.push(&owned[oi]);
                    oi += 1;
                }
            }
        }
        timing.upload_secs = t0.elapsed().as_secs_f64();

        let t1 = Instant::now();
        let results = self.exe.execute_b(&ordered)?;
        timing.execute_secs = t1.elapsed().as_secs_f64();

        let t2 = Instant::now();
        // return_tuple=True lowering: one tuple buffer at [0][0].
        let lit = results[0][0].to_literal_sync()?;
        let parts = lit.to_tuple()?;
        if parts.len() != self.spec.outputs.len() {
            bail!(
                "entry '{}' returned {} outputs, manifest says {}",
                self.spec.name,
                parts.len(),
                self.spec.outputs.len()
            );
        }
        let outs = parts
            .iter()
            .map(HostTensor::from_literal)
            .collect::<Result<Vec<_>>>()?;
        timing.download_secs = t2.elapsed().as_secs_f64();
        Ok((outs, timing))
    }
}

/// The runtime: a PJRT CPU client plus lazily compiled executables and
/// uploaded weight sets, shared across engines.
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    executors: Mutex<HashMap<String, Arc<Executor>>>,
    pub compile_secs: Mutex<HashMap<String, f64>>,
}

unsafe impl Send for Runtime {}
unsafe impl Sync for Runtime {}

impl Runtime {
    pub fn load(artifacts_dir: impl AsRef<Path>) -> Result<Arc<Runtime>> {
        let manifest = Manifest::load(&artifacts_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Arc::new(Runtime {
            client,
            manifest,
            executors: Mutex::new(HashMap::new()),
            compile_secs: Mutex::new(HashMap::new()),
        }))
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    /// Get (compiling on first use) the executor for an entry.
    pub fn executor(&self, name: &str) -> Result<Arc<Executor>> {
        if let Some(e) = self.executors.lock().unwrap().get(name) {
            return Ok(Arc::clone(e));
        }
        let spec = self.manifest.entry(name)?.clone();
        let path = self.manifest.dir.join(&spec.file);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path")?,
        )
        .with_context(|| format!("parsing {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {name}"))?;
        let secs = t0.elapsed().as_secs_f64();
        if std::env::var_os("QUANTSPEC_LOG").is_some() {
            eprintln!("compiled {name} in {secs:.2}s");
        }
        self.compile_secs.lock().unwrap().insert(name.to_string(), secs);
        let executor = Arc::new(Executor { spec, exe });
        self.executors
            .lock()
            .unwrap()
            .insert(name.to_string(), Arc::clone(&executor));
        Ok(executor)
    }

    pub fn upload(&self, t: &HostTensor) -> Result<DeviceTensor> {
        Ok(DeviceTensor {
            buf: t.to_buffer(&self.client)?,
            shape: t.shape.clone(),
            dtype: t.dtype(),
        })
    }

    /// Preload every entry for the given buckets (avoids first-request
    /// compile latency in serving mode).
    pub fn warmup(&self, buckets: &[usize]) -> Result<()> {
        for &b in buckets {
            for kind in [
                "prefill", "draft", "verify", "ar_step", "ar_verify",
                "sparse_draft", "flush", "ar_flush", "sparse_flush",
            ] {
                self.executor(&format!("{kind}_{b}"))?;
            }
        }
        Ok(())
    }
}
