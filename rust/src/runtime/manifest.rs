//! Artifact manifest: what `python -m compile.aot` produced.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use super::tensor::DType;
use crate::config::ModelSpec;
use crate::util::json::Json;

/// One input or output of an entry, in argument order.
#[derive(Debug, Clone)]
pub struct IoSpec {
    pub name: String,
    pub dtype: DType,
    pub shape: Vec<usize>,
}

impl IoSpec {
    fn from_json(j: &Json) -> Result<IoSpec> {
        Ok(IoSpec {
            name: j.req("name")?.as_str().context("io name")?.to_string(),
            dtype: DType::parse(j.req("dtype")?.as_str().context("io dtype")?)?,
            shape: j
                .req("shape")?
                .as_arr()
                .context("io shape")?
                .iter()
                .filter_map(Json::as_usize)
                .collect(),
        })
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One lowered entry point (an .hlo.txt file plus its signature).
#[derive(Debug, Clone)]
pub struct EntrySpec {
    pub name: String,
    pub file: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

/// A weight blob on disk.
#[derive(Debug, Clone)]
pub struct WeightMeta {
    pub file: String,
    pub shape: Vec<usize>,
    /// Logical bit width (4 for the quant-dequant draft set) — memory
    /// accounting uses this, not the on-disk f32 width.
    pub logical_bits: usize,
}

#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub model: ModelSpec,
    pub buckets: Vec<usize>,
    pub score_bucket: usize,
    pub param_order: Vec<String>,
    /// weight set name ("fp" / "q4") -> param name -> meta
    pub weights: BTreeMap<String, BTreeMap<String, WeightMeta>>,
    pub entries: BTreeMap<String, EntrySpec>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("manifest: {e}"))?;

        let model = ModelSpec::from_json(j.req("model")?)?;
        let buckets = j
            .req("buckets")?
            .as_arr()
            .context("buckets")?
            .iter()
            .filter_map(Json::as_usize)
            .collect();
        let score_bucket = j.req("score_bucket")?.as_usize().context("score_bucket")?;
        let param_order = j
            .req("param_order")?
            .as_arr()
            .context("param_order")?
            .iter()
            .filter_map(|v| v.as_str().map(String::from))
            .collect();

        let mut weights = BTreeMap::new();
        for (set, obj) in j.req("weights")?.as_obj().context("weights")? {
            let mut params = BTreeMap::new();
            for (name, meta) in obj.as_obj().context("weight set")? {
                params.insert(
                    name.clone(),
                    WeightMeta {
                        file: meta.req("file")?.as_str().context("file")?.to_string(),
                        shape: meta
                            .req("shape")?
                            .as_arr()
                            .context("shape")?
                            .iter()
                            .filter_map(Json::as_usize)
                            .collect(),
                        logical_bits: meta
                            .req("logical_bits")?
                            .as_usize()
                            .context("logical_bits")?,
                    },
                );
            }
            weights.insert(set.clone(), params);
        }

        let mut entries = BTreeMap::new();
        for (name, e) in j.req("entries")?.as_obj().context("entries")? {
            let parse_io = |key: &str| -> Result<Vec<IoSpec>> {
                e.req(key)?
                    .as_arr()
                    .context("io list")?
                    .iter()
                    .map(IoSpec::from_json)
                    .collect()
            };
            entries.insert(
                name.clone(),
                EntrySpec {
                    name: name.clone(),
                    file: e.req("file")?.as_str().context("file")?.to_string(),
                    inputs: parse_io("inputs")?,
                    outputs: parse_io("outputs")?,
                },
            );
        }

        Ok(Manifest { dir, model, buckets, score_bucket, param_order, weights, entries })
    }

    pub fn entry(&self, name: &str) -> Result<&EntrySpec> {
        self.entries
            .get(name)
            .with_context(|| format!("entry '{name}' not in manifest (buckets: {:?})", self.buckets))
    }

    /// Pick the smallest bucket that fits a prompt of `len` tokens.
    pub fn bucket_for(&self, len: usize) -> Option<usize> {
        self.buckets.iter().copied().filter(|&b| b >= len).min()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<PathBuf> {
        let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        d.join("manifest.json").exists().then_some(d)
    }

    #[test]
    fn loads_real_manifest() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let m = Manifest::load(dir).unwrap();
        assert!(!m.buckets.is_empty());
        assert_eq!(m.model.g, m.model.head_dim);
        assert_eq!(m.model.fb, 2 * m.model.g + m.model.tmax);
        // every bucket has its full entry family
        for b in &m.buckets {
            for kind in ["prefill", "draft", "verify", "ar_step", "ar_verify",
                         "sparse_draft", "flush", "ar_flush", "sparse_flush"] {
                assert!(m.entries.contains_key(&format!("{kind}_{b}")), "{kind}_{b}");
            }
        }
        // weight sets cover the param order
        for set in ["fp", "q4"] {
            let ws = &m.weights[set];
            for p in &m.param_order {
                assert!(ws.contains_key(p), "{set}/{p}");
            }
        }
    }

    #[test]
    fn bucket_selection() {
        let Some(dir) = artifacts_dir() else { return };
        let m = Manifest::load(dir).unwrap();
        assert_eq!(m.bucket_for(100), Some(*m.buckets.iter().min().unwrap()));
        assert_eq!(m.bucket_for(10_000_000), None);
    }
}
