//! Layer-3 runtime: load AOT artifacts (HLO text), compile once on the PJRT
//! CPU client, execute from the request path with device-resident state.
//!
//! Adapted from /opt/xla-example/load_hlo: HLO *text* is the interchange
//! format (jax >= 0.5 serialized protos are rejected by xla_extension
//! 0.5.1); `HloModuleProto::from_text_file` reassigns instruction ids.

pub mod client;
pub mod manifest;
pub mod tensor;
pub mod weights;

pub use client::{Arg, CallTiming, DeviceTensor, Executor, Runtime};
pub use manifest::{EntrySpec, IoSpec, Manifest};
pub use tensor::{DType, HostTensor, Storage};
pub use weights::{WeightSet, Weights};
