//! Host tensors: typed shape-carrying arrays bridging Rust state and XLA
//! literals/buffers.

use anyhow::{bail, Context, Result};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I8,
    I32,
}

impl DType {
    pub fn parse(s: &str) -> Result<DType> {
        Ok(match s {
            "f32" => DType::F32,
            "i8" => DType::I8,
            "i32" => DType::I32,
            other => bail!("unknown dtype '{other}'"),
        })
    }

    pub fn size_bytes(&self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::I8 => 1,
        }
    }
}

#[derive(Debug, Clone, PartialEq)]
pub enum Storage {
    F32(Vec<f32>),
    I8(Vec<i8>),
    I32(Vec<i32>),
}

/// A host-resident tensor (row-major).
#[derive(Debug, Clone, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: Storage,
}

impl HostTensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Result<HostTensor> {
        Self::check(&shape, data.len())?;
        Ok(HostTensor { shape, data: Storage::F32(data) })
    }

    pub fn i8(shape: Vec<usize>, data: Vec<i8>) -> Result<HostTensor> {
        Self::check(&shape, data.len())?;
        Ok(HostTensor { shape, data: Storage::I8(data) })
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Result<HostTensor> {
        Self::check(&shape, data.len())?;
        Ok(HostTensor { shape, data: Storage::I32(data) })
    }

    pub fn scalar_i32(v: i32) -> HostTensor {
        HostTensor { shape: vec![], data: Storage::I32(vec![v]) }
    }

    pub fn zeros(dtype: DType, shape: Vec<usize>) -> HostTensor {
        let n: usize = shape.iter().product();
        let data = match dtype {
            DType::F32 => Storage::F32(vec![0.0; n]),
            DType::I8 => Storage::I8(vec![0; n]),
            DType::I32 => Storage::I32(vec![0; n]),
        };
        HostTensor { shape, data }
    }

    fn check(shape: &[usize], len: usize) -> Result<()> {
        let n: usize = shape.iter().product();
        if n != len {
            bail!("shape {shape:?} wants {n} elements, got {len}");
        }
        Ok(())
    }

    pub fn dtype(&self) -> DType {
        match self.data {
            Storage::F32(_) => DType::F32,
            Storage::I8(_) => DType::I8,
            Storage::I32(_) => DType::I32,
        }
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn byte_size(&self) -> usize {
        self.numel() * self.dtype().size_bytes()
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            Storage::F32(v) => Ok(v),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i8(&self) -> Result<&[i8]> {
        match &self.data {
            Storage::I8(v) => Ok(v),
            _ => bail!("tensor is not i8"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            Storage::I32(v) => Ok(v),
            _ => bail!("tensor is not i32"),
        }
    }

    /// Convert an XLA literal (non-tuple) to a host tensor.
    pub fn from_literal(lit: &xla::Literal) -> Result<HostTensor> {
        let shape = lit.array_shape().context("literal shape")?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        use xla::ElementType as ET;
        let data = match shape.ty() {
            ET::F32 => Storage::F32(lit.to_vec::<f32>()?),
            ET::S8 => Storage::I8(lit.to_vec::<i8>()?),
            ET::S32 => Storage::I32(lit.to_vec::<i32>()?),
            other => bail!("unsupported literal element type {other:?}"),
        };
        Ok(HostTensor { shape: dims, data })
    }

    /// Upload to a device buffer.
    pub fn to_buffer(&self, client: &xla::PjRtClient) -> Result<xla::PjRtBuffer> {
        let buf = match &self.data {
            Storage::F32(v) => client.buffer_from_host_buffer(v, &self.shape, None),
            Storage::I8(v) => client.buffer_from_host_buffer(v, &self.shape, None),
            Storage::I32(v) => client.buffer_from_host_buffer(v, &self.shape, None),
        }?;
        Ok(buf)
    }

    /// Read a raw little-endian f32 blob (weight export format).
    pub fn from_f32_file(path: &std::path::Path, shape: Vec<usize>) -> Result<HostTensor> {
        let bytes = std::fs::read(path).with_context(|| format!("reading {path:?}"))?;
        let n: usize = shape.iter().product();
        if bytes.len() != n * 4 {
            bail!("{path:?}: expected {} bytes for {shape:?}, got {}", n * 4, bytes.len());
        }
        let mut data = Vec::with_capacity(n);
        for chunk in bytes.chunks_exact(4) {
            data.push(f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]));
        }
        HostTensor::f32(shape, data)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_check() {
        assert!(HostTensor::f32(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(HostTensor::f32(vec![2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn zeros_and_sizes() {
        let t = HostTensor::zeros(DType::I8, vec![4, 8]);
        assert_eq!(t.numel(), 32);
        assert_eq!(t.byte_size(), 32);
        assert_eq!(t.dtype(), DType::I8);
        let t = HostTensor::zeros(DType::F32, vec![4, 8]);
        assert_eq!(t.byte_size(), 128);
    }

    #[test]
    fn scalar() {
        let s = HostTensor::scalar_i32(7);
        assert_eq!(s.numel(), 1);
        assert_eq!(s.as_i32().unwrap(), &[7]);
    }

    #[test]
    fn f32_file_roundtrip() {
        let dir = std::env::temp_dir().join("qs_tensor_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("w.bin");
        let vals: Vec<f32> = (0..12).map(|i| i as f32 * 0.5).collect();
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        std::fs::write(&path, bytes).unwrap();
        let t = HostTensor::from_f32_file(&path, vec![3, 4]).unwrap();
        assert_eq!(t.as_f32().unwrap(), vals.as_slice());
        assert!(HostTensor::from_f32_file(&path, vec![5, 4]).is_err());
    }
}
