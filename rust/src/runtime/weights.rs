//! Weight-set loading: raw f32 blobs -> device-resident tensors, uploaded
//! once per process and shared by every engine (Python never runs at
//! serving time; these files were exported by `compile/aot.py`).

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{Context, Result};

use super::client::{DeviceTensor, Runtime};
use super::tensor::HostTensor;

/// Which exported weight set to use.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum WeightSet {
    /// Trained full-precision weights (target model; fp16 on real HW).
    Fp,
    /// INT4-sim quant-dequant weights (QuantSpec draft model).
    Q4,
}

impl WeightSet {
    pub fn key(&self) -> &'static str {
        match self {
            WeightSet::Fp => "fp",
            WeightSet::Q4 => "q4",
        }
    }
}

/// A full parameter set on device, in manifest `param_order`.
pub struct Weights {
    pub set: WeightSet,
    pub tensors: Vec<Arc<DeviceTensor>>,
    pub by_name: BTreeMap<String, Arc<DeviceTensor>>,
    /// Logical bytes (uses the manifest's logical_bits — 4-bit draft
    /// weights count at half a byte per element).
    pub logical_bytes: usize,
}

impl Weights {
    pub fn load(rt: &Runtime, set: WeightSet) -> Result<Weights> {
        let metas = rt
            .manifest
            .weights
            .get(set.key())
            .with_context(|| format!("weight set '{}' missing", set.key()))?;
        let mut tensors = Vec::with_capacity(rt.manifest.param_order.len());
        let mut by_name = BTreeMap::new();
        let mut logical_bytes = 0usize;
        for name in &rt.manifest.param_order {
            let meta = metas
                .get(name)
                .with_context(|| format!("weight '{name}' missing from set"))?;
            let path = rt.manifest.dir.join(&meta.file);
            let host = HostTensor::from_f32_file(&path, meta.shape.clone())?;
            logical_bytes += host.numel() * meta.logical_bits / 8;
            let dev = Arc::new(rt.upload(&host)?);
            tensors.push(Arc::clone(&dev));
            by_name.insert(name.clone(), dev);
        }
        Ok(Weights { set, tensors, by_name, logical_bytes })
    }
}
