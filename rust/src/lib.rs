//! QuantSpec: self-speculative decoding with a hierarchical quantized KV cache.
//!
//! Reproduction of "QuantSpec: Self-Speculative Decoding with Hierarchical
//! Quantized KV Cache" (ICML 2025). Three-layer architecture:
//!
//! * **Layer 1** — Pallas kernels (build-time Python, `python/compile/kernels/`):
//!   hierarchical INT4/INT8 quantization and quantized-KV attention.
//! * **Layer 2** — JAX model (build-time Python, `python/compile/model.py`):
//!   a Llama-style transformer whose attention calls the L1 kernels; lowered
//!   AOT to HLO text artifacts.
//! * **Layer 3** — this crate: the serving coordinator. Request router with
//!   pool-pressure admission control, continuous batcher,
//!   speculative-decoding engine, hierarchical KV-cache manager with the
//!   paper's double full-precision buffer, a paged KV-cache pool
//!   (`pool`: fixed-capacity page arena + session manager with
//!   cost-model reservations, watermarks, and LRU preemption) shared by
//!   all sessions, sparse-KV baselines (StreamingLLM / SnapKV), and an
//!   analytical GPU cost model used to project the paper's A6000 numbers
//!   from this CPU testbed.
//!
//! Python never runs on the request path: `make artifacts` lowers the model
//! once, and the binary is self-contained afterwards.

pub mod util;
pub mod config;
pub mod costmodel;
pub mod quant;
pub mod cache;
pub mod pool;
pub mod runtime;
pub mod model;
pub mod spec;
pub mod stream;
pub mod baselines;
pub mod coordinator;
pub mod metrics;
pub mod trace;
pub mod workload;
pub mod bench;
