//! Serving and model configuration.
//!
//! `ServeConfig` is the coordinator's knob set (method, γ, batching,
//! sampling); `ModelSpec` mirrors the architecture block of the artifact
//! manifest. Config files are JSON (parsed with util::json); every field has
//! a production-sane default so `quantspec serve` runs with no file at all.

use crate::pool::PoolConfig;
use crate::util::json::Json;
use anyhow::{Context, Result};

/// Which decoding method an engine runs. The paper's Table 3 compares
/// QuantSpec against autoregressive decoding and the two sparse-KV
/// self-speculative baselines from MagicDec.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Method {
    /// Plain autoregressive decoding with the FP cache (the "AR" baseline).
    Autoregressive,
    /// QuantSpec: INT4-draft / INT8-verify hierarchical quantized cache.
    QuantSpec,
    /// Self-speculation with an attention-sink + recent-window draft cache.
    StreamingLlm,
    /// Self-speculation with a SnapKV-selected draft cache.
    SnapKv,
}

impl Method {
    pub fn parse(s: &str) -> Result<Method> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "ar" | "autoregressive" => Method::Autoregressive,
            "quantspec" | "qs" => Method::QuantSpec,
            "streamingllm" | "streaming" => Method::StreamingLlm,
            "snapkv" | "snap" => Method::SnapKv,
            other => anyhow::bail!("unknown method '{other}'"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Method::Autoregressive => "AR",
            Method::QuantSpec => "QuantSpec",
            Method::StreamingLlm => "StreamingLLM",
            Method::SnapKv => "SnapKV",
        }
    }

    /// All speculative methods (Table 3 rows).
    pub fn speculative() -> [Method; 3] {
        [Method::StreamingLlm, Method::SnapKv, Method::QuantSpec]
    }
}

/// QuantSpec ablation modes (paper Figure 4): what the draft quantizes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QuantMode {
    /// 4-bit KV cache + 4-bit weights (the full method).
    Both,
    /// 4-bit KV cache, full-precision weights.
    KvOnly,
    /// 4-bit weights, full-precision (dense) KV.
    WeightOnly,
}

impl QuantMode {
    pub fn parse(s: &str) -> Result<QuantMode> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "both" => QuantMode::Both,
            "kv" | "kv-only" | "kvonly" => QuantMode::KvOnly,
            "weight" | "weight-only" | "weightonly" => QuantMode::WeightOnly,
            other => anyhow::bail!("unknown quant mode '{other}'"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            QuantMode::Both => "both",
            QuantMode::KvOnly => "kv-only",
            QuantMode::WeightOnly => "weight-only",
        }
    }
}

/// Sampling configuration shared by draft and target.
#[derive(Debug, Clone, Copy)]
pub struct Sampling {
    /// Temperature 0 = greedy (deterministic; used by correctness tests).
    pub temperature: f32,
    pub seed: u64,
}

impl Default for Sampling {
    fn default() -> Self {
        Sampling { temperature: 0.0, seed: 0 }
    }
}

/// Coordinator-level configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub artifacts_dir: String,
    pub method: Method,
    pub quant_mode: QuantMode,
    /// Speculation length γ (paper Table 6 searches this per dataset).
    pub gamma: usize,
    /// Adapt γ online (AIMD on acceptance) instead of the fixed value.
    pub adaptive_gamma: bool,
    pub sampling: Sampling,
    /// Max generated tokens per request (paper uses 90).
    pub max_new_tokens: usize,
    /// Number of decode engines (worker threads with their own state).
    pub engines: usize,
    /// Queue capacity before the router sheds load (429).
    pub queue_capacity: usize,
    /// HTTP bind address for `serve`.
    pub bind: String,
    /// Context buckets to preload (empty = all in manifest).
    pub buckets: Vec<usize>,
    /// Prefill chunk size in tokens for schedulable prompt processing:
    /// sessions admitted to a step batcher advance one chunk per round
    /// (interleaved with decode cycles), so admission costs each round
    /// O(chunk) instead of O(prompt). 0 = monolithic one-shot prefill.
    pub prefill_chunk_tokens: usize,
    /// Quant-pool backpressure threshold: when the shared quantization
    /// pool's queue depth exceeds this, the batcher defers further prefill
    /// chunks (decode cycles keep running) and counts a
    /// `prefill_deferrals` metric.
    pub quant_queue_soft_limit: usize,
    /// Step workers per engine batcher: each engine's `StepBatcher` round
    /// steps its sessions concurrently on this many workers (bit-identical
    /// to serial rounds per session). 1 = serial rounds; 0 is rejected at
    /// coordinator startup with an error — never silently clamped
    /// (mirrors `pool.quant_workers`).
    pub step_workers: usize,
    /// Sessions one engine's step batcher multiplexes at once (its
    /// round-robin capacity). More slots = more interleaving per engine;
    /// admission control still bounds total KV pages. Under the unified
    /// scheduler the global batcher multiplexes `engines × batcher_slots`.
    pub batcher_slots: usize,
    /// Max distinct tenants the fair-queue admission tracks concurrently
    /// (per-tenant DRR queues; requests beyond this many live tenants are
    /// shed). 0 is rejected at coordinator startup with an error — never
    /// silently clamped (mirrors `step_workers`).
    pub sched_tenants: usize,
    /// Default per-request deadline in milliseconds: a request still queued
    /// (or still running) past its deadline is rejected / timed out cleanly
    /// and its pool pages released. 0 = no deadline.
    pub request_deadline_ms: u64,
    /// Per-tenant admission rate limit in requests/second (token bucket,
    /// burst = one second's worth). 0 = unlimited.
    pub tenant_rate_limit: usize,
    /// Per-tenant weighted-fair-queueing weights (DRR quantum per round).
    /// Unlisted tenants get weight 1. A listed weight of 0 is rejected at
    /// coordinator startup — it would starve that tenant by construction.
    pub fair_weights: Vec<(String, u64)>,
    /// Paged KV-cache pool (admission control + shared arena).
    /// `pool.pages == 0` disables pooling: sessions keep private,
    /// unaccounted cache state as in the original single-session path.
    pub pool: PoolConfig,
    /// Request-scoped tracing: per-request phase timelines feeding the
    /// flight recorder (`GET /debug/requests`) and the per-phase
    /// histograms on `GET /metrics`. Cheap enough to leave on (overhead
    /// is gated ≤1.05× in `pool_pressure` and zero-alloc in
    /// `alloc_hotpath`).
    pub trace_enabled: bool,
    /// Event slots preallocated per traced request; events past this are
    /// dropped (and counted) rather than allocated.
    pub trace_buffer_events: usize,
    /// Completed request timelines the flight recorder ring retains.
    pub flight_recorder_requests: usize,
    /// Hibernate sessions idle longer than this many milliseconds: their
    /// pages move to the cold tier (spill store) and fault back
    /// bit-identically on the next touch — no re-prefill. 0 disables the
    /// sweep. Requires `pool.spill_pages > 0` to have any effect.
    pub hibernate_idle_ms: u64,
    /// Per-request stream buffer capacity in events: when a consumer falls
    /// more than this many undrained events behind, the scheduler sheds the
    /// session at the round boundary (in-band 503 error frame) instead of
    /// buffering unboundedly. 0 = unbounded (the pre-backpressure behavior).
    pub stream_buffer_events: usize,
    /// Seed for the deterministic fault injector (`util::fault`). Only
    /// meaningful when `fault_spec` arms at least one site.
    pub fault_seed: u64,
    /// Fault-injection spec, `site:rate_permille[:max_fires]` comma-joined
    /// (grammar in docs/ROBUSTNESS.md). Empty (the default) disables
    /// injection entirely; a malformed spec is a startup error — never
    /// silently ignored (mirrors `step_workers`).
    pub fault_spec: String,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            artifacts_dir: "artifacts".into(),
            method: Method::QuantSpec,
            quant_mode: QuantMode::Both,
            gamma: 4,
            adaptive_gamma: false,
            sampling: Sampling::default(),
            max_new_tokens: 90,
            engines: 1,
            queue_capacity: 256,
            bind: "127.0.0.1:8311".into(),
            buckets: Vec::new(),
            prefill_chunk_tokens: 0,
            quant_queue_soft_limit: 32,
            step_workers: 1,
            batcher_slots: 4,
            sched_tenants: 8,
            request_deadline_ms: 0,
            tenant_rate_limit: 0,
            fair_weights: Vec::new(),
            pool: PoolConfig { pages: 0, ..PoolConfig::default() },
            trace_enabled: true,
            trace_buffer_events: 4096,
            flight_recorder_requests: 64,
            hibernate_idle_ms: 0,
            stream_buffer_events: 4096,
            fault_seed: 0,
            fault_spec: String::new(),
        }
    }
}

impl ServeConfig {
    /// Load from a JSON file, falling back to defaults per missing field.
    pub fn from_file(path: &str) -> Result<ServeConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path}"))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
        Self::from_json(&j)
    }

    pub fn from_json(j: &Json) -> Result<ServeConfig> {
        let mut c = ServeConfig::default();
        if let Some(v) = j.get("artifacts_dir").and_then(Json::as_str) {
            c.artifacts_dir = v.to_string();
        }
        if let Some(v) = j.get("method").and_then(Json::as_str) {
            c.method = Method::parse(v)?;
        }
        if let Some(v) = j.get("quant_mode").and_then(Json::as_str) {
            c.quant_mode = QuantMode::parse(v)?;
        }
        if let Some(v) = j.get("gamma").and_then(Json::as_usize) {
            c.gamma = v;
        }
        if let Some(v) = j.get("adaptive_gamma").and_then(Json::as_bool) {
            c.adaptive_gamma = v;
        }
        if let Some(v) = j.get("temperature").and_then(Json::as_f64) {
            c.sampling.temperature = v as f32;
        }
        if let Some(v) = j.get("seed").and_then(Json::as_i64) {
            c.sampling.seed = v as u64;
        }
        if let Some(v) = j.get("max_new_tokens").and_then(Json::as_usize) {
            c.max_new_tokens = v;
        }
        if let Some(v) = j.get("engines").and_then(Json::as_usize) {
            c.engines = v.max(1);
        }
        if let Some(v) = j.get("queue_capacity").and_then(Json::as_usize) {
            c.queue_capacity = v;
        }
        if let Some(v) = j.get("bind").and_then(Json::as_str) {
            c.bind = v.to_string();
        }
        if let Some(arr) = j.get("buckets").and_then(Json::as_arr) {
            c.buckets = arr.iter().filter_map(Json::as_usize).collect();
        }
        if let Some(v) = j.get("prefill_chunk_tokens").and_then(Json::as_usize) {
            c.prefill_chunk_tokens = v;
        }
        if let Some(v) = j.get("quant_queue_soft_limit").and_then(Json::as_usize) {
            c.quant_queue_soft_limit = v;
        }
        if let Some(v) = j.get("step_workers").and_then(Json::as_usize) {
            // Deliberately NOT clamped: 0 must surface as a startup error
            // from the coordinator, not be silently bumped to serial.
            c.step_workers = v;
        }
        if let Some(v) = j.get("batcher_slots").and_then(Json::as_usize) {
            c.batcher_slots = v.max(1);
        }
        if let Some(v) = j.get("sched_tenants").and_then(Json::as_usize) {
            // Deliberately NOT clamped: 0 must surface as a startup error
            // from the coordinator (mirrors step_workers).
            c.sched_tenants = v;
        }
        if let Some(v) = j.get("request_deadline_ms").and_then(Json::as_usize) {
            c.request_deadline_ms = v as u64;
        }
        if let Some(v) = j.get("tenant_rate_limit").and_then(Json::as_usize) {
            c.tenant_rate_limit = v;
        }
        if let Some(m) = j.get("fair_weights").and_then(Json::as_obj) {
            // Weight 0 propagates so the coordinator rejects it loudly —
            // a zero-weight tenant would be starved by construction.
            c.fair_weights = m
                .iter()
                .filter_map(|(k, v)| v.as_usize().map(|w| (k.clone(), w as u64)))
                .collect();
        }
        if let Some(v) = j.get("trace_enabled").and_then(Json::as_bool) {
            c.trace_enabled = v;
        }
        if let Some(v) = j.get("trace_buffer_events").and_then(Json::as_usize) {
            c.trace_buffer_events = v;
        }
        if let Some(v) = j.get("flight_recorder_requests").and_then(Json::as_usize) {
            c.flight_recorder_requests = v;
        }
        if let Some(v) = j.get("hibernate_idle_ms").and_then(Json::as_usize) {
            c.hibernate_idle_ms = v as u64;
        }
        if let Some(v) = j.get("stream_buffer_events").and_then(Json::as_usize) {
            c.stream_buffer_events = v;
        }
        if let Some(v) = j.get("fault_seed").and_then(Json::as_i64) {
            c.fault_seed = v as u64;
        }
        if let Some(v) = j.get("fault_spec").and_then(Json::as_str) {
            // Deliberately NOT validated here: the coordinator parses the
            // spec at startup and rejects a malformed one loudly, matching
            // the no-silent-clamp convention of the other knobs.
            c.fault_spec = v.to_string();
        }
        if let Some(p) = j.get("pool") {
            if let Some(v) = p.get("pages").and_then(Json::as_usize) {
                c.pool.pages = v;
            }
            if let Some(v) = p.get("page_tokens").and_then(Json::as_usize) {
                c.pool.page_tokens = v.max(1);
            }
            if let Some(v) = p.get("kv_dim").and_then(Json::as_usize) {
                c.pool.kv_dim = v.max(1);
            }
            if let Some(v) = p.get("high_watermark").and_then(Json::as_f64) {
                c.pool.high_watermark = v.clamp(0.0, 1.0);
            }
            if let Some(v) = p.get("low_watermark").and_then(Json::as_f64) {
                c.pool.low_watermark = v.clamp(0.0, 1.0);
            }
            if let Some(v) = p.get("quant_workers").and_then(Json::as_usize) {
                // `quant_workers` sizes the ONE process-wide quantization
                // pool created at coordinator startup and shared by every
                // session's prefill (1 = serial). Deliberately NOT clamped:
                // 0 must surface as a startup error from the session
                // manager, not be silently bumped.
                c.pool.quant_workers = v;
            }
            if let Some(v) = p.get("spill_pages").and_then(Json::as_usize) {
                // Cold-tier capacity in pages; 0 (the default) disables
                // tiering entirely — no spill store is created.
                c.pool.spill_pages = v;
            }
            if let Some(v) = p.get("spill_dir").and_then(Json::as_str) {
                c.pool.spill_dir = v.to_string();
            }
            if let Some(v) = p.get("fetch_ahead").and_then(Json::as_bool) {
                c.pool.fetch_ahead = v;
            }
            if let Some(v) = p.get("fetch_ahead_max").and_then(Json::as_usize) {
                // Cap on the adaptive fetch-ahead depth (quant groups);
                // the live depth is fault-rate-driven between 1 and this.
                c.pool.fetch_ahead_max = v;
            }
            if c.pool.low_watermark > c.pool.high_watermark {
                c.pool.low_watermark = c.pool.high_watermark;
            }
        }
        Ok(c)
    }
}

/// Architecture block of the manifest (must match the lowered model).
#[derive(Debug, Clone)]
pub struct ModelSpec {
    pub vocab: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub n_layers: usize,
    pub d_ff: usize,
    /// Quantization group size G (== head_dim, paper §4.3.1).
    pub g: usize,
    /// Verify slots (γ_max = tmax - 1).
    pub tmax: usize,
    /// FP buffer capacity FB = 2G + tmax.
    pub fb: usize,
}

impl ModelSpec {
    pub fn from_json(j: &Json) -> Result<ModelSpec> {
        let u = |k: &str| -> Result<usize> {
            j.req(k)?.as_usize().context(format!("model.{k} not usize"))
        };
        Ok(ModelSpec {
            vocab: u("vocab")?,
            d_model: u("d_model")?,
            n_heads: u("n_heads")?,
            head_dim: u("head_dim")?,
            n_layers: u("n_layers")?,
            d_ff: u("d_ff")?,
            g: u("g")?,
            tmax: u("tmax")?,
            fb: u("fb")?,
        })
    }

    /// γ_max supported by the verify artifact (one slot feeds the last
    /// committed token).
    pub fn gamma_max(&self) -> usize {
        self.tmax - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_parse_roundtrip() {
        for m in [Method::Autoregressive, Method::QuantSpec, Method::StreamingLlm, Method::SnapKv] {
            assert_eq!(Method::parse(m.name()).unwrap(), m);
        }
        assert!(Method::parse("nope").is_err());
    }

    #[test]
    fn config_from_json_overrides() {
        let j = Json::parse(
            r#"{"method":"snapkv","gamma":6,"temperature":0.8,"buckets":[512,1024]}"#,
        )
        .unwrap();
        let c = ServeConfig::from_json(&j).unwrap();
        assert_eq!(c.method, Method::SnapKv);
        assert_eq!(c.gamma, 6);
        assert!((c.sampling.temperature - 0.8).abs() < 1e-6);
        assert_eq!(c.buckets, vec![512, 1024]);
        assert_eq!(c.max_new_tokens, 90); // default preserved
        assert_eq!(c.pool.pages, 0, "pool disabled by default");
        assert_eq!(c.prefill_chunk_tokens, 0, "monolithic prefill by default");
        assert_eq!(c.quant_queue_soft_limit, 32);
    }

    #[test]
    fn chunked_prefill_knobs_from_json() {
        let j = Json::parse(
            r#"{"prefill_chunk_tokens":256,"quant_queue_soft_limit":4}"#,
        )
        .unwrap();
        let c = ServeConfig::from_json(&j).unwrap();
        assert_eq!(c.prefill_chunk_tokens, 256);
        assert_eq!(c.quant_queue_soft_limit, 4);
    }

    #[test]
    fn parallel_round_knobs_from_json() {
        let j = Json::parse(r#"{"step_workers":3,"batcher_slots":8}"#).unwrap();
        let c = ServeConfig::from_json(&j).unwrap();
        assert_eq!(c.step_workers, 3);
        assert_eq!(c.batcher_slots, 8);
        // defaults: serial rounds, 4 slots per engine
        let d = ServeConfig::default();
        assert_eq!(d.step_workers, 1);
        assert_eq!(d.batcher_slots, 4);
        // 0 step workers propagates so the coordinator rejects it loudly
        let j = Json::parse(r#"{"step_workers":0}"#).unwrap();
        assert_eq!(ServeConfig::from_json(&j).unwrap().step_workers, 0);
    }

    #[test]
    fn scheduler_knobs_from_json() {
        let d = ServeConfig::default();
        assert_eq!(d.sched_tenants, 8);
        assert_eq!(d.request_deadline_ms, 0, "no deadline by default");
        assert_eq!(d.tenant_rate_limit, 0, "unlimited by default");
        assert!(d.fair_weights.is_empty());
        let j = Json::parse(
            r#"{"sched_tenants":4,"request_deadline_ms":1500,"tenant_rate_limit":20,
                "fair_weights":{"gold":3,"free":1}}"#,
        )
        .unwrap();
        let c = ServeConfig::from_json(&j).unwrap();
        assert_eq!(c.sched_tenants, 4);
        assert_eq!(c.request_deadline_ms, 1500);
        assert_eq!(c.tenant_rate_limit, 20);
        assert_eq!(
            c.fair_weights,
            vec![("free".to_string(), 1), ("gold".to_string(), 3)],
            "BTreeMap order: sorted by tenant name"
        );
        // nonsense values propagate so the coordinator rejects them loudly
        // at startup (mirrors step_workers / quant_workers — no clamping)
        let j = Json::parse(r#"{"sched_tenants":0,"fair_weights":{"bad":0}}"#).unwrap();
        let c = ServeConfig::from_json(&j).unwrap();
        assert_eq!(c.sched_tenants, 0);
        assert_eq!(c.fair_weights, vec![("bad".to_string(), 0)]);
    }

    #[test]
    fn trace_knobs_from_json() {
        let d = ServeConfig::default();
        assert!(d.trace_enabled, "tracing is on by default");
        assert_eq!(d.trace_buffer_events, 4096);
        assert_eq!(d.flight_recorder_requests, 64);
        let j = Json::parse(
            r#"{"trace_enabled":false,"trace_buffer_events":128,
                "flight_recorder_requests":8}"#,
        )
        .unwrap();
        let c = ServeConfig::from_json(&j).unwrap();
        assert!(!c.trace_enabled);
        assert_eq!(c.trace_buffer_events, 128);
        assert_eq!(c.flight_recorder_requests, 8);
    }

    #[test]
    fn pool_config_from_json() {
        let j = Json::parse(
            r#"{"pool":{"pages":128,"page_tokens":32,"kv_dim":4,
                "high_watermark":0.8,"low_watermark":0.95,"quant_workers":6}}"#,
        )
        .unwrap();
        let c = ServeConfig::from_json(&j).unwrap();
        assert_eq!(c.pool.pages, 128);
        assert_eq!(c.pool.page_tokens, 32);
        assert_eq!(c.pool.kv_dim, 4);
        assert!((c.pool.high_watermark - 0.8).abs() < 1e-9);
        // low watermark is clamped to the high one
        assert!((c.pool.low_watermark - 0.8).abs() < 1e-9);
        assert_eq!(c.pool.quant_workers, 6);
        // default is serial quantization
        assert_eq!(ServeConfig::default().pool.quant_workers, 1);
    }

    #[test]
    fn tier_knobs_from_json() {
        let d = ServeConfig::default();
        assert_eq!(d.pool.spill_pages, 0, "tiering off by default");
        assert_eq!(d.pool.spill_dir, "");
        assert!(d.pool.fetch_ahead, "fetch-ahead on once tiering is enabled");
        assert_eq!(d.pool.fetch_ahead_max, 8, "adaptive depth capped at 8 by default");
        assert_eq!(d.hibernate_idle_ms, 0, "no idle sweep by default");
        let j = Json::parse(
            r#"{"hibernate_idle_ms":2500,
                "pool":{"pages":64,"spill_pages":512,"spill_dir":"/tmp/qs",
                        "fetch_ahead":false,"fetch_ahead_max":3}}"#,
        )
        .unwrap();
        let c = ServeConfig::from_json(&j).unwrap();
        assert_eq!(c.hibernate_idle_ms, 2500);
        assert_eq!(c.pool.spill_pages, 512);
        assert_eq!(c.pool.spill_dir, "/tmp/qs");
        assert!(!c.pool.fetch_ahead);
        assert_eq!(c.pool.fetch_ahead_max, 3);
    }

    #[test]
    fn robustness_knobs_from_json() {
        let d = ServeConfig::default();
        assert_eq!(d.stream_buffer_events, 4096);
        assert_eq!(d.fault_seed, 0);
        assert_eq!(d.fault_spec, "", "injection off by default");
        let j = Json::parse(
            r#"{"stream_buffer_events":16,"fault_seed":42,
                "fault_spec":"spill_write:200:3,step_panic:50"}"#,
        )
        .unwrap();
        let c = ServeConfig::from_json(&j).unwrap();
        assert_eq!(c.stream_buffer_events, 16);
        assert_eq!(c.fault_seed, 42);
        assert_eq!(c.fault_spec, "spill_write:200:3,step_panic:50");
        // a malformed spec propagates so the coordinator rejects it loudly
        // at startup (mirrors step_workers — config never validates it)
        let j = Json::parse(r#"{"fault_spec":"bogus:1"}"#).unwrap();
        assert_eq!(ServeConfig::from_json(&j).unwrap().fault_spec, "bogus:1");
    }

    #[test]
    fn zero_quant_workers_propagates_for_startup_rejection() {
        // No silent clamp: 0 flows through so the coordinator's session
        // manager can reject it with a clear error at startup.
        let j = Json::parse(r#"{"pool":{"pages":8,"quant_workers":0}}"#).unwrap();
        let c = ServeConfig::from_json(&j).unwrap();
        assert_eq!(c.pool.quant_workers, 0);
        assert!(crate::pool::SessionManager::new(c.pool).is_err());
    }

    #[test]
    fn model_spec_requires_fields() {
        let j = Json::parse(r#"{"vocab":256}"#).unwrap();
        assert!(ModelSpec::from_json(&j).is_err());
    }
}
