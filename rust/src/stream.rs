//! Incremental token streaming: the `TokenSink` response contract.
//!
//! QuantSpec commits tokens in accepted bursts — one run of
//! `accepted + 1` tokens per verify cycle — so the natural streaming
//! granularity is the commit: every layer that produces committed tokens
//! (the spec engine's generate loop, the step batcher's round boundary in
//! the unified scheduler) pushes each newly committed run into a
//! [`TokenSink`] the moment the sampler accepts it, instead of only
//! accumulating it for an end-of-request response.
//!
//! A sink is the sending half of an unbounded channel of [`StreamEvent`]s:
//! sends never block the decode path, and a send observing a dropped
//! receiver ([`SinkClosed`]) is the *disconnect signal* — the consumer
//! (an HTTP connection thread, a test harness) went away, and the
//! producer side feeds that into the cancellation machinery (the
//! scheduler marks the request and evicts it at the next round boundary,
//! releasing its pool pages).
//!
//! The buffered (non-streaming) response path is the same code path with
//! a draining consumer: [`drain_tokens`] concatenates every `Token`
//! event, and the concatenation is bit-identical to the tokens a buffered
//! `GenResult`/`ResponseOut` reports — pinned by parity tests at the
//! engine, scheduler, and HTTP layers.

use std::sync::mpsc::{channel, Receiver, Sender};

/// One event on a request's response stream, in commit order.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum StreamEvent {
    /// Prompt processing finished; committed tokens follow. `prompt_tokens`
    /// is the (padded) prompt length the prefill consumed.
    Prefilled { prompt_tokens: usize },
    /// One committed run: `tokens` newly accepted in flush `cycle`
    /// (cycle 0 carries the prefill-sampled first token), `total` the
    /// cumulative committed count including this run.
    Token { cycle: usize, tokens: Vec<i32>, total: usize },
    /// Terminal: the request retired normally after `total` tokens.
    Done { total: usize },
    /// Terminal: the request aborted (engine failure, cancellation,
    /// deadline); `message` is the error string the buffered path reports.
    Error { message: String },
}

impl StreamEvent {
    /// Wire name of this event kind (the SSE `event:` field).
    pub fn kind(&self) -> &'static str {
        match self {
            StreamEvent::Prefilled { .. } => "prefill",
            StreamEvent::Token { .. } => "token",
            StreamEvent::Done { .. } => "done",
            StreamEvent::Error { .. } => "error",
        }
    }

    /// True for `Done`/`Error` — nothing follows a terminal event.
    pub fn is_terminal(&self) -> bool {
        matches!(self, StreamEvent::Done { .. } | StreamEvent::Error { .. })
    }
}

/// The consumer of a stream went away: its receiver was dropped before
/// the producer finished. Producers treat this as a client disconnect.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SinkClosed;

impl std::fmt::Display for SinkClosed {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "stream receiver dropped (client disconnected)")
    }
}

impl std::error::Error for SinkClosed {}

/// Sending half of a response stream. Cheap to clone; sends are
/// non-blocking (unbounded channel) and allocation is bounded by the
/// events actually produced — nothing on the decode step path.
#[derive(Debug, Clone)]
pub struct TokenSink {
    tx: Sender<StreamEvent>,
}

impl TokenSink {
    /// A fresh (sink, receiver) pair. The receiver is the response
    /// consumer; dropping it turns every later send into [`SinkClosed`].
    pub fn channel() -> (TokenSink, Receiver<StreamEvent>) {
        let (tx, rx) = channel();
        (TokenSink { tx }, rx)
    }

    /// Push one event toward the consumer. `Err(SinkClosed)` means the
    /// consumer disconnected; the producer should stop and cancel.
    pub fn send(&self, ev: StreamEvent) -> Result<(), SinkClosed> {
        self.tx.send(ev).map_err(|_| SinkClosed)
    }
}

/// Drain a stream to completion, concatenating every `Token` run — the
/// buffered response path, and the parity check's reference reassembly.
/// Returns the concatenated tokens and the terminal event (`None` if the
/// producer dropped the sink without sending one).
pub fn drain_tokens(rx: &Receiver<StreamEvent>) -> (Vec<i32>, Option<StreamEvent>) {
    let mut tokens = Vec::new();
    while let Ok(ev) = rx.recv() {
        match ev {
            StreamEvent::Token { tokens: ref run, .. } => tokens.extend_from_slice(run),
            StreamEvent::Prefilled { .. } => {}
            terminal => return (tokens, Some(terminal)),
        }
    }
    (tokens, None)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_flow_in_order_and_drain_concatenates() {
        let (sink, rx) = TokenSink::channel();
        sink.send(StreamEvent::Prefilled { prompt_tokens: 8 }).unwrap();
        sink.send(StreamEvent::Token { cycle: 0, tokens: vec![1], total: 1 }).unwrap();
        sink.send(StreamEvent::Token { cycle: 1, tokens: vec![2, 3, 4], total: 4 }).unwrap();
        sink.send(StreamEvent::Done { total: 4 }).unwrap();
        let (tokens, terminal) = drain_tokens(&rx);
        assert_eq!(tokens, vec![1, 2, 3, 4]);
        assert_eq!(terminal, Some(StreamEvent::Done { total: 4 }));
    }

    #[test]
    fn dropped_receiver_reports_sink_closed() {
        let (sink, rx) = TokenSink::channel();
        sink.send(StreamEvent::Prefilled { prompt_tokens: 1 }).unwrap();
        drop(rx);
        let err = sink
            .send(StreamEvent::Token { cycle: 0, tokens: vec![1], total: 1 })
            .unwrap_err();
        assert_eq!(err, SinkClosed);
        assert!(err.to_string().contains("disconnected"));
    }

    #[test]
    fn error_terminal_carries_the_buffered_message() {
        let (sink, rx) = TokenSink::channel();
        sink.send(StreamEvent::Token { cycle: 0, tokens: vec![9], total: 1 }).unwrap();
        sink.send(StreamEvent::Error { message: "cancelled: request 3".into() }).unwrap();
        let (tokens, terminal) = drain_tokens(&rx);
        assert_eq!(tokens, vec![9]);
        match terminal {
            Some(StreamEvent::Error { message }) => assert!(message.starts_with("cancelled:")),
            other => panic!("expected Error terminal, got {other:?}"),
        }
        assert!(StreamEvent::Done { total: 0 }.is_terminal());
        assert_eq!(StreamEvent::Prefilled { prompt_tokens: 0 }.kind(), "prefill");
    }

    #[test]
    fn producer_drop_without_terminal_yields_none() {
        let (sink, rx) = TokenSink::channel();
        sink.send(StreamEvent::Token { cycle: 0, tokens: vec![5, 6], total: 2 }).unwrap();
        drop(sink);
        let (tokens, terminal) = drain_tokens(&rx);
        assert_eq!(tokens, vec![5, 6]);
        assert_eq!(terminal, None);
    }
}
