//! Incremental token streaming: the `TokenSink` response contract.
//!
//! QuantSpec commits tokens in accepted bursts — one run of
//! `accepted + 1` tokens per verify cycle — so the natural streaming
//! granularity is the commit: every layer that produces committed tokens
//! (the spec engine's generate loop, the step batcher's round boundary in
//! the unified scheduler) pushes each newly committed run into a
//! [`TokenSink`] the moment the sampler accepts it, instead of only
//! accumulating it for an end-of-request response.
//!
//! A sink is the sending half of a channel of [`StreamEvent`]s: sends
//! never block the decode path, and a send observing a dropped receiver
//! ([`SinkClosed`]) is the *disconnect signal* — the consumer (an HTTP
//! connection thread, a test harness) went away, and the producer side
//! feeds that into the cancellation machinery (the scheduler marks the
//! request and evicts it at the next round boundary, releasing its pool
//! pages).
//!
//! A sink may also be **bounded** ([`TokenSink::bounded`]): the channel
//! itself stays unbounded (sends still never block), but the sink tracks
//! how many events sit unconsumed and exposes
//! [`TokenSink::over_capacity`]. The producer — the scheduler's
//! round-boundary flush — polls that flag and *sheds* the request (503
//! in-band error, pages released) instead of buffering without limit
//! behind a consumer that reads slower than tokens commit. Depth
//! accounting is why the receiving half is the [`StreamReceiver`] wrapper
//! rather than a bare `mpsc::Receiver`.
//!
//! The buffered (non-streaming) response path is the same code path with
//! a draining consumer: [`drain_tokens`] concatenates every `Token`
//! event, and the concatenation is bit-identical to the tokens a buffered
//! `GenResult`/`ResponseOut` reports — pinned by parity tests at the
//! engine, scheduler, and HTTP layers.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvError, RecvTimeoutError, Sender, TryRecvError};
use std::sync::Arc;
use std::time::Duration;

/// One event on a request's response stream, in commit order.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum StreamEvent {
    /// Prompt processing finished; committed tokens follow. `prompt_tokens`
    /// is the (padded) prompt length the prefill consumed.
    Prefilled { prompt_tokens: usize },
    /// One committed run: `tokens` newly accepted in flush `cycle`
    /// (cycle 0 carries the prefill-sampled first token), `total` the
    /// cumulative committed count including this run.
    Token { cycle: usize, tokens: Vec<i32>, total: usize },
    /// Terminal: the request retired normally after `total` tokens.
    Done { total: usize },
    /// Terminal: the request aborted (engine failure, cancellation,
    /// deadline); `message` is the error string the buffered path reports.
    Error { message: String },
}

impl StreamEvent {
    /// Wire name of this event kind (the SSE `event:` field).
    pub fn kind(&self) -> &'static str {
        match self {
            StreamEvent::Prefilled { .. } => "prefill",
            StreamEvent::Token { .. } => "token",
            StreamEvent::Done { .. } => "done",
            StreamEvent::Error { .. } => "error",
        }
    }

    /// True for `Done`/`Error` — nothing follows a terminal event.
    pub fn is_terminal(&self) -> bool {
        matches!(self, StreamEvent::Done { .. } | StreamEvent::Error { .. })
    }
}

/// The consumer of a stream went away: its receiver was dropped before
/// the producer finished. Producers treat this as a client disconnect.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SinkClosed;

impl std::fmt::Display for SinkClosed {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "stream receiver dropped (client disconnected)")
    }
}

impl std::error::Error for SinkClosed {}

/// Sending half of a response stream. Cheap to clone; sends are
/// non-blocking (the underlying channel is unbounded even for a bounded
/// sink — the bound is enforced by the producer shedding on
/// [`TokenSink::over_capacity`], never by blocking the decode path).
#[derive(Debug, Clone)]
pub struct TokenSink {
    tx: Sender<StreamEvent>,
    /// Events sent but not yet consumed by the [`StreamReceiver`].
    depth: Arc<AtomicUsize>,
    /// Shed threshold for `over_capacity` (0 = unbounded).
    capacity: usize,
}

impl TokenSink {
    /// A fresh unbounded (sink, receiver) pair. The receiver is the
    /// response consumer; dropping it turns every later send into
    /// [`SinkClosed`].
    pub fn channel() -> (TokenSink, StreamReceiver) {
        TokenSink::bounded(0)
    }

    /// A (sink, receiver) pair whose sink reports [`TokenSink::
    /// over_capacity`] once more than `capacity` events sit unconsumed
    /// (`capacity == 0` disables the bound). Sends still never block or
    /// fail on depth — backpressure is the PRODUCER's decision, taken at
    /// a clean boundary (the scheduler sheds at end of round), not a
    /// mid-commit stall.
    pub fn bounded(capacity: usize) -> (TokenSink, StreamReceiver) {
        let (tx, rx) = channel();
        let depth = Arc::new(AtomicUsize::new(0));
        (
            TokenSink { tx, depth: Arc::clone(&depth), capacity },
            StreamReceiver { rx, depth },
        )
    }

    /// Push one event toward the consumer. `Err(SinkClosed)` means the
    /// consumer disconnected; the producer should stop and cancel.
    pub fn send(&self, ev: StreamEvent) -> Result<(), SinkClosed> {
        // Increment BEFORE the send: the receiver only decrements for an
        // event it actually pulled, so depth can never underflow.
        self.depth.fetch_add(1, Ordering::AcqRel);
        match self.tx.send(ev) {
            Ok(()) => Ok(()),
            Err(_) => {
                self.depth.fetch_sub(1, Ordering::AcqRel);
                Err(SinkClosed)
            }
        }
    }

    /// Events sent but not yet consumed (instantaneous gauge).
    pub fn depth(&self) -> usize {
        self.depth.load(Ordering::Acquire)
    }

    /// The shed threshold this sink was built with (0 = unbounded).
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// True when a bounded sink's consumer has fallen more than
    /// `capacity` events behind — the producer's signal to shed the
    /// stream instead of buffering unboundedly.
    pub fn over_capacity(&self) -> bool {
        self.capacity > 0 && self.depth.load(Ordering::Acquire) > self.capacity
    }
}

/// Receiving half of a response stream: a `mpsc::Receiver` that also
/// decrements the sink's depth gauge on every consumed event, which is
/// what makes [`TokenSink::over_capacity`] mean "consumer is behind"
/// rather than "events were ever sent".
#[derive(Debug)]
pub struct StreamReceiver {
    rx: Receiver<StreamEvent>,
    depth: Arc<AtomicUsize>,
}

impl StreamReceiver {
    pub fn recv(&self) -> Result<StreamEvent, RecvError> {
        let ev = self.rx.recv()?;
        self.depth.fetch_sub(1, Ordering::AcqRel);
        Ok(ev)
    }

    pub fn try_recv(&self) -> Result<StreamEvent, TryRecvError> {
        let ev = self.rx.try_recv()?;
        self.depth.fetch_sub(1, Ordering::AcqRel);
        Ok(ev)
    }

    pub fn recv_timeout(&self, timeout: Duration) -> Result<StreamEvent, RecvTimeoutError> {
        let ev = self.rx.recv_timeout(timeout)?;
        self.depth.fetch_sub(1, Ordering::AcqRel);
        Ok(ev)
    }

    /// Non-blocking drain of everything currently queued.
    pub fn try_iter(&self) -> impl Iterator<Item = StreamEvent> + '_ {
        std::iter::from_fn(move || self.try_recv().ok())
    }
}

/// Drain a stream to completion, concatenating every `Token` run — the
/// buffered response path, and the parity check's reference reassembly.
/// Returns the concatenated tokens and the terminal event (`None` if the
/// producer dropped the sink without sending one).
pub fn drain_tokens(rx: &StreamReceiver) -> (Vec<i32>, Option<StreamEvent>) {
    let mut tokens = Vec::new();
    while let Ok(ev) = rx.recv() {
        match ev {
            StreamEvent::Token { tokens: ref run, .. } => tokens.extend_from_slice(run),
            StreamEvent::Prefilled { .. } => {}
            terminal => return (tokens, Some(terminal)),
        }
    }
    (tokens, None)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_flow_in_order_and_drain_concatenates() {
        let (sink, rx) = TokenSink::channel();
        sink.send(StreamEvent::Prefilled { prompt_tokens: 8 }).unwrap();
        sink.send(StreamEvent::Token { cycle: 0, tokens: vec![1], total: 1 }).unwrap();
        sink.send(StreamEvent::Token { cycle: 1, tokens: vec![2, 3, 4], total: 4 }).unwrap();
        sink.send(StreamEvent::Done { total: 4 }).unwrap();
        let (tokens, terminal) = drain_tokens(&rx);
        assert_eq!(tokens, vec![1, 2, 3, 4]);
        assert_eq!(terminal, Some(StreamEvent::Done { total: 4 }));
    }

    #[test]
    fn dropped_receiver_reports_sink_closed() {
        let (sink, rx) = TokenSink::channel();
        sink.send(StreamEvent::Prefilled { prompt_tokens: 1 }).unwrap();
        drop(rx);
        let err = sink
            .send(StreamEvent::Token { cycle: 0, tokens: vec![1], total: 1 })
            .unwrap_err();
        assert_eq!(err, SinkClosed);
        assert!(err.to_string().contains("disconnected"));
    }

    #[test]
    fn error_terminal_carries_the_buffered_message() {
        let (sink, rx) = TokenSink::channel();
        sink.send(StreamEvent::Token { cycle: 0, tokens: vec![9], total: 1 }).unwrap();
        sink.send(StreamEvent::Error { message: "cancelled: request 3".into() }).unwrap();
        let (tokens, terminal) = drain_tokens(&rx);
        assert_eq!(tokens, vec![9]);
        match terminal {
            Some(StreamEvent::Error { message }) => assert!(message.starts_with("cancelled:")),
            other => panic!("expected Error terminal, got {other:?}"),
        }
        assert!(StreamEvent::Done { total: 0 }.is_terminal());
        assert_eq!(StreamEvent::Prefilled { prompt_tokens: 0 }.kind(), "prefill");
    }

    #[test]
    fn producer_drop_without_terminal_yields_none() {
        let (sink, rx) = TokenSink::channel();
        sink.send(StreamEvent::Token { cycle: 0, tokens: vec![5, 6], total: 2 }).unwrap();
        drop(sink);
        let (tokens, terminal) = drain_tokens(&rx);
        assert_eq!(tokens, vec![5, 6]);
        assert_eq!(terminal, None);
    }

    #[test]
    fn bounded_sink_reports_over_capacity_and_recovers_on_consumption() {
        let (sink, rx) = TokenSink::bounded(2);
        assert_eq!(sink.capacity(), 2);
        for i in 0..2 {
            sink.send(StreamEvent::Token { cycle: i, tokens: vec![i as i32], total: i + 1 })
                .unwrap();
        }
        // exactly at capacity: not over
        assert_eq!(sink.depth(), 2);
        assert!(!sink.over_capacity());
        // one past: over — but the send itself still succeeded (shedding
        // is the producer's call, never a blocked or failed send)
        sink.send(StreamEvent::Token { cycle: 2, tokens: vec![2], total: 3 }).unwrap();
        assert!(sink.over_capacity());
        // a slow consumer catching up clears the flag
        rx.recv().unwrap();
        assert_eq!(sink.depth(), 2);
        assert!(!sink.over_capacity());
        let rest: Vec<StreamEvent> = rx.try_iter().collect();
        assert_eq!(rest.len(), 2);
        assert_eq!(sink.depth(), 0);
    }

    #[test]
    fn unbounded_sink_never_reports_over_capacity() {
        let (sink, _rx) = TokenSink::channel();
        for i in 0..100 {
            sink.send(StreamEvent::Token { cycle: i, tokens: vec![1], total: i + 1 })
                .unwrap();
        }
        assert_eq!(sink.depth(), 100);
        assert!(!sink.over_capacity(), "capacity 0 disables the bound");
    }

    #[test]
    fn failed_send_does_not_inflate_depth() {
        let (sink, rx) = TokenSink::bounded(1);
        drop(rx);
        assert!(sink.send(StreamEvent::Done { total: 0 }).is_err());
        assert_eq!(sink.depth(), 0, "the undone increment left no residue");
        assert!(!sink.over_capacity());
    }
}
