//! Benchmark harness (no criterion offline): warmup + timed iterations +
//! robust statistics, plus the paper-style table/series printers used by
//! every `benches/*.rs` regenerator.

use std::time::Instant;

/// Timing statistics over N iterations.
#[derive(Debug, Clone, Copy)]
pub struct BenchStats {
    pub iters: usize,
    pub mean_secs: f64,
    pub median_secs: f64,
    pub min_secs: f64,
    pub max_secs: f64,
}

/// Run `f` with warmup, collect per-iteration wall times.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    stats(&times)
}

pub fn stats(times: &[f64]) -> BenchStats {
    let mut sorted = times.to_vec();
    sorted.sort_by(f64::total_cmp);
    let n = sorted.len().max(1);
    BenchStats {
        iters: times.len(),
        mean_secs: times.iter().sum::<f64>() / n as f64,
        median_secs: sorted[n / 2],
        min_secs: *sorted.first().unwrap_or(&0.0),
        max_secs: *sorted.last().unwrap_or(&0.0),
    }
}

/// Plain-text table printer (paper-style rows).
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity");
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self, title: &str) {
        println!("\n== {title} ==");
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!(" {:<w$} |", c, w = widths[i]));
            }
            s
        };
        println!("{}", line(&self.headers));
        println!(
            "|{}|",
            widths.iter().map(|w| "-".repeat(w + 2)).collect::<Vec<_>>().join("|")
        );
        for row in &self.rows {
            println!("{}", line(row));
        }
    }

    /// CSV dump alongside the pretty print (for plotting).
    pub fn write_csv(&self, path: &str) -> std::io::Result<()> {
        let mut out = self.headers.join(",") + "\n";
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        if let Some(dir) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, out)
    }
}

/// A pooled single-session cache prefilled for the verify-window kernel
/// rows shared by `benches/kernel_hotpath.rs` and
/// `benches/table4_kernels.rs`: geometry (G, d), FB = 2G + γ, 3 quant
/// groups + a full C_F1, watermarks disabled, serial quantization. The
/// single home of that setup so both benches measure the same thing.
/// Returns the manager (keep it alive) alongside the cache.
pub fn verify_window_cache(
    g: usize,
    d: usize,
    gamma_w: usize,
) -> (crate::pool::SharedSessionManager, crate::pool::PagedKvCache) {
    use crate::pool::{mock_kv, shared, PagedKvCache, PoolConfig};
    let mgr = shared(PoolConfig {
        pages: 64,
        page_tokens: g,
        kv_dim: d,
        high_watermark: 1.0,
        low_watermark: 1.0,
        quant_workers: 1,
    })
    .expect("pool config valid");
    mgr.lock().unwrap().admit(1, 16, false).unwrap();
    let fb = 2 * g + gamma_w;
    let mut cache = PagedKvCache::new(mgr.clone(), 1, g, d, fb, 8 * g).unwrap();
    cache.prefill(4 * g, &|p| mock_kv(p, p as i32, d)).unwrap();
    (mgr, cache)
}

pub fn fmt_f(v: f64, prec: usize) -> String {
    format!("{v:.prec$}")
}

pub fn fmt_ms(secs: f64) -> String {
    format!("{:.2} ms", secs * 1e3)
}

pub fn fmt_gb(bytes: f64) -> String {
    format!("{:.2} GB", bytes / 1e9)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_ordering() {
        let s = stats(&[3.0, 1.0, 2.0]);
        assert_eq!(s.min_secs, 1.0);
        assert_eq!(s.max_secs, 3.0);
        assert_eq!(s.median_secs, 2.0);
        assert!((s.mean_secs - 2.0).abs() < 1e-12);
    }

    #[test]
    fn bench_runs_expected_iters() {
        let mut n = 0;
        let s = bench(2, 5, || n += 1);
        assert_eq!(n, 7);
        assert_eq!(s.iters, 5);
    }

    #[test]
    fn table_csv() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["1".into(), "x".into()]);
        let path = std::env::temp_dir().join("qs_table.csv");
        t.write_csv(path.to_str().unwrap()).unwrap();
        let got = std::fs::read_to_string(&path).unwrap();
        assert_eq!(got, "a,b\n1,x\n");
    }
}

pub mod paper;
