//! Shared helpers for the paper-experiment regenerators in `benches/`.
//!
//! Context scale: the paper evaluates 4k-128k on Llama-2-7B-class models;
//! this testbed's buckets are 256-2048 on the tiny preset — a fixed 32x
//! scale (DESIGN.md §4). `paper_context` maps a bucket to the paper row it
//! stands in for. Measured quantities (acceptance rate, CPU wall time) come
//! from real runs; A6000 latencies/speedups are projected through the cost
//! model with the measured acceptance (costmodel::latency).

use std::sync::Arc;

use anyhow::Result;

use crate::cache::MemoryReport;
use crate::config::{Method, QuantMode, Sampling};
use crate::model::xla_session::XlaSession;
use crate::model::{Decoder, PhaseTimings};
use crate::runtime::{Runtime, WeightSet, Weights};
use crate::spec::{Sampler, SpecEngine};
use crate::workload::{self, Profile};

/// Paper-equivalent context label for a bucket (32x scale).
pub fn paper_context(bucket: usize) -> String {
    let k = bucket * 32 / 1024;
    format!("{k}k")
}

/// Quick mode for CI-ish runs: QS_BENCH_QUICK=1 trims buckets and tokens.
pub fn quick() -> bool {
    std::env::var("QS_BENCH_QUICK").map_or(false, |v| v != "0")
}

pub struct Harness {
    pub rt: Arc<Runtime>,
    pub w_fp: Arc<Weights>,
    pub w_q4: Arc<Weights>,
}

impl Harness {
    pub fn load() -> Result<Harness> {
        let rt = Runtime::load("artifacts")?;
        let w_fp = Arc::new(Weights::load(&rt, WeightSet::Fp)?);
        let w_q4 = Arc::new(Weights::load(&rt, WeightSet::Q4)?);
        Ok(Harness { rt, w_fp, w_q4 })
    }

    pub fn buckets(&self) -> Vec<usize> {
        let mut b = self.rt.manifest.buckets.clone();
        b.sort_unstable();
        if quick() {
            b.truncate(2);
        }
        b
    }

    pub fn session(
        &self,
        method: Method,
        quant_mode: QuantMode,
        bucket: usize,
    ) -> Result<XlaSession> {
        XlaSession::new(
            Arc::clone(&self.rt),
            method,
            quant_mode,
            bucket,
            Arc::clone(&self.w_fp),
            Arc::clone(&self.w_q4),
        )
    }
}

/// One measured end-to-end decode trial.
#[derive(Debug, Clone)]
pub struct Trial {
    pub method: Method,
    pub bucket: usize,
    pub acceptance: f64,
    pub decode_tps: f64,
    pub prefill_secs: f64,
    pub decode_secs: f64,
    pub tokens: usize,
    pub memory: MemoryReport,
    pub timings: PhaseTimings,
}

#[allow(clippy::too_many_arguments)]
pub fn run_trial(
    h: &Harness,
    method: Method,
    quant_mode: QuantMode,
    bucket: usize,
    profile: Profile,
    seed: u64,
    gamma: usize,
    max_new: usize,
) -> Result<Trial> {
    // steady-state measurement: compile this bucket's entries up front so
    // first-use XLA compilation doesn't pollute decode timings.
    h.rt.warmup(&[bucket])?;
    let mut sess = h.session(method, quant_mode, bucket)?;
    let prompt = workload::prompt(seed, bucket, profile);
    let sampling = Sampling::default(); // greedy: acceptance is deterministic
    let mut eng = SpecEngine::new(gamma, Sampler::new(sampling.temperature, seed));
    let res = eng.generate(&mut sess, &prompt, max_new)?;
    Ok(Trial {
        method,
        bucket,
        acceptance: res.acceptance_rate(),
        decode_tps: res.decode_tokens_per_sec(),
        prefill_secs: res.prefill_secs,
        decode_secs: res.decode_secs,
        tokens: res.tokens.len(),
        memory: sess.memory(),
        timings: sess.timings(),
    })
}

/// Average trials over seeds.
pub fn mean_trials(trials: &[Trial]) -> (f64, f64) {
    let n = trials.len().max(1) as f64;
    let acc = trials.iter().map(|t| t.acceptance).sum::<f64>() / n;
    let tps = trials.iter().map(|t| t.decode_tps).sum::<f64>() / n;
    (acc, tps)
}

/// Mean per-byte perplexity from a score_* entry over `n_docs` synthetic
/// documents (Tables 2 and 5).
pub fn score_ppl(h: &Harness, variant: &str, profile: Profile, n_docs: usize) -> Result<f64> {
    let s = h.rt.manifest.score_bucket;
    let entry = format!("{variant}_{s}");
    let exe = h.rt.executor(&entry)?;
    let mut total_nll = 0.0f64;
    let mut total_tok = 0usize;
    for seed in 0..n_docs as u64 {
        let prompt = workload::prompt(seed * 31 + 7, s, profile);
        let toks = crate::runtime::HostTensor::i32(vec![s], prompt)?;
        let mut args: Vec<crate::runtime::Arg<'_>> =
            vec![crate::runtime::Arg::Host(&toks)];
        for w in &h.w_fp.tensors {
            args.push(crate::runtime::Arg::Device(w));
        }
        let (outs, _) = exe.call(h.rt.client(), &args)?;
        let ll = outs[0].as_f32()?;
        total_nll += ll.iter().map(|&x| -(x as f64)).sum::<f64>();
        total_tok += ll.len();
    }
    Ok((total_nll / total_tok as f64).exp())
}
