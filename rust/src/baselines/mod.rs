//! Sparse-KV self-speculative baselines (paper §5.1, following MagicDec).
//!
//! Both baselines share QuantSpec's engine and verify path; only the draft
//! cache differs:
//! * **StreamingLLM** (Xiao et al.): attention-sink prefix + sliding recent
//!   window.
//! * **SnapKV** (Li et al.): prompt positions selected at prefill time by
//!   pooled attention mass from the final observation window.
//!
//! The draft KV budget is context/4, matching the byte footprint of
//! QuantSpec's 4-bit cache (the paper's fair-comparison setup). Selection
//! here is pure index math; the gather into a dense budget region happens
//! in `model::xla_session`.

/// StreamingLLM: sink prefix + most recent window, ascending order.
pub fn streaming_indices(s: usize, budget: usize, sink_tokens: usize) -> Vec<usize> {
    let sink = sink_tokens.min(budget / 2);
    let recent = budget - sink;
    let mut idx: Vec<usize> = (0..sink).collect();
    idx.extend(s - recent..s);
    idx
}

/// SnapKV: top-(budget-g) positions by max-pooled observation score over
/// the quantizable prefix [0, s-g), ascending, plus the last g prompt
/// tokens (the observation window itself stays).
pub fn snapkv_indices(snap: &[f32], s: usize, g: usize, budget: usize) -> Vec<usize> {
    let keep_sel = budget.saturating_sub(g);
    let pool = 7usize;
    let n = s - g;
    let pooled: Vec<f32> = (0..n)
        .map(|i| {
            let lo = i.saturating_sub(pool / 2);
            let hi = (i + pool / 2 + 1).min(n);
            snap[lo..hi].iter().copied().fold(f32::MIN, f32::max)
        })
        .collect();
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| pooled[b].total_cmp(&pooled[a]));
    let mut sel: Vec<usize> = order.into_iter().take(keep_sel).collect();
    sel.sort_unstable();
    sel.extend(s - g..s);
    sel
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn streaming_shape() {
        let idx = streaming_indices(1024, 256, 64);
        assert_eq!(idx.len(), 256);
        assert_eq!(idx[0], 0);
        assert_eq!(*idx.last().unwrap(), 1023);
        assert!(idx.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn streaming_small_budget_halves_sink() {
        let idx = streaming_indices(512, 64, 64);
        assert_eq!(idx.len(), 64);
        assert!(idx.contains(&31)); // sink capped at budget/2
        assert!(idx.contains(&511));
    }

    #[test]
    fn snapkv_picks_high_scores() {
        let s = 512;
        let g = 64;
        let mut snap = vec![0.0f32; s];
        snap[17] = 9.0;
        snap[200] = 8.0;
        let idx = snapkv_indices(&snap, s, g, 128);
        assert_eq!(idx.len(), 128);
        assert!(idx.contains(&17));
        assert!(idx.contains(&200));
        assert!(idx.windows(2).all(|w| w[0] < w[1]));
        for t in s - g..s {
            assert!(idx.contains(&t), "recent token {t} kept");
        }
    }

    #[test]
    fn snapkv_pooling_keeps_neighborhoods() {
        let s = 256;
        let g = 64;
        let mut snap = vec![0.0f32; s];
        snap[100] = 10.0;
        let idx = snapkv_indices(&snap, s, g, 96);
        // pooled window around the spike should be selected
        for t in 98..=102 {
            assert!(idx.contains(&t), "neighbor {t}");
        }
    }
}
