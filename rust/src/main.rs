//! quantspec — leader binary.
//!
//! Subcommands:
//!   serve    start the HTTP coordinator over the AOT artifacts
//!   run      one-shot generation from the CLI
//!   info     print manifest + cost-model summary
//!   warmup   compile all artifacts for the chosen buckets
//!
//! Benchmarks regenerating the paper's tables/figures live in `benches/`
//! (cargo bench); runnable scenarios in `examples/`.

use std::sync::Arc;

use anyhow::{Context, Result};
use quantspec::config::{Method, QuantMode, ServeConfig};
use quantspec::coordinator::{server, Coordinator, RequestSpec};
use quantspec::costmodel::{self, Hardware, PaperModel};
use quantspec::runtime::Runtime;
use quantspec::util::argparse::Args;

fn main() {
    let args = Args::from_env();
    let code = match dispatch(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            2
        }
    };
    std::process::exit(code);
}

fn config_from_args(args: &Args) -> Result<ServeConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => ServeConfig::from_file(path)?,
        None => ServeConfig::default(),
    };
    if let Some(d) = args.get("artifacts") {
        cfg.artifacts_dir = d.to_string();
    }
    if let Some(m) = args.get("method") {
        cfg.method = Method::parse(m)?;
    }
    if let Some(q) = args.get("quant-mode") {
        cfg.quant_mode = QuantMode::parse(q)?;
    }
    cfg.gamma = args.get_usize("gamma", cfg.gamma);
    cfg.max_new_tokens = args.get_usize("max-new-tokens", cfg.max_new_tokens);
    cfg.engines = args.get_usize("engines", cfg.engines);
    cfg.sampling.temperature = args.get_f64("temperature", cfg.sampling.temperature as f64) as f32;
    cfg.sampling.seed = args.get_usize("seed", cfg.sampling.seed as usize) as u64;
    if let Some(b) = args.get("bind") {
        cfg.bind = b.to_string();
    }
    if let Some(bl) = args.get_list("buckets") {
        cfg.buckets = bl;
    }
    cfg.pool.pages = args.get_usize("pool-pages", cfg.pool.pages);
    cfg.pool.page_tokens = args.get_usize("pool-page-tokens", cfg.pool.page_tokens).max(1);
    // not clamped: 0 is rejected with a clear error at coordinator startup
    cfg.pool.quant_workers = args.get_usize("quant-workers", cfg.pool.quant_workers);
    // cold-tier knobs: spill capacity in pages, spill-file directory, and
    // speculative fetch-ahead of the next verify window
    cfg.pool.spill_pages = args.get_usize("spill-pages", cfg.pool.spill_pages);
    if let Some(d) = args.get("spill-dir") {
        cfg.pool.spill_dir = d.to_string();
    }
    cfg.pool.fetch_ahead = args.get_usize("fetch-ahead", cfg.pool.fetch_ahead as usize) != 0;
    cfg.pool.fetch_ahead_max =
        args.get_usize("fetch-ahead-max", cfg.pool.fetch_ahead_max);
    cfg.hibernate_idle_ms =
        args.get_usize("hibernate-idle-ms", cfg.hibernate_idle_ms as usize) as u64;
    cfg.prefill_chunk_tokens =
        args.get_usize("prefill-chunk-tokens", cfg.prefill_chunk_tokens);
    cfg.quant_queue_soft_limit =
        args.get_usize("quant-queue-soft-limit", cfg.quant_queue_soft_limit);
    // not clamped: 0 is rejected with a clear error at coordinator startup
    cfg.step_workers = args.get_usize("step-workers", cfg.step_workers);
    cfg.batcher_slots = args.get_usize("batcher-slots", cfg.batcher_slots).max(1);
    // request tracing: --trace-enabled 0 turns the subsystem off entirely
    cfg.trace_enabled = args.get_usize("trace-enabled", cfg.trace_enabled as usize) != 0;
    cfg.trace_buffer_events =
        args.get_usize("trace-buffer-events", cfg.trace_buffer_events);
    cfg.flight_recorder_requests =
        args.get_usize("flight-recorder-requests", cfg.flight_recorder_requests);
    // unified-scheduler admission knobs; nonsense values (0 tenants, a
    // zero fair weight) are rejected with clear errors at startup
    cfg.sched_tenants = args.get_usize("sched-tenants", cfg.sched_tenants);
    cfg.request_deadline_ms =
        args.get_usize("request-deadline-ms", cfg.request_deadline_ms as usize) as u64;
    cfg.tenant_rate_limit = args.get_usize("tenant-rate-limit", cfg.tenant_rate_limit);
    if let Some(spec) = args.get("fair-weights") {
        cfg.fair_weights = parse_fair_weights(spec)?;
    }
    Ok(cfg)
}

/// `--fair-weights gold=8,free=1` -> [("gold", 8), ("free", 1)]. Weight 0
/// parses here but is rejected by coordinator startup validation.
fn parse_fair_weights(spec: &str) -> Result<Vec<(String, u64)>> {
    spec.split(',')
        .filter(|s| !s.trim().is_empty())
        .map(|pair| {
            let (t, w) = pair.split_once('=').with_context(|| {
                format!("--fair-weights entry '{pair}' is not tenant=weight")
            })?;
            let w: u64 = w.trim().parse().with_context(|| {
                format!("--fair-weights weight in '{pair}' is not an integer")
            })?;
            Ok((t.trim().to_string(), w))
        })
        .collect()
}

fn dispatch(args: &Args) -> Result<()> {
    match args.subcommand.as_str() {
        "serve" => serve_cmd(args),
        "run" => run_cmd(args),
        "info" => info_cmd(args),
        "warmup" => warmup_cmd(args),
        "" | "help" => {
            print_help();
            Ok(())
        }
        other => {
            print_help();
            anyhow::bail!("unknown subcommand '{other}'")
        }
    }
}

fn print_help() {
    println!(
        "quantspec — self-speculative decoding with hierarchical quantized KV cache

USAGE: quantspec <serve|run|info|warmup> [options]

OPTIONS (shared):
  --artifacts DIR      artifact directory (default: artifacts)
  --method M           ar | quantspec | streamingllm | snapkv
  --quant-mode Q       both | kv-only | weight-only   (Fig. 4 ablations)
  --gamma N            speculation length (default 4)
  --max-new-tokens N   generation budget (default 90, as in the paper)
  --temperature T      0 = greedy
  --engines N          decode engines (serve)
  --bind ADDR          HTTP bind (serve; default 127.0.0.1:8311)
  --mock               use the mock backend (no artifacts needed)
  --pool-pages N       paged KV pool size in pages (0 = pooling off)
  --pool-page-tokens G tokens per pool page (default 64)
  --quant-workers N    size of the ONE process-wide quantization pool shared
                       by all sessions' prefills (default 1 = serial; 0 errors)
  --spill-pages N      cold-tier capacity in pages: page-granular spill to
                       disk replaces eviction as the first reclaim resort,
                       and idle sessions hibernate losslessly
                       (default 0 = tiering off)
  --spill-dir DIR      directory for the spill file (default: the OS temp
                       dir; the file is unlinked on shutdown)
  --fetch-ahead 0|1    speculatively restore the next verify window's cold
                       pages at cycle start (default 1)
  --fetch-ahead-max N  cap on the adaptive fetch-ahead depth in quant groups:
                       the live depth starts at 1 and rises toward N while
                       reads keep faulting on cold pages (default 8)
  --hibernate-idle-ms N
                       scheduler idle sweep: sessions untouched for N ms
                       move wholly to the cold tier and fault back
                       bit-identically on next use (default 0 = off;
                       requires --spill-pages > 0)
  --prefill-chunk-tokens N
                       schedulable prefill: feed prompts in N-token chunks so
                       a batcher round costs O(chunk), not O(prompt)
                       (default 0 = monolithic one-shot prefill)
  --quant-queue-soft-limit N
                       defer prefill chunks while the shared quant pool's
                       queue depth exceeds N (decode keeps running;
                       surfaces as the prefill_deferrals counter; default 32)
  --step-workers N     step workers per engine batcher: a scheduling round
                       steps its sessions concurrently on N workers,
                       bit-identical to serial rounds (default 1 = serial;
                       0 errors at startup)
  --batcher-slots N    sessions one engine batcher multiplexes at once
                       (round-robin capacity; default 4)
  --trace-enabled 0|1  request-scoped phase tracing feeding /debug/requests
                       and the /metrics phase histograms (default 1; the
                       traced hot path stays allocation-free)
  --trace-buffer-events N
                       preallocated trace slots per request; events past
                       the cap are counted as dropped (default 4096)
  --flight-recorder-requests N
                       completed request timelines the flight recorder
                       retains for /debug/requests (default 64)
  --sched-tenants N    concurrent tenant lanes in the unified scheduler's
                       weighted fair queue; idle lanes are reclaimed before
                       new tenants are shed (default 8; 0 errors at startup)
  --request-deadline-ms N
                       default per-request SLO deadline: requests that
                       exceed it are rejected in queue or evicted mid-flight
                       with their pool pages freed (default 0 = none; a
                       request's own deadline_ms overrides this)
  --tenant-rate-limit N
                       per-tenant admission rate in requests/second with a
                       one-second burst (token bucket; default 0 = unlimited)
  --fair-weights SPEC  deficit-round-robin weights per tenant, e.g.
                       gold=8,free=1 (unlisted tenants weigh 1; weight 0
                       errors at startup; a backlogged tenant waits at most
                       the sum of the other tenants' weights in grants)

run-only:
  --prompt TEXT | --prompt-len N --profile pg19|lexsum|infbench --seed S"
    );
}

fn serve_cmd(args: &Args) -> Result<()> {
    let cfg = config_from_args(args)?;
    let bind = cfg.bind.clone();
    let coord = if args.has_flag("mock") {
        Arc::new(Coordinator::with_mock(cfg, 0.1)?)
    } else {
        let rt = Runtime::load(&cfg.artifacts_dir)?;
        let buckets = if cfg.buckets.is_empty() {
            rt.manifest.buckets.clone()
        } else {
            cfg.buckets.clone()
        };
        eprintln!("compiling artifacts for buckets {buckets:?}...");
        rt.warmup(&buckets)?;
        Arc::new(Coordinator::with_runtime(cfg, rt)?)
    };
    let srv = server::serve(Arc::clone(&coord), &bind)
        .with_context(|| format!("binding {bind}"))?;
    println!("quantspec serving on http://{}", srv.addr);
    println!(
        "  POST /generate   POST /cancel   GET /stats   GET /metrics   \
         GET /debug/requests   GET /healthz"
    );
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn run_cmd(args: &Args) -> Result<()> {
    let cfg = config_from_args(args)?;
    let prompt: Vec<i32> = if let Some(text) = args.get("prompt") {
        text.bytes().map(|b| b as i32).collect()
    } else {
        let len = args.get_usize("prompt-len", 512);
        let profile = match args.get_or("profile", "pg19") {
            "lexsum" => quantspec::workload::Profile::LexSum,
            "infbench" => quantspec::workload::Profile::InfBench,
            _ => quantspec::workload::Profile::Pg19,
        };
        quantspec::workload::prompt(cfg.sampling.seed, len, profile)
    };
    let coord = if args.has_flag("mock") {
        Coordinator::with_mock(cfg.clone(), 0.1)?
    } else {
        let rt = Runtime::load(&cfg.artifacts_dir)?;
        Coordinator::with_runtime(cfg.clone(), rt)?
    };
    let out = coord.generate(RequestSpec {
        id: 1,
        prompt,
        max_new_tokens: cfg.max_new_tokens,
        method: None,
        gamma: None,
        tenant: None,
        deadline_ms: None,
        sink: None,
    })?;
    let text: String = out
        .tokens
        .iter()
        .map(|&t| {
            let b = (t as u32).min(255) as u8;
            if b.is_ascii_graphic() || b == b' ' || b == b'\n' {
                b as char
            } else {
                '\u{fffd}'
            }
        })
        .collect();
    println!("--- generated ({} tokens, bucket {}) ---", out.tokens.len(), out.bucket);
    println!("{text}");
    println!("--- stats ---");
    println!("method            : {}", cfg.method.name());
    println!("acceptance rate   : {:.2}%", out.acceptance_rate * 100.0);
    println!("prefill           : {:.3}s", out.prefill_secs);
    println!("decode            : {:.3}s ({:.2} tok/s)", out.decode_secs, out.decode_tokens_per_sec);
    Ok(())
}

fn info_cmd(args: &Args) -> Result<()> {
    let cfg = config_from_args(args)?;
    let rt = Runtime::load(&cfg.artifacts_dir)?;
    let m = &rt.manifest.model;
    println!("model: vocab={} d={} heads={} head_dim={} layers={} ffn={}",
             m.vocab, m.d_model, m.n_heads, m.head_dim, m.n_layers, m.d_ff);
    println!("quant: G={} tmax={} FB={}", m.g, m.tmax, m.fb);
    println!("buckets: {:?} (score bucket {})", rt.manifest.buckets, rt.manifest.score_bucket);
    println!("entries: {}", rt.manifest.entries.len());
    let pm = PaperModel::llama2_7b();
    let hw = Hardware::a6000();
    println!("\ncost model (Llama-2-7B on A6000, the paper's testbed):");
    println!("  ridge point: {:.0} FLOPs/byte", hw.ridge_point());
    for s in [4096usize, 32768, 131_072] {
        let sp = costmodel::latency::projected_speedup(
            &pm, &hw, Method::QuantSpec, QuantMode::Both, 1, s, 4, 0.92,
        );
        println!("  projected QuantSpec speedup @S={s}: {sp:.2}x (α=0.92, γ=4)");
    }
    Ok(())
}

fn warmup_cmd(args: &Args) -> Result<()> {
    let cfg = config_from_args(args)?;
    let rt = Runtime::load(&cfg.artifacts_dir)?;
    let buckets = if cfg.buckets.is_empty() {
        rt.manifest.buckets.clone()
    } else {
        cfg.buckets
    };
    let t0 = std::time::Instant::now();
    rt.warmup(&buckets)?;
    println!(
        "compiled {} entries for buckets {buckets:?} in {:.1}s",
        rt.compile_secs.lock().unwrap().len(),
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}
