//! Serving workloads: synthetic long-context prompts + arrival traces.
//!
//! `textgen` mirrors python/compile/corpus.py (same PCG32, same templates)
//! so benchmark prompts come from the distribution the model was pretrained
//! on — the offline stand-in for PG-19 / ∞Bench Sum / Multi-LexSum
//! (DESIGN.md §4). `traces` builds open-loop Poisson arrival schedules for
//! the serving example.

pub mod textgen;

use crate::util::rng::Pcg32;

/// Dataset profiles mirroring the paper's evaluation sets (Appendix F).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Profile {
    /// Book-like continuous prose (PG-19 stand-in).
    Pg19,
    /// Legal multi-doc summarization-ish (Multi-LexSum stand-in).
    LexSum,
    /// Entity-substituted narrative (∞Bench Sum stand-in).
    InfBench,
}

impl Profile {
    pub fn name(&self) -> &'static str {
        match self {
            Profile::Pg19 => "PG19",
            Profile::LexSum => "Multi-LexSum",
            Profile::InfBench => "InfBench-Sum",
        }
    }

    pub fn all() -> [Profile; 3] {
        [Profile::Pg19, Profile::LexSum, Profile::InfBench]
    }
}

/// Generate a prompt of exactly `len` byte-tokens.
pub fn prompt(seed: u64, len: usize, profile: Profile) -> Vec<i32> {
    let doc = textgen::generate_doc(seed, len, profile);
    doc.into_iter().map(|b| b as i32).collect()
}

/// Poisson arrival offsets (seconds) for `n` requests at `rate` req/s.
pub fn poisson_arrivals(seed: u64, n: usize, rate: f64) -> Vec<f64> {
    let mut rng = Pcg32::new(seed);
    let mut t = 0.0;
    (0..n)
        .map(|_| {
            t += rng.exponential(rate);
            t
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prompt_exact_length_and_ascii() {
        for profile in Profile::all() {
            let p = prompt(7, 777, profile);
            assert_eq!(p.len(), 777);
            assert!(p.iter().all(|&t| (0..256).contains(&t)), "{profile:?}");
        }
    }

    #[test]
    fn prompts_differ_by_seed_and_profile() {
        assert_ne!(prompt(1, 256, Profile::Pg19), prompt(2, 256, Profile::Pg19));
        assert_ne!(prompt(1, 256, Profile::Pg19), prompt(1, 256, Profile::LexSum));
    }

    #[test]
    fn arrivals_monotone_with_mean_near_rate() {
        let a = poisson_arrivals(3, 2000, 10.0);
        assert!(a.windows(2).all(|w| w[0] < w[1]));
        let mean_gap = a.last().unwrap() / 2000.0;
        assert!((0.08..0.12).contains(&mean_gap), "{mean_gap}");
    }
}
