//! Synthetic "book" generator — Rust mirror of python/compile/corpus.py.
//!
//! Same PCG32 stream, same template tables: serving prompts are drawn from
//! the distribution the tiny model was pretrained on, which is what makes
//! acceptance rates in the benchmarks meaningful. The long-range property
//! (a per-document entity cast reused throughout) is what the paper's
//! summarization datasets contribute: sparse draft caches that drop early
//! context lose measurable agreement with the target.

use super::Profile;
use crate::util::rng::Pcg32;

const FIRST: [&str; 16] = [
    "Aldren", "Bryn", "Cormac", "Delia", "Edmund", "Farrah", "Gideon", "Halia",
    "Ines", "Jorah", "Kestrel", "Lysandra", "Merek", "Nadia", "Orin", "Petra",
];
const LAST: [&str; 12] = [
    "Ashford", "Blackwood", "Carver", "Dunmore", "Eastgate", "Fenwick",
    "Greystone", "Hollis", "Ironwood", "Kearney", "Larkspur", "Mercer",
];
const PLACE: [&str; 8] = [
    "Avonlea", "Briarhollow", "Caldera", "Dunhaven", "Eastmarch",
    "Fallowfield", "Gildenport", "Harrowgate",
];
const VERB: [&str; 10] = [
    "argued", "claimed", "discovered", "reported", "testified", "recalled",
    "insisted", "admitted", "wrote", "observed",
];
const OBJ: [&str; 8] = [
    "the ledger", "the treaty", "the northern road", "the old archive",
    "the court record", "the shipment", "the boundary stone",
    "the witness statement",
];
const CONN: [&str; 8] = [
    "Meanwhile", "Later that year", "According to the record",
    "In the third chapter", "As the council noted", "Despite this",
    "By the following spring", "In a separate filing",
];

fn cast(rng: &mut Pcg32, n: usize) -> Vec<String> {
    (0..n)
        .map(|_| format!("{} {}", rng.choice(&FIRST), rng.choice(&LAST)))
        .collect()
}

fn sentence(rng: &mut Pcg32, cast: &[String], places: &[&str]) -> String {
    let s = rng.below(4);
    let a = rng.choice(cast).clone();
    let b = rng.choice(cast).clone();
    let pl = *rng.choice(places);
    let vb = *rng.choice(&VERB);
    let ob = *rng.choice(&OBJ);
    match s {
        0 => format!("{a} {vb} that {ob} in {pl} belonged to {b}."),
        1 => format!("{}, {a} {vb} about {ob} near {pl}.", rng.choice(&CONN)),
        2 => format!("The case of {a} versus {b} concerned {ob} at {pl}."),
        _ => format!("{a} met {b} in {pl} and {vb} over {ob}."),
    }
}

/// Generate one document of exactly `length` bytes.
pub fn generate_doc(seed: u64, length: usize, profile: Profile) -> Vec<u8> {
    let mut rng = Pcg32::new(seed);
    let n_cast = if profile == Profile::Pg19 { 6 } else { 10 };
    let cast = cast(&mut rng, n_cast);
    let places: Vec<&str> = (0..4).map(|_| *rng.choice(&PLACE)).collect();
    let mut doc = match profile {
        Profile::LexSum => format!("FILING {}: {} v. {}.\n", seed % 9973, cast[0], cast[1]),
        Profile::InfBench => {
            format!("The Chronicle of {}. Book {}.\n", places[0], 1 + seed % 12)
        }
        Profile::Pg19 => format!("{}: A History. Chapter {}.\n", places[0], 1 + seed % 20),
    };
    while doc.len() < length {
        let n_sent = 3 + rng.below(4);
        let mut para: Vec<String> = Vec::with_capacity(n_sent);
        for _ in 0..n_sent {
            para.push(sentence(&mut rng, &cast, &places));
        }
        let mut para = para.join(" ");
        if profile == Profile::LexSum && rng.below(6) == 0 {
            para = format!("EXHIBIT {}. {para}", (b'A' + rng.below(26) as u8) as char);
        }
        doc.push_str(&para);
        doc.push('\n');
    }
    doc.truncate(length);
    if matches!(profile, Profile::LexSum | Profile::InfBench) {
        let tail = format!(
            "\nSUMMARY: the dispute between {} and {} over {} in {}",
            cast[0],
            cast[1],
            rng.choice(&OBJ),
            places[0]
        );
        if tail.len() < length {
            let cut = length - tail.len();
            doc.truncate(cut);
            doc.push_str(&tail);
        }
    }
    doc.into_bytes()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        assert_eq!(
            generate_doc(42, 512, Profile::Pg19),
            generate_doc(42, 512, Profile::Pg19)
        );
    }

    #[test]
    fn entities_recur_across_document() {
        // long-range structure: at least one cast name appears in both the
        // first and last quarter of the doc.
        let doc = String::from_utf8(generate_doc(5, 4096, Profile::Pg19)).unwrap();
        let (head, tail) = (&doc[..1024], &doc[3072..]);
        let recur = FIRST
            .iter()
            .filter(|n| head.contains(*n) && tail.contains(*n))
            .count();
        assert!(recur >= 1, "no recurring entities");
    }

    #[test]
    fn profiles_have_markers() {
        let lex = String::from_utf8(generate_doc(1, 2048, Profile::LexSum)).unwrap();
        assert!(lex.starts_with("FILING"));
        assert!(lex.contains("SUMMARY:"));
        let inf = String::from_utf8(generate_doc(1, 2048, Profile::InfBench)).unwrap();
        assert!(inf.starts_with("The Chronicle"));
    }

    #[test]
    fn exact_length_all_profiles() {
        for p in Profile::all() {
            for len in [300usize, 511, 2048] {
                assert_eq!(generate_doc(9, len, p).len(), len);
            }
        }
    }
}
