//! Model backends for the speculative engine.
//!
//! `Decoder` is the contract between the L3 engine and the model: sessions
//! own the KV state; the engine owns tokens and sampling. Two backends:
//! `XlaSession` (the real artifacts, `xla_session.rs`) and `MockDecoder`
//! (a deterministic toy LM with a controllable draft-error rate) so the
//! coordinator, engine, and property tests run without artifacts.

pub mod xla_session;

use anyhow::Result;

use crate::cache::MemoryReport;
use crate::config::Method;

/// Cumulative phase timings for one session (seconds).
#[derive(Debug, Clone, Copy, Default)]
pub struct PhaseTimings {
    pub prefill: f64,
    pub draft: f64,
    pub verify: f64,
    pub flush: f64,
    /// Host<->device transfer share of the above (perf-pass metric).
    pub transfer: f64,
    pub draft_steps: u64,
    pub verify_calls: u64,
    pub flush_calls: u64,
}

/// A decoding session bound to one request's KV state.
pub trait Decoder: Send {
    fn vocab(&self) -> usize;
    fn gamma_max(&self) -> usize;
    fn method(&self) -> Method;

    /// Process the prompt, build caches; returns next-token logits.
    fn prefill(&mut self, tokens: &[i32]) -> Result<Vec<f32>>;

    /// Mark the start of a speculation cycle (records the buffer base the
    /// verify step will rewrite — the paper's O(1) rollback point).
    fn begin_cycle(&mut self);

    /// One draft-model step; appends the fed token's (draft) KV.
    fn draft_step(&mut self, token: i32) -> Result<Vec<f32>>;

    /// Target pass over `[feed, g_1..g_k]`; returns one logits row per
    /// token; rewrites those slots with target KV (Alg. 1 TARGET).
    fn verify(&mut self, tokens: &[i32]) -> Result<Vec<Vec<f32>>>;

    /// Commit `accepted` drafts (+1 for the feed token); `verify_len` =
    /// tokens passed to verify. Flushes the FP buffer when it fills.
    fn commit(&mut self, accepted: usize, verify_len: usize) -> Result<()>;

    /// One autoregressive target step (the AR baseline / fallback).
    fn ar_step(&mut self, token: i32) -> Result<Vec<f32>>;

    fn context_len(&self) -> usize;
    fn memory(&self) -> MemoryReport;
    fn timings(&self) -> PhaseTimings;
}

// ---------------------------------------------------------------------
// Mock backend
// ---------------------------------------------------------------------

/// Deterministic toy LM. The "target" distribution is a peaked function of
/// a rolling hash of the recent context; the "draft" sees the same
/// distribution except that with probability `draft_err` (hash-derived, so
/// reproducible) its argmax is swapped — emulating quantization error and
/// giving a controllable acceptance rate.
pub struct MockDecoder {
    vocab: usize,
    gamma_max: usize,
    committed: Vec<i32>,
    draft_tail: Vec<i32>,
    last_verify: Vec<i32>,
    pub draft_err: f64,
    method: Method,
}

impl MockDecoder {
    pub fn new(vocab: usize, gamma_max: usize, draft_err: f64) -> MockDecoder {
        MockDecoder {
            vocab,
            gamma_max,
            committed: Vec::new(),
            draft_tail: Vec::new(),
            last_verify: Vec::new(),
            draft_err,
            method: Method::QuantSpec,
        }
    }

    /// Override the reported method (tests drive AR vs speculative paths).
    pub fn force_method(&mut self, m: Method) {
        self.method = m;
    }

    fn ctx_hash(ctx: &[i32]) -> u64 {
        // FNV-1a over the last 8 tokens (enough context sensitivity).
        let mut h: u64 = 0xcbf29ce484222325;
        for &t in ctx.iter().rev().take(8) {
            h ^= t as u64 as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        h ^= ctx.len() as u64;
        h.wrapping_mul(0x100000001b3)
    }

    fn logits_for(&self, ctx: &[i32], draft: bool) -> Vec<f32> {
        let h = Self::ctx_hash(ctx);
        let top = (h % self.vocab as u64) as usize;
        let second = ((h >> 17) % self.vocab as u64) as usize;
        let mut logits = vec![0.0f32; self.vocab];
        for (i, l) in logits.iter_mut().enumerate() {
            // small deterministic texture so temperature sampling works
            *l = (((h >> (i % 23)) & 0xff) as f32) / 256.0;
        }
        logits[top] += 6.0;
        if second != top {
            logits[second] += 3.0;
        }
        if draft {
            // hash-coin: flip the argmax with probability draft_err
            let coin = ((h >> 33) & 0xffff) as f64 / 65536.0;
            if coin < self.draft_err {
                logits[top] -= 7.0; // demote; `second` (or texture) wins
            }
        }
        logits
    }

    fn full_ctx(&self) -> Vec<i32> {
        let mut c = self.committed.clone();
        c.extend(&self.draft_tail);
        c
    }
}

impl Decoder for MockDecoder {
    fn vocab(&self) -> usize {
        self.vocab
    }

    fn gamma_max(&self) -> usize {
        self.gamma_max
    }

    fn method(&self) -> Method {
        self.method
    }

    fn prefill(&mut self, tokens: &[i32]) -> Result<Vec<f32>> {
        self.committed = tokens.to_vec();
        self.draft_tail.clear();
        Ok(self.logits_for(&self.committed, false))
    }

    fn begin_cycle(&mut self) {
        self.draft_tail.clear();
    }

    fn draft_step(&mut self, token: i32) -> Result<Vec<f32>> {
        self.draft_tail.push(token);
        let ctx = self.full_ctx();
        Ok(self.logits_for(&ctx, true))
    }

    fn verify(&mut self, tokens: &[i32]) -> Result<Vec<Vec<f32>>> {
        self.last_verify = tokens.to_vec();
        let mut ctx = self.committed.clone();
        let mut rows = Vec::with_capacity(tokens.len());
        for &t in tokens {
            ctx.push(t);
            rows.push(self.logits_for(&ctx, false));
        }
        Ok(rows)
    }

    fn commit(&mut self, accepted: usize, verify_len: usize) -> Result<()> {
        anyhow::ensure!(accepted + 1 <= verify_len, "bad commit");
        self.committed
            .extend(self.last_verify.iter().take(accepted + 1));
        self.draft_tail.clear();
        Ok(())
    }

    fn ar_step(&mut self, token: i32) -> Result<Vec<f32>> {
        self.committed.push(token);
        Ok(self.logits_for(&self.committed, false))
    }

    fn context_len(&self) -> usize {
        self.committed.len()
    }

    fn memory(&self) -> MemoryReport {
        MemoryReport::default()
    }

    fn timings(&self) -> PhaseTimings {
        PhaseTimings::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mock_is_deterministic() {
        let mut a = MockDecoder::new(64, 7, 0.0);
        let mut b = MockDecoder::new(64, 7, 0.0);
        let prompt = vec![1, 2, 3];
        assert_eq!(a.prefill(&prompt).unwrap(), b.prefill(&prompt).unwrap());
        assert_eq!(a.draft_step(9).unwrap(), b.draft_step(9).unwrap());
    }

    #[test]
    fn zero_error_draft_matches_target() {
        let mut m = MockDecoder::new(64, 7, 0.0);
        m.prefill(&[5, 6, 7]).unwrap();
        m.begin_cycle();
        let d = m.draft_step(8).unwrap();
        let v = m.verify(&[8]).unwrap();
        let am = |v: &[f32]| {
            v.iter().enumerate().max_by(|a, b| a.1.total_cmp(b.1)).unwrap().0
        };
        assert_eq!(am(&d), am(&v[0]));
    }

    #[test]
    fn high_error_draft_diverges_sometimes() {
        let mut m = MockDecoder::new(64, 7, 0.9);
        m.prefill(&[1]).unwrap();
        let mut diverged = 0;
        for t in 0..50 {
            m.begin_cycle();
            let d = m.draft_step(t).unwrap();
            let v = m.verify(&[t]).unwrap();
            let am = |v: &[f32]| {
                v.iter().enumerate().max_by(|a, b| a.1.total_cmp(b.1)).unwrap().0
            };
            if am(&d) != am(&v[0]) {
                diverged += 1;
            }
            m.commit(0, 1).unwrap();
        }
        assert!(diverged > 20, "{diverged}");
    }
}
