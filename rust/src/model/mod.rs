//! Model backends for the speculative engine.
//!
//! `Decoder` is the contract between the L3 engine and the model: sessions
//! own the KV state; the engine owns tokens and sampling. Two backends:
//! `XlaSession` (the real artifacts, `xla_session.rs`) and `MockDecoder`
//! (a deterministic toy LM with a controllable draft-error rate) so the
//! coordinator, engine, and property tests run without artifacts.
//!
//! # Chunked prefill
//!
//! Prompt processing has two entry points. `prefill` is the one-shot path.
//! `prefill_chunk(tokens, is_last)` feeds the prompt in slices so a
//! scheduler (`coordinator::batcher::StepBatcher`) can interleave O(chunk)
//! prefill work with decode cycles instead of stalling a round for
//! O(prompt); non-final chunks return `None`, the final chunk returns the
//! next-token logits exactly as `prefill` would. The contract is strict
//! bit-parity: any chunking of the same prompt must leave the decoder in
//! the same state (logits, context, KV pages, byte accounting) as the
//! one-shot call. Backends that cannot quantize incrementally keep the
//! default implementation, which accepts only the whole prompt as a single
//! final chunk and delegates to `prefill` (callers consult
//! `supports_chunked_prefill` and fall back to one chunk).

pub mod xla_session;

use anyhow::{ensure, Context, Result};

use crate::cache::MemoryReport;
use crate::config::Method;
use crate::pool::{mock_kv, mock_kv_into, PagedKvCache, SessionId, SharedSessionManager};

/// Cumulative phase timings for one session (seconds).
#[derive(Debug, Clone, Copy, Default)]
pub struct PhaseTimings {
    pub prefill: f64,
    pub draft: f64,
    pub verify: f64,
    pub flush: f64,
    /// Host<->device transfer share of the above (perf-pass metric).
    pub transfer: f64,
    pub draft_steps: u64,
    pub verify_calls: u64,
    pub flush_calls: u64,
}

/// A decoding session bound to one request's KV state.
pub trait Decoder: Send {
    fn vocab(&self) -> usize;
    fn gamma_max(&self) -> usize;
    fn method(&self) -> Method;

    /// Process the prompt, build caches; returns next-token logits.
    fn prefill(&mut self, tokens: &[i32]) -> Result<Vec<f32>>;

    /// Whether this decoder can take its prompt in arbitrary slices via
    /// [`Decoder::prefill_chunk`]. When false, schedulers must pass the
    /// whole prompt as one final chunk (the default implementation's
    /// one-shot fallback).
    fn supports_chunked_prefill(&self) -> bool {
        false
    }

    /// Feed one prompt slice. Non-final chunks return `Ok(None)`; the
    /// final chunk (`is_last`) completes the prefill and returns the
    /// next-token logits. Chunking must be invisible in the result: state
    /// after the last chunk is bit-identical to `prefill` over the
    /// concatenated tokens. Default: one-shot fallback — only a single
    /// final chunk is accepted and delegated to [`Decoder::prefill`].
    fn prefill_chunk(&mut self, tokens: &[i32], is_last: bool) -> Result<Option<Vec<f32>>> {
        ensure!(
            is_last,
            "this decoder does not support chunked prefill; \
             pass the whole prompt as one final chunk"
        );
        self.prefill(tokens).map(Some)
    }

    /// Mark the start of a speculation cycle (records the buffer base the
    /// verify step will rewrite — the paper's O(1) rollback point).
    fn begin_cycle(&mut self);

    /// One draft-model step; appends the fed token's (draft) KV.
    fn draft_step(&mut self, token: i32) -> Result<Vec<f32>>;

    /// Target pass over `[feed, g_1..g_k]`; returns one logits row per
    /// token; rewrites those slots with target KV (Alg. 1 TARGET).
    fn verify(&mut self, tokens: &[i32]) -> Result<Vec<Vec<f32>>>;

    /// Commit `accepted` drafts (+1 for the feed token); `verify_len` =
    /// tokens passed to verify. Flushes the FP buffer when it fills.
    fn commit(&mut self, accepted: usize, verify_len: usize) -> Result<()>;

    /// One autoregressive target step (the AR baseline / fallback).
    fn ar_step(&mut self, token: i32) -> Result<Vec<f32>>;

    fn context_len(&self) -> usize;
    fn memory(&self) -> MemoryReport;
    fn timings(&self) -> PhaseTimings;

    // ---- KV read-back window (validation / introspection) ---------------

    /// Floats per committed position served by the KV read-back API
    /// (0 = this backend does not expose KV read-back; the window calls
    /// then error). The mock serves its pooled cache's d; the XLA session
    /// serves its FP verify buffer (2·L·H·head_dim: K plane then V plane).
    fn kv_read_dim(&self) -> usize {
        0
    }

    /// Read the KV vector of committed position `pos` (draft = INT4 plane,
    /// target = INT8/FP) into `out` (len = [`Decoder::kv_read_dim`]).
    /// Per-token primitive under the batched window default.
    fn read_kv_token_into(&self, pos: usize, draft: bool, out: &mut [f32]) -> Result<()> {
        let _ = (pos, draft, out);
        anyhow::bail!("this decoder does not expose KV read-back")
    }

    /// Batched read of the committed window `range` into `out`
    /// (len = `range.len() * kv_read_dim()`). The DEFAULT loops the
    /// per-token primitive — correct everywhere, one full lookup per
    /// token. Backends with a batched path override it with a one-shot
    /// window read (`PagedKvCache::read_tokens_into` on the mock: one
    /// shard lock, one group lookup per crossed group; the XLA session's
    /// FP verify buffer: one pass over the host mirrors). Overrides must
    /// be bit-identical to this default — pinned by a mock-parity test.
    fn read_kv_window_into(
        &self,
        range: std::ops::Range<usize>,
        draft: bool,
        out: &mut [f32],
    ) -> Result<()> {
        let d = self.kv_read_dim();
        ensure!(d > 0, "this decoder does not expose KV read-back");
        ensure!(
            out.len() == range.len() * d,
            "out buffer holds {} floats, window {:?} x dim {d} needs {}",
            out.len(),
            range,
            range.len() * d
        );
        for (i, pos) in range.enumerate() {
            self.read_kv_token_into(pos, draft, &mut out[i * d..(i + 1) * d])?;
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------
// Mock backend
// ---------------------------------------------------------------------

/// Mock model constants shared with the router's pool sizing.
pub const MOCK_VOCAB: usize = 64;
pub const MOCK_GAMMA_MAX: usize = 7;

/// FP-buffer capacity FB = 2G + tmax (tmax = gamma_max + 1). The single
/// source of the mock cache geometry: both `MockDecoder::with_pool` and the
/// router's admission sizing go through this, so a reservation and the
/// decoder it funds can never disagree on FB.
pub fn mock_fb(g: usize, gamma_max: usize) -> usize {
    2 * g + gamma_max + 1
}

/// Deterministic toy LM. The "target" distribution is a peaked function of
/// a rolling hash of the recent context; the "draft" sees the same
/// distribution except that with probability `draft_err` (hash-derived, so
/// reproducible) its argmax is swapped — emulating quantization error and
/// giving a controllable acceptance rate.
pub struct MockDecoder {
    vocab: usize,
    gamma_max: usize,
    committed: Vec<i32>,
    draft_tail: Vec<i32>,
    last_verify: Vec<i32>,
    pub draft_err: f64,
    method: Method,
    paged: Option<PagedState>,
    /// True between the first `prefill_chunk` and the final one: the
    /// accumulated prompt lives in `committed`, and the paged cache has
    /// absorbed every G-group that is already safe to quantize.
    mid_prefill: bool,
}

/// Pool-backed KV state of a paged mock session. The decoder writes every
/// token's (deterministic) KV vector through the block table and reads it
/// back through page handles on the draft/verify paths, validating the
/// reconstruction against the paper's error bounds — so page-table bugs
/// surface as decode errors, while logits stay identical to the unpooled
/// mock (acceptance/throughput match the seed path exactly).
///
/// The scratch buffers make the steady-state draft/verify/AR KV path
/// allocation-free: KV projection (`mock_kv_into`), cache writes, and the
/// fused per-token read-back (`read_token_into`) all reuse them. The only
/// allocation left in a draft step is the logits vector the `Decoder`
/// trait returns by value (asserted by `rust/tests/alloc_hotpath.rs`).
struct PagedState {
    cache: PagedKvCache,
    /// Pad tokens prepended in cache coordinates (bucket alignment).
    pad: usize,
    /// Draft writes issued in the current cycle.
    cycle_writes: usize,
    d: usize,
    /// Reusable d-dim buffer for KV vectors on the write path.
    kv_scratch: Vec<f32>,
    /// Reusable d-dim buffers for read-back validation.
    want_scratch: Vec<f32>,
    read_scratch: Vec<f32>,
    /// Reusable (γ_max+1)·d buffers for the batched verify window: the
    /// target rewrite is ONE `write_cycle_slots` call and the read-back is
    /// ONE `read_tokens_into` call (one pool lock each) instead of one
    /// lock per token.
    win_scratch: Vec<f32>,
    win_read: Vec<f32>,
}

impl PagedState {
    /// Token at cache position `p` (left-padded with newline, like
    /// `router::pad_prompt`).
    fn token_at(&self, committed: &[i32], p: usize) -> i32 {
        if p < self.pad {
            0x0A
        } else {
            committed.get(p - self.pad).copied().unwrap_or(0x0A)
        }
    }

    /// Read position 0 back through the quantized page (draft or target
    /// plane) and check it against the generator within the plane's bound.
    /// Runs entirely on scratch buffers: no heap allocation.
    fn validate_read(&mut self, committed: &[i32], draft: bool) -> Result<()> {
        let tok = self.token_at(committed, 0);
        mock_kv_into(0, tok, &mut self.want_scratch);
        self.cache.read_token_into(0, draft, &mut self.read_scratch)?;
        let bound = self.cache.group_error_bound(0, draft)?;
        for (w, g) in self.want_scratch.iter().zip(&self.read_scratch) {
            ensure!(
                (w - g).abs() <= bound * 1.01 + 1e-6,
                "paged KV read-back out of bounds: {w} vs {g} (bound {bound})"
            );
        }
        Ok(())
    }

    /// Read the first `w` committed positions back through the INT8 plane
    /// in ONE batched `read_tokens_into` call (one lock, one group lookup)
    /// and check every token against the generator within the plane's
    /// bound — the verify-path counterpart of the per-token
    /// [`PagedState::validate_read`]. `w` must stay inside group 0 (the
    /// caller clamps to G). Runs entirely on scratch buffers.
    fn validate_window(&mut self, committed: &[i32], w: usize) -> Result<()> {
        let d = self.d;
        let bound = self.cache.group_error_bound(0, false)?;
        self.cache.read_tokens_into(0..w, false, &mut self.win_read[..w * d])?;
        for p in 0..w {
            let tok = self.token_at(committed, p);
            mock_kv_into(p, tok, &mut self.want_scratch);
            for (want, got) in
                self.want_scratch.iter().zip(&self.win_read[p * d..(p + 1) * d])
            {
                ensure!(
                    (want - got).abs() <= bound * 1.01 + 1e-6,
                    "batched KV read-back out of bounds at {p}: {want} vs {got} \
                     (bound {bound})"
                );
            }
        }
        Ok(())
    }
}

impl MockDecoder {
    pub fn new(vocab: usize, gamma_max: usize, draft_err: f64) -> MockDecoder {
        MockDecoder {
            vocab,
            gamma_max,
            committed: Vec::new(),
            // pre-sized so steady-state draft pushes never reallocate
            draft_tail: Vec::with_capacity(gamma_max + 1),
            last_verify: Vec::new(),
            draft_err,
            method: Method::QuantSpec,
            paged: None,
            mid_prefill: false,
        }
    }

    /// A mock decoder whose KV cache lives in the shared paged pool. The
    /// session must already be admitted; `cap_tokens` is the reserved
    /// quantized-region capacity (reservation quant pages × G).
    pub fn with_pool(
        vocab: usize,
        gamma_max: usize,
        draft_err: f64,
        mgr: SharedSessionManager,
        session: SessionId,
        cap_tokens: usize,
    ) -> Result<MockDecoder> {
        let (g, d) = {
            let m = mgr.lock().unwrap_or_else(|p| p.into_inner());
            (m.pool().cfg().page_tokens, m.pool().cfg().kv_dim)
        };
        let fb = mock_fb(g, gamma_max);
        let cache = PagedKvCache::new(mgr, session, g, d, fb, cap_tokens)?;
        let mut dec = MockDecoder::new(vocab, gamma_max, draft_err);
        dec.paged = Some(PagedState {
            cache,
            pad: 0,
            cycle_writes: 0,
            d,
            kv_scratch: vec![0.0; d],
            want_scratch: vec![0.0; d],
            read_scratch: vec![0.0; d],
            win_scratch: vec![0.0; (gamma_max + 1) * d],
            win_read: vec![0.0; (gamma_max + 1) * d],
        });
        Ok(dec)
    }

    /// Pages currently held by this decoder's session (0 when unpooled).
    pub fn pages(&self) -> usize {
        self.paged.as_ref().map(|p| p.cache.pages()).unwrap_or(0)
    }

    /// Override the reported method (tests drive AR vs speculative paths).
    pub fn force_method(&mut self, m: Method) {
        self.method = m;
    }

    /// FNV-1a over the last 8 tokens of the logical context `head ++ tail`
    /// (enough context sensitivity). Taking the context in two parts lets
    /// the draft/verify paths hash `committed ++ draft_tail` without
    /// materializing the concatenation — no per-step clone.
    fn ctx_hash_parts(head: &[i32], tail: &[i32]) -> u64 {
        let mut h: u64 = 0xcbf29ce484222325;
        for &t in tail.iter().rev().chain(head.iter().rev()).take(8) {
            h ^= t as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        h ^= (head.len() + tail.len()) as u64;
        h.wrapping_mul(0x100000001b3)
    }

    /// Logits for the context `head ++ tail`. The returned vector is the
    /// only heap allocation on the steady-state draft path (the `Decoder`
    /// trait returns logits by value).
    fn logits_for_parts(&self, head: &[i32], tail: &[i32], draft: bool) -> Vec<f32> {
        let h = Self::ctx_hash_parts(head, tail);
        let top = (h % self.vocab as u64) as usize;
        let second = ((h >> 17) % self.vocab as u64) as usize;
        let mut logits = vec![0.0f32; self.vocab];
        for (i, l) in logits.iter_mut().enumerate() {
            // small deterministic texture so temperature sampling works
            *l = (((h >> (i % 23)) & 0xff) as f32) / 256.0;
        }
        logits[top] += 6.0;
        if second != top {
            logits[second] += 3.0;
        }
        if draft {
            // hash-coin: flip the argmax with probability draft_err
            let coin = ((h >> 33) & 0xffff) as f64 / 65536.0;
            if coin < self.draft_err {
                logits[top] -= 7.0; // demote; `second` (or texture) wins
            }
        }
        logits
    }

    fn logits_for(&self, ctx: &[i32], draft: bool) -> Vec<f32> {
        self.logits_for_parts(ctx, &[], draft)
    }
}

impl Decoder for MockDecoder {
    fn vocab(&self) -> usize {
        self.vocab
    }

    fn gamma_max(&self) -> usize {
        self.gamma_max
    }

    fn method(&self) -> Method {
        self.method
    }

    fn prefill(&mut self, tokens: &[i32]) -> Result<Vec<f32>> {
        // One-shot = one final chunk; `prefill_chunk` holds the single
        // implementation so the two paths cannot drift.
        self.mid_prefill = false;
        let logits = self.prefill_chunk(tokens, true)?;
        Ok(logits.expect("final prefill chunk returns logits"))
    }

    fn supports_chunked_prefill(&self) -> bool {
        true
    }

    fn prefill_chunk(&mut self, tokens: &[i32], is_last: bool) -> Result<Option<Vec<f32>>> {
        if !self.mid_prefill {
            if let Some(p) = &self.paged {
                // Starting a NEW prefill while un-finalized quant groups
                // exist would resume group-writing after them and serve
                // the abandoned prompt's KV — reject instead. (A finished
                // pooled prefill is rejected downstream by
                // `prefill_finish`, as before.)
                ensure!(
                    p.cache.tracker().is_ok() || p.cache.table().groups.is_empty(),
                    "abandoned partial chunked prefill: pooled KV groups hold \
                     stale data; release the session instead of re-prefilling"
                );
            }
            self.committed.clear();
            self.draft_tail.clear();
            self.mid_prefill = true;
        }
        self.committed.extend_from_slice(tokens);
        let n = self.committed.len();
        if let Some(p) = &mut self.paged {
            let committed = &self.committed;
            let d = p.d;
            if !is_last {
                // Quantize every G-group that is already safe. Groups only
                // become safe once n ≥ 2G, which also pins the final left
                // pad to 0 (padding only happens for prompts under 2G), so
                // cache positions are prompt positions here.
                p.cache.prefill_extend(n, &|pos| {
                    mock_kv(pos, committed.get(pos).copied().unwrap_or(0x0A), d)
                })?;
            } else {
                // Left-pad short prompts (with newline, like
                // `router::pad_prompt`) up to the 2G prefill minimum;
                // logits below still see the unpadded context, so outputs
                // are unchanged.
                let total = n.max(2 * p.cache.page_tokens());
                p.pad = total - n;
                let pad = p.pad;
                p.cache.prefill_finish(total, &|pos| {
                    let tok = if pos < pad {
                        0x0A
                    } else {
                        committed.get(pos - pad).copied().unwrap_or(0x0A)
                    };
                    mock_kv(pos, tok, d)
                })?;
            }
        }
        if !is_last {
            return Ok(None);
        }
        self.mid_prefill = false;
        Ok(Some(self.logits_for(&self.committed, false)))
    }

    fn begin_cycle(&mut self) {
        self.draft_tail.clear();
        if let Some(p) = &mut self.paged {
            let _ = p.cache.begin_cycle();
            p.cycle_writes = 0;
        }
    }

    fn draft_step(&mut self, token: i32) -> Result<Vec<f32>> {
        if let Some(p) = &mut self.paged {
            let i = p.cycle_writes;
            let tr = p.cache.tracker()?;
            let pos = tr.n_q + tr.draft_slot(i)?;
            mock_kv_into(pos, token, &mut p.kv_scratch);
            p.cache.write_cycle_slot(i, &p.kv_scratch)?;
            p.cycle_writes += 1;
            // Draft path reads the INT4 plane through the block table
            // (fused per-token read into the session's scratch buffer).
            p.validate_read(&self.committed, true)?;
        }
        self.draft_tail.push(token);
        Ok(self.logits_for_parts(&self.committed, &self.draft_tail, true))
    }

    fn verify(&mut self, tokens: &[i32]) -> Result<Vec<Vec<f32>>> {
        if let Some(p) = &mut self.paged {
            if !tokens.is_empty() {
                let t = tokens.len();
                let d = p.d;
                ensure!(
                    t * d <= p.win_scratch.len(),
                    "verify window of {t} tokens exceeds gamma_max capacity"
                );
                // Target pass rewrites the whole drafted window in place
                // (Alg. 1) with ONE batched write — one pool lock for the
                // γ-window instead of one per token.
                let base_pos = {
                    let tr = p.cache.tracker()?;
                    tr.n_q + tr.draft_slot(0)?
                };
                for (i, &tok) in tokens.iter().enumerate() {
                    mock_kv_into(base_pos + i, tok, &mut p.win_scratch[i * d..(i + 1) * d]);
                }
                p.cache.write_cycle_slots(0, &p.win_scratch[..t * d])?;
                // Read the drafted (uncommitted) window back in ONE
                // batched read; it lives in the FP buffer, so the
                // read-back must be bit-exact.
                p.cache.read_cycle_slots_into(0, &mut p.win_read[..t * d])?;
                ensure!(
                    p.win_read[..t * d] == p.win_scratch[..t * d],
                    "verify window read-back mismatch"
                );
                // Committed-window spot check through the batched
                // `read_tokens_into` path: verify reads the INT8 plane,
                // one lock + one group lookup for the whole window.
                let w = t.min(p.cache.page_tokens());
                p.validate_window(&self.committed, w)?;
            }
        }
        self.last_verify = tokens.to_vec();
        let mut rows = Vec::with_capacity(tokens.len());
        for i in 0..tokens.len() {
            rows.push(self.logits_for_parts(&self.committed, &tokens[..=i], false));
        }
        Ok(rows)
    }

    fn commit(&mut self, accepted: usize, verify_len: usize) -> Result<()> {
        anyhow::ensure!(accepted + 1 <= verify_len, "bad commit");
        if let Some(p) = &mut self.paged {
            p.cache.commit_cycle(accepted, verify_len)?;
        }
        self.committed
            .extend(self.last_verify.iter().take(accepted + 1));
        self.draft_tail.clear();
        Ok(())
    }

    fn ar_step(&mut self, token: i32) -> Result<Vec<f32>> {
        self.committed.push(token);
        if let Some(p) = &mut self.paged {
            let pos = p.pad + self.committed.len() - 1;
            mock_kv_into(pos, token, &mut p.kv_scratch);
            p.cache.commit_ar(&p.kv_scratch)?;
        }
        Ok(self.logits_for(&self.committed, false))
    }

    fn context_len(&self) -> usize {
        self.committed.len()
    }

    fn kv_read_dim(&self) -> usize {
        self.paged.as_ref().map(|p| p.d).unwrap_or(0)
    }

    fn read_kv_token_into(&self, pos: usize, draft: bool, out: &mut [f32]) -> Result<()> {
        let p = self.paged.as_ref().context("unpooled mock has no KV pages")?;
        // `pos` is a COMMITTED position (the trait contract); the cache
        // left-pads short prompts, so shift by the pad and bound against
        // the committed context — a pad token must never be served as
        // committed KV.
        ensure!(
            pos < self.committed.len(),
            "position {pos} beyond committed context {}",
            self.committed.len()
        );
        p.cache.read_token_into(p.pad + pos, draft, out)
    }

    /// Batched override: ONE `read_tokens_into` window (one shard lock,
    /// one group lookup per crossed group) instead of a per-token loop.
    /// Same pad shift / committed bound as the per-token primitive.
    fn read_kv_window_into(
        &self,
        range: std::ops::Range<usize>,
        draft: bool,
        out: &mut [f32],
    ) -> Result<()> {
        let p = self.paged.as_ref().context("unpooled mock has no KV pages")?;
        ensure!(
            range.end <= self.committed.len(),
            "window {range:?} beyond committed context {}",
            self.committed.len()
        );
        p.cache
            .read_tokens_into(p.pad + range.start..p.pad + range.end, draft, out)
    }

    fn memory(&self) -> MemoryReport {
        match &self.paged {
            None => MemoryReport::default(),
            Some(p) => {
                let (logical, host) = p.cache.session_bytes();
                MemoryReport {
                    weights_logical: 0,
                    weights_host: 0,
                    cache_logical: logical,
                    cache_host: host,
                }
            }
        }
    }

    fn timings(&self) -> PhaseTimings {
        PhaseTimings::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mock_is_deterministic() {
        let mut a = MockDecoder::new(64, 7, 0.0);
        let mut b = MockDecoder::new(64, 7, 0.0);
        let prompt = vec![1, 2, 3];
        assert_eq!(a.prefill(&prompt).unwrap(), b.prefill(&prompt).unwrap());
        assert_eq!(a.draft_step(9).unwrap(), b.draft_step(9).unwrap());
    }

    #[test]
    fn zero_error_draft_matches_target() {
        let mut m = MockDecoder::new(64, 7, 0.0);
        m.prefill(&[5, 6, 7]).unwrap();
        m.begin_cycle();
        let d = m.draft_step(8).unwrap();
        let v = m.verify(&[8]).unwrap();
        let am = |v: &[f32]| {
            v.iter().enumerate().max_by(|a, b| a.1.total_cmp(b.1)).unwrap().0
        };
        assert_eq!(am(&d), am(&v[0]));
    }

    #[test]
    fn paged_mock_matches_unpooled_and_frees() {
        use crate::pool::{shared, PoolConfig};
        use crate::spec::{Sampler, SpecEngine};
        let mgr = shared(PoolConfig {
            pages: 64,
            page_tokens: 8,
            kv_dim: 2,
            high_watermark: 1.0,
            low_watermark: 1.0,
            ..PoolConfig::default()
        })
        .unwrap();
        let prompt = [1, 2, 3, 4, 5, 6];
        let fb = 2 * 8 + 8; // 2G + (gamma_max + 1)
        let pages =
            crate::costmodel::memory::pool_pages_for_request(prompt.len(), 40, 8, fb);
        let cap_tokens = (pages - fb.div_ceil(8)) * 8;
        {
            let mut m = mgr.lock().unwrap();
            assert_eq!(
                m.admit(1, pages, false).unwrap(),
                crate::pool::AdmitOutcome::Admitted
            );
        }
        let mut paged =
            MockDecoder::with_pool(64, 7, 0.2, mgr.clone(), 1, cap_tokens).unwrap();
        let out_paged = SpecEngine::new(4, Sampler::new(0.0, 7))
            .generate(&mut paged, &prompt, 40)
            .unwrap();
        assert!(paged.pages() > 0);
        assert!(paged.memory().cache_host > paged.memory().cache_logical);

        let mut plain = MockDecoder::new(64, 7, 0.2);
        let out_plain = SpecEngine::new(4, Sampler::new(0.0, 7))
            .generate(&mut plain, &prompt, 40)
            .unwrap();
        assert_eq!(out_paged.tokens, out_plain.tokens, "pooling must not change outputs");
        assert_eq!(out_paged.accepted, out_plain.accepted);

        drop(paged);
        let mut m = mgr.lock().unwrap();
        m.release(1);
        assert_eq!(m.pool().pages_in_use(), 0, "session release reclaims all pages");
    }

    #[test]
    fn paged_mock_ar_path() {
        use crate::pool::{shared, PoolConfig};
        use crate::spec::{Sampler, SpecEngine};
        let mgr = shared(PoolConfig {
            pages: 64,
            page_tokens: 8,
            kv_dim: 2,
            high_watermark: 1.0,
            low_watermark: 1.0,
            ..PoolConfig::default()
        })
        .unwrap();
        mgr.lock().unwrap().admit(9, 12, false).unwrap();
        let mut dec = MockDecoder::with_pool(64, 7, 0.0, mgr.clone(), 9, 72).unwrap();
        dec.force_method(Method::Autoregressive);
        let mut plain = MockDecoder::new(64, 7, 0.0);
        plain.force_method(Method::Autoregressive);
        let eng = |d: &mut MockDecoder| {
            SpecEngine::new(1, Sampler::new(0.0, 3))
                .generate(d, &[7, 8, 9], 30)
                .unwrap()
                .tokens
        };
        assert_eq!(eng(&mut dec), eng(&mut plain));
        mgr.lock().unwrap().release(9);
    }

    /// Acceptance criterion for the packed representation: on a pooled
    /// mock session with the default geometry (G=64, d=8), the quantized
    /// region's host bytes are at most 0.55x the pre-packing value
    /// (byte-per-nibble), and `MemoryReport::cache_host` is exactly the
    /// packed page formula.
    #[test]
    fn packed_quant_region_host_bytes_halved() {
        use crate::pool::{shared, PoolConfig};
        let cfg = PoolConfig {
            pages: 16,
            high_watermark: 1.0,
            low_watermark: 1.0,
            ..PoolConfig::default()
        };
        let (g, d) = (cfg.page_tokens, cfg.kv_dim);
        let elems = g * d;
        let quant_host = cfg.quant_page_host_bytes();
        let fp_host = cfg.fp_page_host_bytes();
        let mgr = shared(cfg).unwrap();
        let fb = mock_fb(g, MOCK_GAMMA_MAX);
        let fp_pages = fb.div_ceil(g);
        mgr.lock().unwrap().admit(1, 16, false).unwrap();
        let mut dec =
            MockDecoder::with_pool(64, MOCK_GAMMA_MAX, 0.0, mgr.clone(), 1, 4 * g).unwrap();
        let prompt: Vec<i32> = (0..40).collect();
        dec.prefill(&prompt).unwrap();
        // 40 tokens pad to the 2G bucket: exactly 1 quant group + full C_F1
        let quant_pages = dec.pages() - fp_pages;
        assert_eq!(quant_pages, 1);
        let mem = dec.memory();
        assert_eq!(mem.cache_host, quant_pages * quant_host + fp_pages * fp_host);
        let unpacked = crate::costmodel::memory::unpacked_group_host_bytes(elems);
        assert!(
            (quant_host as f64) <= 0.55 * unpacked as f64,
            "packed quant page {quant_host} B vs pre-PR {unpacked} B"
        );
        // host now tracks logical for the quant region to within the
        // f32-vs-fp16 scale/zero overhead
        assert_eq!(quant_host, elems + 8);
        mgr.lock().unwrap().release(1);
    }

    /// Tentpole acceptance: chunked prefill is bit-identical to monolithic
    /// prefill — final logits, KV page counts, logical/host byte
    /// accounting, and every subsequent draft/verify logit row — across
    /// prompt lengths sweeping group boundaries (±1 around multiples of
    /// G=8) and chunk sizes sweeping chunk boundaries, on pooled sessions.
    #[test]
    fn prop_chunked_prefill_parity_with_monolithic() {
        use crate::costmodel::memory::pool_pages_for_request;
        use crate::pool::{shared, PoolConfig};
        let g = 8;
        let fb = mock_fb(g, MOCK_GAMMA_MAX);
        for len in [3usize, 8, 15, 16, 17, 24, 31, 32, 33, 40, 53] {
            for chunk in [1usize, 5, g - 1, g, g + 1, 2 * g + 3, len] {
                let mgr = shared(PoolConfig {
                    pages: 128,
                    page_tokens: g,
                    kv_dim: 2,
                    high_watermark: 1.0,
                    low_watermark: 1.0,
                    ..PoolConfig::default()
                })
                .unwrap();
                let prompt: Vec<i32> = (0..len as i32).map(|t| (t * 7 + 3) % 64).collect();
                let pages = pool_pages_for_request(len, 30, g, fb);
                let cap = (pages - fb.div_ceil(g)) * g;
                let mut decs = Vec::new();
                for sid in [1u64, 2] {
                    mgr.lock().unwrap().admit(sid, pages, false).unwrap();
                    decs.push(
                        MockDecoder::with_pool(64, MOCK_GAMMA_MAX, 0.2, mgr.clone(), sid, cap)
                            .unwrap(),
                    );
                }
                let mut chunked = decs.pop().unwrap();
                let mut mono = decs.pop().unwrap();
                let want = mono.prefill(&prompt).unwrap();
                let n_chunks = len.div_ceil(chunk).max(1);
                let mut got = None;
                for (i, slice) in prompt.chunks(chunk).enumerate() {
                    let out = chunked.prefill_chunk(slice, i + 1 == n_chunks).unwrap();
                    assert_eq!(out.is_some(), i + 1 == n_chunks, "len {len} chunk {chunk}");
                    got = out.or(got);
                }
                assert_eq!(got.as_deref(), Some(&want[..]), "len {len} chunk {chunk}");
                assert_eq!(mono.pages(), chunked.pages(), "len {len} chunk {chunk}");
                let (ma, mb) = (mono.memory(), chunked.memory());
                assert_eq!(ma.cache_logical, mb.cache_logical, "len {len} chunk {chunk}");
                assert_eq!(ma.cache_host, mb.cache_host, "len {len} chunk {chunk}");
                // the decode state machine continues identically
                for cycle in 0..4 {
                    mono.begin_cycle();
                    chunked.begin_cycle();
                    let t = 1 + cycle % 3;
                    for i in 0..t {
                        let tok = (cycle * 11 + i * 5) as i32 % 64;
                        assert_eq!(
                            mono.draft_step(tok).unwrap(),
                            chunked.draft_step(tok).unwrap(),
                            "len {len} chunk {chunk} cycle {cycle}"
                        );
                    }
                    let vtokens: Vec<i32> =
                        (0..=t).map(|i| (cycle * 13 + i * 3) as i32 % 64).collect();
                    assert_eq!(
                        mono.verify(&vtokens).unwrap(),
                        chunked.verify(&vtokens).unwrap(),
                        "len {len} chunk {chunk} cycle {cycle}"
                    );
                    mono.commit(t - 1, t + 1).unwrap();
                    chunked.commit(t - 1, t + 1).unwrap();
                }
                assert_eq!(mono.pages(), chunked.pages());
                for sid in [1u64, 2] {
                    mgr.lock().unwrap().release(sid);
                }
            }
        }
    }

    /// An abandoned partial chunked prefill on a POOLED session must not
    /// be silently restarted: quant groups already flushed hold the old
    /// prompt's KV, so a fresh prefill is rejected with a clear error
    /// (release the session instead). Unpooled decoders restart freely.
    #[test]
    fn abandoned_partial_chunked_prefill_is_rejected() {
        use crate::pool::{shared, PoolConfig};
        let g = 8;
        let mgr = shared(PoolConfig {
            pages: 32,
            page_tokens: g,
            kv_dim: 2,
            high_watermark: 1.0,
            low_watermark: 1.0,
            ..PoolConfig::default()
        })
        .unwrap();
        mgr.lock().unwrap().admit(1, 16, false).unwrap();
        let mut dec =
            MockDecoder::with_pool(64, MOCK_GAMMA_MAX, 0.0, mgr.clone(), 1, 8 * g).unwrap();
        let prompt_a: Vec<i32> = (0..2 * g as i32).collect();
        // first chunk quantizes group 0 of prompt A, then is abandoned
        assert!(dec.prefill_chunk(&prompt_a, false).unwrap().is_none());
        let err = dec.prefill(&[9, 9, 9]).unwrap_err().to_string();
        assert!(err.contains("stale"), "got: {err}");

        // unpooled: restarting mid-prefill is fine (state fully in memory)
        let mut plain = MockDecoder::new(64, 7, 0.0);
        assert!(plain.prefill_chunk(&prompt_a, false).unwrap().is_none());
        let logits = plain.prefill(&[9, 9, 9]).unwrap();
        let mut fresh = MockDecoder::new(64, 7, 0.0);
        assert_eq!(logits, fresh.prefill(&[9, 9, 9]).unwrap());
        mgr.lock().unwrap().release(1);
    }

    /// The default-trait fallback: a decoder without chunk support still
    /// serves the whole prompt as one final chunk, and rejects partial
    /// chunks instead of corrupting state.
    #[test]
    fn default_prefill_chunk_is_one_shot_fallback() {
        struct OneShot(MockDecoder);
        impl Decoder for OneShot {
            fn vocab(&self) -> usize {
                self.0.vocab()
            }
            fn gamma_max(&self) -> usize {
                self.0.gamma_max()
            }
            fn method(&self) -> Method {
                self.0.method()
            }
            fn prefill(&mut self, t: &[i32]) -> Result<Vec<f32>> {
                self.0.prefill(t)
            }
            fn begin_cycle(&mut self) {
                self.0.begin_cycle()
            }
            fn draft_step(&mut self, t: i32) -> Result<Vec<f32>> {
                self.0.draft_step(t)
            }
            fn verify(&mut self, t: &[i32]) -> Result<Vec<Vec<f32>>> {
                self.0.verify(t)
            }
            fn commit(&mut self, a: usize, v: usize) -> Result<()> {
                self.0.commit(a, v)
            }
            fn ar_step(&mut self, t: i32) -> Result<Vec<f32>> {
                self.0.ar_step(t)
            }
            fn context_len(&self) -> usize {
                self.0.context_len()
            }
            fn memory(&self) -> MemoryReport {
                self.0.memory()
            }
            fn timings(&self) -> PhaseTimings {
                self.0.timings()
            }
        }
        let mut d = OneShot(MockDecoder::new(64, 7, 0.0));
        assert!(!d.supports_chunked_prefill());
        assert!(d.prefill_chunk(&[1, 2], false).is_err(), "partial chunk rejected");
        let via_chunk = d.prefill_chunk(&[1, 2, 3], true).unwrap().unwrap();
        let mut plain = MockDecoder::new(64, 7, 0.0);
        assert_eq!(via_chunk, plain.prefill(&[1, 2, 3]).unwrap());
    }

    /// Satellite acceptance (batched KV window API): a wrapper that keeps
    /// the TRAIT-DEFAULT `read_kv_window_into` (per-token loop) but
    /// delegates the per-token primitive must return bit-for-bit what the
    /// mock's batched override returns, over every window shape — quant
    /// region (both planes), group boundaries, the quant→FP seam, and the
    /// FP tail. This pins the contract the XLA device-path override obeys.
    #[test]
    fn kv_window_trait_default_matches_batched_override() {
        use crate::pool::{shared, PoolConfig};
        /// Delegates everything EXCEPT `read_kv_window_into`, which stays
        /// the trait default (per-token loop over the delegated primitive).
        struct PerTokenOnly(MockDecoder);
        impl Decoder for PerTokenOnly {
            fn vocab(&self) -> usize {
                self.0.vocab()
            }
            fn gamma_max(&self) -> usize {
                self.0.gamma_max()
            }
            fn method(&self) -> Method {
                self.0.method()
            }
            fn prefill(&mut self, t: &[i32]) -> Result<Vec<f32>> {
                self.0.prefill(t)
            }
            fn begin_cycle(&mut self) {
                self.0.begin_cycle()
            }
            fn draft_step(&mut self, t: i32) -> Result<Vec<f32>> {
                self.0.draft_step(t)
            }
            fn verify(&mut self, t: &[i32]) -> Result<Vec<Vec<f32>>> {
                self.0.verify(t)
            }
            fn commit(&mut self, a: usize, v: usize) -> Result<()> {
                self.0.commit(a, v)
            }
            fn ar_step(&mut self, t: i32) -> Result<Vec<f32>> {
                self.0.ar_step(t)
            }
            fn context_len(&self) -> usize {
                self.0.context_len()
            }
            fn memory(&self) -> MemoryReport {
                self.0.memory()
            }
            fn timings(&self) -> PhaseTimings {
                self.0.timings()
            }
            fn kv_read_dim(&self) -> usize {
                self.0.kv_read_dim()
            }
            fn read_kv_token_into(&self, p: usize, d: bool, o: &mut [f32]) -> Result<()> {
                self.0.read_kv_token_into(p, d, o)
            }
            // read_kv_window_into: trait default (per-token loop)
        }
        let g = 8;
        let mgr = shared(PoolConfig {
            pages: 64,
            page_tokens: g,
            kv_dim: 2,
            high_watermark: 1.0,
            low_watermark: 1.0,
            ..PoolConfig::default()
        })
        .unwrap();
        mgr.lock().unwrap().admit(1, 16, false).unwrap();
        let mut dec =
            MockDecoder::with_pool(64, MOCK_GAMMA_MAX, 0.1, mgr.clone(), 1, 8 * g).unwrap();
        let prompt: Vec<i32> = (0..4 * g as i32).map(|t| (t * 5 + 1) % 64).collect();
        dec.prefill(&prompt).unwrap();
        let d = dec.kv_read_dim();
        assert_eq!(d, 2);
        let ctx = 4 * g; // n_q + n_f after a 4G prefill
        let via_default = PerTokenOnly(dec);
        let mut batched = vec![0.0f32; ctx * d];
        let mut looped = vec![0.0f32; ctx * d];
        for start in [0usize, 1, g - 1, g, 3 * g - 1, 3 * g, ctx - 1] {
            for len in [1usize, 2, g, ctx - start] {
                if start + len > ctx {
                    continue;
                }
                for draft in [true, false] {
                    // inner mock: batched override
                    via_default
                        .0
                        .read_kv_window_into(start..start + len, draft, &mut batched[..len * d])
                        .unwrap();
                    // wrapper: trait default looping the per-token primitive
                    via_default
                        .read_kv_window_into(start..start + len, draft, &mut looped[..len * d])
                        .unwrap();
                    assert_eq!(
                        batched[..len * d],
                        looped[..len * d],
                        "start {start} len {len} draft {draft}"
                    );
                }
            }
        }
        // wrong-size scratch and past-context windows reject on both paths
        assert!(via_default.read_kv_window_into(0..2, true, &mut looped[..d]).is_err());
        assert!(via_default
            .0
            .read_kv_window_into(ctx - 1..ctx + 1, false, &mut batched[..2 * d])
            .is_err());
        // an unpooled mock exposes no KV read-back
        let plain = MockDecoder::new(64, 7, 0.0);
        assert_eq!(plain.kv_read_dim(), 0);
        assert!(plain.read_kv_token_into(0, true, &mut [0.0; 2]).is_err());
        mgr.lock().unwrap().release(1);

        // Padded short prompt (regression): prompts under 2G left-pad the
        // cache, and positions are COMMITTED coordinates — position 0 must
        // read the first prompt token's KV (cache slot `pad`), never a
        // 0x0A pad token, and reads past the committed context must error
        // even though padded cache slots exist there.
        mgr.lock().unwrap().admit(2, 16, false).unwrap();
        let mut short =
            MockDecoder::with_pool(64, MOCK_GAMMA_MAX, 0.1, mgr.clone(), 2, 8 * g).unwrap();
        let prompt = [9, 5, 7, 3, 11];
        short.prefill(&prompt).unwrap();
        let pad = 2 * g - prompt.len(); // cache padded to the 2G minimum
        let mut got = vec![0.0f32; d];
        for (i, &tok) in prompt.iter().enumerate() {
            // committed positions land in the FP region here: exact values
            short.read_kv_token_into(i, false, &mut got).unwrap();
            assert_eq!(got, crate::pool::mock_kv(pad + i, tok, d), "pos {i}");
        }
        let mut win = vec![0.0f32; prompt.len() * d];
        short.read_kv_window_into(0..prompt.len(), false, &mut win).unwrap();
        for i in 0..prompt.len() {
            short.read_kv_token_into(i, false, &mut got).unwrap();
            assert_eq!(win[i * d..(i + 1) * d], got[..], "window pos {i}");
        }
        assert!(
            short.read_kv_token_into(prompt.len(), false, &mut got).is_err(),
            "pad region must not be readable as committed KV"
        );
        mgr.lock().unwrap().release(2);
    }

    #[test]
    fn high_error_draft_diverges_sometimes() {
        let mut m = MockDecoder::new(64, 7, 0.9);
        m.prefill(&[1]).unwrap();
        let mut diverged = 0;
        for t in 0..50 {
            m.begin_cycle();
            let d = m.draft_step(t).unwrap();
            let v = m.verify(&[t]).unwrap();
            let am = |v: &[f32]| {
                v.iter().enumerate().max_by(|a, b| a.1.total_cmp(b.1)).unwrap().0
            };
            if am(&d) != am(&v[0]) {
                diverged += 1;
            }
            m.commit(0, 1).unwrap();
        }
        assert!(diverged > 20, "{diverged}");
    }
}
