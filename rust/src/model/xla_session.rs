//! The real model backend: one decoding session over the AOT artifacts.
//!
//! Owns the paper's cache state for one request:
//! * QuantSpec: 8 hierarchical-cache device tensors (upper/lower nibbles +
//!   INT8 scales/zeros for K and V) + the double FP buffer;
//! * AR / weight-only ablation: a dense FP region;
//! * sparse baselines: dense FP region (target side) + a budget-size
//!   gathered draft region (StreamingLLM sinks+window / SnapKV selection).
//!
//! All state mutation happens by calling the lowered entries and swapping
//! the returned tensors in; rollback is counter math (see cache::CacheTracker).

use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::{Decoder, PhaseTimings};
use crate::cache::{CacheTracker, MemoryReport};
use crate::config::{Method, QuantMode};
use crate::runtime::{Arg, DeviceTensor, HostTensor, Runtime, Weights};

/// Attention-sink prefix kept by the StreamingLLM draft (tokens). One
/// quantization block: the paper's baselines use 4 sink tokens + window;
/// block granularity is what our flush entry supports.
const SINK_TOKENS: usize = 64;

pub struct XlaSession {
    rt: Arc<Runtime>,
    method: Method,
    quant_mode: QuantMode,
    w_target: Arc<Weights>,
    w_draft: Arc<Weights>,
    bucket: usize,
    tracker: CacheTracker,
    /// ku, kl, ks, kz, vu, vl, vs, vz (QuantSpec Both / KvOnly).
    qcache: Option<Vec<DeviceTensor>>,
    /// Dense FP region (AR, sparse-baseline target, weight-only ablation).
    dense: Option<(DeviceTensor, DeviceTensor)>,
    /// Sparse draft region + fill + protected prefix.
    sparse: Option<SparseDraft>,
    fk: HostTensor,
    fv: HostTensor,
    timings: PhaseTimings,
}

struct SparseDraft {
    kr: DeviceTensor,
    vr: DeviceTensor,
    n_s: usize,
    protected: usize,
    budget: usize,
}

impl XlaSession {
    /// `bucket` must be one of the manifest buckets; the prompt passed to
    /// `prefill` must be exactly `bucket` tokens (the router pads).
    pub fn new(
        rt: Arc<Runtime>,
        method: Method,
        quant_mode: QuantMode,
        bucket: usize,
        w_target: Arc<Weights>,
        w_draft: Arc<Weights>,
    ) -> Result<XlaSession> {
        let m = &rt.manifest.model;
        anyhow::ensure!(
            rt.manifest.buckets.contains(&bucket),
            "bucket {bucket} not built (have {:?})",
            rt.manifest.buckets
        );
        let (cap, _nb) = caps(bucket, m.g);
        let tracker = CacheTracker::after_prefill(bucket, m.g, m.fb, cap);
        let fb_shape = vec![m.n_layers, m.n_heads, m.fb, m.head_dim];
        Ok(XlaSession {
            rt,
            method,
            quant_mode,
            w_target,
            w_draft,
            bucket,
            tracker,
            qcache: None,
            dense: None,
            sparse: None,
            fk: HostTensor::zeros(crate::runtime::DType::F32, fb_shape.clone()),
            fv: HostTensor::zeros(crate::runtime::DType::F32, fb_shape),
            timings: PhaseTimings::default(),
        })
    }

    fn uses_quant_cache(&self) -> bool {
        self.method == Method::QuantSpec && self.quant_mode != QuantMode::WeightOnly
    }

    fn uses_dense_region(&self) -> bool {
        !self.uses_quant_cache()
    }

    fn entry(&self, kind: &str) -> String {
        format!("{kind}_{}", self.bucket)
    }

    /// Decode-entry scalar args (pos, n_q, n_f) for the current state.
    fn scalars(&self, n_f: usize, region_n: usize) -> [HostTensor; 3] {
        [
            HostTensor::scalar_i32(self.tracker.context_len() as i32),
            HostTensor::scalar_i32(region_n as i32),
            HostTensor::scalar_i32(n_f as i32),
        ]
    }

    fn take_buffers(&mut self, mut outs: Vec<HostTensor>) -> Vec<HostTensor> {
        // decode entries return (logits, fk, fv)
        self.fv = outs.pop().expect("fv");
        self.fk = outs.pop().expect("fk");
        outs
    }

    /// Run the flush entries when the double buffer fills (Alg. 1 22-25).
    fn flush(&mut self) -> Result<()> {
        let t0 = Instant::now();
        let n_q = HostTensor::scalar_i32(self.tracker.n_q as i32);
        if self.uses_quant_cache() {
            let exe = self.rt.executor(&self.entry("flush"))?;
            let qc = self.qcache.as_ref().context("no quant cache")?;
            let mut args: Vec<Arg<'_>> = qc.iter().map(Arg::Device).collect();
            args.push(Arg::Host(&self.fk));
            args.push(Arg::Host(&self.fv));
            args.push(Arg::Host(&n_q));
            let (mut outs, _) = exe.call(self.rt.client(), &args)?;
            let fv = outs.pop().unwrap();
            let fk = outs.pop().unwrap();
            let new_cache = outs
                .into_iter()
                .map(|t| self.rt.upload(&t))
                .collect::<Result<Vec<_>>>()?;
            self.qcache = Some(new_cache);
            self.fk = fk;
            self.fv = fv;
        } else {
            // dense target region flush
            let exe = self.rt.executor(&self.entry("ar_flush"))?;
            let (kr, vr) = self.dense.as_ref().context("no dense region")?;
            let args = vec![
                Arg::Device(kr),
                Arg::Device(vr),
                Arg::Host(&self.fk),
                Arg::Host(&self.fv),
                Arg::Host(&n_q),
            ];
            let (mut outs, _) = exe.call(self.rt.client(), &args)?;
            let fv = outs.pop().unwrap();
            let fk = outs.pop().unwrap();
            let vr2 = self.rt.upload(&outs.pop().unwrap())?;
            let kr2 = self.rt.upload(&outs.pop().unwrap())?;
            self.dense = Some((kr2, vr2));
            // sparse draft region keeps its own copy of the flushed block
            if let Some(sp) = self.sparse.take() {
                let exe = self.rt.executor(&self.entry("sparse_flush"))?;
                let n_s = HostTensor::scalar_i32(sp.n_s as i32);
                let p = HostTensor::scalar_i32(sp.protected as i32);
                let args = vec![
                    Arg::Device(&sp.kr),
                    Arg::Device(&sp.vr),
                    Arg::Host(&self.fk),
                    Arg::Host(&self.fv),
                    Arg::Host(&n_s),
                    Arg::Host(&p),
                ];
                let (mut souts, _) = exe.call(self.rt.client(), &args)?;
                let _fv = souts.pop();
                let _fk = souts.pop();
                let vr2 = self.rt.upload(&souts.pop().unwrap())?;
                let kr2 = self.rt.upload(&souts.pop().unwrap())?;
                self.sparse = Some(SparseDraft {
                    kr: kr2,
                    vr: vr2,
                    n_s: (sp.n_s + self.tracker.g).min(sp.budget),
                    protected: sp.protected,
                    budget: sp.budget,
                });
            }
            self.fk = fk;
            self.fv = fv;
        }
        self.tracker.flush()?;
        self.timings.flush += t0.elapsed().as_secs_f64();
        self.timings.flush_calls += 1;
        Ok(())
    }

    /// Gather tokens (by index) from the full prefill KV into a region of
    /// `budget` capacity. `kfull` is [L,H,S,dh] host. Consecutive indices
    /// are coalesced into contiguous span copies (`read_tokens_into`-style
    /// windows): StreamingLLM's sinks+window and SnapKV's sorted
    /// selections are mostly runs, so the gather performs O(runs) memcpys
    /// per (layer, head) instead of one copy per token.
    fn gather_region(
        &self,
        kfull: &HostTensor,
        vfull: &HostTensor,
        idx: &[usize],
        budget: usize,
    ) -> Result<(DeviceTensor, DeviceTensor)> {
        let (l, h, s, dh) = dims4(kfull)?;
        anyhow::ensure!(idx.len() <= budget, "selection exceeds budget");
        // (dst slot, src token, run length) per maximal consecutive run
        let mut runs: Vec<(usize, usize, usize)> = Vec::new();
        let mut j = 0;
        while j < idx.len() {
            let mut len = 1;
            while j + len < idx.len() && idx[j + len] == idx[j] + len {
                len += 1;
            }
            runs.push((j, idx[j], len));
            j += len;
        }
        let gather = |src: &HostTensor| -> Result<DeviceTensor> {
            let data = src.as_f32()?;
            let mut out = vec![0.0f32; l * h * budget * dh];
            for li in 0..l {
                for hi in 0..h {
                    let src_base = (li * h + hi) * s * dh;
                    let dst_base = (li * h + hi) * budget * dh;
                    for &(dst_j, tok, len) in &runs {
                        let so = src_base + tok * dh;
                        let dc = dst_base + dst_j * dh;
                        out[dc..dc + len * dh].copy_from_slice(&data[so..so + len * dh]);
                    }
                }
            }
            let t = HostTensor::f32(vec![l, h, budget, dh], out)?;
            self.rt.upload(&t)
        };
        Ok((gather(kfull)?, gather(vfull)?))
    }

    /// Pad the first `keep` prefill tokens into the dense region capacity.
    fn dense_region_from_full(
        &self,
        kfull: &HostTensor,
        vfull: &HostTensor,
        keep: usize,
    ) -> Result<(DeviceTensor, DeviceTensor)> {
        let (l, h, s, dh) = dims4(kfull)?;
        let (cap, _) = caps(self.bucket, self.rt.manifest.model.g);
        let place = |src: &HostTensor| -> Result<DeviceTensor> {
            let data = src.as_f32()?;
            let mut out = vec![0.0f32; l * h * cap * dh];
            for li in 0..l {
                for hi in 0..h {
                    let sb = (li * h + hi) * s * dh;
                    let db = (li * h + hi) * cap * dh;
                    out[db..db + keep * dh].copy_from_slice(&data[sb..sb + keep * dh]);
                }
            }
            let t = HostTensor::f32(vec![l, h, cap, dh], out)?;
            self.rt.upload(&t)
        };
        Ok((place(kfull)?, place(vfull)?))
    }

}

fn caps(bucket: usize, g: usize) -> (usize, usize) {
    let cap = bucket + 4 * g; // multiple of the kernel ATTN_CHUNK tile
    (cap, cap / g)
}

fn dims4(t: &HostTensor) -> Result<(usize, usize, usize, usize)> {
    match t.shape.as_slice() {
        [a, b, c, d] => Ok((*a, *b, *c, *d)),
        other => bail!("expected rank-4 tensor, got {other:?}"),
    }
}

impl Decoder for XlaSession {
    fn vocab(&self) -> usize {
        self.rt.manifest.model.vocab
    }

    fn gamma_max(&self) -> usize {
        self.rt.manifest.model.gamma_max()
    }

    fn method(&self) -> Method {
        self.method
    }

    fn prefill(&mut self, tokens: &[i32]) -> Result<Vec<f32>> {
        anyhow::ensure!(
            tokens.len() == self.bucket,
            "prompt must be exactly the bucket size {} (router pads), got {}",
            self.bucket,
            tokens.len()
        );
        let t0 = Instant::now();
        let exe = self.rt.executor(&self.entry("prefill"))?;
        let toks = HostTensor::i32(vec![self.bucket], tokens.to_vec())?;
        let mut args: Vec<Arg<'_>> = vec![Arg::Host(&toks)];
        for w in &self.w_target.tensors {
            args.push(Arg::Device(w));
        }
        let (outs, _) = exe.call(self.rt.client(), &args)?;
        // [logits, ku,kl,ks,kz,vu,vl,vs,vz, fk,fv, kfull,vfull, snap]
        let mut it = outs.into_iter();
        let logits = it.next().context("logits")?;
        let qarrs: Vec<HostTensor> = (0..8).map(|_| it.next().unwrap()).collect();
        let fk = it.next().context("fk")?;
        let fv = it.next().context("fv")?;
        let kfull = it.next().context("kfull")?;
        let vfull = it.next().context("vfull")?;
        let snap = it.next().context("snap")?;

        self.fk = fk;
        self.fv = fv;
        let g = self.rt.manifest.model.g;
        let s = self.bucket;

        if self.uses_quant_cache() {
            self.qcache = Some(
                qarrs
                    .iter()
                    .map(|t| self.rt.upload(t))
                    .collect::<Result<Vec<_>>>()?,
            );
        }
        if self.uses_dense_region() {
            self.dense = Some(self.dense_region_from_full(&kfull, &vfull, s - g)?);
        }
        match self.method {
            Method::StreamingLlm => {
                let budget = (s / 4).max(2 * g);
                let idx = crate::baselines::streaming_indices(s, budget, SINK_TOKENS);
                let (kr, vr) = self.gather_region(&kfull, &vfull, &idx, budget)?;
                let sink = SINK_TOKENS.min(budget / 2);
                self.sparse = Some(SparseDraft {
                    kr,
                    vr,
                    n_s: budget,
                    protected: sink,
                    budget,
                });
            }
            Method::SnapKv => {
                let budget = (s / 4).max(2 * g);
                let idx = crate::baselines::snapkv_indices(snap.as_f32()?, s, g, budget);
                let (kr, vr) = self.gather_region(&kfull, &vfull, &idx, budget)?;
                self.sparse = Some(SparseDraft {
                    kr,
                    vr,
                    n_s: budget,
                    protected: budget - g, // selected set is protected
                    budget,
                });
            }
            _ => {}
        }
        self.timings.prefill += t0.elapsed().as_secs_f64();
        logits.as_f32().map(|v| v.to_vec())
    }

    fn begin_cycle(&mut self) {
        self.tracker.begin_cycle();
    }

    fn draft_step(&mut self, token: i32) -> Result<Vec<f32>> {
        let t0 = Instant::now();
        let i = self.tracker.n_f - self.tracker.cycle_base();
        let slot = self.tracker.draft_slot(i)?;
        let weights = match (self.method, self.quant_mode) {
            (Method::QuantSpec, QuantMode::KvOnly) => Arc::clone(&self.w_target),
            (Method::QuantSpec, _) => Arc::clone(&self.w_draft),
            _ => Arc::clone(&self.w_target), // sparse baselines draft at fp16
        };
        let (entry, region_n): (String, usize) = if self.uses_quant_cache() {
            (self.entry("draft"), self.tracker.n_q)
        } else if self.method == Method::QuantSpec {
            // weight-only ablation: dense fp cache, quantized weights
            (self.entry("ar_step"), self.tracker.n_q)
        } else {
            let sp = self.sparse.as_ref().context("sparse region missing")?;
            (self.entry("sparse_draft"), sp.n_s)
        };
        // Build region args without holding &self borrows across the call:
        // split borrows manually.
        let outs = {
            let region_args: Vec<Arg<'_>> = if self.uses_quant_cache() {
                self.qcache.as_ref().unwrap().iter().map(Arg::Device).collect()
            } else if self.method == Method::QuantSpec {
                let (kr, vr) = self.dense.as_ref().unwrap();
                vec![Arg::Device(kr), Arg::Device(vr)]
            } else {
                let sp = self.sparse.as_ref().unwrap();
                vec![Arg::Device(&sp.kr), Arg::Device(&sp.vr)]
            };
            // SAFETY of the borrow dance: decode_call only reads the region
            // tensors; we re-borrow self mutably afterwards.
            let exe = self.rt.executor(&entry)?;
            let toks_t = HostTensor::i32(vec![1], vec![token])?;
            let scalars = self.scalars(slot, region_n);
            let mut args: Vec<Arg<'_>> = vec![Arg::Host(&toks_t)];
            for s in &scalars {
                args.push(Arg::Host(s));
            }
            args.extend(region_args);
            args.push(Arg::Host(&self.fk));
            args.push(Arg::Host(&self.fv));
            for w in &weights.tensors {
                args.push(Arg::Device(w));
            }
            let (outs, t) = exe.call(self.rt.client(), &args)?;
            self.timings.transfer += t.upload_secs + t.download_secs;
            outs
        };
        let mut rest = self.take_buffers(outs);
        let logits = rest.pop().context("logits")?;
        // The draft "context" advances within the cycle: n_f tracks it so
        // the next draft step's buffer chunk sees this token's KV.
        self.tracker.n_f = slot + 1;
        self.timings.draft += t0.elapsed().as_secs_f64();
        self.timings.draft_steps += 1;
        // logits shape [1, vocab]
        logits.as_f32().map(|v| v.to_vec())
    }

    fn verify(&mut self, tokens: &[i32]) -> Result<Vec<Vec<f32>>> {
        let t0 = Instant::now();
        let tmax = self.rt.manifest.model.tmax;
        anyhow::ensure!(tokens.len() <= tmax, "verify wants <= {tmax} tokens");
        let base = self.tracker.cycle_base();
        // position of slot-0 token = n_q + base
        let mut padded = tokens.to_vec();
        padded.resize(tmax, 0);
        let weights = Arc::clone(&self.w_target);
        let entry = if self.uses_quant_cache() {
            self.entry("verify")
        } else {
            self.entry("ar_verify")
        };
        let outs = {
            let exe = self.rt.executor(&entry)?;
            let toks_t = HostTensor::i32(vec![tmax], padded)?;
            let pos = HostTensor::scalar_i32((self.tracker.n_q + base) as i32);
            let n_q = HostTensor::scalar_i32(self.tracker.n_q as i32);
            let n_f = HostTensor::scalar_i32(base as i32);
            let mut args: Vec<Arg<'_>> = vec![
                Arg::Host(&toks_t),
                Arg::Host(&pos),
                Arg::Host(&n_q),
                Arg::Host(&n_f),
            ];
            if self.uses_quant_cache() {
                args.extend(self.qcache.as_ref().unwrap().iter().map(Arg::Device));
            } else {
                let (kr, vr) = self.dense.as_ref().unwrap();
                args.push(Arg::Device(kr));
                args.push(Arg::Device(vr));
            }
            args.push(Arg::Host(&self.fk));
            args.push(Arg::Host(&self.fv));
            for w in &weights.tensors {
                args.push(Arg::Device(w));
            }
            let (outs, t) = exe.call(self.rt.client(), &args)?;
            self.timings.transfer += t.upload_secs + t.download_secs;
            outs
        };
        let mut rest = self.take_buffers(outs);
        let logits = rest.pop().context("logits")?;
        let vocab = self.vocab();
        let flat = logits.as_f32()?;
        let rows = tokens
            .len()
            .min(tmax);
        let out = (0..rows)
            .map(|i| flat[i * vocab..(i + 1) * vocab].to_vec())
            .collect();
        self.timings.verify += t0.elapsed().as_secs_f64();
        self.timings.verify_calls += 1;
        Ok(out)
    }

    fn commit(&mut self, accepted: usize, verify_len: usize) -> Result<()> {
        let flush = self.tracker.commit_cycle(accepted, verify_len)?;
        if flush {
            self.flush()?;
        }
        self.tracker.check_invariants()
    }

    fn ar_step(&mut self, token: i32) -> Result<Vec<f32>> {
        let t0 = Instant::now();
        let slot = self.tracker.n_f;
        anyhow::ensure!(slot < self.rt.manifest.model.fb, "buffer full");
        let weights = Arc::clone(&self.w_target);
        let entry = self.entry("ar_step");
        let outs = {
            let exe = self.rt.executor(&entry)?;
            let toks_t = HostTensor::i32(vec![1], vec![token])?;
            let scalars = self.scalars(slot, self.tracker.n_q);
            let (kr, vr) = self.dense.as_ref().context("AR needs dense region")?;
            let mut args: Vec<Arg<'_>> = vec![Arg::Host(&toks_t)];
            for s in &scalars {
                args.push(Arg::Host(s));
            }
            args.push(Arg::Device(kr));
            args.push(Arg::Device(vr));
            args.push(Arg::Host(&self.fk));
            args.push(Arg::Host(&self.fv));
            for w in &weights.tensors {
                args.push(Arg::Device(w));
            }
            let (outs, t) = exe.call(self.rt.client(), &args)?;
            self.timings.transfer += t.upload_secs + t.download_secs;
            outs
        };
        let mut rest = self.take_buffers(outs);
        let logits = rest.pop().context("logits")?;
        if self.tracker.commit_ar() {
            self.flush()?;
        }
        self.timings.draft += t0.elapsed().as_secs_f64();
        self.timings.draft_steps += 1;
        logits.as_f32().map(|v| v.to_vec())
    }

    fn context_len(&self) -> usize {
        self.tracker.context_len()
    }

    fn kv_read_dim(&self) -> usize {
        let m = &self.rt.manifest.model;
        2 * m.n_layers * m.n_heads * m.head_dim
    }

    fn read_kv_token_into(&self, pos: usize, draft: bool, out: &mut [f32]) -> Result<()> {
        self.read_kv_window_into(pos..pos + 1, draft, out)
    }

    /// Device-path batched verify-window read (ROADMAP PR-3 follow-up):
    /// the FP verify buffer is mirrored host-side in `fk`/`fv`, so a
    /// whole window is served in ONE pass over each mirror — per (layer,
    /// head) the source span covering every requested token is contiguous
    /// — instead of re-borrowing and re-walking both tensors once per
    /// token as the trait default does. Layout per token:
    /// `[L·H·dh K values | L·H·dh V values]`. The quantized region lives
    /// in device nibble planes with no lowered dequant entry, so windows
    /// must lie inside the FP buffer `[n_q, n_q + n_f)` — anything else
    /// is a clean error, never a silent wrong answer.
    fn read_kv_window_into(
        &self,
        range: std::ops::Range<usize>,
        draft: bool,
        out: &mut [f32],
    ) -> Result<()> {
        // the FP buffer holds full-precision KV: both planes read the same
        let _ = draft;
        let d = self.kv_read_dim();
        anyhow::ensure!(
            out.len() == range.len() * d,
            "out buffer holds {} floats, window {:?} x dim {d} needs {}",
            out.len(),
            range,
            range.len() * d
        );
        if range.is_empty() {
            return Ok(());
        }
        // COMMITTED positions only: mid-cycle `n_f` already counts drafted
        // (unverified) slots, so the committed FP boundary is
        // `cycle_base()` — n_f during a cycle is past it. Matches the
        // mock, which bounds by its committed context.
        let n_q = self.tracker.n_q;
        let committed_f = self.tracker.cycle_base();
        anyhow::ensure!(
            range.start >= n_q && range.end <= n_q + committed_f,
            "device KV window {range:?} outside the committed FP verify \
             buffer [{n_q}, {}) — drafted slots are not committed KV, and \
             the quantized region needs a lowered dequant entry on device",
            n_q + committed_f
        );
        let (l, h, fb, dh) = dims4(&self.fk)?;
        let s0 = range.start - n_q;
        let t = range.len();
        anyhow::ensure!(s0 + t <= fb, "window past the FP buffer capacity {fb}");
        let fk = self.fk.as_f32()?;
        let fv = self.fv.as_f32()?;
        let half = l * h * dh;
        for li in 0..l {
            for hi in 0..h {
                let base = (li * h + hi) * fb * dh;
                let kspan = &fk[base + s0 * dh..base + (s0 + t) * dh];
                let vspan = &fv[base + s0 * dh..base + (s0 + t) * dh];
                let dst = (li * h + hi) * dh;
                for i in 0..t {
                    out[i * d + dst..i * d + dst + dh]
                        .copy_from_slice(&kspan[i * dh..(i + 1) * dh]);
                    out[i * d + half + dst..i * d + half + dst + dh]
                        .copy_from_slice(&vspan[i * dh..(i + 1) * dh]);
                }
            }
        }
        Ok(())
    }

    fn memory(&self) -> MemoryReport {
        let mut r = MemoryReport::default();
        // weights: target always resident; QuantSpec Both/WeightOnly also
        // holds the INT4 draft set.
        r.weights_host = self.w_target.tensors.iter().map(|t| t.byte_size()).sum();
        r.weights_logical = self.w_target.tensors.iter().map(|t| t.byte_size() / 2).sum(); // fp16
        if self.method == Method::QuantSpec && self.quant_mode != QuantMode::KvOnly {
            r.weights_host += self.w_draft.tensors.iter().map(|t| t.byte_size()).sum::<usize>();
            r.weights_logical += self.w_draft.logical_bytes;
        }
        let mut cache_host = self.fk.byte_size() + self.fv.byte_size();
        let mut cache_logical = (self.fk.byte_size() + self.fv.byte_size()) / 2; // fp16
        if let Some(qc) = &self.qcache {
            for (i, t) in qc.iter().enumerate() {
                cache_host += t.byte_size();
                cache_logical += match i {
                    0 | 1 | 4 | 5 => t.byte_size() / 2, // nibbles: 4-bit
                    _ => t.byte_size() / 2,             // scales/zeros: fp16
                };
            }
        }
        if let Some((kr, vr)) = &self.dense {
            cache_host += kr.byte_size() + vr.byte_size();
            cache_logical += (kr.byte_size() + vr.byte_size()) / 2;
        }
        if let Some(sp) = &self.sparse {
            cache_host += sp.kr.byte_size() + sp.vr.byte_size();
            cache_logical += (sp.kr.byte_size() + sp.vr.byte_size()) / 2;
        }
        r.cache_host = cache_host;
        r.cache_logical = cache_logical;
        r
    }

    fn timings(&self) -> PhaseTimings {
        self.timings
    }
}

