//! Paged hierarchical KV-cache pool: the shared memory arena under every
//! session's cache (the serving-scale counterpart of `cache::CacheTracker`).
//!
//! The paper treats one request's KV cache as the bottleneck (§4.3); under
//! multi-sequence serving the binding constraint is the *sum* of caches, so
//! all cache memory is owned by one fixed-capacity [`page::PagePool`] and
//! sessions hold only block tables into it.
//!
//! # Page layout
//!
//! A page holds exactly G tokens of KV for one session, either as a
//! hierarchically quantized group (bit-packed INT4 upper/lower planes at
//! two 4-bit codes per byte + scale/zero — the bit-shared draft/target
//! representation of §4.2) or as full-precision buffer slots. Steady-state
//! reads are fused and lane-wise: per token for the draft path
//! ([`paged::PagedKvCache::read_token_into`]) and batched per verify
//! window ([`paged::PagedKvCache::read_tokens_into`] — one lock, one
//! group lookup per crossed group); both are zero-allocation and touch
//! only the requested tokens' codes. A session's cache is:
//!
//! ```text
//!   groups[0] groups[1] ... groups[n-1] | fp[0] fp[1] fp[2]
//!   └── quantized region, n_q tokens ──┘ └─ FB = 2G+tmax slots ─┘
//! ```
//!
//! Flush = quantize C_F1 *into a freshly allocated page* + shift C_F2;
//! speculation rollback never touches pages (the tracker just commits a
//! smaller count), so both stay O(1) in page traffic.
//!
//! # Sharding (the parallel-rounds contract)
//!
//! Pool state is split so N sessions can decode on N cores without
//! serializing on one mutex:
//!
//! * [`page::PagePool`] — GLOBAL accounting only (page budget, per-kind
//!   counts, byte totals, cache-traffic counters), all atomics; the hard
//!   capacity bound is a CAS.
//! * [`page::SessionShard`] — one per session, owning that session's page
//!   DATA behind its own mutex; `PagedKvCache` clones the `Arc` out at
//!   construction and runs its whole data plane on it.
//! * [`session::SessionManager`] — the control-plane mutex: admission,
//!   release, LRU eviction, and once-per-round batcher telemetry. Lock
//!   order is manager → shard; steady-state draft/verify steps take only
//!   their shard lock (pinned by a test that holds the manager mutex
//!   across a full decode).
//!
//! # Sessions, watermarks, admission
//!
//! [`session::SessionManager`] brokers the arena: requests are admitted
//! with a cost-model page reservation
//! (`costmodel::memory::pool_pages_for_request`) and the manager counts
//! *committed* pages = Σ max(reserved, allocated). Admission holds
//! committed pages at or below the **high watermark**; crossing it first
//! reclaims memory down to the **low watermark**, and only then reports
//! `Saturated` (the router then queues or sheds — never OOM). A
//! reservation larger than the watermarked pool is rejected outright as
//! `TooLarge`.
//!
//! # The tier hierarchy (hot / warm / cold)
//!
//! With tiering enabled (`PoolConfig::spill_pages > 0`), pages move
//! through three tiers — hot FP pages, warm quantized pages (both in the
//! arena), and cold pages spilled to a file-backed [`tier::SpillStore`].
//! Reclamation under pressure is **page-granular first**: the manager's
//! `reclaim` spills a victim's written quantized pages (their KV survives
//! and faults back bit-identically), escalates to whole-shard hibernation
//! ([`page::SessionShard::spill_all`]), and only as a last resort falls
//! back to destructive whole-session eviction. The typed
//! [`tier::ReclaimOutcome`] replaces the old `evict_lru -> Option<SessionId>`
//! surface. Lock order extends to manager → shard data → spill slots; see
//! `tier` module docs for the spill-file format.
//!
//! # Accounting convention
//!
//! Two byte counts are maintained everywhere, matching `cache::MemoryReport`:
//! **logical** bytes use true device bit widths (INT4 = 0.5 B, fp16 KV),
//! **host** bytes are what this CPU testbed actually holds (nibbles in i8,
//! fp in f32). `/stats` and the benches report both; watermarks and
//! capacity are denominated in pages, which are identical in either
//! convention.

pub mod page;
pub mod paged;
pub mod session;
pub mod tier;

pub use page::{
    CacheTraffic, FaultOutcome, PageHandle, PageKind, PagePool, PoolConfig, SessionId,
    SessionShard,
};
pub use paged::{mock_kv, mock_kv_into, BlockTable, PagedKvCache};
pub use session::{
    shared, AdmitOutcome, PoolSnapshot, RoundPhases, SessionManager, SharedSessionManager,
};
pub use tier::{
    ReclaimOutcome, SpillHandle, SpillStore, TierPolicy, TierStats, TierTransition,
};
