//! The pool's tier-transition surface: policy, typed reclaim outcomes,
//! and the file-backed cold tier ([`SpillStore`]).
//!
//! The page hierarchy has three tiers:
//!
//! * **hot** — full-precision pages (the double FP buffer) in the arena;
//! * **warm** — bit-packed quantized pages in the arena;
//! * **cold** — pages serialized into page-aligned, checksummed slots of
//!   an on-disk spill file, no longer counted against the arena budget.
//!
//! [`TierTransition`] names the moves between them: `Demote` is the
//! in-arena hot→warm quantization flush the paged cache already performs,
//! `Spill` parks a warm (or, during hibernation, hot) page in the cold
//! store, and `Restore` faults it back. Every transition is lossless —
//! spilled payloads carry raw plane bytes and IEEE-754 float bits, so a
//! spill/restore round trip is bit-identical (pinned by property tests in
//! `pool/paged.rs`).
//!
//! [`ReclaimOutcome`] is the typed result of the session manager's
//! `reclaim`, replacing the old ad-hoc `evict_lru(exclude) ->
//! Option<SessionId>` surface: page-granular spilling is the first
//! resort, whole-shard hibernation the second, and destructive
//! whole-session eviction only the fallback.
//!
//! # Lock order
//!
//! The store keeps its own slot-map mutex, acquired strictly *after* any
//! shard data lock and never while holding the manager lock's guard
//! across a transition that re-enters the manager. The full order is
//! manager → shard data → spill slots; file I/O (`read_at`/`write_at`)
//! happens outside the slot-map lock.
//!
//! # Spill-file format
//!
//! A flat array of fixed-size slots (`costmodel::memory::spill_slot_bytes`,
//! 4 KiB-aligned). Each occupied slot holds a 32-byte header —
//! magic `"QSPL"`, the slot generation, the page kind, the payload
//! length, and an FNV-1a-64 payload checksum — followed by the payload.
//! Slot generations mirror the arena's handle generations: freeing a slot
//! bumps its generation, so a stale [`SpillHandle`] can never read
//! another page's bytes, and a torn or corrupted slot fails its checksum
//! instead of faulting garbage back into the arena.

use std::fs::{File, OpenOptions};
use std::os::unix::fs::FileExt;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock, PoisonError};

use anyhow::{ensure, Context, Result};

use crate::costmodel::memory::spill_slot_bytes;
use crate::util::fault::{FaultInjector, FaultSite};

use super::page::PageKind;

/// Slot I/O attempts before a transient error becomes permanent: one
/// initial try plus two retries (docs/ROBUSTNESS.md).
const SPILL_IO_ATTEMPTS: u32 = 3;

/// Backoff before retry `n` (1-based): 200µs, 400µs — long enough to ride
/// out a transient EINTR/ENOSPC blip, short enough that a reclaim pass
/// under pressure isn't parked behind a dead disk (the circuit breaker
/// handles the dead-disk case).
fn retry_backoff(attempt: u32) -> std::time::Duration {
    std::time::Duration::from_micros(100u64 << attempt.min(4))
}

/// One move in the page hierarchy. `Demote` (hot→warm) is recorded by the
/// paged cache's quantization flush; `Spill` (warm→cold) and `Restore`
/// (cold→warm) are executed against the [`SpillStore`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TierTransition {
    /// Hot FP page quantized into a warm in-arena page (the flush).
    Demote,
    /// Warm (or hibernating hot) page serialized into the cold store.
    Spill,
    /// Cold page faulted back into the arena.
    Restore,
}

impl TierTransition {
    pub fn name(&self) -> &'static str {
        match self {
            TierTransition::Demote => "demote",
            TierTransition::Spill => "spill",
            TierTransition::Restore => "restore",
        }
    }
}

/// Knobs governing when pages move between tiers. Carried by the
/// [`SpillStore`] so every layer (manager reclaim, paged-cache
/// fetch-ahead) reads one policy without extra plumbing.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TierPolicy {
    /// Escalate page-granular reclaim to whole-shard hibernation when
    /// spilling written quantized pages alone frees nothing.
    pub hibernate_on_pressure: bool,
    /// Speculatively restore the next verify window's cold pages at cycle
    /// start, overlapping the transfer with the decode round.
    pub fetch_ahead: bool,
    /// Max pages one reclaim pass spills from a single victim
    /// (0 = no cap — take everything spillable).
    pub max_spill_batch: usize,
    /// Ceiling on the *adaptive* fetch-ahead depth: how many of the
    /// newest quant groups `begin_cycle` may restore speculatively on top
    /// of the FP buffer. The live depth starts at 1 and is steered
    /// between 1 and this cap by an EWMA of the observed on-demand fault
    /// rate (see [`SpillStore::note_restore`]); treated as 1 when 0.
    pub fetch_ahead_max: usize,
}

impl Default for TierPolicy {
    fn default() -> Self {
        TierPolicy {
            hibernate_on_pressure: true,
            fetch_ahead: true,
            max_spill_batch: 0,
            fetch_ahead_max: 8,
        }
    }
}

/// Typed result of one `SessionManager::reclaim` pass — the redesigned
/// replacement for the ad-hoc `evict_lru(exclude) -> Option<SessionId>`
/// surface. Ordered by preference: spilling preserves the victim's KV
/// (it faults back transparently), eviction destroys it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReclaimOutcome {
    /// Page-granular first resort: `pages` of the victim's written
    /// quantized pages moved to the cold tier.
    Spilled { victim: super::page::SessionId, pages: usize },
    /// The victim's entire resident shard moved cold; it resumes
    /// bit-identically on its next touch instead of being recomputed.
    Hibernated { victim: super::page::SessionId, pages: usize },
    /// Destructive fallback: the victim was evicted whole-session (its
    /// pages are gone, a subsequent touch errors).
    Evicted { victim: super::page::SessionId, pages: usize },
    /// Nothing left to spill, hibernate, or evict.
    Exhausted,
}

impl ReclaimOutcome {
    /// Arena pages the pass freed.
    pub fn pages(&self) -> usize {
        match *self {
            ReclaimOutcome::Spilled { pages, .. }
            | ReclaimOutcome::Hibernated { pages, .. }
            | ReclaimOutcome::Evicted { pages, .. } => pages,
            ReclaimOutcome::Exhausted => 0,
        }
    }

    pub fn victim(&self) -> Option<super::page::SessionId> {
        match *self {
            ReclaimOutcome::Spilled { victim, .. }
            | ReclaimOutcome::Hibernated { victim, .. }
            | ReclaimOutcome::Evicted { victim, .. } => Some(victim),
            ReclaimOutcome::Exhausted => None,
        }
    }

    /// Whether the caller's retry loop should attempt another allocation.
    pub fn progressed(&self) -> bool {
        self.pages() > 0
    }
}

/// Generation-checked reference to one occupied cold-tier slot, mirroring
/// the arena's `PageHandle` discipline: freeing a slot bumps its
/// generation, so stale handles fail validation instead of aliasing.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpillHandle {
    slot: u32,
    gen: u32,
}

impl SpillHandle {
    /// Slot index (for logs/assertions; cannot forge a valid handle).
    pub fn slot(&self) -> u32 {
        self.slot
    }
}

const SLOT_MAGIC: u32 = 0x5153_504C; // "QSPL"
const SLOT_HEADER_BYTES: usize = 32;

fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x1_0000_0000_01b3);
    }
    h
}

fn kind_code(kind: PageKind) -> u32 {
    match kind {
        PageKind::Quant => 0,
        PageKind::Fp => 1,
    }
}

fn kind_from_code(code: u32) -> Result<PageKind> {
    match code {
        0 => Ok(PageKind::Quant),
        1 => Ok(PageKind::Fp),
        _ => anyhow::bail!("spill slot holds unknown page kind {code}"),
    }
}

/// Serialize one FP page for the cold tier: `[len u32 LE]` then raw
/// IEEE-754 bits per value — bit-identical on the way back.
pub fn encode_fp_page(vals: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(4 + 4 * vals.len());
    out.extend_from_slice(&(vals.len() as u32).to_le_bytes());
    for v in vals {
        out.extend_from_slice(&v.to_bits().to_le_bytes());
    }
    out
}

/// Inverse of [`encode_fp_page`]; rejects malformed framing.
pub fn decode_fp_page(buf: &[u8]) -> Result<Vec<f32>> {
    ensure!(buf.len() >= 4, "fp page header truncated ({} bytes)", buf.len());
    let n = u32::from_le_bytes(buf[0..4].try_into().unwrap()) as usize;
    ensure!(
        buf.len() == 4 + 4 * n,
        "fp page payload is {} bytes, expected {}",
        buf.len(),
        4 + 4 * n
    );
    Ok(buf[4..]
        .chunks_exact(4)
        .map(|c| f32::from_bits(u32::from_le_bytes(c.try_into().unwrap())))
        .collect())
}

struct SlotMap {
    gens: Vec<u32>,
    free: Vec<u32>,
}

/// Why one slot-read attempt failed, for the retry policy: transient I/O
/// is worth retrying, corrupt bytes at rest are not.
enum ReadFailure {
    Transient(std::io::Error),
    Corrupt(anyhow::Error),
}

/// Counters the manager's `PoolSnapshot` and `/stats` tier block read in
/// one pass. All fields are lifetime totals except `spilled_pages`
/// (instantaneous cold-tier occupancy).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TierStats {
    pub spilled_pages: usize,
    pub spill_bytes_written: u64,
    pub spill_bytes_read: u64,
    pub restore_faults: u64,
    pub fetch_ahead_hits: u64,
    pub demotions: u64,
    pub hibernations: u64,
    /// Slot I/O attempts retried after a transient failure (each retry
    /// that eventually succeeds costs latency, never correctness).
    pub spill_retries: u64,
    /// Slot I/O operations that failed permanently: retries exhausted, or
    /// a non-retryable checksum/framing mismatch on read.
    pub spill_io_errors: u64,
}

/// The file-backed cold tier. Thread-safe: slot bookkeeping sits behind
/// one mutex, file I/O uses positioned reads/writes (`FileExt`) so
/// concurrent spills and restores never seek over each other, and all
/// accounting is lock-free atomics.
pub struct SpillStore {
    file: File,
    path: PathBuf,
    slot_bytes: usize,
    capacity_slots: usize,
    policy: TierPolicy,
    slots: Mutex<SlotMap>,
    spilled_pages: AtomicUsize,
    bytes_written: AtomicU64,
    bytes_read: AtomicU64,
    restore_faults: AtomicU64,
    fetch_ahead_hits: AtomicU64,
    demotions: AtomicU64,
    hibernations: AtomicU64,
    spill_retries: AtomicU64,
    spill_io_errors: AtomicU64,
    /// Installed once by the coordinator when `fault_spec` arms spill
    /// sites; absent (the default) costs one `OnceLock::get` per I/O.
    fault: OnceLock<Arc<FaultInjector>>,
    /// EWMA of the on-demand fault share of recent restores, in ‰
    /// (0 = every restore was speculative, 1000 = every one blocked a
    /// read). Drives `fetch_depth`.
    fault_ewma_milli: AtomicU64,
    /// Live adaptive fetch-ahead depth in quant groups, 1..=policy max.
    fetch_depth: AtomicUsize,
}

impl SpillStore {
    /// Create a spill file under `dir` (empty ⇒ the system temp dir),
    /// sized for pages of `elems` values. `capacity_pages` caps cold-tier
    /// occupancy (0 = unbounded); when the cap is hit,
    /// [`SpillStore::write_page`] reports `None` and the reclaimer falls
    /// back to eviction. The file is unlinked when the store drops.
    pub fn new(
        dir: &str,
        elems: usize,
        capacity_pages: usize,
        policy: TierPolicy,
    ) -> Result<Arc<SpillStore>> {
        static SEQ: AtomicU64 = AtomicU64::new(0);
        let dir = if dir.is_empty() {
            std::env::temp_dir()
        } else {
            PathBuf::from(dir)
        };
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating spill dir {}", dir.display()))?;
        let name = format!(
            "qs-spill-{}-{}.bin",
            std::process::id(),
            SEQ.fetch_add(1, Ordering::Relaxed)
        );
        let path = dir.join(name);
        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .create_new(true)
            .open(&path)
            .with_context(|| format!("creating spill file {}", path.display()))?;
        Ok(Arc::new(SpillStore {
            file,
            path,
            slot_bytes: spill_slot_bytes(elems),
            capacity_slots: if capacity_pages == 0 { usize::MAX } else { capacity_pages },
            policy,
            slots: Mutex::new(SlotMap { gens: Vec::new(), free: Vec::new() }),
            spilled_pages: AtomicUsize::new(0),
            bytes_written: AtomicU64::new(0),
            bytes_read: AtomicU64::new(0),
            restore_faults: AtomicU64::new(0),
            fetch_ahead_hits: AtomicU64::new(0),
            demotions: AtomicU64::new(0),
            hibernations: AtomicU64::new(0),
            spill_retries: AtomicU64::new(0),
            spill_io_errors: AtomicU64::new(0),
            fault: OnceLock::new(),
            fault_ewma_milli: AtomicU64::new(0),
            fetch_depth: AtomicUsize::new(1),
        }))
    }

    /// Arm this store's spill I/O sites with the process fault injector
    /// (coordinator startup only; a second install is ignored).
    pub fn install_fault_injector(&self, inj: Arc<FaultInjector>) {
        let _ = self.fault.set(inj);
    }

    /// An injected error for `site`, if the injector is armed and fires.
    fn injected(&self, site: FaultSite) -> Option<std::io::Error> {
        match self.fault.get() {
            Some(inj) if inj.should_fire(site) => Some(inj.io_error(site)),
            _ => None,
        }
    }

    /// Slot-map lock with poison recovery: the map's invariants hold at
    /// every await-free unlock point, so a panicking peer (contained
    /// elsewhere) must not wedge all subsequent spill I/O.
    fn slots_lock(&self) -> MutexGuard<'_, SlotMap> {
        self.slots.lock().unwrap_or_else(PoisonError::into_inner)
    }

    pub fn policy(&self) -> TierPolicy {
        self.policy
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Fixed slot size (page-aligned; see `costmodel::spill_slot_bytes`).
    pub fn slot_bytes(&self) -> usize {
        self.slot_bytes
    }

    /// Pages currently parked in the cold tier.
    pub fn spilled_pages(&self) -> usize {
        self.spilled_pages.load(Ordering::Acquire)
    }

    pub fn stats(&self) -> TierStats {
        TierStats {
            spilled_pages: self.spilled_pages.load(Ordering::Acquire),
            spill_bytes_written: self.bytes_written.load(Ordering::Relaxed),
            spill_bytes_read: self.bytes_read.load(Ordering::Relaxed),
            restore_faults: self.restore_faults.load(Ordering::Relaxed),
            fetch_ahead_hits: self.fetch_ahead_hits.load(Ordering::Relaxed),
            demotions: self.demotions.load(Ordering::Relaxed),
            hibernations: self.hibernations.load(Ordering::Relaxed),
            spill_retries: self.spill_retries.load(Ordering::Relaxed),
            spill_io_errors: self.spill_io_errors.load(Ordering::Relaxed),
        }
    }

    /// Account a hot→warm demotion (the paged cache's quantization flush).
    pub fn note_demotion(&self) {
        self.demotions.fetch_add(1, Ordering::Relaxed);
    }

    /// Account `pages` cold→warm restores: speculative ones (fetch-ahead,
    /// before any read blocked) count as hits, on-demand ones as faults.
    ///
    /// Each call is also one sample for the adaptive fetch-ahead
    /// controller: an EWMA (α = 1/8) of the fault share steers the depth
    /// `begin_cycle` prefetches. Sustained on-demand faults (EWMA above
    /// 50%) grow the depth one group per sample up to
    /// `policy.fetch_ahead_max`; once faults stop (EWMA decays below
    /// 12.5%) it shrinks back one per sample toward 1, so an idle or
    /// warm-resident session never over-restores.
    pub fn note_restore(&self, pages: usize, speculative: bool) {
        let ctr = if speculative { &self.fetch_ahead_hits } else { &self.restore_faults };
        ctr.fetch_add(pages as u64, Ordering::Relaxed);
        let sample: u64 = if speculative { 0 } else { 1000 };
        let prev = self.fault_ewma_milli.load(Ordering::Relaxed);
        // α = 1/8: ewma += (sample - ewma) / 8, in integer ‰. A racing
        // writer loses at most one sample's worth of smoothing — fine for
        // a heuristic.
        let ewma = (7 * prev + sample) / 8;
        self.fault_ewma_milli.store(ewma, Ordering::Relaxed);
        let depth = self.fetch_depth.load(Ordering::Relaxed);
        let max = self.policy.fetch_ahead_max.max(1);
        let next = if ewma > 500 {
            (depth + 1).min(max)
        } else if ewma < 125 {
            depth.saturating_sub(1).max(1)
        } else {
            depth.min(max)
        };
        if next != depth {
            self.fetch_depth.store(next, Ordering::Relaxed);
        }
    }

    /// Current adaptive fetch-ahead depth: how many of the newest quant
    /// groups `begin_cycle` restores speculatively (the FP buffer is
    /// always included on top).
    pub fn fetch_ahead_depth(&self) -> usize {
        self.fetch_depth.load(Ordering::Relaxed)
    }

    /// Account one whole-shard hibernation (monotone total).
    pub fn note_hibernation(&self) {
        self.hibernations.fetch_add(1, Ordering::Relaxed);
    }

    /// Park one serialized page in the cold tier. `Ok(None)` means the
    /// tier is at capacity — the caller escalates (eviction) rather than
    /// blocking. The payload must fit the fixed slot.
    pub fn write_page(&self, kind: PageKind, payload: &[u8]) -> Result<Option<SpillHandle>> {
        ensure!(
            SLOT_HEADER_BYTES + payload.len() <= self.slot_bytes,
            "spill payload of {} bytes exceeds the {}-byte slot",
            payload.len(),
            self.slot_bytes
        );
        let (slot, gen) = {
            let mut m = self.slots_lock();
            match m.free.pop() {
                Some(slot) => (slot, m.gens[slot as usize]),
                None => {
                    if m.gens.len() >= self.capacity_slots {
                        return Ok(None);
                    }
                    let slot = m.gens.len() as u32;
                    m.gens.push(0);
                    (slot, 0)
                }
            }
        };
        let mut buf = Vec::with_capacity(SLOT_HEADER_BYTES + payload.len());
        buf.extend_from_slice(&SLOT_MAGIC.to_le_bytes());
        buf.extend_from_slice(&gen.to_le_bytes());
        buf.extend_from_slice(&kind_code(kind).to_le_bytes());
        buf.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        buf.extend_from_slice(&fnv1a64(payload).to_le_bytes());
        buf.extend_from_slice(&[0u8; 8]);
        buf.extend_from_slice(payload);
        let off = slot as u64 * self.slot_bytes as u64;
        // Bounded retry for transient I/O; the write AND its fsync must
        // both land before the slot is considered live — a page the caller
        // will drop from the arena cannot be backed by bytes still sitting
        // in a volatile page cache.
        let mut attempt = 0u32;
        loop {
            let res = match self.injected(FaultSite::SpillWrite) {
                Some(e) => Err(e),
                None => self.file.write_all_at(&buf, off).and_then(|()| self.file.sync_data()),
            };
            match res {
                Ok(()) => break,
                Err(e) => {
                    attempt += 1;
                    if attempt >= SPILL_IO_ATTEMPTS {
                        self.spill_io_errors.fetch_add(1, Ordering::Relaxed);
                        // hand the slot back so an I/O error doesn't leak it
                        let mut m = self.slots_lock();
                        m.gens[slot as usize] = m.gens[slot as usize].wrapping_add(1);
                        m.free.push(slot);
                        return Err(e).with_context(|| {
                            format!("writing spill slot {slot} ({attempt} attempts)")
                        });
                    }
                    self.spill_retries.fetch_add(1, Ordering::Relaxed);
                    std::thread::sleep(retry_backoff(attempt));
                }
            }
        }
        self.bytes_written.fetch_add(buf.len() as u64, Ordering::Relaxed);
        self.spilled_pages.fetch_add(1, Ordering::Release);
        Ok(Some(SpillHandle { slot, gen }))
    }

    fn check(&self, h: SpillHandle, m: &SlotMap) -> Result<()> {
        ensure!(
            (h.slot as usize) < m.gens.len(),
            "spill handle slot {} out of range ({} slots)",
            h.slot,
            m.gens.len()
        );
        ensure!(
            m.gens[h.slot as usize] == h.gen,
            "stale spill handle for slot {} (gen {} != {})",
            h.slot,
            h.gen,
            m.gens[h.slot as usize]
        );
        Ok(())
    }

    /// Read one cold page without freeing its slot (fetch-ahead peeks and
    /// tests). Verifies generation, magic, framing, and checksum.
    /// Transient I/O errors are retried (bounded, with backoff); a
    /// checksum or framing mismatch is NOT retried — the bytes at rest
    /// are wrong, and re-reading them cannot make them right.
    pub fn read_page(&self, h: SpillHandle) -> Result<(PageKind, Vec<u8>)> {
        {
            let m = self.slots_lock();
            self.check(h, &m)?;
        }
        let mut attempt = 0u32;
        loop {
            match self.try_read_slot(h) {
                Ok(out) => return Ok(out),
                Err(ReadFailure::Corrupt(e)) => {
                    self.spill_io_errors.fetch_add(1, Ordering::Relaxed);
                    return Err(e);
                }
                Err(ReadFailure::Transient(e)) => {
                    attempt += 1;
                    if attempt >= SPILL_IO_ATTEMPTS {
                        self.spill_io_errors.fetch_add(1, Ordering::Relaxed);
                        return Err(e).with_context(|| {
                            format!("reading spill slot {} ({attempt} attempts)", h.slot)
                        });
                    }
                    self.spill_retries.fetch_add(1, Ordering::Relaxed);
                    std::thread::sleep(retry_backoff(attempt));
                }
            }
        }
    }

    /// One read attempt, classifying failures for the retry policy.
    fn try_read_slot(
        &self,
        h: SpillHandle,
    ) -> std::result::Result<(PageKind, Vec<u8>), ReadFailure> {
        let corrupt = |e: anyhow::Error| ReadFailure::Corrupt(e);
        if let Some(e) = self.injected(FaultSite::SpillRead) {
            return Err(ReadFailure::Transient(e));
        }
        let off = h.slot as u64 * self.slot_bytes as u64;
        let mut header = [0u8; SLOT_HEADER_BYTES];
        self.file.read_exact_at(&mut header, off).map_err(ReadFailure::Transient)?;
        let magic = u32::from_le_bytes(header[0..4].try_into().unwrap());
        if magic != SLOT_MAGIC {
            return Err(corrupt(anyhow::anyhow!(
                "spill slot {} bad magic {magic:#x}",
                h.slot
            )));
        }
        let gen = u32::from_le_bytes(header[4..8].try_into().unwrap());
        if gen != h.gen {
            return Err(corrupt(anyhow::anyhow!(
                "spill slot {} holds gen {gen}, handle has {}",
                h.slot,
                h.gen
            )));
        }
        let kind =
            kind_from_code(u32::from_le_bytes(header[8..12].try_into().unwrap()))
                .map_err(corrupt)?;
        let len = u32::from_le_bytes(header[12..16].try_into().unwrap()) as usize;
        if SLOT_HEADER_BYTES + len > self.slot_bytes {
            return Err(corrupt(anyhow::anyhow!(
                "spill slot {} claims {len}-byte payload beyond the slot",
                h.slot
            )));
        }
        let want_sum = u64::from_le_bytes(header[16..24].try_into().unwrap());
        let mut payload = vec![0u8; len];
        self.file
            .read_exact_at(&mut payload, off + SLOT_HEADER_BYTES as u64)
            .map_err(ReadFailure::Transient)?;
        if self.injected(FaultSite::SpillCorrupt).is_some() {
            // Simulate data-at-rest rot: the checksum below must catch it.
            if let Some(b) = payload.first_mut() {
                *b = !*b;
            }
        }
        let got_sum = fnv1a64(&payload);
        if got_sum != want_sum {
            return Err(corrupt(anyhow::anyhow!(
                "spill slot {} checksum mismatch ({got_sum:#x} != {want_sum:#x}): \
                 refusing to restore corrupt page",
                h.slot
            )));
        }
        self.bytes_read.fetch_add((SLOT_HEADER_BYTES + len) as u64, Ordering::Relaxed);
        Ok((kind, payload))
    }

    /// Restore semantics: read the page, then free its slot (generation
    /// bumped so the handle dies). The cold tier never holds a page that
    /// is also resident.
    pub fn take_page(&self, h: SpillHandle) -> Result<(PageKind, Vec<u8>)> {
        let out = self.read_page(h)?;
        self.free_page(h)?;
        Ok(out)
    }

    /// Release a cold slot without reading it (page freed while spilled —
    /// session retire). Stale handles error; a slot can't double-free.
    pub fn free_page(&self, h: SpillHandle) -> Result<()> {
        let mut m = self.slots_lock();
        self.check(h, &m)?;
        m.gens[h.slot as usize] = m.gens[h.slot as usize].wrapping_add(1);
        m.free.push(h.slot);
        drop(m);
        self.spilled_pages.fetch_sub(1, Ordering::Release);
        Ok(())
    }
}

impl Drop for SpillStore {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.path);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store(capacity: usize) -> Arc<SpillStore> {
        SpillStore::new("", 16, capacity, TierPolicy::default()).unwrap()
    }

    #[test]
    fn page_roundtrip_and_accounting() {
        let s = store(0);
        assert_eq!(s.slot_bytes() % 4096, 0, "slots are page-aligned");
        let payload: Vec<u8> = (0..100u8).collect();
        let h = s.write_page(PageKind::Quant, &payload).unwrap().unwrap();
        assert_eq!(s.spilled_pages(), 1);
        let (kind, back) = s.read_page(h).unwrap();
        assert_eq!(kind, PageKind::Quant);
        assert_eq!(back, payload);
        assert_eq!(s.spilled_pages(), 1, "read_page leaves the slot occupied");
        let (kind, back) = s.take_page(h).unwrap();
        assert_eq!((kind, back), (PageKind::Quant, payload));
        assert_eq!(s.spilled_pages(), 0, "take_page frees the slot");
        let st = s.stats();
        assert!(st.spill_bytes_written >= 132, "header + payload accounted");
        assert!(st.spill_bytes_read >= 2 * 132, "two reads accounted");
    }

    #[test]
    fn stale_and_double_frees_rejected() {
        let s = store(0);
        let h = s.write_page(PageKind::Fp, &[1, 2, 3]).unwrap().unwrap();
        s.free_page(h).unwrap();
        let err = s.free_page(h).unwrap_err().to_string();
        assert!(err.contains("stale"), "{err}");
        assert!(s.read_page(h).is_err(), "stale read rejected");
        // the freed slot is reused under a new generation; the old handle
        // still cannot see the new occupant
        let h2 = s.write_page(PageKind::Quant, &[9]).unwrap().unwrap();
        assert_eq!(h2.slot(), h.slot(), "slot reused");
        assert!(s.read_page(h).is_err());
        assert_eq!(s.read_page(h2).unwrap().1, vec![9]);
    }

    #[test]
    fn capacity_cap_reports_full_not_error() {
        let s = store(2);
        let a = s.write_page(PageKind::Quant, &[1]).unwrap().unwrap();
        let _b = s.write_page(PageKind::Quant, &[2]).unwrap().unwrap();
        assert!(s.write_page(PageKind::Quant, &[3]).unwrap().is_none(), "full");
        s.free_page(a).unwrap();
        assert!(s.write_page(PageKind::Quant, &[4]).unwrap().is_some(), "slot reusable");
    }

    #[test]
    fn corrupted_payload_fails_checksum() {
        let s = store(0);
        let h = s.write_page(PageKind::Quant, &[7u8; 64]).unwrap().unwrap();
        // flip one payload byte on disk, behind the store's back
        let f = OpenOptions::new().write(true).open(s.path()).unwrap();
        f.write_all_at(&[0xFF], SLOT_HEADER_BYTES as u64 + 5).unwrap();
        let err = s.read_page(h).unwrap_err().to_string();
        assert!(err.contains("checksum"), "{err}");
    }

    #[test]
    fn oversized_payload_rejected() {
        let s = store(0);
        let huge = vec![0u8; s.slot_bytes()];
        let err = s.write_page(PageKind::Fp, &huge).unwrap_err().to_string();
        assert!(err.contains("exceeds"), "{err}");
        assert_eq!(s.spilled_pages(), 0, "failed write leaks no slot");
    }

    #[test]
    fn fp_page_encoding_is_bit_exact() {
        let vals: Vec<f32> = vec![0.0, -0.0, 1.5, f32::MIN_POSITIVE, -3.25e-7, 1e30];
        let bytes = encode_fp_page(&vals);
        let back = decode_fp_page(&bytes).unwrap();
        assert_eq!(vals.len(), back.len());
        for (a, b) in vals.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert!(decode_fp_page(&bytes[..bytes.len() - 1]).is_err());
        assert!(decode_fp_page(&[1, 0]).is_err());
    }

    #[test]
    fn concurrent_spill_restore_is_safe() {
        let s = store(0);
        let threads: Vec<_> = (0..4u8)
            .map(|t| {
                let s = Arc::clone(&s);
                std::thread::spawn(move || {
                    for i in 0..50u8 {
                        let payload = vec![t ^ i; 32];
                        let h = s.write_page(PageKind::Quant, &payload).unwrap().unwrap();
                        let (_, back) = s.take_page(h).unwrap();
                        assert_eq!(back, payload);
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(s.spilled_pages(), 0);
    }

    /// Satellite acceptance: the adaptive fetch-ahead controller starts
    /// conservative (depth 1), converges up to the configured max under a
    /// sustained on-demand fault stream, and decays back to 1 once every
    /// restore is speculative again.
    #[test]
    fn adaptive_fetch_depth_converges_up_under_faults_and_decays() {
        let s = store(0);
        assert_eq!(s.fetch_ahead_depth(), 1, "starts at the minimum depth");
        let max = TierPolicy::default().fetch_ahead_max;
        let mut grew_monotonically = true;
        let mut last = 1;
        for _ in 0..32 {
            s.note_restore(1, false);
            let d = s.fetch_ahead_depth();
            grew_monotonically &= d >= last;
            last = d;
        }
        assert!(grew_monotonically, "depth never steps down mid-fault-burst");
        assert_eq!(s.fetch_ahead_depth(), max, "sustained faults reach the cap");
        assert_eq!(s.stats().restore_faults, 32, "accounting unchanged");
        for _ in 0..64 {
            s.note_restore(1, true);
        }
        assert_eq!(s.fetch_ahead_depth(), 1, "depth decays once faults stop");
        assert_eq!(s.stats().fetch_ahead_hits, 64);
    }

    /// The depth cap comes from the policy, and a zero cap degrades to 1
    /// rather than disabling the speculative FP-buffer restore.
    #[test]
    fn fetch_depth_respects_configured_max() {
        let policy = TierPolicy { fetch_ahead_max: 3, ..TierPolicy::default() };
        let s = SpillStore::new("", 16, 0, policy).unwrap();
        for _ in 0..32 {
            s.note_restore(2, false);
        }
        assert_eq!(s.fetch_ahead_depth(), 3, "clamped at the policy cap");
        let policy = TierPolicy { fetch_ahead_max: 0, ..TierPolicy::default() };
        let s = SpillStore::new("", 16, 0, policy).unwrap();
        for _ in 0..32 {
            s.note_restore(1, false);
        }
        assert_eq!(s.fetch_ahead_depth(), 1, "cap of 0 is treated as 1");
    }

    #[test]
    fn reclaim_outcome_accessors() {
        let spilled = ReclaimOutcome::Spilled { victim: 4, pages: 3 };
        assert_eq!(spilled.pages(), 3);
        assert_eq!(spilled.victim(), Some(4));
        assert!(spilled.progressed());
        assert!(!ReclaimOutcome::Exhausted.progressed());
        assert_eq!(ReclaimOutcome::Exhausted.victim(), None);
        assert_eq!(TierTransition::Spill.name(), "spill");
        assert_eq!(TierTransition::Demote.name(), "demote");
        assert_eq!(TierTransition::Restore.name(), "restore");
    }

    /// A fault spec with a 2-fire budget on `spill_write` at 100% rate
    /// must fail the first two attempts and let the third succeed: the
    /// retry policy absorbs transient I/O without surfacing an error.
    #[test]
    fn transient_write_faults_absorbed_by_retry() {
        let s = store(0);
        s.install_fault_injector(Arc::new(
            FaultInjector::parse(7, "spill_write:1000:2").unwrap(),
        ));
        let h = s.write_page(PageKind::Quant, &[5u8; 32]).unwrap().unwrap();
        assert_eq!(s.read_page(h).unwrap().1, vec![5u8; 32]);
        let st = s.stats();
        assert_eq!(st.spill_retries, 2, "two injected failures, two retries");
        assert_eq!(st.spill_io_errors, 0, "the third attempt landed");
        assert_eq!(s.spilled_pages(), 1);
    }

    /// With the budget above the attempt cap, the write fails permanently
    /// — and the slot it reserved is handed back, not leaked.
    #[test]
    fn exhausted_write_retries_fail_without_leaking_the_slot() {
        let s = store(1);
        s.install_fault_injector(Arc::new(
            FaultInjector::parse(7, "spill_write:1000").unwrap(),
        ));
        let err = s.write_page(PageKind::Quant, &[1]).unwrap_err().to_string();
        assert!(err.contains("spill slot"), "{err}");
        assert_eq!(s.stats().spill_io_errors, 1);
        assert_eq!(s.stats().spill_retries, (SPILL_IO_ATTEMPTS - 1) as u64);
        assert_eq!(s.spilled_pages(), 0, "failed write leaks no page");
    }

    /// Transient read faults retry and succeed; the slot stays occupied
    /// throughout, so a retried restore is indistinguishable from a clean
    /// one.
    #[test]
    fn transient_read_faults_absorbed_by_retry() {
        let s = store(0);
        let h = s.write_page(PageKind::Fp, &[9u8; 16]).unwrap().unwrap();
        s.install_fault_injector(Arc::new(
            FaultInjector::parse(3, "spill_read:1000:2").unwrap(),
        ));
        assert_eq!(s.read_page(h).unwrap().1, vec![9u8; 16]);
        assert_eq!(s.stats().spill_retries, 2);
        assert_eq!(s.stats().spill_io_errors, 0);
    }

    /// Injected payload corruption must be caught by the checksum and NOT
    /// retried: the bytes at rest are wrong, so a second read would return
    /// the same garbage.
    #[test]
    fn injected_corruption_fails_checksum_without_retry() {
        let s = store(0);
        let h = s.write_page(PageKind::Quant, &[3u8; 64]).unwrap().unwrap();
        s.install_fault_injector(Arc::new(
            FaultInjector::parse(5, "spill_corrupt:1000:1").unwrap(),
        ));
        let err = s.read_page(h).unwrap_err().to_string();
        assert!(err.contains("checksum"), "{err}");
        let st = s.stats();
        assert_eq!(st.spill_io_errors, 1);
        assert_eq!(st.spill_retries, 0, "corruption is non-retryable");
        // budget spent: the page is still intact on disk and re-readable
        assert_eq!(s.read_page(h).unwrap().1, vec![3u8; 64]);
    }

    #[test]
    fn spill_file_is_unlinked_on_drop() {
        let s = store(0);
        let path = s.path().to_path_buf();
        s.write_page(PageKind::Quant, &[1, 2]).unwrap().unwrap();
        assert!(path.exists());
        drop(s);
        assert!(!path.exists());
    }
}
