//! Session lifecycle over the sharded page arena: admission reservations,
//! tiered page reclamation (spill → hibernate → evict), and pool-pressure
//! accounting.
//!
//! # Reclamation (the tier escalation)
//!
//! Under pressure the manager's [`SessionManager::reclaim`] frees pages in
//! escalating severity, returning a typed
//! [`ReclaimOutcome`](super::tier::ReclaimOutcome):
//!
//! 1. **Spill** — park the LRU victim's written quantized pages in the
//!    cold tier (page-granular; the victim's KV survives and faults back
//!    bit-identically on its next touch);
//! 2. **Hibernate** — move the LRU victim's entire shard cold (FP buffers
//!    included); the session resumes without re-prefill;
//! 3. **Evict** — the destructive pre-tier fallback: retire the LRU
//!    *preemptable* session outright.
//!
//! Victim selection always skips shards mid-spill/restore
//! (`SessionShard::in_transition`) and the session the reclaim is on
//! behalf of. With tiering disabled (`PoolConfig::spill_pages == 0`) the
//! first two rungs vanish and behavior is exactly the old LRU eviction.
//!
//! # The sharded-locking contract
//!
//! The manager mutex (`SharedSessionManager`) is a **control-plane** lock.
//! It is taken at:
//!
//! * **admit** — watermark admission + creating the session's
//!   [`SessionShard`];
//! * **release / evict** — retiring a shard and reclaiming its pages;
//! * **alloc fallback** — when the arena is full (LRU eviction might
//!   free pages) or a session outgrows its admission reservation (the
//!   common-case allocation — within the reservation, arena not full —
//!   is a lock-free CAS on the arena plus the session's own shard lock);
//! * once-per-round bookkeeping from an embedded step batcher
//!   (`note_prefill_deferrals`, `note_round`) and `/stats` snapshots.
//!
//! Steady-state draft/verify/commit cycles NEVER take this lock: page data
//! lives in per-session [`SessionShard`]s (their own mutexes), the global
//! page budget and cache-traffic counters are atomics on
//! [`PagePool`], and flush-time page allocation goes through the arena's
//! CAS. That is what lets `StepBatcher` rounds step N sessions on N
//! workers at N-core throughput (`rust/src/coordinator/batcher.rs`).
//!
//! Lock order: manager mutex → shard mutex (admission/eviction/release may
//! hold both); a shard mutex is never held while taking the manager mutex.
//!
//! Admission works on *committed* pages: for every live session the manager
//! counts `max(reserved, allocated)` so a freshly admitted request holds its
//! cost-model reservation before it touches a page, and a session that
//! outgrew its estimate is counted at its real footprint (`allocated` is
//! the shard's lock-free live-page mirror). A new reservation is admitted
//! only if committed pages stay at or below the high watermark; when they
//! would not, preemptable sessions (idle prefix caches, paused
//! generations) are LRU-evicted down toward the low watermark first.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Result};

use crate::cache::MemoryReport;
use crate::util::fault::FaultInjector;
use crate::util::json::Json;
use crate::util::threadpool::{PoolHandle, ThreadPool};

use super::page::{PageHandle, PageKind, PagePool, PoolConfig, SessionId, SessionShard};
use super::tier::{ReclaimOutcome, SpillStore, TierPolicy, TierStats};

pub use super::page::CacheTraffic;

/// Consecutive spill-rung I/O failures that open the tiering circuit
/// breaker: past this streak the reclaim ladder stops attempting cold-tier
/// writes (each of which burns its full retry budget against a dead disk)
/// and degrades straight to LRU eviction.
const SPILL_FAIL_STREAK_LIMIT: u32 = 3;

/// While degraded, every Nth reclaim pass lets one spill attempt through
/// as a recovery probe; a probe that spills successfully closes the
/// breaker and restores the full ladder.
const DEGRADED_PROBE_PERIOD: u64 = 16;

/// Per-round wall-time split reported by an embedded step batcher: how much
/// of the round went to prefill chunks vs decode cycles, plus the time
/// sessions sat deferred behind quant-pool backpressure (sessions × round
/// span). Accumulated by [`SessionManager::note_round`] and surfaced in
/// `/stats` as `round_prefill_us` / `round_decode_us` / `round_quant_wait_us`.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct RoundPhases {
    /// Wall time spent inside prefill steps this round (µs, summed over
    /// sessions — can exceed the round span when workers run in parallel).
    pub prefill_us: f64,
    /// Wall time spent inside decode (draft/verify) steps this round (µs).
    pub decode_us: f64,
    /// Deferred-session wait attributed to quant-pool backpressure (µs).
    pub quant_wait_us: f64,
}

/// Outcome of an admission attempt.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmitOutcome {
    /// Reservation booked; the session may allocate.
    Admitted,
    /// Over the watermark right now and nothing evictable — retry after a
    /// release, or shed.
    Saturated,
    /// The reservation alone exceeds the watermarked pool; it can never be
    /// admitted. Fail the request cleanly (never OOM).
    TooLarge,
}

/// One coherent snapshot of every pool statistic, taken under a single
/// manager-lock acquisition by [`SessionManager::snapshot`]. The router's
/// gauge sync, the `/stats` handler, and the benches consume this struct
/// instead of calling a dozen one-off getters (one lock per scrape).
#[derive(Debug, Clone, Copy, Default)]
pub struct PoolSnapshot {
    pub pages_capacity: usize,
    pub pages_in_use: usize,
    pub pages_peak: usize,
    pub pages_committed: usize,
    pub pressure: f64,
    pub high_watermark: f64,
    pub low_watermark: f64,
    /// Admission ceiling in pages (capacity × high_watermark).
    pub high_pages: usize,
    pub sessions_active: usize,
    pub evictions: u64,
    pub cancellations: u64,
    pub prefill_deferrals: u64,
    pub cache_bytes_host: usize,
    pub cache_bytes_logical: usize,
    pub traffic: CacheTraffic,
    /// (workers, jobs executed, queue depth) of the shared quant pool.
    pub quant_workers: usize,
    pub quant_jobs: u64,
    pub quant_queue_depth: usize,
    pub step_workers: usize,
    pub step_workers_busy: usize,
    pub round_span_us: f64,
    pub rounds: u64,
    pub round_phases: RoundPhases,
    // ---- tier block -----------------------------------------------------
    /// Resident full-precision pages (hot tier).
    pub tier_hot_pages: usize,
    /// Resident quantized pages (warm tier).
    pub tier_warm_pages: usize,
    /// Cold-tier counters (all zero when tiering is off).
    pub tier: TierStats,
    /// Sessions whose every page is cold right now.
    pub hibernated_sessions: usize,
    /// Whether a `SpillStore` is attached (`PoolConfig::spill_pages > 0`).
    pub tiering_enabled: bool,
    /// Whether the tiering circuit breaker is open (reclaim degraded to
    /// evict-only after repeated cold-tier I/O failures).
    pub tier_degraded: bool,
}

struct SessionEntry {
    reserved: usize,
    preemptable: bool,
    evicted: bool,
    last_touch: u64,
    /// Wall-clock of the last touch, for the idle-hibernation sweep (the
    /// logical `last_touch` clock orders LRU decisions; this one answers
    /// "idle for how long?").
    touched_at: Instant,
    shard: Arc<SessionShard>,
}

/// Admission/eviction broker between sessions and the shared arena.
/// Also owns the ONE process-wide quantization thread pool (sized by
/// `PoolConfig::quant_workers`): sessions clone a [`PoolHandle`] out at
/// cache construction and fan bulk prefill quantization over the shared
/// workers — no per-prefill thread spawning, and submits never hold the
/// manager mutex.
pub struct SessionManager {
    arena: Arc<PagePool>,
    /// The cold tier (None when `PoolConfig::spill_pages == 0`): every
    /// shard admitted by this manager spills into / faults from it.
    spill: Option<Arc<SpillStore>>,
    /// The shared quantization pool; handles are cloned out per session.
    quant: ThreadPool,
    sessions: BTreeMap<SessionId, SessionEntry>,
    clock: u64,
    evictions: u64,
    /// Prefill chunks deferred by quant-pool backpressure (recorded by
    /// `coordinator::batcher::QuantBackpressure`, surfaced in `/stats`).
    prefill_deferrals: u64,
    /// Requests evicted mid-flight (client cancellation or deadline
    /// expiry) whose pages were released back to the pool.
    cancellations: u64,
    // ---- tiering circuit breaker ---------------------------------------
    /// Consecutive spill-rung I/O failures (reset by any successful spill).
    spill_fail_streak: u32,
    /// Breaker state: when open, `reclaim` skips the lossless spill rungs
    /// and degrades straight to eviction (admissions keep succeeding).
    degraded: bool,
    /// Reclaim passes taken while degraded, for the periodic recovery
    /// probe (every [`DEGRADED_PROBE_PERIOD`]th pass retries one spill).
    degraded_probes: u64,
    // ---- round-parallelism telemetry (embedded step batchers) ----------
    rounds: u64,
    round_span_us: f64,
    step_workers: usize,
    step_workers_busy: usize,
    /// Cumulative per-phase round time (see [`RoundPhases`]).
    round_prefill_us: f64,
    round_decode_us: f64,
    round_quant_wait_us: f64,
}

/// The coordinator and paged caches share the manager behind one mutex.
pub type SharedSessionManager = Arc<Mutex<SessionManager>>;

pub fn shared(cfg: PoolConfig) -> Result<SharedSessionManager> {
    Ok(Arc::new(Mutex::new(SessionManager::new(cfg)?)))
}

impl SessionManager {
    pub fn new(cfg: PoolConfig) -> Result<SessionManager> {
        ensure!(
            cfg.quant_workers >= 1,
            "pool.quant_workers must be >= 1 (the shared quantization pool \
             needs at least one worker; use 1 for serial quantization)"
        );
        let quant = ThreadPool::named(cfg.quant_workers, "qs-quant");
        let spill = if cfg.spill_pages > 0 {
            let policy = TierPolicy {
                fetch_ahead: cfg.fetch_ahead,
                fetch_ahead_max: cfg.fetch_ahead_max,
                ..TierPolicy::default()
            };
            Some(SpillStore::new(
                &cfg.spill_dir,
                cfg.elems(),
                cfg.spill_pages,
                policy,
            )?)
        } else {
            None
        };
        Ok(SessionManager {
            arena: Arc::new(PagePool::new(cfg)),
            spill,
            quant,
            sessions: BTreeMap::new(),
            clock: 0,
            evictions: 0,
            prefill_deferrals: 0,
            cancellations: 0,
            spill_fail_streak: 0,
            degraded: false,
            degraded_probes: 0,
            rounds: 0,
            round_span_us: 0.0,
            step_workers: 0,
            step_workers_busy: 0,
            round_prefill_us: 0.0,
            round_decode_us: 0.0,
            round_quant_wait_us: 0.0,
        })
    }

    pub fn pool(&self) -> &PagePool {
        &self.arena
    }

    /// A `Sync`, cloneable handle onto the process-wide quantization pool.
    pub fn quant_handle(&self) -> PoolHandle {
        self.quant.handle()
    }

    /// (workers, jobs executed, queue depth) of the shared quantization
    /// pool — the `/stats` gauges proving one pool serves every session.
    pub fn quant_pool_stats(&self) -> (usize, u64, usize) {
        (
            self.quant.size(),
            self.quant.jobs_executed() as u64,
            self.quant.queue_depth(),
        )
    }

    pub fn evictions(&self) -> u64 {
        self.evictions
    }

    /// Record `n` prefill chunks deferred under quant-pool backpressure
    /// (the batcher batches a whole round's deferrals into one call).
    pub fn note_prefill_deferrals(&mut self, n: u64) {
        self.prefill_deferrals += n;
    }

    /// Prefill chunks deferred by quant-pool backpressure so far.
    pub fn prefill_deferrals(&self) -> u64 {
        self.prefill_deferrals
    }

    /// Record one mid-flight eviction (cancellation / deadline expiry).
    /// The caller releases the pages via [`SessionManager::release`]; this
    /// only keeps the `/stats` count.
    pub fn note_cancellation(&mut self) {
        self.cancellations += 1;
    }

    /// Requests evicted mid-flight so far (cancel + deadline).
    pub fn cancellations(&self) -> u64 {
        self.cancellations
    }

    /// Once-per-round telemetry from an embedded [`crate::coordinator::
    /// batcher::StepBatcher`]: the round's wall span, how many step
    /// workers ran sessions concurrently, the configured worker count,
    /// and the round's phase split (accumulated as cumulative totals).
    /// One manager-lock acquisition per ROUND (control plane) — the steps
    /// themselves never touch this lock.
    pub fn note_round(
        &mut self,
        span_us: f64,
        busy: usize,
        workers: usize,
        phases: RoundPhases,
    ) {
        self.rounds += 1;
        self.round_span_us = span_us;
        self.step_workers_busy = busy;
        self.step_workers = workers;
        self.round_prefill_us += phases.prefill_us;
        self.round_decode_us += phases.decode_us;
        self.round_quant_wait_us += phases.quant_wait_us;
    }

    /// Cumulative round phase totals accumulated by
    /// [`SessionManager::note_round`].
    pub fn round_phase_totals(&self) -> RoundPhases {
        RoundPhases {
            prefill_us: self.round_prefill_us,
            decode_us: self.round_decode_us,
            quant_wait_us: self.round_quant_wait_us,
        }
    }

    /// Batcher rounds recorded via [`SessionManager::note_round`].
    pub fn rounds(&self) -> u64 {
        self.rounds
    }

    /// Cumulative quantized-cache read traffic (draft vs target path).
    pub fn traffic(&self) -> CacheTraffic {
        self.arena.traffic()
    }

    pub fn active_sessions(&self) -> usize {
        self.sessions.values().filter(|s| !s.evicted).count()
    }

    /// Pages the pool is on the hook for: live pages plus unfilled
    /// reservations (shard live counts are lock-free mirrors).
    pub fn committed_pages(&self) -> usize {
        self.sessions
            .values()
            .filter(|s| !s.evicted)
            .map(|s| s.reserved.max(s.shard.live_pages()))
            .sum()
    }

    fn watermark_pages(&self, frac: f64) -> usize {
        ((self.arena.capacity() as f64) * frac).floor() as usize
    }

    pub fn high_pages(&self) -> usize {
        self.watermark_pages(self.arena.cfg().high_watermark)
    }

    /// Admission control: book `pages` for a new session, evicting idle
    /// preemptable sessions if that is what it takes.
    pub fn admit(
        &mut self,
        id: SessionId,
        pages: usize,
        preemptable: bool,
    ) -> Result<AdmitOutcome> {
        ensure!(
            !self.sessions.contains_key(&id),
            "session {id} already admitted"
        );
        let high = self.high_pages();
        if pages > high {
            return Ok(AdmitOutcome::TooLarge);
        }
        // Over the ceiling: reclaim down toward the low watermark
        // (hysteresis) to make room — page-granular spilling first,
        // destructive eviction only as the last rung.
        if self.committed_pages() + pages > high {
            let low = self.watermark_pages(self.arena.cfg().low_watermark);
            while self.committed_pages() + pages > low {
                if !self.reclaim(None).progressed() {
                    break;
                }
            }
        }
        if self.committed_pages() + pages > high {
            return Ok(AdmitOutcome::Saturated);
        }
        self.clock += 1;
        let shard = Arc::new(SessionShard::with_spill(
            id,
            Arc::clone(&self.arena),
            pages,
            self.spill.clone(),
        ));
        self.sessions.insert(
            id,
            SessionEntry {
                reserved: pages,
                preemptable,
                evicted: false,
                last_touch: self.clock,
                touched_at: Instant::now(),
                shard,
            },
        );
        Ok(AdmitOutcome::Admitted)
    }

    /// The admitted session's shard — the handle a `PagedKvCache` runs its
    /// whole data plane through (one clone at construction, no manager
    /// lock afterwards).
    pub fn shard(&self, id: SessionId) -> Result<Arc<SessionShard>> {
        match self.sessions.get(&id) {
            None => bail!("session {id} not admitted"),
            Some(s) if s.evicted => bail!("session {id} was evicted"),
            Some(s) => Ok(Arc::clone(&s.shard)),
        }
    }

    /// Free every page a session owns and forget it. Idempotent: releasing
    /// an unknown session is a no-op (returns 0).
    pub fn release(&mut self, id: SessionId) -> usize {
        match self.sessions.remove(&id) {
            Some(e) => e.shard.retire(),
            None => 0,
        }
    }

    /// LRU-touch: marks the session recently used (reclaim order).
    pub fn touch(&mut self, id: SessionId) {
        self.clock += 1;
        let clock = self.clock;
        if let Some(s) = self.sessions.get_mut(&id) {
            s.last_touch = clock;
            s.touched_at = Instant::now();
        }
    }

    pub fn set_preemptable(&mut self, id: SessionId, preemptable: bool) {
        if let Some(s) = self.sessions.get_mut(&id) {
            s.preemptable = preemptable;
        }
    }

    pub fn is_evicted(&self, id: SessionId) -> bool {
        self.sessions.get(&id).map(|s| s.evicted).unwrap_or(false)
    }

    /// LRU victim candidates for one reclaim rung, least recent first.
    /// Mid-spill/restore shards are skipped everywhere: tearing one down
    /// (or spilling under it) would race the transition's install step.
    fn lru_victims(
        &self,
        exclude: Option<SessionId>,
        preemptable_only: bool,
    ) -> Vec<SessionId> {
        let mut v: Vec<(u64, SessionId)> = self
            .sessions
            .iter()
            .filter(|(id, s)| {
                (!preemptable_only || s.preemptable)
                    && !s.evicted
                    && !s.shard.in_transition()
                    && s.shard.live_pages() > 0
                    && Some(**id) != exclude
            })
            .map(|(id, s)| (s.last_touch, *id))
            .collect();
        v.sort_unstable();
        v.into_iter().map(|(_, id)| id).collect()
    }

    /// Evict the least-recently-touched preemptable session (drop its
    /// pages; the session must re-prefill if resumed). Returns the victim.
    /// Destructive — callers go through [`SessionManager::reclaim`], which
    /// only lands here after the spill and hibernate rungs free nothing.
    fn evict_lru(&mut self, exclude: Option<SessionId>) -> Option<(SessionId, usize)> {
        let victim = self.lru_victims(exclude, true).into_iter().next()?;
        let entry = self.sessions.get_mut(&victim).expect("victim exists");
        let pages = entry.shard.retire();
        entry.reserved = 0;
        entry.evicted = true;
        self.evictions += 1;
        crate::trace::emit(crate::trace::PhaseEvent::EvictLru { victim });
        Some((victim, pages))
    }

    /// Free arena pages under pressure, least destructively first. One
    /// call works one rung on one victim; callers loop while
    /// [`ReclaimOutcome::progressed`] and the shortage persists. This is
    /// the typed replacement for the old `evict_lru(exclude) ->
    /// Option<SessionId>` first-resort surface: with tiering enabled,
    /// eviction is the *fallback*, not the policy.
    pub fn reclaim(&mut self, exclude: Option<SessionId>) -> ReclaimOutcome {
        if self.spill.is_some() && self.spill_rungs_open() {
            let store = self.spill.clone().expect("checked above");
            let batch = store.policy().max_spill_batch;
            // Rung 1 — page-granular spill of written quantized pages.
            // Any session qualifies (the move is lossless); LRU order
            // keeps actively-decoding sessions at the back of the line.
            for victim in self.lru_victims(exclude, false) {
                let shard = Arc::clone(&self.sessions[&victim].shard);
                let t0 = Instant::now();
                match shard.spill_quant_pages(batch) {
                    Ok(pages) if pages > 0 => {
                        self.note_spill_ok();
                        self.note_spilled(victim, pages, t0);
                        return ReclaimOutcome::Spilled { victim, pages };
                    }
                    Ok(_) => continue,
                    // An I/O error on one victim must not wedge reclaim;
                    // count it toward the circuit breaker and try the
                    // next rung / victim instead.
                    Err(_) => {
                        self.note_spill_failure();
                        continue;
                    }
                }
            }
            // Rung 2 — hibernate the LRU victim's whole shard (FP buffers
            // included). Still lossless: the session resumes without
            // re-prefill.
            if store.policy().hibernate_on_pressure {
                for victim in self.lru_victims(exclude, false) {
                    let shard = Arc::clone(&self.sessions[&victim].shard);
                    let t0 = Instant::now();
                    match shard.spill_all() {
                        Ok(pages) if pages > 0 => {
                            store.note_hibernation();
                            self.note_spill_ok();
                            self.note_spilled(victim, pages, t0);
                            return ReclaimOutcome::Hibernated { victim, pages };
                        }
                        Ok(_) => continue,
                        Err(_) => {
                            self.note_spill_failure();
                            continue;
                        }
                    }
                }
            }
        }
        // Rung 3 — destructive fallback (and the whole ladder while the
        // circuit breaker is open).
        match self.evict_lru(exclude) {
            Some((victim, pages)) => ReclaimOutcome::Evicted { victim, pages },
            None => ReclaimOutcome::Exhausted,
        }
    }

    /// Whether this reclaim pass may attempt the lossless spill rungs.
    /// Healthy: always. Degraded: only every [`DEGRADED_PROBE_PERIOD`]th
    /// pass, as a recovery probe — if the probe's spill succeeds,
    /// [`SessionManager::note_spill_ok`] closes the breaker.
    fn spill_rungs_open(&mut self) -> bool {
        if !self.degraded {
            return true;
        }
        self.degraded_probes += 1;
        self.degraded_probes % DEGRADED_PROBE_PERIOD == 0
    }

    /// A spill rung moved pages: the cold tier is healthy. Reset the
    /// failure streak and close the breaker if it was open.
    fn note_spill_ok(&mut self) {
        self.spill_fail_streak = 0;
        if self.degraded {
            self.degraded = false;
            self.degraded_probes = 0;
        }
    }

    /// A spill rung failed with an I/O error (after the store's own
    /// bounded retries). Enough consecutive failures open the breaker.
    fn note_spill_failure(&mut self) {
        self.spill_fail_streak = self.spill_fail_streak.saturating_add(1);
        if self.spill_fail_streak >= SPILL_FAIL_STREAK_LIMIT && !self.degraded {
            self.degraded = true;
            self.degraded_probes = 0;
        }
    }

    /// Whether the tiering circuit breaker is currently open (reclaim
    /// degraded to evict-only). The `tier_degraded` gauge.
    pub fn tier_degraded(&self) -> bool {
        self.degraded
    }

    /// Arm the cold tier's deterministic fault hooks (no-op when tiering
    /// is off). Chaos tests and the bench soak route their injector
    /// through here so spill I/O faults fire on schedule.
    pub fn set_fault_injector(&self, inj: Arc<FaultInjector>) {
        if let Some(store) = &self.spill {
            store.install_fault_injector(inj);
        }
    }

    /// Shared bookkeeping for the two lossless rungs: shrink the victim's
    /// reservation to its post-spill residency so `committed_pages` drops
    /// (spilled pages must stop counting against admission), and leave a
    /// `spill` trace event.
    fn note_spilled(&mut self, victim: SessionId, pages: usize, t0: Instant) {
        let entry = self.sessions.get_mut(&victim).expect("victim exists");
        entry.reserved = entry.reserved.min(entry.shard.live_pages());
        crate::trace::emit(crate::trace::PhaseEvent::Spill {
            session: victim,
            pages,
            us: t0.elapsed().as_micros() as u64,
        });
    }

    /// Hibernate one session explicitly: move its entire shard to the
    /// cold tier. Used by the scheduler's idle sweep; a no-op (Ok(0))
    /// when tiering is off, the session is unknown/evicted/mid-transition,
    /// or it holds no resident pages.
    pub fn hibernate(&mut self, id: SessionId) -> Result<usize> {
        let Some(store) = self.spill.clone() else { return Ok(0) };
        let shard = match self.sessions.get(&id) {
            Some(s) if !s.evicted && !s.shard.in_transition() => Arc::clone(&s.shard),
            _ => return Ok(0),
        };
        let t0 = Instant::now();
        let pages = shard.spill_all()?;
        if pages > 0 {
            store.note_hibernation();
            self.note_spilled(id, pages, t0);
        }
        Ok(pages)
    }

    /// Idle sweep: hibernate every session untouched for at least
    /// `max_idle` (the scheduler calls this once per loop tick when
    /// `hibernate_idle_ms` > 0). Returns sessions hibernated.
    pub fn hibernate_idle(&mut self, max_idle: Duration) -> usize {
        if self.spill.is_none() {
            return 0;
        }
        let idle: Vec<SessionId> = self
            .sessions
            .iter()
            .filter(|(_, s)| {
                !s.evicted
                    && !s.shard.in_transition()
                    && s.shard.live_pages() > 0
                    && s.touched_at.elapsed() >= max_idle
            })
            .map(|(id, _)| *id)
            .collect();
        let mut hibernated = 0usize;
        for id in idle {
            if matches!(self.hibernate(id), Ok(n) if n > 0) {
                hibernated += 1;
            }
        }
        hibernated
    }

    /// Sessions currently fully cold (every page spilled, none resident) —
    /// the `hibernated_sessions` gauge. Self-clearing: a fault-back makes
    /// the session warm again without manager involvement.
    pub fn hibernated_sessions(&self) -> usize {
        self.sessions
            .values()
            .filter(|s| {
                !s.evicted && s.shard.live_pages() == 0 && s.shard.spilled_pages() > 0
            })
            .count()
    }

    /// The cold tier, when tiering is enabled.
    pub fn spill_store(&self) -> Option<&Arc<SpillStore>> {
        self.spill.as_ref()
    }

    /// Cold-tier counters (zeros when tiering is off).
    pub fn tier_stats(&self) -> TierStats {
        self.spill.as_ref().map(|s| s.stats()).unwrap_or_default()
    }

    /// Allocate one page for a session, evicting preemptable sessions if
    /// the arena itself is full. This is the manager-locked SLOW path; the
    /// data plane first tries `SessionShard::try_alloc` (lock-free budget
    /// CAS, bounded by the admission reservation) and only lands here when
    /// the arena is full or the session outgrows its reservation — holding
    /// the manager mutex here is what keeps `committed_pages` consistent
    /// with concurrent watermark admissions while `live` crosses
    /// `reserved`.
    pub fn alloc(&mut self, id: SessionId, kind: PageKind) -> Result<PageHandle> {
        let shard = match self.sessions.get(&id) {
            None => bail!("session {id} not admitted"),
            Some(s) if s.evicted => bail!("session {id} was evicted"),
            Some(s) => Arc::clone(&s.shard),
        };
        loop {
            if let Some(h) = shard.alloc_locked(kind)? {
                return Ok(h);
            }
            if !self.reclaim(Some(id)).progressed() {
                bail!(
                    "pool exhausted and nothing reclaimable \
                     ({} pages, session {id})",
                    self.arena.capacity()
                );
            }
        }
    }

    pub fn free(&mut self, id: SessionId, h: PageHandle) -> Result<()> {
        let shard = self.shard(id)?;
        shard.free(h)?;
        Ok(())
    }

    // ---- reporting ------------------------------------------------------

    /// Pool-wide cache memory in both conventions (weights are not pooled).
    pub fn memory_report(&self) -> MemoryReport {
        MemoryReport {
            weights_logical: 0,
            weights_host: 0,
            cache_logical: self.arena.logical_bytes(),
            cache_host: self.arena.host_bytes(),
        }
    }

    /// Every pool statistic in one pass — THE read surface for the
    /// router's gauge sync, `/stats`, and the benches (one manager-lock
    /// acquisition per scrape instead of a dozen getter calls).
    pub fn snapshot(&self) -> PoolSnapshot {
        let (quant_workers, quant_jobs, quant_queue_depth) = self.quant_pool_stats();
        PoolSnapshot {
            pages_capacity: self.arena.capacity(),
            pages_in_use: self.arena.pages_in_use(),
            pages_peak: self.arena.peak_pages_in_use(),
            pages_committed: self.committed_pages(),
            pressure: self.arena.pressure(),
            high_watermark: self.arena.cfg().high_watermark,
            low_watermark: self.arena.cfg().low_watermark,
            high_pages: self.high_pages(),
            sessions_active: self.active_sessions(),
            evictions: self.evictions,
            cancellations: self.cancellations,
            prefill_deferrals: self.prefill_deferrals,
            cache_bytes_host: self.arena.host_bytes(),
            cache_bytes_logical: self.arena.logical_bytes(),
            traffic: self.traffic(),
            quant_workers,
            quant_jobs,
            quant_queue_depth,
            step_workers: self.step_workers,
            step_workers_busy: self.step_workers_busy,
            round_span_us: self.round_span_us,
            rounds: self.rounds,
            round_phases: self.round_phase_totals(),
            tier_hot_pages: self.arena.pages_fp(),
            tier_warm_pages: self.arena.pages_quant(),
            tier: self.tier_stats(),
            hibernated_sessions: self.hibernated_sessions(),
            tiering_enabled: self.spill.is_some(),
            tier_degraded: self.degraded,
        }
    }

    /// Snapshot for `/stats` and the benches.
    pub fn stats_json(&self) -> Json {
        let s = self.snapshot();
        Json::obj(vec![
            ("pages_capacity", Json::num(s.pages_capacity as f64)),
            ("pages_in_use", Json::num(s.pages_in_use as f64)),
            ("pages_peak", Json::num(s.pages_peak as f64)),
            ("pages_committed", Json::num(s.pages_committed as f64)),
            ("pressure", Json::num(s.pressure)),
            ("high_watermark", Json::num(s.high_watermark)),
            ("low_watermark", Json::num(s.low_watermark)),
            ("sessions_active", Json::num(s.sessions_active as f64)),
            ("evictions", Json::num(s.evictions as f64)),
            ("cancellations", Json::num(s.cancellations as f64)),
            ("cache_bytes_host", Json::num(s.cache_bytes_host as f64)),
            (
                "cache_bytes_logical",
                Json::num(s.cache_bytes_logical as f64),
            ),
            (
                crate::metrics::names::DEQUANT_CALLS_DRAFT,
                Json::num(s.traffic.dequant_calls_draft as f64),
            ),
            (
                crate::metrics::names::DEQUANT_CALLS_TARGET,
                Json::num(s.traffic.dequant_calls_target as f64),
            ),
            (
                crate::metrics::names::QUANT_BYTES_READ_DRAFT,
                Json::num(s.traffic.bytes_read_draft as f64),
            ),
            (
                crate::metrics::names::QUANT_BYTES_READ_TARGET,
                Json::num(s.traffic.bytes_read_target as f64),
            ),
            (
                crate::metrics::names::QUANT_POOL_WORKERS,
                Json::num(s.quant_workers as f64),
            ),
            (
                crate::metrics::names::QUANT_POOL_JOBS,
                Json::num(s.quant_jobs as f64),
            ),
            (
                crate::metrics::names::QUANT_POOL_QUEUE_DEPTH,
                Json::num(s.quant_queue_depth as f64),
            ),
            (
                crate::metrics::names::PREFILL_DEFERRALS,
                Json::num(s.prefill_deferrals as f64),
            ),
            (
                crate::metrics::names::STEP_WORKERS,
                Json::num(s.step_workers as f64),
            ),
            (
                crate::metrics::names::STEP_WORKERS_BUSY,
                Json::num(s.step_workers_busy as f64),
            ),
            (crate::metrics::names::ROUND_SPAN_US, Json::num(s.round_span_us)),
            (
                crate::metrics::names::BATCHER_ROUNDS,
                Json::num(s.rounds as f64),
            ),
            (
                crate::metrics::names::ROUND_PREFILL_US,
                Json::num(s.round_phases.prefill_us),
            ),
            (
                crate::metrics::names::ROUND_DECODE_US,
                Json::num(s.round_phases.decode_us),
            ),
            (
                crate::metrics::names::ROUND_QUANT_WAIT_US,
                Json::num(s.round_phases.quant_wait_us),
            ),
            (
                "tier",
                Json::obj(vec![
                    ("enabled", Json::Bool(s.tiering_enabled)),
                    (
                        crate::metrics::names::TIER_HOT_PAGES,
                        Json::num(s.tier_hot_pages as f64),
                    ),
                    (
                        crate::metrics::names::TIER_WARM_PAGES,
                        Json::num(s.tier_warm_pages as f64),
                    ),
                    (
                        crate::metrics::names::TIER_SPILLED_PAGES,
                        Json::num(s.tier.spilled_pages as f64),
                    ),
                    (
                        crate::metrics::names::SPILL_BYTES_WRITTEN,
                        Json::num(s.tier.spill_bytes_written as f64),
                    ),
                    ("spill_bytes_read", Json::num(s.tier.spill_bytes_read as f64)),
                    (
                        crate::metrics::names::RESTORE_FAULTS,
                        Json::num(s.tier.restore_faults as f64),
                    ),
                    (
                        crate::metrics::names::FETCH_AHEAD_HITS,
                        Json::num(s.tier.fetch_ahead_hits as f64),
                    ),
                    ("demotions", Json::num(s.tier.demotions as f64)),
                    (
                        crate::metrics::names::SPILL_RETRIES,
                        Json::num(s.tier.spill_retries as f64),
                    ),
                    (
                        crate::metrics::names::SPILL_IO_ERRORS,
                        Json::num(s.tier.spill_io_errors as f64),
                    ),
                    (
                        crate::metrics::names::TIER_DEGRADED,
                        Json::Bool(s.tier_degraded),
                    ),
                    (
                        crate::metrics::names::SESSIONS_HIBERNATED_TOTAL,
                        Json::num(s.tier.hibernations as f64),
                    ),
                    (
                        crate::metrics::names::HIBERNATED_SESSIONS,
                        Json::num(s.hibernated_sessions as f64),
                    ),
                ]),
            ),
        ])
    }

    /// Cross-check session accounting against the arena.
    pub fn check_integrity(&self) -> Result<()> {
        let total: usize = self.sessions.values().map(|s| s.shard.live_pages()).sum();
        ensure!(
            total == self.arena.pages_in_use(),
            "session accounting {} != pool in-use {}",
            total,
            self.arena.pages_in_use()
        );
        for s in self.sessions.values() {
            s.shard.check_integrity()?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mgr(pages: usize) -> SessionManager {
        SessionManager::new(PoolConfig {
            pages,
            page_tokens: 4,
            kv_dim: 2,
            high_watermark: 0.9,
            low_watermark: 0.6,
            ..PoolConfig::default()
        })
        .unwrap()
    }

    #[test]
    fn zero_quant_workers_is_an_error_not_a_clamp() {
        let err = SessionManager::new(PoolConfig {
            quant_workers: 0,
            ..PoolConfig::default()
        })
        .unwrap_err()
        .to_string();
        assert!(err.contains("quant_workers"), "got: {err}");
    }

    #[test]
    fn admission_watermark() {
        let mut m = mgr(10); // high watermark: 9 pages
        assert_eq!(m.admit(1, 5, false).unwrap(), AdmitOutcome::Admitted);
        assert_eq!(m.admit(2, 4, false).unwrap(), AdmitOutcome::Admitted);
        // 9 committed; one more page would cross the ceiling
        assert_eq!(m.admit(3, 1, false).unwrap(), AdmitOutcome::Saturated);
        assert_eq!(m.admit(4, 10, false).unwrap(), AdmitOutcome::TooLarge);
        m.release(1);
        assert_eq!(m.admit(3, 1, false).unwrap(), AdmitOutcome::Admitted);
    }

    #[test]
    fn admission_evicts_lru_preemptable() {
        // capacity 10, high 9, low 8: two 4-page idle sessions; a 2-page
        // request crosses the ceiling and must evict exactly the LRU one.
        let mut m = SessionManager::new(PoolConfig {
            pages: 10,
            page_tokens: 4,
            kv_dim: 2,
            high_watermark: 0.9,
            low_watermark: 0.8,
            ..PoolConfig::default()
        })
        .unwrap();
        m.admit(1, 4, true).unwrap();
        for _ in 0..4 {
            m.alloc(1, PageKind::Quant).unwrap();
        }
        m.admit(2, 4, true).unwrap();
        for _ in 0..4 {
            m.alloc(2, PageKind::Quant).unwrap();
        }
        m.touch(1); // session 2 becomes LRU
        assert_eq!(m.admit(3, 2, false).unwrap(), AdmitOutcome::Admitted);
        assert!(m.is_evicted(2), "LRU preemptable session evicted");
        assert!(!m.is_evicted(1));
        assert_eq!(m.evictions(), 1);
        m.check_integrity().unwrap();
    }

    #[test]
    fn alloc_requires_admission_and_detects_eviction() {
        let mut m = mgr(8);
        assert!(m.alloc(9, PageKind::Fp).is_err());
        m.admit(9, 2, true).unwrap();
        m.alloc(9, PageKind::Fp).unwrap();
        m.evict_lru(None).unwrap();
        assert!(m.alloc(9, PageKind::Fp).is_err(), "evicted session rejected");
        // the shard-level fast path rejects the evicted session too
        let shard = m.sessions.get(&9).unwrap().shard.clone();
        assert!(shard.try_alloc(PageKind::Fp).is_err());
    }

    #[test]
    fn full_pool_alloc_evicts() {
        // Watermarks at 1.0 so admission lets the arena actually fill: a
        // session that outgrows its reservation trips the alloc-path
        // eviction when the arena is full.
        let mut m = SessionManager::new(PoolConfig {
            pages: 4,
            page_tokens: 4,
            kv_dim: 2,
            high_watermark: 1.0,
            low_watermark: 1.0,
            ..PoolConfig::default()
        })
        .unwrap();
        m.admit(1, 3, true).unwrap();
        for _ in 0..3 {
            m.alloc(1, PageKind::Quant).unwrap();
        }
        m.admit(2, 1, false).unwrap();
        m.alloc(2, PageKind::Fp).unwrap();
        // arena now full; session 2's over-reservation alloc evicts 1
        m.alloc(2, PageKind::Fp).unwrap();
        assert!(m.is_evicted(1));
        m.check_integrity().unwrap();
    }

    #[test]
    fn release_is_idempotent() {
        let mut m = mgr(4);
        m.admit(5, 2, false).unwrap();
        m.alloc(5, PageKind::Fp).unwrap();
        assert_eq!(m.release(5), 1);
        assert_eq!(m.release(5), 0);
        assert_eq!(m.pool().pages_in_use(), 0);
    }

    #[test]
    fn round_telemetry_surfaces_in_stats() {
        let mut m = mgr(8);
        m.note_round(
            123.5,
            2,
            4,
            RoundPhases { prefill_us: 100.0, decode_us: 20.0, quant_wait_us: 3.5 },
        );
        m.note_round(
            80.0,
            3,
            4,
            RoundPhases { prefill_us: 0.0, decode_us: 75.0, quant_wait_us: 0.0 },
        );
        assert_eq!(m.rounds(), 2);
        let s = m.snapshot();
        assert_eq!((s.step_workers, s.step_workers_busy, s.rounds), (4, 3, 2));
        assert!((s.round_span_us - 80.0).abs() < 1e-9);
        // phase totals accumulate across rounds (cumulative counters)
        let totals = m.round_phase_totals();
        assert!((totals.prefill_us - 100.0).abs() < 1e-9);
        assert!((totals.decode_us - 95.0).abs() < 1e-9);
        assert!((totals.quant_wait_us - 3.5).abs() < 1e-9);
        let js = m.stats_json().to_string();
        for key in [
            "step_workers",
            "step_workers_busy",
            "round_span_us",
            "batcher_rounds",
            "round_prefill_us",
            "round_decode_us",
            "round_quant_wait_us",
        ] {
            assert!(js.contains(key), "missing {key} in {js}");
        }
    }

    /// A mid-flight eviction (cancel / deadline) counts in `/stats` and
    /// the released pages go back to the pool.
    #[test]
    fn cancellation_count_and_release_surface_in_stats() {
        let mut m = mgr(8);
        m.admit(1, 3, true).unwrap();
        m.alloc(1, PageKind::Quant).unwrap();
        assert_eq!(m.cancellations(), 0);
        m.note_cancellation();
        let freed = m.release(1);
        assert_eq!(freed, 1, "the allocated page came back");
        assert_eq!(m.pool().pages_in_use(), 0);
        assert_eq!(m.cancellations(), 1);
        let js = m.stats_json().to_string();
        assert!(js.contains("\"cancellations\":1"), "{js}");
        m.check_integrity().unwrap();
    }

    #[test]
    fn eviction_emits_trace_event_under_scope() {
        use crate::trace::{PhaseEvent, SpanScope, TraceBuf};
        let mut m = mgr(8);
        m.admit(1, 2, true).unwrap();
        m.alloc(1, PageKind::Quant).unwrap();
        let buf = TraceBuf::new(16);
        {
            let _scope = SpanScope::enter(Arc::clone(&buf));
            assert_eq!(m.evict_lru(None), Some((1, 1)));
        }
        let events = buf.snapshot();
        assert!(
            events
                .iter()
                .any(|(_, e)| matches!(e, PhaseEvent::EvictLru { victim: 1 })),
            "EvictLru not recorded: {events:?}"
        );
    }

    /// Property: random admit/alloc/free/touch/evict/release traffic keeps
    /// session accounting and the arena consistent, and never exceeds
    /// capacity.
    #[test]
    fn prop_manager_invariants() {
        use crate::util::prop::{check, Config};
        check::<Vec<usize>, _>(
            Config { cases: 40, size: 64, ..Config::default() },
            |ops| {
                let mut m = mgr(8);
                let mut next_id: SessionId = 0;
                let mut live: Vec<SessionId> = Vec::new();
                for &op in ops {
                    match op % 6 {
                        0 => {
                            next_id += 1;
                            if let Ok(AdmitOutcome::Admitted) =
                                m.admit(next_id, op % 4 + 1, op % 2 == 0)
                            {
                                live.push(next_id);
                            }
                        }
                        1 | 2 => {
                            if let Some(&id) = live.get(op % live.len().max(1)) {
                                let _ = m.alloc(
                                    id,
                                    if op % 2 == 0 { PageKind::Quant } else { PageKind::Fp },
                                );
                            }
                        }
                        3 => {
                            if !live.is_empty() {
                                let id = live.remove(op % live.len());
                                m.release(id);
                            }
                        }
                        4 => {
                            if let Some(&id) = live.get(op % live.len().max(1)) {
                                m.touch(id);
                            }
                        }
                        _ => {
                            m.evict_lru(None);
                        }
                    }
                    if m.pool().pages_in_use() > m.pool().capacity() {
                        return false;
                    }
                    if m.check_integrity().is_err() {
                        return false;
                    }
                }
                for id in live {
                    m.release(id);
                }
                m.pool().pages_in_use() == 0 && m.check_integrity().is_ok()
            },
        );
    }

    /// Stress (sharded accounting): concurrent sessions allocating and
    /// freeing through their own shards while a chaos thread admits,
    /// evicts, and releases through the manager lock. Under every
    /// interleaving the arena's CAS budget must hold (`peak <= capacity`),
    /// every successful admission must leave committed pages at or under
    /// the high watermark, and the final accounting must balance.
    #[test]
    fn stress_concurrent_shard_allocs_never_overcommit() {
        use std::sync::atomic::{AtomicBool, Ordering};
        use std::thread;
        let cfg = PoolConfig {
            pages: 24,
            page_tokens: 4,
            kv_dim: 2,
            high_watermark: 0.9, // ceiling: 21 pages
            low_watermark: 0.7,
            ..PoolConfig::default()
        };
        let high = 21usize;
        let m = shared(cfg).unwrap();
        let stop = Arc::new(AtomicBool::new(false));
        let mut workers = Vec::new();
        for t in 0..4u64 {
            let m = Arc::clone(&m);
            let stop = Arc::clone(&stop);
            workers.push(thread::spawn(move || {
                let mut iter = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    iter += 1;
                    let id = t * 1_000_000 + iter;
                    let reserved = 3 + (iter % 3) as usize;
                    let admitted = {
                        let mut mm = m.lock().unwrap();
                        match mm.admit(id, reserved, iter % 4 == 0) {
                            Ok(AdmitOutcome::Admitted) => {
                                // the watermark decision we just took must
                                // hold under the same lock
                                assert!(
                                    mm.committed_pages() <= high,
                                    "admission over-committed: {} > {high}",
                                    mm.committed_pages()
                                );
                                true
                            }
                            Ok(_) => false,
                            Err(e) => panic!("admit: {e}"),
                        }
                    };
                    if !admitted {
                        continue;
                    }
                    let shard = m.lock().unwrap().shard(id).unwrap();
                    // lock-free data-plane allocs within the reservation
                    let mut held = Vec::new();
                    for k in 0..reserved {
                        let kind =
                            if k % 2 == 0 { PageKind::Quant } else { PageKind::Fp };
                        match shard.try_alloc(kind) {
                            Ok(Some(h)) => held.push(h),
                            Ok(None) => break, // arena full: fine, move on
                            Err(_) => break,   // evicted under us: fine
                        }
                    }
                    for h in held {
                        // the shard may have been evicted mid-loop; a
                        // stale-handle error is the designed outcome
                        let _ = shard.free(h);
                    }
                    m.lock().unwrap().release(id);
                }
            }));
        }
        // chaos: LRU evictions racing the data plane
        {
            let m = Arc::clone(&m);
            let stop = Arc::clone(&stop);
            workers.push(thread::spawn(move || {
                while !stop.load(Ordering::Relaxed) {
                    m.lock().unwrap().evict_lru(None);
                    thread::yield_now();
                }
            }));
        }
        thread::sleep(std::time::Duration::from_millis(200));
        stop.store(true, Ordering::Relaxed);
        for w in workers {
            w.join().unwrap();
        }
        let mut mm = m.lock().unwrap();
        assert!(
            mm.pool().peak_pages_in_use() <= mm.pool().capacity(),
            "CAS budget breached: peak {} > capacity {}",
            mm.pool().peak_pages_in_use(),
            mm.pool().capacity()
        );
        // drain any sessions a worker left behind at stop time
        let leftover: Vec<SessionId> = mm.sessions.keys().copied().collect();
        for id in leftover {
            mm.release(id);
        }
        assert_eq!(mm.pool().pages_in_use(), 0, "pages leaked under stress");
        mm.check_integrity().unwrap();
    }

    // ---- tiered reclamation ---------------------------------------------

    fn tiered_mgr(pages: usize, spill_pages: usize) -> SessionManager {
        SessionManager::new(PoolConfig {
            pages,
            page_tokens: 4,
            kv_dim: 2,
            high_watermark: 0.9,
            low_watermark: 0.6,
            spill_pages,
            ..PoolConfig::default()
        })
        .unwrap()
    }

    fn write_group(m: &SessionManager, id: SessionId, h: PageHandle, seed: f32) {
        let elems = m.pool().cfg().elems();
        let xs: Vec<f32> = (0..elems).map(|i| seed + i as f32 * 0.25).collect();
        let g = crate::quant::quant_group(&xs).unwrap();
        m.shard(id).unwrap().lock().write_quant(h, g).unwrap();
    }

    #[test]
    fn reclaim_spills_before_evicting() {
        let mut m = tiered_mgr(10, 64); // high 9, low 6
        m.admit(1, 4, true).unwrap();
        let handles: Vec<PageHandle> =
            (0..4).map(|_| m.alloc(1, PageKind::Quant).unwrap()).collect();
        for (i, &h) in handles.iter().enumerate() {
            write_group(&m, 1, h, i as f32);
        }
        m.admit(2, 4, false).unwrap();
        // committed 8; admitting 2 more crosses the ceiling — the first
        // resort must be spilling session 1's pages, not evicting it
        assert_eq!(m.admit(3, 2, false).unwrap(), AdmitOutcome::Admitted);
        assert!(!m.is_evicted(1), "victim survived reclamation");
        assert_eq!(m.evictions(), 0, "no destructive eviction happened");
        assert_eq!(m.tier_stats().spilled_pages, 4);
        assert_eq!(m.hibernated_sessions(), 1, "session 1 is fully cold");
        m.check_integrity().unwrap();
        // the spilled KV faults back bit-identically — no re-prefill
        let shard = m.shard(1).unwrap();
        for &h in &handles {
            assert_eq!(
                shard.fault_page(h).unwrap(),
                crate::pool::FaultOutcome::Restored
            );
        }
        assert_eq!(m.hibernated_sessions(), 0, "gauge self-clears on resume");
        let elems = m.pool().cfg().elems();
        let want: Vec<f32> = (0..elems).map(|i| 2.0 + i as f32 * 0.25).collect();
        let g = crate::quant::quant_group(&want).unwrap();
        assert_eq!(*shard.lock().read_quant(handles[2]).unwrap(), g);
        for id in [1, 2, 3] {
            m.release(id);
        }
        assert_eq!(m.pool().pages_in_use(), 0);
        assert_eq!(m.tier_stats().spilled_pages, 0, "cold slots handed back");
    }

    #[test]
    fn reclaim_escalates_to_hibernation_for_fp_only_shards() {
        let mut m = tiered_mgr(10, 64);
        m.admit(1, 4, true).unwrap();
        for _ in 0..4 {
            m.alloc(1, PageKind::Fp).unwrap(); // no written quant pages
        }
        let out = m.reclaim(None);
        assert!(
            matches!(out, ReclaimOutcome::Hibernated { victim: 1, pages: 4 }),
            "fp-only shard hibernates, got {out:?}"
        );
        assert!(!m.is_evicted(1));
        assert_eq!(m.tier_stats().hibernations, 1);
        assert_eq!(m.hibernated_sessions(), 1);
        // everything is cold now: nothing left to spill OR evict
        assert_eq!(m.reclaim(None), ReclaimOutcome::Exhausted);
        m.check_integrity().unwrap();
    }

    #[test]
    fn reclaim_without_tiering_is_plain_lru_eviction() {
        let mut m = mgr(10); // spill_pages = 0
        m.admit(1, 2, true).unwrap();
        m.alloc(1, PageKind::Quant).unwrap();
        let out = m.reclaim(None);
        assert!(
            matches!(out, ReclaimOutcome::Evicted { victim: 1, pages: 1 }),
            "got {out:?}"
        );
        assert!(m.is_evicted(1));
        assert_eq!(m.evictions(), 1);
    }

    #[test]
    fn hibernate_idle_sweeps_untouched_sessions() {
        let mut m = tiered_mgr(10, 64);
        m.admit(1, 2, true).unwrap();
        let h = m.alloc(1, PageKind::Quant).unwrap();
        write_group(&m, 1, h, 0.0);
        m.admit(2, 2, false).unwrap(); // no pages: nothing to hibernate
        std::thread::sleep(Duration::from_millis(5));
        assert_eq!(m.hibernate_idle(Duration::from_millis(1)), 1);
        assert_eq!(m.hibernated_sessions(), 1);
        assert_eq!(m.tier_stats().hibernations, 1);
        // a freshly touched session is not swept
        let h2 = m.alloc(2, PageKind::Fp).unwrap();
        m.touch(2);
        assert_eq!(m.hibernate_idle(Duration::from_secs(3600)), 0);
        let _ = h2;
        m.check_integrity().unwrap();
    }

    #[test]
    fn snapshot_and_stats_carry_the_tier_block() {
        let mut m = tiered_mgr(10, 64);
        m.admit(1, 2, true).unwrap();
        let h = m.alloc(1, PageKind::Quant).unwrap();
        write_group(&m, 1, h, 1.0);
        m.alloc(1, PageKind::Fp).unwrap();
        let s = m.snapshot();
        assert!(s.tiering_enabled);
        assert_eq!(s.tier_hot_pages, 1);
        assert_eq!(s.tier_warm_pages, 1);
        assert_eq!(s.tier.spilled_pages, 0);
        m.hibernate(1).unwrap();
        let s = m.snapshot();
        assert_eq!((s.tier_hot_pages, s.tier_warm_pages), (0, 0));
        assert_eq!(s.tier.spilled_pages, 2);
        assert_eq!(s.hibernated_sessions, 1);
        assert!(s.tier.spill_bytes_written > 0);
        let js = m.stats_json().to_string();
        for key in [
            "\"tier\"",
            "tier_hot_pages",
            "tier_spilled_pages",
            "spill_bytes_written",
            "restore_faults",
            "fetch_ahead_hits",
            "hibernated_sessions",
            "sessions_hibernated_total",
        ] {
            assert!(js.contains(key), "missing {key} in {js}");
        }
    }

    /// Satellite bugfix pin: victim selection skips shards mid-transition,
    /// and concurrent reclaim + fault-back traffic never panics on a
    /// generation check or leaks a page or cold slot.
    #[test]
    fn stress_concurrent_reclaim_and_restore_no_leaks() {
        use std::sync::atomic::{AtomicBool, Ordering};
        use std::thread;
        let m = Arc::new(Mutex::new(tiered_mgr(16, 64)));
        let ids: Vec<SessionId> = (1..=3).collect();
        let mut setups: Vec<(Arc<SessionShard>, Vec<PageHandle>)> = Vec::new();
        {
            let mut mm = m.lock().unwrap();
            for &id in &ids {
                mm.admit(id, 4, true).unwrap();
                let handles: Vec<PageHandle> = (0..4)
                    .map(|k| {
                        let h = mm.alloc(id, PageKind::Quant).unwrap();
                        write_group(&mm, id, h, (id * 10 + k) as f32);
                        h
                    })
                    .collect();
                setups.push((mm.shard(id).unwrap(), handles));
            }
        }
        let stop = Arc::new(AtomicBool::new(false));
        let mut workers = Vec::new();
        // data planes: fault cold pages back, then spill them again —
        // constant tier churn without the manager lock. Errors (stale
        // handles after an eviction, ArenaFull) are designed outcomes;
        // a panic is the bug this test pins.
        for (shard, handles) in setups {
            let stop = Arc::clone(&stop);
            workers.push(thread::spawn(move || {
                while !stop.load(Ordering::Relaxed) {
                    for &h in &handles {
                        let _ = shard.fault_page(h);
                    }
                    let _ = shard.spill_quant_pages(0);
                    thread::yield_now();
                }
            }));
        }
        // control plane: reclaim pressure racing the spills above
        {
            let m = Arc::clone(&m);
            let stop = Arc::clone(&stop);
            workers.push(thread::spawn(move || {
                while !stop.load(Ordering::Relaxed) {
                    m.lock().unwrap().reclaim(None);
                    thread::yield_now();
                }
            }));
        }
        thread::sleep(std::time::Duration::from_millis(150));
        stop.store(true, Ordering::Relaxed);
        for w in workers {
            w.join().unwrap();
        }
        let mut mm = m.lock().unwrap();
        mm.check_integrity().unwrap();
        for &id in &ids {
            mm.release(id);
        }
        assert_eq!(mm.pool().pages_in_use(), 0, "arena pages leaked");
        assert_eq!(
            mm.tier_stats().spilled_pages,
            0,
            "cold-tier slots leaked"
        );
    }

    // ---- tiering circuit breaker ----------------------------------------

    /// With the cold tier persistently failing, the reclaim ladder opens
    /// the circuit breaker and degrades to eviction — but admissions keep
    /// succeeding and nothing leaks.
    #[test]
    fn persistent_spill_faults_open_the_breaker_but_admissions_survive() {
        let mut m = tiered_mgr(10, 64); // high 9, low 6
        m.set_fault_injector(Arc::new(
            FaultInjector::parse(5, "spill_write:1000").unwrap(),
        ));
        m.admit(1, 4, true).unwrap();
        for k in 0..4 {
            let h = m.alloc(1, PageKind::Quant).unwrap();
            write_group(&m, 1, h, k as f32);
        }
        m.admit(2, 4, false).unwrap();
        let h = m.alloc(2, PageKind::Quant).unwrap();
        write_group(&m, 2, h, 9.0);
        assert!(!m.tier_degraded());
        // committed 8; +2 crosses the ceiling. Every spill rung fails with
        // an injected I/O error, so reclaim falls through to evicting the
        // preemptable session — the admission itself still succeeds.
        assert_eq!(m.admit(3, 2, false).unwrap(), AdmitOutcome::Admitted);
        assert!(m.is_evicted(1), "degraded reclaim fell back to eviction");
        assert_eq!(m.evictions(), 1);
        assert_eq!(
            m.tier_stats().spilled_pages,
            0,
            "nothing reached the failing cold tier"
        );
        assert!(m.tier_stats().spill_io_errors > 0);
        assert!(m.tier_stats().spill_retries > 0);
        assert!(m.tier_degraded(), "breaker open after repeated failures");
        assert!(m.snapshot().tier_degraded);
        let js = m.stats_json().to_string();
        assert!(js.contains("\"tier_degraded\":true"), "{js}");
        assert!(js.contains("spill_io_errors"), "{js}");
        assert!(js.contains("spill_retries"), "{js}");
        m.check_integrity().unwrap();
    }

    /// Once the faults stop, the degraded breaker's periodic probe spills
    /// successfully and closes again — spill service resumes without any
    /// operator intervention.
    #[test]
    fn degraded_breaker_probes_and_closes_once_faults_stop() {
        let mut m = tiered_mgr(10, 64);
        // Budget 12 fires: exactly the four failed spill calls (3 write
        // attempts each) it takes to open the breaker; faults then stop.
        m.set_fault_injector(Arc::new(
            FaultInjector::parse(11, "spill_write:1000:12").unwrap(),
        ));
        for id in [1, 2] {
            m.admit(id, 2, false).unwrap();
            for k in 0..2 {
                let h = m.alloc(id, PageKind::Quant).unwrap();
                write_group(&m, id, h, (id * 10 + k as u64) as f32);
            }
        }
        // One pass: rung 1 fails on both victims, rung 2 fails on both,
        // nothing is preemptable — Exhausted, and the breaker opens.
        assert_eq!(m.reclaim(None), ReclaimOutcome::Exhausted);
        assert!(m.tier_degraded());
        // Degraded passes skip the spill rungs (no cold-tier I/O) until
        // the periodic probe lets one through; with the fault budget
        // spent, the probe spills successfully and closes the breaker.
        let mut probe_outcome = ReclaimOutcome::Exhausted;
        let mut passes = 0;
        while m.tier_degraded() {
            probe_outcome = m.reclaim(None);
            passes += 1;
            assert!(passes <= 16, "probe never closed the breaker");
        }
        assert!(
            matches!(probe_outcome, ReclaimOutcome::Spilled { victim: 1, pages: 2 }),
            "probe should spill the LRU victim, got {probe_outcome:?}"
        );
        assert_eq!(m.tier_stats().spilled_pages, 2);
        m.check_integrity().unwrap();
        for id in [1, 2] {
            m.release(id);
        }
        assert_eq!(m.pool().pages_in_use(), 0);
        assert_eq!(m.tier_stats().spilled_pages, 0, "cold slots handed back");
    }
}
