//! Session lifecycle over the page arena: admission reservations,
//! LRU eviction of preemptable sessions, and pool-pressure accounting.
//!
//! Admission works on *committed* pages: for every live session the manager
//! counts `max(reserved, allocated)` so a freshly admitted request holds its
//! cost-model reservation before it touches a page, and a session that
//! outgrew its estimate is counted at its real footprint. A new reservation
//! is admitted only if committed pages stay at or below the high watermark;
//! when they would not, preemptable sessions (idle prefix caches, paused
//! generations) are LRU-evicted down toward the low watermark first.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use anyhow::{bail, ensure, Result};

use crate::cache::MemoryReport;
use crate::util::json::Json;
use crate::util::threadpool::{PoolHandle, ThreadPool};

use super::page::{PageHandle, PageKind, PagePool, PoolConfig, SessionId};

/// Quantized-cache read traffic, split by decode path (paper §4.2: the
/// draft reads the INT4 plane, verify reads both planes). `bytes_read_*`
/// count host bytes of packed codes actually touched, so acceptance-rate
/// regressions can be correlated with cache traffic in `/stats`.
#[derive(Debug, Clone, Copy, Default)]
pub struct CacheTraffic {
    /// Per-token dequantizations served from the INT4 (draft) plane.
    pub dequant_calls_draft: u64,
    /// Per-token dequantizations served from both planes (target/verify).
    pub dequant_calls_target: u64,
    /// Packed code bytes read on the draft path.
    pub bytes_read_draft: u64,
    /// Packed code bytes read on the target path.
    pub bytes_read_target: u64,
}

/// Outcome of an admission attempt.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmitOutcome {
    /// Reservation booked; the session may allocate.
    Admitted,
    /// Over the watermark right now and nothing evictable — retry after a
    /// release, or shed.
    Saturated,
    /// The reservation alone exceeds the watermarked pool; it can never be
    /// admitted. Fail the request cleanly (never OOM).
    TooLarge,
}

#[derive(Debug, Clone)]
struct SessionEntry {
    reserved: usize,
    allocated: usize,
    preemptable: bool,
    evicted: bool,
    last_touch: u64,
}

/// Allocate/free/preempt broker between sessions and the shared arena.
/// Also owns the ONE process-wide quantization thread pool (sized by
/// `PoolConfig::quant_workers`): sessions clone a [`PoolHandle`] out at
/// cache construction and fan bulk prefill quantization over the shared
/// workers — no per-prefill thread spawning, and submits never hold the
/// manager mutex.
pub struct SessionManager {
    pool: PagePool,
    /// The shared quantization pool; handles are cloned out per session.
    quant: ThreadPool,
    sessions: BTreeMap<SessionId, SessionEntry>,
    clock: u64,
    evictions: u64,
    traffic: CacheTraffic,
    /// Prefill chunks deferred by quant-pool backpressure (recorded by
    /// `coordinator::batcher::QuantBackpressure`, surfaced in `/stats`).
    prefill_deferrals: u64,
}

/// The coordinator and paged caches share the manager behind one mutex.
pub type SharedSessionManager = Arc<Mutex<SessionManager>>;

pub fn shared(cfg: PoolConfig) -> Result<SharedSessionManager> {
    Ok(Arc::new(Mutex::new(SessionManager::new(cfg)?)))
}

impl SessionManager {
    pub fn new(cfg: PoolConfig) -> Result<SessionManager> {
        ensure!(
            cfg.quant_workers >= 1,
            "pool.quant_workers must be >= 1 (the shared quantization pool \
             needs at least one worker; use 1 for serial quantization)"
        );
        let quant = ThreadPool::new(cfg.quant_workers);
        Ok(SessionManager {
            pool: PagePool::new(cfg),
            quant,
            sessions: BTreeMap::new(),
            clock: 0,
            evictions: 0,
            traffic: CacheTraffic::default(),
            prefill_deferrals: 0,
        })
    }

    pub fn pool(&self) -> &PagePool {
        &self.pool
    }

    /// A `Sync`, cloneable handle onto the process-wide quantization pool.
    pub fn quant_handle(&self) -> PoolHandle {
        self.quant.handle()
    }

    /// (workers, jobs executed, queue depth) of the shared quantization
    /// pool — the `/stats` gauges proving one pool serves every session.
    pub fn quant_pool_stats(&self) -> (usize, u64, usize) {
        (
            self.quant.size(),
            self.quant.jobs_executed() as u64,
            self.quant.queue_depth(),
        )
    }

    pub fn evictions(&self) -> u64 {
        self.evictions
    }

    /// Record `n` prefill chunks deferred under quant-pool backpressure
    /// (the batcher batches a whole round's deferrals into one call).
    pub fn note_prefill_deferrals(&mut self, n: u64) {
        self.prefill_deferrals += n;
    }

    /// Prefill chunks deferred by quant-pool backpressure so far.
    pub fn prefill_deferrals(&self) -> u64 {
        self.prefill_deferrals
    }

    /// Cumulative quantized-cache read traffic (draft vs target path).
    pub fn traffic(&self) -> CacheTraffic {
        self.traffic
    }

    /// Record `calls` per-token dequantizations touching `bytes` packed
    /// code bytes in total. The batched window reader accounts one crossed
    /// group at a time (calls = tokens served from that group), so a
    /// γ-window read costs O(groups-crossed) counter updates, not O(γ).
    /// Called on the zero-allocation read path: two plain integer adds.
    pub(crate) fn note_dequant_many(&mut self, draft: bool, calls: u64, bytes: u64) {
        if draft {
            self.traffic.dequant_calls_draft += calls;
            self.traffic.bytes_read_draft += bytes;
        } else {
            self.traffic.dequant_calls_target += calls;
            self.traffic.bytes_read_target += bytes;
        }
    }

    pub fn active_sessions(&self) -> usize {
        self.sessions.values().filter(|s| !s.evicted).count()
    }

    /// Pages the pool is on the hook for: live pages plus unfilled
    /// reservations.
    pub fn committed_pages(&self) -> usize {
        self.sessions
            .values()
            .filter(|s| !s.evicted)
            .map(|s| s.reserved.max(s.allocated))
            .sum()
    }

    fn watermark_pages(&self, frac: f64) -> usize {
        ((self.pool.capacity() as f64) * frac).floor() as usize
    }

    pub fn high_pages(&self) -> usize {
        self.watermark_pages(self.pool.cfg().high_watermark)
    }

    /// Admission control: book `pages` for a new session, evicting idle
    /// preemptable sessions if that is what it takes.
    pub fn admit(
        &mut self,
        id: SessionId,
        pages: usize,
        preemptable: bool,
    ) -> Result<AdmitOutcome> {
        ensure!(
            !self.sessions.contains_key(&id),
            "session {id} already admitted"
        );
        let high = self.high_pages();
        if pages > high {
            return Ok(AdmitOutcome::TooLarge);
        }
        // Over the ceiling: evict LRU preemptable sessions down toward the
        // low watermark (hysteresis) to make room.
        if self.committed_pages() + pages > high {
            let low = self.watermark_pages(self.pool.cfg().low_watermark);
            while self.committed_pages() + pages > low {
                if self.evict_lru(None).is_none() {
                    break;
                }
            }
        }
        if self.committed_pages() + pages > high {
            return Ok(AdmitOutcome::Saturated);
        }
        self.clock += 1;
        self.sessions.insert(
            id,
            SessionEntry {
                reserved: pages,
                allocated: 0,
                preemptable,
                evicted: false,
                last_touch: self.clock,
            },
        );
        Ok(AdmitOutcome::Admitted)
    }

    /// Free every page a session owns and forget it. Idempotent: releasing
    /// an unknown session is a no-op (returns 0).
    pub fn release(&mut self, id: SessionId) -> usize {
        let freed = self.pool.free_all(id);
        self.sessions.remove(&id);
        freed
    }

    /// LRU-touch: marks the session recently used (eviction order).
    pub fn touch(&mut self, id: SessionId) {
        self.clock += 1;
        let clock = self.clock;
        if let Some(s) = self.sessions.get_mut(&id) {
            s.last_touch = clock;
        }
    }

    pub fn set_preemptable(&mut self, id: SessionId, preemptable: bool) {
        if let Some(s) = self.sessions.get_mut(&id) {
            s.preemptable = preemptable;
        }
    }

    pub fn is_evicted(&self, id: SessionId) -> bool {
        self.sessions.get(&id).map(|s| s.evicted).unwrap_or(false)
    }

    /// Evict the least-recently-touched preemptable session (drop its
    /// pages; the session must re-prefill if resumed). Returns the victim.
    pub fn evict_lru(&mut self, exclude: Option<SessionId>) -> Option<SessionId> {
        let victim = self
            .sessions
            .iter()
            .filter(|(id, s)| {
                s.preemptable && !s.evicted && s.allocated > 0 && Some(**id) != exclude
            })
            .min_by_key(|(_, s)| s.last_touch)
            .map(|(id, _)| *id)?;
        self.pool.free_all(victim);
        let entry = self.sessions.get_mut(&victim).expect("victim exists");
        entry.allocated = 0;
        entry.reserved = 0;
        entry.evicted = true;
        self.evictions += 1;
        Some(victim)
    }

    /// Allocate one page for a session, evicting preemptable sessions if
    /// the arena itself is full.
    pub fn alloc(&mut self, id: SessionId, kind: PageKind) -> Result<PageHandle> {
        match self.sessions.get(&id) {
            None => bail!("session {id} not admitted"),
            Some(s) if s.evicted => bail!("session {id} was evicted"),
            Some(_) => {}
        }
        while self.pool.pages_in_use() >= self.pool.capacity() {
            if self.evict_lru(Some(id)).is_none() {
                bail!(
                    "pool exhausted and nothing preemptable \
                     ({} pages, session {id})",
                    self.pool.capacity()
                );
            }
        }
        let h = self.pool.alloc(kind, id)?;
        self.sessions.get_mut(&id).expect("checked above").allocated += 1;
        Ok(h)
    }

    pub fn free(&mut self, id: SessionId, h: PageHandle) -> Result<()> {
        self.pool.free(h, id)?;
        let entry = self.sessions.get_mut(&id);
        if let Some(e) = entry {
            e.allocated = e.allocated.saturating_sub(1);
        }
        Ok(())
    }

    // ---- data-plane passthroughs (owner-checked by the arena) ----------

    pub fn write_quant(
        &mut self,
        id: SessionId,
        h: PageHandle,
        group: crate::quant::PackedGroup,
    ) -> Result<()> {
        self.pool.write_quant(h, id, group)
    }

    pub fn read_quant(&self, id: SessionId, h: PageHandle) -> Result<&crate::quant::PackedGroup> {
        self.pool.read_quant(h, id)
    }

    pub fn fp(&self, id: SessionId, h: PageHandle) -> Result<&[f32]> {
        self.pool.fp(h, id)
    }

    pub fn fp_mut(&mut self, id: SessionId, h: PageHandle) -> Result<&mut [f32]> {
        self.pool.fp_mut(h, id)
    }

    // ---- reporting ------------------------------------------------------

    /// Pool-wide cache memory in both conventions (weights are not pooled).
    pub fn memory_report(&self) -> MemoryReport {
        MemoryReport {
            weights_logical: 0,
            weights_host: 0,
            cache_logical: self.pool.logical_bytes(),
            cache_host: self.pool.host_bytes(),
        }
    }

    /// Snapshot for `/stats` and the benches.
    pub fn stats_json(&self) -> Json {
        let (q_workers, q_jobs, q_depth) = self.quant_pool_stats();
        Json::obj(vec![
            ("pages_capacity", Json::num(self.pool.capacity() as f64)),
            ("pages_in_use", Json::num(self.pool.pages_in_use() as f64)),
            ("pages_peak", Json::num(self.pool.peak_pages_in_use() as f64)),
            ("pages_committed", Json::num(self.committed_pages() as f64)),
            ("pressure", Json::num(self.pool.pressure())),
            ("high_watermark", Json::num(self.pool.cfg().high_watermark)),
            ("low_watermark", Json::num(self.pool.cfg().low_watermark)),
            ("sessions_active", Json::num(self.active_sessions() as f64)),
            ("evictions", Json::num(self.evictions as f64)),
            ("cache_bytes_host", Json::num(self.pool.host_bytes() as f64)),
            (
                "cache_bytes_logical",
                Json::num(self.pool.logical_bytes() as f64),
            ),
            (
                crate::metrics::names::DEQUANT_CALLS_DRAFT,
                Json::num(self.traffic.dequant_calls_draft as f64),
            ),
            (
                crate::metrics::names::DEQUANT_CALLS_TARGET,
                Json::num(self.traffic.dequant_calls_target as f64),
            ),
            (
                crate::metrics::names::QUANT_BYTES_READ_DRAFT,
                Json::num(self.traffic.bytes_read_draft as f64),
            ),
            (
                crate::metrics::names::QUANT_BYTES_READ_TARGET,
                Json::num(self.traffic.bytes_read_target as f64),
            ),
            (
                crate::metrics::names::QUANT_POOL_WORKERS,
                Json::num(q_workers as f64),
            ),
            (crate::metrics::names::QUANT_POOL_JOBS, Json::num(q_jobs as f64)),
            (
                crate::metrics::names::QUANT_POOL_QUEUE_DEPTH,
                Json::num(q_depth as f64),
            ),
            (
                crate::metrics::names::PREFILL_DEFERRALS,
                Json::num(self.prefill_deferrals as f64),
            ),
        ])
    }

    /// Cross-check session accounting against the arena.
    pub fn check_integrity(&self) -> Result<()> {
        self.pool.check_integrity()?;
        let total: usize = self.sessions.values().map(|s| s.allocated).sum();
        ensure!(
            total == self.pool.pages_in_use(),
            "session accounting {} != pool in-use {}",
            total,
            self.pool.pages_in_use()
        );
        for (id, s) in &self.sessions {
            ensure!(
                self.pool.pages_owned(*id) == s.allocated,
                "session {id} claims {} pages, arena holds {}",
                s.allocated,
                self.pool.pages_owned(*id)
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mgr(pages: usize) -> SessionManager {
        SessionManager::new(PoolConfig {
            pages,
            page_tokens: 4,
            kv_dim: 2,
            high_watermark: 0.9,
            low_watermark: 0.6,
            ..PoolConfig::default()
        })
        .unwrap()
    }

    #[test]
    fn zero_quant_workers_is_an_error_not_a_clamp() {
        let err = SessionManager::new(PoolConfig {
            quant_workers: 0,
            ..PoolConfig::default()
        })
        .unwrap_err()
        .to_string();
        assert!(err.contains("quant_workers"), "got: {err}");
    }

    #[test]
    fn admission_watermark() {
        let mut m = mgr(10); // high watermark: 9 pages
        assert_eq!(m.admit(1, 5, false).unwrap(), AdmitOutcome::Admitted);
        assert_eq!(m.admit(2, 4, false).unwrap(), AdmitOutcome::Admitted);
        // 9 committed; one more page would cross the ceiling
        assert_eq!(m.admit(3, 1, false).unwrap(), AdmitOutcome::Saturated);
        assert_eq!(m.admit(4, 10, false).unwrap(), AdmitOutcome::TooLarge);
        m.release(1);
        assert_eq!(m.admit(3, 1, false).unwrap(), AdmitOutcome::Admitted);
    }

    #[test]
    fn admission_evicts_lru_preemptable() {
        // capacity 10, high 9, low 8: two 4-page idle sessions; a 2-page
        // request crosses the ceiling and must evict exactly the LRU one.
        let mut m = SessionManager::new(PoolConfig {
            pages: 10,
            page_tokens: 4,
            kv_dim: 2,
            high_watermark: 0.9,
            low_watermark: 0.8,
            ..PoolConfig::default()
        })
        .unwrap();
        m.admit(1, 4, true).unwrap();
        for _ in 0..4 {
            m.alloc(1, PageKind::Quant).unwrap();
        }
        m.admit(2, 4, true).unwrap();
        for _ in 0..4 {
            m.alloc(2, PageKind::Quant).unwrap();
        }
        m.touch(1); // session 2 becomes LRU
        assert_eq!(m.admit(3, 2, false).unwrap(), AdmitOutcome::Admitted);
        assert!(m.is_evicted(2), "LRU preemptable session evicted");
        assert!(!m.is_evicted(1));
        assert_eq!(m.evictions(), 1);
        m.check_integrity().unwrap();
    }

    #[test]
    fn alloc_requires_admission_and_detects_eviction() {
        let mut m = mgr(8);
        assert!(m.alloc(9, PageKind::Fp).is_err());
        m.admit(9, 2, true).unwrap();
        m.alloc(9, PageKind::Fp).unwrap();
        m.evict_lru(None).unwrap();
        assert!(m.alloc(9, PageKind::Fp).is_err(), "evicted session rejected");
    }

    #[test]
    fn full_pool_alloc_evicts() {
        // Watermarks at 1.0 so admission lets the arena actually fill: a
        // session that outgrows its reservation trips the alloc-path
        // eviction when the arena is full.
        let mut m = SessionManager::new(PoolConfig {
            pages: 4,
            page_tokens: 4,
            kv_dim: 2,
            high_watermark: 1.0,
            low_watermark: 1.0,
            ..PoolConfig::default()
        })
        .unwrap();
        m.admit(1, 3, true).unwrap();
        for _ in 0..3 {
            m.alloc(1, PageKind::Quant).unwrap();
        }
        m.admit(2, 1, false).unwrap();
        m.alloc(2, PageKind::Fp).unwrap();
        // arena now full; session 2's over-reservation alloc evicts 1
        m.alloc(2, PageKind::Fp).unwrap();
        assert!(m.is_evicted(1));
        m.check_integrity().unwrap();
    }

    #[test]
    fn release_is_idempotent() {
        let mut m = mgr(4);
        m.admit(5, 2, false).unwrap();
        m.alloc(5, PageKind::Fp).unwrap();
        assert_eq!(m.release(5), 1);
        assert_eq!(m.release(5), 0);
        assert_eq!(m.pool().pages_in_use(), 0);
    }

    /// Property: random admit/alloc/free/touch/evict/release traffic keeps
    /// session accounting and the arena consistent, and never exceeds
    /// capacity.
    #[test]
    fn prop_manager_invariants() {
        use crate::util::prop::{check, Config};
        check::<Vec<usize>, _>(
            Config { cases: 40, size: 64, ..Config::default() },
            |ops| {
                let mut m = mgr(8);
                let mut next_id: SessionId = 0;
                let mut live: Vec<SessionId> = Vec::new();
                for &op in ops {
                    match op % 6 {
                        0 => {
                            next_id += 1;
                            if let Ok(AdmitOutcome::Admitted) =
                                m.admit(next_id, op % 4 + 1, op % 2 == 0)
                            {
                                live.push(next_id);
                            }
                        }
                        1 | 2 => {
                            if let Some(&id) = live.get(op % live.len().max(1)) {
                                let _ = m.alloc(
                                    id,
                                    if op % 2 == 0 { PageKind::Quant } else { PageKind::Fp },
                                );
                            }
                        }
                        3 => {
                            if !live.is_empty() {
                                let id = live.remove(op % live.len());
                                m.release(id);
                            }
                        }
                        4 => {
                            if let Some(&id) = live.get(op % live.len().max(1)) {
                                m.touch(id);
                            }
                        }
                        _ => {
                            m.evict_lru(None);
                        }
                    }
                    if m.pool().pages_in_use() > m.pool().capacity() {
                        return false;
                    }
                    if m.check_integrity().is_err() {
                        return false;
                    }
                }
                for id in live {
                    m.release(id);
                }
                m.pool().pages_in_use() == 0 && m.check_integrity().is_ok()
            },
        );
    }
}
