//! The page arena: fixed-size pages, generation-checked handles, and
//! logical-vs-host byte accounting.
//!
//! A page is the pool's unit of allocation and holds exactly G tokens of KV
//! state for one session, in one of two layouts:
//!
//! * **Quant** — one hierarchically quantized G-token group
//!   (`quant::PackedGroup`): two bit-packed nibble planes holding **two
//!   4-bit codes per byte** (G·d codes ≈ G·d/2 bytes per plane) plus the
//!   group's scale/zero, so a quant page costs ~G·d host bytes — within
//!   scale/zero overhead of its logical INT4+INT4 size. Immutable once
//!   written; flush writes a fresh page.
//! * **Fp** — G token slots of full-precision KV (G·d f32 on this host,
//!   fp16 logically). The double FP buffer of a session spans
//!   `ceil(FB / G)` such pages and is mutated in place (draft writes,
//!   verify rewrites, flush shifts).
//!
//! Handles carry a per-slot generation that is bumped on free, so stale
//! handles (double-free, use-after-evict) are detected instead of silently
//! corrupting another session's cache.

use anyhow::{bail, ensure, Result};

use crate::quant::PackedGroup;

/// Owner tag for pages; the coordinator uses the request id.
pub type SessionId = u64;

/// Which layout a page holds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PageKind {
    Quant,
    Fp,
}

/// Generation-checked reference to a page in the arena.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PageHandle {
    id: u32,
    gen: u32,
}

impl PageHandle {
    pub fn id(&self) -> u32 {
        self.id
    }
}

/// Pool geometry and admission watermarks.
#[derive(Debug, Clone)]
pub struct PoolConfig {
    /// Total pages in the arena (the hard memory bound).
    pub pages: usize,
    /// Tokens per page == quantization group size G.
    pub page_tokens: usize,
    /// KV feature dim d per token (the mock's kv vectors; real models would
    /// use n_kv_heads * head_dim).
    pub kv_dim: usize,
    /// Admission ceiling: reject new sessions when committed pages would
    /// exceed this fraction of the arena.
    pub high_watermark: f64,
    /// Eviction target: LRU-evict preemptable sessions down to this
    /// fraction before giving up on an admission.
    pub low_watermark: f64,
    /// Size of the ONE process-wide quantization thread pool, created at
    /// coordinator startup by the session manager and shared by every
    /// session: bulk prefill quantization fans out over these workers
    /// through a cloned handle (no per-prefill thread spawning; a
    /// decode-time flush has one group and stays serial). 1 runs
    /// serially; 0 is rejected with an error at startup — never silently
    /// clamped. Output bits are identical at any worker count.
    pub quant_workers: usize,
}

impl Default for PoolConfig {
    fn default() -> Self {
        PoolConfig {
            pages: 256,
            page_tokens: 64,
            kv_dim: 8,
            high_watermark: 0.90,
            low_watermark: 0.70,
            quant_workers: 1,
        }
    }
}

impl PoolConfig {
    fn elems(&self) -> usize {
        self.page_tokens * self.kv_dim
    }

    /// Host bytes of one quant page: two bit-packed nibble planes (two
    /// codes per byte) + f32 scale/zero.
    pub fn quant_page_host_bytes(&self) -> usize {
        crate::costmodel::memory::packed_group_host_bytes(self.elems())
    }

    /// Logical bytes of one quant page: 2×INT4 = 1 byte per element plus
    /// fp16 scale/zero (the paper's bit-shared draft/target cache).
    pub fn quant_page_logical_bytes(&self) -> usize {
        self.elems() + 4
    }

    /// Host bytes of one FP page (f32 on this testbed).
    pub fn fp_page_host_bytes(&self) -> usize {
        4 * self.elems()
    }

    /// Logical bytes of one FP page (fp16 on device).
    pub fn fp_page_logical_bytes(&self) -> usize {
        2 * self.elems()
    }
}

enum PageData {
    /// None until the group is written (alloc-then-quantize window).
    Quant(Option<PackedGroup>),
    Fp(Vec<f32>),
}

struct Slot {
    gen: u32,
    /// None = free; Some((owner, data)) = in use.
    state: Option<(SessionId, PageData)>,
}

/// Fixed-capacity arena of KV pages shared by all sessions.
pub struct PagePool {
    cfg: PoolConfig,
    slots: Vec<Slot>,
    free: Vec<u32>,
    in_use: usize,
    peak_in_use: usize,
    n_quant: usize,
    n_fp: usize,
    allocs: u64,
    frees: u64,
}

impl PagePool {
    pub fn new(cfg: PoolConfig) -> PagePool {
        let pages = cfg.pages;
        PagePool {
            cfg,
            slots: (0..pages).map(|_| Slot { gen: 0, state: None }).collect(),
            // Reversed so pages allocate in ascending id order.
            free: (0..pages as u32).rev().collect(),
            in_use: 0,
            peak_in_use: 0,
            n_quant: 0,
            n_fp: 0,
            allocs: 0,
            frees: 0,
        }
    }

    pub fn cfg(&self) -> &PoolConfig {
        &self.cfg
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    pub fn pages_in_use(&self) -> usize {
        self.in_use
    }

    pub fn peak_pages_in_use(&self) -> usize {
        self.peak_in_use
    }

    /// Fill fraction in [0, 1].
    pub fn pressure(&self) -> f64 {
        if self.slots.is_empty() {
            return 1.0;
        }
        self.in_use as f64 / self.slots.len() as f64
    }

    pub fn allocs(&self) -> u64 {
        self.allocs
    }

    pub fn frees(&self) -> u64 {
        self.frees
    }

    /// Host-resident bytes of all live pages (what this testbed holds).
    pub fn host_bytes(&self) -> usize {
        self.n_quant * self.cfg.quant_page_host_bytes()
            + self.n_fp * self.cfg.fp_page_host_bytes()
    }

    /// Logical bytes of all live pages (true device bit widths).
    pub fn logical_bytes(&self) -> usize {
        self.n_quant * self.cfg.quant_page_logical_bytes()
            + self.n_fp * self.cfg.fp_page_logical_bytes()
    }

    pub fn alloc(&mut self, kind: PageKind, owner: SessionId) -> Result<PageHandle> {
        let Some(id) = self.free.pop() else {
            bail!(
                "pool exhausted: {} / {} pages in use",
                self.in_use,
                self.slots.len()
            );
        };
        let slot = &mut self.slots[id as usize];
        debug_assert!(slot.state.is_none(), "free-list slot in use");
        let data = match kind {
            PageKind::Quant => {
                self.n_quant += 1;
                PageData::Quant(None)
            }
            PageKind::Fp => {
                self.n_fp += 1;
                PageData::Fp(vec![0.0; self.cfg.page_tokens * self.cfg.kv_dim])
            }
        };
        slot.state = Some((owner, data));
        self.in_use += 1;
        self.peak_in_use = self.peak_in_use.max(self.in_use);
        self.allocs += 1;
        Ok(PageHandle { id, gen: slot.gen })
    }

    fn check(&self, h: PageHandle, owner: SessionId) -> Result<()> {
        let slot = self
            .slots
            .get(h.id as usize)
            .ok_or_else(|| anyhow::anyhow!("page id {} out of range", h.id))?;
        ensure!(
            slot.gen == h.gen,
            "stale page handle {} (gen {} != slot gen {}): double free or use after evict",
            h.id,
            h.gen,
            slot.gen
        );
        match &slot.state {
            None => bail!("page {} is free", h.id),
            Some((o, _)) => ensure!(
                *o == owner,
                "page {} owned by session {o}, not {owner}",
                h.id
            ),
        }
        Ok(())
    }

    pub fn free(&mut self, h: PageHandle, owner: SessionId) -> Result<PageKind> {
        self.check(h, owner)?;
        let slot = &mut self.slots[h.id as usize];
        let kind = match slot.state.take() {
            Some((_, PageData::Quant(_))) => {
                self.n_quant -= 1;
                PageKind::Quant
            }
            Some((_, PageData::Fp(_))) => {
                self.n_fp -= 1;
                PageKind::Fp
            }
            None => unreachable!("check() verified the slot is in use"),
        };
        slot.gen = slot.gen.wrapping_add(1);
        self.free.push(h.id);
        self.in_use -= 1;
        self.frees += 1;
        Ok(kind)
    }

    /// Free every page owned by `owner` (session release / eviction).
    /// Returns the number of pages reclaimed.
    pub fn free_all(&mut self, owner: SessionId) -> usize {
        let mut freed = 0;
        for id in 0..self.slots.len() as u32 {
            let is_owned = matches!(&self.slots[id as usize].state, Some((o, _)) if *o == owner);
            if is_owned {
                let gen = self.slots[id as usize].gen;
                self.free(PageHandle { id, gen }, owner)
                    .expect("owned page must free");
                freed += 1;
            }
        }
        freed
    }

    pub fn pages_owned(&self, owner: SessionId) -> usize {
        self.slots
            .iter()
            .filter(|s| matches!(&s.state, Some((o, _)) if *o == owner))
            .count()
    }

    pub fn write_quant(
        &mut self,
        h: PageHandle,
        owner: SessionId,
        group: PackedGroup,
    ) -> Result<()> {
        self.check(h, owner)?;
        let elems = self.cfg.page_tokens * self.cfg.kv_dim;
        ensure!(
            group.len() == elems,
            "quant group has {} codes, page holds {elems}",
            group.len()
        );
        match &mut self.slots[h.id as usize].state {
            Some((_, PageData::Quant(g))) => {
                *g = Some(group);
                Ok(())
            }
            _ => bail!("page {} is not a quant page", h.id),
        }
    }

    pub fn read_quant(&self, h: PageHandle, owner: SessionId) -> Result<&PackedGroup> {
        self.check(h, owner)?;
        match &self.slots[h.id as usize].state {
            Some((_, PageData::Quant(Some(g)))) => Ok(g),
            Some((_, PageData::Quant(None))) => {
                bail!("quant page {} allocated but never written", h.id)
            }
            _ => bail!("page {} is not a quant page", h.id),
        }
    }

    pub fn fp(&self, h: PageHandle, owner: SessionId) -> Result<&[f32]> {
        self.check(h, owner)?;
        match &self.slots[h.id as usize].state {
            Some((_, PageData::Fp(v))) => Ok(v),
            _ => bail!("page {} is not an fp page", h.id),
        }
    }

    pub fn fp_mut(&mut self, h: PageHandle, owner: SessionId) -> Result<&mut [f32]> {
        self.check(h, owner)?;
        match &mut self.slots[h.id as usize].state {
            Some((_, PageData::Fp(v))) => Ok(v),
            _ => bail!("page {} is not an fp page", h.id),
        }
    }

    /// Structural invariants; used by tests and the session manager's
    /// consistency checks.
    pub fn check_integrity(&self) -> Result<()> {
        ensure!(
            self.in_use + self.free.len() == self.slots.len(),
            "page accounting broken: {} in use + {} free != {} slots",
            self.in_use,
            self.free.len(),
            self.slots.len()
        );
        ensure!(
            self.n_quant + self.n_fp == self.in_use,
            "kind counts {} + {} != in_use {}",
            self.n_quant,
            self.n_fp,
            self.in_use
        );
        let mut seen = vec![false; self.slots.len()];
        for &id in &self.free {
            let slot = &self.slots[id as usize];
            ensure!(slot.state.is_none(), "free-list page {id} is in use");
            ensure!(!seen[id as usize], "page {id} on free list twice");
            seen[id as usize] = true;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::quant_group;

    fn pool(pages: usize) -> PagePool {
        PagePool::new(PoolConfig {
            pages,
            page_tokens: 4,
            kv_dim: 2,
            ..PoolConfig::default()
        })
    }

    fn group(pool: &PagePool, seed: f32) -> PackedGroup {
        let n = pool.cfg().page_tokens * pool.cfg().kv_dim;
        let xs: Vec<f32> = (0..n).map(|i| seed + i as f32 * 0.25).collect();
        quant_group(&xs).unwrap()
    }

    #[test]
    fn alloc_free_roundtrip() {
        let mut p = pool(4);
        let h = p.alloc(PageKind::Fp, 1).unwrap();
        assert_eq!(p.pages_in_use(), 1);
        p.fp_mut(h, 1).unwrap()[0] = 3.5;
        assert_eq!(p.fp(h, 1).unwrap()[0], 3.5);
        p.free(h, 1).unwrap();
        assert_eq!(p.pages_in_use(), 0);
        p.check_integrity().unwrap();
    }

    #[test]
    fn exhaustion_and_reuse() {
        let mut p = pool(2);
        let a = p.alloc(PageKind::Fp, 1).unwrap();
        let _b = p.alloc(PageKind::Quant, 1).unwrap();
        assert!(p.alloc(PageKind::Fp, 1).is_err(), "pool must be exhausted");
        p.free(a, 1).unwrap();
        let c = p.alloc(PageKind::Quant, 2).unwrap();
        assert_eq!(c.id(), a.id(), "freed page is reused");
        p.check_integrity().unwrap();
    }

    #[test]
    fn stale_handle_rejected() {
        let mut p = pool(2);
        let h = p.alloc(PageKind::Fp, 1).unwrap();
        p.free(h, 1).unwrap();
        assert!(p.free(h, 1).is_err(), "double free must be rejected");
        let h2 = p.alloc(PageKind::Fp, 1).unwrap();
        assert_eq!(h2.id(), h.id());
        assert!(p.fp(h, 1).is_err(), "stale handle must not read new page");
    }

    #[test]
    fn owner_enforced() {
        let mut p = pool(2);
        let h = p.alloc(PageKind::Fp, 1).unwrap();
        assert!(p.fp(h, 2).is_err());
        assert!(p.free(h, 2).is_err());
        p.free(h, 1).unwrap();
    }

    #[test]
    fn free_all_reclaims_only_owner() {
        let mut p = pool(8);
        for _ in 0..3 {
            p.alloc(PageKind::Quant, 7).unwrap();
        }
        let other = p.alloc(PageKind::Fp, 9).unwrap();
        assert_eq!(p.free_all(7), 3);
        assert_eq!(p.pages_in_use(), 1);
        assert!(p.fp(other, 9).is_ok());
        p.check_integrity().unwrap();
    }

    #[test]
    fn quant_write_read() {
        let mut p = pool(2);
        let h = p.alloc(PageKind::Quant, 1).unwrap();
        assert!(p.read_quant(h, 1).is_err(), "unwritten page unreadable");
        let g = group(&p, -1.0);
        p.write_quant(h, 1, g.clone()).unwrap();
        assert_eq!(*p.read_quant(h, 1).unwrap(), g);
    }

    #[test]
    fn byte_accounting() {
        let mut p = pool(4);
        let elems = 8; // 4 tokens * 2 dims
        p.alloc(PageKind::Quant, 1).unwrap();
        p.alloc(PageKind::Fp, 1).unwrap();
        // packed quant page: two nibbles per byte + f32 scale/zero
        assert_eq!(p.host_bytes(), (elems + 8) + 4 * elems);
        assert_eq!(p.logical_bytes(), (elems + 4) + 2 * elems);
        assert!(p.logical_bytes() < p.host_bytes());
    }

    /// Property: random alloc/free sequences never corrupt the arena —
    /// counts stay consistent, nothing double-frees, nothing leaks.
    #[test]
    fn prop_alloc_free_invariants() {
        use crate::util::prop::{check, Config};
        check::<Vec<usize>, _>(
            Config { cases: 60, size: 48, ..Config::default() },
            |ops| {
                let mut p = pool(6);
                let mut live: Vec<(PageHandle, SessionId)> = Vec::new();
                for &op in ops {
                    match op % 3 {
                        0 | 1 => {
                            let owner = (op % 4) as SessionId;
                            let kind =
                                if op % 2 == 0 { PageKind::Quant } else { PageKind::Fp };
                            match p.alloc(kind, owner) {
                                Ok(h) => live.push((h, owner)),
                                Err(_) => {
                                    if p.pages_in_use() != p.capacity() {
                                        return false; // alloc may only fail when full
                                    }
                                }
                            }
                        }
                        _ => {
                            if !live.is_empty() {
                                let (h, owner) = live.remove(op % live.len());
                                if p.free(h, owner).is_err() {
                                    return false;
                                }
                                // a second free of the same handle must fail
                                if p.free(h, owner).is_ok() {
                                    return false;
                                }
                            }
                        }
                    }
                    if p.check_integrity().is_err() {
                        return false;
                    }
                    if p.pages_in_use() != live.len() {
                        return false;
                    }
                }
                for (h, owner) in live {
                    if p.free(h, owner).is_err() {
                        return false;
                    }
                }
                p.pages_in_use() == 0 && p.check_integrity().is_ok()
            },
        );
    }
}
