//! The sharded page arena: global accounting in atomics, page *data* in
//! per-session shards.
//!
//! A page is the pool's unit of allocation and holds exactly G tokens of KV
//! state for one session, in one of two layouts:
//!
//! * **Quant** — one hierarchically quantized G-token group
//!   (`quant::PackedGroup`): two bit-packed nibble planes holding **two
//!   4-bit codes per byte** (G·d codes ≈ G·d/2 bytes per plane) plus the
//!   group's scale/zero, so a quant page costs ~G·d host bytes — within
//!   scale/zero overhead of its logical INT4+INT4 size. Immutable once
//!   written; flush writes a fresh page.
//! * **Fp** — G token slots of full-precision KV (G·d f32 on this host,
//!   fp16 logically). The double FP buffer of a session spans
//!   `ceil(FB / G)` such pages and is mutated in place (draft writes,
//!   verify rewrites, flush shifts).
//!
//! # Sharded locking (the parallel-rounds contract)
//!
//! The arena used to be one big `Vec<Slot>` behind the session-manager
//! mutex, which serialized every session's draft/verify reads against each
//! other. It is now split in two:
//!
//! * [`PagePool`] — the **global accounting arena**: capacity, pages in
//!   use / peak, per-kind counts, alloc/free totals, and the cache-traffic
//!   counters. All atomics; the capacity bound is enforced by a CAS in
//!   [`PagePool::try_reserve`], so concurrent sessions can allocate without
//!   any lock and still never exceed `pages` in total.
//! * [`SessionShard`] — one per session, owning that session's page
//!   *data* (quant groups + FP buffers) behind its **own** mutex. A
//!   steady-state draft/verify step locks only its shard — uncontended
//!   when the step batcher runs sessions on different workers — and never
//!   touches the session-manager mutex.
//!
//! Lock order: the session-manager mutex may be held while taking a shard
//! lock (admission, eviction, release); a shard lock must NEVER be held
//! while taking the manager mutex. Data-plane code in
//! [`super::paged::PagedKvCache`] only ever takes the shard lock.
//!
//! Handles carry a per-slot generation that is bumped on free, so stale
//! handles (double-free, use-after-evict) are detected instead of silently
//! corrupting another session's cache. Handles are shard-local: page ids
//! are deterministic per session regardless of how other sessions
//! interleave, which is what makes parallel batcher rounds bit-identical
//! to serial ones.
//!
//! # The cold tier
//!
//! When the pool runs with a [`super::tier::SpillStore`], a slot can hold
//! a third state: *spilled* — the page's bytes live in a cold-tier slot
//! and the arena budget it occupied has been handed back. Spilling does
//! NOT bump the slot generation: the page handle stays valid, and
//! [`SessionShard::fault_page`] transparently restores the bytes
//! (bit-identical) when the data plane next touches them. Only `free`
//! bumps generations. Spilled pages are excluded from `live` (they hold
//! no arena budget) and tracked in the `spilled` mirror instead.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};

use anyhow::{bail, ensure, Result};

use crate::quant::PackedGroup;

use super::tier::{decode_fp_page, encode_fp_page, SpillHandle, SpillStore};

/// Owner tag for pages; the coordinator uses the request id.
pub type SessionId = u64;

/// Which layout a page holds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PageKind {
    Quant,
    Fp,
}

/// Generation-checked reference to a page in its session's shard.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PageHandle {
    id: u32,
    gen: u32,
}

impl PageHandle {
    pub fn id(&self) -> u32 {
        self.id
    }
}

/// Pool geometry and admission watermarks.
#[derive(Debug, Clone)]
pub struct PoolConfig {
    /// Total pages in the arena (the hard memory bound).
    pub pages: usize,
    /// Tokens per page == quantization group size G.
    pub page_tokens: usize,
    /// KV feature dim d per token (the mock's kv vectors; real models would
    /// use n_kv_heads * head_dim).
    pub kv_dim: usize,
    /// Admission ceiling: reject new sessions when committed pages would
    /// exceed this fraction of the arena.
    pub high_watermark: f64,
    /// Eviction target: LRU-evict preemptable sessions down to this
    /// fraction before giving up on an admission.
    pub low_watermark: f64,
    /// Size of the ONE process-wide quantization thread pool, created at
    /// coordinator startup by the session manager and shared by every
    /// session: bulk prefill quantization fans out over these workers
    /// through a cloned handle (no per-prefill thread spawning; a
    /// decode-time flush has one group and stays serial). 1 runs
    /// serially; 0 is rejected with an error at startup — never silently
    /// clamped. Output bits are identical at any worker count.
    pub quant_workers: usize,
    /// Cold-tier capacity in pages: 0 disables tiering entirely (the
    /// pre-tier behavior — reclamation is whole-session LRU eviction);
    /// any other value creates a `SpillStore` holding at most this many
    /// spilled pages, making page-granular spill the first resort.
    pub spill_pages: usize,
    /// Directory for the spill file (empty = the system temp dir). The
    /// file is unlinked when the pool shuts down.
    pub spill_dir: String,
    /// Speculatively restore cold pages at cycle start (see
    /// `tier::TierPolicy::fetch_ahead`); only meaningful with tiering on.
    pub fetch_ahead: bool,
    /// Cap on the adaptive fetch-ahead depth in quant groups (see
    /// `tier::TierPolicy::fetch_ahead_max`). The live depth starts at 1
    /// and is steered up to this bound by the observed cold-page fault
    /// rate; 0 is treated as 1.
    pub fetch_ahead_max: usize,
}

impl Default for PoolConfig {
    fn default() -> Self {
        PoolConfig {
            pages: 256,
            page_tokens: 64,
            kv_dim: 8,
            high_watermark: 0.90,
            low_watermark: 0.70,
            quant_workers: 1,
            spill_pages: 0,
            spill_dir: String::new(),
            fetch_ahead: true,
            fetch_ahead_max: 8,
        }
    }
}

impl PoolConfig {
    /// Values per page (`page_tokens × kv_dim`) — the payload size every
    /// page layout and spill slot is derived from.
    pub fn elems(&self) -> usize {
        self.page_tokens * self.kv_dim
    }

    /// Host bytes of one quant page: two bit-packed nibble planes (two
    /// codes per byte) + f32 scale/zero.
    pub fn quant_page_host_bytes(&self) -> usize {
        crate::costmodel::memory::packed_group_host_bytes(self.elems())
    }

    /// Logical bytes of one quant page: 2×INT4 = 1 byte per element plus
    /// fp16 scale/zero (the paper's bit-shared draft/target cache).
    pub fn quant_page_logical_bytes(&self) -> usize {
        self.elems() + 4
    }

    /// Host bytes of one FP page (f32 on this testbed).
    pub fn fp_page_host_bytes(&self) -> usize {
        4 * self.elems()
    }

    /// Logical bytes of one FP page (fp16 on device).
    pub fn fp_page_logical_bytes(&self) -> usize {
        2 * self.elems()
    }
}

/// Quantized-cache read traffic, split by decode path (paper §4.2: the
/// draft reads the INT4 plane, verify reads both planes). `bytes_read_*`
/// count host bytes of packed codes actually touched, so acceptance-rate
/// regressions can be correlated with cache traffic in `/stats`.
#[derive(Debug, Clone, Copy, Default)]
pub struct CacheTraffic {
    /// Per-token dequantizations served from the INT4 (draft) plane.
    pub dequant_calls_draft: u64,
    /// Per-token dequantizations served from both planes (target/verify).
    pub dequant_calls_target: u64,
    /// Packed code bytes read on the draft path.
    pub bytes_read_draft: u64,
    /// Packed code bytes read on the target path.
    pub bytes_read_target: u64,
}

/// Global accounting arena shared by every session shard: page budget,
/// per-kind counts, and cache-traffic counters — all atomics, so the
/// steady-state data plane never takes a global lock. The capacity bound
/// is a CAS in [`PagePool::try_reserve`]: concurrent allocations can
/// interleave freely and the total can still never exceed `pages`.
pub struct PagePool {
    cfg: PoolConfig,
    in_use: AtomicUsize,
    peak_in_use: AtomicUsize,
    n_quant: AtomicUsize,
    n_fp: AtomicUsize,
    allocs: AtomicU64,
    frees: AtomicU64,
    // cache-traffic counters (two relaxed adds on the zero-alloc read path)
    dequant_calls_draft: AtomicU64,
    dequant_calls_target: AtomicU64,
    bytes_read_draft: AtomicU64,
    bytes_read_target: AtomicU64,
}

impl PagePool {
    pub fn new(cfg: PoolConfig) -> PagePool {
        PagePool {
            cfg,
            in_use: AtomicUsize::new(0),
            peak_in_use: AtomicUsize::new(0),
            n_quant: AtomicUsize::new(0),
            n_fp: AtomicUsize::new(0),
            allocs: AtomicU64::new(0),
            frees: AtomicU64::new(0),
            dequant_calls_draft: AtomicU64::new(0),
            dequant_calls_target: AtomicU64::new(0),
            bytes_read_draft: AtomicU64::new(0),
            bytes_read_target: AtomicU64::new(0),
        }
    }

    pub fn cfg(&self) -> &PoolConfig {
        &self.cfg
    }

    pub fn capacity(&self) -> usize {
        self.cfg.pages
    }

    pub fn pages_in_use(&self) -> usize {
        self.in_use.load(Ordering::Acquire)
    }

    pub fn peak_pages_in_use(&self) -> usize {
        self.peak_in_use.load(Ordering::Relaxed)
    }

    /// Resident quantized pages — the **warm** tier occupancy.
    pub fn pages_quant(&self) -> usize {
        self.n_quant.load(Ordering::Relaxed)
    }

    /// Resident full-precision pages — the **hot** tier occupancy.
    pub fn pages_fp(&self) -> usize {
        self.n_fp.load(Ordering::Relaxed)
    }

    /// Fill fraction in [0, 1].
    pub fn pressure(&self) -> f64 {
        if self.cfg.pages == 0 {
            return 1.0;
        }
        self.pages_in_use() as f64 / self.cfg.pages as f64
    }

    pub fn allocs(&self) -> u64 {
        self.allocs.load(Ordering::Relaxed)
    }

    pub fn frees(&self) -> u64 {
        self.frees.load(Ordering::Relaxed)
    }

    /// Host-resident bytes of all live pages (what this testbed holds).
    pub fn host_bytes(&self) -> usize {
        self.n_quant.load(Ordering::Relaxed) * self.cfg.quant_page_host_bytes()
            + self.n_fp.load(Ordering::Relaxed) * self.cfg.fp_page_host_bytes()
    }

    /// Logical bytes of all live pages (true device bit widths).
    pub fn logical_bytes(&self) -> usize {
        self.n_quant.load(Ordering::Relaxed) * self.cfg.quant_page_logical_bytes()
            + self.n_fp.load(Ordering::Relaxed) * self.cfg.fp_page_logical_bytes()
    }

    /// Reserve one page of the global budget (lock-free). Returns false
    /// when the arena is full — the caller either fails cleanly or falls
    /// back to the session manager for LRU eviction. The CAS loop is the
    /// hard capacity bound: under any interleaving of concurrent
    /// reservations, `pages_in_use` never exceeds `capacity`.
    pub(crate) fn try_reserve(&self, kind: PageKind) -> bool {
        let mut cur = self.in_use.load(Ordering::Acquire);
        loop {
            if cur >= self.cfg.pages {
                return false;
            }
            match self.in_use.compare_exchange_weak(
                cur,
                cur + 1,
                Ordering::AcqRel,
                Ordering::Acquire,
            ) {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }
        self.peak_in_use.fetch_max(cur + 1, Ordering::Relaxed);
        match kind {
            PageKind::Quant => self.n_quant.fetch_add(1, Ordering::Relaxed),
            PageKind::Fp => self.n_fp.fetch_add(1, Ordering::Relaxed),
        };
        self.allocs.fetch_add(1, Ordering::Relaxed);
        true
    }

    /// Return one page of the given kind to the global budget.
    pub(crate) fn release_page(&self, kind: PageKind) {
        match kind {
            PageKind::Quant => self.n_quant.fetch_sub(1, Ordering::Relaxed),
            PageKind::Fp => self.n_fp.fetch_sub(1, Ordering::Relaxed),
        };
        self.frees.fetch_add(1, Ordering::Relaxed);
        self.in_use.fetch_sub(1, Ordering::AcqRel);
    }

    /// Record `calls` per-token dequantizations touching `bytes` packed
    /// code bytes in total. The batched window reader accounts one crossed
    /// group at a time (calls = tokens served from that group), so a
    /// γ-window read costs O(groups-crossed) counter updates, not O(γ).
    /// Two relaxed atomic adds — no lock on the zero-allocation read path.
    pub(crate) fn note_dequant_many(&self, draft: bool, calls: u64, bytes: u64) {
        if draft {
            self.dequant_calls_draft.fetch_add(calls, Ordering::Relaxed);
            self.bytes_read_draft.fetch_add(bytes, Ordering::Relaxed);
        } else {
            self.dequant_calls_target.fetch_add(calls, Ordering::Relaxed);
            self.bytes_read_target.fetch_add(bytes, Ordering::Relaxed);
        }
    }

    /// Cumulative quantized-cache read traffic (draft vs target path).
    pub fn traffic(&self) -> CacheTraffic {
        CacheTraffic {
            dequant_calls_draft: self.dequant_calls_draft.load(Ordering::Relaxed),
            dequant_calls_target: self.dequant_calls_target.load(Ordering::Relaxed),
            bytes_read_draft: self.bytes_read_draft.load(Ordering::Relaxed),
            bytes_read_target: self.bytes_read_target.load(Ordering::Relaxed),
        }
    }
}

enum PageData {
    /// None until the group is written (alloc-then-quantize window).
    Quant(Option<PackedGroup>),
    Fp(Vec<f32>),
    /// A written quant page parked in the cold tier; no arena budget held.
    SpilledQuant(SpillHandle),
    /// An FP page parked in the cold tier (hibernated shard).
    SpilledFp(SpillHandle),
}

impl PageData {
    fn kind(&self) -> PageKind {
        match self {
            PageData::Quant(_) | PageData::SpilledQuant(_) => PageKind::Quant,
            PageData::Fp(_) | PageData::SpilledFp(_) => PageKind::Fp,
        }
    }

    fn is_spilled(&self) -> bool {
        matches!(self, PageData::SpilledQuant(_) | PageData::SpilledFp(_))
    }
}

struct Slot {
    gen: u32,
    /// None = free; Some = in use (ownership is the shard itself).
    state: Option<PageData>,
}

/// Page storage of ONE session: slots, free list, and the geometry checks.
/// All methods run under the shard's mutex (see [`SessionShard::lock`]).
pub struct ShardData {
    /// page_tokens × kv_dim, denormalized from the arena config (the one
    /// geometry fact the write path checks against).
    elems: usize,
    slots: Vec<Slot>,
    free: Vec<u32>,
}

impl ShardData {
    fn check(&self, h: PageHandle) -> Result<()> {
        let slot = self
            .slots
            .get(h.id as usize)
            .ok_or_else(|| anyhow::anyhow!("page id {} out of range", h.id))?;
        ensure!(
            slot.gen == h.gen,
            "stale page handle {} (gen {} != slot gen {}): double free or use after evict",
            h.id,
            h.gen,
            slot.gen
        );
        ensure!(slot.state.is_some(), "page {} is free", h.id);
        Ok(())
    }

    pub fn write_quant(&mut self, h: PageHandle, group: PackedGroup) -> Result<()> {
        self.check(h)?;
        ensure!(
            group.len() == self.elems,
            "quant group has {} codes, page holds {}",
            group.len(),
            self.elems
        );
        match &mut self.slots[h.id as usize].state {
            Some(PageData::Quant(g)) => {
                *g = Some(group);
                Ok(())
            }
            _ => bail!("page {} is not a quant page", h.id),
        }
    }

    pub fn read_quant(&self, h: PageHandle) -> Result<&PackedGroup> {
        self.check(h)?;
        match &self.slots[h.id as usize].state {
            Some(PageData::Quant(Some(g))) => Ok(g),
            Some(PageData::Quant(None)) => {
                bail!("quant page {} allocated but never written", h.id)
            }
            Some(PageData::SpilledQuant(_)) => {
                bail!("quant page {} is spilled: fault it back before reading", h.id)
            }
            _ => bail!("page {} is not a quant page", h.id),
        }
    }

    pub fn fp(&self, h: PageHandle) -> Result<&[f32]> {
        self.check(h)?;
        match &self.slots[h.id as usize].state {
            Some(PageData::Fp(v)) => Ok(v),
            Some(PageData::SpilledFp(_)) => {
                bail!("fp page {} is spilled: fault it back before reading", h.id)
            }
            _ => bail!("page {} is not an fp page", h.id),
        }
    }

    pub fn fp_mut(&mut self, h: PageHandle) -> Result<&mut [f32]> {
        self.check(h)?;
        match &mut self.slots[h.id as usize].state {
            Some(PageData::Fp(v)) => Ok(v),
            Some(PageData::SpilledFp(_)) => {
                bail!("fp page {} is spilled: fault it back before writing", h.id)
            }
            _ => bail!("page {} is not an fp page", h.id),
        }
    }

    /// Whether the page behind a (valid) handle is parked in the cold
    /// tier. The windowed readers use this to decide between the
    /// zero-allocation resident fast path and a fault-back.
    pub fn is_spilled(&self, h: PageHandle) -> Result<bool> {
        self.check(h)?;
        Ok(self.slots[h.id as usize]
            .state
            .as_ref()
            .is_some_and(PageData::is_spilled))
    }

    fn live_slots(&self) -> usize {
        self.slots
            .iter()
            .filter(|s| s.state.as_ref().is_some_and(|d| !d.is_spilled()))
            .count()
    }

    fn spilled_slots(&self) -> usize {
        self.slots
            .iter()
            .filter(|s| s.state.as_ref().is_some_and(PageData::is_spilled))
            .count()
    }

    fn check_integrity_inner(&self) -> Result<()> {
        ensure!(
            self.live_slots() + self.spilled_slots() + self.free.len() == self.slots.len(),
            "shard accounting broken: {} live + {} spilled + {} free != {} slots",
            self.live_slots(),
            self.spilled_slots(),
            self.free.len(),
            self.slots.len()
        );
        let mut seen = vec![false; self.slots.len()];
        for &id in &self.free {
            let slot = &self.slots[id as usize];
            ensure!(slot.state.is_none(), "free-list page {id} is in use");
            ensure!(!seen[id as usize], "page {id} on free list twice");
            seen[id as usize] = true;
        }
        Ok(())
    }
}

/// One session's slice of the pool: page data behind its OWN mutex plus a
/// handle onto the global accounting arena. Cloned (`Arc`) into the
/// session's `PagedKvCache`, so the steady-state data plane runs entirely
/// on session-local state — the manager mutex is only for admission,
/// release, eviction, and over-reservation growth.
pub struct SessionShard {
    id: SessionId,
    arena: Arc<PagePool>,
    /// Set by eviction/release: further allocations are rejected (reads
    /// fail on the generation bump that `free_all` performs).
    evicted: AtomicBool,
    /// Pages currently held; mirrored out of the data lock so admission
    /// accounting (`committed_pages`) can read it without taking every
    /// shard's mutex.
    live: AtomicUsize,
    /// Admission reservation: the lock-free allocation fast path is
    /// limited to this many pages (see [`SessionShard::try_alloc`]).
    reserved: AtomicUsize,
    /// Pages of this shard parked in the cold tier (no arena budget).
    spilled: AtomicUsize,
    /// Set while a spill or fault-back is moving this shard's pages
    /// between tiers. Victim selection (reclaim/evict) skips shards with
    /// this flag up, so a mid-restore shard is never torn down under the
    /// transition (the generation-check race the tier tests pin).
    in_transition: AtomicBool,
    /// The cold tier, when tiering is enabled for this pool.
    spill: Option<Arc<SpillStore>>,
    data: Mutex<ShardData>,
}

/// RAII marker for a tier transition in flight on one shard.
struct TransitionGuard<'a> {
    shard: &'a SessionShard,
}

impl Drop for TransitionGuard<'_> {
    fn drop(&mut self) {
        self.shard.in_transition.store(false, Ordering::Release);
    }
}

/// Result of one [`SessionShard::fault_page`] attempt.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultOutcome {
    /// The page was cold and is now resident again (bit-identical).
    Restored,
    /// The page was already resident; nothing to do.
    Resident,
    /// The arena has no free page: the caller must reclaim (via the
    /// session manager) and retry — never while holding this shard's
    /// data lock.
    ArenaFull,
}

impl SessionShard {
    pub fn new(id: SessionId, arena: Arc<PagePool>, reserved: usize) -> SessionShard {
        SessionShard::with_spill(id, arena, reserved, None)
    }

    /// A shard wired to the cold tier: pages of this session may spill
    /// into `spill` and fault back transparently.
    pub fn with_spill(
        id: SessionId,
        arena: Arc<PagePool>,
        reserved: usize,
        spill: Option<Arc<SpillStore>>,
    ) -> SessionShard {
        let elems = arena.cfg().elems();
        SessionShard {
            id,
            arena,
            evicted: AtomicBool::new(false),
            live: AtomicUsize::new(0),
            reserved: AtomicUsize::new(reserved),
            spilled: AtomicUsize::new(0),
            in_transition: AtomicBool::new(false),
            spill,
            data: Mutex::new(ShardData {
                elems,
                slots: Vec::new(),
                free: Vec::new(),
            }),
        }
    }

    pub fn id(&self) -> SessionId {
        self.id
    }

    /// The global accounting arena (config, byte totals, traffic counters).
    pub fn arena(&self) -> &PagePool {
        &self.arena
    }

    pub fn is_evicted(&self) -> bool {
        self.evicted.load(Ordering::Acquire)
    }

    /// Pages this shard currently holds (lock-free).
    pub fn live_pages(&self) -> usize {
        self.live.load(Ordering::Acquire)
    }

    /// The admission reservation bounding the lock-free allocation path.
    pub fn reserved_pages(&self) -> usize {
        self.reserved.load(Ordering::Acquire)
    }

    /// Pages of this shard parked in the cold tier (lock-free mirror).
    pub fn spilled_pages(&self) -> usize {
        self.spilled.load(Ordering::Acquire)
    }

    /// The cold tier this shard spills into, when tiering is enabled.
    pub fn spill_store(&self) -> Option<&Arc<SpillStore>> {
        self.spill.as_ref()
    }

    /// Whether a spill or fault-back is currently moving this shard's
    /// pages between tiers (victim selection must skip such shards).
    pub fn in_transition(&self) -> bool {
        self.in_transition.load(Ordering::Acquire)
    }

    fn begin_transition(&self) -> TransitionGuard<'_> {
        self.in_transition.store(true, Ordering::Release);
        TransitionGuard { shard: self }
    }

    /// Page-granular reclaim: park up to `max` written quantized pages in
    /// the cold tier (0 = no cap), releasing their arena budget. Handles
    /// stay valid — the pages fault back bit-identically on the next
    /// touch. Stops early (no error) when the cold tier fills; the caller
    /// escalates. Returns the number of pages moved.
    pub fn spill_quant_pages(&self, max: usize) -> Result<usize> {
        let Some(store) = self.spill.clone() else { return Ok(0) };
        let _t = self.begin_transition();
        let cap = if max == 0 { usize::MAX } else { max };
        let mut moved = 0usize;
        // An I/O error mid-batch must not skip the accounting for pages
        // already converted, so it is deferred past the counter updates.
        let mut io_err = None;
        let mut d = self.lock();
        for id in 0..d.slots.len() {
            if moved >= cap {
                break;
            }
            let Some(PageData::Quant(Some(g))) = &d.slots[id].state else { continue };
            let payload = g.to_bytes();
            match store.write_page(PageKind::Quant, &payload) {
                Ok(Some(sh)) => {
                    d.slots[id].state = Some(PageData::SpilledQuant(sh));
                    moved += 1;
                }
                Ok(None) => break, // cold tier at capacity
                Err(e) => {
                    io_err = Some(e);
                    break;
                }
            }
        }
        drop(d);
        if moved > 0 {
            self.spilled.fetch_add(moved, Ordering::AcqRel);
            self.live.fetch_sub(moved, Ordering::AcqRel);
            for _ in 0..moved {
                self.arena.release_page(PageKind::Quant);
            }
        }
        match io_err {
            Some(e) => Err(e),
            None => Ok(moved),
        }
    }

    /// Hibernate: park EVERY resident page — FP buffers included — in the
    /// cold tier. The shard keeps its handles and resumes bit-identically
    /// when the pages fault back, so a hibernated session never
    /// re-prefills. Returns the number of pages moved.
    pub fn spill_all(&self) -> Result<usize> {
        let Some(store) = self.spill.clone() else { return Ok(0) };
        let _t = self.begin_transition();
        let mut moved_quant = 0usize;
        let mut moved_fp = 0usize;
        let mut io_err = None; // deferred, as in `spill_quant_pages`
        let mut d = self.lock();
        for id in 0..d.slots.len() {
            let (kind, payload) = match &d.slots[id].state {
                Some(PageData::Quant(Some(g))) => (PageKind::Quant, g.to_bytes()),
                // alloc-then-quantize window: an unwritten quant page has
                // no bytes yet; an empty payload restores the same state
                Some(PageData::Quant(None)) => (PageKind::Quant, Vec::new()),
                Some(PageData::Fp(v)) => (PageKind::Fp, encode_fp_page(v)),
                _ => continue,
            };
            match store.write_page(kind, &payload) {
                Ok(Some(sh)) => {
                    d.slots[id].state = Some(match kind {
                        PageKind::Quant => PageData::SpilledQuant(sh),
                        PageKind::Fp => PageData::SpilledFp(sh),
                    });
                    match kind {
                        PageKind::Quant => moved_quant += 1,
                        PageKind::Fp => moved_fp += 1,
                    }
                }
                Ok(None) => break, // cold tier at capacity — partial hibernate
                Err(e) => {
                    io_err = Some(e);
                    break;
                }
            }
        }
        drop(d);
        let moved = moved_quant + moved_fp;
        if moved > 0 {
            self.spilled.fetch_add(moved, Ordering::AcqRel);
            self.live.fetch_sub(moved, Ordering::AcqRel);
            for _ in 0..moved_quant {
                self.arena.release_page(PageKind::Quant);
            }
            for _ in 0..moved_fp {
                self.arena.release_page(PageKind::Fp);
            }
        }
        match io_err {
            Some(e) => Err(e),
            None => Ok(moved),
        }
    }

    /// Fault one cold page back into the arena (bit-identical restore).
    /// Ordering mirrors `alloc_impl`: reserve arena budget first, do file
    /// I/O without the shard lock, then install under the lock with an
    /// eviction re-check. `ArenaFull` means the caller must reclaim via
    /// the session manager — NEVER while holding this shard's lock — and
    /// retry.
    pub fn fault_page(&self, h: PageHandle) -> Result<FaultOutcome> {
        let store = match &self.spill {
            Some(s) => Arc::clone(s),
            None => return Ok(FaultOutcome::Resident),
        };
        let (sh, kind) = {
            let d = self.lock();
            d.check(h)?;
            match &d.slots[h.id as usize].state {
                Some(PageData::SpilledQuant(sh)) => (*sh, PageKind::Quant),
                Some(PageData::SpilledFp(sh)) => (*sh, PageKind::Fp),
                _ => return Ok(FaultOutcome::Resident),
            }
        };
        let _t = self.begin_transition();
        ensure!(!self.is_evicted(), "session {} was evicted", self.id);
        if !self.arena.try_reserve(kind) {
            return Ok(FaultOutcome::ArenaFull);
        }
        // Read WITHOUT consuming the cold slot; deserialize outside the
        // lock. The slot is handed back only after the restored page is
        // installed, so a failed read, checksum, decode, or install leaves
        // the cold page intact and re-faultable — never half-restored.
        let restored = store.read_page(sh).and_then(|(k, payload)| {
            ensure!(k == kind, "spill slot kind changed under fault");
            Ok(match kind {
                PageKind::Quant if payload.is_empty() => PageData::Quant(None),
                PageKind::Quant => {
                    PageData::Quant(Some(PackedGroup::from_bytes(&payload)?))
                }
                PageKind::Fp => PageData::Fp(decode_fp_page(&payload)?),
            })
        });
        let data = match restored {
            Ok(data) => data,
            Err(e) => {
                self.arena.release_page(kind);
                return Err(e);
            }
        };
        let mut d = self.lock();
        // Re-check under the lock (mirrors alloc_impl): retire may have
        // run between the peek and here — its `free_all` bumped the slot
        // generation and handed the cold slot back — so return the arena
        // budget and bail instead of resurrecting a freed page.
        if self.is_evicted() || d.check(h).is_err() {
            drop(d);
            self.arena.release_page(kind);
            bail!("session {} was evicted mid-restore", self.id);
        }
        match &d.slots[h.id as usize].state {
            Some(s) if s.is_spilled() => {}
            _ => {
                // A competing restore installed first; it also freed the
                // cold slot, so just hand the budget back.
                drop(d);
                self.arena.release_page(kind);
                return Ok(FaultOutcome::Resident);
            }
        }
        d.slots[h.id as usize].state = Some(data);
        drop(d);
        // The page is resident: NOW release the cold slot. Best-effort — a
        // racing retire may have bumped the slot generation and freed it
        // already (same resolution as in `free`).
        let _ = store.free_page(sh);
        self.spilled.fetch_sub(1, Ordering::AcqRel);
        self.live.fetch_add(1, Ordering::AcqRel);
        Ok(FaultOutcome::Restored)
    }

    /// Lock this session's page data for a batch of reads/writes — the
    /// ONE lock a steady-state draft/verify step takes.
    pub fn lock(&self) -> MutexGuard<'_, ShardData> {
        self.data.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Allocate one page against the global budget without any lock beyond
    /// this shard's own — but ONLY within the admission reservation:
    /// committed-page accounting is `max(reserved, live)`, so lock-free
    /// allocations under `reserved` can never erode the watermark
    /// headroom a concurrent admit is computing under the manager mutex.
    /// `Ok(None)` means the arena is full or the session would outgrow
    /// its reservation — the caller falls back to the manager-locked
    /// slow path ([`SessionShard::alloc_locked`]), which can LRU-evict
    /// and keeps the accounting consistent while `live` crosses
    /// `reserved`. (A session's data plane is single-threaded, so the
    /// reservation check is not racing same-shard allocations.)
    pub fn try_alloc(&self, kind: PageKind) -> Result<Option<PageHandle>> {
        if self.live_pages() >= self.reserved_pages() {
            return Ok(None);
        }
        self.alloc_impl(kind)
    }

    /// Manager-locked allocation (over-reservation growth, eviction
    /// retry): the caller holds the session-manager mutex.
    pub(crate) fn alloc_locked(&self, kind: PageKind) -> Result<Option<PageHandle>> {
        self.alloc_impl(kind)
    }

    fn alloc_impl(&self, kind: PageKind) -> Result<Option<PageHandle>> {
        ensure!(!self.is_evicted(), "session {} was evicted", self.id);
        if !self.arena.try_reserve(kind) {
            return Ok(None);
        }
        let mut d = self.lock();
        // Re-check under the shard lock: `retire` sets the flag BEFORE
        // taking this lock, so either we observe it here and hand the
        // budget back, or retire's `free_all` is still waiting on the
        // lock and will reclaim the page we are about to insert. Without
        // this, a page allocated between the flag store and `free_all`
        // would survive on an "evicted" shard — leaked from the global
        // budget once the session entry is gone.
        if self.is_evicted() {
            drop(d);
            self.arena.release_page(kind);
            bail!("session {} was evicted", self.id);
        }
        let data = match kind {
            PageKind::Quant => PageData::Quant(None),
            PageKind::Fp => PageData::Fp(vec![0.0; d.elems]),
        };
        let id = match d.free.pop() {
            Some(id) => {
                d.slots[id as usize].state = Some(data);
                id
            }
            None => {
                let id = d.slots.len() as u32;
                d.slots.push(Slot { gen: 0, state: Some(data) });
                id
            }
        };
        let gen = d.slots[id as usize].gen;
        self.live.fetch_add(1, Ordering::AcqRel);
        Ok(Some(PageHandle { id, gen }))
    }

    pub fn free(&self, h: PageHandle) -> Result<PageKind> {
        let mut d = self.lock();
        d.check(h)?;
        let slot = &mut d.slots[h.id as usize];
        let (kind, cold) = match slot.state.take() {
            Some(PageData::Quant(_)) => (PageKind::Quant, None),
            Some(PageData::Fp(_)) => (PageKind::Fp, None),
            Some(PageData::SpilledQuant(sh)) => (PageKind::Quant, Some(sh)),
            Some(PageData::SpilledFp(sh)) => (PageKind::Fp, Some(sh)),
            None => unreachable!("check() verified the slot is in use"),
        };
        slot.gen = slot.gen.wrapping_add(1);
        d.free.push(h.id);
        drop(d);
        match cold {
            // A spilled page holds a cold slot but no arena budget.
            Some(sh) => {
                self.spilled.fetch_sub(1, Ordering::AcqRel);
                if let Some(store) = &self.spill {
                    // Best-effort: a concurrent fault's restore may have
                    // freed the slot already (its install re-check sees
                    // our generation bump and backs out), so a stale
                    // handle here is that race resolving — not a leak.
                    let _ = store.free_page(sh);
                }
            }
            None => {
                self.live.fetch_sub(1, Ordering::AcqRel);
                self.arena.release_page(kind);
            }
        }
        Ok(kind)
    }

    /// Free every page — resident AND spilled (session release /
    /// eviction). Generation bumps make any handle a stale `PagedKvCache`
    /// still holds error cleanly; cold-tier slots are handed back too.
    pub fn free_all(&self) -> usize {
        let mut guard = self.lock();
        let d = &mut *guard; // split-borrow slots and the free list
        let mut freed_quant = 0usize;
        let mut freed_fp = 0usize;
        let mut cold: Vec<SpillHandle> = Vec::new();
        for (id, slot) in d.slots.iter_mut().enumerate() {
            match slot.state.take() {
                Some(PageData::Quant(_)) => freed_quant += 1,
                Some(PageData::Fp(_)) => freed_fp += 1,
                Some(PageData::SpilledQuant(sh)) | Some(PageData::SpilledFp(sh)) => {
                    cold.push(sh)
                }
                None => continue,
            }
            slot.gen = slot.gen.wrapping_add(1);
            d.free.push(id as u32);
        }
        drop(guard);
        let freed = freed_quant + freed_fp;
        if freed > 0 {
            self.live.fetch_sub(freed, Ordering::AcqRel);
        }
        if !cold.is_empty() {
            self.spilled.fetch_sub(cold.len(), Ordering::AcqRel);
            if let Some(store) = &self.spill {
                for sh in &cold {
                    // Best-effort for the same reason as in `free`.
                    let _ = store.free_page(*sh);
                }
            }
        }
        for _ in 0..freed_quant {
            self.arena.release_page(PageKind::Quant);
        }
        for _ in 0..freed_fp {
            self.arena.release_page(PageKind::Fp);
        }
        freed + cold.len()
    }

    /// Evict: reject future allocations and reclaim every page, resident
    /// and spilled. Called on the unified release path — `PagedKvCache`
    /// release and manager eviction both land here — so it is
    /// **idempotent**: the second call is a no-op. The flag is stored
    /// before `free_all` takes the data lock (see the re-check in
    /// `alloc_impl`).
    pub fn retire(&self) -> usize {
        let already = self.evicted.swap(true, Ordering::AcqRel);
        let freed = self.free_all();
        if already {
            debug_assert_eq!(
                freed, 0,
                "double retire of session {} freed pages: something allocated \
                 after eviction",
                self.id
            );
        }
        freed
    }

    /// Structural invariants of this shard (free-list consistency and the
    /// lock-free `live`/`spilled` mirrors matching the slot states).
    pub fn check_integrity(&self) -> Result<()> {
        let d = self.lock();
        d.check_integrity_inner()?;
        ensure!(
            d.live_slots() == self.live_pages(),
            "shard {}: live mirror {} != {} in-use slots",
            self.id,
            self.live_pages(),
            d.live_slots()
        );
        ensure!(
            d.spilled_slots() == self.spilled_pages(),
            "shard {}: spilled mirror {} != {} spilled slots",
            self.id,
            self.spilled_pages(),
            d.spilled_slots()
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::quant_group;

    fn arena(pages: usize) -> Arc<PagePool> {
        Arc::new(PagePool::new(PoolConfig {
            pages,
            page_tokens: 4,
            kv_dim: 2,
            ..PoolConfig::default()
        }))
    }

    fn group(p: &PagePool, seed: f32) -> PackedGroup {
        let n = p.cfg().page_tokens * p.cfg().kv_dim;
        let xs: Vec<f32> = (0..n).map(|i| seed + i as f32 * 0.25).collect();
        quant_group(&xs).unwrap()
    }

    fn alloc(s: &SessionShard, kind: PageKind) -> Result<PageHandle> {
        match s.try_alloc(kind)? {
            Some(h) => Ok(h),
            None => bail!("arena full"),
        }
    }

    #[test]
    fn alloc_free_roundtrip() {
        let a = arena(4);
        let s = SessionShard::new(1, a.clone(), 16);
        let h = alloc(&s, PageKind::Fp).unwrap();
        assert_eq!(a.pages_in_use(), 1);
        assert_eq!(s.live_pages(), 1);
        s.lock().fp_mut(h).unwrap()[0] = 3.5;
        assert_eq!(s.lock().fp(h).unwrap()[0], 3.5);
        s.free(h).unwrap();
        assert_eq!(a.pages_in_use(), 0);
        s.check_integrity().unwrap();
    }

    #[test]
    fn exhaustion_and_reuse() {
        let a = arena(2);
        let s = SessionShard::new(1, a.clone(), 16);
        let first = alloc(&s, PageKind::Fp).unwrap();
        let _b = alloc(&s, PageKind::Quant).unwrap();
        assert!(
            s.try_alloc(PageKind::Fp).unwrap().is_none(),
            "arena must report full, not error"
        );
        s.free(first).unwrap();
        let c = alloc(&s, PageKind::Quant).unwrap();
        assert_eq!(c.id(), first.id(), "freed slot is reused");
        s.check_integrity().unwrap();
    }

    #[test]
    fn stale_handle_rejected() {
        let a = arena(2);
        let s = SessionShard::new(1, a, 16);
        let h = alloc(&s, PageKind::Fp).unwrap();
        s.free(h).unwrap();
        assert!(s.free(h).is_err(), "double free must be rejected");
        let h2 = alloc(&s, PageKind::Fp).unwrap();
        assert_eq!(h2.id(), h.id());
        assert!(s.lock().fp(h).is_err(), "stale handle must not read new page");
    }

    #[test]
    fn shards_isolate_sessions_under_one_budget() {
        // Two shards on one 3-page arena: handles are shard-local, the
        // budget is global, and freeing one shard leaves the other intact.
        let a = arena(3);
        let s1 = SessionShard::new(7, a.clone(), 16);
        let s2 = SessionShard::new(9, a.clone(), 16);
        let h1 = alloc(&s1, PageKind::Fp).unwrap();
        let h2 = alloc(&s2, PageKind::Fp).unwrap();
        // shard-local ids both start at 0; the data does not alias
        assert_eq!(h1.id(), 0);
        assert_eq!(h2.id(), 0);
        s1.lock().fp_mut(h1).unwrap()[0] = 1.0;
        s2.lock().fp_mut(h2).unwrap()[0] = 2.0;
        assert_eq!(s1.lock().fp(h1).unwrap()[0], 1.0);
        assert_eq!(s2.lock().fp(h2).unwrap()[0], 2.0);
        let _h3 = alloc(&s2, PageKind::Quant).unwrap();
        assert!(s1.try_alloc(PageKind::Fp).unwrap().is_none(), "global budget");
        assert_eq!(s1.free_all(), 1);
        assert_eq!(a.pages_in_use(), 2);
        assert_eq!(s2.lock().fp(h2).unwrap()[0], 2.0, "other shard untouched");
        s1.check_integrity().unwrap();
        s2.check_integrity().unwrap();
    }

    #[test]
    fn retired_shard_rejects_alloc_and_reads() {
        let a = arena(4);
        let s = SessionShard::new(3, a.clone(), 16);
        let h = alloc(&s, PageKind::Fp).unwrap();
        assert_eq!(s.retire(), 1);
        assert_eq!(a.pages_in_use(), 0);
        let err = s.try_alloc(PageKind::Fp).unwrap_err().to_string();
        assert!(err.contains("evicted"), "got: {err}");
        assert_eq!(a.pages_in_use(), 0, "rejected alloc returned its budget");
        assert!(s.lock().fp(h).is_err(), "gen bump invalidates old handles");
    }

    #[test]
    fn quant_write_read() {
        let a = arena(2);
        let s = SessionShard::new(1, a.clone(), 16);
        let h = alloc(&s, PageKind::Quant).unwrap();
        assert!(s.lock().read_quant(h).is_err(), "unwritten page unreadable");
        let g = group(&a, -1.0);
        s.lock().write_quant(h, g.clone()).unwrap();
        assert_eq!(*s.lock().read_quant(h).unwrap(), g);
    }

    #[test]
    fn byte_accounting() {
        let a = arena(4);
        let s = SessionShard::new(1, a.clone(), 16);
        let elems = 8; // 4 tokens * 2 dims
        alloc(&s, PageKind::Quant).unwrap();
        alloc(&s, PageKind::Fp).unwrap();
        // packed quant page: two nibbles per byte + f32 scale/zero
        assert_eq!(a.host_bytes(), (elems + 8) + 4 * elems);
        assert_eq!(a.logical_bytes(), (elems + 4) + 2 * elems);
        assert!(a.logical_bytes() < a.host_bytes());
    }

    #[test]
    fn traffic_counters_are_lock_free_adds() {
        let a = arena(2);
        a.note_dequant_many(true, 3, 12);
        a.note_dequant_many(false, 1, 8);
        let t = a.traffic();
        assert_eq!(t.dequant_calls_draft, 3);
        assert_eq!(t.bytes_read_draft, 12);
        assert_eq!(t.dequant_calls_target, 1);
        assert_eq!(t.bytes_read_target, 8);
    }

    /// Property: random alloc/free sequences across several shards never
    /// corrupt the arena — counts stay consistent, nothing double-frees,
    /// nothing leaks, and the global budget holds.
    #[test]
    fn prop_alloc_free_invariants() {
        use crate::util::prop::{check, Config};
        check::<Vec<usize>, _>(
            Config { cases: 60, size: 48, ..Config::default() },
            |ops| {
                let a = arena(6);
                let shards: Vec<SessionShard> =
                    (0..4u64).map(|i| SessionShard::new(i, a.clone(), a.capacity())).collect();
                let mut live: Vec<(usize, PageHandle)> = Vec::new();
                for &op in ops {
                    match op % 3 {
                        0 | 1 => {
                            let owner = op % 4;
                            let kind =
                                if op % 2 == 0 { PageKind::Quant } else { PageKind::Fp };
                            match shards[owner].try_alloc(kind).unwrap() {
                                Some(h) => live.push((owner, h)),
                                None => {
                                    if a.pages_in_use() != a.capacity() {
                                        return false; // only fails when full
                                    }
                                }
                            }
                        }
                        _ => {
                            if !live.is_empty() {
                                let (owner, h) = live.remove(op % live.len());
                                if shards[owner].free(h).is_err() {
                                    return false;
                                }
                                // a second free of the same handle must fail
                                if shards[owner].free(h).is_ok() {
                                    return false;
                                }
                            }
                        }
                    }
                    if a.pages_in_use() != live.len() {
                        return false;
                    }
                    if shards.iter().any(|s| s.check_integrity().is_err()) {
                        return false;
                    }
                }
                for (owner, h) in live {
                    if shards[owner].free(h).is_err() {
                        return false;
                    }
                }
                a.pages_in_use() == 0
            },
        );
    }

    // ---- cold tier (spill / fault / hibernate) ----

    use crate::pool::tier::{SpillStore, TierPolicy};

    fn tiered(pages: usize, spill_cap: usize) -> (Arc<PagePool>, SessionShard) {
        let a = arena(pages);
        let store =
            SpillStore::new("", a.cfg().elems(), spill_cap, TierPolicy::default()).unwrap();
        let s = SessionShard::with_spill(1, a.clone(), 16, Some(store));
        (a, s)
    }

    #[test]
    fn spill_and_fault_roundtrip_is_bit_identical() {
        let (a, s) = tiered(4, 0);
        let h = alloc(&s, PageKind::Quant).unwrap();
        let g = group(&a, -2.5);
        s.lock().write_quant(h, g.clone()).unwrap();
        assert_eq!(s.spill_quant_pages(0).unwrap(), 1);
        assert_eq!(a.pages_in_use(), 0, "spilled page released its budget");
        assert_eq!(s.live_pages(), 0);
        assert_eq!(s.spilled_pages(), 1);
        assert!(s.lock().is_spilled(h).unwrap());
        let err = s.lock().read_quant(h).unwrap_err().to_string();
        assert!(err.contains("spilled"), "{err}");
        s.check_integrity().unwrap();
        assert_eq!(s.fault_page(h).unwrap(), FaultOutcome::Restored);
        assert_eq!(a.pages_in_use(), 1, "restore re-reserved the budget");
        assert_eq!(s.spilled_pages(), 0);
        assert_eq!(*s.lock().read_quant(h).unwrap(), g, "bit-identical restore");
        assert_eq!(s.fault_page(h).unwrap(), FaultOutcome::Resident);
        s.check_integrity().unwrap();
    }

    #[test]
    fn hibernate_spills_fp_and_unwritten_pages() {
        let (a, s) = tiered(4, 0);
        let hf = alloc(&s, PageKind::Fp).unwrap();
        for (i, v) in s.lock().fp_mut(hf).unwrap().iter_mut().enumerate() {
            *v = i as f32 * 0.5 - 1.0;
        }
        let want: Vec<f32> = s.lock().fp(hf).unwrap().to_vec();
        let hq = alloc(&s, PageKind::Quant).unwrap(); // never written
        assert_eq!(s.spill_all().unwrap(), 2);
        assert_eq!(a.pages_in_use(), 0, "hibernation released every page");
        assert_eq!(s.spilled_pages(), 2);
        s.check_integrity().unwrap();
        assert_eq!(s.fault_page(hf).unwrap(), FaultOutcome::Restored);
        assert_eq!(s.fault_page(hq).unwrap(), FaultOutcome::Restored);
        let got = s.lock().fp(hf).unwrap().to_vec();
        assert_eq!(want.len(), got.len());
        for (w, g) in want.iter().zip(&got) {
            assert_eq!(w.to_bits(), g.to_bits(), "fp restore is bit-exact");
        }
        let err = s.lock().read_quant(hq).unwrap_err().to_string();
        assert!(err.contains("never written"), "unwritten state survives: {err}");
        s.check_integrity().unwrap();
    }

    #[test]
    fn fault_reports_arena_full_without_losing_the_page() {
        let (a, s) = tiered(1, 0);
        let h = alloc(&s, PageKind::Quant).unwrap();
        s.lock().write_quant(h, group(&a, 1.0)).unwrap();
        assert_eq!(s.spill_quant_pages(0).unwrap(), 1);
        // another session takes the only arena page
        let other = SessionShard::new(2, a.clone(), 16);
        let oh = alloc(&other, PageKind::Fp).unwrap();
        assert_eq!(s.fault_page(h).unwrap(), FaultOutcome::ArenaFull);
        assert_eq!(s.spilled_pages(), 1, "page still safe in the cold tier");
        other.free(oh).unwrap();
        assert_eq!(s.fault_page(h).unwrap(), FaultOutcome::Restored);
        s.check_integrity().unwrap();
    }

    #[test]
    fn spill_stops_cleanly_when_cold_tier_full() {
        let (a, s) = tiered(4, 1);
        for seed in 0..2 {
            let h = alloc(&s, PageKind::Quant).unwrap();
            s.lock().write_quant(h, group(&a, seed as f32)).unwrap();
        }
        assert_eq!(s.spill_quant_pages(0).unwrap(), 1, "cap stops the batch");
        assert_eq!(s.live_pages(), 1);
        assert_eq!(s.spilled_pages(), 1);
        assert_eq!(a.pages_in_use(), 1);
        s.check_integrity().unwrap();
    }

    #[test]
    fn retire_is_idempotent_and_frees_cold_slots() {
        let (a, s) = tiered(4, 0);
        let h = alloc(&s, PageKind::Quant).unwrap();
        s.lock().write_quant(h, group(&a, 0.0)).unwrap();
        let _hf = alloc(&s, PageKind::Fp).unwrap();
        assert_eq!(s.spill_quant_pages(0).unwrap(), 1);
        let store = s.spill_store().unwrap().clone();
        assert_eq!(store.spilled_pages(), 1);
        assert_eq!(s.retire(), 2, "resident and spilled pages reclaimed");
        assert_eq!(a.pages_in_use(), 0);
        assert_eq!(store.spilled_pages(), 0, "cold slot handed back");
        assert_eq!(s.retire(), 0, "second retire is a no-op");
        assert!(s.fault_page(h).is_err(), "gen bump invalidates the handle");
        s.check_integrity().unwrap();
    }

    #[test]
    fn free_spilled_page_releases_cold_slot() {
        let (a, s) = tiered(4, 0);
        let h = alloc(&s, PageKind::Quant).unwrap();
        s.lock().write_quant(h, group(&a, 3.0)).unwrap();
        assert_eq!(s.spill_quant_pages(0).unwrap(), 1);
        assert_eq!(s.free(h).unwrap(), PageKind::Quant);
        assert_eq!(s.spilled_pages(), 0);
        assert_eq!(s.spill_store().unwrap().spilled_pages(), 0);
        assert_eq!(a.pages_in_use(), 0, "no arena budget was double-released");
        s.check_integrity().unwrap();
    }

    /// Satellite regression: a restore that fails (here: injected read
    /// faults exhausting the retry budget) must leave the cold page
    /// intact — the arena budget it reserved is returned, the spilled
    /// accounting is unchanged, and a later fault succeeds bit-identically.
    #[test]
    fn failed_restore_leaves_cold_page_refaultable() {
        use crate::util::fault::FaultInjector;
        let (a, s) = tiered(4, 0);
        let h = alloc(&s, PageKind::Quant).unwrap();
        let g = group(&a, -4.0);
        s.lock().write_quant(h, g.clone()).unwrap();
        assert_eq!(s.spill_quant_pages(0).unwrap(), 1);
        // budget 3 = exactly one fault_page's worth of attempts, all failing
        s.spill_store().unwrap().install_fault_injector(Arc::new(
            FaultInjector::parse(13, "spill_read:1000:3").unwrap(),
        ));
        assert!(s.fault_page(h).is_err(), "injected faults exhaust retries");
        assert_eq!(a.pages_in_use(), 0, "reserved budget was returned");
        assert_eq!(s.spilled_pages(), 1, "cold page survived the failure");
        assert_eq!(s.spill_store().unwrap().spilled_pages(), 1);
        s.check_integrity().unwrap();
        // injection budget spent: the same handle faults back cleanly
        assert_eq!(s.fault_page(h).unwrap(), FaultOutcome::Restored);
        assert_eq!(*s.lock().read_quant(h).unwrap(), g, "bit-identical");
        s.check_integrity().unwrap();
    }

    /// Same contract for non-retryable corruption: a checksum mismatch on
    /// restore refuses the page but does not consume the slot, so once the
    /// (injected, budgeted) corruption stops the page is recoverable.
    #[test]
    fn corrupt_restore_refused_without_consuming_the_slot() {
        use crate::util::fault::FaultInjector;
        let (a, s) = tiered(4, 0);
        let h = alloc(&s, PageKind::Quant).unwrap();
        let g = group(&a, 2.0);
        s.lock().write_quant(h, g.clone()).unwrap();
        assert_eq!(s.spill_quant_pages(0).unwrap(), 1);
        s.spill_store().unwrap().install_fault_injector(Arc::new(
            FaultInjector::parse(29, "spill_corrupt:1000:1").unwrap(),
        ));
        let err = s.fault_page(h).unwrap_err().to_string();
        assert!(err.contains("checksum"), "{err}");
        assert_eq!(s.spilled_pages(), 1, "slot not consumed by the refusal");
        assert_eq!(a.pages_in_use(), 0);
        s.check_integrity().unwrap();
        assert_eq!(s.fault_page(h).unwrap(), FaultOutcome::Restored);
        assert_eq!(*s.lock().read_quant(h).unwrap(), g);
    }

    #[test]
    fn transition_flag_raised_during_spill() {
        let (a, s) = tiered(4, 0);
        let h = alloc(&s, PageKind::Quant).unwrap();
        s.lock().write_quant(h, group(&a, 0.5)).unwrap();
        assert!(!s.in_transition());
        s.spill_quant_pages(0).unwrap();
        assert!(!s.in_transition(), "guard cleared after the batch");
    }
}
