//! Per-session paged view of the hierarchical cache: a block table over the
//! shared arena driven by the paper's `CacheTracker` state machine.
//!
//! Layout per session:
//!
//! * `groups[i]` — quant page holding committed tokens `[i·G, (i+1)·G)`;
//!   grows by exactly one page per flush (the paper's amortized 1/G
//!   quantization cost becomes one page allocation per G tokens).
//! * `fp[j]` — FP page holding buffer slots `[j·G, (j+1)·G)`; the double FP
//!   buffer (FB = 2G + tmax slots) is `ceil(FB/G)` pages allocated up
//!   front and mutated in place.
//!
//! Speculation rollback stays O(1): verify rewrites the drafted FP slots in
//! place, so rejecting tokens is only the tracker committing a smaller
//! count — no page traffic. A flush quantizes C_F1 *into a freshly
//! allocated page* and shifts C_F2 down, so a mid-flush failure (pool
//! exhausted, nothing evictable) surfaces as a clean error before any
//! state is lost.
//!
//! # Locking (the parallel-rounds contract)
//!
//! All page data lives in this session's [`SessionShard`]; every method
//! here locks ONLY that shard (uncontended when each session steps on its
//! own batcher worker) plus atomic adds on the global arena for byte and
//! traffic accounting. The session-manager mutex is touched exactly
//! twice in a session's life: once at construction (geometry check, shard
//! fetch, FP-page allocation) and once at [`PagedKvCache::release`] — and
//! on the slow allocation path when the arena is FULL (LRU eviction might
//! free pages) or the session outgrows its admission reservation.
//! Steady-state draft/verify/commit cycles, including flush-time page
//! allocation (a lock-free CAS on the arena budget, bounded by the
//! reservation), never acquire it.
//!
//! Steady-state reads go through [`PagedKvCache::read_token_into`] (one
//! token) and [`PagedKvCache::read_tokens_into`] (a verify window of t
//! contiguous slots): packed codes are dequantized lane-wise straight into
//! a caller scratch buffer — no whole-group dequantization, no heap
//! allocation (the cost model the paper's Table 4 kernels assume). The
//! windowed read takes the shard mutex ONCE and does one group lookup per
//! crossed group, so a γ-cycle's verify pays O(groups-crossed) lookups
//! instead of O(γ). Bulk quantization (prefill) fans out over the
//! process-wide shared pool sized by `PoolConfig::quant_workers`.
//!
//! Prefill comes in two shapes: one-shot ([`PagedKvCache::prefill`], a
//! G-multiple bucket) and chunked ([`PagedKvCache::prefill_extend`] per
//! chunk + [`PagedKvCache::prefill_finish`]), which quantizes full
//! G-groups incrementally as tokens arrive so a scheduler can spread an
//! O(prompt) prefill over O(chunk) slices. Both produce bit-identical
//! caches (pages, codes, byte accounting) for the same token stream.

use std::sync::Arc;

use anyhow::{bail, ensure, Context, Result};

use crate::cache::CacheTracker;
use crate::quant::{quant_group, quant_groups_parallel};
use crate::util::rng::Pcg32;
use crate::util::threadpool::PoolHandle;

use super::page::{FaultOutcome, PageHandle, PageKind, SessionId, SessionShard};
use super::session::SharedSessionManager;

/// Bound on fault-back retries when a concurrent reclaim keeps spilling
/// an operation's pages out from under it (each retry restores them
/// first, so forward progress needs adversarial timing to be this slow).
const FAULT_RETRIES: usize = 64;

/// Map from a session's logical cache to arena pages.
#[derive(Debug, Default, Clone)]
pub struct BlockTable {
    /// Quantized region, one page per committed G-token group.
    pub groups: Vec<PageHandle>,
    /// Double FP buffer pages (fixed once allocated).
    pub fp: Vec<PageHandle>,
}

/// One session's KV cache living entirely in its pool shard.
pub struct PagedKvCache {
    mgr: SharedSessionManager,
    /// This session's slice of the arena: the data plane runs on its lock.
    shard: Arc<SessionShard>,
    pub session: SessionId,
    table: BlockTable,
    tracker: Option<CacheTracker>,
    g: usize,
    d: usize,
    fb: usize,
    /// Quantized-region token capacity (the reservation, rounded to G).
    cap_tokens: usize,
    /// Handle onto the process-wide shared quantization pool (cloned out
    /// of the session manager at construction; submits happen without the
    /// manager mutex).
    quant: PoolHandle,
}

impl PagedKvCache {
    /// Allocate the FP buffer pages; the quantized region grows at prefill
    /// and flush time. The session must already be admitted.
    pub fn new(
        mgr: SharedSessionManager,
        session: SessionId,
        g: usize,
        d: usize,
        fb: usize,
        cap_tokens: usize,
    ) -> Result<PagedKvCache> {
        ensure!(g > 0 && d > 0 && fb >= 2 * g, "bad cache geometry");
        ensure!(cap_tokens % g == 0, "cap_tokens must be a multiple of G");
        let fp_pages = (fb + g - 1) / g;
        let mut table = BlockTable::default();
        let (quant, shard) = {
            let mut m = lock(&mgr);
            ensure!(
                m.pool().cfg().page_tokens == g && m.pool().cfg().kv_dim == d,
                "cache geometry (G={g}, d={d}) does not match pool ({}, {})",
                m.pool().cfg().page_tokens,
                m.pool().cfg().kv_dim
            );
            let quant = m.quant_handle();
            let shard = m.shard(session)?;
            // manager-locked allocation at construction: the slow path can
            // LRU-evict if the arena is already tight at admission time
            for _ in 0..fp_pages {
                table.fp.push(m.alloc(session, PageKind::Fp)?);
            }
            (quant, shard)
        };
        Ok(PagedKvCache {
            mgr,
            shard,
            session,
            table,
            tracker: None,
            g,
            d,
            fb,
            cap_tokens,
            quant,
        })
    }

    pub fn tracker(&self) -> Result<&CacheTracker> {
        self.tracker.as_ref().context("cache not prefilled")
    }

    fn tracker_mut(&mut self) -> Result<&mut CacheTracker> {
        self.tracker.as_mut().context("cache not prefilled")
    }

    pub fn table(&self) -> &BlockTable {
        &self.table
    }

    /// Tokens per page (the quantization group size G).
    pub fn page_tokens(&self) -> usize {
        self.g
    }

    /// Pages this session currently holds.
    pub fn pages(&self) -> usize {
        self.table.groups.len() + self.table.fp.len()
    }

    /// (logical, host) bytes of this session's cache. Pure arithmetic over
    /// the block table and the arena config — no lock.
    pub fn session_bytes(&self) -> (usize, usize) {
        let cfg = self.shard.arena().cfg();
        let logical = self.table.groups.len() * cfg.quant_page_logical_bytes()
            + self.table.fp.len() * cfg.fp_page_logical_bytes();
        let host = self.table.groups.len() * cfg.quant_page_host_bytes()
            + self.table.fp.len() * cfg.fp_page_host_bytes();
        (logical, host)
    }

    /// Allocate one page: the lock-free shard/arena fast path (bounded by
    /// the admission reservation), falling back to the manager-locked
    /// slow path (tier reclaim, over-reservation growth) when the arena
    /// is full or the reservation is exhausted. A reservation covers the
    /// whole decode (`pool_pages_for_request` sizes prompt + budget), so
    /// steady-state flushes take no global lock.
    fn alloc_page(&self, kind: PageKind) -> Result<PageHandle> {
        if let Some(h) = self.shard.try_alloc(kind)? {
            return Ok(h);
        }
        lock(&self.mgr).alloc(self.session, kind)
    }

    // ---- cold-tier fault-back --------------------------------------------

    /// Restore any of `pages` parked in the cold tier. On `ArenaFull` the
    /// manager reclaims (page-granular spill first, eviction last — never
    /// while a shard lock is held) and the fault retries. Emits one
    /// `Restore` (on-demand) or `FetchAhead` (speculative) trace event
    /// covering the batch and splits the tier counters into
    /// `restore_faults` vs `fetch_ahead_hits` accordingly.
    fn fault_pages(&self, pages: &[PageHandle], speculative: bool) -> Result<usize> {
        let t0 = std::time::Instant::now();
        let mut restored = 0usize;
        for &h in pages {
            loop {
                match self.shard.fault_page(h)? {
                    FaultOutcome::Resident => break,
                    FaultOutcome::Restored => {
                        restored += 1;
                        break;
                    }
                    FaultOutcome::ArenaFull => {
                        let outcome = lock(&self.mgr).reclaim(Some(self.session));
                        if !outcome.progressed() {
                            bail!(
                                "arena exhausted faulting session {} back from the cold tier",
                                self.session
                            );
                        }
                    }
                }
            }
        }
        if restored > 0 {
            if let Some(store) = self.shard.spill_store() {
                store.note_restore(restored, speculative);
            }
            let us = t0.elapsed().as_micros() as u64;
            crate::trace::emit(if speculative {
                crate::trace::PhaseEvent::FetchAhead { pages: restored, us }
            } else {
                crate::trace::PhaseEvent::Restore { pages: restored, us }
            });
        }
        Ok(restored)
    }

    /// Fault the FP buffer back in (hibernation spills it wholesale).
    /// Allocation-free no-op when the shard has nothing spilled.
    fn ensure_fp_resident(&self) -> Result<()> {
        if self.shard.spilled_pages() == 0 {
            return Ok(());
        }
        self.fault_pages(&self.table.fp, false).map(|_| ())
    }

    /// Fault back any cold pages the committed window `range` touches.
    /// The resident fast path is one atomic load — no lock, no allocation.
    fn ensure_window_resident(&self, range: &std::ops::Range<usize>) -> Result<()> {
        if self.shard.spilled_pages() == 0 {
            return Ok(());
        }
        let tr = self.tracker()?;
        let mut pages: Vec<PageHandle> = Vec::new();
        let mut pos = range.start;
        while pos < range.end.min(tr.n_q) {
            let gi = pos / self.g;
            pages.push(self.table.groups[gi]);
            pos = (gi + 1) * self.g;
        }
        if range.end > tr.n_q {
            let first = range.start.max(tr.n_q) - tr.n_q;
            let n = range.end - range.start.max(tr.n_q);
            for (pi, _, _, _) in fp_spans(self.g, self.d, first, n) {
                pages.push(self.table.fp[pi]);
            }
        }
        self.fault_pages(&pages, false).map(|_| ())
    }

    /// Run `body`, faulting cold pages back (via `ensure`) and retrying
    /// when a concurrent reclaim spills them mid-operation. Resident
    /// pages never hit the retry arm, so the fast path costs nothing.
    fn with_resident<T>(
        &self,
        ensure: impl Fn(&Self) -> Result<()>,
        mut body: impl FnMut() -> Result<T>,
    ) -> Result<T> {
        let mut attempts = 0usize;
        loop {
            ensure(self)?;
            match body() {
                Err(e)
                    if attempts < FAULT_RETRIES
                        && e.to_string().contains("is spilled") =>
                {
                    attempts += 1;
                }
                other => return other,
            }
        }
    }

    // ---- FP buffer slots -------------------------------------------------

    fn write_fp_slot(&mut self, slot: usize, vals: &[f32]) -> Result<()> {
        ensure!(vals.len() == self.d, "kv vector dim {} != {}", vals.len(), self.d);
        ensure!(slot < self.fb, "fp slot {slot} out of buffer (FB={})", self.fb);
        let off = (slot % self.g) * self.d;
        let page = self.table.fp[slot / self.g];
        self.with_resident(
            |c| c.ensure_fp_resident(),
            || {
                let mut s = self.shard.lock();
                s.fp_mut(page)?[off..off + self.d].copy_from_slice(vals);
                Ok(())
            },
        )
    }

    /// Zero-allocation FP read; the single home of the slot → (page,
    /// offset) mapping shared with `write_fp_slot`.
    fn read_fp_slot_into(&self, slot: usize, out: &mut [f32]) -> Result<()> {
        ensure!(slot < self.fb, "fp slot {slot} out of buffer (FB={})", self.fb);
        let off = (slot % self.g) * self.d;
        let page = self.table.fp[slot / self.g];
        self.with_resident(
            |c| c.ensure_fp_resident(),
            || {
                let s = self.shard.lock();
                out.copy_from_slice(&s.fp(page)?[off..off + self.d]);
                Ok(())
            },
        )
    }

    fn read_fp_slot(&self, slot: usize) -> Result<Vec<f32>> {
        let mut out = vec![0.0f32; self.d];
        self.read_fp_slot_into(slot, &mut out)?;
        Ok(out)
    }

    // ---- lifecycle -------------------------------------------------------

    /// Prefill a padded bucket of `padded_len` tokens (multiple of G,
    /// ≥ 2G): quantize the leading `padded_len − G` tokens into fresh quant
    /// pages, keep the trailing G tokens full-precision in C_F1. `kv(p)`
    /// yields the d-dim KV vector of position `p`. One-shot wrapper over
    /// [`PagedKvCache::prefill_finish`] (which accepts arbitrary lengths;
    /// this entry point keeps the classic bucket contract).
    pub fn prefill(
        &mut self,
        padded_len: usize,
        kv: &dyn Fn(usize) -> Vec<f32>,
    ) -> Result<()> {
        ensure!(
            padded_len % self.g == 0 && padded_len >= 2 * self.g,
            "padded prefill of {padded_len} tokens is not a bucket of G={}",
            self.g
        );
        self.prefill_finish(padded_len, kv)
    }

    /// Incremental (chunked) prefill: with `n_seen` prompt tokens available
    /// so far, quantize and flush every full G-group that is certain to
    /// land in the quantized region *regardless of the final prompt
    /// length* — group k is safe once `n_seen ≥ (k+2)·G`, because the
    /// finalized FP tail always keeps at least G trailing tokens. Already
    /// written groups are skipped, so driving this once per chunk costs
    /// O(chunk) per call, and the final cache state is bit-identical to a
    /// one-shot [`PagedKvCache::prefill`] of the same tokens. Quantization
    /// fans out over the process-wide shared pool.
    pub fn prefill_extend(
        &mut self,
        n_seen: usize,
        kv: &dyn Fn(usize) -> Vec<f32>,
    ) -> Result<()> {
        ensure!(self.tracker.is_none(), "cache already prefilled");
        let safe_groups = n_seen.saturating_sub(self.g) / self.g;
        self.quantize_prefill_groups(safe_groups, kv)
    }

    /// Final prefill step for a context of `total` tokens (any length
    /// ≥ 2G): quantizes the remaining leading groups not yet written by
    /// [`PagedKvCache::prefill_extend`], fills the FP buffer with the
    /// trailing `total − n_q ∈ [G, 2G)` tokens, and installs the tracker.
    pub fn prefill_finish(
        &mut self,
        total: usize,
        kv: &dyn Fn(usize) -> Vec<f32>,
    ) -> Result<()> {
        ensure!(self.tracker.is_none(), "cache already prefilled");
        ensure!(
            total >= 2 * self.g,
            "prefill of {total} tokens is under the 2G={} minimum",
            2 * self.g
        );
        let n_q = (total - self.g) / self.g * self.g;
        ensure!(
            self.table.groups.len() * self.g <= n_q,
            "prefill_extend wrote {} groups past the final region ({n_q} tokens)",
            self.table.groups.len()
        );
        self.quantize_prefill_groups(n_q / self.g, kv)?;
        for (slot, pos) in (n_q..total).enumerate() {
            let v = kv(pos);
            self.write_fp_slot(slot, &v)?;
        }
        self.tracker = Some(CacheTracker::after_prefill(
            total,
            self.g,
            self.fb,
            self.cap_tokens,
        ));
        Ok(())
    }

    /// Quantize prefill groups `[groups_written, target_groups)` into fresh
    /// quant pages. Quantize in bounded batches: the fan-out sees several
    /// groups at once, but transient f32 staging stays O(batch · G · d)
    /// instead of the whole region — serial (workers <= 1) keeps the old
    /// one-group-at-a-time peak exactly.
    fn quantize_prefill_groups(
        &mut self,
        target_groups: usize,
        kv: &dyn Fn(usize) -> Vec<f32>,
    ) -> Result<()> {
        ensure!(
            target_groups * self.g <= self.cap_tokens,
            "prefill of {} groups exceeds reserved quant capacity {} tokens",
            target_groups,
            self.cap_tokens
        );
        let batch = if self.quant.size() <= 1 { 1 } else { 4 * self.quant.size() };
        let mut gi = self.table.groups.len();
        while gi < target_groups {
            let end = (gi + batch).min(target_groups);
            let mut flats = Vec::with_capacity(end - gi);
            for b in gi..end {
                let mut flat = Vec::with_capacity(self.g * self.d);
                for t in 0..self.g {
                    let v = kv(b * self.g + t);
                    ensure!(v.len() == self.d, "kv vector dim {} != {}", v.len(), self.d);
                    flat.extend_from_slice(&v);
                }
                flats.push(flat);
            }
            let groups = quant_groups_parallel(flats, &self.quant)
                .context("prefill quantization")?;
            for group in groups {
                let page = self.alloc_page(PageKind::Quant)?;
                self.shard.lock().write_quant(page, group)?;
                self.table.groups.push(page);
            }
            gi = end;
        }
        Ok(())
    }

    /// Begin a speculation cycle (records the O(1) rollback point). With
    /// tiering enabled this is also the fetch-ahead point: cold pages the
    /// cycle is about to touch are restored speculatively, before any
    /// read blocks on them.
    pub fn begin_cycle(&mut self) -> Result<()> {
        self.tracker_mut()?.begin_cycle();
        self.fetch_ahead()
    }

    /// Speculatively restore the pages the coming cycle will touch — the
    /// FP buffer (draft writes and verify rewrites land there) plus the
    /// newest N quant groups, where N is the store's adaptive fetch-ahead
    /// depth: it starts at 1 (the verify window's usual left edge) and is
    /// steered between 1 and `TierPolicy::fetch_ahead_max` by an EWMA of
    /// the observed on-demand fault rate, so a session whose reads keep
    /// blocking on cold pages prefetches deeper while a warm-resident one
    /// stays minimal. Gated on `TierPolicy::fetch_ahead`;
    /// allocation-free when nothing is spilled.
    fn fetch_ahead(&self) -> Result<()> {
        if self.shard.spilled_pages() == 0 {
            return Ok(());
        }
        let depth = match self.shard.spill_store() {
            Some(store) if store.policy().fetch_ahead => store.fetch_ahead_depth(),
            _ => return Ok(()),
        };
        let mut pages = self.table.fp.clone();
        let depth = depth.min(self.table.groups.len());
        pages.extend(self.table.groups.iter().rev().take(depth).copied());
        self.fault_pages(&pages, true).map(|_| ())
    }

    /// Write the i-th cycle slot (draft KV on the way out, target KV on the
    /// verify rewrite — both land on the same page slot).
    pub fn write_cycle_slot(&mut self, i: usize, vals: &[f32]) -> Result<usize> {
        let slot = self.tracker()?.draft_slot(i)?;
        self.write_fp_slot(slot, vals)?;
        Ok(slot)
    }

    /// Write `vals.len() / d` contiguous cycle slots starting at cycle slot
    /// `first` under ONE shard lock (the verify rewrite of a whole
    /// γ-window; the per-token [`PagedKvCache::write_cycle_slot`] pays one
    /// lock per slot). One contiguous copy per crossed FP page.
    pub fn write_cycle_slots(&mut self, first: usize, vals: &[f32]) -> Result<()> {
        ensure!(
            !vals.is_empty() && vals.len() % self.d == 0,
            "cycle window of {} floats is not a whole number of d={} vectors",
            vals.len(),
            self.d
        );
        let t = vals.len() / self.d;
        let tr = self.tracker()?;
        let s0 = tr.draft_slot(first)?;
        // the last slot's check bounds the whole window (slots are base+i)
        tr.draft_slot(first + t - 1)?;
        self.with_resident(
            |c| c.ensure_fp_resident(),
            || {
                let mut s = self.shard.lock();
                for (pi, po, off, len) in fp_spans(self.g, self.d, s0, t) {
                    s.fp_mut(self.table.fp[pi])?[po..po + len]
                        .copy_from_slice(&vals[off..off + len]);
                }
                Ok(())
            },
        )
    }

    /// Commit a cycle; flush C_F1 into a fresh quant page if the double
    /// buffer filled.
    pub fn commit_cycle(&mut self, accepted: usize, verify_len: usize) -> Result<()> {
        let flush = self.tracker_mut()?.commit_cycle(accepted, verify_len)?;
        if flush {
            self.flush()?;
        }
        self.tracker()?.check_invariants()
    }

    /// One autoregressive commit: KV for the fed token lands at the buffer
    /// tail.
    pub fn commit_ar(&mut self, vals: &[f32]) -> Result<()> {
        let slot = self.tracker()?.n_f;
        self.write_fp_slot(slot, vals)?;
        let flush = self.tracker_mut()?.commit_ar();
        if flush {
            self.flush()?;
        }
        self.tracker()?.check_invariants()
    }

    /// Quantize C_F1 into a newly allocated page and shift C_F2 → C_F1.
    /// This is the hot → warm tier demotion: a page's worth of FP KV
    /// becomes a quantized group, counted on the tier stats when a spill
    /// store is attached.
    fn flush(&mut self) -> Result<()> {
        let t0 = std::time::Instant::now();
        let out = self.flush_inner();
        if out.is_ok() {
            if let Some(store) = self.shard.spill_store() {
                store.note_demotion();
            }
        }
        crate::trace::emit(crate::trace::PhaseEvent::QuantFlush {
            us: t0.elapsed().as_micros() as u64,
        });
        out
    }

    fn flush_inner(&mut self) -> Result<()> {
        let n_f = self.tracker()?.n_f;
        ensure!(n_f >= 2 * self.g, "flush without a full C_F2");
        ensure!(
            (self.table.groups.len() + 1) * self.g <= self.cap_tokens,
            "quant region would exceed reserved capacity {} tokens",
            self.cap_tokens
        );
        let mut flat = Vec::with_capacity(self.g * self.d);
        for t in 0..self.g {
            flat.extend_from_slice(&self.read_fp_slot(t)?);
        }
        let group = quant_group(&flat).context("flush quantization")?;
        let page = self.alloc_page(PageKind::Quant)?;
        self.shard.lock().write_quant(page, group)?;
        self.table.groups.push(page);
        // Shift the surviving buffer tail down by G slots.
        let mut tail = Vec::with_capacity((n_f - self.g) * self.d);
        for t in self.g..n_f {
            tail.extend_from_slice(&self.read_fp_slot(t)?);
        }
        for (i, chunk) in tail.chunks_exact(self.d).enumerate() {
            self.write_fp_slot(i, chunk)?;
        }
        self.tracker_mut()?.flush()
    }

    // ---- reads (through page handles) ------------------------------------

    /// KV vector of committed position `pos`, read through the block
    /// table: quantized region pages are dequantized via the draft (INT4)
    /// or target (INT8) plane; buffer slots come back full-precision.
    /// Allocating wrapper over [`PagedKvCache::read_token_into`].
    pub fn read_token(&self, pos: usize, draft: bool) -> Result<Vec<f32>> {
        let mut out = vec![0.0f32; self.d];
        self.read_token_into(pos, draft, &mut out)?;
        Ok(out)
    }

    /// Zero-allocation read of committed position `pos` into `out` (len d).
    /// Single-token wrapper over [`PagedKvCache::read_tokens_into`] — this
    /// is the draft steady-state hot path; only the requested token's d
    /// packed codes are touched, never the whole G·d group, and nothing is
    /// heap-allocated.
    pub fn read_token_into(&self, pos: usize, draft: bool, out: &mut [f32]) -> Result<()> {
        self.read_tokens_into(pos..pos + 1, draft, out)
    }

    /// Zero-allocation batched read of the committed window `range` into
    /// `out` (len `range.len() * d`) — the verify hot path. The SHARD
    /// mutex is taken ONCE for the whole window and the quantized region
    /// costs one block-table/shard lookup per *crossed group* (lane-wise
    /// span dequant), so a γ-token verify window pays O(groups-crossed)
    /// lookups instead of O(γ) lock/lookup round-trips. FP-buffer slots
    /// are copied one contiguous span per crossed page. Dequant calls and
    /// packed bytes touched are recorded on the arena's atomic
    /// [`super::session::CacheTraffic`] counters exactly as per-token
    /// reads would — no global lock anywhere on this path.
    pub fn read_tokens_into(
        &self,
        range: std::ops::Range<usize>,
        draft: bool,
        out: &mut [f32],
    ) -> Result<()> {
        ensure!(
            out.len() == range.len() * self.d,
            "out buffer holds {} floats, window {:?} x dim {} needs {}",
            out.len(),
            range,
            self.d,
            range.len() * self.d
        );
        if range.is_empty() {
            return Ok(());
        }
        let tr = self.tracker()?;
        ensure!(
            range.end <= tr.n_q + tr.n_f,
            "window {range:?} beyond context ({} tokens)",
            tr.n_q + tr.n_f
        );
        self.with_resident(
            |c| c.ensure_window_resident(&range),
            || self.read_window_resident(range.clone(), draft, out),
        )
    }

    /// The resident body of [`PagedKvCache::read_tokens_into`]: errors
    /// (instead of faulting) if the window touches a cold page, so the
    /// wrapper can restore and retry without this path ever allocating.
    fn read_window_resident(
        &self,
        range: std::ops::Range<usize>,
        draft: bool,
        out: &mut [f32],
    ) -> Result<()> {
        let tr = self.tracker()?;
        let s = self.shard.lock();
        let mut pos = range.start;
        let mut off = 0usize;
        // quantized region: one group lookup + one lane-wise span per group
        while pos < range.end.min(tr.n_q) {
            let gi = pos / self.g;
            let end = ((gi + 1) * self.g).min(range.end).min(tr.n_q);
            let k = end - pos;
            {
                let group = s.read_quant(self.table.groups[gi])?;
                group.dequant_span_into(
                    (pos % self.g) * self.d,
                    draft,
                    &mut out[off..off + k * self.d],
                );
            }
            // draft touches the upper plane only; target reads both
            let plane = self.d.div_ceil(2) as u64;
            let bytes = k as u64 * if draft { plane } else { 2 * plane };
            self.shard.arena().note_dequant_many(draft, k as u64, bytes);
            pos = end;
            off += k * self.d;
        }
        // FP buffer tail: one contiguous copy per crossed page
        if pos < range.end {
            let first = pos - tr.n_q;
            let n = range.end - pos;
            let base = off;
            for (pi, po, span_off, len) in fp_spans(self.g, self.d, first, n) {
                out[base + span_off..base + span_off + len]
                    .copy_from_slice(&s.fp(self.table.fp[pi])?[po..po + len]);
            }
        }
        Ok(())
    }

    /// Zero-allocation batched read of `out.len() / d` cycle slots starting
    /// at cycle slot `first` — the drafted, NOT-yet-committed window the
    /// verify pass just rewrote. Committed positions go through
    /// [`PagedKvCache::read_tokens_into`]; cycle slots live past `n_f`, so
    /// they are addressed in draft-slot space. One shard lock, one
    /// contiguous copy per crossed FP page.
    pub fn read_cycle_slots_into(&self, first: usize, out: &mut [f32]) -> Result<()> {
        ensure!(
            !out.is_empty() && out.len() % self.d == 0,
            "cycle window of {} floats is not a whole number of d={} vectors",
            out.len(),
            self.d
        );
        let t = out.len() / self.d;
        let tr = self.tracker()?;
        let s0 = tr.draft_slot(first)?;
        // the last slot's check bounds the whole window (slots are base+i)
        tr.draft_slot(first + t - 1)?;
        self.with_resident(
            |c| c.ensure_fp_resident(),
            || {
                let s = self.shard.lock();
                for (pi, po, off, len) in fp_spans(self.g, self.d, s0, t) {
                    out[off..off + len]
                        .copy_from_slice(&s.fp(self.table.fp[pi])?[po..po + len]);
                }
                Ok(())
            },
        )
    }

    /// Reconstruction-error bound of group `gi` for the chosen plane
    /// (paper §4.2): used by the mock decoder's read-back validation.
    pub fn group_error_bound(&self, gi: usize, draft: bool) -> Result<f32> {
        ensure!(gi < self.table.groups.len(), "group {gi} out of range");
        let h = self.table.groups[gi];
        self.with_resident(
            |c| {
                if c.shard.spilled_pages() > 0 {
                    c.fault_pages(&[h], false)?;
                }
                Ok(())
            },
            || {
                let s = self.shard.lock();
                let group = s.read_quant(h)?;
                let (e8, e4) = crate::quant::error_bounds(group);
                Ok(if draft { e4 } else { e8 })
            },
        )
    }

    /// Move group `gi` to a freshly allocated page (defragmentation /
    /// tiering primitive). The quantized codes move verbatim, so dequant
    /// output is bit-identical afterwards.
    pub fn relocate_group(&mut self, gi: usize) -> Result<()> {
        ensure!(gi < self.table.groups.len(), "group {gi} out of range");
        let old = self.table.groups[gi];
        if self.shard.spilled_pages() > 0 {
            self.fault_pages(&[old], false)?;
        }
        let data = self.shard.lock().read_quant(old)?.clone();
        let new = self.alloc_page(PageKind::Quant)?;
        self.shard.lock().write_quant(new, data)?;
        self.shard.free(old)?;
        self.table.groups[gi] = new;
        Ok(())
    }

    /// Return every page to the pool and forget the session (one manager
    /// lock — the session leaves the admission books here). Routes
    /// through the shard's idempotent `retire()`, which also frees any
    /// cold-tier slots the session still holds.
    pub fn release(&mut self) {
        lock(&self.mgr).release(self.session);
        self.table = BlockTable::default();
        self.tracker = None;
    }
}

pub(crate) fn lock(
    mgr: &SharedSessionManager,
) -> std::sync::MutexGuard<'_, super::session::SessionManager> {
    mgr.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Contiguous FP-page spans covering `n` buffer slots starting at slot
/// `first`: yields `(page_idx, page_offset, out_offset, len)` in f32
/// elements, one item per crossed page. The single home of the
/// slot → (page, offset) span arithmetic shared by the batched cycle
/// writer/reader and `read_tokens_into`'s FP tail.
fn fp_spans(
    g: usize,
    d: usize,
    first: usize,
    n: usize,
) -> impl Iterator<Item = (usize, usize, usize, usize)> {
    let mut slot = first;
    let mut off = 0usize;
    std::iter::from_fn(move || {
        if slot >= first + n {
            return None;
        }
        let page_idx = slot / g;
        let end = ((page_idx + 1) * g).min(first + n);
        let k = end - slot;
        let item = (page_idx, (slot % g) * d, off, k * d);
        slot = end;
        off += k * d;
        Some(item)
    })
}

/// Deterministic d-dim KV vector for (position, token) — the mock model's
/// "KV projection", shared by decoder and tests so read-back validation can
/// recompute expected values. Allocating wrapper over [`mock_kv_into`].
pub fn mock_kv(pos: usize, token: i32, d: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; d];
    mock_kv_into(pos, token, &mut out);
    out
}

/// Zero-allocation variant of [`mock_kv`]: fills `out` (len d) in place so
/// the decoder's steady-state draft/verify/AR paths can reuse one scratch
/// buffer instead of allocating a vector per step.
pub fn mock_kv_into(pos: usize, token: i32, out: &mut [f32]) {
    let seed = ((pos as u64) << 32) ^ (token as u32 as u64) ^ 0x9E37_79B9_7F4A_7C15;
    let mut rng = Pcg32::new(seed);
    for o in out.iter_mut() {
        *o = rng.uniform() as f32 * 4.0 - 2.0;
    }
}

#[cfg(test)]
mod tests {
    use super::super::page::PoolConfig;
    use super::super::session::shared;
    use super::*;

    const G: usize = 8;
    const D: usize = 2;
    const TMAX: usize = 4;
    const FB: usize = 2 * G + TMAX;

    fn pool_mgr(pages: usize) -> SharedSessionManager {
        pool_mgr_workers(pages, 1)
    }

    fn pool_mgr_workers(pages: usize, quant_workers: usize) -> SharedSessionManager {
        shared(PoolConfig {
            pages,
            page_tokens: G,
            kv_dim: D,
            high_watermark: 1.0,
            low_watermark: 1.0,
            quant_workers,
            ..PoolConfig::default()
        })
        .unwrap()
    }

    /// Manager with the cold tier enabled (spill store backed by a temp
    /// file, unbounded slots).
    fn tiered_mgr(pages: usize, spill_pages: usize) -> SharedSessionManager {
        shared(PoolConfig {
            pages,
            page_tokens: G,
            kv_dim: D,
            high_watermark: 1.0,
            low_watermark: 1.0,
            quant_workers: 1,
            spill_pages,
            ..PoolConfig::default()
        })
        .unwrap()
    }

    fn cache(mgr: &SharedSessionManager, session: SessionId, cap_groups: usize) -> PagedKvCache {
        lock(mgr)
            .admit(session, cap_groups + (FB + G - 1) / G, false)
            .unwrap();
        PagedKvCache::new(mgr.clone(), session, G, D, FB, cap_groups * G).unwrap()
    }

    fn prefilled(mgr: &SharedSessionManager, session: SessionId, buckets: usize) -> PagedKvCache {
        let mut c = cache(mgr, session, buckets + 4);
        c.prefill(buckets * G, &|p| mock_kv(p, p as i32, D)).unwrap();
        c
    }

    #[test]
    fn prefill_layout_and_reads() {
        let mgr = pool_mgr(32);
        let c = prefilled(&mgr, 1, 3); // 24 tokens: 2 quant groups + full C_F1
        let tr = c.tracker().unwrap();
        assert_eq!(tr.n_q, 2 * G);
        assert_eq!(tr.n_f, G);
        assert_eq!(c.table().groups.len(), 2);
        assert_eq!(c.table().fp.len(), (FB + G - 1) / G);
        // FP region reads back exactly
        for pos in 2 * G..3 * G {
            assert_eq!(c.read_token(pos, false).unwrap(), mock_kv(pos, pos as i32, D));
        }
        // quantized region reads back within the paper's error bounds
        for pos in 0..2 * G {
            let want = mock_kv(pos, pos as i32, D);
            for (draft, _) in [(false, "int8"), (true, "int4")] {
                let got = c.read_token(pos, draft).unwrap();
                let bound = c.group_error_bound(pos / G, draft).unwrap();
                for (w, g) in want.iter().zip(&got) {
                    assert!((w - g).abs() <= bound * 1.01 + 1e-6, "{w} vs {g}");
                }
            }
        }
    }

    #[test]
    fn spec_cycles_flush_and_rollback() {
        let mgr = pool_mgr(32);
        let mut c = prefilled(&mgr, 1, 2);
        let mut pos = 2 * G; // next cache position to write
        for cycle in 0..10 {
            c.begin_cycle().unwrap();
            let t = 1 + (cycle % TMAX); // verify length this cycle
            for i in 0..t {
                c.write_cycle_slot(i, &mock_kv(pos + i, (pos + i) as i32, D)).unwrap();
            }
            let accepted = t - 1; // one rejected unless t == 1
            c.commit_cycle(accepted, t).unwrap();
            pos += accepted + 1;
            let tr = c.tracker().unwrap();
            assert_eq!(tr.context_len(), pos);
        }
        // everything still readable through the (grown) block table
        for p in 0..pos {
            assert_eq!(c.read_token(p, false).unwrap().len(), D);
        }
        assert!(c.table().groups.len() >= 2, "flushes grew the quant region");
        c.release();
        assert_eq!(lock(&mgr).pool().pages_in_use(), 0);
    }

    #[test]
    fn ar_commits_flush() {
        let mgr = pool_mgr(32);
        let mut c = prefilled(&mgr, 2, 2);
        let before = c.table().groups.len();
        for i in 0..3 * G {
            let pos = 2 * G + i;
            c.commit_ar(&mock_kv(pos, pos as i32, D)).unwrap();
        }
        assert!(c.table().groups.len() > before);
        let tr = c.tracker().unwrap();
        assert_eq!(tr.context_len(), 2 * G + 3 * G);
        c.release();
    }

    #[test]
    fn relocation_is_bit_identical() {
        let mgr = pool_mgr(32);
        let mut c = prefilled(&mgr, 1, 3);
        let before: Vec<Vec<f32>> =
            (0..G).map(|p| c.read_token(p, false).unwrap()).collect();
        let before_draft: Vec<Vec<f32>> =
            (0..G).map(|p| c.read_token(p, true).unwrap()).collect();
        let old_page = c.table().groups[0];
        c.relocate_group(0).unwrap();
        assert_ne!(c.table().groups[0], old_page, "group moved pages");
        for p in 0..G {
            assert_eq!(c.read_token(p, false).unwrap(), before[p], "int8 plane");
            assert_eq!(c.read_token(p, true).unwrap(), before_draft[p], "int4 plane");
        }
        lock(&mgr).check_integrity().unwrap();
        c.release();
    }

    #[test]
    fn pool_exhaustion_is_clean_error() {
        // 3 FP pages + 1 quant page fit; the first flush needs a second
        // quant page and must fail with an error, not corrupt state.
        let mgr = pool_mgr(4);
        lock(&mgr).admit(1, 4, false).unwrap();
        let mut c = PagedKvCache::new(mgr.clone(), 1, G, D, FB, 8 * G).unwrap();
        c.prefill(2 * G, &|p| mock_kv(p, p as i32, D)).unwrap();
        let mut failed = false;
        for i in 0..2 * G {
            let pos = 2 * G + i;
            if c.commit_ar(&mock_kv(pos, pos as i32, D)).is_err() {
                failed = true;
                break;
            }
        }
        assert!(failed, "flush past the pool must error");
        lock(&mgr).check_integrity().unwrap();
        c.release();
        assert_eq!(lock(&mgr).pool().pages_in_use(), 0);
    }

    /// Tentpole acceptance (no global lock on the hot path): a thread
    /// holding the session-manager mutex the ENTIRE time must not block a
    /// steady-state decode — pref-filled cache, draft writes, batched
    /// verify reads/rewrites, commits, and the flushes they trigger all
    /// run on the shard lock + arena atomics alone. Before the sharding
    /// refactor this deadlocked on the first read.
    #[test]
    fn steady_state_steps_never_take_the_manager_lock() {
        use std::sync::mpsc;
        use std::thread;
        let mgr = pool_mgr(64);
        let mut c = cache(&mgr, 1, 24);
        c.prefill(3 * G, &|p| mock_kv(p, p as i32, D)).unwrap();
        let guard = lock(&mgr); // manager mutex held for the whole decode
        let (tx, rx) = mpsc::channel();
        let worker = thread::spawn(move || {
            let mut pos = 3 * G;
            let mut win = vec![0.0f32; TMAX * D];
            let mut committed = vec![0.0f32; G * D];
            for cycle in 0..6 * G {
                c.begin_cycle().unwrap();
                let t = 1 + (cycle % TMAX);
                for i in 0..t {
                    c.write_cycle_slot(i, &mock_kv(pos + i, (pos + i) as i32, D))
                        .unwrap();
                }
                c.read_cycle_slots_into(0, &mut win[..t * D]).unwrap();
                c.read_tokens_into(0..G, cycle % 2 == 0, &mut committed).unwrap();
                c.commit_cycle(t - 1, t).unwrap();
                pos += t;
            }
            tx.send(c).unwrap();
        });
        let mut c = rx
            .recv_timeout(std::time::Duration::from_secs(30))
            .expect("steady-state decode blocked on the manager mutex");
        drop(guard);
        worker.join().unwrap();
        assert!(c.table().groups.len() > 2, "flushes ran lock-free");
        c.release();
        assert_eq!(lock(&mgr).pool().pages_in_use(), 0);
    }

    /// Property (packed-read parity): for random prefills and planes, the
    /// fused zero-allocation `read_token_into` returns exactly what the
    /// allocating `read_token` does at every position — quantized region
    /// (draft and target plane) and FP buffer alike.
    #[test]
    fn prop_read_token_into_matches_read_token() {
        use crate::util::prop::{check, Config};
        check::<Vec<u64>, _>(
            Config { cases: 20, size: 8, ..Config::default() },
            |seeds| {
                for &seed in seeds {
                    let buckets = 2 + (seed % 4) as usize;
                    let mgr = pool_mgr(64);
                    let c = {
                        let mut c = cache(&mgr, 1, buckets + 4);
                        c.prefill(buckets * G, &|p| {
                            mock_kv(p, (p as i32) ^ (seed as i32), D)
                        })
                        .unwrap();
                        c
                    };
                    let mut out = vec![0.0f32; D];
                    for pos in 0..buckets * G {
                        for draft in [true, false] {
                            let want = c.read_token(pos, draft).unwrap();
                            c.read_token_into(pos, draft, &mut out).unwrap();
                            if out != want {
                                return false;
                            }
                        }
                    }
                    // wrong-size scratch is rejected, positions past the
                    // context are rejected
                    if c.read_token_into(0, true, &mut [0.0; D + 1]).is_ok() {
                        return false;
                    }
                    if c.read_token_into(buckets * G, false, &mut out).is_ok() {
                        return false;
                    }
                }
                true
            },
        );
    }

    /// Property (batched window parity): over EVERY `(start, len)` window
    /// of a prefilled-then-decoded cache — including windows spanning
    /// group boundaries and the quantized-region → FP-buffer seam — the
    /// one-lock `read_tokens_into` returns bit-for-bit what `len`
    /// independent `read_token_into` calls return, for both planes.
    #[test]
    fn prop_read_tokens_into_matches_per_token_reads() {
        use crate::util::prop::{check, Config};
        check::<Vec<u64>, _>(
            Config { cases: 6, size: 3, ..Config::default() },
            |seeds| {
                for &seed in seeds {
                    let buckets = 2 + (seed % 3) as usize;
                    let mgr = pool_mgr(64);
                    let mut c = cache(&mgr, 1, buckets + 4);
                    c.prefill(buckets * G, &|p| {
                        mock_kv(p, (p as i32) ^ (seed as i32), D)
                    })
                    .unwrap();
                    // extend the FP buffer past C_F1 so windows can end in
                    // the buffer tail (not just at the prefill seam)
                    for i in 0..(seed % (G as u64 - 1)) as usize + 1 {
                        let pos = buckets * G + i;
                        c.commit_ar(&mock_kv(pos, pos as i32, D)).unwrap();
                    }
                    let ctx = {
                        let tr = c.tracker().unwrap();
                        tr.n_q + tr.n_f
                    };
                    let mut tok = vec![0.0f32; D];
                    let mut win = vec![0.0f32; ctx * D];
                    for start in 0..ctx {
                        for len in 0..=(ctx - start) {
                            for draft in [true, false] {
                                c.read_tokens_into(
                                    start..start + len,
                                    draft,
                                    &mut win[..len * D],
                                )
                                .unwrap();
                                for (j, pos) in (start..start + len).enumerate() {
                                    c.read_token_into(pos, draft, &mut tok).unwrap();
                                    if win[j * D..(j + 1) * D] != tok[..] {
                                        return false;
                                    }
                                }
                            }
                        }
                    }
                    // wrong-size scratch and out-of-context windows reject
                    if c.read_tokens_into(0..2, true, &mut win[..D]).is_ok() {
                        return false;
                    }
                    if c
                        .read_tokens_into(ctx - 1..ctx + 1, false, &mut win[..2 * D])
                        .is_ok()
                    {
                        return false;
                    }
                }
                true
            },
        );
    }

    /// Property (chunked-prefill parity): for prompt lengths sweeping
    /// group boundaries (±1 around G multiples) and chunk sizes sweeping
    /// the chunk-boundary cases, driving `prefill_extend` once per chunk
    /// and then `prefill_finish` yields a cache bit-identical to a
    /// one-shot prefill of the same length — same page count, same
    /// logical/host bytes, same tracker split, and identical dequant
    /// output at every position on both planes.
    #[test]
    fn prop_chunked_prefill_matches_one_shot() {
        for len in [2 * G, 2 * G + 1, 3 * G - 1, 3 * G, 3 * G + 1, 5 * G - 1, 5 * G + 3] {
            for chunk in [1usize, 3, G - 1, G, G + 1, 2 * G + 3, len] {
                let mgr = pool_mgr(64);
                let kv = |p: usize| mock_kv(p, (p as i32) ^ 77, D);
                let mut a = cache(&mgr, 1, len / G + 4);
                a.prefill_finish(len, &kv).unwrap();
                let mut b = cache(&mgr, 2, len / G + 4);
                let mut seen = 0usize;
                while seen < len {
                    seen = (seen + chunk).min(len);
                    b.prefill_extend(seen, &kv).unwrap();
                }
                b.prefill_finish(len, &kv).unwrap();
                assert_eq!(
                    a.table().groups.len(),
                    b.table().groups.len(),
                    "len {len} chunk {chunk}: page counts diverge"
                );
                assert_eq!(a.session_bytes(), b.session_bytes(), "len {len} chunk {chunk}");
                let (ta, tb) = (a.tracker().unwrap(), b.tracker().unwrap());
                assert_eq!((ta.n_q, ta.n_f), (tb.n_q, tb.n_f), "len {len} chunk {chunk}");
                for pos in 0..len {
                    for draft in [true, false] {
                        assert_eq!(
                            a.read_token(pos, draft).unwrap(),
                            b.read_token(pos, draft).unwrap(),
                            "len {len} chunk {chunk} pos {pos} draft {draft}"
                        );
                    }
                }
                // double-finish and post-finish extend are rejected
                assert!(b.prefill_finish(len, &kv).is_err());
                assert!(b.prefill_extend(len, &kv).is_err());
                a.release();
                b.release();
            }
        }
    }

    /// `prefill_finish` rejects totals under 2G, and an extend that
    /// outran the final length surfaces as a clean error.
    #[test]
    fn chunked_prefill_guards() {
        let mgr = pool_mgr(64);
        let kv = |p: usize| mock_kv(p, p as i32, D);
        let mut c = cache(&mgr, 1, 8);
        assert!(c.prefill_finish(2 * G - 1, &kv).is_err());
        c.prefill_extend(4 * G, &kv).unwrap(); // 3 groups now written
        assert!(
            c.prefill_finish(3 * G, &kv).is_err(),
            "finish shorter than the extended region must fail"
        );
    }

    /// Batched cycle-slot writes land bit-identically to per-slot writes,
    /// including windows crossing an FP page boundary.
    #[test]
    fn write_cycle_slots_matches_per_slot_writes() {
        let mgr = pool_mgr(32);
        let mut a = prefilled(&mgr, 1, 2);
        let mut b = prefilled(&mgr, 2, 2);
        // advance the buffer so the cycle window straddles an FP page
        // boundary (slots 14..18 with G = 8 cross from fp[1] into fp[2])
        for i in 0..6 {
            let pos = 2 * G + i;
            a.commit_ar(&mock_kv(pos, pos as i32, D)).unwrap();
            b.commit_ar(&mock_kv(pos, pos as i32, D)).unwrap();
        }
        let t = TMAX;
        let mut flat = Vec::with_capacity(t * D);
        for i in 0..t {
            flat.extend_from_slice(&mock_kv(1000 + i, i as i32, D));
        }
        a.begin_cycle().unwrap();
        b.begin_cycle().unwrap();
        for (i, chunk) in flat.chunks_exact(D).enumerate() {
            a.write_cycle_slot(i, chunk).unwrap();
        }
        b.write_cycle_slots(0, &flat).unwrap();
        // the drafted (uncommitted) window reads back bit-exactly through
        // the batched cycle-slot reader, on both caches
        let mut back = vec![0.0f32; t * D];
        for c in [&a, &b] {
            c.read_cycle_slots_into(0, &mut back).unwrap();
            assert_eq!(back, flat);
        }
        a.commit_cycle(t - 1, t).unwrap();
        b.commit_cycle(t - 1, t).unwrap();
        let ctx = a.tracker().unwrap().context_len();
        for pos in 0..ctx {
            assert_eq!(
                a.read_token(pos, false).unwrap(),
                b.read_token(pos, false).unwrap(),
                "pos {pos}"
            );
        }
        // a window past the FP buffer is rejected up front
        let mut c = prefilled(&mgr, 3, 2);
        c.begin_cycle().unwrap();
        let giant = vec![0.0f32; (FB + 1) * D];
        assert!(c.write_cycle_slots(0, &giant).is_err());
    }

    /// Acceptance: ONE quantization pool serves every session. Two
    /// sessions prefill concurrently through the same manager; the shared
    /// pool's `jobs_executed` counter accumulates both fan-outs, its size
    /// stays `pool.quant_workers`, and outputs are bit-identical to a
    /// serially-quantized manager.
    #[test]
    fn quant_pool_is_shared_across_sessions() {
        use std::thread;
        let mgr = pool_mgr_workers(128, 3);
        let buckets = 6; // 5 quant groups per prefill -> parallel path
        let readers: Vec<_> = (1..=2u64)
            .map(|sid| {
                let mgr = mgr.clone();
                thread::spawn(move || {
                    let c = prefilled(&mgr, sid, buckets);
                    (0..buckets * G)
                        .map(|p| c.read_token(p, false).unwrap())
                        .collect::<Vec<_>>()
                })
            })
            .collect();
        let outputs: Vec<_> = readers.into_iter().map(|h| h.join().unwrap()).collect();
        let (size, jobs, depth) = lock(&mgr).quant_pool_stats();
        assert_eq!(size, 3, "pool sized by quant_workers, created once");
        assert_eq!(
            jobs,
            2 * (buckets as u64 - 1),
            "both sessions' groups went through the one shared pool"
        );
        assert_eq!(depth, 0, "queue drained");
        let serial_mgr = pool_mgr_workers(128, 1);
        for (sid, out) in outputs.iter().enumerate() {
            let sid = sid as u64 + 10;
            let c = prefilled(&serial_mgr, sid, buckets);
            for (p, want) in out.iter().enumerate() {
                assert_eq!(&c.read_token(p, false).unwrap(), want, "pos {p}");
            }
        }
        let (_, serial_jobs, _) = lock(&serial_mgr).quant_pool_stats();
        assert_eq!(serial_jobs, 0, "single-worker pool quantizes inline");
    }

    #[test]
    fn parallel_prefill_is_bit_identical_to_serial() {
        let serial_mgr = pool_mgr_workers(64, 1);
        let parallel_mgr = pool_mgr_workers(64, 4);
        let mut caches = Vec::new();
        for mgr in [&serial_mgr, &parallel_mgr] {
            caches.push(prefilled(mgr, 1, 6)); // 5 quant groups each
        }
        for pos in 0..6 * G {
            for draft in [true, false] {
                assert_eq!(
                    caches[0].read_token(pos, draft).unwrap(),
                    caches[1].read_token(pos, draft).unwrap(),
                    "pos {pos} draft {draft}"
                );
            }
        }
    }

    #[test]
    fn traffic_counters_split_draft_and_target() {
        let mgr = pool_mgr(32);
        let c = prefilled(&mgr, 1, 3);
        let mut out = vec![0.0f32; D];
        for pos in 0..3 {
            c.read_token_into(pos, true, &mut out).unwrap();
        }
        c.read_token_into(0, false, &mut out).unwrap();
        // FP-region reads are full precision: no dequant counted
        c.read_token_into(2 * G + 1, true, &mut out).unwrap();
        let t = lock(&mgr).traffic();
        assert_eq!(t.dequant_calls_draft, 3);
        assert_eq!(t.dequant_calls_target, 1);
        let plane = D.div_ceil(2) as u64;
        assert_eq!(t.bytes_read_draft, 3 * plane);
        assert_eq!(t.bytes_read_target, 2 * plane);
    }

    /// Property: random accept/reject traffic preserves tracker invariants,
    /// keeps every position readable, and releases with zero leaked pages.
    #[test]
    fn prop_random_cycles_no_leak() {
        use crate::util::prop::{check, Config};
        check::<Vec<usize>, _>(
            Config { cases: 30, size: 40, ..Config::default() },
            |ops| {
                let mgr = pool_mgr(64);
                lock(&mgr).admit(1, 43, false).unwrap();
                let mut c = PagedKvCache::new(mgr.clone(), 1, G, D, FB, 40 * G).unwrap();
                c.prefill(12 * G, &|p| mock_kv(p, p as i32, D)).unwrap();
                let mut pos = 12 * G;
                for &op in ops {
                    if c.begin_cycle().is_err() {
                        return false;
                    }
                    let t = 1 + op % TMAX;
                    for i in 0..t {
                        if c.write_cycle_slot(i, &mock_kv(pos + i, op as i32, D)).is_err() {
                            return false;
                        }
                    }
                    let accepted = op % t;
                    if c.commit_cycle(accepted, t).is_err() {
                        return false;
                    }
                    pos += accepted + 1;
                    let ok = {
                        let tr = c.tracker().unwrap();
                        tr.check_invariants().is_ok() && tr.context_len() == pos
                    };
                    if !ok || c.read_token(pos - 1, true).is_err() {
                        return false;
                    }
                }
                c.release();
                lock(&mgr).pool().pages_in_use() == 0
            },
        );
    }

    /// Property (tier round-trip, the spill/restore acceptance): over
    /// randomized prefill sizes, decode traffic, and spill shapes
    /// (whole-session hibernation vs partial page-granular spills),
    /// every committed position reads back bit-identically through the
    /// transparent fault-back on both planes, and the arena's page and
    /// logical/host byte accounting returns exactly to its pre-spill
    /// value once the session is resident again.
    #[test]
    fn prop_spill_restore_roundtrip_bit_identical() {
        use crate::util::prop::{check, Config};
        check::<Vec<u64>, _>(
            Config { cases: 10, size: 5, ..Config::default() },
            |seeds| {
                for &seed in seeds {
                    let buckets = 2 + (seed % 4) as usize;
                    let mgr = tiered_mgr(64, 64);
                    let mut c = cache(&mgr, 1, buckets + 4);
                    c.prefill(buckets * G, &|p| mock_kv(p, (p as i32) ^ seed as i32, D))
                        .unwrap();
                    let mut pos = buckets * G;
                    for _ in 0..(seed % 7) as usize {
                        c.commit_ar(&mock_kv(pos, pos as i32, D)).unwrap();
                        pos += 1;
                    }
                    let ctx = {
                        let tr = c.tracker().unwrap();
                        tr.n_q + tr.n_f
                    };
                    let mut want = vec![0.0f32; ctx * D];
                    let mut want_draft = vec![0.0f32; ctx * D];
                    c.read_tokens_into(0..ctx, false, &mut want).unwrap();
                    c.read_tokens_into(0..ctx, true, &mut want_draft).unwrap();
                    let (resident0, logical0, host0) = {
                        let m = lock(&mgr);
                        let p = m.pool();
                        (p.pages_in_use(), p.logical_bytes(), p.host_bytes())
                    };
                    // spill: whole-session hibernation or a partial
                    // page-granular demotion, alternating by seed
                    let moved = if seed % 2 == 0 {
                        lock(&mgr).hibernate(1).unwrap()
                    } else {
                        c.shard.spill_quant_pages(1 + (seed % 3) as usize).unwrap()
                    };
                    if moved == 0 || c.shard.spilled_pages() != moved {
                        return false;
                    }
                    // transparent fault-back: same bits on both planes
                    let mut got = vec![0.0f32; ctx * D];
                    c.read_tokens_into(0..ctx, false, &mut got).unwrap();
                    if got != want {
                        return false;
                    }
                    c.read_tokens_into(0..ctx, true, &mut got).unwrap();
                    if got != want_draft {
                        return false;
                    }
                    // pull the rest of the FP buffer back; the books must
                    // close exactly
                    let mut tmp = vec![0.0f32; D];
                    c.read_fp_slot_into(0, &mut tmp).unwrap();
                    {
                        let m = lock(&mgr);
                        let p = m.pool();
                        if c.shard.spilled_pages() != 0
                            || (p.pages_in_use(), p.logical_bytes(), p.host_bytes())
                                != (resident0, logical0, host0)
                            || m.tier_stats().restore_faults == 0
                        {
                            return false;
                        }
                    }
                    c.release();
                    if lock(&mgr).pool().pages_in_use() != 0 {
                        return false;
                    }
                }
                true
            },
        );
    }

    /// Fetch-ahead vs on-demand accounting: `begin_cycle` speculatively
    /// restores the FP buffer and the newest quant group (fetch-ahead
    /// hits); touching an older cold group afterwards is an on-demand
    /// restore fault. The two land on separate tier counters, and the
    /// faulted bits match the pre-hibernation read exactly.
    #[test]
    fn fetch_ahead_hits_and_restore_faults_are_split() {
        let mgr = tiered_mgr(32, 32);
        let mut c = cache(&mgr, 1, 8);
        c.prefill(4 * G, &|p| mock_kv(p, p as i32, D)).unwrap(); // 3 groups + C_F1
        let want = c.read_token(0, true).unwrap();
        let fp_pages = c.table().fp.len();
        let moved = lock(&mgr).hibernate(1).unwrap();
        assert_eq!(moved, 3 + fp_pages, "hibernate parked the whole shard");
        c.begin_cycle().unwrap();
        let st = lock(&mgr).tier_stats();
        assert_eq!(
            st.fetch_ahead_hits as usize,
            fp_pages + 1,
            "FP buffer + newest group restored speculatively"
        );
        assert_eq!(st.restore_faults, 0);
        let mut out = vec![0.0f32; D];
        c.read_token_into(0, true, &mut out).unwrap();
        assert_eq!(out, want, "fault-back is bit-identical");
        let st = lock(&mgr).tier_stats();
        assert_eq!(st.fetch_ahead_hits as usize, fp_pages + 1);
        assert_eq!(st.restore_faults, 1, "oldest group faulted on demand");
        c.release();
        assert_eq!(lock(&mgr).pool().pages_in_use(), 0);
    }

    /// Driving the adaptive controller up with a synthetic fault stream
    /// makes `begin_cycle` prefetch deeper: the FP buffer plus the newest
    /// THREE quant groups come back speculatively in one fetch-ahead
    /// (bounded by how many groups exist), leaving no on-demand faults
    /// for the cycle's reads.
    #[test]
    fn fetch_ahead_depth_scales_restored_groups() {
        let mgr = tiered_mgr(32, 32);
        let mut c = cache(&mgr, 1, 8);
        c.prefill(4 * G, &|p| mock_kv(p, p as i32, D)).unwrap(); // 3 groups + C_F1
        let fp_pages = c.table().fp.len();
        let store = Arc::clone(c.shard.spill_store().unwrap());
        for _ in 0..16 {
            store.note_restore(1, false); // synthetic sustained faults
        }
        assert!(store.fetch_ahead_depth() >= 3, "controller deepened under faults");
        lock(&mgr).hibernate(1).unwrap();
        let faults_before = lock(&mgr).tier_stats().restore_faults;
        c.begin_cycle().unwrap();
        let st = lock(&mgr).tier_stats();
        assert_eq!(
            st.fetch_ahead_hits as usize,
            fp_pages + 3,
            "FP buffer + all three quant groups restored speculatively"
        );
        assert_eq!(st.restore_faults, faults_before, "nothing left to fault on demand");
        let mut out = vec![0.0f32; D];
        c.read_token_into(0, true, &mut out).unwrap();
        let st = lock(&mgr).tier_stats();
        assert_eq!(st.restore_faults, faults_before, "reads hit resident pages");
        c.release();
        assert_eq!(lock(&mgr).pool().pages_in_use(), 0);
    }

    /// Every flush is a hot → warm demotion on the tier books when a
    /// spill store is attached.
    #[test]
    fn flush_counts_demotions_when_tiering_enabled() {
        let mgr = tiered_mgr(32, 32);
        let mut c = cache(&mgr, 1, 8);
        c.prefill(2 * G, &|p| mock_kv(p, p as i32, D)).unwrap();
        for i in 0..2 * G {
            let pos = 2 * G + i;
            c.commit_ar(&mock_kv(pos, pos as i32, D)).unwrap();
        }
        assert_eq!(lock(&mgr).tier_stats().demotions, 2, "two buffer flushes");
        c.release();
    }
}
