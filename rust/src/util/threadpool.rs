//! Fixed-size worker pool over std threads + channels (no tokio offline).
//!
//! The coordinator's continuous batcher runs decode engines on this pool;
//! jobs are boxed closures. `join` blocks until all submitted jobs drain —
//! used at shutdown and by batch-scoped scopes in benches.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
    pending: Arc<(Mutex<usize>, Condvar)>,
    executed: Arc<AtomicUsize>,
}

impl ThreadPool {
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let pending = Arc::new((Mutex::new(0usize), Condvar::new()));
        let executed = Arc::new(AtomicUsize::new(0));
        let workers = (0..threads)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let pending = Arc::clone(&pending);
                let executed = Arc::clone(&executed);
                thread::Builder::new()
                    .name(format!("qs-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                job();
                                executed.fetch_add(1, Ordering::Relaxed);
                                let (lock, cv) = &*pending;
                                let mut n = lock.lock().unwrap();
                                *n -= 1;
                                if *n == 0 {
                                    cv.notify_all();
                                }
                            }
                            Err(_) => break,
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers, pending, executed }
    }

    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        let (lock, _) = &*self.pending;
        *lock.lock().unwrap() += 1;
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("worker channel closed");
    }

    /// Block until every submitted job has completed.
    pub fn join(&self) {
        let (lock, cv) = &*self.pending;
        let mut n = lock.lock().unwrap();
        while *n > 0 {
            n = cv.wait(n).unwrap();
        }
    }

    pub fn jobs_executed(&self) -> usize {
        self.executed.load(Ordering::Relaxed)
    }

    pub fn size(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.tx.take(); // close the channel; workers exit on recv error
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.join();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
        assert_eq!(pool.jobs_executed(), 100);
    }

    #[test]
    fn join_with_no_jobs_returns() {
        let pool = ThreadPool::new(2);
        pool.join();
    }

    #[test]
    fn drop_waits_for_workers() {
        let counter = Arc::new(AtomicUsize::new(0));
        {
            let pool = ThreadPool::new(2);
            for _ in 0..10 {
                let c = Arc::clone(&counter);
                pool.submit(move || {
                    std::thread::sleep(std::time::Duration::from_millis(1));
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
            pool.join();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 10);
    }
}
