//! Fixed-size worker pool over std threads (no tokio offline).
//!
//! The queue is a plain `Mutex<VecDeque>` + condvar (not `mpsc`) so the
//! pool can hand out [`PoolHandle`] — a `Sync`, cloneable submission handle
//! that lets ONE process-wide pool serve many concurrent producers. The
//! coordinator creates a single quantization pool at startup (sized by
//! `pool.quant_workers`); every session clones a handle out of the session
//! manager and fans its prefill quantization over the shared workers
//! instead of spawning a fresh pool per prefill.
//!
//! Two completion scopes:
//! * [`ThreadPool::join`] — global: blocks until *every* submitted job has
//!   drained (shutdown, single-tenant benches);
//! * [`WaitGroup`] + [`PoolHandle::scoped_submit`] — caller-scoped: each
//!   producer waits for exactly the jobs it submitted, so concurrent
//!   sessions never block on each other's work.
//!
//! Two pool shapes share those scopes:
//! * [`ThreadPool`] — one FIFO queue, workers race to pop (the shared
//!   quantization pool, per-batcher step pools);
//! * [`StealPool`] — per-worker deques with work stealing: submissions
//!   round-robin across workers, an idle worker drains its own deque front
//!   first and then steals from the *back* of a victim's, so the
//!   process-wide scheduler pool (threads `qs-sched-*`) keeps every core
//!   busy even when one engine's sessions dominate the queue.
//!
//! [`ScopedSpawn`] abstracts over both handles so the step batcher can fan
//! a round over whichever pool the coordinator wired in.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct QueueState {
    jobs: VecDeque<Job>,
    /// Jobs submitted but not yet finished (queued + running).
    pending: usize,
    closed: bool,
}

struct Inner {
    state: Mutex<QueueState>,
    /// Workers park here waiting for jobs.
    work_cv: Condvar,
    /// `join` callers park here waiting for `pending` to reach zero.
    done_cv: Condvar,
    executed: AtomicUsize,
    size: usize,
}

impl Inner {
    fn submit(&self, job: Job) {
        {
            let mut s = self.state.lock().unwrap();
            assert!(!s.closed, "pool shut down");
            s.pending += 1;
            s.jobs.push_back(job);
        }
        self.work_cv.notify_one();
    }

    fn queue_depth(&self) -> usize {
        self.state.lock().unwrap().jobs.len()
    }
}

/// A `Sync`, cloneable submission handle onto a [`ThreadPool`]'s queue.
///
/// Handles are cheap (`Arc` clone) and do not keep the workers alive: the
/// owning [`ThreadPool`] must outlive every submit (submitting after the
/// pool dropped panics). A job that panics kills its worker thread; jobs
/// here return errors through their own channels instead of panicking.
#[derive(Clone)]
pub struct PoolHandle {
    inner: Arc<Inner>,
}

impl PoolHandle {
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.inner.submit(Box::new(f));
    }

    /// Submit a job tracked by `wg`: `wg.wait()` returns once every job
    /// submitted through that group has finished. Unlike
    /// [`ThreadPool::join`] this is caller-scoped — it does not wait on
    /// jobs other producers pushed onto the same shared pool.
    pub fn scoped_submit<F: FnOnce() + Send + 'static>(&self, wg: &WaitGroup, f: F) {
        *wg.inner.0.lock().unwrap() += 1;
        let wg = Arc::clone(&wg.inner);
        self.inner.submit(Box::new(move || {
            f();
            let (lock, cv) = &*wg;
            let mut n = lock.lock().unwrap();
            *n -= 1;
            if *n == 0 {
                cv.notify_all();
            }
        }));
    }

    /// Worker threads behind this handle.
    pub fn size(&self) -> usize {
        self.inner.size
    }

    /// Jobs completed over the pool's lifetime (all producers).
    pub fn jobs_executed(&self) -> usize {
        self.inner.executed.load(Ordering::Relaxed)
    }

    /// Jobs queued but not yet picked up (instantaneous gauge).
    pub fn queue_depth(&self) -> usize {
        self.inner.queue_depth()
    }
}

/// Common scoped-submission surface over [`PoolHandle`] (one FIFO queue)
/// and [`StealHandle`] (stealing deques), so round dispatch is written once
/// against `&dyn ScopedSpawn`.
pub trait ScopedSpawn: Send + Sync {
    /// Submit a boxed job tracked by `wg` (see [`PoolHandle::scoped_submit`]).
    fn spawn_scoped(&self, wg: &WaitGroup, job: Box<dyn FnOnce() + Send + 'static>);
    /// Worker threads behind this handle.
    fn workers(&self) -> usize;
}

impl ScopedSpawn for PoolHandle {
    fn spawn_scoped(&self, wg: &WaitGroup, job: Box<dyn FnOnce() + Send + 'static>) {
        self.scoped_submit(wg, job);
    }

    fn workers(&self) -> usize {
        self.size()
    }
}

/// Caller-scoped completion tracker for [`PoolHandle::scoped_submit`].
#[derive(Clone, Default)]
pub struct WaitGroup {
    inner: Arc<(Mutex<usize>, Condvar)>,
}

impl WaitGroup {
    pub fn new() -> WaitGroup {
        WaitGroup::default()
    }

    /// Block until every job submitted through this group has completed.
    pub fn wait(&self) {
        let (lock, cv) = &*self.inner;
        let mut n = lock.lock().unwrap();
        while *n > 0 {
            n = cv.wait(n).unwrap();
        }
    }
}

pub struct ThreadPool {
    inner: Arc<Inner>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(threads: usize) -> Self {
        Self::named(threads, "qs-worker")
    }

    /// A pool whose worker threads are named `{name}-{i}` — the process
    /// now runs several kinds of pool (the shared quantization pool, a
    /// step pool per embedded batcher), and thread names are what keeps a
    /// stack dump readable.
    pub fn named(threads: usize, name: &str) -> Self {
        let threads = threads.max(1);
        let inner = Arc::new(Inner {
            state: Mutex::new(QueueState {
                jobs: VecDeque::new(),
                pending: 0,
                closed: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
            executed: AtomicUsize::new(0),
            size: threads,
        });
        let workers = (0..threads)
            .map(|i| {
                let inner = Arc::clone(&inner);
                thread::Builder::new()
                    .name(format!("{name}-{i}"))
                    .spawn(move || worker_loop(&inner))
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { inner, workers }
    }

    /// A `Sync`, cloneable submission handle shared by all producers.
    pub fn handle(&self) -> PoolHandle {
        PoolHandle { inner: Arc::clone(&self.inner) }
    }

    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.inner.submit(Box::new(f));
    }

    /// Block until every submitted job (from every producer) has completed.
    pub fn join(&self) {
        let mut s = self.inner.state.lock().unwrap();
        while s.pending > 0 {
            s = self.inner.done_cv.wait(s).unwrap();
        }
    }

    pub fn jobs_executed(&self) -> usize {
        self.inner.executed.load(Ordering::Relaxed)
    }

    pub fn queue_depth(&self) -> usize {
        self.inner.queue_depth()
    }

    pub fn size(&self) -> usize {
        self.inner.size
    }
}

fn worker_loop(inner: &Inner) {
    loop {
        let job = {
            let mut s = inner.state.lock().unwrap();
            loop {
                // Drain queued work before honoring shutdown so drop keeps
                // the old "waits for all submitted jobs" semantics.
                if let Some(j) = s.jobs.pop_front() {
                    break Some(j);
                }
                if s.closed {
                    break None;
                }
                s = inner.work_cv.wait(s).unwrap();
            }
        };
        let Some(job) = job else { break };
        job();
        inner.executed.fetch_add(1, Ordering::Relaxed);
        let mut s = inner.state.lock().unwrap();
        s.pending -= 1;
        if s.pending == 0 {
            inner.done_cv.notify_all();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.inner.state.lock().unwrap().closed = true;
        self.inner.work_cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

struct StealState {
    /// One deque per worker. Submissions round-robin across them; worker
    /// `i` pops its own front (FIFO for its share) and steals from the
    /// *back* of a victim's deque, so a thief takes the coldest job.
    queues: Vec<VecDeque<Job>>,
    rr: usize,
    pending: usize,
    closed: bool,
}

struct StealInner {
    state: Mutex<StealState>,
    work_cv: Condvar,
    done_cv: Condvar,
    executed: AtomicUsize,
    steals: AtomicUsize,
    size: usize,
}

impl StealInner {
    fn submit(&self, job: Job) {
        {
            let mut s = self.state.lock().unwrap();
            assert!(!s.closed, "pool shut down");
            s.pending += 1;
            let slot = s.rr;
            s.rr = (s.rr + 1) % self.size;
            s.queues[slot].push_back(job);
        }
        // Any worker may take it (own pop or steal), so one wake suffices.
        self.work_cv.notify_one();
    }

    fn queue_depth(&self) -> usize {
        self.state.lock().unwrap().queues.iter().map(VecDeque::len).sum()
    }
}

/// A `Sync`, cloneable submission handle onto a [`StealPool`]. Same
/// contract as [`PoolHandle`]: cheap to clone, panics if used after the
/// owning pool dropped.
#[derive(Clone)]
pub struct StealHandle {
    inner: Arc<StealInner>,
}

impl StealHandle {
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.inner.submit(Box::new(f));
    }

    /// Submit a job tracked by `wg` — caller-scoped completion, exactly as
    /// [`PoolHandle::scoped_submit`].
    pub fn scoped_submit<F: FnOnce() + Send + 'static>(&self, wg: &WaitGroup, f: F) {
        *wg.inner.0.lock().unwrap() += 1;
        let wg = Arc::clone(&wg.inner);
        self.inner.submit(Box::new(move || {
            f();
            let (lock, cv) = &*wg;
            let mut n = lock.lock().unwrap();
            *n -= 1;
            if *n == 0 {
                cv.notify_all();
            }
        }));
    }

    pub fn size(&self) -> usize {
        self.inner.size
    }

    pub fn jobs_executed(&self) -> usize {
        self.inner.executed.load(Ordering::Relaxed)
    }

    /// Jobs a worker took from another worker's deque (lifetime counter).
    /// Nonzero under imbalanced load is the pool doing its job.
    pub fn steals(&self) -> usize {
        self.inner.steals.load(Ordering::Relaxed)
    }

    pub fn queue_depth(&self) -> usize {
        self.inner.queue_depth()
    }
}

impl ScopedSpawn for StealHandle {
    fn spawn_scoped(&self, wg: &WaitGroup, job: Box<dyn FnOnce() + Send + 'static>) {
        self.scoped_submit(wg, job);
    }

    fn workers(&self) -> usize {
        self.size()
    }
}

/// Work-stealing worker pool: the process-wide step pool behind the
/// cross-engine scheduler. Deques live under one mutex (critical sections
/// are O(1) pops/pushes; this codebase is std-only, no lock-free deques),
/// which keeps the stealing logic auditable while still removing the
/// head-of-line blocking a single FIFO queue imposes on uneven producers.
pub struct StealPool {
    inner: Arc<StealInner>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl StealPool {
    /// A stealing pool whose worker threads are named `{name}-{i}` (the
    /// scheduler names its pool `qs-sched`).
    pub fn named(threads: usize, name: &str) -> Self {
        let threads = threads.max(1);
        let inner = Arc::new(StealInner {
            state: Mutex::new(StealState {
                queues: (0..threads).map(|_| VecDeque::new()).collect(),
                rr: 0,
                pending: 0,
                closed: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
            executed: AtomicUsize::new(0),
            steals: AtomicUsize::new(0),
            size: threads,
        });
        let workers = (0..threads)
            .map(|i| {
                let inner = Arc::clone(&inner);
                thread::Builder::new()
                    .name(format!("{name}-{i}"))
                    .spawn(move || steal_worker_loop(&inner, i))
                    .expect("spawn steal worker")
            })
            .collect();
        StealPool { inner, workers }
    }

    pub fn handle(&self) -> StealHandle {
        StealHandle { inner: Arc::clone(&self.inner) }
    }

    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.inner.submit(Box::new(f));
    }

    /// Block until every submitted job (from every producer) has completed.
    pub fn join(&self) {
        let mut s = self.inner.state.lock().unwrap();
        while s.pending > 0 {
            s = self.inner.done_cv.wait(s).unwrap();
        }
    }

    pub fn jobs_executed(&self) -> usize {
        self.inner.executed.load(Ordering::Relaxed)
    }

    pub fn steals(&self) -> usize {
        self.inner.steals.load(Ordering::Relaxed)
    }

    pub fn queue_depth(&self) -> usize {
        self.inner.queue_depth()
    }

    pub fn size(&self) -> usize {
        self.inner.size
    }
}

fn steal_worker_loop(inner: &StealInner, me: usize) {
    loop {
        let job = {
            let mut s = inner.state.lock().unwrap();
            loop {
                // Own deque first (front: FIFO for this worker's share)...
                if let Some(j) = s.queues[me].pop_front() {
                    break Some(j);
                }
                // ...then steal the coldest job off a victim's back.
                let n = inner.size;
                let stolen = (1..n)
                    .map(|d| (me + d) % n)
                    .find_map(|v| s.queues[v].pop_back());
                if let Some(j) = stolen {
                    inner.steals.fetch_add(1, Ordering::Relaxed);
                    break Some(j);
                }
                // Drain everything queued before honoring shutdown.
                if s.closed {
                    break None;
                }
                s = inner.work_cv.wait(s).unwrap();
            }
        };
        let Some(job) = job else { break };
        job();
        inner.executed.fetch_add(1, Ordering::Relaxed);
        let mut s = inner.state.lock().unwrap();
        s.pending -= 1;
        if s.pending == 0 {
            inner.done_cv.notify_all();
        }
    }
}

impl Drop for StealPool {
    fn drop(&mut self) {
        self.inner.state.lock().unwrap().closed = true;
        self.inner.work_cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.join();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
        assert_eq!(pool.jobs_executed(), 100);
        assert_eq!(pool.queue_depth(), 0);
    }

    #[test]
    fn join_with_no_jobs_returns() {
        let pool = ThreadPool::new(2);
        pool.join();
    }

    #[test]
    fn drop_waits_for_workers() {
        let counter = Arc::new(AtomicUsize::new(0));
        {
            let pool = ThreadPool::new(2);
            for _ in 0..10 {
                let c = Arc::clone(&counter);
                pool.submit(move || {
                    std::thread::sleep(std::time::Duration::from_millis(1));
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
            pool.join();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn handle_is_send_sync_clone() {
        fn assert_traits<T: Send + Sync + Clone>() {}
        assert_traits::<PoolHandle>();
        assert_traits::<WaitGroup>();
    }

    /// A wait group waits for exactly its own jobs: the fast group drains
    /// while a gated job from another group is still parked on a worker.
    #[test]
    fn scoped_wait_groups_track_only_their_jobs() {
        let pool = ThreadPool::new(2);
        let h = pool.handle();
        let gate = Arc::new((Mutex::new(false), Condvar::new()));
        let wg_slow = WaitGroup::new();
        {
            let gate = Arc::clone(&gate);
            h.scoped_submit(&wg_slow, move || {
                let (lock, cv) = &*gate;
                let mut open = lock.lock().unwrap();
                while !*open {
                    open = cv.wait(open).unwrap();
                }
            });
        }
        let wg_fast = WaitGroup::new();
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..8 {
            let c = Arc::clone(&counter);
            h.scoped_submit(&wg_fast, move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        // must return even though the gated job never finished
        wg_fast.wait();
        assert_eq!(counter.load(Ordering::SeqCst), 8);
        {
            let (lock, cv) = &*gate;
            *lock.lock().unwrap() = true;
            cv.notify_all();
        }
        wg_slow.wait();
        pool.join();
        assert_eq!(pool.jobs_executed(), 9);
    }

    /// Many producer threads share ONE pool through cloned handles; every
    /// job lands on the same worker set and the shared counters add up.
    #[test]
    fn concurrent_handles_share_one_pool() {
        let pool = ThreadPool::new(3);
        let counter = Arc::new(AtomicUsize::new(0));
        let producers: Vec<_> = (0..4)
            .map(|_| {
                let h = pool.handle();
                let counter = Arc::clone(&counter);
                thread::spawn(move || {
                    let wg = WaitGroup::new();
                    for _ in 0..25 {
                        let c = Arc::clone(&counter);
                        h.scoped_submit(&wg, move || {
                            c.fetch_add(1, Ordering::SeqCst);
                        });
                    }
                    wg.wait();
                })
            })
            .collect();
        for p in producers {
            p.join().unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
        assert_eq!(pool.jobs_executed(), 100, "one shared executed counter");
        assert_eq!(pool.size(), 3, "no extra pools spawned");
        assert_eq!(pool.queue_depth(), 0);
    }

    #[test]
    fn steal_pool_runs_all_jobs() {
        let pool = StealPool::named(4, "qs-sched");
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.join();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
        assert_eq!(pool.jobs_executed(), 100);
        assert_eq!(pool.queue_depth(), 0);
        assert_eq!(pool.size(), 4);
    }

    /// Imbalanced load forces stealing: one worker's deque is pinned behind
    /// a gated job while the rest of its round-robin share sits queued, so
    /// idle workers must steal those jobs for the fast group to drain.
    #[test]
    fn idle_steal_workers_drain_a_blocked_peers_deque() {
        let pool = StealPool::named(2, "qs-sched");
        let h = pool.handle();
        let gate = Arc::new((Mutex::new(false), Condvar::new()));
        let wg_slow = WaitGroup::new();
        {
            let gate = Arc::clone(&gate);
            h.scoped_submit(&wg_slow, move || {
                let (lock, cv) = &*gate;
                let mut open = lock.lock().unwrap();
                while !*open {
                    open = cv.wait(open).unwrap();
                }
            });
        }
        // Round-robin puts half of these behind the gated job's deque; the
        // free worker must steal them or wg_fast.wait() deadlocks.
        let wg_fast = WaitGroup::new();
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..16 {
            let c = Arc::clone(&counter);
            h.scoped_submit(&wg_fast, move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        wg_fast.wait();
        assert_eq!(counter.load(Ordering::SeqCst), 16);
        assert!(h.steals() > 0, "blocked peer's jobs were stolen");
        {
            let (lock, cv) = &*gate;
            *lock.lock().unwrap() = true;
            cv.notify_all();
        }
        wg_slow.wait();
        pool.join();
        assert_eq!(pool.jobs_executed(), 17);
    }

    /// Both handle types drive the same generic dispatch path.
    #[test]
    fn scoped_spawn_is_object_safe_over_both_pools() {
        let fifo = ThreadPool::new(2);
        let steal = StealPool::named(2, "qs-sched");
        let fifo_h = fifo.handle();
        let steal_h = steal.handle();
        let handles: Vec<&dyn ScopedSpawn> = vec![&fifo_h, &steal_h];
        let counter = Arc::new(AtomicUsize::new(0));
        for h in handles {
            assert_eq!(h.workers(), 2);
            let wg = WaitGroup::new();
            for _ in 0..10 {
                let c = Arc::clone(&counter);
                h.spawn_scoped(
                    &wg,
                    Box::new(move || {
                        c.fetch_add(1, Ordering::SeqCst);
                    }),
                );
            }
            wg.wait();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 20);
    }
}
