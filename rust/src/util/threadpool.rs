//! Fixed-size worker pool over std threads (no tokio offline).
//!
//! The queue is a plain `Mutex<VecDeque>` + condvar (not `mpsc`) so the
//! pool can hand out [`PoolHandle`] — a `Sync`, cloneable submission handle
//! that lets ONE process-wide pool serve many concurrent producers. The
//! coordinator creates a single quantization pool at startup (sized by
//! `pool.quant_workers`); every session clones a handle out of the session
//! manager and fans its prefill quantization over the shared workers
//! instead of spawning a fresh pool per prefill.
//!
//! Two completion scopes:
//! * [`ThreadPool::join`] — global: blocks until *every* submitted job has
//!   drained (shutdown, single-tenant benches);
//! * [`WaitGroup`] + [`PoolHandle::scoped_submit`] — caller-scoped: each
//!   producer waits for exactly the jobs it submitted, so concurrent
//!   sessions never block on each other's work.
//!
//! Two pool shapes share those scopes:
//! * [`ThreadPool`] — one FIFO queue, workers race to pop (the shared
//!   quantization pool, per-batcher step pools);
//! * [`StealPool`] — per-worker deques with work stealing: submissions
//!   round-robin across workers, an idle worker drains its own deque front
//!   first and then steals from the *back* of a victim's, so the
//!   process-wide scheduler pool (threads `qs-sched-*`) keeps every core
//!   busy even when one engine's sessions dominate the queue.
//!
//! [`ScopedSpawn`] abstracts over both handles so the step batcher can fan
//! a round over whichever pool the coordinator wired in.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Lock a pool mutex, recovering from poison: the data under these locks
/// (job deques and counters) is valid at every instruction boundary, and
/// jobs run OUTSIDE the lock, so a poisoned state mutex only ever means
/// "some thread panicked elsewhere" — never torn queue state.
fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Decrements its wait group on drop — panic-safe completion signaling
/// for scoped jobs: a panicking job still releases its waiter during
/// unwind instead of hanging `WaitGroup::wait` forever.
struct WgGuard(Arc<(Mutex<usize>, Condvar)>);

impl Drop for WgGuard {
    fn drop(&mut self) {
        let (lock, cv) = &*self.0;
        let mut n = lock.lock().unwrap_or_else(PoisonError::into_inner);
        *n -= 1;
        if *n == 0 {
            cv.notify_all();
        }
    }
}

struct QueueState {
    jobs: VecDeque<Job>,
    /// Jobs submitted but not yet finished (queued + running).
    pending: usize,
    closed: bool,
}

struct Inner {
    state: Mutex<QueueState>,
    /// Workers park here waiting for jobs.
    work_cv: Condvar,
    /// `join` callers park here waiting for `pending` to reach zero.
    done_cv: Condvar,
    executed: AtomicUsize,
    /// Jobs that panicked and were contained (worker survived).
    panics: AtomicUsize,
    size: usize,
}

impl Inner {
    fn submit(&self, job: Job) {
        {
            let mut s = lock_recover(&self.state);
            assert!(!s.closed, "pool shut down");
            s.pending += 1;
            s.jobs.push_back(job);
        }
        self.work_cv.notify_one();
    }

    fn queue_depth(&self) -> usize {
        lock_recover(&self.state).jobs.len()
    }
}

/// A `Sync`, cloneable submission handle onto a [`ThreadPool`]'s queue.
///
/// Handles are cheap (`Arc` clone) and do not keep the workers alive: the
/// owning [`ThreadPool`] must outlive every submit (submitting after the
/// pool dropped panics). A job that panics is CONTAINED: the unwind is
/// caught, the worker survives, the `panics_contained` counter ticks, and
/// any wait group the job was scoped to is still released.
#[derive(Clone)]
pub struct PoolHandle {
    inner: Arc<Inner>,
}

impl PoolHandle {
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.inner.submit(Box::new(f));
    }

    /// Submit a job tracked by `wg`: `wg.wait()` returns once every job
    /// submitted through that group has finished. Unlike
    /// [`ThreadPool::join`] this is caller-scoped — it does not wait on
    /// jobs other producers pushed onto the same shared pool.
    pub fn scoped_submit<F: FnOnce() + Send + 'static>(&self, wg: &WaitGroup, f: F) {
        *lock_recover(&wg.inner.0) += 1;
        let wg = Arc::clone(&wg.inner);
        self.inner.submit(Box::new(move || {
            // Drop-guard, not a trailing decrement: a panic in f() must
            // still release the waiter or `wg.wait()` hangs forever.
            let _done = WgGuard(wg);
            f();
        }));
    }

    /// Worker threads behind this handle.
    pub fn size(&self) -> usize {
        self.inner.size
    }

    /// Jobs completed over the pool's lifetime (all producers).
    pub fn jobs_executed(&self) -> usize {
        self.inner.executed.load(Ordering::Relaxed)
    }

    /// Jobs that panicked and were contained (lifetime counter).
    pub fn panics_contained(&self) -> usize {
        self.inner.panics.load(Ordering::Relaxed)
    }

    /// Jobs queued but not yet picked up (instantaneous gauge).
    pub fn queue_depth(&self) -> usize {
        self.inner.queue_depth()
    }
}

/// Common scoped-submission surface over [`PoolHandle`] (one FIFO queue)
/// and [`StealHandle`] (stealing deques), so round dispatch is written once
/// against `&dyn ScopedSpawn`.
pub trait ScopedSpawn: Send + Sync {
    /// Submit a boxed job tracked by `wg` (see [`PoolHandle::scoped_submit`]).
    fn spawn_scoped(&self, wg: &WaitGroup, job: Box<dyn FnOnce() + Send + 'static>);
    /// Worker threads behind this handle.
    fn workers(&self) -> usize;
}

impl ScopedSpawn for PoolHandle {
    fn spawn_scoped(&self, wg: &WaitGroup, job: Box<dyn FnOnce() + Send + 'static>) {
        self.scoped_submit(wg, job);
    }

    fn workers(&self) -> usize {
        self.size()
    }
}

/// Caller-scoped completion tracker for [`PoolHandle::scoped_submit`].
#[derive(Clone, Default)]
pub struct WaitGroup {
    inner: Arc<(Mutex<usize>, Condvar)>,
}

impl WaitGroup {
    pub fn new() -> WaitGroup {
        WaitGroup::default()
    }

    /// Block until every job submitted through this group has completed.
    pub fn wait(&self) {
        let (lock, cv) = &*self.inner;
        let mut n = lock.lock().unwrap_or_else(PoisonError::into_inner);
        while *n > 0 {
            n = cv.wait(n).unwrap_or_else(PoisonError::into_inner);
        }
    }
}

pub struct ThreadPool {
    inner: Arc<Inner>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(threads: usize) -> Self {
        Self::named(threads, "qs-worker")
    }

    /// A pool whose worker threads are named `{name}-{i}` — the process
    /// now runs several kinds of pool (the shared quantization pool, a
    /// step pool per embedded batcher), and thread names are what keeps a
    /// stack dump readable.
    pub fn named(threads: usize, name: &str) -> Self {
        let threads = threads.max(1);
        let inner = Arc::new(Inner {
            state: Mutex::new(QueueState {
                jobs: VecDeque::new(),
                pending: 0,
                closed: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
            executed: AtomicUsize::new(0),
            panics: AtomicUsize::new(0),
            size: threads,
        });
        let workers = (0..threads)
            .map(|i| {
                let inner = Arc::clone(&inner);
                thread::Builder::new()
                    .name(format!("{name}-{i}"))
                    .spawn(move || worker_loop(&inner))
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { inner, workers }
    }

    /// A `Sync`, cloneable submission handle shared by all producers.
    pub fn handle(&self) -> PoolHandle {
        PoolHandle { inner: Arc::clone(&self.inner) }
    }

    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.inner.submit(Box::new(f));
    }

    /// Block until every submitted job (from every producer) has completed.
    pub fn join(&self) {
        let mut s = lock_recover(&self.inner.state);
        while s.pending > 0 {
            s = self
                .inner
                .done_cv
                .wait(s)
                .unwrap_or_else(PoisonError::into_inner);
        }
    }

    pub fn jobs_executed(&self) -> usize {
        self.inner.executed.load(Ordering::Relaxed)
    }

    /// Jobs that panicked and were contained (lifetime counter).
    pub fn panics_contained(&self) -> usize {
        self.inner.panics.load(Ordering::Relaxed)
    }

    pub fn queue_depth(&self) -> usize {
        self.inner.queue_depth()
    }

    pub fn size(&self) -> usize {
        self.inner.size
    }
}

fn worker_loop(inner: &Inner) {
    loop {
        let job = {
            let mut s = lock_recover(&inner.state);
            loop {
                // Drain queued work before honoring shutdown so drop keeps
                // the old "waits for all submitted jobs" semantics.
                if let Some(j) = s.jobs.pop_front() {
                    break Some(j);
                }
                if s.closed {
                    break None;
                }
                s = inner
                    .work_cv
                    .wait(s)
                    .unwrap_or_else(PoisonError::into_inner);
            }
        };
        let Some(job) = job else { break };
        // Containment: a panicking job must not take the worker (and with
        // it a slice of pool capacity) down, and must still decrement
        // `pending` so `join` never hangs. The state lock is NOT held
        // while the job runs, so the unwind cannot poison queue state.
        if catch_unwind(AssertUnwindSafe(job)).is_ok() {
            inner.executed.fetch_add(1, Ordering::Relaxed);
        } else {
            inner.panics.fetch_add(1, Ordering::Relaxed);
        }
        let mut s = lock_recover(&inner.state);
        s.pending -= 1;
        if s.pending == 0 {
            inner.done_cv.notify_all();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        lock_recover(&self.inner.state).closed = true;
        self.inner.work_cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

struct StealState {
    /// One deque per worker. Submissions round-robin across them; worker
    /// `i` pops its own front (FIFO for its share) and steals from the
    /// *back* of a victim's deque, so a thief takes the coldest job.
    queues: Vec<VecDeque<Job>>,
    rr: usize,
    pending: usize,
    closed: bool,
}

struct StealInner {
    state: Mutex<StealState>,
    work_cv: Condvar,
    done_cv: Condvar,
    executed: AtomicUsize,
    steals: AtomicUsize,
    /// Jobs that panicked and were contained (worker survived).
    panics: AtomicUsize,
    size: usize,
}

impl StealInner {
    fn submit(&self, job: Job) {
        {
            let mut s = lock_recover(&self.state);
            assert!(!s.closed, "pool shut down");
            s.pending += 1;
            let slot = s.rr;
            s.rr = (s.rr + 1) % self.size;
            s.queues[slot].push_back(job);
        }
        // Any worker may take it (own pop or steal), so one wake suffices.
        self.work_cv.notify_one();
    }

    fn queue_depth(&self) -> usize {
        lock_recover(&self.state).queues.iter().map(VecDeque::len).sum()
    }
}

/// A `Sync`, cloneable submission handle onto a [`StealPool`]. Same
/// contract as [`PoolHandle`]: cheap to clone, panics if used after the
/// owning pool dropped.
#[derive(Clone)]
pub struct StealHandle {
    inner: Arc<StealInner>,
}

impl StealHandle {
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.inner.submit(Box::new(f));
    }

    /// Submit a job tracked by `wg` — caller-scoped completion, exactly as
    /// [`PoolHandle::scoped_submit`].
    pub fn scoped_submit<F: FnOnce() + Send + 'static>(&self, wg: &WaitGroup, f: F) {
        *lock_recover(&wg.inner.0) += 1;
        let wg = Arc::clone(&wg.inner);
        self.inner.submit(Box::new(move || {
            // Same panic-safe drop-guard as `PoolHandle::scoped_submit`.
            let _done = WgGuard(wg);
            f();
        }));
    }

    pub fn size(&self) -> usize {
        self.inner.size
    }

    pub fn jobs_executed(&self) -> usize {
        self.inner.executed.load(Ordering::Relaxed)
    }

    /// Jobs that panicked and were contained (lifetime counter).
    pub fn panics_contained(&self) -> usize {
        self.inner.panics.load(Ordering::Relaxed)
    }

    /// Jobs a worker took from another worker's deque (lifetime counter).
    /// Nonzero under imbalanced load is the pool doing its job.
    pub fn steals(&self) -> usize {
        self.inner.steals.load(Ordering::Relaxed)
    }

    pub fn queue_depth(&self) -> usize {
        self.inner.queue_depth()
    }
}

impl ScopedSpawn for StealHandle {
    fn spawn_scoped(&self, wg: &WaitGroup, job: Box<dyn FnOnce() + Send + 'static>) {
        self.scoped_submit(wg, job);
    }

    fn workers(&self) -> usize {
        self.size()
    }
}

/// Work-stealing worker pool: the process-wide step pool behind the
/// cross-engine scheduler. Deques live under one mutex (critical sections
/// are O(1) pops/pushes; this codebase is std-only, no lock-free deques),
/// which keeps the stealing logic auditable while still removing the
/// head-of-line blocking a single FIFO queue imposes on uneven producers.
pub struct StealPool {
    inner: Arc<StealInner>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl StealPool {
    /// A stealing pool whose worker threads are named `{name}-{i}` (the
    /// scheduler names its pool `qs-sched`).
    pub fn named(threads: usize, name: &str) -> Self {
        let threads = threads.max(1);
        let inner = Arc::new(StealInner {
            state: Mutex::new(StealState {
                queues: (0..threads).map(|_| VecDeque::new()).collect(),
                rr: 0,
                pending: 0,
                closed: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
            executed: AtomicUsize::new(0),
            steals: AtomicUsize::new(0),
            panics: AtomicUsize::new(0),
            size: threads,
        });
        let workers = (0..threads)
            .map(|i| {
                let inner = Arc::clone(&inner);
                thread::Builder::new()
                    .name(format!("{name}-{i}"))
                    .spawn(move || steal_worker_loop(&inner, i))
                    .expect("spawn steal worker")
            })
            .collect();
        StealPool { inner, workers }
    }

    pub fn handle(&self) -> StealHandle {
        StealHandle { inner: Arc::clone(&self.inner) }
    }

    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.inner.submit(Box::new(f));
    }

    /// Block until every submitted job (from every producer) has completed.
    pub fn join(&self) {
        let mut s = lock_recover(&self.inner.state);
        while s.pending > 0 {
            s = self
                .inner
                .done_cv
                .wait(s)
                .unwrap_or_else(PoisonError::into_inner);
        }
    }

    pub fn jobs_executed(&self) -> usize {
        self.inner.executed.load(Ordering::Relaxed)
    }

    /// Jobs that panicked and were contained (lifetime counter).
    pub fn panics_contained(&self) -> usize {
        self.inner.panics.load(Ordering::Relaxed)
    }

    pub fn steals(&self) -> usize {
        self.inner.steals.load(Ordering::Relaxed)
    }

    pub fn queue_depth(&self) -> usize {
        self.inner.queue_depth()
    }

    pub fn size(&self) -> usize {
        self.inner.size
    }
}

fn steal_worker_loop(inner: &StealInner, me: usize) {
    loop {
        let job = {
            let mut s = lock_recover(&inner.state);
            loop {
                // Own deque first (front: FIFO for this worker's share)...
                if let Some(j) = s.queues[me].pop_front() {
                    break Some(j);
                }
                // ...then steal the coldest job off a victim's back.
                let n = inner.size;
                let stolen = (1..n)
                    .map(|d| (me + d) % n)
                    .find_map(|v| s.queues[v].pop_back());
                if let Some(j) = stolen {
                    inner.steals.fetch_add(1, Ordering::Relaxed);
                    break Some(j);
                }
                // Drain everything queued before honoring shutdown.
                if s.closed {
                    break None;
                }
                s = inner
                    .work_cv
                    .wait(s)
                    .unwrap_or_else(PoisonError::into_inner);
            }
        };
        let Some(job) = job else { break };
        // Same containment contract as `worker_loop`: the step pool must
        // survive a panicking session step with `pending` still balanced.
        if catch_unwind(AssertUnwindSafe(job)).is_ok() {
            inner.executed.fetch_add(1, Ordering::Relaxed);
        } else {
            inner.panics.fetch_add(1, Ordering::Relaxed);
        }
        let mut s = lock_recover(&inner.state);
        s.pending -= 1;
        if s.pending == 0 {
            inner.done_cv.notify_all();
        }
    }
}

impl Drop for StealPool {
    fn drop(&mut self) {
        lock_recover(&self.inner.state).closed = true;
        self.inner.work_cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.join();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
        assert_eq!(pool.jobs_executed(), 100);
        assert_eq!(pool.queue_depth(), 0);
    }

    #[test]
    fn join_with_no_jobs_returns() {
        let pool = ThreadPool::new(2);
        pool.join();
    }

    #[test]
    fn drop_waits_for_workers() {
        let counter = Arc::new(AtomicUsize::new(0));
        {
            let pool = ThreadPool::new(2);
            for _ in 0..10 {
                let c = Arc::clone(&counter);
                pool.submit(move || {
                    std::thread::sleep(std::time::Duration::from_millis(1));
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
            pool.join();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn handle_is_send_sync_clone() {
        fn assert_traits<T: Send + Sync + Clone>() {}
        assert_traits::<PoolHandle>();
        assert_traits::<WaitGroup>();
    }

    /// A wait group waits for exactly its own jobs: the fast group drains
    /// while a gated job from another group is still parked on a worker.
    #[test]
    fn scoped_wait_groups_track_only_their_jobs() {
        let pool = ThreadPool::new(2);
        let h = pool.handle();
        let gate = Arc::new((Mutex::new(false), Condvar::new()));
        let wg_slow = WaitGroup::new();
        {
            let gate = Arc::clone(&gate);
            h.scoped_submit(&wg_slow, move || {
                let (lock, cv) = &*gate;
                let mut open = lock.lock().unwrap();
                while !*open {
                    open = cv.wait(open).unwrap();
                }
            });
        }
        let wg_fast = WaitGroup::new();
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..8 {
            let c = Arc::clone(&counter);
            h.scoped_submit(&wg_fast, move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        // must return even though the gated job never finished
        wg_fast.wait();
        assert_eq!(counter.load(Ordering::SeqCst), 8);
        {
            let (lock, cv) = &*gate;
            *lock.lock().unwrap() = true;
            cv.notify_all();
        }
        wg_slow.wait();
        pool.join();
        assert_eq!(pool.jobs_executed(), 9);
    }

    /// Many producer threads share ONE pool through cloned handles; every
    /// job lands on the same worker set and the shared counters add up.
    #[test]
    fn concurrent_handles_share_one_pool() {
        let pool = ThreadPool::new(3);
        let counter = Arc::new(AtomicUsize::new(0));
        let producers: Vec<_> = (0..4)
            .map(|_| {
                let h = pool.handle();
                let counter = Arc::clone(&counter);
                thread::spawn(move || {
                    let wg = WaitGroup::new();
                    for _ in 0..25 {
                        let c = Arc::clone(&counter);
                        h.scoped_submit(&wg, move || {
                            c.fetch_add(1, Ordering::SeqCst);
                        });
                    }
                    wg.wait();
                })
            })
            .collect();
        for p in producers {
            p.join().unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
        assert_eq!(pool.jobs_executed(), 100, "one shared executed counter");
        assert_eq!(pool.size(), 3, "no extra pools spawned");
        assert_eq!(pool.queue_depth(), 0);
    }

    #[test]
    fn steal_pool_runs_all_jobs() {
        let pool = StealPool::named(4, "qs-sched");
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.join();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
        assert_eq!(pool.jobs_executed(), 100);
        assert_eq!(pool.queue_depth(), 0);
        assert_eq!(pool.size(), 4);
    }

    /// Imbalanced load forces stealing: one worker's deque is pinned behind
    /// a gated job while the rest of its round-robin share sits queued, so
    /// idle workers must steal those jobs for the fast group to drain.
    #[test]
    fn idle_steal_workers_drain_a_blocked_peers_deque() {
        let pool = StealPool::named(2, "qs-sched");
        let h = pool.handle();
        let gate = Arc::new((Mutex::new(false), Condvar::new()));
        let wg_slow = WaitGroup::new();
        {
            let gate = Arc::clone(&gate);
            h.scoped_submit(&wg_slow, move || {
                let (lock, cv) = &*gate;
                let mut open = lock.lock().unwrap();
                while !*open {
                    open = cv.wait(open).unwrap();
                }
            });
        }
        // Round-robin puts half of these behind the gated job's deque; the
        // free worker must steal them or wg_fast.wait() deadlocks.
        let wg_fast = WaitGroup::new();
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..16 {
            let c = Arc::clone(&counter);
            h.scoped_submit(&wg_fast, move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        wg_fast.wait();
        assert_eq!(counter.load(Ordering::SeqCst), 16);
        assert!(h.steals() > 0, "blocked peer's jobs were stolen");
        {
            let (lock, cv) = &*gate;
            *lock.lock().unwrap() = true;
            cv.notify_all();
        }
        wg_slow.wait();
        pool.join();
        assert_eq!(pool.jobs_executed(), 17);
    }

    /// A panicking job is contained: the worker survives to run later
    /// jobs, `join` still returns (pending balanced), and the panic is
    /// counted instead of silently eating a worker.
    #[test]
    fn panicking_job_does_not_kill_the_worker_or_hang_join() {
        let pool = ThreadPool::new(1); // one worker: it MUST survive
        pool.submit(|| panic!("injected: job panic"));
        let counter = Arc::new(AtomicUsize::new(0));
        let c = Arc::clone(&counter);
        pool.submit(move || {
            c.fetch_add(1, Ordering::SeqCst);
        });
        pool.join();
        assert_eq!(counter.load(Ordering::SeqCst), 1, "worker survived");
        assert_eq!(pool.panics_contained(), 1);
        assert_eq!(pool.jobs_executed(), 1, "panicked job not counted as executed");
        assert_eq!(pool.queue_depth(), 0);
    }

    /// A panicking scoped job still releases its wait group — the unwind
    /// runs the drop-guard, so `wg.wait()` cannot hang.
    #[test]
    fn panicking_scoped_job_still_releases_its_wait_group() {
        let pool = ThreadPool::new(2);
        let h = pool.handle();
        let wg = WaitGroup::new();
        let counter = Arc::new(AtomicUsize::new(0));
        h.scoped_submit(&wg, || panic!("injected: scoped panic"));
        let c = Arc::clone(&counter);
        h.scoped_submit(&wg, move || {
            c.fetch_add(1, Ordering::SeqCst);
        });
        wg.wait(); // hangs forever without the drop-guard
        assert_eq!(counter.load(Ordering::SeqCst), 1);
        assert_eq!(h.panics_contained(), 1);
    }

    /// The stealing pool has the same containment contract.
    #[test]
    fn steal_pool_contains_panicking_jobs() {
        let pool = StealPool::named(2, "qs-sched");
        let h = pool.handle();
        let wg = WaitGroup::new();
        for _ in 0..4 {
            h.scoped_submit(&wg, || panic!("injected: step panic"));
        }
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..8 {
            let c = Arc::clone(&counter);
            h.scoped_submit(&wg, move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        wg.wait();
        pool.join();
        assert_eq!(counter.load(Ordering::SeqCst), 8, "both workers survived");
        assert_eq!(pool.panics_contained(), 4);
        assert_eq!(pool.jobs_executed(), 8);
        assert_eq!(pool.queue_depth(), 0);
    }

    /// Both handle types drive the same generic dispatch path.
    #[test]
    fn scoped_spawn_is_object_safe_over_both_pools() {
        let fifo = ThreadPool::new(2);
        let steal = StealPool::named(2, "qs-sched");
        let fifo_h = fifo.handle();
        let steal_h = steal.handle();
        let handles: Vec<&dyn ScopedSpawn> = vec![&fifo_h, &steal_h];
        let counter = Arc::new(AtomicUsize::new(0));
        for h in handles {
            assert_eq!(h.workers(), 2);
            let wg = WaitGroup::new();
            for _ in 0..10 {
                let c = Arc::clone(&counter);
                h.spawn_scoped(
                    &wg,
                    Box::new(move || {
                        c.fetch_add(1, Ordering::SeqCst);
                    }),
                );
            }
            wg.wait();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 20);
    }
}
