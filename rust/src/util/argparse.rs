//! Tiny subcommand CLI parser (no clap offline).
//!
//! Grammar: `prog <subcommand> [--flag] [--key value] [positional...]`.
//! Flags may also be written `--key=value`.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: String,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut it = argv.into_iter().peekable();
        let mut out = Args::default();
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                out.subcommand = it.next().unwrap();
            }
        }
        while let Some(arg) = it.next() {
            if let Some(stripped) = arg.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map_or(false, |n| !n.starts_with("--")) {
                    out.options.insert(stripped.to_string(), it.next().unwrap());
                } else {
                    out.flags.push(stripped.to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_list(&self, key: &str) -> Option<Vec<usize>> {
        self.get(key)
            .map(|v| v.split(',').filter_map(|x| x.trim().parse().ok()).collect())
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_options() {
        // Grammar note: a bare --flag greedily binds a following
        // non-dash token as its value, so positionals go before flags.
        let a = parse("serve file.txt --port 8080 --buckets=512,1024 --verbose");
        assert_eq!(a.subcommand, "serve");
        assert_eq!(a.get("port"), Some("8080"));
        assert_eq!(a.get_list("buckets"), Some(vec![512, 1024]));
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional, vec!["file.txt"]);
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse("bench --quick --gamma 4");
        assert!(a.has_flag("quick"));
        assert_eq!(a.get_usize("gamma", 0), 4);
    }

    #[test]
    fn defaults() {
        let a = parse("run");
        assert_eq!(a.get_usize("missing", 7), 7);
        assert_eq!(a.get_or("x", "d"), "d");
    }

    #[test]
    fn no_subcommand() {
        let a = parse("--help");
        assert_eq!(a.subcommand, "");
        assert!(a.has_flag("help"));
    }
}
