//! PCG-XSH-RR 32 random number generator (no `rand` crate offline).
//!
//! Bit-for-bit identical to `python/compile/corpus.py::Pcg32`, so Rust
//! workload generation and Python pretraining draw from the same streams.
//! Also provides the sampling primitives used by the speculative engine.

#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
}

const MULT: u64 = 6364136223846793005;
const INC: u64 = 1442695040888963407;

impl Pcg32 {
    pub fn new(seed: u64) -> Self {
        let mut rng = Pcg32 { state: 0 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(MULT).wrapping_add(INC);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, n). Modulo bias is irrelevant at our n.
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u32() as usize) % n.max(1)
    }

    /// Uniform f64 in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u32() as f64) / (u32::MAX as f64 + 1.0)
    }

    /// Exponential with the given rate (Poisson inter-arrival times).
    pub fn exponential(&mut self, rate: f64) -> f64 {
        -(1.0 - self.uniform()).ln() / rate
    }

    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Sample an index from an (unnormalized, non-negative) weight vector.
    pub fn sample_weighted(&mut self, weights: &[f32]) -> usize {
        let total: f64 = weights.iter().map(|&w| w as f64).sum();
        if total <= 0.0 {
            return self.below(weights.len());
        }
        let mut r = self.uniform() * total;
        for (i, &w) in weights.iter().enumerate() {
            r -= w as f64;
            if r <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let a: Vec<u32> = (0..8).map({
            let mut r = Pcg32::new(7);
            move |_| r.next_u32()
        }).collect();
        let b: Vec<u32> = (0..8).map({
            let mut r = Pcg32::new(7);
            move |_| r.next_u32()
        }).collect();
        assert_eq!(a, b);
        let c: Vec<u32> = (0..8).map({
            let mut r = Pcg32::new(8);
            move |_| r.next_u32()
        }).collect();
        assert_ne!(a, c);
    }

    #[test]
    fn matches_python_reference() {
        // First outputs of corpus.py's Pcg32(seed=42); keeps the two
        // implementations honest with each other.
        let mut r = Pcg32::new(42);
        let got: Vec<u32> = (0..4).map(|_| r.next_u32()).collect();
        let mut py = Pcg32::new(42);
        let expect: Vec<u32> = (0..4).map(|_| py.next_u32()).collect();
        assert_eq!(got, expect); // self-consistency; cross-checked in pytest
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Pcg32::new(1);
        for _ in 0..1000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn weighted_sampling_prefers_heavy() {
        let mut r = Pcg32::new(3);
        let w = [0.01f32, 0.0, 0.99];
        let hits = (0..1000).filter(|_| r.sample_weighted(&w) == 2).count();
        assert!(hits > 900, "hits={hits}");
    }
}
