//! Mini property-testing framework (no proptest offline).
//!
//! `check` runs a property over N seeded random cases; on failure it
//! performs greedy input shrinking via the case's `Shrink` implementation
//! and reports the minimal failing case. Used for coordinator invariants
//! (routing, batching, cache state machine) and substrate round-trips.

use super::rng::Pcg32;

/// Types that can be generated from an RNG with a size hint.
pub trait Gen: Sized {
    fn gen(rng: &mut Pcg32, size: usize) -> Self;
}

/// Types that can propose strictly "smaller" variants of themselves.
pub trait Shrink: Sized {
    fn shrink(&self) -> Vec<Self> {
        Vec::new()
    }
}

impl Gen for usize {
    fn gen(rng: &mut Pcg32, size: usize) -> Self {
        rng.below(size.max(1))
    }
}

impl Shrink for usize {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self > 0 {
            out.push(self / 2);
            out.push(self - 1);
        }
        out
    }
}

impl Gen for u64 {
    fn gen(rng: &mut Pcg32, _size: usize) -> Self {
        rng.next_u64()
    }
}

impl Shrink for u64 {}

impl Gen for f64 {
    fn gen(rng: &mut Pcg32, _size: usize) -> Self {
        rng.uniform()
    }
}

impl Shrink for f64 {}

impl<T: Gen> Gen for Vec<T> {
    fn gen(rng: &mut Pcg32, size: usize) -> Self {
        let len = rng.below(size.max(1));
        (0..len).map(|_| T::gen(rng, size)).collect()
    }
}

impl<T: Shrink + Clone> Shrink for Vec<T> {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if !self.is_empty() {
            out.push(self[..self.len() / 2].to_vec());
            out.push(self[1..].to_vec());
            // shrink one element
            for (i, item) in self.iter().enumerate().take(4) {
                for smaller in item.shrink() {
                    let mut v = self.clone();
                    v[i] = smaller;
                    out.push(v);
                }
            }
        }
        out
    }
}

impl<A: Gen, B: Gen> Gen for (A, B) {
    fn gen(rng: &mut Pcg32, size: usize) -> Self {
        (A::gen(rng, size), B::gen(rng, size))
    }
}

impl<A: Shrink + Clone, B: Shrink + Clone> Shrink for (A, B) {
    fn shrink(&self) -> Vec<Self> {
        let mut out: Vec<Self> = self
            .0
            .shrink()
            .into_iter()
            .map(|a| (a, self.1.clone()))
            .collect();
        out.extend(self.1.shrink().into_iter().map(|b| (self.0.clone(), b)));
        out
    }
}

/// Configuration for a property run.
pub struct Config {
    pub cases: usize,
    pub size: usize,
    pub seed: u64,
    pub max_shrinks: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 200, size: 64, seed: 0xDEC0DE, max_shrinks: 400 }
    }
}

/// Run `prop` over random cases; panic with the minimal failing case.
pub fn check<T, F>(cfg: Config, prop: F)
where
    T: Gen + Shrink + Clone + std::fmt::Debug,
    F: Fn(&T) -> bool,
{
    let mut rng = Pcg32::new(cfg.seed);
    for case_idx in 0..cfg.cases {
        let input = T::gen(&mut rng, cfg.size);
        if !prop(&input) {
            let minimal = shrink_loop(input, &prop, cfg.max_shrinks);
            panic!(
                "property failed (case {case_idx}/{}), minimal input: {:?}",
                cfg.cases, minimal
            );
        }
    }
}

/// Convenience: default config.
pub fn check_default<T, F>(prop: F)
where
    T: Gen + Shrink + Clone + std::fmt::Debug,
    F: Fn(&T) -> bool,
{
    check(Config::default(), prop)
}

fn shrink_loop<T, F>(mut failing: T, prop: &F, budget: usize) -> T
where
    T: Shrink + Clone,
    F: Fn(&T) -> bool,
{
    let mut spent = 0;
    loop {
        let mut advanced = false;
        for cand in failing.shrink() {
            spent += 1;
            if spent > budget {
                return failing;
            }
            if !prop(&cand) {
                failing = cand;
                advanced = true;
                break;
            }
        }
        if !advanced {
            return failing;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check_default::<Vec<usize>, _>(|v| v.len() < 64);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics() {
        check_default::<usize, _>(|&n| n < 10);
    }

    #[test]
    fn shrink_finds_small_counterexample() {
        // Property "sum < 50" fails; the shrunk witness should be small.
        let result = std::panic::catch_unwind(|| {
            check_default::<Vec<usize>, _>(|v| v.iter().sum::<usize>() < 50)
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("minimal input"), "{msg}");
    }
}
